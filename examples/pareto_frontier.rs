//! Figure 1 reproduction: aggregated vs disaggregated Pareto frontiers
//! for Qwen3-235B on 64×H200, ISL 4096 / OSL 1024, TTFT ≤ 1000 ms.
//!
//! Run: `cargo run --release --example pareto_frontier [-- --full]`

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let rep = aiconfigurator::experiments::fig1_pareto::run(!full);
    println!("{}", rep.render());
}

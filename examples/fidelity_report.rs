//! Fidelity + efficiency report: regenerates the paper's evaluation
//! tables/figures (§5.1 Fig 6, §5.2 Fig 7, §5.3 Table 1).
//!
//! Run: `cargo run --release --example fidelity_report -- [--exp fig6|fig7|table1|all] [--full]`
//!
//! `--full` runs the paper-scale sweeps (360 + 600 + 128 fidelity
//! configurations for Fig 6, etc.); default quick mode uses reduced grids.

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");
    let exp = args
        .iter()
        .position(|a| a == "--exp")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let quick = !full;
    if exp == "fig6" || exp == "all" {
        println!("{}", aiconfigurator::experiments::fig6_agg_fidelity::run(quick).render());
    }
    if exp == "fig7" || exp == "all" {
        println!("{}", aiconfigurator::experiments::fig7_disagg_fidelity::run(quick).render());
    }
    if exp == "table1" || exp == "all" {
        println!("{}", aiconfigurator::experiments::table1_efficiency::run(quick).render());
    }
}

//! End-to-end serving driver (the E2E validation required by DESIGN.md):
//!
//! 1. Starts the config-search service with the AOT Pallas interp kernel
//!    on its hot path (PJRT), bound to the Qwen3-32B/H100/TRT-LLM
//!    context.
//! 2. Fires a batch of concurrent workload-descriptor requests at it
//!    over TCP (multiple client threads × several requests each, with
//!    varying ISL/OSL/SLA).
//! 3. Reports request latency percentiles + sustained search throughput.
//! 4. Takes the recommended configuration from the last response and
//!    validates it in the ground-truth discrete-event simulator.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`
//! (falls back to the native interpolation path without artifacts).

use std::time::Instant;

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::service::{make_request, Client, SearchServer, ServerConfig};
use aiconfigurator::util::stats;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let have_artifacts = artifacts.join("interp.hlo.txt").exists();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        artifacts: have_artifacts.then(|| artifacts.to_path_buf()),
        seed: 0xA1C0,
        ..Default::default()
    };
    let pjrt_ctx =
        have_artifacts.then_some(("qwen3-32b", "h100", 8u32, 1u32, Framework::TrtLlm));
    println!(
        "starting config-search service ({} hot path)...",
        if have_artifacts { "PJRT/Pallas" } else { "native (run `make artifacts` for PJRT)" }
    );
    let (server, addr) = SearchServer::bind(&cfg, pjrt_ctx)?;
    let stop = server.stopper();
    let server_thread = std::thread::spawn(move || server.run());

    // --- Load: 4 client threads × 6 requests each, varied workloads. ----
    let clients = 4;
    let per_client = 6;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || -> anyhow::Result<(Vec<f64>, Option<String>)> {
                let mut cl = Client::connect(&addr)?;
                let mut lat = Vec::new();
                let mut best = None;
                for i in 0..per_client {
                    let isl = [1024u32, 2048, 4000][(c + i) % 3];
                    let osl = [128u32, 256, 500][(c + i) % 3];
                    let wl = WorkloadSpec::new(
                        "qwen3-32b",
                        isl,
                        osl,
                        1500.0,
                        20.0 + 10.0 * ((c + i) % 4) as f64,
                    );
                    let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, (c * 100 + i) as u64);
                    let t = Instant::now();
                    let resp = cl.request(&req)?;
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    anyhow::ensure!(
                        resp.req_str("status")? == "ok",
                        "bad response: {}",
                        resp.to_string()
                    );
                    if let Some(top) = resp.req("top")?.as_arr().and_then(|a| a.first()) {
                        best = Some(format!(
                            "{} -> {:.1} tok/s/GPU",
                            top.req_str("config")?,
                            top.req_f64("thru_per_gpu")?
                        ));
                    }
                }
                Ok((lat, best))
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut last_best = None;
    for h in handles {
        let (lat, best) = h.join().unwrap()?;
        all_lat.extend(lat);
        if best.is_some() {
            last_best = best;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let n = all_lat.len();
    println!("\n=== service load results ===");
    println!("requests: {n} over {clients} connections in {wall:.2}s");
    println!(
        "latency  p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms  (first request includes DB build)",
        stats::percentile(&all_lat, 50.0),
        stats::percentile(&all_lat, 90.0),
        stats::percentile(&all_lat, 99.0),
    );
    println!("search throughput: {:.1} searches/s", n as f64 / wall);
    if let Some(b) = &last_best {
        println!("last recommendation: {b}");
    }

    // --- Validate a recommendation in the ground-truth simulator. -------
    println!("\n=== validating the 4000/500 recommendation in the DES ===");
    use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
    use aiconfigurator::models::{by_name, Dtype};
    use aiconfigurator::pareto;
    use aiconfigurator::perfdb::PerfDatabase;
    use aiconfigurator::search::{SearchSpace, TaskRunner};
    use aiconfigurator::silicon::Silicon;
    use aiconfigurator::simulator::{aggregated::AggregatedSim, disagg::DisaggSim, SimConfig};
    use aiconfigurator::workload::closed_loop;

    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 0xA1C0);
    let wl = WorkloadSpec::new("qwen3-32b", 4000, 500, 1500.0, 30.0);
    let report =
        TaskRunner::new(&model, &cluster, SearchSpace::default_for(&model, Framework::TrtLlm), wl.clone())
            .run(&db);
    let analysis = pareto::analyze(&report.evaluated, &wl.sla);
    let best = analysis.best().expect("feasible config");
    println!("recommended: {} (predicted {:.1} tok/s/GPU @ {:.1} tok/s/user)",
             best.cand.label(), best.est.thru_per_gpu, best.est.speed);
    let (thru, speed) = match &best.cand {
        aiconfigurator::config::Candidate::Aggregated { engine, .. } => {
            let res = AggregatedSim::new(&silicon, &model, &cluster, *engine, SimConfig::default())
                .run(&closed_loop(3 * engine.batch as usize, wl.isl, wl.osl));
            (
                res.output_tokens as f64 / (res.makespan_ms / 1000.0)
                    / engine.parallel.gpus() as f64,
                res.speed(),
            )
        }
        aiconfigurator::config::Candidate::Disaggregated { prefill, decode, x, y } => {
            let res = DisaggSim::new(
                &silicon, &model, &cluster, *prefill, *decode, *x, *y, SimConfig::default(),
            )
            .run(&closed_loop((3 * y * decode.batch).max(24) as usize, wl.isl, wl.osl));
            (res.thru_per_gpu(), res.speed())
        }
    };
    println!(
        "simulator: {thru:.1} tok/s/GPU @ {speed:.1} tok/s/user (deviation thru {:+.1}%, speed {:+.1}%)",
        (best.est.thru_per_gpu / thru - 1.0) * 100.0,
        (best.est.speed / speed - 1.0) * 100.0
    );

    // Shut the server down (poke the accept loop with a dummy connect).
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
    let _ = server_thread.join();
    println!("\nserve_e2e OK");
    Ok(())
}

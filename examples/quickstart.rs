//! Quickstart: the paper's 5-step workflow end to end, in ~40 lines.
//!
//! 1. PerfDatabase — offline profiling (synthetic silicon here).
//! 2. TaskRunner — enumerate the valid configuration space.
//! 3. InferenceSession — estimate TTFT/TPOT/throughput per candidate.
//! 4. Pareto analyzer — SLA filter + ranking.
//! 5. Generator — emit ready-to-run launch files.
//!
//! Run: `cargo run --release --example quickstart`

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::generator;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::pareto;
use aiconfigurator::perfdb::PerfDatabase;
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;

fn main() -> anyhow::Result<()> {
    // Deployment context: Qwen3-32B on one 8xH100 node, TensorRT-LLM.
    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());

    // Workload + SLA: chat-style, TTFT <= 1s, >= 30 tokens/s per user.
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 1000.0, 30.0);

    // Step 1: build (or load) the operator performance database.
    println!("[1/5] profiling operator database...");
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 42);
    println!("      simulated campaign cost: {:.1} GPU-hours", db.profile_cost_hours);

    // Steps 2-3: enumerate + estimate every candidate configuration.
    println!("[2/5] + [3/5] searching the configuration space...");
    let space = SearchSpace::default_for(&model, Framework::TrtLlm);
    let report = TaskRunner::new(&model, &cluster, space, wl.clone()).run(&db);
    println!(
        "      {} configs priced, {} candidates, {:.3}s ({:.2} ms median/config)",
        report.configs_priced,
        report.evaluated.len(),
        report.elapsed_s,
        report.median_config_ms
    );

    // Step 4: Pareto analysis under the SLA.
    println!("[4/5] Pareto analysis...");
    let analysis = pareto::analyze(&report.evaluated, &wl.sla);
    println!("      {} SLA-feasible candidates; top 5:", analysis.feasible.len());
    for e in analysis.feasible.iter().take(5) {
        println!(
            "      {:>8.1} tok/s/GPU @ {:>5.1} tok/s/user, TTFT {:>6.1} ms — {}",
            e.est.thru_per_gpu, e.est.speed, e.est.ttft_ms, e.cand.label()
        );
    }

    // Step 5: generate launch files for the winner.
    let best = analysis.best().expect("no feasible config");
    let bundle = generator::generate(&best.cand, "Qwen/Qwen3-32B-FP8", &wl);
    println!("[5/5] launch bundle for {}:", best.cand.label());
    for (name, _) in &bundle.files {
        println!("      {name}");
    }
    let dir = std::env::temp_dir().join("aiconfigurator_quickstart");
    bundle.write_to(&dir)?;
    println!("      written to {}", dir.display());
    Ok(())
}

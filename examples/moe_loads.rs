//! Figure 5 reproduction + MoE power-law kernel demo.
//!
//! Prints the expert-load skew table (α sweep) from the native sampler,
//! then — if `artifacts/` is built — runs the AOT-compiled Pallas
//! power-law kernel through PJRT and cross-checks it against the native
//! implementation (loads sum, imbalance ordering).
//!
//! Run: `make artifacts && cargo run --release --example moe_loads`

use aiconfigurator::runtime::{PjrtService, MOE_EXPERTS};
use aiconfigurator::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Figure 5 table (native path).
    let rep = aiconfigurator::experiments::fig5_powerlaw::run(false);
    println!("{}", rep.render());

    // PJRT kernel cross-check (optional: requires `make artifacts`).
    let dir = std::path::Path::new("artifacts");
    if !dir.join("moe_powerlaw.hlo.txt").exists() {
        println!("artifacts/ not built — skipping PJRT kernel demo (run `make artifacts`)");
        return Ok(());
    }
    // The interp executable needs a grid payload; zeros are fine here.
    let grids = vec![0f32; aiconfigurator::perfdb::tables::GRID_LEN];
    let svc = PjrtService::start(dir, grids)?;

    let alphas = [0.05f32, 0.6, 1.2];
    let s = alphas.len();
    let mut rng = Rng::new(7);
    let u: Vec<f32> = (0..s * MOE_EXPERTS).map(|_| rng.f64_open() as f32).collect();
    let params: Vec<f32> = alphas.iter().flat_map(|_| [1.0, 100.0, 8192.0]).collect();
    let (loads, imb) = svc.moe(&u, &alphas, &params)?;

    println!("PJRT Pallas kernel (S={s} scenarios, E={MOE_EXPERTS} experts):");
    for (i, a) in alphas.iter().enumerate() {
        let row = &loads[i * MOE_EXPERTS..(i + 1) * MOE_EXPERTS];
        let sum: f32 = row.iter().sum();
        let mut sorted: Vec<f32> = row.to_vec();
        sorted.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let top20: f32 = sorted[..MOE_EXPERTS / 5].iter().sum::<f32>() / sum;
        println!(
            "  alpha={a:<4} tokens={sum:>8.0} imbalance={:>6.2} top-20% share={:>5.1}%",
            imb[i],
            top20 * 100.0
        );
        assert!((sum - 8192.0).abs() < 2.0, "loads must sum to T*K");
    }
    assert!(imb[2] > imb[0], "imbalance must grow with alpha");
    println!("kernel cross-check OK");
    Ok(())
}

//! §5.4 case study (Figure 8 + Table 2): optimal aggregated vs
//! disaggregated serving of Qwen3-32B-FP8 on 8×H200 under a production
//! SLA (TTFT ≤ 1200 ms, ≥ 60 tokens/s/user), with ground-truth
//! validation in the discrete-event simulator and generated launch files.
//!
//! Run: `cargo run --release --example case_study [-- --full]`

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let rep = aiconfigurator::experiments::fig8_case_study::run(!full);
    println!("{}", rep.render());
}

use aiconfigurator::config::*;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::perfmodel::{self, memory};
use aiconfigurator::search::SearchSpace;
use aiconfigurator::silicon::Silicon;
use aiconfigurator::simulator::{aggregated::AggregatedSim, SimConfig};
use aiconfigurator::workload::closed_loop;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::perfdb::PerfDatabase;

fn main() {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
    let model = by_name("qwen3-235b").unwrap();
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    println!("{:>6} {:>5} {:>5} {:>3} {:>3} | {:>9} {:>9} {:>7} | {:>9} {:>9}",
             "isl","osl","conc","tp","ep","pred_tpot","sim_tpot","err%","pred_ttft","sim_ttft");
    for &(isl, osl, conc, tp, ep) in &[
        (128u32,128u32,4u32,1u32,1u32),(128,128,32,8,8),(512,256,16,4,4),(1024,128,4,2,2),
        (2048,256,32,8,1),(4096,512,32,8,8),(4096,128,4,1,1),(4096,512,4,8,8),
        (128,512,32,2,2),(1024,512,16,8,4)] {
        let mut eng = EngineConfig{ framework: Framework::TrtLlm,
            parallel: ParallelSpec{tp,pp:1,ep,dp:1}, batch: conc,
            weight_dtype: Dtype::Fp8, kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: aiconfigurator::topology::Placement::packed()};
        eng.batch = conc;
        if !SearchSpace::layout_valid(&model, &cluster, &eng.parallel) ||
           !memory::fits(&model, cluster.gpu.mem_bytes(), &eng, isl, osl) { continue; }
        let wl = WorkloadSpec::new("qwen3-235b", isl, osl, f64::INFINITY, 0.0);
        let cand = Candidate::Aggregated{engine: eng, replicas: 1};
        let est = perfmodel::estimate(&db, &model, &cluster, &cand, &wl);
        let sim = AggregatedSim::new(&sil, &model, &cluster, eng, SimConfig::default())
            .run(&closed_loop(2*conc as usize, isl, osl));
        let err = (est.tpot_ms - sim.mean_tpot_ms())/sim.mean_tpot_ms()*100.0;
        println!("{:>6} {:>5} {:>5} {:>3} {:>3} | {:>9.2} {:>9.2} {:>7.1} | {:>9.0} {:>9.0}",
                 isl, osl, conc, tp, ep, est.tpot_ms, sim.mean_tpot_ms(), err,
                 est.ttft_ms, sim.mean_ttft_ms());
    }
}

//! Minimal, dependency-free shim of the `anyhow` API surface used by
//! this repository (the build environment has no crates.io access).
//!
//! Provides [`Error`], [`Result`], and the `anyhow!` / `bail!` /
//! `ensure!` macros. Any `std::error::Error + Send + Sync + 'static`
//! converts into [`Error`] via `?`, and the `{:#}` alternate display
//! used by the CLI prints the same message as `{}` (this shim keeps a
//! flat message instead of a context chain — `.context()` is not part
//! of the subset).

use std::fmt;

/// A flattened error: message only (no backtrace, no cause chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (the same trick
// real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with a defaulted error type, as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros() {
        let e: Error = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        assert_eq!(format!("{e:#}"), "x = 7");
        assert_eq!(format!("{e:?}"), "x = 7");

        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", true);
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert!(f(false).is_err());

        fn g() -> Result<()> {
            bail!("boom {}", 3)
        }
        assert_eq!(g().unwrap_err().to_string(), "boom 3");
    }

    #[test]
    fn ensure_without_message() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("condition failed"));
    }
}

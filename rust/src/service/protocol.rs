//! Versioned wire protocol for the L3 service.
//!
//! Two request dialects share one dispatch path:
//!
//! * **v2 envelope** — `{"v": 2, "id": ..., "op": "search" | "sweep" |
//!   "plan" | "validate" | "replan" | "stats", ...}` with typed error responses
//!   `{"v": 2, "id": ..., "error": {"code": ..., "message": ...}}`.
//! * **legacy (v1)** — the original bare requests: the operation is
//!   inferred from which field is present (`plan` → plan, `workloads` →
//!   sweep, `workload` → search). Responses keep their original shape
//!   (string `error`, flat `status`) and are tagged `"v": 1`; pinned
//!   tests hold the rest of the v1 payload byte-compatible.
//!
//! The v1 → v2 mapping table lives in DESIGN.md §8. This module also
//! owns [`RequestKey`] — the normalized identity the coalescer uses to
//! detect identical in-flight requests — and [`SpaceOverrides`], the one
//! code path through which both the CLI flags and service requests
//! mutate a [`SearchSpace`], so the two frontends can never diverge on
//! validation.

use crate::config::{ServingMode, WorkloadSpec};
use crate::frameworks::Framework;
use crate::hardware::{gpu_by_name, ClusterSpec};
use crate::models::{by_name, ModelArch};
use crate::search::SearchSpace;
use crate::util::json::{self, Json};

/// The operations the service answers. `validate` and `replan` are
/// v2-only: the legacy dialect predates them, so [`infer_legacy_op`]
/// never produces them and v1 clients cannot reach them by accident.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Search,
    Sweep,
    Plan,
    Validate,
    Replan,
    Stats,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Search => "search",
            OpKind::Sweep => "sweep",
            OpKind::Plan => "plan",
            OpKind::Validate => "validate",
            OpKind::Replan => "replan",
            OpKind::Stats => "stats",
        }
    }

    pub fn parse(s: &str) -> Option<OpKind> {
        match s {
            "search" => Some(OpKind::Search),
            "sweep" => Some(OpKind::Sweep),
            "plan" => Some(OpKind::Plan),
            "validate" => Some(OpKind::Validate),
            "replan" => Some(OpKind::Replan),
            "stats" => Some(OpKind::Stats),
            _ => None,
        }
    }
}

/// Machine-readable error class carried by v2 error responses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// The request is malformed or names unknown entities.
    BadRequest,
    /// Admission control shed the request (queue over its limit).
    Overloaded,
    /// `"v"` names a protocol version this server does not speak.
    UnsupportedVersion,
    /// A v2 envelope named an unknown `"op"`.
    UnsupportedOp,
    /// The server failed while computing (worker panic, lost result).
    Internal,
}

impl ErrCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrCode::BadRequest => "bad_request",
            ErrCode::Overloaded => "overloaded",
            ErrCode::UnsupportedVersion => "unsupported_version",
            ErrCode::UnsupportedOp => "unsupported_op",
            ErrCode::Internal => "internal",
        }
    }
}

/// A typed service error: code for machines, message for humans. v1
/// clients see only the message (their `error` field is a string).
#[derive(Clone, Debug)]
pub struct ServiceError {
    pub code: ErrCode,
    pub message: String,
}

impl ServiceError {
    pub fn bad_request(message: impl Into<String>) -> ServiceError {
        ServiceError { code: ErrCode::BadRequest, message: message.into() }
    }

    pub fn overloaded(message: impl Into<String>) -> ServiceError {
        ServiceError { code: ErrCode::Overloaded, message: message.into() }
    }

    pub fn internal(message: impl Into<String>) -> ServiceError {
        ServiceError { code: ErrCode::Internal, message: message.into() }
    }
}

/// A parsed request envelope: protocol version, correlation id, the
/// operation, and the body the operation handlers read fields from.
/// For v1 the body is the whole bare request (field names are shared
/// between the dialects, so handlers are version-blind).
#[derive(Clone, Debug)]
pub struct Envelope {
    pub v: u8,
    pub id: Option<Json>,
    pub op: OpKind,
    pub body: Json,
    /// Caller-supplied correlation token, echoed verbatim in the
    /// response and the request log line. Does not shape the answer, so
    /// it is stripped from coalescing keys.
    pub trace_id: Option<String>,
    /// `"explain": true` — attach the explainability report to
    /// search/sweep/plan payloads. Shapes the answer, so it is *part*
    /// of the coalescing key.
    pub explain: bool,
}

/// Infer the operation of a bare (v1) request from its fields.
fn infer_legacy_op(req: &Json) -> Result<OpKind, ServiceError> {
    if req.get("plan").is_some() {
        Ok(OpKind::Plan)
    } else if req.get("workloads").is_some() {
        Ok(OpKind::Sweep)
    } else if req.get("workload").is_some() {
        Ok(OpKind::Search)
    } else {
        Err(ServiceError::bad_request(
            "request names no operation: send a v2 envelope {\"v\":2,\"op\":...} or a \
             legacy 'workload'/'workloads'/'plan' field",
        ))
    }
}

/// Parse a request into an [`Envelope`], classifying it as v1 or v2.
pub fn parse_envelope(req: &Json) -> Result<Envelope, ServiceError> {
    let id = req.get("id").cloned();
    let trace_id = match req.get("trace_id") {
        None => None,
        Some(t) => Some(
            t.as_str()
                .ok_or_else(|| ServiceError::bad_request("'trace_id' must be a string"))?
                .to_string(),
        ),
    };
    let explain = match req.get("explain") {
        None => false,
        Some(e) => e
            .as_bool()
            .ok_or_else(|| ServiceError::bad_request("'explain' must be a boolean"))?,
    };
    let version = match req.get("v") {
        None => 1,
        Some(v) => {
            let x = v.as_f64().filter(|x| x.fract() == 0.0).ok_or_else(|| {
                ServiceError::bad_request("'v' must be an integer protocol version")
            })?;
            x as i64
        }
    };
    match version {
        1 => Ok(Envelope {
            v: 1,
            id,
            op: infer_legacy_op(req)?,
            body: req.clone(),
            trace_id,
            explain,
        }),
        2 => {
            let op_name = req.get("op").and_then(|o| o.as_str()).ok_or_else(|| {
                ServiceError::bad_request("a v2 envelope requires an 'op' string")
            })?;
            let op = OpKind::parse(op_name).ok_or_else(|| ServiceError {
                code: ErrCode::UnsupportedOp,
                message: format!(
                    "unknown op '{op_name}' (expected search|sweep|plan|validate|replan|stats)"
                ),
            })?;
            Ok(Envelope { v: 2, id, op, body: req.clone(), trace_id, explain })
        }
        other => Err(ServiceError {
            code: ErrCode::UnsupportedVersion,
            message: format!("unsupported protocol version {other} (this server speaks v1 and v2)"),
        }),
    }
}

/// Tag a success payload with the request's protocol version and echo
/// its correlation id. Handlers produce version-blind payloads; this is
/// the only place response envelopes are stamped.
pub fn stamp(mut payload: Json, env: &Envelope) -> Json {
    payload.set("v", json::num(env.v as f64));
    if let Some(id) = &env.id {
        payload.set("id", id.clone());
    }
    if let Some(tid) = &env.trace_id {
        payload.set("trace_id", json::s(tid));
    }
    payload
}

/// Error response in the dialect the request spoke. v1 keeps the
/// original flat string shape (plus the `"v"` tag); v2 carries the
/// typed `{code, message}` object.
pub fn error_response(env: Option<&Envelope>, err: &ServiceError) -> Json {
    match env {
        Some(e) if e.v == 1 => {
            let mut o = Json::obj();
            o.set("v", json::num(1.0))
                .set("status", json::s("error"))
                .set("error", json::s(&err.message));
            if let Some(id) = &e.id {
                o.set("id", id.clone());
            }
            o
        }
        other => {
            let mut detail = Json::obj();
            detail
                .set("code", json::s(err.code.as_str()))
                .set("message", json::s(&err.message));
            let mut o = Json::obj();
            o.set("v", json::num(2.0)).set("status", json::s("error")).set("error", detail);
            if let Some(id) = other.and_then(|e| e.id.as_ref()) {
                o.set("id", id.clone());
            }
            o
        }
    }
}

/// Error response for a request that failed before an [`Envelope`]
/// existed (unparseable JSON, bad `"v"` field, no recognizable op).
/// Requests that did not explicitly ask for v2 answer in the v1 shape —
/// the legacy dialect is the default, so pre-v2 clients keep seeing
/// string errors for garbage input.
pub fn error_for_request(req: &Json, err: &ServiceError) -> Json {
    let asked_v2 = matches!(req.get("v").and_then(|v| v.as_f64()), Some(x) if x >= 2.0);
    let env = Envelope {
        v: if asked_v2 { 2 } else { 1 },
        id: req.get("id").cloned(),
        op: OpKind::Stats,
        body: Json::Null,
        trace_id: req.get("trace_id").and_then(|t| t.as_str()).map(str::to_string),
        explain: false,
    };
    error_response(Some(&env), err)
}

/// Normalized identity of a request for the coalescer: two requests
/// with the same key are guaranteed to produce the same payload (modulo
/// the stamped `v`/`id` and the wall-clock `elapsed_ms`), so in-flight
/// duplicates share one computation. Built from the *parsed* structs —
/// not raw text — so default-elision, field order and v1-vs-v2 framing
/// all normalize away.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RequestKey(String);

impl RequestKey {
    pub fn as_str(&self) -> &str {
        &self.0
    }

    pub fn into_string(self) -> String {
        self.0
    }

    /// Opaque key for unit tests that don't want to build a request.
    #[cfg(test)]
    pub(crate) fn test_key(s: &str) -> RequestKey {
        RequestKey(s.to_string())
    }
}

/// The request body minus the envelope-only fields (`v`, `id`, `op`,
/// `trace_id`) — everything left shapes the answer and belongs in a
/// canonical-body coalescing key.
fn canonical_body(body: &Json) -> Json {
    let mut b = body.clone();
    if let Json::Obj(m) = &mut b {
        m.remove("v");
        m.remove("id");
        m.remove("op");
        m.remove("trace_id");
    }
    b
}

/// Compute the coalescing key for an envelope. Errors here are the
/// same validation errors the handler would raise, surfaced before the
/// request is queued.
pub fn request_key(env: &Envelope) -> anyhow::Result<RequestKey> {
    let body = &env.body;
    let key = match env.op {
        OpKind::Search => {
            let wl = WorkloadSpec::from_json(body.req("workload")?)?;
            let pc = parse_context(body, &wl.model)?;
            format!(
                "search|{}|{}|explain:{}",
                pc.norm_json().to_string(),
                wl.to_json().to_string(),
                env.explain
            )
        }
        OpKind::Sweep => {
            let wls = parse_sweep_workloads(body)?;
            let pc = parse_context(body, &wls[0].model)?;
            let scenarios: Vec<String> =
                wls.iter().map(|w| w.to_json().to_string()).collect();
            format!(
                "sweep|{}|{}|explain:{}",
                pc.norm_json().to_string(),
                scenarios.join(";"),
                env.explain
            )
        }
        OpKind::Plan => {
            // Plans have no single normalized context (per-leg fabrics);
            // key on the canonical body minus the envelope fields. The
            // BTreeMap behind Json::Obj serializes keys sorted, so field
            // order normalizes away even without full parsing.
            // `explain` stays in the body — it shapes the payload;
            // `trace_id` is pure correlation and must not break
            // coalescing.
            format!("plan|{}", canonical_body(body).to_string())
        }
        OpKind::Validate => {
            // Same canonical-body keying as Plan: a validate request is
            // a plan request plus the replay knobs, all of which shape
            // the report and so belong in the key.
            format!("validate|{}", canonical_body(body).to_string())
        }
        OpKind::Replan => {
            // A replan request is a plan request plus its delta; both
            // shape the answer, so both belong in the key.
            format!("replan|{}", canonical_body(body).to_string())
        }
        OpKind::Stats => "stats".to_string(),
    };
    Ok(RequestKey(key))
}

/// Workloads of a sweep request, validated (non-empty, one model).
pub fn parse_sweep_workloads(body: &Json) -> anyhow::Result<Vec<WorkloadSpec>> {
    let wls_json = body
        .req("workloads")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("'workloads' must be an array"))?;
    anyhow::ensure!(!wls_json.is_empty(), "'workloads' array is empty");
    let wls: Vec<WorkloadSpec> = wls_json
        .iter()
        .map(WorkloadSpec::from_json)
        .collect::<anyhow::Result<Vec<_>>>()?;
    anyhow::ensure!(
        wls.iter().all(|w| w.model == wls[0].model),
        "all workloads in a sweep must target the same model"
    );
    Ok(wls)
}

/// The cluster trio shared by every operation — `plan` reads exactly
/// these three fields, search/sweep read them plus the GPU/fabric pair
/// (a plan's GPUs and fabrics are per fleet leg).
pub fn parse_cluster_base(req: &Json) -> anyhow::Result<(u32, u32, Framework)> {
    let gpn = req.f64_or("gpus_per_node", 8.0) as u32;
    let nodes = req.f64_or("num_nodes", 1.0) as u32;
    let fw_name = req.str_or("framework", "trtllm");
    let fw = Framework::parse(fw_name)
        .ok_or_else(|| anyhow::anyhow!("unknown framework '{fw_name}'"))?;
    Ok((gpn, nodes, fw))
}

/// Search-space overrides: the one validated mutation path shared by
/// the CLI flags (`--modes`, `--flag-sweep`, `--max-num-tokens`,
/// `--kv-frac`, `--cuda-graph`) and the service request fields
/// (`modes`, `flag_sweep`, `flags.*`). Both frontends parse into this
/// struct and call [`SpaceOverrides::apply`], so range rules (token
/// counts positive, kv fractions in (0, 1], no `static` mode) can never
/// fork between them again.
#[derive(Clone, Debug, Default)]
pub struct SpaceOverrides {
    pub modes: Option<Vec<ServingMode>>,
    pub flag_sweep: Option<bool>,
    pub max_num_tokens: Option<Vec<u32>>,
    pub kv_frac: Option<Vec<f64>>,
    pub cuda_graph: Option<Vec<bool>>,
}

impl SpaceOverrides {
    /// Parse the service-request form. Overrides are validated loudly:
    /// a wrong-typed value is an error, never a silent fall-through to
    /// the resolver.
    pub fn from_request(req: &Json) -> anyhow::Result<SpaceOverrides> {
        let mut ov = SpaceOverrides::default();
        if let Some(modes) = req.get("modes") {
            let arr = modes
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'modes' must be an array of strings"))?;
            ov.modes = Some(
                arr.iter()
                    .map(|m| {
                        m.as_str().and_then(ServingMode::parse).ok_or_else(|| {
                            anyhow::anyhow!("unknown serving mode {m:?} in 'modes'")
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            );
        }
        if let Some(v) = req.get("flag_sweep") {
            ov.flag_sweep = Some(
                v.as_bool()
                    .ok_or_else(|| anyhow::anyhow!("'flag_sweep' must be a boolean"))?,
            );
        }
        if let Some(flags) = req.get("flags") {
            if let Some(v) = flags.get("max_num_tokens") {
                let x = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("flags.max_num_tokens must be a number"))?;
                anyhow::ensure!(
                    (1.0..=u32::MAX as f64).contains(&x) && x.fract() == 0.0,
                    "flags.max_num_tokens must be a positive integer"
                );
                ov.max_num_tokens = Some(vec![x as u32]);
            }
            if let Some(v) = flags.get("kv_frac") {
                let x = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("flags.kv_frac must be a number"))?;
                ov.kv_frac = Some(vec![x]);
            }
            if let Some(v) = flags.get("cuda_graph") {
                let b = v
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("flags.cuda_graph must be a boolean"))?;
                ov.cuda_graph = Some(vec![b]);
            }
        }
        Ok(ov)
    }

    /// Apply to a space, enforcing the shared range rules.
    pub fn apply(&self, space: &mut SearchSpace) -> anyhow::Result<()> {
        if let Some(modes) = &self.modes {
            space.modes = modes.clone();
        }
        // `static` parses but is not a searchable deployment shape:
        // reject loudly instead of pricing nothing (see crate::search).
        crate::search::ensure_searchable_modes(&space.modes)?;
        if let Some(fs) = self.flag_sweep {
            space.flag_sweep = fs;
        }
        if let Some(mnt) = &self.max_num_tokens {
            anyhow::ensure!(!mnt.is_empty(), "max_num_tokens named no values");
            anyhow::ensure!(mnt.iter().all(|&n| n >= 1), "max_num_tokens values must be positive");
            space.max_num_tokens = mnt.clone();
        }
        if let Some(kv) = &self.kv_frac {
            anyhow::ensure!(!kv.is_empty(), "kv_frac named no values");
            anyhow::ensure!(
                kv.iter().all(|&x| x > 0.0 && x <= 1.0),
                "kv_frac values must be in (0, 1]"
            );
            space.kv_frac = kv.clone();
        }
        if let Some(cg) = &self.cuda_graph {
            space.cuda_graph = cg.clone();
        }
        Ok(())
    }
}

/// Deployment context parsed from a request's shared fields — one
/// parser for the search and sweep handlers *and* the coalescing-key
/// builder, so no two paths can interpret request fields differently.
/// Pure: resolving the warm database/calibration for the context is the
/// server state's job ([`super::State`]).
pub struct ParsedContext {
    pub model: ModelArch,
    pub model_name: String,
    pub gpu_name: String,
    pub fabric_name: String,
    pub gpn: u32,
    pub nodes: u32,
    pub fw: Framework,
    pub cluster: ClusterSpec,
    pub top_k: usize,
    pub space: SearchSpace,
    /// Tiered fabrics price rank layouts; a PJRT-bound server must
    /// reject them (the AOT kernel prices the packed layout only).
    pub placement_aware: bool,
}

impl ParsedContext {
    /// The warm-cache key for this context.
    pub fn db_key(&self) -> super::DbKey {
        (
            self.model_name.clone(),
            self.gpu_name.clone(),
            self.gpn,
            self.nodes,
            self.fw.name().to_string(),
            self.fabric_name.clone(),
        )
    }

    /// Canonical JSON of everything that shapes the answer (defaults
    /// resolved, fields sorted by the BTreeMap serializer) — the
    /// context half of a search/sweep [`RequestKey`].
    pub fn norm_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", json::s(&self.model_name))
            .set("gpu", json::s(&self.gpu_name))
            .set("gpus_per_node", json::num(self.gpn as f64))
            .set("num_nodes", json::num(self.nodes as f64))
            .set("framework", json::s(self.fw.name()))
            .set("fabric", json::s(&self.fabric_name))
            .set("top_k", json::num(self.top_k as f64))
            .set(
                "modes",
                json::arr(self.space.modes.iter().map(|m| json::s(m.name()))),
            )
            .set("flag_sweep", Json::Bool(self.space.flag_sweep))
            .set(
                "max_num_tokens",
                json::arr(self.space.max_num_tokens.iter().map(|&n| json::num(n as f64))),
            )
            .set("kv_frac", json::farr(&self.space.kv_frac))
            .set(
                "cuda_graph",
                json::arr(self.space.cuda_graph.iter().map(|&b| Json::Bool(b))),
            );
        o
    }
}

/// Parse the shared search/sweep context fields of a request.
pub fn parse_context(req: &Json, model_name: &str) -> anyhow::Result<ParsedContext> {
    let (gpn, nodes, fw) = parse_cluster_base(req)?;
    let gpu_name = req.str_or("gpu", "h100").to_string();
    let top_k = req.f64_or("top_k", 5.0) as usize;
    // Optional tiered fabric ("hgx-h100", "gb200-nvl72", ...); absent =
    // the legacy flat topology, bit-for-bit the pre-fabric behavior.
    let fabric_name = req.str_or("fabric", "legacy").to_string();
    let fabric = crate::topology::fabric::by_name(&fabric_name, gpn)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric '{fabric_name}'"))?;
    let model =
        by_name(model_name).ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let gpu =
        gpu_by_name(&gpu_name).ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}'"))?;
    let cluster = ClusterSpec::with_fabric(gpu, gpn, nodes, fabric);
    let mut space = SearchSpace::default_for(&model, fw);
    SpaceOverrides::from_request(req)?.apply(&mut space)?;
    Ok(ParsedContext {
        model,
        model_name: model_name.to_string(),
        gpu_name,
        fabric_name,
        gpn,
        nodes,
        fw,
        cluster,
        top_k,
        space,
        placement_aware: fabric.placement_aware(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v2_envelope_parses_and_v1_is_inferred() {
        let v2 = json::parse(r#"{"v": 2, "id": 7, "op": "search", "workload": {}}"#).unwrap();
        let env = parse_envelope(&v2).unwrap();
        assert_eq!(env.v, 2);
        assert_eq!(env.op, OpKind::Search);
        assert_eq!(env.id.as_ref().and_then(|i| i.as_f64()), Some(7.0));

        let v1 = json::parse(r#"{"workloads": [], "id": 3}"#).unwrap();
        let env = parse_envelope(&v1).unwrap();
        assert_eq!(env.v, 1);
        assert_eq!(env.op, OpKind::Sweep);

        let plan = json::parse(r#"{"plan": {}}"#).unwrap();
        assert_eq!(parse_envelope(&plan).unwrap().op, OpKind::Plan);

        // `validate` exists only as an explicit v2 op — the legacy
        // field-sniffing path must keep reading a bare `plan` field as
        // a plan request, never a validation.
        let val = json::parse(r#"{"v": 2, "op": "validate", "plan": {}}"#).unwrap();
        assert_eq!(parse_envelope(&val).unwrap().op, OpKind::Validate);
    }

    #[test]
    fn bad_versions_and_ops_are_typed_errors() {
        let v9 = json::parse(r#"{"v": 9, "op": "search"}"#).unwrap();
        assert_eq!(parse_envelope(&v9).unwrap_err().code, ErrCode::UnsupportedVersion);

        let noop = json::parse(r#"{"v": 2, "id": 1}"#).unwrap();
        assert_eq!(parse_envelope(&noop).unwrap_err().code, ErrCode::BadRequest);

        let weird = json::parse(r#"{"v": 2, "op": "warp"}"#).unwrap();
        assert_eq!(parse_envelope(&weird).unwrap_err().code, ErrCode::UnsupportedOp);

        let bare = json::parse(r#"{"hello": 1}"#).unwrap();
        assert_eq!(parse_envelope(&bare).unwrap_err().code, ErrCode::BadRequest);
    }

    #[test]
    fn error_responses_match_the_request_dialect() {
        let err = ServiceError::bad_request("boom");
        let v1 = json::parse(r#"{"workload": {}, "id": 4}"#).unwrap();
        let env = parse_envelope(&v1).unwrap();
        let resp = error_response(Some(&env), &err);
        assert_eq!(resp.req_str("status").unwrap(), "error");
        assert_eq!(resp.req_str("error").unwrap(), "boom");
        assert_eq!(resp.req_f64("v").unwrap(), 1.0);

        let v2 = json::parse(r#"{"v": 2, "op": "search", "id": 4}"#).unwrap();
        let env = parse_envelope(&v2).unwrap();
        let resp = error_response(Some(&env), &err);
        assert_eq!(resp.req("error").unwrap().req_str("code").unwrap(), "bad_request");
        assert_eq!(resp.req("error").unwrap().req_str("message").unwrap(), "boom");
        assert_eq!(resp.req_f64("id").unwrap(), 4.0);
        assert_eq!(resp.req_f64("v").unwrap(), 2.0);
    }

    #[test]
    fn request_key_normalizes_versions_defaults_and_field_order() {
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        // v1 with defaults elided vs v2 with defaults spelled out, in a
        // different field order: one key.
        let mut v1 = Json::obj();
        v1.set("workload", wl.to_json()).set("id", json::num(1.0));
        let mut v2 = Json::obj();
        v2.set("v", json::num(2.0))
            .set("op", json::s("search"))
            .set("id", json::num(99.0))
            .set("framework", json::s("trtllm"))
            .set("gpu", json::s("h100"))
            .set("gpus_per_node", json::num(8.0))
            .set("num_nodes", json::num(1.0))
            .set("workload", wl.to_json());
        let k1 = request_key(&parse_envelope(&v1).unwrap()).unwrap();
        let k2 = request_key(&parse_envelope(&v2).unwrap()).unwrap();
        assert_eq!(k1, k2);

        // A different workload is a different key.
        let wl2 = WorkloadSpec::new("llama3.1-8b", 1024, 64, 2000.0, 5.0);
        let mut other = Json::obj();
        other.set("workload", wl2.to_json());
        let k3 = request_key(&parse_envelope(&other).unwrap()).unwrap();
        assert_ne!(k1, k3);

        // So is the same workload with a space override.
        let mut pinned = Json::obj();
        let mut flags = Json::obj();
        flags.set("kv_frac", json::num(0.8));
        pinned.set("workload", wl.to_json()).set("flags", flags);
        let k4 = request_key(&parse_envelope(&pinned).unwrap()).unwrap();
        assert_ne!(k1, k4);
    }

    #[test]
    fn space_overrides_validate_ranges_for_both_frontends() {
        let model = by_name("llama3.1-8b").unwrap();
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let ov = SpaceOverrides { kv_frac: Some(vec![1.5]), ..Default::default() };
        assert!(ov.apply(&mut space).is_err(), "kv_frac > 1 must be rejected");
        let ov = SpaceOverrides { max_num_tokens: Some(vec![0]), ..Default::default() };
        assert!(ov.apply(&mut space).is_err(), "zero token budget must be rejected");
        let ov = SpaceOverrides {
            kv_frac: Some(vec![0.8]),
            max_num_tokens: Some(vec![4096]),
            flag_sweep: Some(true),
            ..Default::default()
        };
        ov.apply(&mut space).unwrap();
        assert_eq!(space.kv_frac, vec![0.8]);
        assert_eq!(space.max_num_tokens, vec![4096]);
        assert!(space.flag_sweep);
    }

    #[test]
    fn trace_id_echoes_but_never_splits_coalescing() {
        let a = json::parse(
            r#"{"v": 2, "op": "plan", "plan": {"windows": 4}, "trace_id": "req-7"}"#,
        )
        .unwrap();
        let b = json::parse(r#"{"v": 2, "op": "plan", "plan": {"windows": 4}}"#).unwrap();
        let ea = parse_envelope(&a).unwrap();
        let eb = parse_envelope(&b).unwrap();
        assert_eq!(ea.trace_id.as_deref(), Some("req-7"));
        assert_eq!(eb.trace_id, None);
        // Same key: trace_id is correlation, not computation.
        assert_eq!(request_key(&ea).unwrap(), request_key(&eb).unwrap());
        // Echoed by the stamping point (and absent when not supplied).
        let stamped = stamp(Json::obj(), &ea);
        assert_eq!(stamped.req_str("trace_id").unwrap(), "req-7");
        assert!(stamp(Json::obj(), &eb).get("trace_id").is_none());
        // A non-string trace_id is a loud error.
        let bad = json::parse(r#"{"v": 2, "op": "stats", "trace_id": 9}"#).unwrap();
        assert_eq!(parse_envelope(&bad).unwrap_err().code, ErrCode::BadRequest);
    }

    #[test]
    fn explain_flag_is_part_of_the_key() {
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let mut plain = Json::obj();
        plain.set("workload", wl.to_json());
        let mut explained = Json::obj();
        explained.set("workload", wl.to_json()).set("explain", Json::Bool(true));
        let ke = request_key(&parse_envelope(&explained).unwrap()).unwrap();
        let kp = request_key(&parse_envelope(&plain).unwrap()).unwrap();
        assert_ne!(ke, kp, "explain shapes the payload, so it must split the key");
        assert!(parse_envelope(&explained).unwrap().explain);
        assert!(!parse_envelope(&plain).unwrap().explain);
        // Wrong type is a loud error.
        let bad = json::parse(r#"{"workload": {}, "explain": "yes"}"#).unwrap();
        assert_eq!(parse_envelope(&bad).unwrap_err().code, ErrCode::BadRequest);
    }

    #[test]
    fn plan_keys_ignore_envelope_fields() {
        let a = json::parse(r#"{"plan": {"windows": 4}, "id": 1}"#).unwrap();
        let b = json::parse(r#"{"v": 2, "op": "plan", "plan": {"windows": 4}, "id": 2}"#).unwrap();
        let ka = request_key(&parse_envelope(&a).unwrap()).unwrap();
        let kb = request_key(&parse_envelope(&b).unwrap()).unwrap();
        assert_eq!(ka, kb);
    }
}

//! Bounded worker pool + request coalescer for the service pipeline.
//!
//! [`crate::util::pool::scoped_map`] is a fork-join helper: workers are
//! born and die inside one call, which is right for a single search's
//! internal parallelism but wrong for a server — there the pool must
//! outlive any one request, bound *admission* (not just concurrency),
//! and shed load instead of queueing unboundedly. [`ServicePool`] is
//! that long-lived variant: a fixed worker set over a bounded
//! `VecDeque`, where [`ServicePool::try_submit`] refuses work the
//! moment the backlog hits the configured limit, so an overloaded
//! server answers "overloaded" in microseconds instead of timing out
//! every client equally. Worker sizing reuses
//! [`crate::util::pool::effective_threads`]; each job is itself a
//! multi-threaded search, so the default worker count stays small.
//!
//! [`Coalescer`] is the companion admission optimization: requests with
//! the same normalized [`RequestKey`] elect one *leader* whose
//! computation is fanned out to every concurrent *follower*, so a
//! thundering herd of identical searches costs one search.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::json::Json;

use super::protocol::{RequestKey, ServiceError};

/// One unit of pool work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    ready: Condvar,
}

/// Long-lived bounded worker pool with load-shedding admission.
pub struct ServicePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    queue_limit: usize,
}

impl ServicePool {
    /// `workers = 0` sizes to min(4, hardware threads): each job is an
    /// internally parallel search, so a few concurrent jobs already
    /// saturate the machine. `queue_limit` bounds the *backlog* (jobs
    /// admitted but not yet running); 0 means the default of 64.
    pub fn new(workers: usize, queue_limit: usize) -> ServicePool {
        let workers = if workers == 0 {
            crate::util::pool::effective_threads(0, 4)
        } else {
            workers
        };
        let queue_limit = if queue_limit == 0 { 64 } else { queue_limit };
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        ServicePool { shared, handles, workers, queue_limit }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn queue_limit(&self) -> usize {
        self.queue_limit
    }

    /// Jobs admitted but not yet picked up by a worker.
    pub fn depth(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Admit a job, or refuse it (`false`) when the backlog is at the
    /// limit — the caller turns that into a typed `overloaded` error.
    pub fn try_submit(&self, job: Job) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        if q.jobs.len() >= self.queue_limit || q.shutdown {
            return false;
        }
        q.jobs.push_back(job);
        drop(q);
        self.shared.ready.notify_one();
        true
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    return;
                }
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        // A panicking job must not take the worker down with it; the
        // leader guard (below) turns the lost result into a typed
        // internal error for the waiters.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

impl Drop for ServicePool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
            // Pending jobs are dropped; their leader guards publish
            // internal errors so no follower hangs on a dead pool.
            q.jobs.clear();
        }
        self.shared.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The shared result slot of one coalesced computation.
pub struct Flight {
    slot: Mutex<Option<Result<Json, ServiceError>>>,
    done: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight { slot: Mutex::new(None), done: Condvar::new() }
    }

    /// Block until the leader publishes, then take a copy.
    pub fn wait(&self) -> Result<Json, ServiceError> {
        let mut slot = self.slot.lock().unwrap();
        loop {
            if let Some(res) = slot.as_ref() {
                return res.clone();
            }
            slot = self.done.wait(slot).unwrap();
        }
    }
}

/// What `join` handed this request: compute (leader) or wait (follower).
pub enum Ticket<'a> {
    Leader(LeadGuard<'a>),
    Follower(Arc<Flight>),
}

/// The leader's obligation to publish. Dropping without publishing
/// (worker panic, shed after election, dropped queue) publishes a typed
/// internal error so followers never hang.
pub struct LeadGuard<'a> {
    coalescer: &'a Coalescer,
    key: String,
    flight: Arc<Flight>,
    published: bool,
}

impl LeadGuard<'_> {
    /// Deliver the computation to every waiter and retire the flight.
    pub fn publish(mut self, res: Result<Json, ServiceError>) {
        self.publish_inner(res);
    }

    fn publish_inner(&mut self, res: Result<Json, ServiceError>) {
        if self.published {
            return;
        }
        self.published = true;
        // Retire the flight *before* filling the slot: a request
        // arriving after this point starts a fresh computation instead
        // of latching onto a finished one (results may be cached
        // upstream, but the coalescer itself only dedups in-flight
        // work).
        self.coalescer.inflight.lock().unwrap().remove(&self.key);
        *self.flight.slot.lock().unwrap() = Some(res);
        self.flight.done.notify_all();
    }
}

impl Drop for LeadGuard<'_> {
    fn drop(&mut self) {
        self.publish_inner(Err(ServiceError::internal(
            "request leader aborted before publishing a result",
        )));
    }
}

/// In-flight request deduplication by normalized [`RequestKey`].
#[derive(Default)]
pub struct Coalescer {
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
}

impl Coalescer {
    pub fn new() -> Coalescer {
        Coalescer::default()
    }

    /// Number of distinct computations currently in flight.
    pub fn inflight(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }

    /// Join the flight for `key`: the first caller becomes the leader,
    /// everyone else a follower of the leader's flight.
    pub fn join(&self, key: &RequestKey) -> Ticket<'_> {
        let mut map = self.inflight.lock().unwrap();
        if let Some(flight) = map.get(key.as_str()) {
            return Ticket::Follower(flight.clone());
        }
        let flight = Arc::new(Flight::new());
        map.insert(key.as_str().to_string(), flight.clone());
        Ticket::Leader(LeadGuard {
            coalescer: self,
            key: key.as_str().to_string(),
            flight,
            published: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn pool_runs_jobs_and_sheds_over_limit() {
        let pool = ServicePool::new(2, 2);
        assert_eq!(pool.workers(), 2);
        let done = Arc::new(AtomicU64::new(0));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let blocking_job = |done: &Arc<AtomicU64>, gate: &Arc<(Mutex<bool>, Condvar)>| {
            let done = done.clone();
            let gate = gate.clone();
            Box::new(move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                done.fetch_add(1, Ordering::SeqCst);
            }) as Job
        };
        // Two blocking jobs occupy both workers (wait for pickup so the
        // queue-limit check below sees an empty backlog)...
        for _ in 0..2 {
            assert!(pool.try_submit(blocking_job(&done, &gate)));
            let t0 = std::time::Instant::now();
            while pool.depth() > 0 && t0.elapsed().as_secs() < 5 {
                std::thread::yield_now();
            }
            assert_eq!(pool.depth(), 0, "a free worker must pick the job up");
        }
        // ...two more fill the backlog to its limit...
        for _ in 0..2 {
            assert!(pool.try_submit(blocking_job(&done, &gate)));
        }
        // ...and anything beyond is shed.
        assert!(!pool.try_submit(Box::new(|| {})), "backlog at limit must shed");
        // Open the gate; all admitted blocking jobs finish.
        *gate.0.lock().unwrap() = true;
        gate.1.notify_all();
        let t0 = std::time::Instant::now();
        while done.load(Ordering::SeqCst) < 4 && t0.elapsed().as_secs() < 5 {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = ServicePool::new(1, 8);
        assert!(pool.try_submit(Box::new(|| panic!("job blew up"))));
        let done = Arc::new(AtomicU64::new(0));
        let d = done.clone();
        assert!(pool.try_submit(Box::new(move || {
            d.fetch_add(1, Ordering::SeqCst);
        })));
        let t0 = std::time::Instant::now();
        while done.load(Ordering::SeqCst) == 0 && t0.elapsed().as_secs() < 5 {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 1, "worker must outlive a panicking job");
    }

    #[test]
    fn coalescer_elects_one_leader_and_fans_out() {
        let co = Coalescer::new();
        let key = RequestKey::test_key("k1");
        let Ticket::Leader(lead) = co.join(&key) else {
            panic!("first joiner must lead");
        };
        let Ticket::Follower(flight) = co.join(&key) else {
            panic!("second joiner must follow");
        };
        assert_eq!(co.inflight(), 1);
        let other = RequestKey::test_key("k2");
        assert!(matches!(co.join(&other), Ticket::Leader(_)), "distinct keys don't coalesce");

        lead.publish(Ok(json::num(42.0)));
        assert_eq!(flight.wait().unwrap(), json::num(42.0));
        // The flight retired: a new joiner recomputes.
        assert!(matches!(co.join(&key), Ticket::Leader(_)));
    }

    #[test]
    fn dropped_leader_publishes_internal_error() {
        let co = Coalescer::new();
        let key = RequestKey::test_key("k");
        let Ticket::Leader(lead) = co.join(&key) else { panic!() };
        let Ticket::Follower(flight) = co.join(&key) else { panic!() };
        drop(lead);
        let err = flight.wait().unwrap_err();
        assert_eq!(err.code, super::super::protocol::ErrCode::Internal);
        assert_eq!(co.inflight(), 0);
    }
}

//! Shared warm-entry cache: one capacity-bounded LRU of profiled
//! databases for the whole server, replacing the per-`State` unbounded
//! maps that previously grew one `PerfDatabase` (+ calibration
//! composition) per context forever.
//!
//! Each entry bundles everything warm for one [`DbKey`] context: the
//! analytic database, the calibrated composition when the server's
//! artifact matches, and a shared operator-latency [`MemoStore`] so
//! repeated requests against the context start with a hot memo instead
//! of an empty one (calibrated contexts opt out — see DESIGN.md §8 on
//! per-request tier accounting).
//!
//! Builds are single-flight: concurrent misses on one key elect one
//! builder and the rest wait on a condvar, so a thundering herd on a
//! cold context profiles the ~2 s database once, not N times.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};

use crate::perfdb::{CalibratedDb, MemoStore, PerfDatabase};

use super::stats::CacheGauges;

/// (model, gpu, gpus_per_node, num_nodes, framework, fabric) — the
/// fabric name is part of the cache key: the same GPU pool wired as
/// `legacy` and as `gb200-nvl72` profiles different comm tables.
pub type DbKey = (String, String, u32, u32, String, String);

/// Everything warm for one context.
pub struct WarmEntry {
    pub db: Arc<PerfDatabase>,
    /// Calibrated composition when the server's artifact matches this
    /// context (answers then carry provenance tiers).
    pub cal: Option<Arc<CalibratedDb>>,
    /// Cross-request operator-latency memo for the plain-analytic and
    /// PJRT oracles of this context.
    pub memo: MemoStore,
}

struct Slot {
    entry: Arc<WarmEntry>,
    /// LRU stamp: bumped on every hit from a monotonic tick.
    stamp: u64,
}

struct Inner {
    map: HashMap<DbKey, Slot>,
    /// Keys currently being built by some thread (single-flight).
    building: HashSet<DbKey>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Capacity-bounded LRU of [`WarmEntry`] keyed by [`DbKey`].
pub struct WarmCache {
    inner: Mutex<Inner>,
    built: Condvar,
    cap: usize,
}

impl WarmCache {
    pub fn new(cap: usize) -> WarmCache {
        WarmCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                building: HashSet::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            built: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, evictions) so far. A request that waited for
    /// another thread's in-flight build counts as the miss it was when
    /// it arrived.
    pub fn stats(&self) -> (u64, u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.hits, g.misses, g.evictions)
    }

    pub fn gauges(&self) -> CacheGauges {
        let g = self.inner.lock().unwrap();
        CacheGauges {
            entries: g.map.len(),
            cap: self.cap,
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
        }
    }

    /// Look up without touching LRU order or counters (tests, metrics).
    pub fn peek(&self, key: &DbKey) -> Option<Arc<WarmEntry>> {
        self.inner.lock().unwrap().map.get(key).map(|s| s.entry.clone())
    }

    /// Pre-insert an entry built outside the cache (the PJRT context at
    /// bind time). Subject to the same capacity bound as built entries.
    pub fn seed(&self, key: DbKey, entry: WarmEntry) {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let stamp = g.tick;
        g.map.insert(key, Slot { entry: Arc::new(entry), stamp });
        Self::evict_over_cap(&mut g, self.cap);
    }

    /// Fetch the warm entry for `key`, building it with `build` on a
    /// miss. The build runs outside the lock; concurrent misses on the
    /// same key wait for the elected builder instead of duplicating the
    /// profiling work. Build errors propagate to every waiter as their
    /// own retry (the key is released, so a later request re-attempts).
    pub fn get_or_build(
        &self,
        key: &DbKey,
        build: impl FnOnce() -> anyhow::Result<WarmEntry>,
    ) -> anyhow::Result<Arc<WarmEntry>> {
        {
            let mut g = self.inner.lock().unwrap();
            loop {
                if let Some(slot) = g.map.get(key) {
                    let entry = slot.entry.clone();
                    g.tick += 1;
                    let stamp = g.tick;
                    g.map.get_mut(key).unwrap().stamp = stamp;
                    g.hits += 1;
                    return Ok(entry);
                }
                if g.building.contains(key) {
                    // Someone else is building this context: wait, then
                    // re-check (the build may also have failed).
                    g = self.built.wait(g).unwrap();
                    continue;
                }
                g.misses += 1;
                g.building.insert(key.clone());
                break;
            }
        }
        let built = build();
        let mut g = self.inner.lock().unwrap();
        g.building.remove(key);
        self.built.notify_all();
        match built {
            Ok(entry) => {
                g.tick += 1;
                let stamp = g.tick;
                let entry = Arc::new(entry);
                g.map.insert(key.clone(), Slot { entry: entry.clone(), stamp });
                Self::evict_over_cap(&mut g, self.cap);
                Ok(entry)
            }
            Err(e) => Err(e),
        }
    }

    fn evict_over_cap(g: &mut Inner, cap: usize) {
        while g.map.len() > cap {
            let Some(oldest) =
                g.map.iter().min_by_key(|(_, s)| s.stamp).map(|(k, _)| k.clone())
            else {
                return;
            };
            g.map.remove(&oldest);
            g.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::{gpu_by_name, ClusterSpec};
    use crate::models::by_name;
    use crate::silicon::Silicon;

    fn key(model: &str, gpn: u32) -> DbKey {
        (model.into(), "h100".into(), gpn, 1, "trtllm".into(), "legacy".into())
    }

    fn entry() -> WarmEntry {
        let cluster = ClusterSpec::new(gpu_by_name("h100").unwrap(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("llama3.1-8b").unwrap();
        let db = PerfDatabase::build(&sil, &model, crate::models::Dtype::Fp8, 1);
        WarmEntry { db: Arc::new(db), cal: None, memo: MemoStore::new() }
    }

    #[test]
    fn lru_evicts_the_least_recent_key() {
        let cache = WarmCache::new(2);
        let db = entry().db;
        let build = |db: &Arc<PerfDatabase>| {
            let db = db.clone();
            move || Ok(WarmEntry { db, cal: None, memo: MemoStore::new() })
        };
        cache.get_or_build(&key("a", 8), build(&db)).unwrap();
        cache.get_or_build(&key("b", 8), build(&db)).unwrap();
        // Touch "a", then insert "c": "b" is the LRU victim.
        cache.get_or_build(&key("a", 8), build(&db)).unwrap();
        cache.get_or_build(&key("c", 8), build(&db)).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.peek(&key("a", 8)).is_some());
        assert!(cache.peek(&key("b", 8)).is_none(), "LRU key must be evicted");
        assert!(cache.peek(&key("c", 8)).is_some());
        let (hits, misses, evictions) = cache.stats();
        assert_eq!((hits, misses, evictions), (1, 3, 1));
    }

    #[test]
    fn build_errors_release_the_key_for_retry() {
        let cache = WarmCache::new(2);
        let k = key("a", 8);
        assert!(cache
            .get_or_build(&k, || anyhow::bail!("profiling failed"))
            .is_err());
        assert!(cache.peek(&k).is_none());
        // The key is not wedged: a later build succeeds.
        let e = entry();
        let db = e.db.clone();
        cache
            .get_or_build(&k, move || Ok(WarmEntry { db, cal: None, memo: MemoStore::new() }))
            .unwrap();
        assert!(cache.peek(&k).is_some());
    }

    #[test]
    fn concurrent_misses_build_once() {
        let cache = Arc::new(WarmCache::new(4));
        let e = entry();
        let db = e.db.clone();
        let builds = Arc::new(std::sync::atomic::AtomicU64::new(0));
        std::thread::scope(|sc| {
            for _ in 0..8 {
                let cache = cache.clone();
                let db = db.clone();
                let builds = builds.clone();
                sc.spawn(move || {
                    cache
                        .get_or_build(&key("a", 8), move || {
                            builds.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                            // Widen the race window so waiters pile up.
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            Ok(WarmEntry { db, cal: None, memo: MemoStore::new() })
                        })
                        .unwrap();
                });
            }
        });
        assert_eq!(
            builds.load(std::sync::atomic::Ordering::SeqCst),
            1,
            "single-flight: one elected builder"
        );
        assert_eq!(cache.len(), 1);
    }
}

//! Config-search service: the L3 serving coordinator.
//!
//! A threaded TCP server speaking JSON-lines — now a production-shaped
//! request pipeline rather than a per-connection loop:
//!
//! * [`protocol`] — the versioned envelope (v2 `{"v":2,"op":...}` with
//!   typed errors; legacy bare requests answer as v1) and the
//!   normalized [`protocol::RequestKey`] identity of a request.
//! * [`pool`] — a bounded worker pool with load-shedding admission
//!   control, plus the coalescer that lets identical in-flight requests
//!   share one computation.
//! * [`cache`] — one capacity-bounded LRU of warm per-context entries
//!   (profiled database + calibrated composition + operator memo),
//!   shared by every connection.
//! * [`stats`] — lock-free counters/histograms behind the `stats`
//!   request and its `/metrics`-style text dump.
//!
//! Connections feed lines into the shared [`Pipeline`]; each request is
//! keyed, coalesced, admitted (or shed with a typed `overloaded`
//! error), and answered by a pool worker running the TaskRunner →
//! Pareto pipeline — the paper's 5-step workflow behind one socket.
//!
//! When started with an artifacts directory, interpolation queries from
//! *all* connections funnel through the single PJRT evaluator thread
//! ([`crate::runtime::PjrtService`]) — a dynamic batcher over the
//! AOT-compiled Pallas kernel. (The vendored build has no tokio, so
//! concurrency is plain OS threads; see DESIGN.md §8.)

pub mod cache;
pub mod pool;
pub mod protocol;
pub mod stats;

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::{Candidate, WorkloadSpec};
use crate::frameworks::Framework;
use crate::generator;
use crate::hardware::{gpu_by_name, ClusterSpec};
use crate::models::by_name;
use crate::pareto;
use crate::perfdb::{
    CalibratedDb, CalibrationArtifact, LatencyOracle, MemoOracle, MemoStore, PerfDatabase,
};
use crate::runtime::{PjrtOracle, PjrtService};
use crate::search::{RunOptions, SearchReport, TaskRunner};
use crate::silicon::Silicon;
use crate::util::json::{self, Json};

pub use cache::{DbKey, WarmCache, WarmEntry};
pub use pool::{Coalescer, ServicePool, Ticket};
pub use protocol::{Envelope, ErrCode, OpKind, ServiceError};
pub use stats::ServiceStats;

/// Default resident contexts in the warm cache. A warm entry is a full
/// profiled database (a few MB + ~seconds of profiling to rebuild), so
/// the default is small; `--cache-cap` raises it for fleet-wide
/// servers.
pub const DEFAULT_CACHE_CAP: usize = 8;

/// Server configuration.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:0" (0 = ephemeral).
    pub addr: String,
    /// Artifacts dir for the PJRT-backed hot path (None = native interp).
    pub artifacts: Option<PathBuf>,
    /// Calibration artifact (from the `calibrate` CLI): composed over
    /// the database of every request whose context matches the
    /// artifact's; other contexts stay analytic.
    pub calibration: Option<PathBuf>,
    pub seed: u64,
    /// Pool workers (0 = min(4, hardware threads)).
    pub workers: usize,
    /// Admission backlog limit before shedding (0 = 64).
    pub queue_limit: usize,
    /// Warm-cache capacity in contexts (0 = [`DEFAULT_CACHE_CAP`]).
    pub cache_cap: usize,
    /// Capture a span trace for every Nth answered request and fold it
    /// into the `aiconf_span_*` metrics (0 = tracing off, the default:
    /// the hot path then never installs a recorder).
    pub trace_sample: usize,
}

/// Shared server state (public so in-process embedding — tests, the
/// serve_e2e example — can drive requests without a socket).
pub struct State {
    /// Warm per-context entries, shared by all connections.
    cache: WarmCache,
    /// Service counters (shared by the pipeline and direct embedding).
    pub stats: ServiceStats,
    /// Calibration artifact loaded at startup (if any).
    artifact: Option<CalibrationArtifact>,
    /// PJRT evaluator bound to the context named at startup (if any).
    pjrt: Option<(DbKey, PjrtService)>,
    seed: u64,
    /// Span-capture sampling period (0 = off): every Nth dispatched
    /// request runs under a [`crate::trace::Recorder`] whose category
    /// totals land in [`ServiceStats::add_spans`].
    trace_sample: usize,
    /// Requests seen by the sampler (all ops except `stats`).
    trace_seen: AtomicU64,
}

impl State {
    pub fn new(seed: u64) -> State {
        State::with_caps(seed, None, DEFAULT_CACHE_CAP)
    }

    /// A state whose matching-context requests answer through the
    /// calibrated three-tier chain.
    pub fn with_calibration(seed: u64, artifact: CalibrationArtifact) -> State {
        State::with_caps(seed, Some(artifact), DEFAULT_CACHE_CAP)
    }

    /// Full-control constructor (tests size the cache down to force
    /// eviction).
    pub fn with_caps(
        seed: u64,
        artifact: Option<CalibrationArtifact>,
        cache_cap: usize,
    ) -> State {
        State {
            cache: WarmCache::new(cache_cap),
            stats: ServiceStats::new(),
            artifact,
            pjrt: None,
            seed,
            trace_sample: 0,
            trace_seen: AtomicU64::new(0),
        }
    }

    /// Enable span-capture sampling: every `n`-th request records a
    /// trace into the `aiconf_span_*` metrics (0 = off).
    pub fn set_trace_sample(&mut self, n: usize) {
        self.trace_sample = n;
    }

    /// The sampler's decision for one request: a fresh recorder every
    /// Nth dispatch, `None` otherwise. With sampling off this is one
    /// branch — no atomics touched.
    fn sample_recorder(&self) -> Option<crate::trace::Recorder> {
        if self.trace_sample == 0 {
            return None;
        }
        let n = self.trace_seen.fetch_add(1, Ordering::Relaxed);
        (n % self.trace_sample as u64 == 0).then(crate::trace::Recorder::new)
    }

    pub fn cache(&self) -> &WarmCache {
        &self.cache
    }

    /// The warm entry for a context: cache hit, or a single-flight
    /// build of database + calibrated composition + memo store.
    fn entry_for(&self, key: &DbKey) -> anyhow::Result<Arc<WarmEntry>> {
        self.cache.get_or_build(key, || {
            let db = Arc::new(build_db(key, self.seed)?);
            let cal = self.compose_cal(&db)?;
            Ok(WarmEntry { db, cal, memo: MemoStore::new() })
        })
    }

    /// Compose the server's calibration artifact over a context's
    /// database. `None` when no artifact is loaded or its profiling
    /// context differs from this request's.
    fn compose_cal(&self, db: &Arc<PerfDatabase>) -> anyhow::Result<Option<Arc<CalibratedDb>>> {
        let Some(art) = &self.artifact else { return Ok(None) };
        // Artifacts are fitted against legacy-fabric grids; tiered-fabric
        // contexts stay analytic (same "silently analytic on non-matching
        // context" contract as the other fields — `CalibratedDb::compose`
        // would reject the combination loudly).
        if db.cluster.fabric.placement_aware() {
            return Ok(None);
        }
        let matches = art.gpu == db.ctx.gpu
            && art.gpus_per_node == db.ctx.gpus_per_node
            && art.num_nodes == db.ctx.num_nodes
            && art.model == db.ctx.model
            && art.framework == db.ctx.framework
            && art.kv_dtype == db.ctx.kv_dtype;
        if !matches {
            return Ok(None);
        }
        Ok(Some(Arc::new(CalibratedDb::compose((**db).clone(), art)?)))
    }
}

/// The request pipeline every connection feeds into: envelope parsing →
/// coalescing → bounded-pool admission → dispatch → response stamping.
pub struct Pipeline {
    state: Arc<State>,
    pool: ServicePool,
    coalescer: Coalescer,
}

impl Pipeline {
    /// `workers`/`queue_limit` as in [`ServerConfig`] (0 = defaults).
    pub fn new(state: Arc<State>, workers: usize, queue_limit: usize) -> Pipeline {
        Pipeline { state, pool: ServicePool::new(workers, queue_limit), coalescer: Coalescer::new() }
    }

    pub fn state(&self) -> &Arc<State> {
        &self.state
    }

    /// Jobs admitted but not yet running (the shed gauge).
    pub fn queue_depth(&self) -> usize {
        self.pool.depth()
    }

    /// One raw line from a connection (may be blank → `None`, invalid
    /// UTF-8 or unparseable JSON → typed error response).
    pub fn handle_line_bytes(&self, buf: &[u8]) -> Option<Json> {
        let Ok(line) = std::str::from_utf8(buf) else {
            self.state.stats.malformed.fetch_add(1, Ordering::Relaxed);
            self.state.stats.errors.fetch_add(1, Ordering::Relaxed);
            return Some(protocol::error_response(
                None,
                &ServiceError::bad_request("request line is not valid UTF-8"),
            ));
        };
        let line = line.trim();
        if line.is_empty() {
            return None;
        }
        Some(self.handle_line(line))
    }

    /// One request line (non-blank).
    pub fn handle_line(&self, line: &str) -> Json {
        match json::parse(line) {
            Ok(req) => self.handle(&req),
            Err(e) => {
                self.state.stats.malformed.fetch_add(1, Ordering::Relaxed);
                self.state.stats.errors.fetch_add(1, Ordering::Relaxed);
                protocol::error_response(
                    None,
                    &ServiceError::bad_request(format!("unparseable request line: {e:#}")),
                )
            }
        }
    }

    /// One parsed request through the full pipeline.
    pub fn handle(&self, req: &Json) -> Json {
        let t0 = Instant::now();
        let env = match protocol::parse_envelope(req) {
            Ok(env) => env,
            Err(err) => {
                self.state.stats.errors.fetch_add(1, Ordering::Relaxed);
                return protocol::error_for_request(req, &err);
            }
        };
        // Stats answer inline — observability must not queue behind the
        // very backlog it reports.
        if env.op == OpKind::Stats {
            self.state.stats.bump(OpKind::Stats);
            return protocol::stamp(self.stats_payload(), &env);
        }
        // Key before admission, so identical requests coalesce even
        // when the queue is full (followers ride the in-flight leader
        // for free instead of being shed).
        let key = match protocol::request_key(&env) {
            Ok(k) => k,
            Err(e) => {
                self.state.stats.errors.fetch_add(1, Ordering::Relaxed);
                return protocol::error_response(
                    Some(&env),
                    &ServiceError::bad_request(format!("{e:#}")),
                );
            }
        };
        let result = match self.coalescer.join(&key) {
            Ticket::Follower(flight) => {
                self.state.stats.coalesce_followers.fetch_add(1, Ordering::Relaxed);
                self.state.stats.bump(env.op);
                flight.wait()
            }
            Ticket::Leader(lead) => {
                let (tx, rx) = std::sync::mpsc::channel();
                let state = self.state.clone();
                // `explain` is part of the request key, so every waiter
                // in a coalesced group asked for the same answer shape.
                let (op, body, explain) = (env.op, env.body.clone(), env.explain);
                let admitted = self.pool.try_submit(Box::new(move || {
                    let res = dispatch(op, &body, &state, explain)
                        .map_err(|e| ServiceError::bad_request(format!("{e:#}")));
                    let _ = tx.send(res);
                }));
                if !admitted {
                    self.state.stats.shed.fetch_add(1, Ordering::Relaxed);
                    self.state.stats.errors.fetch_add(1, Ordering::Relaxed);
                    let err = ServiceError::overloaded(format!(
                        "request shed: admission queue at its limit of {} (retry, raise \
                         --queue-limit, or add workers)",
                        self.pool.queue_limit()
                    ));
                    // Followers that latched on while we held the lead
                    // get the same typed refusal instead of hanging.
                    lead.publish(Err(err.clone()));
                    return protocol::error_response(Some(&env), &err);
                }
                self.state.stats.coalesce_leaders.fetch_add(1, Ordering::Relaxed);
                let res = rx.recv().unwrap_or_else(|_| {
                    Err(ServiceError::internal("worker dropped the result (job panicked?)"))
                });
                lead.publish(res.clone());
                res
            }
        };
        match result {
            Ok(payload) => {
                self.state
                    .stats
                    .record_latency(env.op, t0.elapsed().as_secs_f64() * 1e3);
                protocol::stamp(payload, &env)
            }
            Err(err) => {
                self.state.stats.errors.fetch_add(1, Ordering::Relaxed);
                protocol::error_response(Some(&env), &err)
            }
        }
    }

    fn stats_payload(&self) -> Json {
        let cache = self.state.cache.gauges();
        let pool = stats::PoolGauges {
            queue_depth: self.pool.depth(),
            queue_limit: self.pool.queue_limit(),
            workers: self.pool.workers(),
        };
        let mut o = Json::obj();
        o.set("status", json::s("ok"))
            .set("stats", self.state.stats.to_json(&cache, Some(&pool)))
            .set("metrics_text", json::s(&self.state.stats.render_metrics(&cache, Some(&pool))));
        o
    }
}

/// The running server handle.
pub struct SearchServer {
    listener: TcpListener,
    pipeline: Arc<Pipeline>,
    stop: Arc<AtomicBool>,
}

impl SearchServer {
    /// Bind. If `cfg.artifacts` is set, also pre-build the database for
    /// `pjrt_ctx` and start the PJRT evaluator on its grids.
    pub fn bind(cfg: &ServerConfig, pjrt_ctx: Option<(&str, &str, u32, u32, Framework)>) -> anyhow::Result<(SearchServer, SocketAddr)> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let artifact = match &cfg.calibration {
            Some(path) => Some(CalibrationArtifact::load(path)?),
            None => None,
        };
        let cache_cap = if cfg.cache_cap == 0 { DEFAULT_CACHE_CAP } else { cfg.cache_cap };
        let mut state = State::with_caps(cfg.seed, artifact, cache_cap);
        state.set_trace_sample(cfg.trace_sample);
        if let (Some(dir), Some((model, gpu, gpn, nodes, fw))) = (&cfg.artifacts, pjrt_ctx) {
            let key: DbKey =
                (model.into(), gpu.into(), gpn, nodes, fw.name().into(), "legacy".into());
            let db = Arc::new(build_db(&key, cfg.seed)?);
            let svc = PjrtService::start(dir, db.grids().to_vec())?;
            state
                .cache
                .seed(key.clone(), WarmEntry { db, cal: None, memo: MemoStore::new() });
            state.pjrt = Some((key, svc));
        }
        let pipeline = Arc::new(Pipeline::new(Arc::new(state), cfg.workers, cfg.queue_limit));
        Ok((SearchServer { listener, pipeline, stop: Arc::new(AtomicBool::new(false)) }, addr))
    }

    /// Handle to request shutdown from another thread.
    pub fn stopper(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// The shared pipeline (for in-process embedding alongside the
    /// socket, e.g. a health prober reading `stats`).
    pub fn pipeline(&self) -> Arc<Pipeline> {
        self.pipeline.clone()
    }

    /// Accept loop (blocks). Each connection gets a reader thread; all
    /// of them feed the shared pipeline. Returns when the stop flag is
    /// set (checked between connections — poke it with a dummy
    /// connect).
    pub fn run(self) -> anyhow::Result<()> {
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let pipeline = self.pipeline.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &pipeline);
            });
        }
        Ok(())
    }
}

/// Read lines, answer each through the pipeline. Malformed lines (bad
/// JSON, invalid UTF-8) get a typed error reply and the loop continues
/// — only genuine socket I/O failures (or EOF) end the connection.
fn handle_conn(stream: TcpStream, pipeline: &Pipeline) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf = Vec::new();
    loop {
        buf.clear();
        // read_until, not read_line: a line of invalid UTF-8 must reach
        // the pipeline as a malformed request, not kill the connection
        // loop as an I/O error with no reply.
        if reader.read_until(b'\n', &mut buf)? == 0 {
            return Ok(());
        }
        let Some(resp) = pipeline.handle_line_bytes(&buf) else { continue };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

fn build_db(key: &DbKey, seed: u64) -> anyhow::Result<PerfDatabase> {
    let (model_name, gpu_name, gpn, nodes, fw_name, fabric_name) = key;
    let model =
        by_name(model_name).ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let gpu = gpu_by_name(gpu_name).ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}'"))?;
    let fw = Framework::parse(fw_name)
        .ok_or_else(|| anyhow::anyhow!("unknown framework '{fw_name}'"))?;
    let fabric = crate::topology::fabric::by_name(fabric_name, *gpn)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric '{fabric_name}'"))?;
    let cluster = ClusterSpec::with_fabric(gpu, *gpn, *nodes, fabric);
    let silicon = Silicon::new(cluster, fw.profile());
    // Ampere has no FP8 tensor cores: `preferred_kv_dtype` profiles
    // such contexts at FP16 — the same default the CLI `plan` path and
    // the planner's engine space use, so service plans price a100
    // fleet legs consistently with the CLI.
    Ok(PerfDatabase::build(&silicon, &model, gpu.preferred_kv_dtype(), seed))
}

/// Handle one JSON request line (exposed for in-process tests).
pub fn handle_request_line(line: &str, state: &State) -> anyhow::Result<Json> {
    let req = json::parse(line)?;
    handle_request(&req, state)
}

/// Version-aware single-request entry point for in-process embedding
/// (no pool, no coalescing — the [`Pipeline`] adds those): parse the
/// envelope, dispatch, stamp the response with `v`/`id`.
pub fn handle_request(req: &Json, state: &State) -> anyhow::Result<Json> {
    let env = protocol::parse_envelope(req).map_err(|e| anyhow::anyhow!("{}", e.message))?;
    let payload = dispatch(env.op, &env.body, state, env.explain)?;
    Ok(protocol::stamp(payload, &env))
}

/// Version-blind operation dispatch. Payloads carry no `v`/`id` — the
/// caller stamps them (so a coalesced payload can be fanned out to
/// waiters holding different ids). `explain` (part of the request key)
/// attaches the "why this config won" report to the payload.
fn dispatch(op: OpKind, body: &Json, state: &State, explain: bool) -> anyhow::Result<Json> {
    state.stats.bump(op);
    if op == OpKind::Stats {
        // Stats without a pipeline (direct embedding): no queue to
        // report. Never traced — observability must not observe itself.
        let cache = state.cache.gauges();
        let mut o = Json::obj();
        o.set("status", json::s("ok"))
            .set("stats", state.stats.to_json(&cache, None))
            .set("metrics_text", json::s(&state.stats.render_metrics(&cache, None)));
        return Ok(o);
    }
    let rec = state.sample_recorder();
    if let Some(r) = &rec {
        r.install();
    }
    let result = match op {
        OpKind::Search => handle_search_request(body, state, explain),
        OpKind::Sweep => handle_sweep_request(body, state, explain),
        OpKind::Plan => handle_plan_request(body, state, explain),
        OpKind::Validate => handle_validate_request(body, state, explain),
        OpKind::Replan => handle_replan_request(body, state, explain),
        OpKind::Stats => unreachable!("answered above"),
    };
    if let Some(r) = rec {
        state.stats.add_spans(&r.finish());
    }
    result
}

/// Reject placement-aware fabrics on a PJRT-bound server: the AOT
/// kernel prices the packed layout only (the CLI does the same for
/// --fabric with --pjrt) — reject loudly instead of silently falling
/// through to a different oracle.
fn ensure_pjrt_fabric_ok(state: &State, pc: &protocol::ParsedContext) -> anyhow::Result<()> {
    anyhow::ensure!(
        state.pjrt.is_none() || !pc.placement_aware,
        "'fabric' is not supported on a PJRT-bound server: the AOT kernel prices the \
         packed layout only (restart without --pjrt or drop the fabric field)"
    );
    Ok(())
}

/// Run scenarios against the context's warm entry with the right
/// oracle chain. All three chains go through `run_sweep_cached`, which
/// produces exactly the same reports as independent `run` calls
/// (regression-tested in crate::search):
///
/// * PJRT-bound context → PJRT oracle over the **shared** warm memo.
/// * Calibrated context → a per-request clone of the cached composition
///   with a **fresh private** memo, so tier counts stay per-request and
///   deterministic (unique-shape counts; see DESIGN.md §8).
/// * Plain analytic → the database over the **shared** warm memo.
fn run_reports(
    state: &State,
    key: &DbKey,
    entry: &WarmEntry,
    runner: &TaskRunner,
    wls: &[WorkloadSpec],
) -> Vec<SearchReport> {
    let opts = RunOptions::default();
    match &state.pjrt {
        Some((pk, svc)) if pk == key => {
            let oracle = PjrtOracle { svc, db: &entry.db };
            let memo = MemoOracle::with_store(&oracle, &entry.memo);
            runner.run_sweep_cached(&memo, wls, &opts)
        }
        _ => match &entry.cal {
            Some(cal) => {
                // The ~2 MB grid copy is deliberate: it costs ~0.1 ms
                // against a search that runs for hundreds, and keeps
                // CalibratedDb free of interior Arcs.
                let cal = (**cal).clone();
                let memo = MemoOracle::new(&cal);
                runner.run_sweep_cached(&memo, wls, &opts)
            }
            None => {
                let memo = MemoOracle::with_store(entry.db.as_ref(), &entry.memo);
                runner.run_sweep_cached(&memo, wls, &opts)
            }
        },
    }
}

/// The oracle the explain decomposition prices against: the context's
/// calibrated composition when present, else the analytic database.
fn explain_oracle(entry: &WarmEntry) -> &dyn LatencyOracle {
    match &entry.cal {
        Some(c) => &**c,
        None => entry.db.as_ref(),
    }
}

fn handle_search_request(req: &Json, state: &State, explain: bool) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let wl = WorkloadSpec::from_json(req.req("workload")?)?;
    let pc = protocol::parse_context(req, &wl.model)?;
    ensure_pjrt_fabric_ok(state, &pc)?;
    let key = pc.db_key();
    let entry = state.entry_for(&key)?;

    let runner = TaskRunner::new(&pc.model, &pc.cluster, pc.space.clone(), wl.clone());
    let report = run_reports(state, &key, &entry, &runner, std::slice::from_ref(&wl))
        .pop()
        .expect("one scenario in, one report out");
    let analysis = pareto::analyze(&report.evaluated, &wl.sla);

    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("configs_priced", json::num(report.configs_priced as f64))
        .set("candidates", json::num(report.evaluated.len() as f64))
        .set("feasible", json::num(analysis.feasible.len() as f64))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("top", top_json(&analysis, pc.top_k))
        .set("flags", flags_json(&report));
    if let Some(t) = report.tier_counts {
        state.stats.add_tiers(&t);
        resp.set("tiers", tiers_json(&t));
    }
    if let Some(best) = analysis.best() {
        resp.set("launch", launch_json(&best.cand, &wl));
    }
    if explain {
        resp.set(
            "explain",
            crate::trace::explain::search_explain(
                explain_oracle(&entry),
                &pc.model,
                &pc.cluster,
                &wl,
                &report,
            ),
        );
    }
    Ok(resp)
}

/// Per-tier oracle query counts of a report, as JSON.
fn tiers_json(t: &crate::perfdb::TierSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("measured", json::num(t.measured as f64))
        .set("calibrated", json::num(t.calibrated as f64))
        .set("analytic", json::num(t.analytic as f64))
        .set("sol", json::num(t.sol as f64));
    o
}

/// Per-framework resolved-vs-default flag deltas of a report, as JSON.
fn flags_json(report: &SearchReport) -> Json {
    let mut arr = Vec::new();
    for s in &report.flag_summaries {
        let mut o = Json::obj();
        o.set("framework", json::s(s.framework.name()))
            .set("default_kv_frac", json::num(s.defaults.kv_frac))
            .set("default_max_num_tokens", json::num(s.defaults.max_num_tokens as f64))
            .set("resolved_kv_frac_min", json::num(s.kv_frac_min))
            .set("resolved_kv_frac_max", json::num(s.kv_frac_max))
            .set("resolved_max_num_tokens_min", json::num(s.mnt_min as f64))
            .set("resolved_max_num_tokens_max", json::num(s.mnt_max as f64))
            .set("engines_off_default", json::num(s.nondefault as f64))
            .set("engines_total", json::num(s.total as f64));
        arr.push(o);
    }
    Json::Arr(arr)
}

/// Top-k feasible candidates as a JSON array.
fn top_json(analysis: &pareto::Analysis, top_k: usize) -> Json {
    let mut top = Vec::new();
    for e in analysis.feasible.iter().take(top_k) {
        // The chosen rank layout (EXPERIMENTS.md "placement" field):
        // the decode pool's placement for disaggregated composites.
        let placement = match &e.cand {
            Candidate::Aggregated { engine, .. } => engine.placement,
            Candidate::Disaggregated { decode, .. } => decode.placement,
        };
        let mut o = Json::obj();
        o.set("config", json::s(&e.cand.label()))
            .set("mode", json::s(e.cand.mode().name()))
            .set("placement", json::s(&placement.label()))
            .set("gpus", json::num(e.cand.total_gpus() as f64))
            .set("ttft_ms", json::num(e.est.ttft_ms))
            .set("tpot_ms", json::num(e.est.tpot_ms))
            .set("speed", json::num(e.est.speed))
            .set("thru_per_gpu", json::num(e.est.thru_per_gpu));
        top.push(o);
    }
    Json::Arr(top)
}

/// Batch sweep: price every workload scenario in one TaskRunner pass
/// (shared engine enumeration + memoized oracle), answering one result
/// object per scenario.
fn handle_sweep_request(req: &Json, state: &State, explain: bool) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let wls = protocol::parse_sweep_workloads(req)?;
    let pc = protocol::parse_context(req, &wls[0].model)?;
    ensure_pjrt_fabric_ok(state, &pc)?;
    let key = pc.db_key();
    let entry = state.entry_for(&key)?;

    let runner = TaskRunner::new(&pc.model, &pc.cluster, pc.space.clone(), wls[0].clone());
    let reports = run_reports(state, &key, &entry, &runner, &wls);

    let mut results = Vec::new();
    for (wl, report) in wls.iter().zip(&reports) {
        let analysis = pareto::analyze(&report.evaluated, &wl.sla);
        let mut o = Json::obj();
        o.set("isl", json::num(wl.isl as f64))
            .set("osl", json::num(wl.osl as f64))
            .set("configs_priced", json::num(report.configs_priced as f64))
            .set("candidates", json::num(report.evaluated.len() as f64))
            .set("feasible", json::num(analysis.feasible.len() as f64))
            .set("top", top_json(&analysis, pc.top_k))
            .set("flags", flags_json(report));
        if let Some(t) = report.tier_counts {
            state.stats.add_tiers(&t);
            o.set("tiers", tiers_json(&t));
        }
        if let Some(best) = analysis.best() {
            o.set("launch", launch_json(&best.cand, wl));
        }
        if explain {
            o.set(
                "explain",
                crate::trace::explain::search_explain(
                    explain_oracle(&entry),
                    &pc.model,
                    &pc.cluster,
                    wl,
                    report,
                ),
            );
        }
        results.push(o);
    }
    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("scenarios", json::num(wls.len() as f64))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("results", Json::Arr(results));
    Ok(resp)
}

/// Capacity-plan request:
/// `{"plan": {"workload": {...}, "traffic": {"kind": "diurnal", ...},
///   "windows": 24, "window_hours": 1, "fleet": ["h100", "a100"],
///   "max_gpus": 64, "prune": true},
///   "gpus_per_node": 8, "num_nodes": 1, "framework": "trtllm"}`
/// → the cost-minimal replica schedule ([`crate::planner`]) plus the
/// Dynamo `DeploymentSchedule` YAML. Fleet-leg databases come from the
/// same warm cache the search path uses, so repeated plans skip
/// re-profiling (the dominant cost); operator-latency memos are
/// per-request.
fn handle_plan_request(req: &Json, state: &State, explain: bool) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let parts = parse_plan_parts(req, state)?;
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
        parts.legs.iter().map(|(c, d)| (*c, d.as_ref())).collect();
    let plan = crate::planner::plan(&parts.model, parts.fw, &parts.spec, &fleet)?;

    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("plan", plan.to_json(&parts.wl))
        .set(
            "schedule_yaml",
            json::s(&generator::dynamo::plan_schedule_yaml(&plan, &parts.wl.model, &parts.wl)),
        );
    if explain {
        resp.set("explain", plan_explain_json(&parts, &plan));
    }
    Ok(resp)
}

/// The `"explain"` payload of a plan-shaped response ("why this plan
/// won"), against the request's own fleet-leg oracles.
fn plan_explain_json(parts: &PlanParts, plan: &crate::planner::DeploymentPlan) -> Json {
    let legs: Vec<(String, ClusterSpec, &dyn LatencyOracle)> = parts
        .legs
        .iter()
        .map(|(c, o)| (c.gpu.name.to_string(), *c, o.as_ref()))
        .collect();
    crate::trace::explain::plan_explain(&parts.model, &parts.wl, plan, &legs)
}

/// The parsed pieces of a plan/validate request body: workload, model,
/// framework, plan spec and the priced fleet legs (with their oracles
/// from the warm cache).
struct PlanParts {
    wl: WorkloadSpec,
    model: crate::models::ModelArch,
    fw: Framework,
    spec: crate::planner::PlanSpec,
    legs: Vec<(ClusterSpec, Arc<dyn LatencyOracle>)>,
    gpn: u32,
    nodes: u32,
}

/// Resolve one fleet-leg token (`GPU[@FABRIC]`, grammar shared with the
/// CLI's --fleet) to its cluster and warm-cache oracle — the leg half
/// of [`parse_plan_parts`], also used for a replan delta's added legs.
fn plan_leg(
    state: &State,
    name: &str,
    model: &str,
    gpn: u32,
    nodes: u32,
    fw: Framework,
) -> anyhow::Result<(ClusterSpec, Arc<dyn LatencyOracle>)> {
    let leg = crate::hardware::parse_fleet_leg(name, gpn)?;
    let key: DbKey =
        (model.to_string(), leg.gpu_name, gpn, nodes, fw.name().to_string(), leg.fabric_name);
    let entry = state.entry_for(&key)?;
    let oracle: Arc<dyn LatencyOracle> = match &entry.cal {
        // Per-request clone: private tier counters (DESIGN.md §8).
        Some(cal) => Arc::new((**cal).clone()),
        None => entry.db.clone(),
    };
    Ok((ClusterSpec::with_fabric(leg.gpu, gpn, nodes, leg.fabric), oracle))
}

/// Shared request parsing for `plan` and `validate`: both read the same
/// `"plan"` object; `validate` additionally replays the plan. One
/// parser so the two ops can never interpret the fields differently.
fn parse_plan_parts(req: &Json, state: &State) -> anyhow::Result<PlanParts> {
    let p = req.req("plan")?;
    let wl = WorkloadSpec::from_json(p.req("workload")?)?;
    let traffic = crate::planner::TrafficModel::from_json(p.req("traffic")?)?;
    let (gpn, nodes, fw) = protocol::parse_cluster_base(req)?;
    let model =
        by_name(&wl.model).ok_or_else(|| anyhow::anyhow!("unknown model '{}'", wl.model))?;

    let names: Vec<String> = match p.get("fleet") {
        Some(fj) => {
            let arr = fj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'fleet' must be an array of GPU name strings"))?;
            anyhow::ensure!(!arr.is_empty(), "'fleet' named no GPU types");
            arr.iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        anyhow::anyhow!("'fleet' entries must be GPU name strings, got {v:?}")
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        }
        None => vec![req.str_or("gpu", "h100").to_string()],
    };
    let mut legs: Vec<(ClusterSpec, Arc<dyn LatencyOracle>)> = Vec::new();
    for name in &names {
        legs.push(plan_leg(state, name, &wl.model, gpn, nodes, fw)?);
    }

    let spec = crate::planner::PlanSpec {
        workload: wl.clone(),
        traffic,
        windows: p.f64_or("windows", 24.0) as usize,
        window_h: p.f64_or("window_hours", 1.0),
        max_gpus: p.get("max_gpus").and_then(|v| v.as_f64()).map(|v| v as u32),
        prune: p.bool_or("prune", true),
        demand_override: Vec::new(),
    };
    Ok(PlanParts { wl, model, fw, spec, legs, gpn, nodes })
}

/// Plan-validation request (v2-only):
/// `{"v": 2, "op": "validate", "plan": {... as the plan op ...},
///   "validate": {"seed": 1, "len_jitter": 0.1, "scale_lag_s": 30,
///   "failure_rate_per_replica_h": 0.5, "restart_s": 120}, ...}`
/// → plans exactly as the `plan` op would, then replays a trace drawn
/// from the plan's own traffic model through the fleet-level
/// discrete-event simulator ([`crate::fleetsim`]) and reports the
/// per-window optimism gap (promised minus achieved SLA attainment,
/// attributed to queueing / scale-lag / contention / failures). The
/// `"validate"` object is optional; every knob defaults to the benign
/// value (no injection, no jitter).
fn handle_validate_request(req: &Json, state: &State, explain: bool) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let parts = parse_plan_parts(req, state)?;
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
        parts.legs.iter().map(|(c, d)| (*c, d.as_ref())).collect();
    let plan = crate::planner::plan(&parts.model, parts.fw, &parts.spec, &fleet)?;

    let v = req.get("validate");
    let knob = |k: &str, d: f64| v.map(|o| o.f64_or(k, d)).unwrap_or(d);
    let seed_f = knob("seed", crate::simulator::SimConfig::default().seed as f64);
    anyhow::ensure!(
        seed_f >= 0.0 && seed_f.fract() == 0.0 && seed_f < 9.0e15,
        "validate.seed must be a non-negative integer"
    );
    let seed = seed_f as u64;
    let len_jitter = knob("len_jitter", 0.0);
    anyhow::ensure!(
        (0.0..1.0).contains(&len_jitter),
        "validate.len_jitter must be in [0, 1), got {len_jitter}"
    );
    let cfg = crate::fleetsim::FleetConfig {
        seed,
        scale_lag_s: knob("scale_lag_s", 0.0),
        failure_rate_per_replica_h: knob("failure_rate_per_replica_h", 0.0),
        restart_s: knob("restart_s", 120.0),
        sim: crate::simulator::SimConfig { seed, ..Default::default() },
    };
    let trace = parts.spec.traffic.trace(
        parts.spec.windows,
        parts.spec.window_h,
        &parts.wl,
        len_jitter,
        seed,
    );
    anyhow::ensure!(
        !trace.is_empty(),
        "the traffic model produced an empty trace (all windows at zero QPS?) — \
         nothing to validate"
    );

    // The replay engines need each leg's silicon profile; the warm
    // cache holds databases, not Silicon, so rebuild per leg (cheap:
    // a profile lookup, not a profiling run).
    let silicons: Vec<Silicon> =
        parts.legs.iter().map(|(c, _)| Silicon::new(*c, parts.fw.profile())).collect();
    let fleet_legs: Vec<crate::fleetsim::FleetLeg<'_>> = parts
        .legs
        .iter()
        .zip(&silicons)
        .map(|((c, _), s)| crate::fleetsim::FleetLeg {
            name: c.gpu.name.to_string(),
            cluster: *c,
            silicon: s,
        })
        .collect();
    let report =
        crate::fleetsim::replay(&parts.model, &parts.spec, &plan, &fleet_legs, &trace, &cfg)?;

    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("trace_requests", json::num(trace.len() as f64))
        .set("plan", plan.to_json(&parts.wl))
        .set("report", report.to_json());
    if explain {
        resp.set("explain", plan_explain_json(&parts, &plan));
    }
    Ok(resp)
}

/// Differential replan request (v2-only):
/// `{"v": 2, "op": "replan", "plan": {... as the plan op ...},
///   "delta": {"kind": "search-delta", "window_edits": [...],
///   "reprice": [...], "add_legs": [...], "remove_legs": [...]}, ...}`
/// → plans exactly as the `plan` op would, applies the delta through
/// the incremental replan layer ([`crate::planner::replan`]) — only
/// added legs are swept; window edits, repricing and removals patch the
/// retained frontier — and reports the patched plan plus the config
/// diff (options that entered/left the deployment frontier, windows
/// whose choice changed) and the re-priced-candidate counts. The
/// result is bit-identical to a from-scratch `plan` of the patched
/// request (CI-pinned). `recalibrate` deltas are CLI-only: they need a
/// new calibration artifact, which a running server does not take.
fn handle_replan_request(req: &Json, state: &State, explain: bool) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let parts = parse_plan_parts(req, state)?;
    let delta = crate::search::SearchDelta::from_json(req.req("delta")?)?;
    anyhow::ensure!(
        delta.recalibrate.is_empty(),
        "'recalibrate' deltas are CLI-only: swapping a calibration artifact needs \
         `aiconf replan --calibration ...`, a running server keeps its launch-time calibration"
    );

    // Baseline plan + retained arena over per-request memos.
    let memos: Vec<MemoOracle<'_>> =
        parts.legs.iter().map(|(_, o)| MemoOracle::new(o.as_ref())).collect();
    let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        parts.legs.iter().zip(&memos).map(|((c, _), m)| (*c, m)).collect();
    let (baseline, mut arena) =
        crate::planner::plan_arena(&parts.model, parts.fw, &parts.spec, &fleet)?;

    // Added legs resolve through the same warm-cache path as the
    // original fleet legs.
    let added: Vec<(ClusterSpec, Arc<dyn LatencyOracle>)> = delta
        .add_legs
        .iter()
        .map(|n| plan_leg(state, n, &parts.wl.model, parts.gpn, parts.nodes, parts.fw))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let added_memos: Vec<MemoOracle<'_>> =
        added.iter().map(|(_, o)| MemoOracle::new(o.as_ref())).collect();
    let swept: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        added.iter().zip(&added_memos).map(|((c, _), m)| (*c, m)).collect();

    let rep =
        crate::planner::replan(&parts.model, parts.fw, &mut arena, &baseline, &delta, &swept)?;
    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("replan", rep.to_json(&parts.wl))
        .set(
            "schedule_yaml",
            json::s(&generator::dynamo::plan_schedule_yaml(&rep.plan, &parts.wl.model, &parts.wl)),
        );
    if explain {
        // Explained against the original legs only: an added leg's
        // oracle lives in this request frame, and the peak-window
        // breakdown falls back gracefully when its leg is absent.
        resp.set("explain", plan_explain_json(&parts, &rep.plan));
    }
    Ok(resp)
}

fn launch_json(cand: &Candidate, wl: &WorkloadSpec) -> Json {
    let bundle = generator::generate(cand, &wl.model, wl);
    let mut files = Json::obj();
    for (name, content) in &bundle.files {
        files.set(name, json::s(content));
    }
    files
}

/// Blocking client helper (used by examples/tests/benches).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(line.trim())
    }
}

/// Build a legacy (v1) search request JSON.
pub fn make_request(
    wl: &WorkloadSpec,
    gpu: &str,
    gpn: u32,
    nodes: u32,
    fw: Framework,
    id: u64,
) -> Json {
    let mut o = Json::obj();
    o.set("id", json::num(id as f64))
        .set("workload", wl.to_json())
        .set("gpu", json::s(gpu))
        .set("gpus_per_node", json::num(gpn as f64))
        .set("num_nodes", json::num(nodes as f64))
        .set("framework", json::s(fw.name()));
    o
}

/// Build the same search request as a v2 envelope.
pub fn make_request_v2(
    wl: &WorkloadSpec,
    gpu: &str,
    gpn: u32,
    nodes: u32,
    fw: Framework,
    id: u64,
) -> Json {
    let mut o = make_request(wl, gpu, gpn, nodes, fw, id);
    o.set("v", json::num(2.0)).set("op", json::s("search"));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> State {
        State::new(1)
    }

    fn legacy_key(model: &str) -> DbKey {
        (model.into(), "h100".into(), 8, 1, "trtllm".into(), "legacy".into())
    }

    #[test]
    fn request_roundtrip_in_process() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 7);
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert_eq!(resp.req_f64("id").unwrap(), 7.0);
        assert_eq!(resp.req_f64("v").unwrap(), 1.0, "legacy requests answer tagged v1");
        assert!(resp.req_f64("feasible").unwrap() > 0.0);
        let top = resp.req("top").unwrap().as_arr().unwrap();
        assert!(!top.is_empty());
        assert!(top[0].req_f64("thru_per_gpu").unwrap() > 0.0);
        assert!(resp.get("launch").is_some());
    }

    #[test]
    fn v2_envelope_answers_like_v1() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let v1 = handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 7), &st)
            .unwrap();
        let v2 = handle_request(&make_request_v2(&wl, "h100", 8, 1, Framework::TrtLlm, 7), &st)
            .unwrap();
        assert_eq!(v2.req_f64("v").unwrap(), 2.0);
        // Identical payload modulo the envelope tag and wall clock.
        let strip = |mut j: Json| {
            if let Json::Obj(m) = &mut j {
                m.remove("v");
                m.remove("elapsed_ms");
            }
            j
        };
        assert_eq!(strip(v1), strip(v2));
    }

    #[test]
    fn db_cache_reused() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        handle_request(&req, &st).unwrap();
        assert_eq!(st.cache().len(), 1);
        handle_request(&req, &st).unwrap();
        assert_eq!(st.cache().len(), 1);
        let (hits, misses, _) = st.cache().stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn sweep_request_matches_independent_requests() {
        let st = state();
        let wl_a = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let wl_b = WorkloadSpec::new("llama3.1-8b", 512, 64, 3000.0, 5.0);

        let mut sweep_req = Json::obj();
        sweep_req
            .set("workloads", Json::Arr(vec![wl_a.to_json(), wl_b.to_json()]))
            .set("gpu", json::s("h100"))
            .set("gpus_per_node", json::num(8.0))
            .set("num_nodes", json::num(1.0))
            .set("framework", json::s("trtllm"));
        let sweep = handle_request(&sweep_req, &st).unwrap();
        assert_eq!(sweep.req_str("status").unwrap(), "ok");
        assert_eq!(sweep.req_f64("scenarios").unwrap(), 2.0);
        let results = sweep.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);

        for (wl, res) in [wl_a, wl_b].iter().zip(results) {
            let single = handle_request(
                &make_request(wl, "h100", 8, 1, Framework::TrtLlm, 1),
                &st,
            )
            .unwrap();
            assert_eq!(
                res.req_f64("feasible").unwrap(),
                single.req_f64("feasible").unwrap()
            );
            let t_sweep = res.req("top").unwrap().as_arr().unwrap()[0]
                .req_f64("thru_per_gpu")
                .unwrap();
            let t_single = single.req("top").unwrap().as_arr().unwrap()[0]
                .req_f64("thru_per_gpu")
                .unwrap();
            assert_eq!(t_sweep, t_single);
        }
    }

    #[test]
    fn sweep_rejects_mixed_models() {
        let st = state();
        let mut req = Json::obj();
        req.set(
            "workloads",
            Json::Arr(vec![
                WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0).to_json(),
                WorkloadSpec::new("qwen3-32b", 512, 64, 2000.0, 5.0).to_json(),
            ]),
        );
        let err = handle_request(&req, &st).unwrap_err();
        assert!(err.to_string().contains("same model"));
    }

    fn plan_request(fleet: &[&str], windows: f64) -> Json {
        let mut traffic = Json::obj();
        traffic
            .set("kind", json::s("diurnal"))
            .set("peak_qps", json::num(80.0))
            .set("trough_qps", json::num(4.0))
            .set("period_h", json::num(24.0));
        let mut plan = Json::obj();
        plan.set(
            "workload",
            WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0).to_json(),
        )
        .set("traffic", traffic)
        .set("windows", json::num(windows))
        .set("window_hours", json::num(24.0 / windows))
        .set("fleet", Json::Arr(fleet.iter().map(|g| json::s(g)).collect()));
        let mut req = Json::obj();
        req.set("plan", plan)
            .set("gpus_per_node", json::num(8.0))
            .set("num_nodes", json::num(1.0))
            .set("framework", json::s("trtllm"))
            .set("id", json::num(42.0));
        req
    }

    #[test]
    fn plan_request_returns_schedule() {
        let st = state();
        let resp = handle_request(&plan_request(&["h100"], 4.0), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert_eq!(resp.req_f64("id").unwrap(), 42.0);
        let plan = resp.req("plan").unwrap();
        let windows = plan.req("windows").unwrap().as_arr().unwrap();
        assert_eq!(windows.len(), 4);
        for w in windows {
            assert!(w.req_f64("capacity_qps").unwrap() >= w.req_f64("demand_qps").unwrap());
        }
        assert!(plan.req_f64("total_cost_usd").unwrap() > 0.0);
        assert!(
            plan.req_f64("total_cost_usd").unwrap()
                <= plan.req_f64("static_peak_cost_usd").unwrap() + 1e-9
        );
        let yaml = resp.req_str("schedule_yaml").unwrap();
        assert!(yaml.contains("kind: DeploymentSchedule"));
        assert!(yaml.contains("- window: 0"));
        // The leg database landed in the shared warm cache.
        assert_eq!(st.cache().len(), 1);
    }

    #[test]
    fn plan_request_heterogeneous_fleet_never_loses_to_homogeneous() {
        let st = state();
        let resp = handle_request(&plan_request(&["h100", "a100"], 3.0), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let plan = resp.req("plan").unwrap();
        if let Some(h) = plan.get("best_homogeneous") {
            assert!(
                plan.req_f64("total_cost_usd").unwrap() <= h.req_f64("cost_usd").unwrap() + 1e-9
            );
        }
        assert_eq!(st.cache().len(), 2, "one cached db per fleet leg");
    }

    #[test]
    fn validate_request_replays_the_plan_and_reports_the_gap() {
        let st = state();
        // Tiny trace so the in-process replay stays fast: two 36 s
        // windows at ~1-2 QPS, generous SLA.
        let mut traffic = Json::obj();
        traffic
            .set("kind", json::s("diurnal"))
            .set("peak_qps", json::num(2.0))
            .set("trough_qps", json::num(1.0))
            .set("period_h", json::num(0.02));
        let mut plan = Json::obj();
        plan.set(
            "workload",
            WorkloadSpec::new("llama3.1-8b", 256, 32, 5000.0, 2.0).to_json(),
        )
        .set("traffic", traffic)
        .set("windows", json::num(2.0))
        .set("window_hours", json::num(0.01))
        .set("fleet", Json::Arr(vec![json::s("h100")]));
        let mut req = Json::obj();
        req.set("v", json::num(2.0))
            .set("op", json::s("validate"))
            .set("plan", plan)
            .set("gpus_per_node", json::num(8.0))
            .set("num_nodes", json::num(1.0))
            .set("framework", json::s("trtllm"))
            .set("id", json::num(9.0));
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert_eq!(resp.req_f64("id").unwrap(), 9.0);
        assert!(resp.req_f64("trace_requests").unwrap() > 0.0);
        assert!(resp.get("plan").is_some(), "the planned schedule rides along");
        let report = resp.req("report").unwrap();
        assert!(report.req_f64("offered").unwrap() > 0.0);
        assert_eq!(report.req("windows").unwrap().as_arr().unwrap().len(), 2);
        // No injection: the plan keeps (most of) its promise.
        assert!(
            report.req_f64("optimism_gap").unwrap() <= 0.5,
            "gap {} too large for an uninjected replay",
            report.req_f64("optimism_gap").unwrap()
        );
        // The op is first-class in the stats rollup.
        let stats_resp =
            handle_request(&json::parse(r#"{"v": 2, "op": "stats"}"#).unwrap(), &st).unwrap();
        let counts = stats_resp.req("stats").unwrap().req("requests").unwrap();
        assert_eq!(counts.req("validate").unwrap().req_f64("count").unwrap(), 1.0);
    }

    #[test]
    fn replan_request_applies_delta_and_matches_a_fresh_plan() {
        let st = state();
        // From-scratch reference: a plan over the patched (two-leg)
        // fleet. The replan below must reproduce it bit for bit.
        let fresh = handle_request(&plan_request(&["h100", "a100"], 3.0), &st).unwrap();
        // Replan: start from h100 only, the delta adds the a100 leg.
        let mut req = plan_request(&["h100"], 3.0);
        req.set("v", json::num(2.0)).set("op", json::s("replan"));
        let mut delta = Json::obj();
        delta
            .set("kind", json::s("search-delta"))
            .set("add_legs", Json::Arr(vec![json::s("a100")]));
        req.set("delta", delta);
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let rep = resp.req("replan").unwrap();
        assert!(rep.req_f64("repriced_configs").unwrap() > 0.0, "the added leg is swept");
        assert!(
            rep.req_f64("repriced_configs").unwrap()
                < rep.req_f64("baseline_priced_configs").unwrap(),
            "replan must price strictly fewer configs than a full re-search"
        );
        assert_eq!(
            rep.req("plan").unwrap().to_string(),
            fresh.req("plan").unwrap().to_string(),
            "incremental replan must be bit-identical to the from-scratch plan"
        );
        assert!(resp.req_str("schedule_yaml").unwrap().contains("kind: DeploymentSchedule"));
        // Counted as its own op in the stats rollup.
        let stats_resp =
            handle_request(&json::parse(r#"{"v": 2, "op": "stats"}"#).unwrap(), &st).unwrap();
        let counts = stats_resp.req("stats").unwrap().req("requests").unwrap();
        assert_eq!(counts.req("replan").unwrap().req_f64("count").unwrap(), 1.0);
    }

    #[test]
    fn replan_request_reprice_prices_nothing_and_recalibrate_is_rejected() {
        let st = state();
        let mut req = plan_request(&["h100"], 3.0);
        req.set("v", json::num(2.0)).set("op", json::s("replan"));
        let mut delta = Json::obj();
        let mut rp = Json::obj();
        rp.set("gpu", json::s("h100")).set("usd_per_hour", json::num(1.49));
        delta.set("kind", json::s("search-delta")).set("reprice", Json::Arr(vec![rp]));
        req.set("delta", delta);
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let rep = resp.req("replan").unwrap();
        assert_eq!(
            rep.req_f64("repriced_configs").unwrap(),
            0.0,
            "a GPU reprice is a pure cost re-derivation"
        );
        assert!(rep.req_f64("baseline_priced_configs").unwrap() > 0.0);

        let mut req = plan_request(&["h100"], 2.0);
        req.set("v", json::num(2.0)).set("op", json::s("replan"));
        let mut delta = Json::obj();
        delta
            .set("kind", json::s("search-delta"))
            .set("recalibrate", Json::Arr(vec![json::s("h100")]));
        req.set("delta", delta);
        let err = handle_request(&req, &st).unwrap_err();
        assert!(err.to_string().contains("CLI-only"), "{err:#}");
    }

    #[test]
    fn plan_request_bad_traffic_is_error() {
        let st = state();
        let mut req = plan_request(&["h100"], 2.0);
        // Overwrite traffic with an unknown kind.
        let mut traffic = Json::obj();
        traffic.set("kind", json::s("square"));
        let mut plan = req.req("plan").unwrap().clone();
        plan.set("traffic", traffic);
        req.set("plan", plan);
        assert!(handle_request(&req, &st).is_err());
    }

    #[test]
    fn bad_model_is_error() {
        let st = state();
        let wl = WorkloadSpec::new("not-a-model", 512, 64, 2000.0, 5.0);
        let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        assert!(handle_request(&req, &st).is_err());
    }

    #[test]
    fn static_mode_request_is_rejected_not_silently_empty() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let mut req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        req.set("modes", Json::Arr(vec![json::s("static")]));
        let err = handle_request(&req, &st).unwrap_err();
        assert!(err.to_string().contains("static"), "{err}");
        // Unknown mode strings are also loud errors, not silent drops.
        let mut req2 = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        req2.set("modes", Json::Arr(vec![json::s("warp-drive")]));
        assert!(handle_request(&req2, &st).is_err());
    }

    #[test]
    fn calibrated_state_reports_tiers_for_matching_context_only() {
        use crate::models::Dtype;
        // Fit an artifact for the llama3.1-8b/h100/trtllm/fp8 context.
        let cluster = ClusterSpec::new(gpu_by_name("h100").unwrap(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("llama3.1-8b").unwrap();
        let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 1);
        let sets = crate::perfdb::measure::synthesize(&sil, &model, Dtype::Fp8, 3, 12);
        let art = crate::perfdb::calibrate::fit(&db, &sets).unwrap();
        let st = State::with_calibration(1, art);

        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let resp =
            handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let tiers = resp.req("tiers").unwrap();
        assert!(
            tiers.req_f64("calibrated").unwrap() + tiers.req_f64("measured").unwrap() > 0.0,
            "calibrated context must answer through the calibrated tiers"
        );
        // The composition is cached in the warm entry, and each request
        // gets a private accounting scope: an identical second request
        // reports the same tier volume, not a cumulative one.
        let resp_again =
            handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 2), &st).unwrap();
        let entry = st.cache().peek(&legacy_key("llama3.1-8b")).unwrap();
        assert!(entry.cal.is_some(), "matching context caches its composition");
        let t2 = resp_again.req("tiers").unwrap();
        let total = |t: &Json| {
            t.req_f64("measured").unwrap()
                + t.req_f64("calibrated").unwrap()
                + t.req_f64("analytic").unwrap()
                + t.req_f64("sol").unwrap()
        };
        assert_eq!(total(tiers), total(t2), "tier counts must be per-request");
        // A different model context stays analytic — no tiers reported.
        let wl2 = WorkloadSpec::new("qwen3-32b", 512, 64, 2000.0, 5.0);
        let resp2 =
            handle_request(&make_request(&wl2, "h100", 8, 1, Framework::TrtLlm, 3), &st).unwrap();
        assert_eq!(resp2.req_str("status").unwrap(), "ok");
        assert!(resp2.get("tiers").is_none());
        let entry2 = st.cache().peek(&legacy_key("qwen3-32b")).unwrap();
        assert!(entry2.cal.is_none(), "non-matching context stays analytic");
    }

    #[test]
    fn fabric_request_reports_placements_and_caches_separately() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, f64::INFINITY, 0.0);
        let mut req = make_request(&wl, "h100", 8, 2, Framework::TrtLlm, 9);
        req.set("fabric", json::s("hgx-h100"));
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let top = resp.req("top").unwrap().as_arr().unwrap();
        assert!(!top.is_empty());
        for t in top {
            assert!(t.req_str("placement").is_ok(), "placement field missing: {t:?}");
        }
        // The same context on the legacy fabric is a different cache
        // entry (different comm tables).
        let legacy = handle_request(&make_request(&wl, "h100", 8, 2, Framework::TrtLlm, 10), &st)
            .unwrap();
        assert_eq!(legacy.req_str("status").unwrap(), "ok");
        assert_eq!(st.cache().len(), 2);
        // Unknown fabrics are loud errors, not silent legacy fallbacks.
        let mut bad = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 11);
        bad.set("fabric", json::s("warp-fabric"));
        assert!(handle_request(&bad, &st).is_err());
    }

    #[test]
    fn response_reports_flag_deltas_and_honors_overrides() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let resp =
            handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st).unwrap();
        let flags = resp.req("flags").unwrap().as_arr().unwrap();
        assert_eq!(flags.len(), 1);
        assert_eq!(flags[0].req_str("framework").unwrap(), "trtllm");
        assert!(flags[0].req_f64("engines_total").unwrap() > 0.0);
        assert!(flags[0].req_f64("engines_off_default").unwrap() > 0.0);

        // Per-request overrides pin the flag values across the grid.
        let mut req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 2);
        let mut over = Json::obj();
        over.set("max_num_tokens", json::num(4096.0)).set("kv_frac", json::num(0.8));
        req.set("flags", over);
        let resp = handle_request(&req, &st).unwrap();
        let flags = resp.req("flags").unwrap().as_arr().unwrap();
        assert_eq!(flags[0].req_f64("resolved_max_num_tokens_min").unwrap(), 4096.0);
        assert_eq!(flags[0].req_f64("resolved_max_num_tokens_max").unwrap(), 4096.0);
        assert_eq!(flags[0].req_f64("resolved_kv_frac_min").unwrap(), 0.8);
    }

    #[test]
    fn stats_request_reports_counts_without_a_pipeline() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st).unwrap();
        let req = json::parse(r#"{"v": 2, "op": "stats", "id": 5}"#).unwrap();
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert_eq!(resp.req_f64("id").unwrap(), 5.0);
        let stats = resp.req("stats").unwrap();
        assert_eq!(
            stats.req("requests").unwrap().req("search").unwrap().req_f64("count").unwrap(),
            1.0
        );
        assert_eq!(stats.req("cache").unwrap().req_f64("entries").unwrap(), 1.0);
        // No pipeline → no pool gauges.
        assert!(stats.get("pool").is_none());
        assert!(resp.req_str("metrics_text").unwrap().contains("aiconf_requests_total"));
    }

    #[test]
    fn explain_flag_attaches_the_report_and_stays_off_by_default() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let plain =
            handle_request(&make_request_v2(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st)
                .unwrap();
        assert!(plain.get("explain").is_none(), "explain is strictly opt-in");

        let mut req = make_request_v2(&wl, "h100", 8, 1, Framework::TrtLlm, 2);
        req.set("explain", Json::Bool(true));
        let resp = handle_request(&req, &st).unwrap();
        let e = resp.req("explain").unwrap();
        assert_eq!(e.req_str("kind").unwrap(), "search-explain");
        let phases = e.req("winner").unwrap().req("phases").unwrap();
        assert!(phases.req("prefill").unwrap().get("gemm").is_some());
        assert!(e.req("pruning").unwrap().req_f64("configs_priced").unwrap() > 0.0);

        let mut preq = plan_request(&["h100"], 2.0);
        preq.set("v", json::num(2.0))
            .set("op", json::s("plan"))
            .set("explain", Json::Bool(true));
        let presp = handle_request(&preq, &st).unwrap();
        let pe = presp.req("explain").unwrap();
        assert_eq!(pe.req_str("kind").unwrap(), "plan-explain");
        assert!(pe.req("costs").unwrap().req_f64("total_usd").unwrap() > 0.0);
        // The explain report rides next to the plan, never inside it
        // (the replan bit-equality pin compares plan JSON strings).
        assert!(presp.req("plan").unwrap().get("explain").is_none());
    }

    #[test]
    fn trace_sampling_feeds_the_span_metrics() {
        let mut st = State::new(1);
        st.set_trace_sample(1);
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st).unwrap();
        let resp =
            handle_request(&json::parse(r#"{"v": 2, "op": "stats"}"#).unwrap(), &st).unwrap();
        let spans = resp.req("stats").unwrap().req("spans").unwrap();
        assert!(
            spans.req("search").unwrap().req_f64("count").unwrap() >= 1.0,
            "a sampled search must record search-category spans"
        );
        assert!(spans.req("price").unwrap().req_f64("total_us").unwrap() >= 0.0);
        assert!(resp
            .req_str("metrics_text")
            .unwrap()
            .contains("aiconf_span_count{cat=\"search\"}"));
    }
}

//! Config-search service: the L3 serving coordinator.
//!
//! A threaded TCP server speaking JSON-lines: each request carries a
//! workload descriptor + cluster/framework context; the server runs the
//! TaskRunner → Pareto pipeline and answers with the top configurations
//! and ready-to-launch files. Databases are built on demand and cached
//! per (model, hardware, framework) context — the paper's 5-step
//! workflow behind one socket.
//!
//! When started with an artifacts directory, interpolation queries from
//! *all* connections funnel through the single PJRT evaluator thread
//! ([`crate::runtime::PjrtService`]) — a dynamic batcher over the
//! AOT-compiled Pallas kernel. (The vendored build has no tokio, so
//! concurrency is plain OS threads; see DESIGN.md.)

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::{Candidate, ServingMode, WorkloadSpec};
use crate::frameworks::Framework;
use crate::generator;
use crate::hardware::{gpu_by_name, ClusterSpec};
use crate::models::by_name;
use crate::pareto;
use crate::perfdb::{CalibratedDb, CalibrationArtifact, LatencyOracle, PerfDatabase};
use crate::runtime::{PjrtOracle, PjrtService};
use crate::search::{SearchSpace, TaskRunner};
use crate::silicon::Silicon;
use crate::util::json::{self, Json};

/// Server configuration.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    /// Bind address, e.g. "127.0.0.1:0" (0 = ephemeral).
    pub addr: String,
    /// Artifacts dir for the PJRT-backed hot path (None = native interp).
    pub artifacts: Option<PathBuf>,
    /// Calibration artifact (from the `calibrate` CLI): composed over
    /// the database of every request whose context matches the
    /// artifact's; other contexts stay analytic.
    pub calibration: Option<PathBuf>,
    pub seed: u64,
}

/// (model, gpu, gpus_per_node, num_nodes, framework, fabric) — the
/// fabric name is part of the cache key: the same GPU pool wired as
/// `legacy` and as `gb200-nvl72` profiles different comm tables.
type DbKey = (String, String, u32, u32, String, String);

/// Shared server state (public so in-process embedding — tests, the
/// serve_e2e example — can drive requests without a socket).
pub struct State {
    dbs: Mutex<HashMap<DbKey, Arc<PerfDatabase>>>,
    /// Calibrated composition per context, built lazily from `artifact`.
    cals: Mutex<HashMap<DbKey, Arc<CalibratedDb>>>,
    /// Calibration artifact loaded at startup (if any).
    artifact: Option<CalibrationArtifact>,
    /// PJRT evaluator bound to the context named at startup (if any).
    pjrt: Option<(DbKey, PjrtService)>,
    seed: u64,
}

impl State {
    pub fn new(seed: u64) -> State {
        State {
            dbs: Mutex::new(HashMap::new()),
            cals: Mutex::new(HashMap::new()),
            artifact: None,
            pjrt: None,
            seed,
        }
    }

    /// A state whose matching-context requests answer through the
    /// calibrated three-tier chain.
    pub fn with_calibration(seed: u64, artifact: CalibrationArtifact) -> State {
        let mut st = State::new(seed);
        st.artifact = Some(artifact);
        st
    }
}

/// The running server handle.
pub struct SearchServer {
    listener: TcpListener,
    state: Arc<State>,
    stop: Arc<AtomicBool>,
}

impl SearchServer {
    /// Bind. If `cfg.artifacts` is set, also pre-build the database for
    /// `pjrt_ctx` and start the PJRT evaluator on its grids.
    pub fn bind(cfg: &ServerConfig, pjrt_ctx: Option<(&str, &str, u32, u32, Framework)>) -> anyhow::Result<(SearchServer, SocketAddr)> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let mut dbs = HashMap::new();
        let mut pjrt = None;
        if let (Some(dir), Some((model, gpu, gpn, nodes, fw))) = (&cfg.artifacts, pjrt_ctx) {
            let key: DbKey =
                (model.into(), gpu.into(), gpn, nodes, fw.name().into(), "legacy".into());
            let db = Arc::new(build_db(&key, cfg.seed)?);
            let svc = PjrtService::start(dir, db.grids().to_vec())?;
            dbs.insert(key.clone(), db);
            pjrt = Some((key, svc));
        }
        let artifact = match &cfg.calibration {
            Some(path) => Some(CalibrationArtifact::load(path)?),
            None => None,
        };
        Ok((
            SearchServer {
                listener,
                state: Arc::new(State {
                    dbs: Mutex::new(dbs),
                    cals: Mutex::new(HashMap::new()),
                    artifact,
                    pjrt,
                    seed: cfg.seed,
                }),
                stop: Arc::new(AtomicBool::new(false)),
            },
            addr,
        ))
    }

    /// Handle to request shutdown from another thread.
    pub fn stopper(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop (blocks). Each connection gets a thread; each line is
    /// one request. Returns when the stop flag is set (checked between
    /// connections — poke it with a dummy connect).
    pub fn run(self) -> anyhow::Result<()> {
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let state = self.state.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &state);
            });
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, state: &State) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = match handle_request_line(line.trim(), state) {
            Ok(j) => j,
            Err(e) => {
                let mut o = Json::obj();
                o.set("status", json::s("error")).set("error", json::s(&format!("{e:#}")));
                o
            }
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

fn build_db(key: &DbKey, seed: u64) -> anyhow::Result<PerfDatabase> {
    let (model_name, gpu_name, gpn, nodes, fw_name, fabric_name) = key;
    let model =
        by_name(model_name).ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let gpu = gpu_by_name(gpu_name).ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}'"))?;
    let fw = Framework::parse(fw_name)
        .ok_or_else(|| anyhow::anyhow!("unknown framework '{fw_name}'"))?;
    let fabric = crate::topology::fabric::by_name(fabric_name, *gpn)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric '{fabric_name}'"))?;
    let cluster = ClusterSpec::with_fabric(gpu, *gpn, *nodes, fabric);
    let silicon = Silicon::new(cluster, fw.profile());
    // Ampere has no FP8 tensor cores: `preferred_kv_dtype` profiles
    // such contexts at FP16 — the same default the CLI `plan` path and
    // the planner's engine space use, so service plans price a100
    // fleet legs consistently with the CLI.
    Ok(PerfDatabase::build(&silicon, &model, gpu.preferred_kv_dtype(), seed))
}

/// Handle one JSON request line (exposed for in-process tests).
pub fn handle_request_line(line: &str, state: &State) -> anyhow::Result<Json> {
    let req = json::parse(line)?;
    handle_request(&req, state)
}

pub fn handle_request(req: &Json, state: &State) -> anyhow::Result<Json> {
    // Capacity-plan form: {"plan": {...}} searches a traffic-aware
    // replica schedule instead of a single-point configuration.
    if req.get("plan").is_some() {
        return handle_plan_request(req, state);
    }
    // Batch form: {"workloads": [wl, wl, ...]} prices many scenarios in
    // one sweep (shared engine enumeration + memoized oracle queries).
    if req.get("workloads").is_some() {
        return handle_sweep_request(req, state);
    }
    let t0 = Instant::now();
    let wl = WorkloadSpec::from_json(req.req("workload")?)?;
    let ctx = request_ctx(req, state, &wl.model)?;

    let runner = TaskRunner::new(&ctx.model, &ctx.cluster, ctx.space.clone(), wl.clone());
    // PJRT hot path when the request matches the bound context;
    // calibrated chain when the context matches the loaded artifact.
    let report = match &state.pjrt {
        Some((pk, svc)) if *pk == ctx.key => {
            let oracle = PjrtOracle { svc, db: &ctx.db };
            runner.run(&oracle)
        }
        _ => match &ctx.cal {
            Some(cal) => runner.run(cal.as_ref()),
            None => runner.run(ctx.db.as_ref() as &dyn LatencyOracle),
        },
    };
    let top_k = ctx.top_k;
    let analysis = pareto::analyze(&report.evaluated, &wl.sla);

    // Response.
    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("configs_priced", json::num(report.configs_priced as f64))
        .set("candidates", json::num(report.evaluated.len() as f64))
        .set("feasible", json::num(analysis.feasible.len() as f64))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("top", top_json(&analysis, top_k))
        .set("flags", flags_json(&report));
    if let Some(t) = report.tier_counts {
        resp.set("tiers", tiers_json(&t));
    }
    if let Some(id) = req.get("id") {
        resp.set("id", id.clone());
    }
    if let Some(best) = analysis.best() {
        resp.set("launch", launch_json(&best.cand, &wl));
    }
    Ok(resp)
}

/// Deployment context parsed from a request's shared fields — one
/// parser for both the single-workload and batch-sweep handlers so the
/// two paths can never interpret request fields differently.
struct ReqCtx {
    model: crate::models::ModelArch,
    cluster: ClusterSpec,
    top_k: usize,
    key: DbKey,
    db: Arc<PerfDatabase>,
    /// Calibrated composition when the server's artifact matches this
    /// request's context (answers then carry provenance tiers).
    cal: Option<Arc<CalibratedDb>>,
    space: SearchSpace,
}

fn request_ctx(req: &Json, state: &State, model_name: &str) -> anyhow::Result<ReqCtx> {
    let gpu_name = req.str_or("gpu", "h100");
    let gpn = req.f64_or("gpus_per_node", 8.0) as u32;
    let nodes = req.f64_or("num_nodes", 1.0) as u32;
    let fw = Framework::parse(req.str_or("framework", "trtllm"))
        .ok_or_else(|| anyhow::anyhow!("unknown framework"))?;
    let top_k = req.f64_or("top_k", 5.0) as usize;
    // Optional tiered fabric ("hgx-h100", "gb200-nvl72", ...); absent =
    // the legacy flat topology, bit-for-bit the pre-fabric behavior.
    let fabric_name = req.str_or("fabric", "legacy").to_string();
    let fabric = crate::topology::fabric::by_name(&fabric_name, gpn)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric '{fabric_name}'"))?;
    // A PJRT-bound server answers its context from the AOT kernel,
    // which prices the packed layout only: reject fabric requests
    // loudly (the CLI does the same for --fabric with --pjrt) instead
    // of silently falling through to a different oracle.
    anyhow::ensure!(
        state.pjrt.is_none() || !fabric.placement_aware(),
        "'fabric' is not supported on a PJRT-bound server: the AOT kernel prices the \
         packed layout only (restart without --pjrt or drop the fabric field)"
    );

    let model =
        by_name(model_name).ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let gpu =
        gpu_by_name(gpu_name).ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}'"))?;
    let cluster = ClusterSpec::with_fabric(gpu, gpn, nodes, fabric);

    // Database: cached per context.
    let key: DbKey =
        (model_name.to_string(), gpu_name.to_string(), gpn, nodes, fw.name().to_string(), fabric_name);
    let db = db_for(state, &key)?;
    let cal = calibrated_for(state, &key, &db)?;

    // Search space (modes and launch-flag handling overridable per
    // request).
    let mut space = SearchSpace::default_for(&model, fw);
    if let Some(modes) = req.get("modes").and_then(|m| m.as_arr()) {
        space.modes = modes
            .iter()
            .map(|m| {
                m.as_str()
                    .and_then(ServingMode::parse)
                    .ok_or_else(|| anyhow::anyhow!("unknown serving mode {m:?} in 'modes'"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    // `static` parses but is not a searchable deployment shape: reject
    // loudly instead of pricing nothing (see crate::search).
    crate::search::ensure_searchable_modes(&space.modes)?;
    // Overrides are validated loudly: a wrong-typed value is an error,
    // never a silent fall-through to the resolver.
    if let Some(v) = req.get("flag_sweep") {
        space.flag_sweep = v
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("'flag_sweep' must be a boolean"))?;
    }
    if let Some(flags) = req.get("flags") {
        if let Some(v) = flags.get("max_num_tokens") {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("flags.max_num_tokens must be a number"))?;
            anyhow::ensure!(
                (1.0..=u32::MAX as f64).contains(&x) && x.fract() == 0.0,
                "flags.max_num_tokens must be a positive integer"
            );
            space.max_num_tokens = vec![x as u32];
        }
        if let Some(v) = flags.get("kv_frac") {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("flags.kv_frac must be a number"))?;
            anyhow::ensure!(x > 0.0 && x <= 1.0, "flags.kv_frac must be in (0, 1]");
            space.kv_frac = vec![x];
        }
        if let Some(v) = flags.get("cuda_graph") {
            let b = v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("flags.cuda_graph must be a boolean"))?;
            space.cuda_graph = vec![b];
        }
    }
    Ok(ReqCtx { model, cluster, top_k, key, db, cal, space })
}

/// Per-tier oracle query counts of a report, as JSON.
fn tiers_json(t: &crate::perfdb::TierSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("measured", json::num(t.measured as f64))
        .set("calibrated", json::num(t.calibrated as f64))
        .set("analytic", json::num(t.analytic as f64))
        .set("sol", json::num(t.sol as f64));
    o
}

/// Lazily compose (and cache) the server's calibration artifact over a
/// context's database. `None` when no artifact is loaded or its
/// profiling context differs from this request's. The returned value
/// is a **clone** of the cached composition (grids copied by value,
/// tier counters fresh), so each request accounts its own tier counts
/// even when concurrent requests share a context. The ~2 MB grid copy
/// is deliberate: it costs ~0.1 ms against a search that runs for
/// hundreds, and keeps CalibratedDb free of interior Arcs.
fn calibrated_for(
    state: &State,
    key: &DbKey,
    db: &Arc<PerfDatabase>,
) -> anyhow::Result<Option<Arc<CalibratedDb>>> {
    let Some(art) = &state.artifact else { return Ok(None) };
    // Artifacts are fitted against legacy-fabric grids; tiered-fabric
    // contexts stay analytic (same "silently analytic on non-matching
    // context" contract as the other fields — `CalibratedDb::compose`
    // would reject the combination loudly).
    if db.cluster.fabric.placement_aware() {
        return Ok(None);
    }
    let matches = art.gpu == db.ctx.gpu
        && art.gpus_per_node == db.ctx.gpus_per_node
        && art.num_nodes == db.ctx.num_nodes
        && art.model == db.ctx.model
        && art.framework == db.ctx.framework
        && art.kv_dtype == db.ctx.kv_dtype;
    if !matches {
        return Ok(None);
    }
    let mut cals = state.cals.lock().unwrap();
    if let Some(c) = cals.get(key) {
        return Ok(Some(Arc::new((**c).clone())));
    }
    let c = Arc::new(CalibratedDb::compose((**db).clone(), art)?);
    cals.insert(key.clone(), c.clone());
    Ok(Some(Arc::new((*c).clone())))
}

/// Per-framework resolved-vs-default flag deltas of a report, as JSON.
fn flags_json(report: &crate::search::SearchReport) -> Json {
    let mut arr = Vec::new();
    for s in &report.flag_summaries {
        let mut o = Json::obj();
        o.set("framework", json::s(s.framework.name()))
            .set("default_kv_frac", json::num(s.defaults.kv_frac))
            .set("default_max_num_tokens", json::num(s.defaults.max_num_tokens as f64))
            .set("resolved_kv_frac_min", json::num(s.kv_frac_min))
            .set("resolved_kv_frac_max", json::num(s.kv_frac_max))
            .set("resolved_max_num_tokens_min", json::num(s.mnt_min as f64))
            .set("resolved_max_num_tokens_max", json::num(s.mnt_max as f64))
            .set("engines_off_default", json::num(s.nondefault as f64))
            .set("engines_total", json::num(s.total as f64));
        arr.push(o);
    }
    Json::Arr(arr)
}

/// Fetch (or build and cache) the database for a context key.
fn db_for(state: &State, key: &DbKey) -> anyhow::Result<Arc<PerfDatabase>> {
    let mut dbs = state.dbs.lock().unwrap();
    match dbs.get(key) {
        Some(db) => Ok(db.clone()),
        None => {
            let db = Arc::new(build_db(key, state.seed)?);
            dbs.insert(key.clone(), db.clone());
            Ok(db)
        }
    }
}

/// Top-k feasible candidates as a JSON array.
fn top_json(analysis: &pareto::Analysis, top_k: usize) -> Json {
    let mut top = Vec::new();
    for e in analysis.feasible.iter().take(top_k) {
        // The chosen rank layout (EXPERIMENTS.md "placement" field):
        // the decode pool's placement for disaggregated composites.
        let placement = match &e.cand {
            Candidate::Aggregated { engine, .. } => engine.placement,
            Candidate::Disaggregated { decode, .. } => decode.placement,
        };
        let mut o = Json::obj();
        o.set("config", json::s(&e.cand.label()))
            .set("mode", json::s(e.cand.mode().name()))
            .set("placement", json::s(&placement.label()))
            .set("gpus", json::num(e.cand.total_gpus() as f64))
            .set("ttft_ms", json::num(e.est.ttft_ms))
            .set("tpot_ms", json::num(e.est.tpot_ms))
            .set("speed", json::num(e.est.speed))
            .set("thru_per_gpu", json::num(e.est.thru_per_gpu));
        top.push(o);
    }
    Json::Arr(top)
}

/// Batch sweep: price every workload scenario in one TaskRunner pass
/// (shared engine enumeration + memoized oracle), answering one result
/// object per scenario.
fn handle_sweep_request(req: &Json, state: &State) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let wls_json = req
        .req("workloads")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("'workloads' must be an array"))?;
    anyhow::ensure!(!wls_json.is_empty(), "'workloads' array is empty");
    let wls: Vec<WorkloadSpec> = wls_json
        .iter()
        .map(WorkloadSpec::from_json)
        .collect::<anyhow::Result<Vec<_>>>()?;
    anyhow::ensure!(
        wls.iter().all(|w| w.model == wls[0].model),
        "all workloads in a sweep must target the same model"
    );
    let ctx = request_ctx(req, state, &wls[0].model)?;
    let top_k = ctx.top_k;

    let runner = TaskRunner::new(&ctx.model, &ctx.cluster, ctx.space.clone(), wls[0].clone());
    let reports = match &state.pjrt {
        Some((pk, svc)) if *pk == ctx.key => {
            let oracle = PjrtOracle { svc, db: &ctx.db };
            runner.run_sweep(&oracle, &wls)
        }
        _ => match &ctx.cal {
            Some(cal) => runner.run_sweep(cal.as_ref(), &wls),
            None => runner.run_sweep(ctx.db.as_ref() as &dyn LatencyOracle, &wls),
        },
    };

    let mut results = Vec::new();
    for (wl, report) in wls.iter().zip(&reports) {
        let analysis = pareto::analyze(&report.evaluated, &wl.sla);
        let mut o = Json::obj();
        o.set("isl", json::num(wl.isl as f64))
            .set("osl", json::num(wl.osl as f64))
            .set("configs_priced", json::num(report.configs_priced as f64))
            .set("candidates", json::num(report.evaluated.len() as f64))
            .set("feasible", json::num(analysis.feasible.len() as f64))
            .set("top", top_json(&analysis, top_k))
            .set("flags", flags_json(report));
        if let Some(t) = report.tier_counts {
            o.set("tiers", tiers_json(&t));
        }
        if let Some(best) = analysis.best() {
            o.set("launch", launch_json(&best.cand, wl));
        }
        results.push(o);
    }
    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("scenarios", json::num(wls.len() as f64))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("results", Json::Arr(results));
    if let Some(id) = req.get("id") {
        resp.set("id", id.clone());
    }
    Ok(resp)
}

/// Capacity-plan request:
/// `{"plan": {"workload": {...}, "traffic": {"kind": "diurnal", ...},
///   "windows": 24, "window_hours": 1, "fleet": ["h100", "a100"],
///   "max_gpus": 64, "prune": true},
///   "gpus_per_node": 8, "num_nodes": 1, "framework": "trtllm"}`
/// → the cost-minimal replica schedule ([`crate::planner`]) plus the
/// Dynamo `DeploymentSchedule` YAML. Fleet-leg databases come from the
/// same per-context cache the search path uses, so repeated plans skip
/// re-profiling (the dominant cost); operator-latency memos are
/// per-request.
fn handle_plan_request(req: &Json, state: &State) -> anyhow::Result<Json> {
    let t0 = Instant::now();
    let p = req.req("plan")?;
    let wl = WorkloadSpec::from_json(p.req("workload")?)?;
    let traffic = crate::planner::TrafficModel::from_json(p.req("traffic")?)?;
    let gpn = req.f64_or("gpus_per_node", 8.0) as u32;
    let nodes = req.f64_or("num_nodes", 1.0) as u32;
    let fw = Framework::parse(req.str_or("framework", "trtllm"))
        .ok_or_else(|| anyhow::anyhow!("unknown framework"))?;
    let model =
        by_name(&wl.model).ok_or_else(|| anyhow::anyhow!("unknown model '{}'", wl.model))?;

    let names: Vec<String> = match p.get("fleet") {
        Some(fj) => {
            let arr = fj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'fleet' must be an array of GPU name strings"))?;
            anyhow::ensure!(!arr.is_empty(), "'fleet' named no GPU types");
            arr.iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        anyhow::anyhow!("'fleet' entries must be GPU name strings, got {v:?}")
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        }
        None => vec![req.str_or("gpu", "h100").to_string()],
    };
    let mut legs: Vec<(ClusterSpec, Arc<dyn LatencyOracle>)> = Vec::new();
    for name in &names {
        // Per-leg fabrics: "h100@gb200-nvl72" wires this leg's cluster
        // with a named tiered fabric; a bare GPU name keeps the legacy
        // flat topology (grammar shared with the CLI's --fleet —
        // `hardware::parse_fleet_leg`).
        let leg = crate::hardware::parse_fleet_leg(name, gpn)?;
        let key: DbKey =
            (wl.model.clone(), leg.gpu_name, gpn, nodes, fw.name().to_string(), leg.fabric_name);
        let db = db_for(state, &key)?;
        let oracle: Arc<dyn LatencyOracle> = match calibrated_for(state, &key, &db)? {
            Some(cal) => cal,
            None => db,
        };
        legs.push((ClusterSpec::with_fabric(leg.gpu, gpn, nodes, leg.fabric), oracle));
    }

    let spec = crate::planner::PlanSpec {
        workload: wl.clone(),
        traffic,
        windows: p.f64_or("windows", 24.0) as usize,
        window_h: p.f64_or("window_hours", 1.0),
        max_gpus: p.get("max_gpus").and_then(|v| v.as_f64()).map(|v| v as u32),
        prune: p.bool_or("prune", true),
    };
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
        legs.iter().map(|(c, d)| (*c, d.as_ref())).collect();
    let plan = crate::planner::plan(&model, fw, &spec, &fleet)?;

    let mut resp = Json::obj();
    resp.set("status", json::s("ok"))
        .set("elapsed_ms", json::num(t0.elapsed().as_secs_f64() * 1e3))
        .set("plan", plan.to_json(&wl))
        .set(
            "schedule_yaml",
            json::s(&generator::dynamo::plan_schedule_yaml(&plan, &wl.model, &wl)),
        );
    if let Some(id) = req.get("id") {
        resp.set("id", id.clone());
    }
    Ok(resp)
}

fn launch_json(cand: &Candidate, wl: &WorkloadSpec) -> Json {
    let bundle = generator::generate(cand, &wl.model, wl);
    let mut files = Json::obj();
    for (name, content) in &bundle.files {
        files.set(name, json::s(content));
    }
    files
}

/// Blocking client helper (used by examples/tests/benches).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(line.trim())
    }
}

/// Build a search request JSON.
pub fn make_request(
    wl: &WorkloadSpec,
    gpu: &str,
    gpn: u32,
    nodes: u32,
    fw: Framework,
    id: u64,
) -> Json {
    let mut o = Json::obj();
    o.set("id", json::num(id as f64))
        .set("workload", wl.to_json())
        .set("gpu", json::s(gpu))
        .set("gpus_per_node", json::num(gpn as f64))
        .set("num_nodes", json::num(nodes as f64))
        .set("framework", json::s(fw.name()));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> State {
        State::new(1)
    }

    #[test]
    fn request_roundtrip_in_process() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 7);
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert_eq!(resp.req_f64("id").unwrap(), 7.0);
        assert!(resp.req_f64("feasible").unwrap() > 0.0);
        let top = resp.req("top").unwrap().as_arr().unwrap();
        assert!(!top.is_empty());
        assert!(top[0].req_f64("thru_per_gpu").unwrap() > 0.0);
        assert!(resp.get("launch").is_some());
    }

    #[test]
    fn db_cache_reused() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        handle_request(&req, &st).unwrap();
        assert_eq!(st.dbs.lock().unwrap().len(), 1);
        handle_request(&req, &st).unwrap();
        assert_eq!(st.dbs.lock().unwrap().len(), 1);
    }

    #[test]
    fn sweep_request_matches_independent_requests() {
        let st = state();
        let wl_a = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let wl_b = WorkloadSpec::new("llama3.1-8b", 512, 64, 3000.0, 5.0);

        let mut sweep_req = Json::obj();
        sweep_req
            .set("workloads", Json::Arr(vec![wl_a.to_json(), wl_b.to_json()]))
            .set("gpu", json::s("h100"))
            .set("gpus_per_node", json::num(8.0))
            .set("num_nodes", json::num(1.0))
            .set("framework", json::s("trtllm"));
        let sweep = handle_request(&sweep_req, &st).unwrap();
        assert_eq!(sweep.req_str("status").unwrap(), "ok");
        assert_eq!(sweep.req_f64("scenarios").unwrap(), 2.0);
        let results = sweep.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);

        for (wl, res) in [wl_a, wl_b].iter().zip(results) {
            let single = handle_request(
                &make_request(wl, "h100", 8, 1, Framework::TrtLlm, 1),
                &st,
            )
            .unwrap();
            assert_eq!(
                res.req_f64("feasible").unwrap(),
                single.req_f64("feasible").unwrap()
            );
            let t_sweep = res.req("top").unwrap().as_arr().unwrap()[0]
                .req_f64("thru_per_gpu")
                .unwrap();
            let t_single = single.req("top").unwrap().as_arr().unwrap()[0]
                .req_f64("thru_per_gpu")
                .unwrap();
            assert_eq!(t_sweep, t_single);
        }
    }

    #[test]
    fn sweep_rejects_mixed_models() {
        let st = state();
        let mut req = Json::obj();
        req.set(
            "workloads",
            Json::Arr(vec![
                WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0).to_json(),
                WorkloadSpec::new("qwen3-32b", 512, 64, 2000.0, 5.0).to_json(),
            ]),
        );
        let err = handle_request(&req, &st).unwrap_err();
        assert!(err.to_string().contains("same model"));
    }

    fn plan_request(fleet: &[&str], windows: f64) -> Json {
        let mut traffic = Json::obj();
        traffic
            .set("kind", json::s("diurnal"))
            .set("peak_qps", json::num(80.0))
            .set("trough_qps", json::num(4.0))
            .set("period_h", json::num(24.0));
        let mut plan = Json::obj();
        plan.set(
            "workload",
            WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0).to_json(),
        )
        .set("traffic", traffic)
        .set("windows", json::num(windows))
        .set("window_hours", json::num(24.0 / windows))
        .set("fleet", Json::Arr(fleet.iter().map(|g| json::s(g)).collect()));
        let mut req = Json::obj();
        req.set("plan", plan)
            .set("gpus_per_node", json::num(8.0))
            .set("num_nodes", json::num(1.0))
            .set("framework", json::s("trtllm"))
            .set("id", json::num(42.0));
        req
    }

    #[test]
    fn plan_request_returns_schedule() {
        let st = state();
        let resp = handle_request(&plan_request(&["h100"], 4.0), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert_eq!(resp.req_f64("id").unwrap(), 42.0);
        let plan = resp.req("plan").unwrap();
        let windows = plan.req("windows").unwrap().as_arr().unwrap();
        assert_eq!(windows.len(), 4);
        for w in windows {
            assert!(w.req_f64("capacity_qps").unwrap() >= w.req_f64("demand_qps").unwrap());
        }
        assert!(plan.req_f64("total_cost_usd").unwrap() > 0.0);
        assert!(
            plan.req_f64("total_cost_usd").unwrap()
                <= plan.req_f64("static_peak_cost_usd").unwrap() + 1e-9
        );
        let yaml = resp.req_str("schedule_yaml").unwrap();
        assert!(yaml.contains("kind: DeploymentSchedule"));
        assert!(yaml.contains("- window: 0"));
        // The leg database landed in the shared cache.
        assert_eq!(st.dbs.lock().unwrap().len(), 1);
    }

    #[test]
    fn plan_request_heterogeneous_fleet_never_loses_to_homogeneous() {
        let st = state();
        let resp = handle_request(&plan_request(&["h100", "a100"], 3.0), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let plan = resp.req("plan").unwrap();
        if let Some(h) = plan.get("best_homogeneous") {
            assert!(
                plan.req_f64("total_cost_usd").unwrap() <= h.req_f64("cost_usd").unwrap() + 1e-9
            );
        }
        assert_eq!(st.dbs.lock().unwrap().len(), 2, "one cached db per fleet leg");
    }

    #[test]
    fn plan_request_bad_traffic_is_error() {
        let st = state();
        let mut req = plan_request(&["h100"], 2.0);
        // Overwrite traffic with an unknown kind.
        let mut traffic = Json::obj();
        traffic.set("kind", json::s("square"));
        let mut plan = req.req("plan").unwrap().clone();
        plan.set("traffic", traffic);
        req.set("plan", plan);
        assert!(handle_request(&req, &st).is_err());
    }

    #[test]
    fn bad_model_is_error() {
        let st = state();
        let wl = WorkloadSpec::new("not-a-model", 512, 64, 2000.0, 5.0);
        let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        assert!(handle_request(&req, &st).is_err());
    }

    #[test]
    fn static_mode_request_is_rejected_not_silently_empty() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let mut req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        req.set("modes", Json::Arr(vec![json::s("static")]));
        let err = handle_request(&req, &st).unwrap_err();
        assert!(err.to_string().contains("static"), "{err}");
        // Unknown mode strings are also loud errors, not silent drops.
        let mut req2 = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
        req2.set("modes", Json::Arr(vec![json::s("warp-drive")]));
        assert!(handle_request(&req2, &st).is_err());
    }

    #[test]
    fn calibrated_state_reports_tiers_for_matching_context_only() {
        use crate::models::Dtype;
        // Fit an artifact for the llama3.1-8b/h100/trtllm/fp8 context.
        let cluster = ClusterSpec::new(gpu_by_name("h100").unwrap(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("llama3.1-8b").unwrap();
        let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 1);
        let sets = crate::perfdb::measure::synthesize(&sil, &model, Dtype::Fp8, 3, 12);
        let art = crate::perfdb::calibrate::fit(&db, &sets).unwrap();
        let st = State::with_calibration(1, art);

        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let resp =
            handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let tiers = resp.req("tiers").unwrap();
        assert!(
            tiers.req_f64("calibrated").unwrap() + tiers.req_f64("measured").unwrap() > 0.0,
            "calibrated context must answer through the calibrated tiers"
        );
        // The composition is cached, and each request gets a private
        // accounting scope: an identical second request reports the
        // same tier volume, not a cumulative one.
        let resp_again =
            handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 2), &st).unwrap();
        assert_eq!(st.cals.lock().unwrap().len(), 1);
        let t2 = resp_again.req("tiers").unwrap();
        let total = |t: &Json| {
            t.req_f64("measured").unwrap()
                + t.req_f64("calibrated").unwrap()
                + t.req_f64("analytic").unwrap()
                + t.req_f64("sol").unwrap()
        };
        assert_eq!(total(tiers), total(t2), "tier counts must be per-request");
        // A different model context stays analytic — no tiers reported.
        let wl2 = WorkloadSpec::new("qwen3-32b", 512, 64, 2000.0, 5.0);
        let resp2 =
            handle_request(&make_request(&wl2, "h100", 8, 1, Framework::TrtLlm, 3), &st).unwrap();
        assert_eq!(resp2.req_str("status").unwrap(), "ok");
        assert!(resp2.get("tiers").is_none());
        assert_eq!(st.cals.lock().unwrap().len(), 1);
    }

    #[test]
    fn fabric_request_reports_placements_and_caches_separately() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, f64::INFINITY, 0.0);
        let mut req = make_request(&wl, "h100", 8, 2, Framework::TrtLlm, 9);
        req.set("fabric", json::s("hgx-h100"));
        let resp = handle_request(&req, &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        let top = resp.req("top").unwrap().as_arr().unwrap();
        assert!(!top.is_empty());
        for t in top {
            assert!(t.req_str("placement").is_ok(), "placement field missing: {t:?}");
        }
        // The same context on the legacy fabric is a different cache
        // entry (different comm tables).
        let legacy = handle_request(&make_request(&wl, "h100", 8, 2, Framework::TrtLlm, 10), &st)
            .unwrap();
        assert_eq!(legacy.req_str("status").unwrap(), "ok");
        assert_eq!(st.dbs.lock().unwrap().len(), 2);
        // Unknown fabrics are loud errors, not silent legacy fallbacks.
        let mut bad = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 11);
        bad.set("fabric", json::s("warp-fabric"));
        assert!(handle_request(&bad, &st).is_err());
    }

    #[test]
    fn response_reports_flag_deltas_and_honors_overrides() {
        let st = state();
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let resp =
            handle_request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1), &st).unwrap();
        let flags = resp.req("flags").unwrap().as_arr().unwrap();
        assert_eq!(flags.len(), 1);
        assert_eq!(flags[0].req_str("framework").unwrap(), "trtllm");
        assert!(flags[0].req_f64("engines_total").unwrap() > 0.0);
        assert!(flags[0].req_f64("engines_off_default").unwrap() > 0.0);

        // Per-request overrides pin the flag values across the grid.
        let mut req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 2);
        let mut over = Json::obj();
        over.set("max_num_tokens", json::num(4096.0)).set("kv_frac", json::num(0.8));
        req.set("flags", over);
        let resp = handle_request(&req, &st).unwrap();
        let flags = resp.req("flags").unwrap().as_arr().unwrap();
        assert_eq!(flags[0].req_f64("resolved_max_num_tokens_min").unwrap(), 4096.0);
        assert_eq!(flags[0].req_f64("resolved_max_num_tokens_max").unwrap(), 4096.0);
        assert_eq!(flags[0].req_f64("resolved_kv_frac_min").unwrap(), 0.8);
    }
}

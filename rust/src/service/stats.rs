//! Service observability: lock-free counters and latency histograms
//! behind the `stats` request and the `/metrics`-style text dump.
//!
//! Everything here is `AtomicU64` — recording a request costs a handful
//! of relaxed atomic adds, so the hot path never takes a lock for
//! accounting. Latencies land in a log-spaced histogram (3 buckets per
//! octave from ~4 µs to ~8 s), from which p50/p99 are read as bucket
//! midpoints: quantiles are approximate to within one bucket width
//! (~26%), which is plenty to tell a 100 ms search from a 2 s sweep.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::perfdb::TierSnapshot;
use crate::trace::{self, Trace};
use crate::util::json::{self, Json};

use super::protocol::OpKind;

const BUCKETS: usize = 64;
/// Buckets per octave: resolution of the latency histogram.
const PER_OCTAVE: f64 = 3.0;
/// Shift so bucket 0 sits at ~2^-8 ms (≈ 4 µs).
const OFFSET: f64 = 24.0;

/// Fixed-bucket log-2 latency histogram (milliseconds).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

// [AtomicU64; 64] has no Default impl (std stops at 32): build by hand.
impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

fn bucket_of(ms: f64) -> usize {
    if ms <= 0.0 {
        return 0;
    }
    let idx = (ms.log2() * PER_OCTAVE + OFFSET).floor();
    idx.clamp(0.0, (BUCKETS - 1) as f64) as usize
}

/// Geometric midpoint of a bucket, in ms.
fn bucket_value(i: usize) -> f64 {
    2f64.powf((i as f64 + 0.5 - OFFSET) / PER_OCTAVE)
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&self, ms: f64) {
        self.buckets[bucket_of(ms)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((ms * 1e3).max(0.0) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / 1e3 / n as f64
        }
    }

    /// Approximate percentile (`p` in [0, 100]): the midpoint of the
    /// bucket holding the rank-th observation. 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_value(i);
            }
        }
        bucket_value(BUCKETS - 1)
    }
}

/// Per-operation counters: answered requests and their latency.
#[derive(Default)]
pub struct OpStat {
    pub count: AtomicU64,
    pub latency: Histogram,
}

/// All service-level counters. Gauges that live elsewhere (queue depth,
/// cache occupancy) are passed in at snapshot time — see
/// [`PoolGauges`]/[`CacheGauges`].
#[derive(Default)]
pub struct ServiceStats {
    pub search: OpStat,
    pub sweep: OpStat,
    pub plan: OpStat,
    pub validate: OpStat,
    pub replan: OpStat,
    pub stats_reqs: AtomicU64,
    /// Error responses of any kind (typed, legacy, shed).
    pub errors: AtomicU64,
    /// Lines that never became a request (bad JSON, invalid UTF-8).
    pub malformed: AtomicU64,
    /// Requests refused by admission control.
    pub shed: AtomicU64,
    /// Coalesced groups: one leader computes...
    pub coalesce_leaders: AtomicU64,
    /// ...and each follower reuses the leader's payload.
    pub coalesce_followers: AtomicU64,
    /// Oracle provenance totals across all answered searches/sweeps
    /// (measured, calibrated, analytic, SoL).
    tiers: [AtomicU64; 4],
    /// Trace-derived span time per category (µs), accumulated from
    /// sampled request traces (`--trace-sample`). Indexed by
    /// [`trace::cat_index`].
    span_us: [AtomicU64; trace::CATS.len()],
    /// Trace-derived span counts per category, same indexing.
    span_count: [AtomicU64; trace::CATS.len()],
}

impl ServiceStats {
    pub fn new() -> ServiceStats {
        ServiceStats::default()
    }

    fn op_stat(&self, op: OpKind) -> Option<&OpStat> {
        match op {
            OpKind::Search => Some(&self.search),
            OpKind::Sweep => Some(&self.sweep),
            OpKind::Plan => Some(&self.plan),
            OpKind::Validate => Some(&self.validate),
            OpKind::Replan => Some(&self.replan),
            OpKind::Stats => None,
        }
    }

    /// Count one answered request of `op`.
    pub fn bump(&self, op: OpKind) {
        match self.op_stat(op) {
            Some(s) => {
                s.count.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.stats_reqs.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record end-to-end latency for an answered `op` request.
    pub fn record_latency(&self, op: OpKind, ms: f64) {
        if let Some(s) = self.op_stat(op) {
            s.latency.record(ms);
        }
    }

    pub fn add_tiers(&self, t: &TierSnapshot) {
        for (slot, v) in self.tiers.iter().zip([t.measured, t.calibrated, t.analytic, t.sol]) {
            slot.fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Fold a finished request trace into the per-category span
    /// accumulators (the `aiconf_span_*` series). Span time is summed
    /// at µs granularity; sub-µs spans still count.
    pub fn add_spans(&self, t: &Trace) {
        for (cat, total_us, count) in t.cat_totals() {
            let i = trace::cat_index(cat);
            self.span_us[i].fetch_add(total_us as u64, Ordering::Relaxed);
            self.span_count[i].fetch_add(count, Ordering::Relaxed);
        }
    }

    /// Fraction of search/sweep/plan requests answered without a fresh
    /// computation (0 before any coalescing).
    pub fn coalesce_rate(&self) -> f64 {
        let l = self.coalesce_leaders.load(Ordering::Relaxed);
        let f = self.coalesce_followers.load(Ordering::Relaxed);
        if l + f == 0 {
            0.0
        } else {
            f as f64 / (l + f) as f64
        }
    }

    /// Snapshot as the `stats` response body. Queue/cache gauges are
    /// owned by the pipeline and warm cache respectively and passed in;
    /// `pool` is `None` when stats are read outside a pipeline (the
    /// in-process `handle_request` path has no queue).
    pub fn to_json(&self, cache: &CacheGauges, pool: Option<&PoolGauges>) -> Json {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut requests = Json::obj();
        for (name, s) in [
            ("search", &self.search),
            ("sweep", &self.sweep),
            ("plan", &self.plan),
            ("validate", &self.validate),
            ("replan", &self.replan),
        ] {
            let mut o = Json::obj();
            o.set("count", json::num(ld(&s.count)))
                .set("p50_ms", json::num(s.latency.percentile(50.0)))
                .set("p99_ms", json::num(s.latency.percentile(99.0)))
                .set("mean_ms", json::num(s.latency.mean_ms()));
            requests.set(name, o);
        }
        requests.set("stats", json::num(ld(&self.stats_reqs)));

        let mut coalesce = Json::obj();
        coalesce
            .set("leaders", json::num(ld(&self.coalesce_leaders)))
            .set("followers", json::num(ld(&self.coalesce_followers)))
            .set("rate", json::num(self.coalesce_rate()));

        let mut cache_o = Json::obj();
        cache_o
            .set("entries", json::num(cache.entries as f64))
            .set("capacity", json::num(cache.cap as f64))
            .set("hits", json::num(cache.hits as f64))
            .set("misses", json::num(cache.misses as f64))
            .set("evictions", json::num(cache.evictions as f64))
            .set("hit_rate", json::num(cache.hit_rate()));

        let mut tiers = Json::obj();
        for (name, slot) in
            ["measured", "calibrated", "analytic", "sol"].iter().zip(&self.tiers)
        {
            tiers.set(name, json::num(ld(slot)));
        }

        let mut spans = Json::obj();
        for (i, cat) in trace::CATS.iter().enumerate() {
            let n = ld(&self.span_count[i]);
            if n == 0.0 {
                continue;
            }
            let mut so = Json::obj();
            so.set("total_us", json::num(ld(&self.span_us[i]))).set("count", json::num(n));
            spans.set(cat, so);
        }

        let mut o = Json::obj();
        o.set("requests", requests)
            .set("errors", json::num(ld(&self.errors)))
            .set("malformed", json::num(ld(&self.malformed)))
            .set("shed", json::num(ld(&self.shed)))
            .set("coalesce", coalesce)
            .set("cache", cache_o)
            .set("tiers", tiers)
            .set("spans", spans);
        if let Some(p) = pool {
            let mut po = Json::obj();
            po.set("queue_depth", json::num(p.queue_depth as f64))
                .set("queue_limit", json::num(p.queue_limit as f64))
                .set("workers", json::num(p.workers as f64));
            o.set("pool", po);
        }
        o
    }

    /// Prometheus-style exposition text (one gauge/counter per line),
    /// the `metrics_text` field of a `stats` response. Each metric
    /// family is announced by exactly one `# HELP` / `# TYPE` pair, and
    /// all samples of a family are contiguous under it.
    pub fn render_metrics(&self, cache: &CacheGauges, pool: Option<&PoolGauges>) -> String {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut out = String::new();
        let ops = [
            ("search", &self.search),
            ("sweep", &self.sweep),
            ("plan", &self.plan),
            ("validate", &self.validate),
            ("replan", &self.replan),
        ];
        family(&mut out, "aiconf_requests_total", "counter", "Answered requests by operation.");
        for (name, s) in ops {
            out.push_str(&format!(
                "aiconf_requests_total{{op=\"{name}\"}} {}\n",
                ld(&s.count)
            ));
        }
        out.push_str(&format!("aiconf_requests_total{{op=\"stats\"}} {}\n", ld(&self.stats_reqs)));
        family(
            &mut out,
            "aiconf_request_latency_ms",
            "summary",
            "End-to-end request latency quantiles, milliseconds.",
        );
        for (name, s) in ops {
            for (q, p) in [("0.5", 50.0), ("0.99", 99.0)] {
                out.push_str(&format!(
                    "aiconf_request_latency_ms{{op=\"{name}\",quantile=\"{q}\"}} {:.3}\n",
                    s.latency.percentile(p)
                ));
            }
        }
        family(&mut out, "aiconf_errors_total", "counter", "Error responses of any kind.");
        out.push_str(&format!("aiconf_errors_total {}\n", ld(&self.errors)));
        family(&mut out, "aiconf_malformed_total", "counter", "Lines that never became a request.");
        out.push_str(&format!("aiconf_malformed_total {}\n", ld(&self.malformed)));
        family(&mut out, "aiconf_shed_total", "counter", "Requests refused by admission control.");
        out.push_str(&format!("aiconf_shed_total {}\n", ld(&self.shed)));
        family(&mut out, "aiconf_coalesce_total", "counter", "Coalesced request groups by role.");
        out.push_str(&format!(
            "aiconf_coalesce_total{{role=\"leader\"}} {}\n",
            ld(&self.coalesce_leaders)
        ));
        out.push_str(&format!(
            "aiconf_coalesce_total{{role=\"follower\"}} {}\n",
            ld(&self.coalesce_followers)
        ));
        family(&mut out, "aiconf_cache_entries", "gauge", "Warm-cache entries resident.");
        out.push_str(&format!("aiconf_cache_entries {}\n", cache.entries));
        family(&mut out, "aiconf_cache_capacity", "gauge", "Warm-cache capacity.");
        out.push_str(&format!("aiconf_cache_capacity {}\n", cache.cap));
        family(&mut out, "aiconf_cache_hits_total", "counter", "Warm-cache hits.");
        out.push_str(&format!("aiconf_cache_hits_total {}\n", cache.hits));
        family(&mut out, "aiconf_cache_misses_total", "counter", "Warm-cache misses.");
        out.push_str(&format!("aiconf_cache_misses_total {}\n", cache.misses));
        family(&mut out, "aiconf_cache_evictions_total", "counter", "Warm-cache evictions.");
        out.push_str(&format!("aiconf_cache_evictions_total {}\n", cache.evictions));
        family(
            &mut out,
            "aiconf_oracle_queries_total",
            "counter",
            "Oracle queries by provenance tier.",
        );
        for (name, slot) in
            ["measured", "calibrated", "analytic", "sol"].iter().zip(&self.tiers)
        {
            out.push_str(&format!(
                "aiconf_oracle_queries_total{{tier=\"{name}\"}} {}\n",
                ld(slot)
            ));
        }
        family(
            &mut out,
            "aiconf_span_total_us",
            "counter",
            "Trace span time by category from sampled requests, microseconds.",
        );
        for (i, cat) in trace::CATS.iter().enumerate() {
            out.push_str(&format!(
                "aiconf_span_total_us{{cat=\"{cat}\"}} {}\n",
                ld(&self.span_us[i])
            ));
        }
        family(
            &mut out,
            "aiconf_span_count",
            "counter",
            "Trace spans recorded by category from sampled requests.",
        );
        for (i, cat) in trace::CATS.iter().enumerate() {
            out.push_str(&format!(
                "aiconf_span_count{{cat=\"{cat}\"}} {}\n",
                ld(&self.span_count[i])
            ));
        }
        if let Some(p) = pool {
            family(&mut out, "aiconf_queue_depth", "gauge", "Requests waiting in the pool queue.");
            out.push_str(&format!("aiconf_queue_depth {}\n", p.queue_depth));
            family(&mut out, "aiconf_queue_limit", "gauge", "Pool queue admission limit.");
            out.push_str(&format!("aiconf_queue_limit {}\n", p.queue_limit));
            family(&mut out, "aiconf_pool_workers", "gauge", "Worker threads in the pool.");
            out.push_str(&format!("aiconf_pool_workers {}\n", p.workers));
        }
        out
    }
}

/// Emit the one `# HELP` / `# TYPE` pair announcing a metric family.
fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Point-in-time worker-pool gauges (owned by the pipeline).
pub struct PoolGauges {
    pub queue_depth: usize,
    pub queue_limit: usize,
    pub workers: usize,
}

/// Point-in-time warm-cache gauges (owned by [`super::cache::WarmCache`]).
pub struct CacheGauges {
    pub entries: usize,
    pub cap: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheGauges {
    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_bracket_the_data() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(10.0);
        }
        for _ in 0..10 {
            h.record(1000.0);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        // Log-bucket midpoints: within ~26% of the true value.
        assert!((7.0..14.0).contains(&p50), "p50 = {p50}");
        assert!((700.0..1400.0).contains(&p99), "p99 = {p99}");
        assert!(h.mean_ms() > p50 && h.mean_ms() < p99);
        assert_eq!(Histogram::new().percentile(99.0), 0.0);
    }

    #[test]
    fn histogram_extremes_clamp() {
        let h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(1e12);
        assert_eq!(h.count(), 3);
        assert!(h.percentile(100.0) > 0.0);
    }

    #[test]
    fn stats_snapshot_has_the_advertised_fields() {
        let st = ServiceStats::new();
        st.bump(OpKind::Search);
        st.record_latency(OpKind::Search, 120.0);
        st.coalesce_leaders.fetch_add(1, Ordering::Relaxed);
        st.coalesce_followers.fetch_add(3, Ordering::Relaxed);
        st.add_tiers(&TierSnapshot { measured: 5, calibrated: 7, analytic: 2, sol: 1 });
        let cache = CacheGauges { entries: 2, cap: 8, hits: 9, misses: 3, evictions: 1 };
        let pool = PoolGauges { queue_depth: 4, queue_limit: 64, workers: 2 };
        let j = st.to_json(&cache, Some(&pool));
        assert_eq!(j.req("requests").unwrap().req("search").unwrap().req_f64("count").unwrap(), 1.0);
        assert!(j.req("requests").unwrap().req("search").unwrap().req_f64("p50_ms").unwrap() > 0.0);
        assert_eq!(j.req("coalesce").unwrap().req_f64("rate").unwrap(), 0.75);
        assert_eq!(j.req("cache").unwrap().req_f64("hit_rate").unwrap(), 0.75);
        assert_eq!(j.req("pool").unwrap().req_f64("queue_depth").unwrap(), 4.0);
        assert_eq!(j.req("tiers").unwrap().req_f64("calibrated").unwrap(), 7.0);

        let text = st.render_metrics(&cache, Some(&pool));
        assert!(text.contains("aiconf_requests_total{op=\"search\"} 1"));
        assert!(text.contains("aiconf_queue_depth 4"));
        assert!(text.contains("aiconf_coalesce_total{role=\"follower\"} 3"));
        assert!(text.contains("aiconf_oracle_queries_total{tier=\"measured\"} 5"));
    }

    #[test]
    fn span_accumulators_surface_in_both_outputs() {
        let rec = crate::trace::Recorder::new();
        rec.install();
        {
            let _outer = crate::trace::span("search", "search");
            let _inner = crate::trace::span("price", "price");
        }
        let trace = rec.finish();
        assert!(trace.len() >= 2);

        let st = ServiceStats::new();
        st.add_spans(&trace);
        let cache = CacheGauges { entries: 0, cap: 8, hits: 0, misses: 0, evictions: 0 };
        let j = st.to_json(&cache, None);
        let spans = j.req("spans").unwrap();
        assert_eq!(spans.req("search").unwrap().req_f64("count").unwrap(), 1.0);
        assert_eq!(spans.req("price").unwrap().req_f64("count").unwrap(), 1.0);

        let text = st.render_metrics(&cache, None);
        assert!(text.contains("aiconf_span_count{cat=\"search\"} 1"));
        assert!(text.contains("aiconf_span_count{cat=\"price\"} 1"));
        assert!(text.contains("aiconf_span_total_us{cat=\"search\"}"));
    }

    /// Prometheus exposition hygiene: one HELP/TYPE pair per family,
    /// every series named `aiconf_[a-z0-9_]*`, every value a finite
    /// number.
    #[test]
    fn metrics_text_is_prometheus_clean() {
        let st = ServiceStats::new();
        st.bump(OpKind::Search);
        st.record_latency(OpKind::Search, 12.0);
        st.add_tiers(&TierSnapshot { measured: 1, calibrated: 2, analytic: 3, sol: 4 });
        let cache = CacheGauges { entries: 1, cap: 8, hits: 2, misses: 1, evictions: 0 };
        let pool = PoolGauges { queue_depth: 0, queue_limit: 64, workers: 2 };
        let text = st.render_metrics(&cache, Some(&pool));

        let mut seen_meta: Vec<String> = Vec::new();
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            if let Some(rest) =
                line.strip_prefix("# HELP ").or_else(|| line.strip_prefix("# TYPE "))
            {
                let key = format!("{} {}", &line[2..6], rest.split(' ').next().unwrap());
                assert!(!seen_meta.contains(&key), "duplicate exposition line: {line}");
                seen_meta.push(key);
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment: {line}");
            // Series name: up to `{` or the value separator space.
            let name_end = line.find('{').unwrap_or_else(|| line.find(' ').unwrap());
            let name = &line[..name_end];
            assert!(name.starts_with("aiconf_"), "bad metric name: {name}");
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "bad character in metric name: {name}"
            );
            // Every sample line also needs a HELP and a TYPE above it.
            assert!(seen_meta.contains(&format!("HELP {name}")), "no HELP for {name}");
            assert!(seen_meta.contains(&format!("TYPE {name}")), "no TYPE for {name}");
            let value = line.rsplit(' ').next().unwrap();
            let v: f64 = value.parse().unwrap_or(f64::NAN);
            assert!(v.is_finite(), "non-finite value in: {line}");
        }
        // Both span families made it out even with zero samples.
        assert!(seen_meta.contains(&"TYPE aiconf_span_total_us".to_string()));
        assert!(seen_meta.contains(&"TYPE aiconf_span_count".to_string()));
    }
}

//! `aiconfigurator` — CLI for the AIConfigurator reproduction.
//!
//! Subcommands mirror the paper's workflow (§4.1):
//!   build-db     offline profiling → perf database JSON (PerfDatabase)
//!   calibrate    fit measurement sets into a calibration artifact
//!   search       TaskRunner + Pareto analyzer + Generator
//!   sweep        batch search: many (ISL, OSL, SLA) scenarios, one pass
//!   plan         traffic-aware capacity planner: cost-minimal replica
//!                schedules over dynamic QPS curves (mixed GPU fleets)
//!   validate     fleet-level replay of a planned schedule: achieved vs
//!                promised SLA attainment, optimism gap by cause
//!   simulate     ground-truth discrete-event simulation of one config
//!   experiment   regenerate a paper table/figure (fig1..fig8, table1)
//!   serve        run the TCP config-search service
//!
//! (Arg parsing is hand-rolled: the offline build environment has no
//! clap — see DESIGN.md substitutions.)

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use aiconfigurator::config::{Candidate, ServingMode, WorkloadSpec};
use aiconfigurator::experiments;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{gpu_by_name, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::pareto;
use aiconfigurator::perfdb::{
    calibrate, measure, CalibratedDb, CalibrationArtifact, LatencyOracle, MemoOracle,
    PerfDatabase,
};
use aiconfigurator::planner::TrafficModel;
use aiconfigurator::runtime::{PjrtOracle, PjrtService};
use aiconfigurator::search::{SearchDelta, SearchSpace, TaskRunner};
use aiconfigurator::service::protocol::SpaceOverrides;
use aiconfigurator::service::{SearchServer, ServerConfig};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::simulator::aggregated::AggregatedSim;
use aiconfigurator::simulator::SimConfig;
use aiconfigurator::trace;
use aiconfigurator::util::bench::oracle_line;
use aiconfigurator::workload::closed_loop;
use aiconfigurator::{generator, simulator};

const USAGE: &str = "\
aiconfigurator — lightning-fast LLM serving configuration search (reproduction)

USAGE:
  aiconfigurator search     --model <name> [--gpu h100] [--gpus-per-node 8]
                            [--nodes 1] [--fabric NAME] [--framework trtllm]
                            --isl N --osl N
                            [--ttft MS] [--speed TOK_S] [--modes agg,disagg]
                            [--top 5] [--prune] [--out-dir DIR]
                            [--flag-sweep] [--max-num-tokens N[,N...]]
                            [--kv-frac F[,F...]] [--cuda-graph on|off|both]
                            [--pjrt ARTIFACTS_DIR] [--calibration FILE.json]
                            [--trace-out FILE.json] [--explain]
                            [--explain-out FILE.json]
  aiconfigurator sweep      --model <name> [--gpu h100] [--gpus-per-node 8]
                            [--nodes 1] [--fabric NAME] [--framework trtllm]
                            [--prune] [--modes agg,disagg] [--flag-sweep]
                            [--max-num-tokens N[,N...]] [--kv-frac F[,F...]]
                            [--cuda-graph on|off|both] [--calibration FILE.json]
                            [--trace-out FILE.json] [--explain]
                            [--explain-out FILE.json]
                            --scenarios ISL:OSL:TTFT:SPEED[,ISL:OSL:TTFT:SPEED...]
                            (TTFT in ms or 'inf'; SPEED in tokens/s/user or 0)
  aiconfigurator topo       [--fabric NAME|all] [--gpu h100] [--gpus-per-node 8]
                            [--nodes 2] [--group 16]
                            (prints each fabric preset, the placements it
                             enumerates for sample parallel shapes, and the
                             per-collective per-algorithm cost tables)
  aiconfigurator calibrate  --model <name> [--gpu h100] [--framework trtllm]
                            --measurements DIR (layout DIR/<gpu>/<table>.json)
                            [--out ARTIFACT.json] [--report FIDELITY.json]
                            [--synthesize] [--seed 7] [--points 48]
                            [--check-improves]
                            (fits per-table log-space corrections of the
                             analytic fill against measured kernel latencies;
                             --synthesize first writes a fixed-seed synthetic
                             measurement set for the context into DIR;
                             --check-improves exits non-zero unless post-fit
                             MAPE < pre-fit MAPE for every table — the CI
                             calibration-smoke gate)
  aiconfigurator plan       --model <name> [--fleet h100,a100@a100-pcie]
                            [--gpus-per-node 8]
                            [--nodes 1] [--framework trtllm] --isl N --osl N
                            [--ttft MS] [--speed TOK_S]
                            --traffic diurnal|ramp|bursty
                              diurnal: --peak-qps Q [--trough-qps Q] [--period-h 24]
                              ramp:    --start-qps Q --end-qps Q
                              bursty:  --base-qps Q --burst-qps Q
                                       [--burst-prob 0.15] [--burst-seed 7]
                            [--windows 24] [--window-hours 1] [--max-gpus N]
                            [--no-prune] [--out-dir DIR] [--calibration FILE.json]
                            [--trace-out FILE.json] [--explain]
                            [--explain-out FILE.json]
  aiconfigurator replan     --model <name> [--fleet h100,a100@a100-pcie]
                            [--gpus-per-node 8] [--nodes 1] [--framework trtllm]
                            --isl N --osl N [--ttft MS] [--speed TOK_S]
                            (--traffic ... as `plan`) [--windows 24]
                            [--window-hours 1] [--max-gpus N] [--no-prune]
                            --delta DELTA.json [--calibration FILE.json]
                            [--out REPORT.json] [--check-equal]
                            [--trace-out FILE.json]
                            (plans as `plan` would, then applies a committed
                             search-delta — window demand edits, per-GPU
                             repricing, a swapped calibration artifact, fleet
                             legs added/removed — through the incremental
                             replan layer: only recalibrated/added legs are
                             re-swept, everything else patches the retained
                             Pareto frontier. Prints the config diff (options
                             that entered/left the frontier, windows whose
                             deployment changed, cost delta) and the
                             re-priced-candidate counts. With 'recalibrate'
                             deltas, --calibration is the *swapped* artifact:
                             the baseline stays analytic. --check-equal also
                             runs the full from-scratch plan of the patched
                             inputs and exits non-zero unless the incremental
                             result is bit-identical and re-priced strictly
                             fewer configs — the CI replan-smoke gate)
  aiconfigurator validate   --model <name> [--fleet h100,a100@a100-pcie]
                            [--gpus-per-node 8] [--nodes 1] [--framework trtllm]
                            --isl N --osl N [--ttft MS] [--speed TOK_S]
                            (--traffic ... as `plan`  |  --trace-spec FILE.json)
                            [--windows 24] [--window-hours 1] [--max-gpus N]
                            [--no-prune] [--seed N] [--len-jitter F]
                            [--scale-lag SECONDS] [--failure-rate PER_REPLICA_H]
                            [--restart SECONDS] [--calibration FILE.json]
                            [--out REPORT.json] [--check-gap FRAC]
                            [--trace-out FILE.json]
                            (plans as `plan` would, then replays a trace drawn
                             from the plan's own traffic model through the
                             fleet simulator — router, replica lifecycle,
                             scale-up lag, KV-transfer contention, seeded
                             failure injection. Reports per-window achieved vs
                             promised SLA attainment and the optimism gap
                             broken down by queueing/scale-lag/contention/
                             failure. --trace-spec pins traffic+windows+seed
                             from a committed JSON spec; --check-gap exits
                             non-zero when the gap exceeds FRAC — the CI
                             validate-smoke gate)
  aiconfigurator build-db   --model <name> [--gpu h100] [--framework trtllm]
                            [--nodes 1] --out FILE.json
  aiconfigurator simulate   --model <name> [--gpu h100] [--framework trtllm]
                            [--tp 1] [--ep 1] [--batch 8] --isl N --osl N
                            [--ttft MS] [--speed TOK_S] [--requests 32]
                            [--seed N]
                            (--ttft/--speed steer flag resolution so the
                             simulated engine matches the searched one;
                             --seed pins the scheduler-jitter stream)
  aiconfigurator experiment <fig1|fig5|fig6|fig7|fig8|table1|all> [--full]
  aiconfigurator serve      [--addr 127.0.0.1:7788] [--pjrt ARTIFACTS_DIR]
                            [--calibration FILE.json] [--workers N]
                            [--queue-limit N] [--cache-cap N]
                            [--trace-sample N]
                            [--model <name> --gpu h100 --framework trtllm]
                            (v2 JSON-lines protocol with bounded worker
                             pool, request coalescing, warm LRU database
                             cache and a 'stats' observability request;
                             --trace-sample N captures spans for every Nth
                             request into the aiconf_span_* metrics, 0 = off)

Models: llama3.1-8b qwen3-32b qwen3-235b deepseek-v3 mixtral-8x7b gpt-oss-120b
GPUs:   a100 h100 h200 b200 b200-sxm gb200-nvl72    Frameworks: trtllm vllm sglang
Fabrics: legacy (default) hgx-h100 gb200-nvl72 a100-pcie dgx-multirail
         (--fabric switches to tiered, placement-aware pricing: the
          search then enumerates rank layouts — TP inside vs spanning
          NVLink domains, rail striping — as a structural axis and the
          chosen placement is reported and emitted; `plan` fleet legs
          take per-leg fabrics as GPU@FABRIC)

Flags accept both '--key value' and '--key=value'.
Launch flags (kv-cache fraction, max-num-tokens, CUDA graphs, chunked
prefill) are resolved analytically per candidate by the backend layer
from the memory model and the TTFT budget; pass --max-num-tokens /
--kv-frac / --cuda-graph to override (comma lists sweep), or
--flag-sweep to also price framework defaults + no-graph + 2 extra
token-capacity points per candidate for comparison. Serving modes:
'agg' and 'disagg' are searchable; 'static' is simulation-only
(`simulate`) and is rejected by search/sweep.
`plan` searches traffic-aware deployment schedules: replicas of the
cost-optimal engine config (and GPU type — --fleet may mix types) per
time window, meeting the SLA at minimum $ cost.
`--calibration` composes a calibration artifact (from `calibrate`) over
the analytic database: queries then resolve measured cell →
calibrated-analytic → SoL, and reports carry per-tier query counts
(plan applies it to the fleet leg whose GPU matches the artifact).
`--trace-out FILE` records hierarchical spans across the run (search →
grid build → pricing batches → frontier merge; plan → per-leg sweep →
schedule; validate → replay; replan → invalidation → re-price) and
writes Chrome trace-event JSON (open in chrome://tracing or Perfetto);
a span-tree summary is printed. `--explain` prints a 'why this config
won' report: per-phase latency decomposition by primitive class (GEMM/
attention/comm/memory/host), resolved launch-flag provenance, the
pruning audit and the nearest runner-up margin; --explain-out FILE
persists the JSON.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let (flags, positional) = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "search" => cmd_search(&flags),
        "sweep" => cmd_sweep(&flags),
        "topo" => cmd_topo(&flags),
        "plan" => cmd_plan(&flags),
        "replan" => cmd_replan(&flags),
        "validate" => cmd_validate(&flags),
        "calibrate" => cmd_calibrate(&flags),
        "build-db" => cmd_build_db(&flags),
        "simulate" => cmd_simulate(&flags),
        "experiment" => cmd_experiment(&positional, &flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value`, `--key=value` and bare `--switch` flags plus
/// positionals. `--key=value` binds tighter than the lookahead rule, so
/// values that themselves start with `--` (or contain `=`) are
/// expressible: `--scenarios=1024:128:inf:0`.
fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if let Some((key, value)) = name.split_once('=') {
                flags.insert(key.to_string(), value.to_string());
                i += 1;
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn flag<'a>(f: &'a HashMap<String, String>, k: &str, default: &'a str) -> &'a str {
    f.get(k).map(String::as_str).unwrap_or(default)
}

fn flag_u32(f: &HashMap<String, String>, k: &str, default: u32) -> anyhow::Result<u32> {
    match f.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{k} must be an integer, got '{v}'")),
    }
}

fn flag_f64(f: &HashMap<String, String>, k: &str, default: f64) -> anyhow::Result<f64> {
    match f.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{k} must be a number, got '{v}'")),
    }
}

fn flag_u64(f: &HashMap<String, String>, k: &str, default: u64) -> anyhow::Result<u64> {
    match f.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{k} must be an integer, got '{v}'")),
    }
}

/// The one comma-list value parser: every list-valued option
/// (`--max-num-tokens`, `--kv-frac`, `--scenarios`, `--fleet`, `topo`'s
/// shape lists) goes through here, so a new option can never fork the
/// `--key=value` list grammar again (it used to be re-implemented per
/// flag).
fn parse_list<T>(
    raw: &str,
    what: &str,
    parse: impl Fn(&str) -> anyhow::Result<T>,
) -> anyhow::Result<Vec<T>> {
    let items: Vec<T> = raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(&parse)
        .collect::<anyhow::Result<Vec<T>>>()
        .map_err(|e| anyhow::anyhow!("--{what}: {e:#}"))?;
    anyhow::ensure!(!items.is_empty(), "--{what} named no values");
    Ok(items)
}

/// Table of the search-space list flags: (flag name, setter into the
/// shared [`SpaceOverrides`]). Driven by [`apply_space_flags`]; each
/// setter funnels through [`parse_list`] and stays parse-only — the
/// range rules (token counts positive, kv fractions in (0, 1]) live in
/// [`SpaceOverrides::apply`], shared with the service protocol, so the
/// two frontends can never drift.
type SpaceFlagSetter = fn(&mut SpaceOverrides, &str) -> anyhow::Result<()>;
const SPACE_LIST_FLAGS: &[(&str, SpaceFlagSetter)] = &[
    ("max-num-tokens", |ov, v| {
        ov.max_num_tokens = Some(parse_list(v, "max-num-tokens", |s| {
            s.parse::<u32>().map_err(|_| anyhow::anyhow!("must be integers, got '{s}'"))
        })?);
        Ok(())
    }),
    ("kv-frac", |ov, v| {
        ov.kv_frac = Some(parse_list(v, "kv-frac", |s| {
            s.parse::<f64>().map_err(|_| anyhow::anyhow!("must be numbers, got '{s}'"))
        })?);
        Ok(())
    }),
    ("cuda-graph", |ov, v| {
        ov.cuda_graph = Some(match v {
            "on" | "true" | "1" => vec![true],
            "off" | "false" | "0" => vec![false],
            "both" => vec![true, false],
            other => anyhow::bail!("--cuda-graph must be on|off|both, got '{other}'"),
        });
        Ok(())
    }),
];

struct Ctx {
    model: aiconfigurator::models::ModelArch,
    cluster: ClusterSpec,
    framework: Framework,
    silicon: Silicon,
}

/// Resolve `--fabric` (default: the legacy flat topology) against a
/// node width.
fn fabric_flag(
    f: &HashMap<String, String>,
    gpus_per_node: u32,
) -> anyhow::Result<aiconfigurator::topology::FabricSpec> {
    let name = flag(f, "fabric", "legacy");
    aiconfigurator::topology::fabric::by_name(name, gpus_per_node)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric '{name}' (see --help for presets)"))
}

fn load_ctx(f: &HashMap<String, String>) -> anyhow::Result<Ctx> {
    let model_name = f.get("model").ok_or_else(|| anyhow::anyhow!("--model is required"))?;
    let model = by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}' (see --help)"))?;
    let gpu_name = flag(f, "gpu", "h100");
    let gpu = gpu_by_name(gpu_name).ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}'"))?;
    let gpn = flag_u32(f, "gpus-per-node", 8)?;
    let cluster =
        ClusterSpec::with_fabric(gpu, gpn, flag_u32(f, "nodes", 1)?, fabric_flag(f, gpn)?);
    let fw_name = flag(f, "framework", "trtllm");
    let framework = Framework::parse(fw_name)
        .ok_or_else(|| anyhow::anyhow!("unknown framework '{fw_name}'"))?;
    Ok(Ctx { model, cluster, framework, silicon: Silicon::new(cluster, framework.profile()) })
}

/// Parse `--modes` (rejecting unknown tokens and the unsearchable
/// `static` mode) and the launch-flag override switches into the space,
/// through the same [`SpaceOverrides`] the service protocol applies.
fn apply_space_flags(
    space: &mut SearchSpace,
    f: &HashMap<String, String>,
) -> anyhow::Result<()> {
    let mut ov = SpaceOverrides::default();
    if let Some(modes) = f.get("modes") {
        ov.modes = Some(
            modes
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    ServingMode::parse(s)
                        .ok_or_else(|| anyhow::anyhow!("unknown serving mode '{s}' in --modes"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
        );
    }
    ov.flag_sweep = Some(f.contains_key("flag-sweep"));
    for (key, set) in SPACE_LIST_FLAGS {
        if let Some(v) = f.get(*key) {
            set(&mut ov, v)?;
        }
    }
    ov.apply(space)
}

fn print_flag_summaries(report: &aiconfigurator::search::SearchReport) {
    for s in &report.flag_summaries {
        println!("flags [{}]", s.describe());
    }
}

fn print_tier_counts(report: &aiconfigurator::search::SearchReport) {
    if let Some(t) = report.tier_counts {
        println!(
            "oracle tiers: {} measured-cell, {} calibrated-analytic, {} analytic, {} SoL ({} queries)",
            t.measured,
            t.calibrated,
            t.analytic,
            t.sol,
            t.total()
        );
    }
}

/// Install a span recorder when `--trace-out FILE` was passed. The
/// paired [`finish_trace`] writes the Chrome trace and prints the span
/// tree; without the flag both are no-ops and nothing is installed —
/// the traced code paths then run their zero-cost inert guards.
fn start_trace(f: &HashMap<String, String>) -> Option<trace::Recorder> {
    f.get("trace-out").map(|_| {
        let rec = trace::Recorder::new();
        rec.install();
        rec
    })
}

/// Write the finished trace as Chrome trace-event JSON (open in
/// chrome://tracing or Perfetto) and print the span-tree summary.
fn finish_trace(
    f: &HashMap<String, String>,
    rec: Option<trace::Recorder>,
) -> anyhow::Result<()> {
    let (Some(path), Some(rec)) = (f.get("trace-out"), rec) else { return Ok(()) };
    let tr = rec.finish();
    let p = Path::new(path);
    if let Some(parent) = p.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(p, tr.to_chrome_json().to_string())?;
    print!("{}", tr.render_tree());
    println!("wrote Chrome trace ({} spans) to {path}", tr.len());
    Ok(())
}

/// Was an explain report requested (`--explain` or `--explain-out`)?
fn explain_wanted(f: &HashMap<String, String>) -> bool {
    f.contains_key("explain") || f.contains_key("explain-out")
}

/// Persist an explain report when `--explain-out FILE` was passed.
fn write_explain(
    f: &HashMap<String, String>,
    e: &aiconfigurator::util::json::Json,
) -> anyhow::Result<()> {
    if let Some(out) = f.get("explain-out") {
        let path = Path::new(out);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, e.to_string())?;
        println!("wrote explain report to {out}");
    }
    Ok(())
}

/// Load a `--calibration` artifact and compose it over a freshly
/// profiled database (context must match — DESIGN.md compatibility
/// rules).
fn load_calibrated(path: &str, db: PerfDatabase) -> anyhow::Result<CalibratedDb> {
    let art = CalibrationArtifact::load(Path::new(path))?;
    eprintln!(
        "calibration: {} tables fitted, {} measured cells ({})",
        art.fits.len(),
        art.measured_cells.len(),
        art.provenance
    );
    CalibratedDb::compose(db, &art)
}

fn cmd_search(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let ctx = load_ctx(f)?;
    let isl = flag_u32(f, "isl", 0)?;
    let osl = flag_u32(f, "osl", 0)?;
    anyhow::ensure!(isl > 0 && osl > 0, "--isl and --osl are required");
    let wl = WorkloadSpec::new(
        ctx.model.name,
        isl,
        osl,
        flag_f64(f, "ttft", f64::INFINITY)?,
        flag_f64(f, "speed", 0.0)?,
    );

    eprintln!("building performance database (offline profiling of silicon)...");
    let db = PerfDatabase::build(&ctx.silicon, &ctx.model, ctx.cluster.gpu.preferred_kv_dtype(), 0xA1C0);

    let mut space = SearchSpace::default_for(&ctx.model, ctx.framework);
    apply_space_flags(&mut space, f)?;

    let runner = TaskRunner::new(&ctx.model, &ctx.cluster, space, wl.clone());
    let opts = aiconfigurator::search::RunOptions { prune: f.contains_key("prune") };
    let rec = start_trace(f);
    // Every oracle tier runs behind a memo: workers price through
    // thread-local fronts, and the stats line below reports the
    // ops-priced rate and hit share from the shared store's counters.
    // The explain report is built inside the branch while the oracle is
    // still alive (calibration consumes the database).
    let run = |oracle: &dyn LatencyOracle| {
        let memo = MemoOracle::new(oracle);
        let report = runner.run_cached(&memo, &opts);
        let explain = explain_wanted(f).then(|| {
            trace::explain::search_explain(oracle, &ctx.model, &ctx.cluster, &wl, &report)
        });
        (report, memo.stats(), explain)
    };
    // Optional PJRT-backed hot path (AOT Pallas kernel via the runtime).
    let (report, (memo_hits, memo_misses), explain) = if let Some(dir) = f.get("pjrt") {
        anyhow::ensure!(
            !f.contains_key("calibration"),
            "--calibration is not supported with --pjrt: the AOT kernel interpolates the \
             analytic grids (drop one of the two flags)"
        );
        anyhow::ensure!(
            !f.contains_key("fabric"),
            "--fabric is not supported with --pjrt: the AOT kernel prices the packed \
             layout only (drop one of the two flags)"
        );
        eprintln!("loading AOT artifacts from {dir} (PJRT interp on the hot path)...");
        let svc = PjrtService::start(std::path::Path::new(dir), db.grids().to_vec())?;
        let oracle = PjrtOracle { svc: &svc, db: &db };
        run(&oracle)
    } else if let Some(path) = f.get("calibration") {
        anyhow::ensure!(
            !ctx.cluster.fabric.placement_aware(),
            "--calibration is not supported with a tiered --fabric: artifacts are fitted \
             against legacy-fabric grids (drop one of the two flags)"
        );
        let cal = load_calibrated(path, db)?;
        run(&cal)
    } else {
        run(&db)
    };

    let analysis = pareto::analyze(&report.evaluated, &wl.sla);
    println!(
        "searched {} configs ({} candidates{}) in {:.2}s — median {:.2} ms/config; {} SLA-feasible",
        report.configs_priced,
        report.evaluated.len(),
        if report.pruned > 0 {
            format!(", {} pruned in-sweep", report.pruned)
        } else {
            String::new()
        },
        report.elapsed_s,
        report.median_config_ms,
        analysis.feasible.len()
    );
    println!("{}", oracle_line(memo_hits, memo_misses, report.elapsed_s));
    let top = flag_u32(f, "top", 5)? as usize;
    println!(
        "{:<6} {:>14} {:>12} {:>10} {:>6}  configuration",
        "mode", "thru t/s/GPU", "speed t/s/u", "TTFT ms", "GPUs"
    );
    for e in analysis.feasible.iter().take(top) {
        println!(
            "{:<6} {:>14.1} {:>12.1} {:>10.1} {:>6}  {}",
            match e.cand.mode() {
                ServingMode::Aggregated => "agg",
                ServingMode::Disaggregated => "disagg",
                ServingMode::Static => "static",
            },
            e.est.thru_per_gpu,
            e.est.speed,
            e.est.ttft_ms,
            e.cand.total_gpus(),
            e.cand.label()
        );
    }
    print_flag_summaries(&report);
    print_tier_counts(&report);
    if let Some(e) = &explain {
        print!("{}", trace::explain::render_search_explain(e));
        write_explain(f, e)?;
    }
    if let Some(best) = analysis.best() {
        if let Some(dir) = f.get("out-dir") {
            let bundle = generator::generate(&best.cand, ctx.model.name, &wl);
            bundle.write_to(std::path::Path::new(dir))?;
            println!("wrote launch bundle to {dir}/");
        }
    } else {
        println!("no configuration satisfies the SLA — relax --ttft/--speed");
    }
    finish_trace(f, rec)?;
    Ok(())
}

/// Parse `ISL:OSL:TTFT:SPEED` (TTFT may be `inf`).
fn parse_scenario(model: &str, s: &str) -> anyhow::Result<WorkloadSpec> {
    let parts: Vec<&str> = s.split(':').collect();
    anyhow::ensure!(
        parts.len() == 4,
        "scenario '{s}' must be ISL:OSL:TTFT:SPEED (TTFT in ms or 'inf')"
    );
    let isl: u32 =
        parts[0].parse().map_err(|_| anyhow::anyhow!("bad ISL in scenario '{s}'"))?;
    let osl: u32 =
        parts[1].parse().map_err(|_| anyhow::anyhow!("bad OSL in scenario '{s}'"))?;
    let ttft: f64 = if parts[2].eq_ignore_ascii_case("inf") {
        f64::INFINITY
    } else {
        parts[2].parse().map_err(|_| anyhow::anyhow!("bad TTFT in scenario '{s}'"))?
    };
    let speed: f64 =
        parts[3].parse().map_err(|_| anyhow::anyhow!("bad SPEED in scenario '{s}'"))?;
    anyhow::ensure!(isl > 0 && osl > 0, "scenario '{s}': ISL and OSL must be positive");
    anyhow::ensure!(
        ttft > 0.0 && speed >= 0.0,
        "scenario '{s}': TTFT must be positive (or 'inf') and SPEED non-negative"
    );
    Ok(WorkloadSpec::new(model, isl, osl, ttft, speed))
}

fn cmd_sweep(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let ctx = load_ctx(f)?;
    let raw = f
        .get("scenarios")
        .ok_or_else(|| anyhow::anyhow!("--scenarios is required (ISL:OSL:TTFT:SPEED,...)"))?;
    let scenarios: Vec<WorkloadSpec> =
        parse_list(raw, "scenarios", |s| parse_scenario(ctx.model.name, s))?;

    eprintln!("building performance database (offline profiling of silicon)...");
    let db = PerfDatabase::build(&ctx.silicon, &ctx.model, ctx.cluster.gpu.preferred_kv_dtype(), 0xA1C0);

    let mut space = SearchSpace::default_for(&ctx.model, ctx.framework);
    apply_space_flags(&mut space, f)?;
    let runner = TaskRunner::new(&ctx.model, &ctx.cluster, space, scenarios[0].clone());
    let opts = aiconfigurator::search::RunOptions { prune: f.contains_key("prune") };

    let rec = start_trace(f);
    let t0 = std::time::Instant::now();
    // Branch-scoped memo (calibration consumes the database): the whole
    // sweep shares one store, priced through per-worker memo fronts.
    // Per-scenario explain reports are built while the oracle is alive.
    let run = |oracle: &dyn LatencyOracle| {
        let memo = MemoOracle::new(oracle);
        let reports = runner.run_sweep_cached(&memo, &scenarios, &opts);
        let explains: Vec<aiconfigurator::util::json::Json> = if explain_wanted(f) {
            scenarios
                .iter()
                .zip(&reports)
                .map(|(wl, r)| {
                    trace::explain::search_explain(oracle, &ctx.model, &ctx.cluster, wl, r)
                })
                .collect()
        } else {
            Vec::new()
        };
        (reports, memo.stats(), explains)
    };
    let (reports, (memo_hits, memo_misses), explains) = if let Some(path) = f.get("calibration") {
        anyhow::ensure!(
            !ctx.cluster.fabric.placement_aware(),
            "--calibration is not supported with a tiered --fabric: artifacts are fitted \
             against legacy-fabric grids (drop one of the two flags)"
        );
        let cal = load_calibrated(path, db)?;
        run(&cal)
    } else {
        run(&db)
    };
    let total_s = t0.elapsed().as_secs_f64();

    println!(
        "{:>6} {:>6} {:>9} {:>8} {:>8} {:>9} {:>7}  best configuration",
        "isl", "osl", "ttft<=ms", "speed>=", "configs", "feasible", "pruned"
    );
    for (wl, report) in scenarios.iter().zip(&reports) {
        let analysis = pareto::analyze(&report.evaluated, &wl.sla);
        let best = analysis
            .best()
            .map(|b| format!("{:.1} tok/s/GPU  {}", b.est.thru_per_gpu, b.cand.label()))
            .unwrap_or_else(|| "(none meets the SLA)".to_string());
        println!(
            "{:>6} {:>6} {:>9.0} {:>8.1} {:>8} {:>9} {:>7}  {}",
            wl.isl,
            wl.osl,
            wl.sla.ttft_ms,
            wl.sla.min_speed,
            report.configs_priced,
            analysis.feasible.len(),
            report.pruned,
            best
        );
        for s in &report.flag_summaries {
            println!("{:>13} flags [{}]", "", s.describe());
        }
        if let Some(t) = report.tier_counts {
            println!(
                "{:>13} tiers [{} measured, {} calibrated, {} analytic, {} SoL]",
                "", t.measured, t.calibrated, t.analytic, t.sol
            );
        }
    }
    println!(
        "swept {} scenarios in {:.2}s (shared engine grid + memoized oracle)",
        scenarios.len(),
        total_s
    );
    println!("{}", oracle_line(memo_hits, memo_misses, total_s));
    if !explains.is_empty() {
        for (wl, e) in scenarios.iter().zip(&explains) {
            println!("--- explain isl={} osl={} ---", wl.isl, wl.osl);
            print!("{}", trace::explain::render_search_explain(e));
        }
        // --explain-out gets the whole sweep as a JSON array.
        let all = aiconfigurator::util::json::Json::Array(explains);
        write_explain(f, &all)?;
    }
    finish_trace(f, rec)?;
    Ok(())
}

/// `topo`: print the fabric presets, the placements they enumerate for
/// sample parallel shapes, and per-collective per-algorithm cost
/// tables over the placed link path.
fn cmd_topo(f: &HashMap<String, String>) -> anyhow::Result<()> {
    use aiconfigurator::config::ParallelSpec;
    use aiconfigurator::topology::{collective, fabric, placement};

    let gpu_name = flag(f, "gpu", "h100");
    let gpu = gpu_by_name(gpu_name).ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}'"))?;
    let gpn = flag_u32(f, "gpus-per-node", 8)?;
    let nodes = flag_u32(f, "nodes", 2)?;
    let which = flag(f, "fabric", "all");
    let fabrics: Vec<aiconfigurator::topology::FabricSpec> = if which == "all" {
        let mut v = vec![aiconfigurator::topology::FabricSpec::legacy(gpn)];
        v.extend(fabric::all());
        v
    } else {
        vec![fabric_flag(f, gpn)?]
    };

    for fb in fabrics {
        let cluster = ClusterSpec::with_fabric(gpu, gpn, nodes, fb);
        println!(
            "fabric {:<14} domain {:>3} GPUs | intra {:>5.0} GB/s @{:.1}us | {}x{:.0} GB/s IB @{:.1}us{}{}",
            fb.name,
            cluster.domain_size(),
            cluster.nvlink_bw_gbs(),
            fb.intra_latency_us,
            fb.rails,
            fb.rail_gbs,
            fb.ib_latency_us,
            if fb.pod_nodes > 0 {
                format!(" | pods of {} nodes ({:.0} GB/s spine)", fb.pod_nodes, fb.pod_gbs)
            } else {
                String::new()
            },
            if fb.placement_aware() { "" } else { " | legacy flat model" },
        );

        // Placement enumeration for sample shapes on this geometry.
        for (tp, pp, ep) in [(8u32, 1u32, 1u32), (8, 2, 1), (4, 2, 1), (4, 1, 4)] {
            let p = ParallelSpec { tp, pp, ep, dp: 1 };
            if p.gpus() > cluster.total_gpus() {
                continue;
            }
            let pls = placement::enumerate(&cluster, &p);
            let labels: Vec<String> = pls.iter().map(|pl| pl.label()).collect();
            println!("  placements tp{tp} pp{pp} ep{ep}: {}", labels.join(" | "));
        }

        // Per-collective, per-algorithm cost table for one group.
        let group = flag_u32(f, "group", 16)?.min(cluster.total_gpus()).max(2);
        let span = placement::natural_span(&cluster, group);
        let rails = fb.rails;
        println!(
            "  costs, {group}-GPU group (span {span}, rails {rails}), microseconds:");
        let sizes: &[(f64, &str)] = &[
            (64.0 * 1024.0, "64KiB"),
            (1048576.0, "1MiB"),
            (16.0 * 1048576.0, "16MiB"),
            (256.0 * 1048576.0, "256MiB"),
            (1.074e9, "1GiB"),
        ];
        let header: Vec<&str> =
            collective::algo_table(&cluster, group, span, rails, 1.0).iter().map(|r| r.0).collect();
        println!("  {:>8} {}", "bytes", header.iter().map(|h| format!("{h:>22}")).collect::<String>());
        for &(bytes, label) in sizes {
            let row = collective::algo_table(&cluster, group, span, rails, bytes);
            let cells: String = row.iter().map(|(_, us)| format!("{us:>22.1}")).collect();
            println!("  {label:>8} {cells}");
        }
        println!();
    }
    Ok(())
}

/// Parse the traffic model from `--traffic` + its per-kind flags.
fn parse_traffic(f: &HashMap<String, String>) -> anyhow::Result<TrafficModel> {
    let kind = f
        .get("traffic")
        .ok_or_else(|| anyhow::anyhow!("--traffic is required (diurnal|ramp|bursty)"))?;
    let req = |key: &str| -> anyhow::Result<f64> {
        anyhow::ensure!(f.contains_key(key), "--{key} is required for --traffic {kind}");
        flag_f64(f, key, 0.0)
    };
    let model = match kind.as_str() {
        "diurnal" => TrafficModel::Diurnal {
            peak_qps: req("peak-qps")?,
            trough_qps: flag_f64(f, "trough-qps", 0.0)?,
            period_h: flag_f64(f, "period-h", 24.0)?,
        },
        "ramp" => TrafficModel::Ramp { start_qps: req("start-qps")?, end_qps: req("end-qps")? },
        "bursty" => TrafficModel::Bursty {
            base_qps: req("base-qps")?,
            burst_qps: req("burst-qps")?,
            burst_prob: flag_f64(f, "burst-prob", 0.15)?,
            seed: flag_u32(f, "burst-seed", 7)? as u64,
        },
        other => anyhow::bail!("unknown --traffic '{other}' (diurnal|ramp|bursty)"),
    };
    model.validate()?;
    Ok(model)
}

/// Parse the flags shared by `plan` and `validate` into (model,
/// framework, workload).
fn parse_plan_workload(
    f: &HashMap<String, String>,
) -> anyhow::Result<(aiconfigurator::models::ModelArch, Framework, WorkloadSpec)> {
    let model_name = f.get("model").ok_or_else(|| anyhow::anyhow!("--model is required"))?;
    let model = by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}' (see --help)"))?;
    let fw_name = flag(f, "framework", "trtllm");
    let framework = Framework::parse(fw_name)
        .ok_or_else(|| anyhow::anyhow!("unknown framework '{fw_name}'"))?;
    let isl = flag_u32(f, "isl", 0)?;
    let osl = flag_u32(f, "osl", 0)?;
    anyhow::ensure!(isl > 0 && osl > 0, "--isl and --osl are required");
    let wl = WorkloadSpec::new(
        model.name,
        isl,
        osl,
        flag_f64(f, "ttft", f64::INFINITY)?,
        flag_f64(f, "speed", 0.0)?,
    );
    Ok((model, framework, wl))
}

/// One priced fleet leg with its execution substrate kept alive — the
/// planner consumes the oracle; `validate` additionally replays on the
/// leg's silicon.
struct PlanLeg {
    cluster: ClusterSpec,
    silicon: Silicon,
    oracle: Box<dyn LatencyOracle>,
}

/// Build the fleet legs for `plan`/`validate`: one leg per `--fleet`
/// GPU type, each profiled against that platform's synthetic silicon
/// (Ampere legs profile fp16 — no fp8). A `--calibration` artifact is
/// composed over the leg whose GPU it was fitted for; other legs stay
/// analytic.
fn build_fleet_legs(
    f: &HashMap<String, String>,
    model: &aiconfigurator::models::ModelArch,
    framework: Framework,
) -> anyhow::Result<Vec<PlanLeg>> {
    let gpn = flag_u32(f, "gpus-per-node", 8)?;
    let nodes = flag_u32(f, "nodes", 1)?;
    let artifact = match f.get("calibration") {
        Some(path) => Some(CalibrationArtifact::load(Path::new(path))?),
        None => None,
    };
    // Fleet legs parse as GPU[@FABRIC] (shared grammar with the
    // service — `hardware::parse_fleet_leg`): a bare name keeps the
    // legacy flat topology, `@` wires the leg with a named tiered
    // fabric — mixed fleets may mix fabrics.
    let legs_spec: Vec<aiconfigurator::hardware::FleetLeg> =
        parse_list(flag(f, "fleet", "h100"), "fleet", |name| {
            aiconfigurator::hardware::parse_fleet_leg(name, gpn)
        })?;
    let mut legs: Vec<PlanLeg> = Vec::new();
    for leg in legs_spec {
        let (gpu, fabric) = (leg.gpu, leg.fabric);
        let cluster = ClusterSpec::with_fabric(gpu, gpn, nodes, fabric);
        let silicon = Silicon::new(cluster, framework.profile());
        eprintln!(
            "profiling fleet leg {}{} ({} GPUs @ ${:.2}/h each)...",
            gpu.name,
            if fabric.placement_aware() {
                format!(" on {}", fabric.name)
            } else {
                String::new()
            },
            cluster.total_gpus(),
            gpu.usd_per_hour
        );
        let db = PerfDatabase::build(&silicon, model, gpu.preferred_kv_dtype(), 0xA1C0);
        let oracle: Box<dyn LatencyOracle> = match &artifact {
            Some(art) if art.gpu == gpu.name => {
                eprintln!(
                    "  composing calibration over the {} leg ({} tables, {} measured cells)",
                    gpu.name,
                    art.fits.len(),
                    art.measured_cells.len()
                );
                Box::new(CalibratedDb::compose(db, art)?)
            }
            _ => Box::new(db),
        };
        legs.push(PlanLeg { cluster, silicon, oracle });
    }
    anyhow::ensure!(!legs.is_empty(), "--fleet named no GPU types");
    if let Some(art) = &artifact {
        anyhow::ensure!(
            legs.iter().any(|l| l.cluster.gpu.name == art.gpu),
            "--calibration artifact is for gpu '{}' but the fleet has no such leg",
            art.gpu
        );
    }
    Ok(legs)
}

fn cmd_plan(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let (model, framework, wl) = parse_plan_workload(f)?;
    let spec = aiconfigurator::planner::PlanSpec {
        workload: wl,
        traffic: parse_traffic(f)?,
        windows: flag_u32(f, "windows", 24)? as usize,
        window_h: flag_f64(f, "window-hours", 1.0)?,
        max_gpus: if f.contains_key("max-gpus") {
            Some(flag_u32(f, "max-gpus", 0)?)
        } else {
            None
        },
        prune: !f.contains_key("no-prune"),
        demand_override: Vec::new(),
    };
    let legs = build_fleet_legs(f, &model, framework)?;
    // CLI-owned memo per leg (bit-transparent: `planner::plan` wraps
    // raw oracles in exactly this memo internally) so the shared
    // oracle stats line can report ops priced + hit rate.
    let memos: Vec<MemoOracle<'_>> =
        legs.iter().map(|l| MemoOracle::new(l.oracle.as_ref())).collect();
    let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        legs.iter().zip(&memos).map(|(l, m)| (l.cluster, m)).collect();

    let rec = start_trace(f);
    let t0 = std::time::Instant::now();
    let plan = aiconfigurator::planner::plan_cached(&model, framework, &spec, &fleet)?;
    let elapsed = t0.elapsed().as_secs_f64();

    println!(
        "{:>3} {:>13} {:>9} {:>9} {:>5} {:>5} {:>9}  deployment",
        "win", "hours", "qps", "gpu", "reps", "gpus", "cost $"
    );
    for w in &plan.windows {
        println!(
            "{:>3} {:>6.1}-{:<6.1} {:>9.1} {:>9} {:>5} {:>5} {:>9.2}  {}",
            w.index,
            w.t_start_h,
            w.t_end_h,
            w.demand_qps,
            w.gpu,
            w.replicas,
            w.gpus,
            w.cost_usd,
            w.cand.label()
        );
    }
    println!(
        "planned {} windows in {:.2}s — total ${:.2} ({} options priced, {} pruned on the (cost, capacity, speed, footprint) frontier)",
        plan.windows.len(),
        elapsed,
        plan.total_cost_usd,
        plan.options_considered,
        plan.options_pruned
    );
    println!(
        "vs static peak provisioning: ${:.2} ({:.0}% saved by following the traffic)",
        plan.static_peak_cost_usd,
        100.0 * plan.elastic_savings_frac()
    );
    if let Some((gpu, cost)) = &plan.best_homogeneous {
        if plan.total_cost_usd < cost - 1e-9 {
            println!(
                "vs best homogeneous fleet (all-{gpu}): ${cost:.2} — mixing GPU types saves ${:.2}",
                cost - plan.total_cost_usd
            );
        } else {
            println!("best homogeneous fleet (all-{gpu}) matches: ${cost:.2}");
        }
    }
    for l in &legs {
        if let Some(t) = l.oracle.provenance_counts() {
            println!(
                "{} leg oracle tiers: {} measured-cell, {} calibrated-analytic, {} analytic, {} SoL",
                l.cluster.gpu.name, t.measured, t.calibrated, t.analytic, t.sol
            );
        }
    }
    let (hits, misses) = memos
        .iter()
        .map(|m| m.stats())
        .fold((0u64, 0u64), |(h, m), (h2, m2)| (h + h2, m + m2));
    println!("{}", oracle_line(hits, misses, elapsed));
    if explain_wanted(f) {
        let named: Vec<(String, ClusterSpec, &dyn LatencyOracle)> = legs
            .iter()
            .map(|l| (l.cluster.gpu.name.to_string(), l.cluster, l.oracle.as_ref()))
            .collect();
        let e = trace::explain::plan_explain(&model, &spec.workload, &plan, &named);
        print!("{}", trace::explain::render_plan_explain(&e));
        write_explain(f, &e)?;
    }

    if let Some(dir) = f.get("out-dir") {
        let dirp = std::path::Path::new(dir);
        std::fs::create_dir_all(dirp)?;
        std::fs::write(dirp.join("plan.json"), plan.to_json(&spec.workload).to_string())?;
        std::fs::write(
            dirp.join("schedule.yaml"),
            generator::dynamo::plan_schedule_yaml(&plan, model.name, &spec.workload),
        )?;
        for w in &plan.windows {
            // Scale-to-zero windows get no bundle (schedule.yaml marks
            // them `bundle: ~`) — emitting one would contradict the
            // schedule's replicas: 0.
            if w.replicas == 0 {
                continue;
            }
            // Aggregated windows scale by replica count inside the
            // bundle; disaggregated windows launch `replicas` identical
            // composites (the schedule.yaml carries the count).
            let cand = match &w.cand {
                Candidate::Aggregated { engine, .. } => {
                    Candidate::Aggregated { engine: *engine, replicas: w.replicas }
                }
                c => c.clone(),
            };
            let bundle = generator::generate(&cand, model.name, &spec.workload);
            bundle.write_to(&dirp.join(format!("window_{:02}", w.index)))?;
        }
        println!("wrote plan.json, schedule.yaml and per-window launch bundles to {dir}/");
    }
    finish_trace(f, rec)?;
    Ok(())
}

/// Build one fleet leg from its `GPU[@FABRIC]` token — the per-leg
/// half of [`build_fleet_legs`], used by `replan` for legs the delta
/// recalibrates or adds (each gets its own oracle, composed over the
/// artifact only when one is passed *and* matches the leg's GPU).
fn build_plan_leg(
    token: &str,
    gpn: u32,
    nodes: u32,
    model: &aiconfigurator::models::ModelArch,
    framework: Framework,
    artifact: Option<&CalibrationArtifact>,
) -> anyhow::Result<PlanLeg> {
    let leg = aiconfigurator::hardware::parse_fleet_leg(token, gpn)?;
    let cluster = ClusterSpec::with_fabric(leg.gpu, gpn, nodes, leg.fabric);
    let silicon = Silicon::new(cluster, framework.profile());
    eprintln!("profiling fleet leg {} ({} GPUs)...", leg.gpu.name, cluster.total_gpus());
    let db = PerfDatabase::build(&silicon, model, leg.gpu.preferred_kv_dtype(), 0xA1C0);
    let oracle: Box<dyn LatencyOracle> = match artifact {
        Some(art) if art.gpu == leg.gpu.name => Box::new(CalibratedDb::compose(db, art)?),
        _ => Box::new(db),
    };
    Ok(PlanLeg { cluster, silicon, oracle })
}

/// `replan`: plan exactly as `plan` would, then apply a committed
/// [`SearchDelta`] through the incremental replan layer — only
/// recalibrated/added legs are re-swept; window edits, GPU repricing
/// and leg removals patch the retained Pareto frontier — and print the
/// config diff plus the re-priced-candidate counts. `--check-equal`
/// additionally runs the full from-scratch plan of the patched inputs
/// and exits non-zero unless the incremental result is bit-identical
/// and re-priced strictly fewer configs (the CI replan-smoke gate).
/// With `recalibrate` deltas, the baseline fleet is built *without*
/// `--calibration` and the recalibrated legs are rebuilt *with* it —
/// the artifact is the "swapped calibration" the delta describes.
fn cmd_replan(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let (model, framework, wl) = parse_plan_workload(f)?;
    let spec = aiconfigurator::planner::PlanSpec {
        workload: wl.clone(),
        traffic: parse_traffic(f)?,
        windows: flag_u32(f, "windows", 24)? as usize,
        window_h: flag_f64(f, "window-hours", 1.0)?,
        max_gpus: if f.contains_key("max-gpus") {
            Some(flag_u32(f, "max-gpus", 0)?)
        } else {
            None
        },
        prune: !f.contains_key("no-prune"),
        demand_override: Vec::new(),
    };
    let delta_path = f
        .get("delta")
        .ok_or_else(|| anyhow::anyhow!("--delta FILE.json is required (a search-delta spec)"))?;
    let delta_text = std::fs::read_to_string(Path::new(delta_path))
        .map_err(|e| anyhow::anyhow!("cannot read delta spec {delta_path}: {e}"))?;
    let delta = SearchDelta::from_json(&aiconfigurator::util::json::parse(&delta_text)?)?;
    let gpn = flag_u32(f, "gpus-per-node", 8)?;
    let nodes = flag_u32(f, "nodes", 1)?;
    let artifact = match f.get("calibration") {
        Some(path) => Some(CalibrationArtifact::load(Path::new(path))?),
        None => None,
    };
    if !delta.recalibrate.is_empty() {
        let art = artifact.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "the delta recalibrates {:?} but no --calibration artifact was passed \
                 (the artifact is the swapped calibration)",
                delta.recalibrate
            )
        })?;
        for token in &delta.recalibrate {
            let leg = aiconfigurator::hardware::parse_fleet_leg(token, gpn)?;
            anyhow::ensure!(
                art.gpu == leg.gpu.name,
                "--calibration artifact is for gpu '{}' but the delta recalibrates '{}'",
                art.gpu,
                leg.gpu.name
            );
        }
    }

    // Baseline fleet, always analytic: with a recalibrate delta the
    // artifact describes the *new* state, not the baseline.
    let tokens: Vec<String> = flag(f, "fleet", "h100")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!tokens.is_empty(), "--fleet named no GPU types");
    let rec = start_trace(f);
    let t0 = std::time::Instant::now();
    let legs: Vec<PlanLeg> = tokens
        .iter()
        .map(|t| build_plan_leg(t, gpn, nodes, &model, framework, None))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let memos: Vec<MemoOracle<'_>> =
        legs.iter().map(|l| MemoOracle::new(l.oracle.as_ref())).collect();
    let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        legs.iter().zip(&memos).map(|(l, m)| (l.cluster, m)).collect();
    let (baseline, mut arena) =
        aiconfigurator::planner::plan_arena(&model, framework, &spec, &fleet)?;
    let baseline_s = t0.elapsed().as_secs_f64();

    // Legs the delta re-sweeps: recalibrated (with the artifact), then
    // added (analytic) — the order `planner::replan` expects.
    let swept_legs: Vec<PlanLeg> = delta
        .recalibrate
        .iter()
        .map(|t| build_plan_leg(t, gpn, nodes, &model, framework, artifact.as_ref()))
        .chain(
            delta
                .add_legs
                .iter()
                .map(|t| build_plan_leg(t, gpn, nodes, &model, framework, None)),
        )
        .collect::<anyhow::Result<Vec<_>>>()?;
    let swept_memos: Vec<MemoOracle<'_>> =
        swept_legs.iter().map(|l| MemoOracle::new(l.oracle.as_ref())).collect();
    let swept: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        swept_legs.iter().zip(&swept_memos).map(|(l, m)| (l.cluster, m)).collect();

    let t1 = std::time::Instant::now();
    let rep =
        aiconfigurator::planner::replan(&model, framework, &mut arena, &baseline, &delta, &swept)?;
    let replan_s = t1.elapsed().as_secs_f64();

    println!(
        "replanned in {replan_s:.3}s (baseline plan took {baseline_s:.2}s) — re-priced {} \
         engine configs; a full re-search would price {}",
        rep.repriced_configs, rep.baseline_priced_configs
    );
    println!(
        "plan: ${:.2} over {} windows ({} window(s) changed deployment vs baseline ${:.2})",
        rep.plan.total_cost_usd,
        rep.plan.windows.len(),
        rep.windows_changed,
        baseline.total_cost_usd
    );
    for label in &rep.entered {
        println!("  + entered frontier: {label}");
    }
    for label in &rep.left {
        println!("  - left frontier:    {label}");
    }
    if rep.entered.is_empty() && rep.left.is_empty() {
        println!("  frontier membership unchanged");
    }
    let (hits, misses) = memos
        .iter()
        .chain(&swept_memos)
        .map(|m| m.stats())
        .fold((0u64, 0u64), |(h, m), (h2, m2)| (h + h2, m + m2));
    println!("{}", oracle_line(hits, misses, baseline_s + replan_s));

    if let Some(out) = f.get("out") {
        let path = Path::new(out);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, rep.to_json(&wl).to_string())?;
        println!("wrote replan report to {out}");
    }

    if f.contains_key("check-equal") {
        // From-scratch reference: the patched fleet in canonical order
        // (removed legs dropped, added legs appended), repriced GPUs,
        // recalibrated legs under the artifact, window edits as demand
        // overrides.
        let mut patched_tokens = tokens.clone();
        for r in &delta.remove_legs {
            let gpu = gpu_by_name(r)
                .ok_or_else(|| anyhow::anyhow!("unknown gpu '{r}' in delta"))?;
            let pos = patched_tokens
                .iter()
                .position(|t| {
                    aiconfigurator::hardware::parse_fleet_leg(t, gpn)
                        .map(|l| l.gpu.name == gpu.name)
                        .unwrap_or(false)
                })
                .ok_or_else(|| anyhow::anyhow!("delta removes '{r}' but no fleet leg uses it"))?;
            patched_tokens.remove(pos);
        }
        patched_tokens.extend(delta.add_legs.iter().cloned());
        let recalibrated: Vec<&str> = delta
            .recalibrate
            .iter()
            .map(|t| aiconfigurator::hardware::parse_fleet_leg(t, gpn).map(|l| l.gpu.name))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut fresh_legs: Vec<PlanLeg> = Vec::new();
        for t in &patched_tokens {
            let leg_gpu = aiconfigurator::hardware::parse_fleet_leg(t, gpn)?.gpu.name;
            let art = if recalibrated.contains(&leg_gpu) { artifact.as_ref() } else { None };
            let mut leg = build_plan_leg(t, gpn, nodes, &model, framework, art)?;
            for (g, price) in &delta.reprice {
                let gpu = gpu_by_name(g)
                    .ok_or_else(|| anyhow::anyhow!("unknown gpu '{g}' in delta"))?;
                if leg.cluster.gpu.name == gpu.name {
                    leg.cluster.gpu.usd_per_hour = *price;
                }
            }
            fresh_legs.push(leg);
        }
        let mut patched_spec = spec.clone();
        patched_spec.demand_override = delta.window_edits.clone();
        let fresh_fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
            fresh_legs.iter().map(|l| (l.cluster, l.oracle.as_ref())).collect();
        let fresh =
            aiconfigurator::planner::plan(&model, framework, &patched_spec, &fresh_fleet)?;
        anyhow::ensure!(
            rep.plan.to_json(&wl).to_string() == fresh.to_json(&wl).to_string(),
            "replan-equivalence check FAILED: the incremental replan differs from the \
             from-scratch plan of the patched inputs (incremental ${:.4} vs fresh ${:.4})",
            rep.plan.total_cost_usd,
            fresh.total_cost_usd
        );
        anyhow::ensure!(
            rep.repriced_configs < rep.baseline_priced_configs,
            "replan-equivalence check FAILED: replan re-priced {} configs but a full \
             re-search prices {} — no work was saved",
            rep.repriced_configs,
            rep.baseline_priced_configs
        );
        println!(
            "check passed: incremental replan is bit-identical to the from-scratch plan \
             and re-priced {}/{} configs",
            rep.repriced_configs, rep.baseline_priced_configs
        );
    }
    finish_trace(f, rec)?;
    Ok(())
}

/// Load a committed trace spec: a small JSON file pinning the traffic
/// model, horizon and seeds so CI replays the *same* trace every run
/// (`artifacts/traces/*.json`). Returns
/// (traffic, windows, window_hours, len_jitter, seed).
fn load_trace_spec(path: &Path) -> anyhow::Result<(TrafficModel, usize, f64, f64, u64)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read trace spec {}: {e}", path.display()))?;
    let j = aiconfigurator::util::json::parse(&text)?;
    anyhow::ensure!(
        j.str_or("kind", "") == "trace-spec",
        "{} is not a trace spec (want \"kind\": \"trace-spec\")",
        path.display()
    );
    let traffic = TrafficModel::from_json(j.req("traffic")?)?;
    traffic.validate()?;
    let windows = j.req_f64("windows")? as usize;
    let window_h = j.req_f64("window_hours")?;
    anyhow::ensure!(windows > 0, "trace spec: windows must be positive");
    anyhow::ensure!(window_h > 0.0, "trace spec: window_hours must be positive");
    let len_jitter = j.f64_or("len_jitter", 0.0);
    anyhow::ensure!(
        (0.0..1.0).contains(&len_jitter),
        "trace spec: len_jitter must be in [0, 1)"
    );
    let seed = j.f64_or("seed", 0.0);
    anyhow::ensure!(
        seed >= 0.0 && seed.fract() == 0.0 && seed < 9.007199254740992e15,
        "trace spec: seed must be a non-negative integer"
    );
    Ok((traffic, windows, window_h, len_jitter, seed as u64))
}

/// `validate`: plan exactly as `plan` would, then replay a trace drawn
/// from the plan's own traffic model through the fleet simulator
/// ([`aiconfigurator::fleetsim`]) and report achieved vs promised SLA
/// attainment — the planner's optimism gap, by cause.
fn cmd_validate(f: &HashMap<String, String>) -> anyhow::Result<()> {
    use aiconfigurator::fleetsim;

    let (model, framework, wl) = parse_plan_workload(f)?;
    let seed = flag_u64(f, "seed", 0xD15C)?;
    // Horizon + trace source: a committed spec file pins everything;
    // otherwise the same --traffic flags as `plan`, seeded by --seed.
    let (traffic, windows, window_h, len_jitter, trace_seed) = match f.get("trace-spec") {
        Some(path) => load_trace_spec(Path::new(path))?,
        None => (
            parse_traffic(f)?,
            flag_u32(f, "windows", 24)? as usize,
            flag_f64(f, "window-hours", 1.0)?,
            flag_f64(f, "len-jitter", 0.0)?,
            seed,
        ),
    };
    let spec = aiconfigurator::planner::PlanSpec {
        workload: wl.clone(),
        traffic,
        windows,
        window_h,
        max_gpus: if f.contains_key("max-gpus") {
            Some(flag_u32(f, "max-gpus", 0)?)
        } else {
            None
        },
        prune: !f.contains_key("no-prune"),
        demand_override: Vec::new(),
    };
    let legs = build_fleet_legs(f, &model, framework)?;
    // Memo-wrapped legs (same wrapping `planner::plan` does itself) so
    // the shared oracle stats line can report the planning cost.
    let memos: Vec<MemoOracle<'_>> =
        legs.iter().map(|l| MemoOracle::new(l.oracle.as_ref())).collect();
    let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        legs.iter().zip(&memos).map(|(l, m)| (l.cluster, m)).collect();

    let rec = start_trace(f);
    let t0 = std::time::Instant::now();
    let plan = aiconfigurator::planner::plan_cached(&model, framework, &spec, &fleet)?;
    let plan_s = t0.elapsed().as_secs_f64();
    let trace = spec.traffic.trace(windows, window_h, &wl, len_jitter, trace_seed);
    anyhow::ensure!(
        !trace.is_empty(),
        "the materialized trace is empty — raise the traffic rates or widen the windows"
    );
    eprintln!(
        "replaying {} requests over {} windows ({} segment(s))...",
        trace.len(),
        windows,
        plan.segments().len()
    );
    let cfg = fleetsim::FleetConfig {
        seed,
        scale_lag_s: flag_f64(f, "scale-lag", 0.0)?,
        failure_rate_per_replica_h: flag_f64(f, "failure-rate", 0.0)?,
        restart_s: flag_f64(f, "restart", 120.0)?,
        sim: SimConfig { seed, ..SimConfig::default() },
    };
    let fleet_legs: Vec<fleetsim::FleetLeg<'_>> = legs
        .iter()
        .map(|l| fleetsim::FleetLeg {
            name: l.cluster.gpu.name.to_string(),
            cluster: l.cluster,
            silicon: &l.silicon,
        })
        .collect();
    let report = fleetsim::replay(&model, &spec, &plan, &fleet_legs, &trace, &cfg)?;
    let elapsed = t0.elapsed().as_secs_f64();

    print!("{}", report.render());
    println!(
        "validated the plan in {:.2}s (plan ${:.2}; injection: lag {}s, {}/replica-h, restart {}s)",
        elapsed,
        plan.total_cost_usd,
        cfg.scale_lag_s,
        cfg.failure_rate_per_replica_h,
        cfg.restart_s
    );
    let (hits, misses) = memos
        .iter()
        .map(|m| m.stats())
        .fold((0u64, 0u64), |(h, m), (h2, m2)| (h + h2, m + m2));
    println!("{}", oracle_line(hits, misses, plan_s));

    if let Some(out) = f.get("out") {
        let path = Path::new(out);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, report.to_json().to_string())?;
        println!("wrote validation report to {out}");
    }
    if f.contains_key("check-gap") {
        let max_gap = flag_f64(f, "check-gap", 0.1)?;
        anyhow::ensure!(
            report.optimism_gap <= max_gap,
            "optimism gap {:.4} exceeds the allowed {:.4}: the planner promised {:.4} \
             attainment but the fleet achieved {:.4} (misses: {} queueing, {} scale-lag, \
             {} contention, {} failure)",
            report.optimism_gap,
            max_gap,
            report.promised_attainment,
            report.achieved_attainment,
            report.misses.queueing,
            report.misses.scale_lag,
            report.misses.contention,
            report.misses.failure
        );
        println!(
            "check passed: optimism gap {:.4} <= {:.4}",
            report.optimism_gap, max_gap
        );
    }
    finish_trace(f, rec)?;
    Ok(())
}

/// Fit a calibration artifact from a measurement directory, print and
/// optionally persist the fidelity report. With `--check-improves`,
/// exit non-zero unless every fitted table's post-fit MAPE beats its
/// pre-fit MAPE (the CI calibration-smoke gate).
fn cmd_calibrate(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let ctx = load_ctx(f)?;
    let dt = ctx.cluster.gpu.preferred_kv_dtype();
    let meas = f
        .get("measurements")
        .ok_or_else(|| anyhow::anyhow!("--measurements is required (DIR/<gpu>/<table>.json)"))?;
    let dir = Path::new(meas);

    if f.contains_key("synthesize") {
        let seed = flag_u32(f, "seed", 7)? as u64;
        let points = flag_u32(f, "points", 48)? as usize;
        anyhow::ensure!(points >= 1, "--points must be positive");
        let sets = measure::synthesize(&ctx.silicon, &ctx.model, dt, seed, points);
        measure::write_sets(dir, &sets)?;
        println!(
            "synthesized {} measurement sets ({} points each, seed {seed}) into {}/{}/",
            sets.len(),
            points,
            meas,
            ctx.cluster.gpu.name
        );
    }

    eprintln!("building analytic database (offline profiling of silicon)...");
    let db = PerfDatabase::build(&ctx.silicon, &ctx.model, dt, 0xA1C0);
    let sets = measure::load_dir(dir, ctx.cluster.gpu.name)?;
    let n_points: usize = sets.iter().map(|s| s.entries.len()).sum();
    let mut art = calibrate::fit(&db, &sets)?;
    art.provenance = format!("{} from {}", art.provenance, meas);

    use aiconfigurator::perfdb::tables::{NX, NY, NZ};
    println!(
        "{:<13} {:>7} {:>9} {:>10} {:>10} {:>8}  correction@mid",
        "table", "points", "outliers", "pre MAPE", "post MAPE", "clamped"
    );
    for t in &art.fits {
        println!(
            "{:<13} {:>7} {:>9} {:>9.1}% {:>9.1}% {:>8}  x{:.3}",
            t.table.name(),
            t.n_points,
            t.n_outliers,
            t.pre_mape * 100.0,
            t.post_mape * 100.0,
            t.clamped_axes.iter().filter(|&&c| c).count(),
            t.factor_at(NX / 2, NY / 2, NZ / 2)
        );
    }
    println!(
        "fitted {} tables from {} measurements ({} / {} / {} / {})",
        art.fits.len(),
        n_points,
        ctx.cluster.gpu.name,
        ctx.model.name,
        ctx.framework.name(),
        dt.name()
    );

    if let Some(out) = f.get("out") {
        art.save(Path::new(out))?;
        println!("wrote calibration artifact to {out}");
    }
    if let Some(rep) = f.get("report") {
        let path = Path::new(rep);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, art.fidelity_json().to_string())?;
        println!("wrote fidelity report to {rep}");
    }
    if f.contains_key("check-improves") {
        anyhow::ensure!(
            art.all_tables_improve(),
            "calibration did NOT improve every table: {}",
            art.fits
                .iter()
                .filter(|t| t.post_mape >= t.pre_mape)
                .map(|t| format!(
                    "{} (pre {:.1}% -> post {:.1}%)",
                    t.table.name(),
                    t.pre_mape * 100.0,
                    t.post_mape * 100.0
                ))
                .collect::<Vec<_>>()
                .join(", ")
        );
        println!(
            "check passed: post-fit MAPE < pre-fit MAPE for all {} fitted tables",
            art.fits.len()
        );
    }
    Ok(())
}

fn cmd_build_db(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let ctx = load_ctx(f)?;
    let out = f.get("out").ok_or_else(|| anyhow::anyhow!("--out is required"))?;
    let db = PerfDatabase::build(&ctx.silicon, &ctx.model, ctx.cluster.gpu.preferred_kv_dtype(), 0xA1C0);
    db.save(std::path::Path::new(out))?;
    println!(
        "profiled {} ({} on {}) -> {out} (simulated campaign cost {:.1} GPU-hours)",
        ctx.model.name,
        ctx.framework.name(),
        ctx.cluster.gpu.name,
        db.profile_cost_hours
    );
    Ok(())
}

fn cmd_simulate(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let ctx = load_ctx(f)?;
    let isl = flag_u32(f, "isl", 1024)?;
    let osl = flag_u32(f, "osl", 128)?;
    let batch = flag_u32(f, "batch", 8)?;
    let parallel = aiconfigurator::config::ParallelSpec {
        tp: flag_u32(f, "tp", 1)?,
        pp: 1,
        ep: flag_u32(f, "ep", 1)?,
        dp: 1,
    };
    let dt = ctx.cluster.gpu.preferred_kv_dtype();
    // Launch flags resolved by the backend layer for this workload
    // shape; pass the same --ttft/--speed as the search to simulate the
    // exact engine the search priced and emitted.
    let wl = WorkloadSpec::new(
        ctx.model.name,
        isl,
        osl,
        flag_f64(f, "ttft", f64::INFINITY)?,
        flag_f64(f, "speed", 0.0)?,
    );
    let flags = ctx
        .framework
        .backend()
        .resolve_flags(&ctx.model, &ctx.cluster, &wl, &parallel, batch, dt);
    let eng = aiconfigurator::config::EngineConfig {
        framework: ctx.framework,
        parallel,
        batch,
        weight_dtype: dt,
        kv_dtype: dt,
        flags,
        placement: aiconfigurator::topology::Placement::packed(),
    };
    eprintln!(
        "resolved flags: kv_frac {:.2}, max_num_tokens {}, cuda_graph {}, chunked_prefill {}",
        flags.kv_frac, flags.max_num_tokens, flags.cuda_graph, flags.chunked_prefill
    );
    let n = flag_u32(f, "requests", 4 * batch)? as usize;
    // User-settable jitter seed (was hard-coded to the SimConfig
    // default): same seed ⇒ bit-identical metrics, different seed ⇒ a
    // different scheduler-jitter stream (pinned in tests/fleetsim.rs).
    let sim_cfg =
        SimConfig { seed: flag_u64(f, "seed", SimConfig::default().seed)?, ..SimConfig::default() };
    let sim = AggregatedSim::new(&ctx.silicon, &ctx.model, &ctx.cluster, eng, sim_cfg);
    let res = sim.run(&closed_loop(n, isl, osl));
    print_sim(&res);
    Ok(())
}

fn print_sim(res: &simulator::SimResult) {
    println!(
        "completed {} requests in {:.1}s over {} iterations",
        res.completed,
        res.makespan_ms / 1000.0,
        res.iterations
    );
    println!(
        "TTFT mean {:.1} ms (p99 {:.1}) | TPOT mean {:.2} ms | speed {:.1} tok/s/user | {:.1} tok/s/GPU",
        res.mean_ttft_ms(),
        res.p99_ttft_ms(),
        res.mean_tpot_ms(),
        res.speed(),
        res.thru_per_gpu()
    );
}

fn cmd_experiment(pos: &[String], f: &HashMap<String, String>) -> anyhow::Result<()> {
    let which = pos.first().map(String::as_str).unwrap_or("all");
    let quick = !f.contains_key("full");
    let run_one = |name: &str| -> anyhow::Result<()> {
        let rep = match name {
            "fig1" => experiments::fig1_pareto::run(quick),
            "fig5" => experiments::fig5_powerlaw::run(quick),
            "fig6" => experiments::fig6_agg_fidelity::run(quick),
            "fig7" => experiments::fig7_disagg_fidelity::run(quick),
            "fig8" | "table2" => experiments::fig8_case_study::run(quick),
            "table1" => experiments::table1_efficiency::run(quick),
            other => anyhow::bail!("unknown experiment '{other}'"),
        };
        println!("{}", rep.render());
        Ok(())
    };
    if which == "all" {
        for n in ["fig1", "fig5", "fig6", "fig7", "fig8", "table1"] {
            run_one(n)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

fn cmd_serve(f: &HashMap<String, String>) -> anyhow::Result<()> {
    // PJRT answers the bound context from the uncalibrated analytic
    // grids, which would silently shadow a calibration artifact for
    // exactly the context it was fitted for — reject the combination
    // loudly, as `search` does.
    anyhow::ensure!(
        !(f.contains_key("pjrt") && f.contains_key("calibration")),
        "--calibration is not supported with --pjrt: the AOT kernel would answer the \
         bound context from the uncalibrated grids (drop one of the two flags)"
    );
    let cfg = ServerConfig {
        addr: flag(f, "addr", "127.0.0.1:7788").to_string(),
        artifacts: f.get("pjrt").map(PathBuf::from),
        calibration: f.get("calibration").map(PathBuf::from),
        seed: 0xA1C0,
        // 0 = the pipeline defaults (min(4, cores) workers, backlog 64,
        // 8 warm contexts).
        workers: flag_u32(f, "workers", 0)? as usize,
        queue_limit: flag_u32(f, "queue-limit", 0)? as usize,
        cache_cap: flag_u32(f, "cache-cap", 0)? as usize,
        trace_sample: flag_u32(f, "trace-sample", 0)? as usize,
    };
    let pjrt_ctx = if cfg.artifacts.is_some() {
        let model = f.get("model").map(String::as_str).unwrap_or("qwen3-32b");
        Some((
            model,
            flag(f, "gpu", "h100"),
            flag_u32(f, "gpus-per-node", 8)?,
            flag_u32(f, "nodes", 1)?,
            Framework::parse(flag(f, "framework", "trtllm"))
                .ok_or_else(|| anyhow::anyhow!("unknown framework"))?,
        ))
    } else {
        None
    };
    let (server, addr) = SearchServer::bind(&cfg, pjrt_ctx)?;
    println!("aiconfigurator service listening on {addr} (JSON-lines)");
    server.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_space_and_equals_syntax() {
        let (f, pos) = parse_flags(&argv(&[
            "--model",
            "qwen3-32b",
            "--isl=4000",
            "--prune",
            "fig1",
            "--scenarios=1024:128:inf:0,512:64:1000:20",
        ]));
        assert_eq!(f.get("model").unwrap(), "qwen3-32b");
        assert_eq!(f.get("isl").unwrap(), "4000");
        assert_eq!(f.get("prune").unwrap(), "true");
        assert_eq!(f.get("scenarios").unwrap(), "1024:128:inf:0,512:64:1000:20");
        assert_eq!(pos, vec!["fig1".to_string()]);
    }

    #[test]
    fn equals_binds_tighter_than_lookahead() {
        // Values that start with '--' or contain '=' are expressible
        // only through the '=' form.
        let (f, _) = parse_flags(&argv(&["--out-dir=/tmp/a=b", "--tag=", "--speed=-5"]));
        assert_eq!(f.get("out-dir").unwrap(), "/tmp/a=b");
        assert_eq!(f.get("tag").unwrap(), "");
        assert_eq!(f.get("speed").unwrap(), "-5");
    }

    #[test]
    fn switch_followed_by_flag_stays_boolean() {
        let (f, _) = parse_flags(&argv(&["--prune", "--isl", "4000", "--full"]));
        assert_eq!(f.get("prune").unwrap(), "true");
        assert_eq!(f.get("isl").unwrap(), "4000");
        assert_eq!(f.get("full").unwrap(), "true");
    }

    #[test]
    fn space_flag_table_drives_list_overrides() {
        // One table, one list grammar: the same machinery parses every
        // list-valued option (the pre-topo code re-implemented the
        // comma grammar per flag).
        let model = by_name("llama3.1-8b").unwrap();
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let mut f = HashMap::new();
        f.insert("max-num-tokens".to_string(), "2048, 4096".to_string());
        f.insert("kv-frac".to_string(), "0.85".to_string());
        f.insert("cuda-graph".to_string(), "both".to_string());
        apply_space_flags(&mut space, &f).unwrap();
        assert_eq!(space.max_num_tokens, vec![2048, 4096]);
        assert_eq!(space.kv_frac, vec![0.85]);
        assert_eq!(space.cuda_graph, vec![true, false]);
        // Bad values stay loud errors through the table.
        let mut bad = HashMap::new();
        bad.insert("kv-frac".to_string(), "1.5".to_string());
        assert!(apply_space_flags(&mut space, &bad).is_err());
        let mut empty = HashMap::new();
        empty.insert("max-num-tokens".to_string(), " , ".to_string());
        assert!(apply_space_flags(&mut space, &empty).is_err());
    }

    #[test]
    fn parse_list_trims_and_rejects_empty() {
        let v = parse_list("a, b ,c", "x", |s| Ok(s.to_string())).unwrap();
        assert_eq!(v, vec!["a", "b", "c"]);
        assert!(parse_list("", "x", |s| Ok(s.to_string())).is_err());
    }

    #[test]
    fn traffic_flag_parsing() {
        let mk = |pairs: &[(&str, &str)]| -> HashMap<String, String> {
            pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
        };
        let m = parse_traffic(&mk(&[
            ("traffic", "diurnal"),
            ("peak-qps", "200"),
            ("trough-qps", "20"),
        ]))
        .unwrap();
        assert_eq!(
            m,
            TrafficModel::Diurnal { peak_qps: 200.0, trough_qps: 20.0, period_h: 24.0 }
        );
        let m = parse_traffic(&mk(&[
            ("traffic", "bursty"),
            ("base-qps", "30"),
            ("burst-qps", "300"),
        ]))
        .unwrap();
        assert_eq!(
            m,
            TrafficModel::Bursty { base_qps: 30.0, burst_qps: 300.0, burst_prob: 0.15, seed: 7 }
        );
        // Missing required knobs and unknown kinds are clean errors.
        assert!(parse_traffic(&mk(&[("traffic", "diurnal")])).is_err());
        assert!(parse_traffic(&mk(&[("traffic", "ramp"), ("start-qps", "1")])).is_err());
        assert!(parse_traffic(&mk(&[("traffic", "square")])).is_err());
        assert!(parse_traffic(&mk(&[])).is_err());
    }
}

//! Fidelity metrics (paper §5): MAPE, Pearson correlation, banded MAPE
//! (the 25–50 tokens/s/user interactive region of Fig 7).
//!
//! This module is one half of the crate's metrics story, and the two
//! halves deliberately stay separate (DESIGN.md §12):
//!
//! * **Fidelity** (here) — pure math over prediction/truth pairs,
//!   answering "how close is the model to the hardware". No state, no
//!   atomics; callers own the sample vectors.
//! * **Operational** ([`crate::service::stats`]) — lock-free runtime
//!   counters behind the serving path's `stats` op and its
//!   Prometheus-style `metrics_text` (request rates, latency
//!   histograms, cache/coalescing gauges, `aiconf_span_*` trace
//!   rollups).
//!
//! [`ServiceStats`] is re-exported here so "the metrics surface" is one
//! import path even though the implementations live where they are
//! used.

pub use crate::service::stats::{CacheGauges, PoolGauges, ServiceStats};

/// Mean Absolute Percentage Error between predictions and ground truth.
/// Pairs with non-positive truth are skipped.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut sum = 0.0;
    let mut n = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        if *t > 0.0 {
            sum += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * sum / n as f64
    }
}

/// Pearson correlation coefficient r.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// MAPE restricted to samples whose `band_key` lies in [lo, hi]
/// (e.g. Fig 7's 25–50 tokens/s/user interactive region).
pub fn banded_mape(pred: &[f64], truth: &[f64], band_key: &[f64], lo: f64, hi: f64) -> f64 {
    let mut p = Vec::new();
    let mut t = Vec::new();
    for i in 0..pred.len() {
        if (lo..=hi).contains(&band_key[i]) {
            p.push(pred[i]);
            t.push(truth[i]);
        }
    }
    mape(&p, &t)
}

/// A (prediction, truth) accumulator for fidelity reports.
#[derive(Clone, Debug, Default)]
pub struct FidelitySet {
    pub pred: Vec<f64>,
    pub truth: Vec<f64>,
}

impl FidelitySet {
    pub fn push(&mut self, pred: f64, truth: f64) {
        self.pred.push(pred);
        self.truth.push(truth);
    }

    pub fn mape(&self) -> f64 {
        mape(&self.pred, &self.truth)
    }

    pub fn r(&self) -> f64 {
        pearson(&self.pred, &self.truth)
    }

    pub fn len(&self) -> usize {
        self.pred.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pred.is_empty()
    }

    /// Drop pairs whose truth exceeds `cap` (the paper filters
    /// TTFT > 1000 ms as pathological queuing outliers).
    pub fn filtered(&self, cap: f64) -> FidelitySet {
        let mut out = FidelitySet::default();
        for (p, t) in self.pred.iter().zip(&self.truth) {
            if *t <= cap {
                out.push(*p, *t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mape_basics() {
        assert_eq!(mape(&[110.0], &[100.0]), 10.0);
        assert_eq!(mape(&[90.0, 110.0], &[100.0, 100.0]), 10.0);
        assert_eq!(mape(&[], &[]), 0.0);
        // zero-truth pairs skipped
        assert_eq!(mape(&[5.0, 110.0], &[0.0, 100.0]), 10.0);
    }

    #[test]
    fn pearson_basics() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn banded() {
        let pred = [10.0, 20.0, 30.0];
        let truth = [10.0, 10.0, 10.0];
        let key = [1.0, 5.0, 9.0];
        // only the middle sample is in [4, 6]
        assert_eq!(banded_mape(&pred, &truth, &key, 4.0, 6.0), 100.0);
    }

    #[test]
    fn fidelity_set_filter() {
        let mut f = FidelitySet::default();
        f.push(100.0, 90.0);
        f.push(5000.0, 4000.0); // outlier
        let g = f.filtered(1000.0);
        assert_eq!(g.len(), 1);
        assert!(g.mape() > 0.0);
    }
}

//! Model architecture registry (paper §4.4 "popular open-weights models").
//!
//! Performance modeling needs only architecture *shapes* — layer counts,
//! hidden sizes, attention layout (MHA/GQA/MLA), MoE expert geometry —
//! never weights. All numbers below are the public configs of the models
//! the paper evaluates (Qwen3-32B, Qwen3-235B-A22B, DeepSeek-V3,
//! Llama3.1-8B) plus the other families the PerfDatabase covers
//! (Mixtral, GPT-OSS).

pub mod presets;

pub use presets::{by_name, list_names};

/// Numeric formats the operator database is parameterized over.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    Fp16,
    Fp8,
    Int8,
    Int4,
}

impl Dtype {
    /// Bytes per element (Int4 is 0.5 — use [`Dtype::bits`] for exact math).
    pub fn bytes(self) -> f64 {
        self.bits() as f64 / 8.0
    }

    pub fn bits(self) -> u32 {
        match self {
            Dtype::Fp16 => 16,
            Dtype::Fp8 | Dtype::Int8 => 8,
            Dtype::Int4 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::Fp16 => "fp16",
            Dtype::Fp8 => "fp8",
            Dtype::Int8 => "int8",
            Dtype::Int4 => "int4",
        }
    }

    pub fn parse(s: &str) -> Option<Dtype> {
        match s.to_ascii_lowercase().as_str() {
            "fp16" | "bf16" | "half" => Some(Dtype::Fp16),
            "fp8" | "e4m3" => Some(Dtype::Fp8),
            "int8" => Some(Dtype::Int8),
            "int4" | "w4" | "awq" => Some(Dtype::Int4),
            _ => None,
        }
    }
}

/// Attention family — determines both compute shape and KV-cache layout.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttnKind {
    /// Multi-head attention: `kv_heads == heads`.
    Mha,
    /// Grouped-query attention with `kv_heads` KV groups.
    Gqa,
    /// Multi-head latent attention (DeepSeek): KV compressed into a
    /// latent of `kv_lora_rank` (+ decoupled RoPE dim).
    Mla {
        q_lora_rank: u64,
        kv_lora_rank: u64,
        qk_rope_dim: u64,
        qk_nope_dim: u64,
        v_head_dim: u64,
    },
}

/// Mixture-of-experts geometry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MoeConfig {
    pub num_experts: u64,
    pub top_k: u64,
    /// Per-expert FFN intermediate size.
    pub expert_inter: u64,
    /// Shared-expert intermediate size (0 = none).
    pub shared_inter: u64,
    /// Leading dense layers (DeepSeek-V3 has 3).
    pub first_dense_layers: u64,
    /// Power-law skew α observed for this model's routing (paper §4.4.1;
    /// Qwen3-235B ≈ 1.2 → 20% of experts take ~70% of tokens).
    pub load_alpha: f64,
}

/// A transformer architecture, sufficient for operator decomposition.
#[derive(Clone, Debug)]
pub struct ModelArch {
    pub name: &'static str,
    pub num_layers: u64,
    pub hidden: u64,
    pub heads: u64,
    pub kv_heads: u64,
    pub head_dim: u64,
    /// Dense-FFN intermediate size (used by dense layers).
    pub inter: u64,
    pub vocab: u64,
    pub attn: AttnKind,
    pub moe: Option<MoeConfig>,
}

impl ModelArch {
    pub fn is_moe(&self) -> bool {
        self.moe.is_some()
    }

    /// Attention weight parameters per layer.
    pub fn attn_params_per_layer(&self) -> u64 {
        match self.attn {
            AttnKind::Mha | AttnKind::Gqa => {
                let q = self.hidden * self.heads * self.head_dim;
                let kv = 2 * self.hidden * self.kv_heads * self.head_dim;
                let o = self.heads * self.head_dim * self.hidden;
                q + kv + o
            }
            AttnKind::Mla {
                q_lora_rank,
                kv_lora_rank,
                qk_rope_dim,
                qk_nope_dim,
                v_head_dim,
            } => {
                let q_dim = qk_nope_dim + qk_rope_dim;
                let q = self.hidden * q_lora_rank + q_lora_rank * self.heads * q_dim;
                let kv_down = self.hidden * (kv_lora_rank + qk_rope_dim);
                let kv_up = kv_lora_rank * self.heads * (qk_nope_dim + v_head_dim);
                let o = self.heads * v_head_dim * self.hidden;
                q + kv_down + kv_up + o
            }
        }
    }

    /// FFN weight parameters for layer `l` (gated SwiGLU: 3 matrices).
    pub fn ffn_params_layer(&self, l: u64) -> u64 {
        match &self.moe {
            Some(moe) if l >= moe.first_dense_layers => {
                moe.num_experts * 3 * self.hidden * moe.expert_inter
                    + 3 * self.hidden * moe.shared_inter
            }
            _ => 3 * self.hidden * self.inter,
        }
    }

    /// Total parameter count (weights only; norms/bias negligible).
    pub fn total_params(&self) -> u64 {
        let embed = 2 * self.vocab * self.hidden; // in + lm_head
        let per_layer_attn = self.attn_params_per_layer();
        let ffn: u64 = (0..self.num_layers).map(|l| self.ffn_params_layer(l)).sum();
        embed + self.num_layers * per_layer_attn + ffn
    }

    /// Active parameters per token (MoE models activate top_k experts).
    pub fn active_params(&self) -> u64 {
        match &self.moe {
            None => self.total_params(),
            Some(moe) => {
                let embed = 2 * self.vocab * self.hidden;
                let attn = self.num_layers * self.attn_params_per_layer();
                let dense = moe.first_dense_layers * 3 * self.hidden * self.inter;
                let active_moe = (self.num_layers - moe.first_dense_layers)
                    * (moe.top_k * 3 * self.hidden * moe.expert_inter
                        + 3 * self.hidden * moe.shared_inter);
                embed + attn + dense + active_moe
            }
        }
    }

    /// KV-cache bytes per token per layer (full model, before TP/PP split).
    pub fn kv_bytes_per_token_layer(&self, kv_dtype: Dtype) -> f64 {
        match self.attn {
            AttnKind::Mha | AttnKind::Gqa => {
                (2 * self.kv_heads * self.head_dim) as f64 * kv_dtype.bytes()
            }
            AttnKind::Mla {
                kv_lora_rank,
                qk_rope_dim,
                ..
            } => (kv_lora_rank + qk_rope_dim) as f64 * kv_dtype.bytes(),
        }
    }

    /// KV-cache bytes per token for the whole model.
    pub fn kv_bytes_per_token(&self, kv_dtype: Dtype) -> f64 {
        self.num_layers as f64 * self.kv_bytes_per_token_layer(kv_dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_in_published_ballpark() {
        // (name, expected total params in B, tolerance in B)
        for (name, want, tol) in [
            ("llama3.1-8b", 8.0, 1.0),
            ("qwen3-32b", 32.0, 4.0),
            ("qwen3-235b", 235.0, 25.0),
            ("deepseek-v3", 671.0, 70.0),
            ("mixtral-8x7b", 47.0, 6.0),
            ("gpt-oss-120b", 117.0, 20.0),
        ] {
            let m = by_name(name).unwrap();
            let got = m.total_params() as f64 / 1e9;
            assert!(
                (got - want).abs() < tol,
                "{name}: got {got:.1}B params, want ~{want}B"
            );
        }
    }

    #[test]
    fn active_params_moe() {
        let m = by_name("qwen3-235b").unwrap();
        let active = m.active_params() as f64 / 1e9;
        // Qwen3-235B-A22B: ~22B active.
        assert!((active - 22.0).abs() < 4.0, "active={active:.1}B");
        // Dense model: active == total.
        let d = by_name("qwen3-32b").unwrap();
        assert_eq!(d.active_params(), d.total_params());
    }

    #[test]
    fn kv_bytes_gqa_vs_mla() {
        let gqa = by_name("qwen3-32b").unwrap();
        // 8 kv heads * 128 dim * 2 (K+V) * 2 bytes = 4096 B/token/layer.
        assert_eq!(gqa.kv_bytes_per_token_layer(Dtype::Fp16), 4096.0);
        let mla = by_name("deepseek-v3").unwrap();
        // MLA latent: (512 + 64) * 2 bytes = 1152 — far smaller than GQA
        // would be at 128 heads.
        assert_eq!(mla.kv_bytes_per_token_layer(Dtype::Fp16), 1152.0);
    }

    #[test]
    fn registry_lookup() {
        assert!(by_name("nope").is_none());
        assert!(list_names().len() >= 6);
        for n in list_names() {
            assert!(by_name(n).is_some());
        }
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("FP8"), Some(Dtype::Fp8));
        assert_eq!(Dtype::parse("bf16"), Some(Dtype::Fp16));
        assert_eq!(Dtype::parse("w4"), Some(Dtype::Int4));
        assert_eq!(Dtype::parse("fp64"), None);
        assert_eq!(Dtype::Int4.bytes(), 0.5);
    }
}

//! Public architecture configs for the models the paper evaluates.

use super::{AttnKind, ModelArch, MoeConfig};

/// Llama 3.1 8B — dense GQA (paper Table 1).
pub fn llama3_1_8b() -> ModelArch {
    ModelArch {
        name: "llama3.1-8b",
        num_layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        inter: 14336,
        vocab: 128256,
        attn: AttnKind::Gqa,
        moe: None,
    }
}

/// Qwen3 32B — dense GQA (paper §5.1, §5.4).
pub fn qwen3_32b() -> ModelArch {
    ModelArch {
        name: "qwen3-32b",
        num_layers: 64,
        hidden: 5120,
        heads: 64,
        kv_heads: 8,
        head_dim: 128,
        inter: 25600,
        vocab: 151936,
        attn: AttnKind::Gqa,
        moe: None,
    }
}

/// Qwen3 235B-A22B — 128-expert MoE, top-8 (paper §5.1, Fig 1).
/// Routing skew α≈1.2: "~70% of compute is handled by only 20% of
/// active experts" (§4.4.1).
pub fn qwen3_235b() -> ModelArch {
    ModelArch {
        name: "qwen3-235b",
        num_layers: 94,
        hidden: 4096,
        heads: 64,
        kv_heads: 4,
        head_dim: 128,
        inter: 12288,
        vocab: 151936,
        attn: AttnKind::Gqa,
        moe: Some(MoeConfig {
            num_experts: 128,
            top_k: 8,
            expert_inter: 1536,
            shared_inter: 0,
            first_dense_layers: 0,
            load_alpha: 1.2,
        }),
    }
}

/// DeepSeek-V3 671B — MLA + 256-expert MoE top-8 + shared expert
/// (paper §5.2, Fig 7).
pub fn deepseek_v3() -> ModelArch {
    ModelArch {
        name: "deepseek-v3",
        num_layers: 61,
        hidden: 7168,
        heads: 128,
        kv_heads: 128,
        head_dim: 128,
        inter: 18432,
        vocab: 129280,
        attn: AttnKind::Mla {
            q_lora_rank: 1536,
            kv_lora_rank: 512,
            qk_rope_dim: 64,
            qk_nope_dim: 128,
            v_head_dim: 128,
        },
        moe: Some(MoeConfig {
            num_experts: 256,
            top_k: 8,
            expert_inter: 2048,
            shared_inter: 2048,
            first_dense_layers: 3,
            load_alpha: 1.1,
        }),
    }
}

/// Mixtral 8x7B — 8-expert MoE top-2.
pub fn mixtral_8x7b() -> ModelArch {
    ModelArch {
        name: "mixtral-8x7b",
        num_layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 8,
        head_dim: 128,
        inter: 14336,
        vocab: 32000,
        attn: AttnKind::Gqa,
        moe: Some(MoeConfig {
            num_experts: 8,
            top_k: 2,
            expert_inter: 14336,
            shared_inter: 0,
            first_dense_layers: 0,
            load_alpha: 0.6,
        }),
    }
}

/// GPT-OSS 120B — 128-expert MoE top-4.
pub fn gpt_oss_120b() -> ModelArch {
    ModelArch {
        name: "gpt-oss-120b",
        num_layers: 36,
        hidden: 2880,
        heads: 64,
        kv_heads: 8,
        head_dim: 64,
        inter: 2880,
        vocab: 201088,
        attn: AttnKind::Gqa,
        moe: Some(MoeConfig {
            num_experts: 128,
            top_k: 4,
            expert_inter: 2880,
            shared_inter: 0,
            first_dense_layers: 0,
            load_alpha: 0.9,
        }),
    }
}

/// Look up a model by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<ModelArch> {
    match name.to_ascii_lowercase().as_str() {
        "llama3.1-8b" | "llama3-8b" | "llama" => Some(llama3_1_8b()),
        "qwen3-32b" => Some(qwen3_32b()),
        "qwen3-235b" | "qwen3-235b-a22b" => Some(qwen3_235b()),
        "deepseek-v3" | "dsv3" => Some(deepseek_v3()),
        "mixtral-8x7b" | "mixtral" => Some(mixtral_8x7b()),
        "gpt-oss-120b" | "gpt-oss" => Some(gpt_oss_120b()),
        _ => None,
    }
}

/// Canonical registry names.
pub fn list_names() -> &'static [&'static str] {
    &[
        "llama3.1-8b",
        "qwen3-32b",
        "qwen3-235b",
        "deepseek-v3",
        "mixtral-8x7b",
        "gpt-oss-120b",
    ]
}

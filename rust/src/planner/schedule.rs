//! Schedule optimization: pick a replica count of one deployment option
//! per traffic window at minimum total cost.
//!
//! Windows are independent (no switching cost is modeled — replica
//! counts change at window boundaries the way autoscalers re-target),
//! so the globally cost-minimal schedule decomposes into per-window
//! argmins; [`optimize`] is therefore *exact*, and the brute-force
//! enumeration in the tests only exists to pin that fact. Ties break
//! deterministically toward the earliest option in input order, which
//! is also what makes the pruned planner bit-identical to the
//! exhaustive one (see [`super::options`]).

use super::options::PricedOption;

/// Replicas of an option needed to serve `demand_qps` (ceiling; 0 when
/// there is no demand — scale-to-zero). `None` when the count would
/// overflow the u32 replica granularity — the option simply cannot
/// serve that demand (a saturating cast here would silently report an
/// under-provisioned schedule as feasible).
pub fn replicas_needed(demand_qps: f64, qps_per_unit: f64) -> Option<u32> {
    if demand_qps <= 0.0 {
        return Some(0);
    }
    debug_assert!(qps_per_unit > 0.0);
    let n = (demand_qps / qps_per_unit).ceil();
    if n.is_finite() && n <= u32::MAX as f64 {
        Some(n as u32)
    } else {
        None
    }
}

/// One window's decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowChoice {
    /// Index into the option slice handed to [`optimize`].
    pub option: usize,
    pub replicas: u32,
    pub cost_usd: f64,
}

/// A full schedule over the horizon. `choices[w]` is `None` when no
/// option can serve window `w` under the GPU cap.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub choices: Vec<Option<WindowChoice>>,
    /// Sum over the served windows.
    pub total_cost_usd: f64,
}

/// Cheapest (option, replicas) for one window, scanning options in
/// order and keeping the first strict improvement. `max_gpus` caps the
/// window's total GPU footprint (options needing more are skipped).
pub fn choose_window(
    options: &[PricedOption],
    demand_qps: f64,
    window_h: f64,
    max_gpus: Option<u32>,
) -> Option<WindowChoice> {
    let mut best: Option<WindowChoice> = None;
    for (i, o) in options.iter().enumerate() {
        let Some(n) = replicas_needed(demand_qps, o.qps_per_unit) else {
            continue; // demand beyond this option's replica range
        };
        if let Some(cap) = max_gpus {
            if n as u64 * o.unit_gpus as u64 > cap as u64 {
                continue;
            }
        }
        let cost = n as f64 * o.usd_per_hour * window_h;
        let improves = match best {
            Some(b) => cost < b.cost_usd,
            None => true,
        };
        if improves {
            best = Some(WindowChoice { option: i, replicas: n, cost_usd: cost });
        }
    }
    best
}

/// Exact min-cost schedule for a demand curve (one [`choose_window`]
/// per window).
pub fn optimize(
    options: &[PricedOption],
    demands_qps: &[f64],
    window_h: f64,
    max_gpus: Option<u32>,
) -> Schedule {
    let choices: Vec<Option<WindowChoice>> = demands_qps
        .iter()
        .map(|&d| choose_window(options, d, window_h, max_gpus))
        .collect();
    let total_cost_usd = choices.iter().flatten().map(|c| c.cost_usd).sum();
    Schedule { choices, total_cost_usd }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::options::prune_options;
    use crate::planner::testutil::opt;
    use crate::util::rng::Rng;

    #[test]
    fn replica_ceiling() {
        assert_eq!(replicas_needed(0.0, 5.0), Some(0));
        assert_eq!(replicas_needed(-1.0, 5.0), Some(0));
        assert_eq!(replicas_needed(0.1, 5.0), Some(1));
        assert_eq!(replicas_needed(5.0, 5.0), Some(1));
        assert_eq!(replicas_needed(5.01, 5.0), Some(2));
        assert_eq!(replicas_needed(50.0, 5.0), Some(10));
        // Beyond u32 granularity: infeasible, never a saturated count.
        assert_eq!(replicas_needed(1e18, 1e-6), None);
        assert_eq!(replicas_needed(u32::MAX as f64 * 10.0, 1.0), None);
    }

    #[test]
    fn window_picks_min_cost_with_ceiling_effects() {
        // big: $10/h serves 10 QPS; small: $3/h serves 2 QPS. At 10 QPS
        // the big unit wins (10 vs 5×3=15); at 1 QPS the small one
        // (3 vs 10) — the ceiling is what makes both survive.
        let opts = vec![opt("big", 8, 10.0, 10.0, 30.0), opt("small", 1, 3.0, 2.0, 30.0)];
        let hi = choose_window(&opts, 10.0, 1.0, None).unwrap();
        assert_eq!((hi.option, hi.replicas, hi.cost_usd), (0, 1, 10.0));
        let lo = choose_window(&opts, 1.0, 1.0, None).unwrap();
        assert_eq!((lo.option, lo.replicas, lo.cost_usd), (1, 1, 3.0));
        // GPU cap can forbid the big option.
        let capped = choose_window(&opts, 10.0, 1.0, Some(6)).unwrap();
        assert_eq!((capped.option, capped.replicas, capped.cost_usd), (1, 5, 15.0));
        // An impossible cap yields no choice.
        assert!(choose_window(&opts, 10.0, 1.0, Some(0)).is_none());
    }

    /// The acceptance pin: a mixed-GPU schedule strictly beats the best
    /// single-GPU-type schedule on a diurnal-style two-level demand.
    #[test]
    fn heterogeneous_fleet_beats_best_homogeneous_pinned() {
        let opts = vec![opt("h100", 8, 10.0, 10.0, 30.0), opt("a100", 1, 3.0, 2.0, 25.0)];
        let demands = [10.0, 1.0]; // peak window, trough window
        let het = optimize(&opts, &demands, 1.0, None);
        assert_eq!(het.total_cost_usd, 13.0); // 10 (h100 at peak) + 3 (a100 at trough)
        let homo_h100 = optimize(&opts[..1], &demands, 1.0, None);
        let homo_a100 = optimize(&opts[1..], &demands, 1.0, None);
        assert_eq!(homo_h100.total_cost_usd, 20.0);
        assert_eq!(homo_a100.total_cost_usd, 18.0); // 5×3 + 1×3
        assert!(het.total_cost_usd < homo_h100.total_cost_usd.min(homo_a100.total_cost_usd));
        // And the schedule really mixes GPU types across windows.
        let gpus: Vec<&str> = het
            .choices
            .iter()
            .map(|c| opts[c.unwrap().option].gpu.as_str())
            .collect();
        assert_eq!(gpus, vec!["h100", "a100"]);
    }

    #[test]
    fn zero_demand_windows_cost_nothing() {
        let opts = vec![opt("g", 1, 2.0, 4.0, 20.0)];
        let s = optimize(&opts, &[0.0, 8.0, 0.0], 2.0, None);
        let c: Vec<(u32, f64)> =
            s.choices.iter().map(|x| (x.unwrap().replicas, x.unwrap().cost_usd)).collect();
        assert_eq!(c, vec![(0, 0.0), (2, 8.0), (0, 0.0)]);
        assert_eq!(s.total_cost_usd, 8.0);
    }

    /// Brute-force pin of optimality AND prune-transparency: on random
    /// small grids, (a) every per-window choice is the true argmin over
    /// all (option, minimal-replica) pairs, and (b) optimizing over the
    /// k-frontier-pruned option subset returns the *same* schedule —
    /// same original options, same replicas, same cost.
    #[test]
    fn pruned_schedule_matches_exhaustive_bruteforce_on_random_grids() {
        let mut rng = Rng::new(0x9_1A7);
        for case in 0..200 {
            let n_opts = 1 + rng.below(12) as usize;
            let opts: Vec<PricedOption> = (0..n_opts)
                .map(|i| {
                    // Coarse values force cost/capacity ties.
                    opt(
                        if i % 2 == 0 { "h100" } else { "a100" },
                        1 + rng.below(8) as u32,
                        1.0 + rng.below(6) as f64 * 2.0,
                        1.0 + rng.below(6) as f64 * 3.0,
                        10.0 + rng.below(4) as f64 * 10.0,
                    )
                })
                .collect();
            let windows = 1 + rng.below(6) as usize;
            let demands: Vec<f64> =
                (0..windows).map(|_| rng.below(40) as f64).collect();
            let window_h = 0.5 + rng.f64();
            let cap = if rng.below(3) == 0 { Some(8 + rng.below(40) as u32) } else { None };

            let full = optimize(&opts, &demands, window_h, cap);
            // (a) brute force: scan every option for every window.
            for (w, &d) in demands.iter().enumerate() {
                let mut best: Option<(usize, u32, f64)> = None;
                for (i, o) in opts.iter().enumerate() {
                    let Some(n) = replicas_needed(d, o.qps_per_unit) else { continue };
                    if let Some(c) = cap {
                        if n as u64 * o.unit_gpus as u64 > c as u64 {
                            continue;
                        }
                    }
                    let cost = n as f64 * o.usd_per_hour * window_h;
                    let improves = match best {
                        Some((_, _, b)) => cost < b,
                        None => true,
                    };
                    if improves {
                        best = Some((i, n, cost));
                    }
                }
                match (best, full.choices[w]) {
                    (None, None) => {}
                    (Some((i, n, c)), Some(ch)) => {
                        assert_eq!((i, n), (ch.option, ch.replicas), "case {case} w{w}");
                        assert_eq!(c, ch.cost_usd, "case {case} w{w}");
                    }
                    other => panic!("case {case} w{w}: {other:?}"),
                }
            }
            // (b) prune transparency: identical schedule through the
            // k-objective frontier subset.
            let kept = prune_options(&opts);
            let pruned_opts: Vec<PricedOption> =
                kept.iter().map(|&i| opts[i].clone()).collect();
            let pruned = optimize(&pruned_opts, &demands, window_h, cap);
            assert_eq!(pruned.total_cost_usd, full.total_cost_usd, "case {case}");
            for (w, (a, b)) in full.choices.iter().zip(&pruned.choices).enumerate() {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert_eq!(a.option, kept[b.option], "case {case} w{w}: option");
                        assert_eq!(a.replicas, b.replicas, "case {case} w{w}: replicas");
                        assert_eq!(a.cost_usd, b.cost_usd, "case {case} w{w}: cost");
                    }
                    other => panic!("case {case} w{w}: {other:?}"),
                }
            }
        }
    }
}

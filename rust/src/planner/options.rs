//! Deployment options: the supply side of the capacity planner.
//!
//! A [`PricedOption`] is one *unit* of deployable capacity — a single
//! engine replica (aggregated) or one whole (x)P(y)D composite
//! (disaggregated) — priced in $/hour from the GPU preset's
//! `usd_per_hour` and rated in sustainable queries/s from the sweep
//! engine's throughput estimate. The planner scales units per window,
//! so options from different GPU types mix freely in one schedule.
//!
//! [`prune_options`] discards options that can never appear in a
//! cost-minimal schedule using the k-objective
//! [`crate::pareto::FrontierAccumulator`] over
//! (−$/hour, capacity, speed, −GPU footprint). The drop is *provably*
//! safe under the ceiling replica count: if A costs no more per unit
//! and serves no fewer QPS per unit than B, then for every demand d,
//! `ceil(d/cap_A) ≤ ceil(d/cap_B)` and so
//! `ceil(d/cap_A)·cost_A ≤ ceil(d/cap_B)·cost_B` — A's window cost
//! never exceeds B's. The footprint objective makes the same argument
//! hold under a per-window GPU cap (A's footprint
//! `n_A·gpus_A ≤ n_B·gpus_B` stays cap-feasible whenever B's was), and
//! the speed objective only ever *keeps more* options. The pruned
//! planner therefore returns exactly the schedule exhaustive
//! enumeration finds (regression-tested).

use crate::config::{Candidate, WorkloadSpec};
use crate::hardware::GpuSpec;
use crate::pareto::FrontierAccumulator;
use crate::perfmodel::PerfEstimate;
use crate::search::SearchReport;

/// One unit of deployable, SLA-feasible capacity.
#[derive(Clone, Debug)]
pub struct PricedOption {
    /// GPU preset name (the fleet leg this option deploys on).
    pub gpu: String,
    /// The deployment unit: aggregated candidates are normalized to
    /// **one** engine replica; disaggregated candidates are one whole
    /// (x)P(y)D composite.
    pub cand: Candidate,
    /// GPUs per unit.
    pub unit_gpus: u32,
    /// $/hour per unit (unit_gpus × the GPU's list price).
    pub usd_per_hour: f64,
    /// Sustainable request rate per unit, queries/s
    /// (tokens/s/GPU × unit GPUs ÷ OSL tokens/request).
    pub qps_per_unit: f64,
    /// The sweep engine's per-request projection (replica-invariant).
    pub est: PerfEstimate,
}

impl PricedOption {
    /// The planner's maximized objectives: (−cost/h, capacity, speed,
    /// −GPU footprint). The footprint coordinate exists for the GPU-cap
    /// safety argument (module docs); within one GPU type it is
    /// redundant with cost, across types it is not.
    pub fn objectives(&self) -> [f64; 4] {
        [-self.usd_per_hour, self.qps_per_unit, self.est.speed, -(self.unit_gpus as f64)]
    }
}

/// Extract the SLA-feasible options of one fleet leg from a sweep
/// report (which must be **unpruned**: the engine's 2-objective
/// (speed, thru) in-sweep pruning is not cost-aware, so a cheaper
/// small-footprint option could be lost). Order follows the report.
pub fn options_from_report(
    gpu: &GpuSpec,
    wl: &WorkloadSpec,
    report: &SearchReport,
) -> Vec<PricedOption> {
    let mut out = Vec::new();
    for e in &report.evaluated {
        if !e.est.meets(&wl.sla) {
            continue;
        }
        let unit = match &e.cand {
            Candidate::Aggregated { engine, .. } => {
                Candidate::Aggregated { engine: *engine, replicas: 1 }
            }
            disagg => disagg.clone(),
        };
        let unit_gpus = unit.total_gpus();
        if unit_gpus == 0 || wl.osl == 0 {
            continue;
        }
        let qps = e.est.thru_per_gpu * unit_gpus as f64 / wl.osl as f64;
        if !qps.is_finite() || qps <= 0.0 {
            continue;
        }
        out.push(PricedOption {
            gpu: gpu.name.to_string(),
            cand: unit,
            unit_gpus,
            usd_per_hour: unit_gpus as f64 * gpu.usd_per_hour,
            qps_per_unit: qps,
            est: e.est,
        });
    }
    out
}

/// Indices of the options surviving the k-objective frontier prune, in
/// input order. Mirrors the sweep engine's accumulator discipline:
/// members later evicted from the running frontier stay *kept* (they
/// were non-dominated when offered), which is exactly what makes the
/// exhaustive argmin always survive — see the module docs for the
/// proof sketch.
pub fn prune_options(options: &[PricedOption]) -> Vec<usize> {
    let mut acc = FrontierAccumulator::new();
    let mut kept = Vec::new();
    for (i, o) in options.iter().enumerate() {
        if acc.offer_point(&o.objectives()) {
            kept.push(i);
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags, Sla};
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::Dtype;
    use crate::search::runner::Evaluated;

    fn engine(tp: u32, batch: u32) -> EngineConfig {
        EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(tp),
            batch,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        }
    }

    fn evaluated(tp: u32, replicas: u32, thru: f64, speed: f64, ttft: f64) -> Evaluated {
        Evaluated {
            cand: Candidate::Aggregated { engine: engine(tp, 16), replicas },
            est: PerfEstimate {
                ttft_ms: ttft,
                tpot_ms: 1000.0 / speed,
                speed,
                thru_per_gpu: thru,
                concurrency: 16,
            },
        }
    }

    fn report(evs: Vec<Evaluated>) -> SearchReport {
        SearchReport {
            configs_priced: evs.len(),
            flag_summaries: crate::search::flag_summaries(&evs),
            evaluated: evs,
            pruned: 0,
            elapsed_s: 0.0,
            median_config_ms: 0.0,
            tier_counts: None,
        }
    }

    #[test]
    fn units_are_single_replicas_priced_by_footprint() {
        let gpu = h100_sxm();
        let wl = WorkloadSpec {
            model: "llama3.1-8b".into(),
            isl: 1024,
            osl: 100,
            prefix: 0,
            sla: Sla { ttft_ms: 1000.0, min_speed: 10.0 },
        };
        // 8 replicas of a TP1 engine at 500 tok/s/GPU: the unit is ONE
        // replica — 1 GPU, 500/100 = 5 QPS, one GPU-hour of cost.
        let r = report(vec![
            evaluated(1, 8, 500.0, 20.0, 500.0),
            evaluated(4, 2, 300.0, 40.0, 300.0),
            evaluated(1, 8, 500.0, 20.0, 2000.0), // TTFT violates SLA
        ]);
        let opts = options_from_report(&gpu, &wl, &r);
        assert_eq!(opts.len(), 2, "SLA filter must drop the slow option");
        assert_eq!(opts[0].unit_gpus, 1);
        assert!(matches!(opts[0].cand, Candidate::Aggregated { replicas: 1, .. }));
        assert!((opts[0].qps_per_unit - 5.0).abs() < 1e-9);
        assert_eq!(opts[0].usd_per_hour, gpu.usd_per_hour);
        // TP4 unit: 4 GPUs, 300·4/100 = 12 QPS, 4 GPU-hours of cost.
        assert_eq!(opts[1].unit_gpus, 4);
        assert!((opts[1].qps_per_unit - 12.0).abs() < 1e-9);
        assert_eq!(opts[1].usd_per_hour, 4.0 * gpu.usd_per_hour);
    }

    #[test]
    fn prune_keeps_cost_capacity_tradeoffs_drops_dominated() {
        let gpu = h100_sxm();
        let wl = WorkloadSpec {
            model: "llama3.1-8b".into(),
            isl: 1024,
            osl: 100,
            prefix: 0,
            sla: Sla { ttft_ms: f64::INFINITY, min_speed: 0.0 },
        };
        let r = report(vec![
            evaluated(1, 8, 500.0, 20.0, 500.0), // 1 GPU, 5 QPS
            evaluated(4, 2, 300.0, 20.0, 500.0), // 4 GPUs, 12 QPS — trade-off, kept
            evaluated(4, 2, 200.0, 20.0, 500.0), // 4 GPUs, 8 QPS — dominated by ↑
            evaluated(1, 8, 500.0, 20.0, 500.0), // exact duplicate of idx 0 — dropped
        ]);
        let opts = options_from_report(&gpu, &wl, &r);
        assert_eq!(opts.len(), 4);
        assert_eq!(prune_options(&opts), vec![0, 1]);
    }
}

//! Time-varying traffic models: the demand side of the capacity
//! planner. Each model turns a planning horizon of `windows` windows of
//! `window_h` hours into a deterministic per-window QPS curve; the
//! bursty model draws from [`crate::util::rng`] so every curve is
//! reproducible from its seed. [`TrafficModel::trace`] additionally
//! materializes the curve as an open-loop request trace
//! ([`crate::workload::piecewise_poisson`]) for simulator validation of
//! a planned schedule.

use crate::config::WorkloadSpec;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::workload::{self, Request};

/// A deterministic time-varying QPS model over the planning horizon.
#[derive(Clone, Debug, PartialEq)]
pub enum TrafficModel {
    /// Smooth day/night cycle: starts at `trough_qps`, peaks at
    /// `peak_qps` half a `period_h` in (raised-cosine shape).
    Diurnal { peak_qps: f64, trough_qps: f64, period_h: f64 },
    /// Linear ramp from `start_qps` (first window) to `end_qps` (last).
    Ramp { start_qps: f64, end_qps: f64 },
    /// Baseline load with randomly placed bursts: each window spikes to
    /// `burst_qps` with probability `burst_prob`, else runs at
    /// `base_qps`. Deterministic per `seed`.
    Bursty { base_qps: f64, burst_qps: f64, burst_prob: f64, seed: u64 },
}

/// The diurnal raised-cosine demand at `t_h` hours (one definition for
/// both the representative curve and the window-peak provisioning).
fn raised_cosine(peak: f64, trough: f64, period_h: f64, t_h: f64) -> f64 {
    let phase = 2.0 * std::f64::consts::PI * t_h / period_h.max(1e-9);
    trough + (peak - trough) * 0.5 * (1.0 - phase.cos())
}

impl TrafficModel {
    pub fn name(&self) -> &'static str {
        match self {
            TrafficModel::Diurnal { .. } => "diurnal",
            TrafficModel::Ramp { .. } => "ramp",
            TrafficModel::Bursty { .. } => "bursty",
        }
    }

    /// Demand at each window (evaluated at the window midpoint for the
    /// continuous models), queries/s.
    pub fn qps_curve(&self, windows: usize, window_h: f64) -> Vec<f64> {
        assert!(window_h > 0.0, "window length must be positive");
        match *self {
            TrafficModel::Diurnal { peak_qps, trough_qps, period_h } => (0..windows)
                .map(|i| raised_cosine(peak_qps, trough_qps, period_h, (i as f64 + 0.5) * window_h))
                .collect(),
            TrafficModel::Ramp { start_qps, end_qps } => (0..windows)
                .map(|i| {
                    if windows <= 1 {
                        start_qps
                    } else {
                        start_qps + (end_qps - start_qps) * i as f64 / (windows - 1) as f64
                    }
                })
                .collect(),
            TrafficModel::Bursty { base_qps, burst_qps, burst_prob, seed } => {
                let mut rng = Rng::new(seed);
                (0..windows)
                    .map(|_| if rng.f64() < burst_prob { burst_qps } else { base_qps })
                    .collect()
            }
        }
    }

    /// Per-window demand the planner must **provision** for: the
    /// maximum instantaneous demand inside each window, rather than
    /// the representative sample [`Self::qps_curve`] reports. A
    /// midpoint-provisioned rising window would run under-capacity at
    /// its edges. Closed forms per model:
    /// - diurnal: max of the window-edge samples, plus the crest value
    ///   `peak_qps` whenever a crest time (`period·(k + 1/2)`) falls
    ///   inside the window — exact for the raised cosine;
    /// - ramp: conservative neighbor-max of the window samples
    ///   (monotone between samples);
    /// - bursty: piecewise-constant, so the curve itself.
    pub fn qps_window_peak(&self, windows: usize, window_h: f64) -> Vec<f64> {
        match *self {
            TrafficModel::Diurnal { peak_qps, trough_qps, period_h } => {
                let period = period_h.max(1e-9);
                let at = |t_h: f64| raised_cosine(peak_qps, trough_qps, period_h, t_h);
                (0..windows)
                    .map(|i| {
                        let t0 = i as f64 * window_h;
                        let t1 = (i + 1) as f64 * window_h;
                        let mut m = at(t0).max(at(t1));
                        let k = (t0 / period - 0.5).ceil();
                        let crest = (k + 0.5) * period;
                        if crest <= t1 {
                            m = m.max(peak_qps);
                        }
                        m
                    })
                    .collect()
            }
            TrafficModel::Ramp { .. } => {
                let curve = self.qps_curve(windows, window_h);
                (0..windows).map(|i| curve[i].max(curve[(i + 1).min(windows - 1)])).collect()
            }
            TrafficModel::Bursty { .. } => self.qps_curve(windows, window_h),
        }
    }

    /// Materialize the curve as an open-loop Poisson trace (for
    /// validating a planned schedule against the ground-truth
    /// simulator).
    ///
    /// This is the **single** trace builder: both the planner-side
    /// tooling and the fleet replay ([`crate::fleetsim::replay`]) must
    /// come through here so a plan is always validated against traffic
    /// drawn from its own model. Delegates to
    /// [`workload::piecewise_poisson`]; `len_jitter` is that
    /// function's ±fraction uniform ISL/OSL jitter (0.2 ⇒ each
    /// request's lengths are drawn uniformly within ±20% of the
    /// workload's nominal lengths, floored at 1 token). Deterministic
    /// per `seed`.
    pub fn trace(
        &self,
        windows: usize,
        window_h: f64,
        wl: &WorkloadSpec,
        len_jitter: f64,
        seed: u64,
    ) -> Vec<Request> {
        let qps = self.qps_curve(windows, window_h);
        workload::piecewise_poisson(&qps, window_h * 3600.0, wl.isl, wl.osl, len_jitter, seed)
    }

    /// Parse from the JSON wire format, e.g.
    /// `{"kind": "diurnal", "peak_qps": 200, "trough_qps": 20, "period_h": 24}`,
    /// `{"kind": "ramp", "start_qps": 10, "end_qps": 300}`,
    /// `{"kind": "bursty", "base_qps": 40, "burst_qps": 400, "burst_prob": 0.2, "seed": 7}`.
    pub fn from_json(j: &Json) -> anyhow::Result<TrafficModel> {
        let kind = j.req_str("kind")?;
        let model = match kind {
            "diurnal" => TrafficModel::Diurnal {
                peak_qps: j.req_f64("peak_qps")?,
                trough_qps: j.f64_or("trough_qps", 0.0),
                period_h: j.f64_or("period_h", 24.0),
            },
            "ramp" => TrafficModel::Ramp {
                start_qps: j.req_f64("start_qps")?,
                end_qps: j.req_f64("end_qps")?,
            },
            "bursty" => {
                // The wire format carries numbers as f64, so only
                // integer seeds up to 2^53 survive the round-trip;
                // reject anything else rather than silently planning a
                // different curve than the client asked for.
                let seed = match j.get("seed") {
                    None => 7,
                    Some(v) => {
                        let f = v
                            .as_f64()
                            .ok_or_else(|| anyhow::anyhow!("bursty 'seed' must be a number"))?;
                        anyhow::ensure!(
                            f >= 0.0 && f.fract() == 0.0 && f <= 9_007_199_254_740_992.0,
                            "bursty 'seed' must be a non-negative integer ≤ 2^53 \
                             (JSON numbers are f64)"
                        );
                        f as u64
                    }
                };
                TrafficModel::Bursty {
                    base_qps: j.req_f64("base_qps")?,
                    burst_qps: j.req_f64("burst_qps")?,
                    burst_prob: j.f64_or("burst_prob", 0.15),
                    seed,
                }
            }
            other => anyhow::bail!("unknown traffic kind '{other}' (diurnal|ramp|bursty)"),
        };
        model.validate()?;
        Ok(model)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kind", json::s(self.name()));
        match *self {
            TrafficModel::Diurnal { peak_qps, trough_qps, period_h } => {
                o.set("peak_qps", json::num(peak_qps))
                    .set("trough_qps", json::num(trough_qps))
                    .set("period_h", json::num(period_h));
            }
            TrafficModel::Ramp { start_qps, end_qps } => {
                o.set("start_qps", json::num(start_qps)).set("end_qps", json::num(end_qps));
            }
            TrafficModel::Bursty { base_qps, burst_qps, burst_prob, seed } => {
                o.set("base_qps", json::num(base_qps))
                    .set("burst_qps", json::num(burst_qps))
                    .set("burst_prob", json::num(burst_prob))
                    .set("seed", json::num(seed as f64));
            }
        }
        o
    }

    /// Reject curves the planner can't mean anything sensible for.
    pub fn validate(&self) -> anyhow::Result<()> {
        let ok = |v: f64| v.is_finite() && v >= 0.0;
        match *self {
            TrafficModel::Diurnal { peak_qps, trough_qps, period_h } => {
                anyhow::ensure!(ok(peak_qps) && ok(trough_qps), "diurnal QPS must be ≥ 0");
                anyhow::ensure!(peak_qps >= trough_qps, "peak_qps must be ≥ trough_qps");
                anyhow::ensure!(period_h > 0.0, "period_h must be positive");
            }
            TrafficModel::Ramp { start_qps, end_qps } => {
                anyhow::ensure!(ok(start_qps) && ok(end_qps), "ramp QPS must be ≥ 0");
            }
            TrafficModel::Bursty { base_qps, burst_qps, burst_prob, .. } => {
                anyhow::ensure!(ok(base_qps) && ok(burst_qps), "bursty QPS must be ≥ 0");
                anyhow::ensure!(
                    (0.0..=1.0).contains(&burst_prob),
                    "burst_prob must be in [0, 1]"
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_stays_in_band_and_peaks_mid_period() {
        let m = TrafficModel::Diurnal { peak_qps: 200.0, trough_qps: 20.0, period_h: 24.0 };
        let q = m.qps_curve(24, 1.0);
        assert_eq!(q.len(), 24);
        assert!(q.iter().all(|&v| (20.0..=200.0).contains(&v)));
        // First window sits near the trough, the mid-period window near
        // the peak.
        assert!(q[0] < 30.0, "q0={}", q[0]);
        assert!(q[11] > 190.0 || q[12] > 190.0, "midday {} {}", q[11], q[12]);
    }

    #[test]
    fn ramp_hits_both_endpoints() {
        let m = TrafficModel::Ramp { start_qps: 10.0, end_qps: 110.0 };
        let q = m.qps_curve(11, 2.0);
        assert_eq!(q[0], 10.0);
        assert_eq!(q[10], 110.0);
        assert!(q.windows(2).all(|w| w[1] > w[0]));
        assert_eq!(m.qps_curve(1, 1.0), vec![10.0]);
    }

    #[test]
    fn bursty_is_two_level_and_seed_deterministic() {
        let m = TrafficModel::Bursty { base_qps: 40.0, burst_qps: 400.0, burst_prob: 0.3, seed: 9 };
        let q = m.qps_curve(200, 0.5);
        assert!(q.iter().all(|&v| v == 40.0 || v == 400.0));
        let bursts = q.iter().filter(|&&v| v == 400.0).count();
        assert!(bursts > 20 && bursts < 120, "burst count {bursts}");
        assert_eq!(q, m.qps_curve(200, 0.5));
        let other = TrafficModel::Bursty {
            base_qps: 40.0,
            burst_qps: 400.0,
            burst_prob: 0.3,
            seed: 10,
        };
        assert_ne!(q, other.qps_curve(200, 0.5));
    }

    #[test]
    fn window_peak_dominates_curve_and_captures_crests() {
        // The reviewer-style case: 4 windows of 6 h over a 24 h period.
        // The crest (t = 12 h) sits on the boundary of windows 1 and 2;
        // both must provision the full peak, not the midpoint sample.
        let m = TrafficModel::Diurnal { peak_qps: 300.0, trough_qps: 10.0, period_h: 24.0 };
        let curve = m.qps_curve(4, 6.0);
        let peak = m.qps_window_peak(4, 6.0);
        assert_eq!(peak.len(), 4);
        for (p, c) in peak.iter().zip(&curve) {
            assert!(p >= c, "peak {p} < curve sample {c}");
        }
        assert_eq!(peak[1], 300.0);
        assert_eq!(peak[2], 300.0);
        assert!(curve[1] < 300.0, "midpoint sample must be below the crest");
        // Monotone ramp: each window provisions for its higher edge.
        let r = TrafficModel::Ramp { start_qps: 10.0, end_qps: 110.0 };
        let rc = r.qps_curve(11, 1.0);
        let rp = r.qps_window_peak(11, 1.0);
        for i in 0..11 {
            assert_eq!(rp[i], rc[(i + 1).min(10)]);
        }
        // Bursty is piecewise-constant: peak == curve.
        let b = TrafficModel::Bursty { base_qps: 5.0, burst_qps: 50.0, burst_prob: 0.4, seed: 3 };
        assert_eq!(b.qps_window_peak(40, 0.5), b.qps_curve(40, 0.5));
    }

    #[test]
    fn json_roundtrip_all_kinds() {
        let models = [
            TrafficModel::Diurnal { peak_qps: 120.0, trough_qps: 12.0, period_h: 24.0 },
            TrafficModel::Ramp { start_qps: 5.0, end_qps: 50.0 },
            TrafficModel::Bursty { base_qps: 30.0, burst_qps: 300.0, burst_prob: 0.2, seed: 3 },
        ];
        for m in models {
            let back = TrafficModel::from_json(&m.to_json()).unwrap();
            assert_eq!(back, m);
        }
        assert!(TrafficModel::from_json(&json::parse(r#"{"kind":"square"}"#).unwrap()).is_err());
        // Validation rejects inverted diurnal bands.
        let bad = json::parse(r#"{"kind":"diurnal","peak_qps":1,"trough_qps":9}"#).unwrap();
        assert!(TrafficModel::from_json(&bad).is_err());
        // Seeds the f64 wire format would corrupt are rejected, not
        // silently rewritten.
        for bad_seed in ["-1", "1.5", "1e17"] {
            let s = format!(r#"{{"kind":"bursty","base_qps":1,"burst_qps":2,"seed":{bad_seed}}}"#);
            assert!(TrafficModel::from_json(&json::parse(&s).unwrap()).is_err(), "{bad_seed}");
        }
    }

    #[test]
    fn trace_follows_curve() {
        let m = TrafficModel::Ramp { start_qps: 0.0, end_qps: 40.0 };
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 1000.0, 10.0);
        // Two windows of 1/100 hour (36 s): first silent, second ~40 QPS.
        let t = m.trace(2, 0.01, &wl, 0.0, 21);
        assert!(!t.is_empty());
        assert!(t.iter().all(|r| r.arrival_ms >= 36_000.0));
        let rate = t.len() as f64 / 36.0;
        assert!((rate - 40.0).abs() < 10.0, "rate {rate}");
    }
}

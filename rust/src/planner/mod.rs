//! Traffic-aware capacity planner: cost/SLA deployment schedules over
//! dynamic workloads.
//!
//! The layer above per-configuration pricing (cf. Vidur's what-if
//! search and GUIDE's heterogeneous-deployment planning, PAPERS.md):
//! given a time-varying traffic model ([`traffic::TrafficModel`]), a
//! candidate fleet of GPU types priced by `usd_per_hour`
//! ([`crate::hardware::GpuSpec`]) and an SLA, find how many replicas of
//! which engine configuration — on which GPU type — to run in each
//! time window so the SLA holds at minimum cost.
//!
//! Pipeline per plan:
//! 1. each fleet leg is priced by the sweep engine
//!    ([`crate::search::TaskRunner::run_sweep_cached`]); a leg-owned
//!    [`crate::perfdb::MemoOracle`] is shared across every window of
//!    the horizon — and across repeated plans when the caller holds
//!    its memos ([`plan_cached`]; operator latencies are
//!    cluster-specific, so legs do not share one memo — each leg's is
//!    reused instead);
//! 2. SLA-feasible candidates become deployment *units*
//!    ([`options::PricedOption`]), k-objective-pruned on the
//!    (−cost/h, capacity, speed, −footprint) frontier
//!    ([`options::prune_options`] over
//!    [`crate::pareto::FrontierAccumulator`]);
//! 3. the per-window min-cost schedule is exact
//!    ([`schedule::optimize`]; brute-force-pinned in tests), and the
//!    plan reports the heterogeneity dividend (vs the best
//!    single-GPU-type schedule) and the elasticity dividend (vs
//!    statically provisioning the peak for the whole horizon);
//! 4. callers that expect follow-up what-ifs keep the priced state in
//!    a [`PlanArena`] and apply [`crate::search::SearchDelta`]s with
//!    [`replan`]: only recalibrated/added legs re-sweep, repricing and
//!    removals patch the tracked k-objective frontier incrementally
//!    (retractions re-admit formerly dominated survivors), and window
//!    edits splice re-chosen windows into the baseline — with the
//!    result pinned bit-identical to a from-scratch plan of the
//!    patched inputs.

pub mod options;
pub mod schedule;
pub mod traffic;

pub use options::{options_from_report, prune_options, PricedOption};
pub use schedule::{choose_window, optimize, replicas_needed, Schedule, WindowChoice};
pub use traffic::TrafficModel;

use crate::config::{Candidate, WorkloadSpec};
use crate::frameworks::Framework;
use crate::hardware::{gpu_by_name, ClusterSpec};
use crate::models::ModelArch;
use crate::pareto::FrontierAccumulator;
use crate::perfdb::{LatencyOracle, MemoOracle};
use crate::perfmodel::PerfEstimate;
use crate::search::{RunOptions, SearchDelta, SearchSpace, TaskRunner};
use crate::trace;
use crate::util::json::{self, Json};

/// Planner input.
#[derive(Clone, Debug)]
pub struct PlanSpec {
    /// The request shape + SLA every window must serve.
    pub workload: WorkloadSpec,
    pub traffic: TrafficModel,
    /// Number of scheduling windows in the horizon.
    pub windows: usize,
    /// Window length, hours.
    pub window_h: f64,
    /// Per-window GPU budget across the fleet (None = unbounded).
    pub max_gpus: Option<u32>,
    /// k-objective-prune the option set before the window search (the
    /// optimal schedule is preserved exactly; tested).
    pub prune: bool,
    /// Per-window peak-demand overrides `(window index, peak QPS)`,
    /// applied over the traffic model's window peaks in order (later
    /// entries win). The replan layer's window-edit deltas land here,
    /// so a from-scratch plan of the patched spec is the replan's
    /// bit-equality reference.
    pub demand_override: Vec<(usize, f64)>,
}

impl PlanSpec {
    pub fn new(workload: WorkloadSpec, traffic: TrafficModel, windows: usize, window_h: f64) -> Self {
        PlanSpec {
            workload,
            traffic,
            windows,
            window_h,
            max_gpus: None,
            prune: true,
            demand_override: Vec::new(),
        }
    }
}

/// One window of the final plan.
#[derive(Clone, Debug)]
pub struct WindowPlan {
    pub index: usize,
    /// Window span, hours from horizon start.
    pub t_start_h: f64,
    pub t_end_h: f64,
    /// Peak instantaneous demand inside the window (what the planner
    /// provisions for).
    pub demand_qps: f64,
    /// GPU preset name of the chosen option.
    pub gpu: String,
    /// The deployment unit (one engine replica / one xPyD composite).
    pub cand: Candidate,
    /// Units deployed this window (0 = scale-to-zero).
    pub replicas: u32,
    /// Total GPUs this window (u64: replicas × unit GPUs can exceed
    /// u32 for extreme uncapped demands).
    pub gpus: u64,
    /// Aggregate serveable rate, queries/s.
    pub capacity_qps: f64,
    /// Per-request projection of the chosen unit.
    pub est: PerfEstimate,
    pub cost_usd: f64,
}

/// A full cost-minimal deployment schedule.
#[derive(Clone, Debug)]
pub struct DeploymentPlan {
    pub windows: Vec<WindowPlan>,
    pub total_cost_usd: f64,
    /// Best schedule restricted to a single GPU type (None when no
    /// single type can serve every window); the gap to `total_cost_usd`
    /// is the heterogeneity dividend.
    pub best_homogeneous: Option<(String, f64)>,
    /// Cost of statically provisioning the peak window's deployment for
    /// the entire horizon (what a non-traffic-aware search would buy).
    pub static_peak_cost_usd: f64,
    /// SLA-feasible options priced across the fleet.
    pub options_considered: usize,
    /// Options discarded by the k-objective frontier prune.
    pub options_pruned: usize,
}

impl DeploymentPlan {
    /// Savings of the traffic-aware schedule vs static peak
    /// provisioning, in [0, 1).
    pub fn elastic_savings_frac(&self) -> f64 {
        if self.static_peak_cost_usd > 0.0 {
            1.0 - self.total_cost_usd / self.static_peak_cost_usd
        } else {
            0.0
        }
    }

    /// Maximal runs of consecutive windows deploying the *same unit on
    /// the same GPU type* (replica counts may differ): the granularity
    /// at which replicas keep their identity when a schedule is
    /// executed. Scaling inside a segment adds/removes replicas of a
    /// running deployment; a segment boundary tears the fleet down and
    /// launches a different engine. [`crate::fleetsim`] replays each
    /// segment as one fleet of persistent replicas. Returns inclusive
    /// `(first, last)` window-index pairs covering the horizon.
    pub fn segments(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for (w, win) in self.windows.iter().enumerate() {
            match out.last_mut() {
                Some((_, last))
                    if *last + 1 == w && {
                        let prev = &self.windows[*last];
                        prev.gpu == win.gpu && prev.cand == win.cand
                    } =>
                {
                    *last = w;
                }
                _ => out.push((w, w)),
            }
        }
        out
    }

    pub fn to_json(&self, wl: &WorkloadSpec) -> Json {
        let mut windows = Vec::new();
        for w in &self.windows {
            let mut o = Json::obj();
            o.set("window", json::num(w.index as f64))
                .set("t_start_h", json::num(w.t_start_h))
                .set("t_end_h", json::num(w.t_end_h))
                .set("demand_qps", json::num(w.demand_qps))
                .set("gpu", json::s(&w.gpu))
                .set("config", json::s(&w.cand.label()))
                .set("mode", json::s(w.cand.mode().name()))
                .set("replicas", json::num(w.replicas as f64))
                .set("gpus", json::num(w.gpus as f64))
                .set("capacity_qps", json::num(w.capacity_qps))
                .set("ttft_ms", json::num(w.est.ttft_ms))
                .set("speed", json::num(w.est.speed))
                .set("cost_usd", json::num(w.cost_usd));
            windows.push(o);
        }
        let mut o = Json::obj();
        o.set("workload", wl.to_json())
            .set("windows", Json::Arr(windows))
            .set("total_cost_usd", json::num(self.total_cost_usd))
            .set("static_peak_cost_usd", json::num(self.static_peak_cost_usd))
            .set("elastic_savings_frac", json::num(self.elastic_savings_frac()))
            .set("options_considered", json::num(self.options_considered as f64))
            .set("options_pruned", json::num(self.options_pruned as f64));
        if let Some((gpu, cost)) = &self.best_homogeneous {
            let mut h = Json::obj();
            h.set("gpu", json::s(gpu)).set("cost_usd", json::num(*cost));
            o.set("best_homogeneous", h);
        }
        o
    }
}

/// Plan against caller-owned per-leg memos (the warm path: callers
/// that reuse memos across plans, as the memo-warm half of
/// `benches/planner.rs` does). Legs are `(cluster, memo)` pairs; each
/// memo must wrap an oracle profiled for that cluster.
pub fn plan_cached(
    model: &ModelArch,
    framework: Framework,
    spec: &PlanSpec,
    fleet: &[(ClusterSpec, &MemoOracle<'_>)],
) -> anyhow::Result<DeploymentPlan> {
    let _sp = trace::span("plan", "plan");
    check_spec(spec)?;
    anyhow::ensure!(!fleet.is_empty(), "the candidate fleet is empty");
    let demands = demands_for(spec)?;

    // 1. Price every fleet leg (one single-scenario sweep per leg; the
    //    leg's memo keeps repeat plans warm).
    let mut all: Vec<PricedOption> = Vec::new();
    for (cluster, memo) in fleet {
        let (options, _) = price_leg(model, framework, &spec.workload, cluster, memo);
        all.extend(options);
    }

    // 2. k-objective frontier prune (schedule-transparent).
    let kept: Vec<usize> =
        if spec.prune { prune_options(&all) } else { (0..all.len()).collect() };

    // 3. Exact per-window min-cost schedule + reference points.
    assemble_plan(spec, &demands, &all, &kept)
}

fn check_spec(spec: &PlanSpec) -> anyhow::Result<()> {
    anyhow::ensure!(spec.windows > 0, "plan horizon needs at least one window");
    // Bounds the per-request work for service callers (a year of hourly
    // windows is 8760; nobody plans more granularly than this).
    anyhow::ensure!(
        spec.windows <= 100_000,
        "plan horizon of {} windows is unreasonably large (max 100000)",
        spec.windows
    );
    anyhow::ensure!(spec.window_h > 0.0, "window length must be positive hours");
    spec.traffic.validate()
}

/// Per-window provisioning targets: the traffic model's window *peaks*
/// (a midpoint-sampled rising window would run under capacity at its
/// edges — `TrafficModel::qps_window_peak`), then the spec's explicit
/// per-window overrides in order.
fn demands_for(spec: &PlanSpec) -> anyhow::Result<Vec<f64>> {
    let mut demands = spec.traffic.qps_window_peak(spec.windows, spec.window_h);
    for &(w, qps) in &spec.demand_override {
        anyhow::ensure!(
            w < demands.len(),
            "demand override for window {w} is out of range ({} windows)",
            demands.len()
        );
        anyhow::ensure!(
            qps.is_finite() && qps >= 0.0,
            "demand override for window {w}: {qps} must be finite and non-negative"
        );
        demands[w] = qps;
    }
    Ok(demands)
}

/// Price one fleet leg: a single-scenario sweep through the leg's memo.
/// Reports must be unpruned — see [`options_from_report`]. Returns the
/// leg's SLA-feasible options (report order) and the engine configs the
/// sweep priced (the replan layer's savings denominator).
///
/// Mixed-generation fleets need no special-casing here:
/// `SearchSpace::engine_grid` falls back to the GPU's preferred dtype
/// when none of the default sweep dtypes is supported (FP8 on Ampere),
/// so every leg contributes options.
fn price_leg(
    model: &ModelArch,
    framework: Framework,
    wl: &WorkloadSpec,
    cluster: &ClusterSpec,
    memo: &MemoOracle<'_>,
) -> (Vec<PricedOption>, usize) {
    let sp = trace::span(&format!("leg_sweep {}", cluster.gpu.name), "plan");
    let space = SearchSpace::default_for(model, framework);
    let runner = TaskRunner::new(model, cluster, space, wl.clone());
    let reports = runner.run_sweep_cached(memo, std::slice::from_ref(wl), &RunOptions::default());
    let options = options_from_report(&cluster.gpu, wl, &reports[0]);
    sp.add("configs_priced", reports[0].configs_priced as f64);
    sp.add("options", options.len() as f64);
    (options, reports[0].configs_priced)
}

/// One window's plan entry from the schedule layer's choice. Shared by
/// full assembly and the replan layer's window splice so both produce
/// bit-identical entries.
fn window_plan(w: usize, demand: f64, spec: &PlanSpec, o: &PricedOption, c: &WindowChoice) -> WindowPlan {
    WindowPlan {
        index: w,
        t_start_h: w as f64 * spec.window_h,
        t_end_h: (w + 1) as f64 * spec.window_h,
        demand_qps: demand,
        gpu: o.gpu.clone(),
        cand: o.cand.clone(),
        replicas: c.replicas,
        gpus: c.replicas as u64 * o.unit_gpus as u64,
        capacity_qps: c.replicas as f64 * o.qps_per_unit,
        est: o.est,
        cost_usd: c.cost_usd,
    }
}

/// Reference points: best single-GPU-type schedule and static peak
/// provisioning (both over the *unpruned* option set, so they are
/// honest baselines rather than artifacts of the prune).
fn reference_points(
    all: &[PricedOption],
    demands: &[f64],
    spec: &PlanSpec,
) -> (Option<(String, f64)>, f64) {
    let mut best_homogeneous: Option<(String, f64)> = None;
    let mut gpu_names: Vec<&str> = all.iter().map(|o| o.gpu.as_str()).collect();
    gpu_names.sort_unstable();
    gpu_names.dedup();
    for name in gpu_names {
        let subset: Vec<PricedOption> =
            all.iter().filter(|o| o.gpu == name).cloned().collect();
        let s = optimize(&subset, demands, spec.window_h, spec.max_gpus);
        let improves = match &best_homogeneous {
            Some((_, c)) => s.total_cost_usd < *c,
            None => true,
        };
        if s.choices.iter().all(|c| c.is_some()) && improves {
            best_homogeneous = Some((name.to_string(), s.total_cost_usd));
        }
    }
    let peak = demands.iter().cloned().fold(0.0f64, f64::max);
    let static_peak_cost_usd = choose_window(all, peak, spec.window_h, spec.max_gpus)
        .map(|c| c.cost_usd * spec.windows as f64)
        .unwrap_or(f64::INFINITY);
    (best_homogeneous, static_peak_cost_usd)
}

/// Schedule + report assembly over an already-priced option set: the
/// shared back half of [`plan_cached`], [`plan_arena`] and [`replan`] —
/// sharing it is what pins an incremental replan bit-identical to a
/// from-scratch plan of the same options.
fn assemble_plan(
    spec: &PlanSpec,
    demands: &[f64],
    all: &[PricedOption],
    kept: &[usize],
) -> anyhow::Result<DeploymentPlan> {
    let sp = trace::span("schedule", "plan");
    sp.add("options_considered", all.len() as f64);
    sp.add("options_pruned", (all.len() - kept.len()) as f64);
    sp.add("windows", spec.windows as f64);
    anyhow::ensure!(
        !all.is_empty(),
        "no SLA-feasible deployment option on any fleet leg — relax the SLA or widen the fleet"
    );
    let pruned_set: Vec<PricedOption> = kept.iter().map(|&i| all[i].clone()).collect();
    let sched = optimize(&pruned_set, demands, spec.window_h, spec.max_gpus);
    let mut windows = Vec::with_capacity(spec.windows);
    for (w, choice) in sched.choices.iter().enumerate() {
        let c = choice.ok_or_else(|| {
            anyhow::anyhow!(
                "window {w} (demand {:.1} QPS) cannot be served by any option (GPU cap: {:?})",
                demands[w],
                spec.max_gpus
            )
        })?;
        windows.push(window_plan(w, demands[w], spec, &pruned_set[c.option], &c));
    }
    let (best_homogeneous, static_peak_cost_usd) = reference_points(all, demands, spec);
    Ok(DeploymentPlan {
        windows,
        total_cost_usd: sched.total_cost_usd,
        best_homogeneous,
        static_peak_cost_usd,
        options_considered: all.len(),
        options_pruned: all.len() - kept.len(),
    })
}

/// Plan with fresh (cold) memos over plain oracles — the CLI path.
pub fn plan(
    model: &ModelArch,
    framework: Framework,
    spec: &PlanSpec,
    fleet: &[(ClusterSpec, &dyn LatencyOracle)],
) -> anyhow::Result<DeploymentPlan> {
    let memos: Vec<MemoOracle<'_>> =
        fleet.iter().map(|(_, oracle)| MemoOracle::new(*oracle)).collect();
    let legs: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        fleet.iter().zip(&memos).map(|((cluster, _), memo)| (*cluster, memo)).collect();
    plan_cached(model, framework, spec, &legs)
}

/// Per-leg state retained between a plan and its replans: the leg's
/// cluster, its priced options and their arena ids in the tracked
/// frontier accumulator, and how many engine configs the leg's sweep
/// priced (the replan savings denominator).
struct LegState {
    cluster: ClusterSpec,
    options: Vec<PricedOption>,
    /// Tracked-accumulator arena id of each option, parallel to
    /// `options`. Ascending across the concatenation of legs in leg
    /// order — the invariant that makes `kept_indices` reproduce
    /// [`prune_options`]' input-order semantics.
    ids: Vec<usize>,
    configs_priced: usize,
}

/// Retained priced state from [`plan_arena`], the differential replan
/// substrate: consume a [`SearchDelta`] with [`replan`] and only the
/// legs the delta invalidates are re-swept, while the k-objective
/// frontier is patched incrementally (retractions re-admit formerly
/// dominated survivors from the tracked arena instead of re-pricing).
pub struct PlanArena {
    spec: PlanSpec,
    legs: Vec<LegState>,
    tracked: FrontierAccumulator,
    /// Kept-option labels of the last assembled plan, for the
    /// entered/left diff in [`ReplanReport`].
    last_kept: Vec<String>,
}

impl PlanArena {
    /// Engine configs a full from-scratch re-sweep of the current fleet
    /// would price — the denominator for replan savings claims.
    pub fn baseline_priced_configs(&self) -> usize {
        self.legs.iter().map(|l| l.configs_priced).sum()
    }

    /// Current fleet legs' GPU preset names, in leg order.
    pub fn leg_gpus(&self) -> Vec<String> {
        self.legs.iter().map(|l| l.cluster.gpu.name.to_string()).collect()
    }

    fn all_options(&self) -> Vec<PricedOption> {
        self.legs.iter().flat_map(|l| l.options.iter().cloned()).collect()
    }

    /// Indices into the leg-concatenation order kept by the tracked
    /// frontier — reproduces [`prune_options`] over [`all_options`]
    /// because arena ids ascend in that same order and the tracked
    /// accumulator's kept set equals an in-order offer replay.
    fn kept_indices(&self) -> Vec<usize> {
        let all_len: usize = self.legs.iter().map(|l| l.options.len()).sum();
        if !self.spec.prune {
            return (0..all_len).collect();
        }
        let kept: std::collections::HashSet<usize> =
            self.tracked.kept_ids().into_iter().collect();
        self.legs
            .iter()
            .flat_map(|l| l.ids.iter())
            .enumerate()
            .filter(|(_, id)| kept.contains(id))
            .map(|(i, _)| i)
            .collect()
    }

    /// Re-seed the tracked accumulator from scratch, reassigning arena
    /// ids in leg-concatenation order. Needed after a mid-list leg
    /// re-sweep (recalibration): fresh options appended to the old
    /// arena would break the ascending-id ↔ input-order invariant.
    fn rebuild_tracked(&mut self) {
        let mut acc = FrontierAccumulator::new();
        for leg in &mut self.legs {
            leg.ids.clear();
            for o in &leg.options {
                leg.ids.push(acc.offer_tracked(&o.objectives()));
            }
        }
        self.tracked = acc;
    }
}

/// Stable identity of a deployment option across re-pricing: the cost
/// coordinate may change under a delta, but GPU + engine label +
/// footprint is what operators recognise as "the same config".
fn option_label(o: &PricedOption) -> String {
    format!("{}|{}|{}", o.gpu, o.cand.label(), o.unit_gpus)
}

/// All legs whose GPU preset matches `token` (alias-tolerant via
/// [`gpu_by_name`]). Repricing applies to every match; removal and
/// recalibration require exactly one.
fn legs_matching(legs: &[LegState], token: &str) -> anyhow::Result<Vec<usize>> {
    let gpu = gpu_by_name(token)
        .ok_or_else(|| anyhow::anyhow!("unknown gpu '{token}' in delta"))?;
    let hits: Vec<usize> = legs
        .iter()
        .enumerate()
        .filter(|(_, l)| l.cluster.gpu.name == gpu.name)
        .map(|(i, _)| i)
        .collect();
    anyhow::ensure!(!hits.is_empty(), "delta names gpu '{token}' but no fleet leg uses it");
    Ok(hits)
}

fn leg_matching_one(legs: &[LegState], token: &str) -> anyhow::Result<usize> {
    let hits = legs_matching(legs, token)?;
    anyhow::ensure!(
        hits.len() == 1,
        "delta names gpu '{token}' which matches {} fleet legs — remove/recalibrate need exactly one",
        hits.len()
    );
    Ok(hits[0])
}

/// Like [`plan_cached`], but also returns the retained [`PlanArena`]
/// so later [`SearchDelta`]s can be applied with [`replan`] instead of
/// a full re-search. The returned plan is bit-identical to
/// [`plan_cached`] on the same inputs (pinned in tests).
pub fn plan_arena(
    model: &ModelArch,
    framework: Framework,
    spec: &PlanSpec,
    fleet: &[(ClusterSpec, &MemoOracle<'_>)],
) -> anyhow::Result<(DeploymentPlan, PlanArena)> {
    check_spec(spec)?;
    anyhow::ensure!(!fleet.is_empty(), "the candidate fleet is empty");
    let demands = demands_for(spec)?;

    let mut arena = PlanArena {
        spec: spec.clone(),
        legs: Vec::with_capacity(fleet.len()),
        tracked: FrontierAccumulator::new(),
        last_kept: Vec::new(),
    };
    for (cluster, memo) in fleet {
        let (options, configs_priced) =
            price_leg(model, framework, &spec.workload, cluster, memo);
        let ids: Vec<usize> =
            options.iter().map(|o| arena.tracked.offer_tracked(&o.objectives())).collect();
        arena.legs.push(LegState { cluster: *cluster, options, ids, configs_priced });
    }

    let all = arena.all_options();
    let kept = arena.kept_indices();
    debug_assert!(!spec.prune || kept == prune_options(&all));
    let plan = assemble_plan(spec, &demands, &all, &kept)?;
    arena.last_kept = kept.iter().map(|&i| option_label(&all[i])).collect();
    Ok((plan, arena))
}

/// What a replan produced, and what it saved.
pub struct ReplanReport {
    pub plan: DeploymentPlan,
    /// Engine configs actually re-priced by this replan (recalibrated
    /// + added legs only; reprices, removals and window edits cost no
    /// oracle work).
    pub repriced_configs: usize,
    /// Engine configs a full from-scratch re-search of the patched
    /// fleet would price.
    pub baseline_priced_configs: usize,
    /// Kept-option labels that entered the deployment frontier.
    pub entered: Vec<String>,
    /// Kept-option labels that left the deployment frontier.
    pub left: Vec<String>,
    /// Windows whose (gpu, config, replicas) choice changed vs the
    /// baseline plan.
    pub windows_changed: usize,
}

impl ReplanReport {
    pub fn to_json(&self, wl: &WorkloadSpec) -> Json {
        let mut o = Json::obj();
        o.set("kind", json::s("replan-report"))
            .set("plan", self.plan.to_json(wl))
            .set("repriced_configs", json::num(self.repriced_configs as f64))
            .set("baseline_priced_configs", json::num(self.baseline_priced_configs as f64))
            .set(
                "entered",
                Json::Arr(self.entered.iter().map(|s| json::s(s)).collect()),
            )
            .set("left", Json::Arr(self.left.iter().map(|s| json::s(s)).collect()))
            .set("windows_changed", json::num(self.windows_changed as f64));
        o
    }
}

/// Apply a [`SearchDelta`] to a retained [`PlanArena`], re-pricing only
/// what the delta invalidates, and return the patched plan plus a
/// config diff vs `baseline`.
///
/// `swept` supplies one `(cluster, memo)` pair per recalibrated leg
/// (in `delta.recalibrate` order) followed by one per added leg (in
/// `delta.add_legs` order); the memo must wrap an oracle profiled for
/// that cluster — for recalibration, the *new* calibration artifact.
///
/// The result is bit-identical to a from-scratch [`plan_cached`] of
/// the patched inputs (CI-pinned via `--check-equal`):
/// - window edits land in `spec.demand_override` and, when the delta is
///   window-only, splice re-chosen windows into the baseline through
///   the same [`window_plan`]/[`choose_window`] path full assembly uses;
/// - GPU repricing rewrites each option's cost coordinate in place with
///   the exact [`options_from_report`] expression and updates the
///   tracked frontier, re-admitting formerly dominated survivors;
/// - removed legs retract their arena ids (no re-pricing);
/// - recalibrated legs re-sweep in place and rebuild the tracked
///   accumulator (mid-list id reassignment); added legs sweep and
///   append incrementally.
pub fn replan(
    model: &ModelArch,
    framework: Framework,
    arena: &mut PlanArena,
    baseline: &DeploymentPlan,
    delta: &SearchDelta,
    swept: &[(ClusterSpec, &MemoOracle<'_>)],
) -> anyhow::Result<ReplanReport> {
    let sp = trace::span("replan", "replan");
    delta.validate()?;
    anyhow::ensure!(
        swept.len() == delta.recalibrate.len() + delta.add_legs.len(),
        "replan needs one swept (cluster, memo) pair per recalibrated then per added leg: \
         expected {}, got {}",
        delta.recalibrate.len() + delta.add_legs.len(),
        swept.len()
    );
    anyhow::ensure!(
        baseline.windows.len() == arena.spec.windows,
        "baseline plan has {} windows but the arena spec has {}",
        baseline.windows.len(),
        arena.spec.windows
    );

    // Window-only deltas never touch the option set: splice re-chosen
    // windows into the baseline instead of re-running the full
    // schedule. Demand overrides accumulate in the spec so a
    // from-scratch plan of the patched spec stays the equality
    // reference for *future* replans too.
    if delta.only_window_edits() {
        arena.spec.demand_override.extend(delta.window_edits.iter().cloned());
        let spec = arena.spec.clone();
        let demands = demands_for(&spec)?;
        let all = arena.all_options();
        let kept = arena.kept_indices();
        let pruned_set: Vec<PricedOption> = kept.iter().map(|&i| all[i].clone()).collect();
        let mut edited: Vec<usize> = delta.window_edits.iter().map(|&(w, _)| w).collect();
        edited.sort_unstable();
        edited.dedup();
        let mut windows = baseline.windows.clone();
        for &w in &edited {
            let c = choose_window(&pruned_set, demands[w], spec.window_h, spec.max_gpus)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "window {w} (demand {:.1} QPS) cannot be served by any option (GPU cap: {:?})",
                        demands[w],
                        spec.max_gpus
                    )
                })?;
            windows[w] = window_plan(w, demands[w], &spec, &pruned_set[c.option], &c);
        }
        // Fresh in-order sum: the same addends in the same order as
        // `optimize`'s total, so the spliced plan stays bit-identical
        // to a from-scratch recompute.
        let total_cost_usd: f64 = windows.iter().map(|w| w.cost_usd).sum();
        let (best_homogeneous, static_peak_cost_usd) = reference_points(&all, &demands, &spec);
        let windows_changed = windows
            .iter()
            .zip(&baseline.windows)
            .filter(|(a, b)| a.gpu != b.gpu || a.cand != b.cand || a.replicas != b.replicas)
            .count();
        let plan = DeploymentPlan {
            windows,
            total_cost_usd,
            best_homogeneous,
            static_peak_cost_usd,
            options_considered: all.len(),
            options_pruned: all.len() - kept.len(),
        };
        sp.add("windows_changed", windows_changed as f64);
        return Ok(ReplanReport {
            plan,
            repriced_configs: 0,
            baseline_priced_configs: arena.baseline_priced_configs(),
            entered: Vec::new(),
            left: Vec::new(),
            windows_changed,
        });
    }

    // 1. GPU repricing: a pure cost re-derivation — rewrite the cost
    //    coordinate of every option on every matching leg and update
    //    the tracked frontier (retraction + re-admission inside).
    for (token, price) in &delta.reprice {
        for i in legs_matching(&arena.legs, token)? {
            let leg = &mut arena.legs[i];
            leg.cluster.gpu.usd_per_hour = *price;
            for (o, &id) in leg.options.iter_mut().zip(&leg.ids) {
                o.usd_per_hour = o.unit_gpus as f64 * price;
                arena.tracked.update(id, &o.objectives());
            }
        }
    }

    // 2. Removed legs: pure retraction — formerly dominated survivors
    //    on other legs are re-admitted from the tracked arena.
    for token in &delta.remove_legs {
        let i = leg_matching_one(&arena.legs, token)?;
        for &id in &arena.legs[i].ids {
            arena.tracked.retract(id);
        }
        arena.legs.remove(i);
    }

    // 3. Recalibrated legs: re-sweep in place against the new
    //    calibration artifact's oracle.
    let mut repriced_configs = 0usize;
    for (k, token) in delta.recalibrate.iter().enumerate() {
        let i = leg_matching_one(&arena.legs, token)?;
        let (cluster, memo) = &swept[k];
        anyhow::ensure!(
            cluster.gpu.name == arena.legs[i].cluster.gpu.name,
            "swept cluster for recalibrated leg '{token}' is {}, expected {}",
            cluster.gpu.name,
            arena.legs[i].cluster.gpu.name
        );
        let (options, priced) =
            price_leg(model, framework, &arena.spec.workload, cluster, memo);
        repriced_configs += priced;
        arena.legs[i] =
            LegState { cluster: *cluster, options, ids: Vec::new(), configs_priced: priced };
    }

    // 4. Added legs: sweep and append at the end (the canonical leg
    //    position for `--check-equal` fleets).
    let recal = delta.recalibrate.len();
    let mut added: Vec<LegState> = Vec::new();
    for (k, token) in delta.add_legs.iter().enumerate() {
        let gpu = gpu_by_name(token)
            .ok_or_else(|| anyhow::anyhow!("unknown gpu '{token}' in delta"))?;
        let (cluster, memo) = &swept[recal + k];
        anyhow::ensure!(
            cluster.gpu.name == gpu.name,
            "swept cluster for added leg '{token}' is {}, expected {}",
            cluster.gpu.name,
            gpu.name
        );
        let (options, priced) =
            price_leg(model, framework, &arena.spec.workload, cluster, memo);
        repriced_configs += priced;
        added.push(LegState { cluster: *cluster, options, ids: Vec::new(), configs_priced: priced });
    }
    if recal > 0 {
        // Mid-list re-sweeps break the ascending-id ↔ leg-order
        // invariant; re-seed the accumulator over the final leg list.
        arena.legs.extend(added);
        arena.rebuild_tracked();
    } else {
        for mut leg in added {
            for o in &leg.options {
                leg.ids.push(arena.tracked.offer_tracked(&o.objectives()));
            }
            arena.legs.push(leg);
        }
    }

    // 5. Window edits (if any rode along a structural delta) land in
    //    the spec; then assemble through the exact full-plan path.
    arena.spec.demand_override.extend(delta.window_edits.iter().cloned());
    let spec = arena.spec.clone();
    let demands = demands_for(&spec)?;
    let all = arena.all_options();
    let kept = arena.kept_indices();
    debug_assert!(!spec.prune || kept == prune_options(&all));
    let plan = assemble_plan(&spec, &demands, &all, &kept)?;

    // 6. Config diff vs the previous plan's frontier and windows.
    let kept_labels: Vec<String> = kept.iter().map(|&i| option_label(&all[i])).collect();
    let prev: std::collections::HashSet<&str> =
        arena.last_kept.iter().map(|s| s.as_str()).collect();
    let now: std::collections::HashSet<&str> =
        kept_labels.iter().map(|s| s.as_str()).collect();
    let entered: Vec<String> =
        kept_labels.iter().filter(|l| !prev.contains(l.as_str())).cloned().collect();
    let left: Vec<String> =
        arena.last_kept.iter().filter(|l| !now.contains(l.as_str())).cloned().collect();
    let windows_changed = plan
        .windows
        .iter()
        .zip(&baseline.windows)
        .filter(|(a, b)| a.gpu != b.gpu || a.cand != b.cand || a.replicas != b.replicas)
        .count();
    arena.last_kept = kept_labels;
    sp.add("repriced_configs", repriced_configs as f64);
    sp.add("windows_changed", windows_changed as f64);
    Ok(ReplanReport {
        plan,
        repriced_configs,
        baseline_priced_configs: arena.baseline_priced_configs(),
        entered,
        left,
        windows_changed,
    })
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags};
    use crate::models::Dtype;

    /// A synthetic option: the schedule layer only reads `unit_gpus`,
    /// `usd_per_hour`, `qps_per_unit` and the objectives.
    pub fn opt(gpu: &str, unit_gpus: u32, usd_per_hour: f64, qps: f64, speed: f64) -> PricedOption {
        let eng = EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(unit_gpus),
            batch: 16,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        };
        PricedOption {
            gpu: gpu.to_string(),
            cand: Candidate::Aggregated { engine: eng, replicas: 1 },
            unit_gpus,
            usd_per_hour,
            qps_per_unit: qps,
            est: PerfEstimate {
                ttft_ms: 100.0,
                tpot_ms: 1000.0 / speed,
                speed,
                thru_per_gpu: 1.0,
                concurrency: 16,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{a100_sxm, h100_sxm};
    use crate::models::by_name;
    use crate::silicon::Silicon;

    fn spec(windows: usize) -> PlanSpec {
        PlanSpec::new(
            WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0),
            TrafficModel::Diurnal { peak_qps: 120.0, trough_qps: 5.0, period_h: 24.0 },
            windows,
            24.0 / windows as f64,
        )
    }

    #[test]
    fn plan_serves_every_window_and_scales_with_demand() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let spec = spec(8);
        let p = plan(&model, Framework::TrtLlm, &spec, &[(cluster, &sil)]).unwrap();
        assert_eq!(p.windows.len(), 8);
        assert!(p.total_cost_usd > 0.0);
        assert!(p.options_considered > 0);
        let demands = spec.traffic.qps_window_peak(8, 3.0);
        for (w, d) in p.windows.iter().zip(&demands) {
            assert_eq!(w.demand_qps, *d);
            assert!(w.capacity_qps >= w.demand_qps, "window {} under-provisioned", w.index);
            assert!(w.est.meets(&spec.workload.sla));
            assert!(w.gpus >= w.replicas as u64, "unit is at least one GPU");
        }
        // Min-cost per window is nondecreasing in demand, so the peak
        // window costs at least the trough window.
        let peak = p.windows.iter().cloned().fold(None::<WindowPlan>, |m, w| match m {
            Some(b) if b.demand_qps >= w.demand_qps => Some(b),
            _ => Some(w),
        });
        let trough = p.windows.iter().cloned().fold(None::<WindowPlan>, |m, w| match m {
            Some(b) if b.demand_qps <= w.demand_qps => Some(b),
            _ => Some(w),
        });
        assert!(peak.unwrap().cost_usd >= trough.unwrap().cost_usd);
        // The traffic-aware schedule can't cost more than static peak
        // provisioning, or than the best homogeneous schedule.
        assert!(p.total_cost_usd <= p.static_peak_cost_usd + 1e-9);
        let (_, homo) = p.best_homogeneous.clone().unwrap();
        assert!(p.total_cost_usd <= homo + 1e-9);
    }

    #[test]
    fn pruned_plan_equals_exhaustive_plan_end_to_end() {
        let model = by_name("llama3.1-8b").unwrap();
        let legs = [
            ClusterSpec::new(h100_sxm(), 8, 1),
            ClusterSpec::new(a100_sxm(), 8, 1),
        ];
        let sils: Vec<Silicon> =
            legs.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
        let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> = legs
            .iter()
            .zip(&sils)
            .map(|(c, s)| (*c, s as &dyn LatencyOracle))
            .collect();
        let mut sp = spec(6);
        sp.prune = true;
        let pruned = plan(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        sp.prune = false;
        let full = plan(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        assert!(pruned.options_pruned > 0, "prune should discard something");
        assert_eq!(full.options_pruned, 0);
        assert_eq!(pruned.total_cost_usd, full.total_cost_usd);
        assert_eq!(pruned.windows.len(), full.windows.len());
        for (a, b) in pruned.windows.iter().zip(&full.windows) {
            assert_eq!(a.gpu, b.gpu);
            assert_eq!(a.cand, b.cand);
            assert_eq!(a.replicas, b.replicas);
            assert_eq!(a.cost_usd, b.cost_usd);
        }
    }

    #[test]
    fn warm_memo_plans_are_identical() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let memo = MemoOracle::new(&sil);
        let legs: Vec<(ClusterSpec, &MemoOracle<'_>)> = vec![(cluster, &memo)];
        let sp = spec(4);
        let a = plan_cached(&model, Framework::TrtLlm, &sp, &legs).unwrap();
        let b = plan_cached(&model, Framework::TrtLlm, &sp, &legs).unwrap();
        let (hits, _) = memo.stats();
        assert!(hits > 0);
        assert_eq!(a.total_cost_usd, b.total_cost_usd);
        for (x, y) in a.windows.iter().zip(&b.windows) {
            assert_eq!(x.cand, y.cand);
            assert_eq!(x.replicas, y.replicas);
        }
    }

    #[test]
    fn infeasible_sla_is_a_clean_error() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut sp = spec(2);
        sp.workload.sla.min_speed = 1e9; // nothing generates that fast
        let err = plan(&model, Framework::TrtLlm, &sp, &[(cluster, &sil)]).unwrap_err();
        assert!(err.to_string().contains("no SLA-feasible"), "{err:#}");
    }

    #[test]
    fn to_json_shape() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let sp = spec(3);
        let p = plan(&model, Framework::TrtLlm, &sp, &[(cluster, &sil)]).unwrap();
        let j = p.to_json(&sp.workload);
        assert_eq!(j.req("windows").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.req_f64("total_cost_usd").unwrap() > 0.0);
        assert!(j.req_f64("static_peak_cost_usd").unwrap() >= j.req_f64("total_cost_usd").unwrap());
        let w0 = &j.req("windows").unwrap().as_arr().unwrap()[0];
        assert!(w0.req_f64("replicas").unwrap() >= 0.0);
        assert!(w0.get("config").is_some());
    }

    /// The replan bit-equality pin compares serialized plans:
    /// `DeploymentPlan` carries no wall-clock fields, so string equality
    /// of the JSON is exactly "same schedule, same costs, bit for bit".
    fn assert_plans_identical(a: &DeploymentPlan, b: &DeploymentPlan, wl: &WorkloadSpec) {
        assert_eq!(a.to_json(wl).to_string(), b.to_json(wl).to_string());
    }

    /// A swapped calibration artifact for recalibration tests: same
    /// silicon, uniformly slower operators.
    struct Recalibrated<'a> {
        inner: &'a Silicon,
        factor: f64,
    }

    impl LatencyOracle for Recalibrated<'_> {
        fn op_latency_us(&self, op: &crate::ops::Op) -> f64 {
            self.inner.op_latency_us(op) * self.factor
        }
    }

    #[test]
    fn plan_arena_matches_plan_cached_bit_for_bit() {
        let model = by_name("llama3.1-8b").unwrap();
        let legs = [ClusterSpec::new(h100_sxm(), 8, 1), ClusterSpec::new(a100_sxm(), 8, 1)];
        let sils: Vec<Silicon> =
            legs.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
        let memos: Vec<MemoOracle<'_>> = sils.iter().map(|s| MemoOracle::new(s)).collect();
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            legs.iter().zip(&memos).map(|(c, m)| (*c, m)).collect();
        for prune in [true, false] {
            let mut sp = spec(4);
            sp.prune = prune;
            let a = plan_cached(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
            let (b, arena) = plan_arena(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
            assert_plans_identical(&a, &b, &sp.workload);
            assert!(arena.baseline_priced_configs() > 0);
            assert_eq!(arena.leg_gpus(), vec!["h100-sxm", "a100-sxm"]);
        }
    }

    #[test]
    fn replan_window_edit_splices_bit_identically_without_repricing() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let memo = MemoOracle::new(&sil);
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> = vec![(cluster, &memo)];
        let sp = spec(6);
        let (baseline, mut arena) =
            plan_arena(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        let delta = SearchDelta {
            window_edits: vec![(2, 500.0), (4, 1.0)],
            ..SearchDelta::default()
        };
        let rep = replan(&model, Framework::TrtLlm, &mut arena, &baseline, &delta, &[]).unwrap();
        assert_eq!(rep.repriced_configs, 0, "window edits must price nothing");
        assert!(rep.windows_changed >= 1, "a 4x demand surge must change the schedule");
        let mut patched = sp.clone();
        patched.demand_override = vec![(2, 500.0), (4, 1.0)];
        let fresh = plan_cached(&model, Framework::TrtLlm, &patched, &fleet).unwrap();
        assert_plans_identical(&rep.plan, &fresh, &sp.workload);
        // A second window edit stacks on the first (later entries win).
        let delta2 =
            SearchDelta { window_edits: vec![(2, 40.0)], ..SearchDelta::default() };
        let rep2 =
            replan(&model, Framework::TrtLlm, &mut arena, &rep.plan, &delta2, &[]).unwrap();
        patched.demand_override.push((2, 40.0));
        let fresh2 = plan_cached(&model, Framework::TrtLlm, &patched, &fleet).unwrap();
        assert_plans_identical(&rep2.plan, &fresh2, &sp.workload);
    }

    #[test]
    fn replan_reprice_matches_from_scratch_and_prices_nothing() {
        let model = by_name("llama3.1-8b").unwrap();
        let legs = [ClusterSpec::new(h100_sxm(), 8, 1), ClusterSpec::new(a100_sxm(), 8, 1)];
        let sils: Vec<Silicon> =
            legs.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
        let memos: Vec<MemoOracle<'_>> = sils.iter().map(|s| MemoOracle::new(s)).collect();
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            legs.iter().zip(&memos).map(|(c, m)| (*c, m)).collect();
        let sp = spec(6);
        let (baseline, mut arena) =
            plan_arena(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        // Make the A100 nearly free: its options storm the cost
        // frontier and the H100-heavy schedule has to yield.
        let delta = SearchDelta {
            reprice: vec![("a100".to_string(), 0.10)],
            ..SearchDelta::default()
        };
        let rep = replan(&model, Framework::TrtLlm, &mut arena, &baseline, &delta, &[]).unwrap();
        assert_eq!(rep.repriced_configs, 0, "repricing is a pure cost re-derivation");
        assert!(rep.baseline_priced_configs > 0);
        let mut cheap_a100 = a100_sxm();
        cheap_a100.usd_per_hour = 0.10;
        let legs2 = [legs[0], ClusterSpec::new(cheap_a100, 8, 1)];
        let sils2: Vec<Silicon> =
            legs2.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
        let memos2: Vec<MemoOracle<'_>> = sils2.iter().map(|s| MemoOracle::new(s)).collect();
        let fleet2: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            legs2.iter().zip(&memos2).map(|(c, m)| (*c, m)).collect();
        let fresh = plan_cached(&model, Framework::TrtLlm, &sp, &fleet2).unwrap();
        assert_plans_identical(&rep.plan, &fresh, &sp.workload);
    }

    #[test]
    fn replan_remove_leg_retracts_and_readmits_bit_identically() {
        let model = by_name("llama3.1-8b").unwrap();
        let legs = [ClusterSpec::new(h100_sxm(), 8, 1), ClusterSpec::new(a100_sxm(), 8, 1)];
        let sils: Vec<Silicon> =
            legs.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
        let memos: Vec<MemoOracle<'_>> = sils.iter().map(|s| MemoOracle::new(s)).collect();
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            legs.iter().zip(&memos).map(|(c, m)| (*c, m)).collect();
        let sp = spec(6);
        let (baseline, mut arena) =
            plan_arena(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        let delta =
            SearchDelta { remove_legs: vec!["a100".to_string()], ..SearchDelta::default() };
        let rep = replan(&model, Framework::TrtLlm, &mut arena, &baseline, &delta, &[]).unwrap();
        assert_eq!(rep.repriced_configs, 0, "removal is a pure retraction");
        assert_eq!(arena.leg_gpus(), vec!["h100-sxm"]);
        let fresh = plan_cached(&model, Framework::TrtLlm, &sp, &fleet[..1]).unwrap();
        assert_plans_identical(&rep.plan, &fresh, &sp.workload);
    }

    #[test]
    fn replan_add_leg_sweeps_only_the_new_leg() {
        let model = by_name("llama3.1-8b").unwrap();
        let h100 = ClusterSpec::new(h100_sxm(), 8, 1);
        let a100 = ClusterSpec::new(a100_sxm(), 8, 1);
        let sil_h = Silicon::new(h100, Framework::TrtLlm.profile());
        let sil_a = Silicon::new(a100, Framework::TrtLlm.profile());
        let memo_h = MemoOracle::new(&sil_h);
        let memo_a = MemoOracle::new(&sil_a);
        let sp = spec(6);
        let (baseline, mut arena) =
            plan_arena(&model, Framework::TrtLlm, &sp, &[(h100, &memo_h)]).unwrap();
        let delta =
            SearchDelta { add_legs: vec!["a100".to_string()], ..SearchDelta::default() };
        let rep = replan(
            &model,
            Framework::TrtLlm,
            &mut arena,
            &baseline,
            &delta,
            &[(a100, &memo_a)],
        )
        .unwrap();
        assert!(rep.repriced_configs > 0, "the added leg must be swept");
        assert!(
            rep.repriced_configs < rep.baseline_priced_configs,
            "replan must price strictly fewer configs than a full re-search"
        );
        let fleet2: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            vec![(h100, &memo_h), (a100, &memo_a)];
        let fresh = plan_cached(&model, Framework::TrtLlm, &sp, &fleet2).unwrap();
        assert_plans_identical(&rep.plan, &fresh, &sp.workload);
    }

    #[test]
    fn replan_recalibrate_matches_from_scratch_with_the_new_oracle() {
        let model = by_name("llama3.1-8b").unwrap();
        let h100 = ClusterSpec::new(h100_sxm(), 8, 1);
        let a100 = ClusterSpec::new(a100_sxm(), 8, 1);
        let sil_h = Silicon::new(h100, Framework::TrtLlm.profile());
        let sil_a = Silicon::new(a100, Framework::TrtLlm.profile());
        let memo_h = MemoOracle::new(&sil_h);
        let memo_a = MemoOracle::new(&sil_a);
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            vec![(h100, &memo_h), (a100, &memo_a)];
        let sp = spec(6);
        let (baseline, mut arena) =
            plan_arena(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        // Recalibrating the *first* leg forces the mid-list tracked
        // accumulator rebuild.
        let recal = Recalibrated { inner: &sil_h, factor: 1.25 };
        let memo_recal = MemoOracle::new(&recal);
        let delta =
            SearchDelta { recalibrate: vec!["h100".to_string()], ..SearchDelta::default() };
        let rep = replan(
            &model,
            Framework::TrtLlm,
            &mut arena,
            &baseline,
            &delta,
            &[(h100, &memo_recal)],
        )
        .unwrap();
        assert!(rep.repriced_configs > 0, "the recalibrated leg must re-sweep");
        assert!(rep.repriced_configs < rep.baseline_priced_configs);
        let memo_recal2 = MemoOracle::new(&recal);
        let fleet2: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            vec![(h100, &memo_recal2), (a100, &memo_a)];
        let fresh = plan_cached(&model, Framework::TrtLlm, &sp, &fleet2).unwrap();
        assert_plans_identical(&rep.plan, &fresh, &sp.workload);
    }

    #[test]
    fn replan_rejects_bad_deltas() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let memo = MemoOracle::new(&sil);
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> = vec![(cluster, &memo)];
        let sp = spec(3);
        let (baseline, mut arena) =
            plan_arena(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        // Empty delta.
        let err = replan(
            &model,
            Framework::TrtLlm,
            &mut arena,
            &baseline,
            &SearchDelta::default(),
            &[],
        )
        .unwrap_err();
        assert!(err.to_string().contains("empty"), "{err:#}");
        // Unknown GPU token.
        let delta =
            SearchDelta { remove_legs: vec!["tpu9000".to_string()], ..SearchDelta::default() };
        let err =
            replan(&model, Framework::TrtLlm, &mut arena, &baseline, &delta, &[]).unwrap_err();
        assert!(err.to_string().contains("unknown gpu"), "{err:#}");
        // A leg the fleet doesn't have.
        let delta =
            SearchDelta { remove_legs: vec!["b200".to_string()], ..SearchDelta::default() };
        let err =
            replan(&model, Framework::TrtLlm, &mut arena, &baseline, &delta, &[]).unwrap_err();
        assert!(err.to_string().contains("no fleet leg"), "{err:#}");
        // Missing swept pair for an added leg.
        let delta =
            SearchDelta { add_legs: vec!["a100".to_string()], ..SearchDelta::default() };
        let err =
            replan(&model, Framework::TrtLlm, &mut arena, &baseline, &delta, &[]).unwrap_err();
        assert!(err.to_string().contains("swept"), "{err:#}");
    }
}

//! Traffic-aware capacity planner: cost/SLA deployment schedules over
//! dynamic workloads.
//!
//! The layer above per-configuration pricing (cf. Vidur's what-if
//! search and GUIDE's heterogeneous-deployment planning, PAPERS.md):
//! given a time-varying traffic model ([`traffic::TrafficModel`]), a
//! candidate fleet of GPU types priced by `usd_per_hour`
//! ([`crate::hardware::GpuSpec`]) and an SLA, find how many replicas of
//! which engine configuration — on which GPU type — to run in each
//! time window so the SLA holds at minimum cost.
//!
//! Pipeline per plan:
//! 1. each fleet leg is priced by the sweep engine
//!    ([`crate::search::TaskRunner::run_sweep_cached`]); a leg-owned
//!    [`crate::perfdb::MemoOracle`] is shared across every window of
//!    the horizon — and across repeated plans when the caller holds
//!    its memos ([`plan_cached`]; operator latencies are
//!    cluster-specific, so legs do not share one memo — each leg's is
//!    reused instead);
//! 2. SLA-feasible candidates become deployment *units*
//!    ([`options::PricedOption`]), k-objective-pruned on the
//!    (−cost/h, capacity, speed, −footprint) frontier
//!    ([`options::prune_options`] over
//!    [`crate::pareto::FrontierAccumulator`]);
//! 3. the per-window min-cost schedule is exact
//!    ([`schedule::optimize`]; brute-force-pinned in tests), and the
//!    plan reports the heterogeneity dividend (vs the best
//!    single-GPU-type schedule) and the elasticity dividend (vs
//!    statically provisioning the peak for the whole horizon).

pub mod options;
pub mod schedule;
pub mod traffic;

pub use options::{options_from_report, prune_options, PricedOption};
pub use schedule::{choose_window, optimize, replicas_needed, Schedule, WindowChoice};
pub use traffic::TrafficModel;

use crate::config::{Candidate, WorkloadSpec};
use crate::frameworks::Framework;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::perfdb::{LatencyOracle, MemoOracle};
use crate::perfmodel::PerfEstimate;
use crate::search::{RunOptions, SearchSpace, TaskRunner};
use crate::util::json::{self, Json};

/// Planner input.
#[derive(Clone, Debug)]
pub struct PlanSpec {
    /// The request shape + SLA every window must serve.
    pub workload: WorkloadSpec,
    pub traffic: TrafficModel,
    /// Number of scheduling windows in the horizon.
    pub windows: usize,
    /// Window length, hours.
    pub window_h: f64,
    /// Per-window GPU budget across the fleet (None = unbounded).
    pub max_gpus: Option<u32>,
    /// k-objective-prune the option set before the window search (the
    /// optimal schedule is preserved exactly; tested).
    pub prune: bool,
}

impl PlanSpec {
    pub fn new(workload: WorkloadSpec, traffic: TrafficModel, windows: usize, window_h: f64) -> Self {
        PlanSpec { workload, traffic, windows, window_h, max_gpus: None, prune: true }
    }
}

/// One window of the final plan.
#[derive(Clone, Debug)]
pub struct WindowPlan {
    pub index: usize,
    /// Window span, hours from horizon start.
    pub t_start_h: f64,
    pub t_end_h: f64,
    /// Peak instantaneous demand inside the window (what the planner
    /// provisions for).
    pub demand_qps: f64,
    /// GPU preset name of the chosen option.
    pub gpu: String,
    /// The deployment unit (one engine replica / one xPyD composite).
    pub cand: Candidate,
    /// Units deployed this window (0 = scale-to-zero).
    pub replicas: u32,
    /// Total GPUs this window (u64: replicas × unit GPUs can exceed
    /// u32 for extreme uncapped demands).
    pub gpus: u64,
    /// Aggregate serveable rate, queries/s.
    pub capacity_qps: f64,
    /// Per-request projection of the chosen unit.
    pub est: PerfEstimate,
    pub cost_usd: f64,
}

/// A full cost-minimal deployment schedule.
#[derive(Clone, Debug)]
pub struct DeploymentPlan {
    pub windows: Vec<WindowPlan>,
    pub total_cost_usd: f64,
    /// Best schedule restricted to a single GPU type (None when no
    /// single type can serve every window); the gap to `total_cost_usd`
    /// is the heterogeneity dividend.
    pub best_homogeneous: Option<(String, f64)>,
    /// Cost of statically provisioning the peak window's deployment for
    /// the entire horizon (what a non-traffic-aware search would buy).
    pub static_peak_cost_usd: f64,
    /// SLA-feasible options priced across the fleet.
    pub options_considered: usize,
    /// Options discarded by the k-objective frontier prune.
    pub options_pruned: usize,
}

impl DeploymentPlan {
    /// Savings of the traffic-aware schedule vs static peak
    /// provisioning, in [0, 1).
    pub fn elastic_savings_frac(&self) -> f64 {
        if self.static_peak_cost_usd > 0.0 {
            1.0 - self.total_cost_usd / self.static_peak_cost_usd
        } else {
            0.0
        }
    }

    /// Maximal runs of consecutive windows deploying the *same unit on
    /// the same GPU type* (replica counts may differ): the granularity
    /// at which replicas keep their identity when a schedule is
    /// executed. Scaling inside a segment adds/removes replicas of a
    /// running deployment; a segment boundary tears the fleet down and
    /// launches a different engine. [`crate::fleetsim`] replays each
    /// segment as one fleet of persistent replicas. Returns inclusive
    /// `(first, last)` window-index pairs covering the horizon.
    pub fn segments(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for (w, win) in self.windows.iter().enumerate() {
            match out.last_mut() {
                Some((_, last))
                    if *last + 1 == w && {
                        let prev = &self.windows[*last];
                        prev.gpu == win.gpu && prev.cand == win.cand
                    } =>
                {
                    *last = w;
                }
                _ => out.push((w, w)),
            }
        }
        out
    }

    pub fn to_json(&self, wl: &WorkloadSpec) -> Json {
        let mut windows = Vec::new();
        for w in &self.windows {
            let mut o = Json::obj();
            o.set("window", json::num(w.index as f64))
                .set("t_start_h", json::num(w.t_start_h))
                .set("t_end_h", json::num(w.t_end_h))
                .set("demand_qps", json::num(w.demand_qps))
                .set("gpu", json::s(&w.gpu))
                .set("config", json::s(&w.cand.label()))
                .set("mode", json::s(w.cand.mode().name()))
                .set("replicas", json::num(w.replicas as f64))
                .set("gpus", json::num(w.gpus as f64))
                .set("capacity_qps", json::num(w.capacity_qps))
                .set("ttft_ms", json::num(w.est.ttft_ms))
                .set("speed", json::num(w.est.speed))
                .set("cost_usd", json::num(w.cost_usd));
            windows.push(o);
        }
        let mut o = Json::obj();
        o.set("workload", wl.to_json())
            .set("windows", Json::Arr(windows))
            .set("total_cost_usd", json::num(self.total_cost_usd))
            .set("static_peak_cost_usd", json::num(self.static_peak_cost_usd))
            .set("elastic_savings_frac", json::num(self.elastic_savings_frac()))
            .set("options_considered", json::num(self.options_considered as f64))
            .set("options_pruned", json::num(self.options_pruned as f64));
        if let Some((gpu, cost)) = &self.best_homogeneous {
            let mut h = Json::obj();
            h.set("gpu", json::s(gpu)).set("cost_usd", json::num(*cost));
            o.set("best_homogeneous", h);
        }
        o
    }
}

/// Plan against caller-owned per-leg memos (the warm path: callers
/// that reuse memos across plans, as the memo-warm half of
/// `benches/planner.rs` does). Legs are `(cluster, memo)` pairs; each
/// memo must wrap an oracle profiled for that cluster.
pub fn plan_cached(
    model: &ModelArch,
    framework: Framework,
    spec: &PlanSpec,
    fleet: &[(ClusterSpec, &MemoOracle<'_>)],
) -> anyhow::Result<DeploymentPlan> {
    anyhow::ensure!(spec.windows > 0, "plan horizon needs at least one window");
    // Bounds the per-request work for service callers (a year of hourly
    // windows is 8760; nobody plans more granularly than this).
    anyhow::ensure!(
        spec.windows <= 100_000,
        "plan horizon of {} windows is unreasonably large (max 100000)",
        spec.windows
    );
    anyhow::ensure!(spec.window_h > 0.0, "window length must be positive hours");
    anyhow::ensure!(!fleet.is_empty(), "the candidate fleet is empty");
    spec.traffic.validate()?;
    let wl = &spec.workload;
    // Provision each window for its *peak* instantaneous demand — a
    // midpoint-sampled rising window would run under capacity at its
    // edges (`TrafficModel::qps_window_peak`).
    let demands = spec.traffic.qps_window_peak(spec.windows, spec.window_h);

    // 1. Price every fleet leg (one single-scenario sweep per leg; the
    //    leg's memo keeps repeat plans warm). Reports must be unpruned —
    //    see `options_from_report`.
    let mut all: Vec<PricedOption> = Vec::new();
    for (cluster, memo) in fleet {
        // Mixed-generation fleets need no special-casing here:
        // `SearchSpace::engine_grid` falls back to the GPU's preferred
        // dtype when none of the default sweep dtypes is supported
        // (FP8 on Ampere), so every leg contributes options.
        let space = SearchSpace::default_for(model, framework);
        let runner = TaskRunner::new(model, cluster, space, wl.clone());
        let reports =
            runner.run_sweep_cached(memo, std::slice::from_ref(wl), &RunOptions::default());
        all.extend(options_from_report(&cluster.gpu, wl, &reports[0]));
    }
    anyhow::ensure!(
        !all.is_empty(),
        "no SLA-feasible deployment option on any fleet leg — relax the SLA or widen the fleet"
    );
    let considered = all.len();

    // 2. k-objective frontier prune (schedule-transparent).
    let kept: Vec<usize> =
        if spec.prune { prune_options(&all) } else { (0..all.len()).collect() };
    let pruned_set: Vec<PricedOption> = kept.iter().map(|&i| all[i].clone()).collect();

    // 3. Exact per-window min-cost schedule.
    let sched = optimize(&pruned_set, &demands, spec.window_h, spec.max_gpus);
    let mut windows = Vec::with_capacity(spec.windows);
    for (w, choice) in sched.choices.iter().enumerate() {
        let c = choice.ok_or_else(|| {
            anyhow::anyhow!(
                "window {w} (demand {:.1} QPS) cannot be served by any option (GPU cap: {:?})",
                demands[w],
                spec.max_gpus
            )
        })?;
        let o = &pruned_set[c.option];
        windows.push(WindowPlan {
            index: w,
            t_start_h: w as f64 * spec.window_h,
            t_end_h: (w + 1) as f64 * spec.window_h,
            demand_qps: demands[w],
            gpu: o.gpu.clone(),
            cand: o.cand.clone(),
            replicas: c.replicas,
            gpus: c.replicas as u64 * o.unit_gpus as u64,
            capacity_qps: c.replicas as f64 * o.qps_per_unit,
            est: o.est,
            cost_usd: c.cost_usd,
        });
    }

    // Reference points: best single-GPU-type schedule and static peak
    // provisioning (both over the *unpruned* option set, so they are
    // honest baselines rather than artifacts of the prune).
    let mut best_homogeneous: Option<(String, f64)> = None;
    let mut gpu_names: Vec<&str> = all.iter().map(|o| o.gpu.as_str()).collect();
    gpu_names.sort_unstable();
    gpu_names.dedup();
    for name in gpu_names {
        let subset: Vec<PricedOption> =
            all.iter().filter(|o| o.gpu == name).cloned().collect();
        let s = optimize(&subset, &demands, spec.window_h, spec.max_gpus);
        let improves = match &best_homogeneous {
            Some((_, c)) => s.total_cost_usd < *c,
            None => true,
        };
        if s.choices.iter().all(|c| c.is_some()) && improves {
            best_homogeneous = Some((name.to_string(), s.total_cost_usd));
        }
    }
    let peak = demands.iter().cloned().fold(0.0f64, f64::max);
    let static_peak_cost_usd = choose_window(&all, peak, spec.window_h, spec.max_gpus)
        .map(|c| c.cost_usd * spec.windows as f64)
        .unwrap_or(f64::INFINITY);

    Ok(DeploymentPlan {
        windows,
        total_cost_usd: sched.total_cost_usd,
        best_homogeneous,
        static_peak_cost_usd,
        options_considered: considered,
        options_pruned: considered - kept.len(),
    })
}

/// Plan with fresh (cold) memos over plain oracles — the CLI path.
pub fn plan(
    model: &ModelArch,
    framework: Framework,
    spec: &PlanSpec,
    fleet: &[(ClusterSpec, &dyn LatencyOracle)],
) -> anyhow::Result<DeploymentPlan> {
    let memos: Vec<MemoOracle<'_>> =
        fleet.iter().map(|(_, oracle)| MemoOracle::new(*oracle)).collect();
    let legs: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        fleet.iter().zip(&memos).map(|((cluster, _), memo)| (*cluster, memo)).collect();
    plan_cached(model, framework, spec, &legs)
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags};
    use crate::models::Dtype;

    /// A synthetic option: the schedule layer only reads `unit_gpus`,
    /// `usd_per_hour`, `qps_per_unit` and the objectives.
    pub fn opt(gpu: &str, unit_gpus: u32, usd_per_hour: f64, qps: f64, speed: f64) -> PricedOption {
        let eng = EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(unit_gpus),
            batch: 16,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        };
        PricedOption {
            gpu: gpu.to_string(),
            cand: Candidate::Aggregated { engine: eng, replicas: 1 },
            unit_gpus,
            usd_per_hour,
            qps_per_unit: qps,
            est: PerfEstimate {
                ttft_ms: 100.0,
                tpot_ms: 1000.0 / speed,
                speed,
                thru_per_gpu: 1.0,
                concurrency: 16,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{a100_sxm, h100_sxm};
    use crate::models::by_name;
    use crate::silicon::Silicon;

    fn spec(windows: usize) -> PlanSpec {
        PlanSpec::new(
            WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0),
            TrafficModel::Diurnal { peak_qps: 120.0, trough_qps: 5.0, period_h: 24.0 },
            windows,
            24.0 / windows as f64,
        )
    }

    #[test]
    fn plan_serves_every_window_and_scales_with_demand() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let spec = spec(8);
        let p = plan(&model, Framework::TrtLlm, &spec, &[(cluster, &sil)]).unwrap();
        assert_eq!(p.windows.len(), 8);
        assert!(p.total_cost_usd > 0.0);
        assert!(p.options_considered > 0);
        let demands = spec.traffic.qps_window_peak(8, 3.0);
        for (w, d) in p.windows.iter().zip(&demands) {
            assert_eq!(w.demand_qps, *d);
            assert!(w.capacity_qps >= w.demand_qps, "window {} under-provisioned", w.index);
            assert!(w.est.meets(&spec.workload.sla));
            assert!(w.gpus >= w.replicas as u64, "unit is at least one GPU");
        }
        // Min-cost per window is nondecreasing in demand, so the peak
        // window costs at least the trough window.
        let peak = p.windows.iter().cloned().fold(None::<WindowPlan>, |m, w| match m {
            Some(b) if b.demand_qps >= w.demand_qps => Some(b),
            _ => Some(w),
        });
        let trough = p.windows.iter().cloned().fold(None::<WindowPlan>, |m, w| match m {
            Some(b) if b.demand_qps <= w.demand_qps => Some(b),
            _ => Some(w),
        });
        assert!(peak.unwrap().cost_usd >= trough.unwrap().cost_usd);
        // The traffic-aware schedule can't cost more than static peak
        // provisioning, or than the best homogeneous schedule.
        assert!(p.total_cost_usd <= p.static_peak_cost_usd + 1e-9);
        let (_, homo) = p.best_homogeneous.clone().unwrap();
        assert!(p.total_cost_usd <= homo + 1e-9);
    }

    #[test]
    fn pruned_plan_equals_exhaustive_plan_end_to_end() {
        let model = by_name("llama3.1-8b").unwrap();
        let legs = [
            ClusterSpec::new(h100_sxm(), 8, 1),
            ClusterSpec::new(a100_sxm(), 8, 1),
        ];
        let sils: Vec<Silicon> =
            legs.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
        let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> = legs
            .iter()
            .zip(&sils)
            .map(|(c, s)| (*c, s as &dyn LatencyOracle))
            .collect();
        let mut sp = spec(6);
        sp.prune = true;
        let pruned = plan(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        sp.prune = false;
        let full = plan(&model, Framework::TrtLlm, &sp, &fleet).unwrap();
        assert!(pruned.options_pruned > 0, "prune should discard something");
        assert_eq!(full.options_pruned, 0);
        assert_eq!(pruned.total_cost_usd, full.total_cost_usd);
        assert_eq!(pruned.windows.len(), full.windows.len());
        for (a, b) in pruned.windows.iter().zip(&full.windows) {
            assert_eq!(a.gpu, b.gpu);
            assert_eq!(a.cand, b.cand);
            assert_eq!(a.replicas, b.replicas);
            assert_eq!(a.cost_usd, b.cost_usd);
        }
    }

    #[test]
    fn warm_memo_plans_are_identical() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let memo = MemoOracle::new(&sil);
        let legs: Vec<(ClusterSpec, &MemoOracle<'_>)> = vec![(cluster, &memo)];
        let sp = spec(4);
        let a = plan_cached(&model, Framework::TrtLlm, &sp, &legs).unwrap();
        let b = plan_cached(&model, Framework::TrtLlm, &sp, &legs).unwrap();
        let (hits, _) = memo.stats();
        assert!(hits > 0);
        assert_eq!(a.total_cost_usd, b.total_cost_usd);
        for (x, y) in a.windows.iter().zip(&b.windows) {
            assert_eq!(x.cand, y.cand);
            assert_eq!(x.replicas, y.replicas);
        }
    }

    #[test]
    fn infeasible_sla_is_a_clean_error() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut sp = spec(2);
        sp.workload.sla.min_speed = 1e9; // nothing generates that fast
        let err = plan(&model, Framework::TrtLlm, &sp, &[(cluster, &sil)]).unwrap_err();
        assert!(err.to_string().contains("no SLA-feasible"), "{err:#}");
    }

    #[test]
    fn to_json_shape() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let sp = spec(3);
        let p = plan(&model, Framework::TrtLlm, &sp, &[(cluster, &sil)]).unwrap();
        let j = p.to_json(&sp.workload);
        assert_eq!(j.req("windows").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.req_f64("total_cost_usd").unwrap() > 0.0);
        assert!(j.req_f64("static_peak_cost_usd").unwrap() >= j.req_f64("total_cost_usd").unwrap());
        let w0 = &j.req("windows").unwrap().as_arr().unwrap()[0];
        assert!(w0.req_f64("replicas").unwrap() >= 0.0);
        assert!(w0.get("config").is_some());
    }
}

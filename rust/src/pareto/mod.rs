//! Pareto Analyzer (paper §4.1 step 4): filter SLA-valid configurations,
//! extract the throughput-vs-speed Pareto frontier (Fig 1 / Fig 8), and
//! rank the feasible set by per-GPU system throughput.
//!
//! The frontier extraction is a sort-based O(n log n) scan (the seed
//! implementation was the O(n²) dominated-by-anything filter), and
//! [`FrontierAccumulator`] provides the *incremental* variant the search
//! engine uses to discard dominated candidates while the sweep is still
//! running instead of after it.
//!
//! Dominance is also exposed in **k-objective** form ([`dominates`],
//! [`k_frontier_indices`], [`FrontierAccumulator::offer_point`]): the
//! capacity planner ([`crate::planner`]) prunes deployment options on
//! the (−cost/hour, request capacity, speed, −GPU footprint) frontier
//! with exactly the same accumulator the 2-objective sweep path uses.

use crate::config::Sla;
use crate::perfmodel::PerfEstimate;
use crate::search::runner::Evaluated;

/// Full analysis of a search report.
#[derive(Clone, Debug)]
pub struct Analysis {
    /// SLA-feasible candidates, best throughput first.
    pub feasible: Vec<Evaluated>,
    /// Indices (into `feasible`) forming the speed/throughput frontier.
    pub frontier: Vec<usize>,
}

impl Analysis {
    pub fn best(&self) -> Option<&Evaluated> {
        self.feasible.first()
    }
}

/// Extract the Pareto frontier over (generation speed, per-GPU
/// throughput) from an arbitrary point set. Returns indices into the
/// input, sorted by speed ascending.
///
/// Identical (speed, thru) pairs are deduplicated deterministically:
/// the **smallest input index** represents each frontier point (the
/// seed's retain-based filter kept ties in sort-dependent order; the
/// tie rule is now explicit and tested).
pub fn frontier_indices(points: &[PerfEstimate]) -> Vec<usize> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    // Sort by speed desc, thru desc, index asc — wholly deterministic.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        points[b]
            .speed
            .partial_cmp(&points[a].speed)
            .unwrap()
            .then(points[b].thru_per_gpu.partial_cmp(&points[a].thru_per_gpu).unwrap())
            .then(a.cmp(&b))
    });
    // One pass over speed groups: a group survives iff its max throughput
    // strictly exceeds the best throughput seen at any higher speed
    // (otherwise some faster point dominates it).
    let mut out = Vec::new();
    let mut best_thru_above = f64::NEG_INFINITY;
    let mut i = 0;
    while i < n {
        let speed = points[idx[i]].speed;
        let mut j = i;
        while j < n && points[idx[j]].speed == speed {
            j += 1;
        }
        // Within the group the sort puts max-thru first.
        let group_max_thru = points[idx[i]].thru_per_gpu;
        if group_max_thru > best_thru_above {
            let mut rep = usize::MAX;
            for &k in &idx[i..j] {
                if points[k].thru_per_gpu == group_max_thru {
                    rep = rep.min(k);
                }
            }
            out.push(rep);
            best_thru_above = group_max_thru;
        }
        i = j;
    }
    // The scan ran speed-descending; report speed-ascending as before.
    out.reverse();
    out
}

/// Analyze a search result against an SLA.
pub fn analyze(evaluated: &[Evaluated], sla: &Sla) -> Analysis {
    let mut feasible: Vec<Evaluated> =
        evaluated.iter().filter(|e| e.est.meets(sla)).cloned().collect();
    feasible.sort_by(|a, b| b.est.thru_per_gpu.partial_cmp(&a.est.thru_per_gpu).unwrap());
    let pts: Vec<PerfEstimate> = feasible.iter().map(|e| e.est).collect();
    let frontier = frontier_indices(&pts);
    Analysis { feasible, frontier }
}

/// k-objective weak dominance: does `a` dominate `b`? All objectives
/// are maximized; `a` dominates `b` iff `a` is ≥ `b` on every
/// coordinate and strictly greater on at least one. Callers with a
/// minimized objective (cost) negate it.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "objective arity mismatch");
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strict = true;
        }
    }
    strict
}

/// Batch O(n²) k-objective dominance filter: indices of the points not
/// dominated by any other, in ascending input order. Exact duplicates
/// are represented once, by the smallest input index (the same tie rule
/// as [`frontier_indices`]). This is the reference the incremental
/// [`FrontierAccumulator`] is property-tested against; the planner uses
/// it on small option sets where O(n²) is irrelevant.
pub fn k_frontier_indices(points: &[Vec<f64>]) -> Vec<usize> {
    let n = points.len();
    let mut out = Vec::new();
    'outer: for i in 0..n {
        for j in 0..n {
            if j != i && dominates(&points[j], &points[i]) {
                continue 'outer;
            }
        }
        for j in 0..i {
            if points[j] == points[i] {
                continue 'outer; // duplicate — smallest index already kept
            }
        }
        out.push(i);
    }
    out
}

/// Incremental k-objective Pareto frontier for in-sweep pruning.
///
/// The arity is fixed by the first `offer_point` (the 2-objective
/// (speed, thru) convenience [`FrontierAccumulator::offer`] is what the
/// search engine uses; the capacity planner runs (−cost/h, qps
/// capacity, speed, −GPU footprint)). `offer_point` answers "is this
/// point on the running frontier?" in O(k·d) (k = current frontier
/// size, typically tens) and evicts members the new point dominates.
/// Exact duplicates of a live member are rejected, so an
/// accumulator-pruned sweep also deduplicates — the frontier and the
/// argmax of any single objective are preserved exactly (tested against
/// the batch filter and the unpruned sweep path).
#[derive(Clone, Debug, Default)]
pub struct FrontierAccumulator {
    /// Live frontier points in the 2-objective fast path — the sweep
    /// engine's (speed, thru) hot loop stays tuple-based and
    /// allocation-free, exactly as before the k-objective extension.
    pts2: Vec<(f64, f64)>,
    /// Live frontier points at any other arity (the planner's
    /// 4-objective prune).
    ptsk: Vec<Vec<f64>>,
    /// How many offers were rejected (dominated or duplicate).
    rejected: usize,
    /// Tracked-mode arena: every point ever offered through
    /// [`FrontierAccumulator::offer_tracked`], dominated ones included,
    /// so a retraction can re-admit formerly-dominated survivors.
    arena: Vec<TrackedPoint>,
    /// Arena ids of the live frontier, parallel to `ptsk`.
    frontier_ids: Vec<usize>,
}

/// One arena slot of a tracked accumulator (see
/// [`FrontierAccumulator::offer_tracked`]).
#[derive(Clone, Debug)]
struct TrackedPoint {
    pt: Vec<f64>,
    /// False once retracted. Retained (not freed) so arena ids stay
    /// stable across retractions.
    alive: bool,
    /// Did the offer discipline accept this point when it was last
    /// offered/replayed? Mirrors the return value of `offer_point`:
    /// accepted points may later be *evicted* from the running frontier
    /// without becoming un-accepted — the planner's conservative
    /// "kept" semantics ([`crate::planner::prune_options`]).
    accepted: bool,
}

impl FrontierAccumulator {
    pub fn new() -> FrontierAccumulator {
        FrontierAccumulator::default()
    }

    /// The search engine's 2-objective (speed, thru) form — the hot
    /// path (thousands of offers per sweep), kept allocation-free.
    pub fn offer(&mut self, speed: f64, thru: f64) -> bool {
        // Hard assert (not debug): a release-mode arity mix would
        // silently split the frontier across the two stores and return
        // wrong dominance answers. The check is O(1) next to the scan.
        assert!(
            self.ptsk.is_empty() && self.arena.is_empty(),
            "objective arity changed mid-stream"
        );
        for &(s, t) in &self.pts2 {
            if s >= speed && t >= thru {
                self.rejected += 1;
                return false;
            }
        }
        // Not dominated: evict anything the new point weakly dominates.
        self.pts2.retain(|&(s, t)| !(speed >= s && thru >= t));
        self.pts2.push((speed, thru));
        true
    }

    /// Offer a k-objective point. Returns `true` if it joins the
    /// running frontier (caller keeps it), `false` if it is weakly
    /// dominated by — or equal to — an existing member (caller
    /// discards it). Two-element points take the 2-objective fast
    /// path; the arity is otherwise fixed by the first offer.
    pub fn offer_point(&mut self, p: &[f64]) -> bool {
        if let [speed, thru] = *p {
            return self.offer(speed, thru);
        }
        assert!(
            self.pts2.is_empty() && (self.ptsk.is_empty() || self.ptsk[0].len() == p.len()),
            "objective arity changed mid-stream"
        );
        assert!(
            self.arena.is_empty(),
            "streaming offer on a tracked accumulator — use offer_tracked"
        );
        for q in &self.ptsk {
            if q.iter().zip(p).all(|(a, b)| a >= b) {
                self.rejected += 1;
                return false;
            }
        }
        self.ptsk.retain(|q| !p.iter().zip(q.iter()).all(|(a, b)| a >= b));
        self.ptsk.push(p.to_vec());
        true
    }

    /// Convenience for estimates.
    pub fn offer_est(&mut self, est: &PerfEstimate) -> bool {
        self.offer(est.speed, est.thru_per_gpu)
    }

    /// Current frontier size.
    pub fn len(&self) -> usize {
        self.pts2.len() + self.ptsk.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pts2.is_empty() && self.ptsk.is_empty()
    }

    /// Points rejected so far (the pruning win).
    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// The live 2-objective frontier, in offer-survival order. Used by
    /// the search runner to merge per-worker accumulators and to replay
    /// a deterministic strict-dominance filter over the full sweep.
    pub fn points(&self) -> &[(f64, f64)] {
        &self.pts2
    }

    /// Is (speed, thru) *strictly* dominated by a live frontier member
    /// (≥ on both objectives, > on at least one)? Unlike [`offer`],
    /// this treats exact duplicates as NOT dominated, so the answer is
    /// independent of which of two equal candidates was offered first —
    /// the property the parallel sweep needs for scheduling-independent
    /// pruning.
    ///
    /// [`offer`]: FrontierAccumulator::offer
    pub fn dominated(&self, speed: f64, thru: f64) -> bool {
        self.pts2
            .iter()
            .any(|&(s, t)| (s >= speed && t >= thru) && (s > speed || t > thru))
    }

    // --- Tracked mode (differential replan) -----------------------------
    //
    // The replan path (DESIGN.md §11) needs the frontier to support
    // *retractions*: when a delta invalidates a priced option, the
    // option leaves the accumulator and any point it had dominated must
    // be re-admitted. Tracked mode therefore retains every offered
    // point — the dominated-set arena — under a stable arena id.
    //
    // Bit-equality contract: after any interleaving of
    // `offer_tracked` / `retract` / `update`, [`Self::kept_ids`] is
    // exactly the accepted set produced by streaming the *live* arena
    // points through [`Self::offer_point`] in ascending id order, and
    // [`Self::frontier_ids`] is (as a set) `k_frontier_indices` over the
    // live points. `rejected()` accumulates across internal replays and
    // is NOT pinned against a from-scratch run.
    //
    // A retraction of a point that was *rejected* at offer is O(1): a
    // rejected point never entered the running frontier, so it cannot
    // have influenced any later accept/evict decision. Retracting or
    // updating an *accepted* point replays the live points in id order —
    // acceptance of later offers may depend on it (directly or through a
    // chain of evictions), so nothing short of a replay preserves the
    // streaming semantics the planner's conservative kept-set pins.

    /// Offer a point in tracked mode, returning its stable arena id.
    /// Tracked mode is k-objective only and exclusive with the
    /// streaming `offer`/`offer_point` surface on one accumulator.
    pub fn offer_tracked(&mut self, p: &[f64]) -> usize {
        assert!(
            self.pts2.is_empty() && p.len() > 2,
            "tracked mode is k-objective (k > 2) only"
        );
        assert!(
            self.arena.is_empty() || self.arena[0].pt.len() == p.len(),
            "objective arity changed mid-stream"
        );
        let id = self.arena.len();
        self.arena.push(TrackedPoint { pt: p.to_vec(), alive: true, accepted: false });
        self.admit(id);
        id
    }

    /// Retract an arena point (idempotent). See the tracked-mode notes
    /// above for why accepted points trigger a replay.
    pub fn retract(&mut self, id: usize) {
        assert!(id < self.arena.len(), "retract of unknown arena id {id}");
        if !self.arena[id].alive {
            return;
        }
        self.arena[id].alive = false;
        if self.arena[id].accepted {
            self.replay();
        }
    }

    /// Replace an arena point's objectives in place (re-pricing) and
    /// revive it if retracted. Always replays: the new value can change
    /// every downstream accept/evict decision.
    pub fn update(&mut self, id: usize, p: &[f64]) {
        assert!(id < self.arena.len(), "update of unknown arena id {id}");
        assert_eq!(self.arena[id].pt.len(), p.len(), "objective arity changed mid-stream");
        self.arena[id].pt = p.to_vec();
        self.arena[id].alive = true;
        self.replay();
    }

    /// Is this arena point live and offer-accepted?
    pub fn is_kept(&self, id: usize) -> bool {
        self.arena[id].alive && self.arena[id].accepted
    }

    /// Live, offer-accepted arena ids in ascending order — the
    /// conservative kept set (superset of the live frontier).
    pub fn kept_ids(&self) -> Vec<usize> {
        (0..self.arena.len()).filter(|&id| self.is_kept(id)).collect()
    }

    /// Arena ids of the live frontier, in offer-survival order.
    pub fn frontier_ids(&self) -> &[usize] {
        &self.frontier_ids
    }

    /// Number of live arena points.
    pub fn live_len(&self) -> usize {
        self.arena.iter().filter(|t| t.alive).count()
    }

    /// Run the offer discipline for arena point `id` against the live
    /// frontier, recording the accept/reject outcome. Mirrors
    /// [`Self::offer_point`]'s generic branch exactly, with `ptsk` and
    /// `frontier_ids` kept parallel.
    fn admit(&mut self, id: usize) {
        let p = self.arena[id].pt.clone();
        for q in &self.ptsk {
            if q.iter().zip(&p).all(|(a, b)| a >= b) {
                self.rejected += 1;
                self.arena[id].accepted = false;
                return;
            }
        }
        let mut i = 0;
        while i < self.ptsk.len() {
            if p.iter().zip(self.ptsk[i].iter()).all(|(a, b)| a >= b) {
                self.ptsk.remove(i);
                self.frontier_ids.remove(i);
            } else {
                i += 1;
            }
        }
        self.ptsk.push(p);
        self.frontier_ids.push(id);
        self.arena[id].accepted = true;
    }

    /// Rebuild the running frontier by streaming every live arena point
    /// through the offer discipline in ascending id order.
    fn replay(&mut self) {
        self.ptsk.clear();
        self.frontier_ids.clear();
        for id in 0..self.arena.len() {
            if self.arena[id].alive {
                self.admit(id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Candidate, EngineConfig, ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::models::Dtype;
    use crate::util::rng::Rng;

    fn ev(speed: f64, thru: f64, ttft: f64) -> Evaluated {
        let eng = EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(1),
            batch: 1,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        };
        Evaluated {
            cand: Candidate::Aggregated { engine: eng, replicas: 1 },
            est: PerfEstimate {
                ttft_ms: ttft,
                tpot_ms: 1000.0 / speed,
                speed,
                thru_per_gpu: thru,
                concurrency: 1,
            },
        }
    }

    /// The seed's O(n²) implementation, kept as the test reference.
    fn frontier_bruteforce(points: &[PerfEstimate]) -> Vec<usize> {
        let dominated = |a: &PerfEstimate, b: &PerfEstimate| {
            b.speed >= a.speed
                && b.thru_per_gpu >= a.thru_per_gpu
                && (b.speed > a.speed || b.thru_per_gpu > a.thru_per_gpu)
        };
        let mut idx: Vec<usize> = (0..points.len()).collect();
        idx.retain(|&i| {
            !points.iter().enumerate().any(|(j, b)| j != i && dominated(&points[i], b))
        });
        idx.sort_by(|&a, &b| {
            points[a]
                .speed
                .partial_cmp(&points[b].speed)
                .unwrap()
                .then(points[a].thru_per_gpu.partial_cmp(&points[b].thru_per_gpu).unwrap())
        });
        idx.dedup_by(|&mut a, &mut b| {
            points[a].speed == points[b].speed
                && points[a].thru_per_gpu == points[b].thru_per_gpu
        });
        idx
    }

    #[test]
    fn frontier_excludes_dominated() {
        let pts = vec![
            ev(10.0, 100.0, 500.0).est,
            ev(20.0, 80.0, 500.0).est,
            ev(15.0, 90.0, 500.0).est, // dominated by neither
            ev(9.0, 90.0, 500.0).est,  // dominated by (10,100) and (15,90)
            ev(30.0, 30.0, 500.0).est,
        ];
        let f = frontier_indices(&pts);
        assert!(f.contains(&0) && f.contains(&1) && f.contains(&2) && f.contains(&4));
        assert!(!f.contains(&3));
    }

    #[test]
    fn sorted_scan_matches_bruteforce_on_random_sets() {
        let mut rng = Rng::new(0xFA57);
        for case in 0..200 {
            let n = 1 + rng.below(120) as usize;
            let pts: Vec<PerfEstimate> = (0..n)
                .map(|_| {
                    // Coarse values make ties and duplicates likely.
                    ev(
                        (rng.f64() * 8.0).round() * 5.0,
                        (rng.f64() * 8.0).round() * 25.0,
                        100.0,
                    )
                    .est
                })
                .collect();
            let fast = frontier_indices(&pts);
            let slow = frontier_bruteforce(&pts);
            // Same frontier by value, same order.
            let val = |v: &[usize]| -> Vec<(f64, f64)> {
                v.iter().map(|&i| (pts[i].speed, pts[i].thru_per_gpu)).collect()
            };
            assert_eq!(val(&fast), val(&slow), "case {case}");
        }
    }

    #[test]
    fn tie_breaking_is_deterministic_smallest_index() {
        // Three identical frontier points plus a dominated one: exactly
        // one representative survives and it is the smallest index.
        let pts = vec![
            ev(10.0, 50.0, 1.0).est, // duplicate (idx 0) — representative
            ev(10.0, 50.0, 1.0).est, // duplicate (idx 1)
            ev(10.0, 50.0, 1.0).est, // duplicate (idx 2)
            ev(5.0, 40.0, 1.0).est,  // dominated
            ev(20.0, 20.0, 1.0).est,
        ];
        assert_eq!(frontier_indices(&pts), vec![0, 4]);
        // Same set, duplicates shuffled: representative follows the index.
        let pts2 = vec![
            ev(20.0, 20.0, 1.0).est,
            ev(10.0, 50.0, 1.0).est, // smallest duplicate index now 1
            ev(10.0, 50.0, 1.0).est,
        ];
        assert_eq!(frontier_indices(&pts2), vec![1, 0]);
    }

    #[test]
    fn frontier_sorted_by_speed_ascending() {
        let mut rng = Rng::new(7);
        let pts: Vec<PerfEstimate> =
            (0..60).map(|_| ev(1.0 + rng.f64() * 50.0, rng.f64() * 500.0, 1.0).est).collect();
        let f = frontier_indices(&pts);
        assert!(f.windows(2).all(|w| pts[w[0]].speed < pts[w[1]].speed));
    }

    #[test]
    fn accumulator_matches_batch_frontier() {
        let mut rng = Rng::new(0xACC);
        for _ in 0..100 {
            let n = 1 + rng.below(80) as usize;
            let pts: Vec<PerfEstimate> = (0..n)
                .map(|_| {
                    ev((rng.f64() * 6.0).round() * 7.0, (rng.f64() * 6.0).round() * 13.0, 1.0)
                        .est
                })
                .collect();
            let mut acc = FrontierAccumulator::new();
            let mut kept = Vec::new();
            for (i, p) in pts.iter().enumerate() {
                if acc.offer_est(p) {
                    kept.push(i);
                }
            }
            // Every batch-frontier value must be represented among the
            // kept candidates (the accumulator is a conservative filter:
            // it may keep points later discovered to be dominated, but
            // can never lose a frontier point).
            let batch = frontier_indices(&pts);
            for &i in &batch {
                assert!(
                    kept.iter().any(|&k| {
                        pts[k].speed == pts[i].speed
                            && pts[k].thru_per_gpu == pts[i].thru_per_gpu
                    }),
                    "lost frontier point {i}"
                );
            }
            assert_eq!(acc.rejected() + kept.len(), n);
            // And the final frontier of the kept subset equals the batch one.
            let kept_pts: Vec<PerfEstimate> = kept.iter().map(|&k| pts[k]).collect();
            let sub = frontier_indices(&kept_pts);
            let vals = |ids: &[usize], ps: &[PerfEstimate]| -> Vec<(f64, f64)> {
                ids.iter().map(|&i| (ps[i].speed, ps[i].thru_per_gpu)).collect()
            };
            assert_eq!(vals(&sub, &kept_pts), vals(&batch, &pts));
        }
    }

    /// `dominated` is the strict filter the parallel sweep replays after
    /// merging per-worker accumulators: duplicates of a live member are
    /// NOT dominated (both survive), while anything a member strictly
    /// beats is.
    #[test]
    fn strict_dominated_check_and_points_view() {
        let mut acc = FrontierAccumulator::new();
        acc.offer(10.0, 100.0);
        acc.offer(20.0, 50.0);
        assert_eq!(acc.points(), &[(10.0, 100.0), (20.0, 50.0)]);
        // Strictly inside the frontier.
        assert!(acc.dominated(9.0, 100.0));
        assert!(acc.dominated(10.0, 99.0));
        assert!(acc.dominated(5.0, 40.0));
        // Exact duplicate of a member: offer() would reject it, but the
        // strict check keeps it — scheduling independence.
        assert!(!acc.dominated(10.0, 100.0));
        assert!(!acc.dominated(20.0, 50.0));
        // Trade-offs and out-of-envelope points survive.
        assert!(!acc.dominated(15.0, 80.0));
        assert!(!acc.dominated(25.0, 1.0));

        // Consistency with the batch reference on a random coarse grid:
        // strictly dominated ⇔ some *other* point dominates it.
        let mut rng = Rng::new(0xD0D0);
        let pts: Vec<(f64, f64)> = (0..60)
            .map(|_| ((rng.f64() * 6.0).round() * 5.0, (rng.f64() * 6.0).round() * 11.0))
            .collect();
        let mut acc = FrontierAccumulator::new();
        for &(s, t) in &pts {
            acc.offer(s, t);
        }
        for &(s, t) in &pts {
            let brute = pts
                .iter()
                .any(|&(a, b)| (a >= s && b >= t) && (a > s || b > t));
            assert_eq!(acc.dominated(s, t), brute, "point ({s}, {t})");
        }
    }

    #[test]
    fn dominance_requires_one_strict_coordinate() {
        assert!(dominates(&[2.0, 1.0, 1.0], &[1.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]), "equal points don't dominate");
        assert!(!dominates(&[2.0, 0.5, 1.0], &[1.0, 1.0, 1.0]), "trade-off is not dominance");
        assert!(dominates(&[-1.0, 5.0], &[-2.0, 5.0]), "negated-cost convention");
    }

    #[test]
    fn k_frontier_small_pinned() {
        // (−cost, capacity, speed): a cheap/slow, an expensive/fast, a
        // strictly-worse one, and a duplicate of the first.
        let pts = vec![
            vec![-3.0, 2.0, 10.0],  // frontier (cheap)
            vec![-10.0, 9.0, 30.0], // frontier (big)
            vec![-10.0, 9.0, 20.0], // dominated by idx 1
            vec![-3.0, 2.0, 10.0],  // duplicate of idx 0
        ];
        assert_eq!(k_frontier_indices(&pts), vec![0, 1]);
        assert!(k_frontier_indices(&[]).is_empty());
    }

    /// The incremental accumulator in 3-D matches the batch O(n²)
    /// dominance filter on random point sets, including duplicates and
    /// ties (the satellite property test; mirrored in tests/proptests).
    #[test]
    fn k_accumulator_matches_batch_filter() {
        let mut rng = Rng::new(0x3D3D);
        for case in 0..150 {
            let n = 1 + rng.below(60) as usize;
            // Coarse grid values make ties/duplicates likely; the first
            // coordinate is negative (the planner's −cost convention).
            let pts: Vec<Vec<f64>> = (0..n)
                .map(|_| {
                    vec![
                        -(rng.f64() * 5.0).round() * 2.0,
                        (rng.f64() * 5.0).round() * 3.0,
                        (rng.f64() * 5.0).round() * 7.0,
                    ]
                })
                .collect();
            let mut acc = FrontierAccumulator::new();
            let mut kept = Vec::new();
            for (i, p) in pts.iter().enumerate() {
                if acc.offer_point(p) {
                    kept.push(i);
                }
            }
            assert_eq!(acc.rejected() + kept.len(), n, "case {case}");
            let batch = k_frontier_indices(&pts);
            // The accumulator is a conservative filter: every batch-
            // frontier point survives in `kept` (it may also keep points
            // later discovered to be dominated, never lose one).
            for &i in &batch {
                assert!(kept.iter().any(|&k| pts[k] == pts[i]), "case {case}: lost point {i}");
            }
            // And the frontier of the kept subset equals the batch
            // frontier, value for value, in the same (input) order.
            let kept_pts: Vec<Vec<f64>> = kept.iter().map(|&k| pts[k].clone()).collect();
            let sub = k_frontier_indices(&kept_pts);
            let sub_vals: Vec<&Vec<f64>> = sub.iter().map(|&i| &kept_pts[i]).collect();
            let batch_vals: Vec<&Vec<f64>> = batch.iter().map(|&i| &pts[i]).collect();
            assert_eq!(sub_vals, batch_vals, "case {case}");
        }
    }

    /// The 2-objective accumulator path is the k=2 special case: same
    /// kept set whether points go through `offer` or `offer_point`.
    #[test]
    fn two_objective_offer_is_k2_special_case() {
        let mut rng = Rng::new(0x2D2D);
        let pts: Vec<(f64, f64)> = (0..80)
            .map(|_| ((rng.f64() * 6.0).round() * 5.0, (rng.f64() * 6.0).round() * 11.0))
            .collect();
        let mut a = FrontierAccumulator::new();
        let mut b = FrontierAccumulator::new();
        for &(s, t) in &pts {
            assert_eq!(a.offer(s, t), b.offer_point(&[s, t]));
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.rejected(), b.rejected());
    }

    #[test]
    fn analyze_filters_and_ranks() {
        let sla = Sla { ttft_ms: 1000.0, min_speed: 12.0 };
        let evs = vec![
            ev(10.0, 200.0, 100.0), // too slow per user
            ev(20.0, 150.0, 100.0),
            ev(25.0, 120.0, 2000.0), // TTFT violation
            ev(15.0, 170.0, 900.0),
        ];
        let a = analyze(&evs, &sla);
        assert_eq!(a.feasible.len(), 2);
        assert_eq!(a.best().unwrap().est.thru_per_gpu, 170.0);
        // Both feasible points are mutually non-dominated here.
        assert_eq!(a.frontier.len(), 2);
    }

    #[test]
    fn empty_input_safe() {
        let a = analyze(&[], &Sla { ttft_ms: 1.0, min_speed: 1.0 });
        assert!(a.best().is_none());
        assert!(a.frontier.is_empty());
        assert!(frontier_indices(&[]).is_empty());
    }

    /// Reference for the tracked-mode bit-equality contract: stream the
    /// live arena points through a fresh streaming accumulator in id
    /// order and report (kept ids, frontier ids as a sorted set).
    fn tracked_reference(pts: &[(Vec<f64>, bool)]) -> (Vec<usize>, Vec<usize>) {
        let mut acc = FrontierAccumulator::new();
        let mut kept = Vec::new();
        for (id, (p, alive)) in pts.iter().enumerate() {
            if *alive && acc.offer_point(p) {
                kept.push(id);
            }
        }
        let live: Vec<Vec<f64>> = pts.iter().filter(|(_, a)| *a).map(|(p, _)| p.clone()).collect();
        let live_ids: Vec<usize> =
            (0..pts.len()).filter(|&i| pts[i].1).collect();
        let frontier: Vec<usize> =
            k_frontier_indices(&live).into_iter().map(|i| live_ids[i]).collect();
        (kept, frontier)
    }

    #[test]
    fn tracked_offers_match_streaming_offers() {
        let mut rng = Rng::new(0x7A5C);
        let pts: Vec<Vec<f64>> = (0..60)
            .map(|_| {
                vec![
                    -(rng.f64() * 5.0).round() * 2.0,
                    (rng.f64() * 5.0).round() * 3.0,
                    (rng.f64() * 5.0).round() * 7.0,
                ]
            })
            .collect();
        let mut tracked = FrontierAccumulator::new();
        let mut streaming = FrontierAccumulator::new();
        for p in &pts {
            let id = tracked.offer_tracked(p);
            assert_eq!(tracked.is_kept(id), streaming.offer_point(p));
        }
        assert_eq!(tracked.len(), streaming.len());
        assert_eq!(tracked.rejected(), streaming.rejected());
    }

    /// Retracting a frontier member re-admits the points it had
    /// dominated; retracting a rejected point is a pure tombstone.
    #[test]
    fn retract_readmits_formerly_dominated_points() {
        let mut acc = FrontierAccumulator::new();
        let a = acc.offer_tracked(&[5.0, 5.0, 5.0]);
        let b = acc.offer_tracked(&[3.0, 3.0, 3.0]); // dominated by a
        let c = acc.offer_tracked(&[1.0, 9.0, 1.0]); // trade-off, kept
        assert!(acc.is_kept(a) && !acc.is_kept(b) && acc.is_kept(c));
        assert_eq!(acc.kept_ids(), vec![a, c]);

        acc.retract(a);
        assert!(!acc.is_kept(a), "retracted point leaves the kept set");
        assert!(acc.is_kept(b), "formerly-dominated point re-admitted");
        assert_eq!(acc.kept_ids(), vec![b, c]);
        let mut f = acc.frontier_ids().to_vec();
        f.sort_unstable();
        assert_eq!(f, vec![b, c]);

        // b was rejected at its original offer but is accepted now;
        // retracting c (accepted) replays, retracting b twice is a no-op.
        acc.retract(b);
        acc.retract(b);
        assert_eq!(acc.kept_ids(), vec![c]);
    }

    /// `update` re-prices a point in place: the id is stable, and the
    /// kept set tracks the new objectives exactly as a from-scratch
    /// stream over the updated values would.
    #[test]
    fn update_reprices_in_place() {
        let mut acc = FrontierAccumulator::new();
        let a = acc.offer_tracked(&[5.0, 5.0, 5.0]);
        let b = acc.offer_tracked(&[4.0, 4.0, 4.0]); // dominated
        acc.update(a, &[2.0, 2.0, 2.0]); // a collapses below b
        assert!(!acc.is_kept(a), "updated point now dominated by b");
        assert!(acc.is_kept(b));
        acc.update(a, &[9.0, 9.0, 9.0]);
        assert!(acc.is_kept(a));
        assert!(!acc.is_kept(b), "b dominated again after a's re-price");
        assert_eq!(acc.live_len(), 2);
    }

    /// Random interleavings of offer/retract/update match the
    /// from-scratch reference after every mutation (the tracked-mode
    /// bit-equality pin; mirrored at scale in tests/proptests.rs).
    #[test]
    fn tracked_interleavings_match_from_scratch_recompute() {
        let mut rng = Rng::new(0xDE17A);
        for case in 0..40 {
            let mut acc = FrontierAccumulator::new();
            let mut mirror: Vec<(Vec<f64>, bool)> = Vec::new();
            for _ in 0..60 {
                let roll = rng.below(10);
                if roll < 5 || mirror.is_empty() {
                    let p = vec![
                        -(rng.f64() * 4.0).round() * 2.0,
                        (rng.f64() * 4.0).round() * 3.0,
                        (rng.f64() * 4.0).round() * 5.0,
                    ];
                    let id = acc.offer_tracked(&p);
                    assert_eq!(id, mirror.len(), "case {case}: arena ids are dense");
                    mirror.push((p, true));
                } else if roll < 8 {
                    let id = rng.below(mirror.len() as u64) as usize;
                    acc.retract(id);
                    mirror[id].1 = false;
                } else {
                    let id = rng.below(mirror.len() as u64) as usize;
                    let p = vec![
                        -(rng.f64() * 4.0).round() * 2.0,
                        (rng.f64() * 4.0).round() * 3.0,
                        (rng.f64() * 4.0).round() * 5.0,
                    ];
                    acc.update(id, &p);
                    mirror[id] = (p, true);
                }
                let (kept_ref, frontier_ref) = tracked_reference(&mirror);
                assert_eq!(acc.kept_ids(), kept_ref, "case {case}: kept set diverged");
                let mut f = acc.frontier_ids().to_vec();
                f.sort_unstable();
                let mut fr = frontier_ref;
                fr.sort_unstable();
                // Frontier compared by value: duplicates may be
                // represented by different (equal-valued) ids.
                let vals = |ids: &[usize]| -> Vec<&Vec<f64>> {
                    ids.iter().map(|&i| &mirror[i].0).collect()
                };
                assert_eq!(vals(&f), vals(&fr), "case {case}: frontier diverged");
            }
        }
    }
}

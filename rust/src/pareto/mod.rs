//! Pareto Analyzer (paper §4.1 step 4): filter SLA-valid configurations,
//! extract the throughput-vs-speed Pareto frontier (Fig 1 / Fig 8), and
//! rank the feasible set by per-GPU system throughput.

use crate::config::Sla;
use crate::perfmodel::PerfEstimate;
use crate::search::runner::Evaluated;

/// Full analysis of a search report.
#[derive(Clone, Debug)]
pub struct Analysis {
    /// SLA-feasible candidates, best throughput first.
    pub feasible: Vec<Evaluated>,
    /// Indices (into `feasible`) forming the speed/throughput frontier.
    pub frontier: Vec<usize>,
}

impl Analysis {
    pub fn best(&self) -> Option<&Evaluated> {
        self.feasible.first()
    }
}

/// Is `a` Pareto-dominated by `b` in (speed, throughput) maximization?
fn dominated(a: &PerfEstimate, b: &PerfEstimate) -> bool {
    b.speed >= a.speed
        && b.thru_per_gpu >= a.thru_per_gpu
        && (b.speed > a.speed || b.thru_per_gpu > a.thru_per_gpu)
}

/// Extract the Pareto frontier over (generation speed, per-GPU
/// throughput) from an arbitrary point set. Returns indices into the
/// input, sorted by speed ascending.
pub fn frontier_indices(points: &[PerfEstimate]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.retain(|&i| !points.iter().enumerate().any(|(j, b)| j != i && dominated(&points[i], b)));
    // Deduplicate identical (speed, thru) pairs.
    idx.sort_by(|&a, &b| {
        points[a]
            .speed
            .partial_cmp(&points[b].speed)
            .unwrap()
            .then(points[a].thru_per_gpu.partial_cmp(&points[b].thru_per_gpu).unwrap())
    });
    idx.dedup_by(|&mut a, &mut b| {
        points[a].speed == points[b].speed && points[a].thru_per_gpu == points[b].thru_per_gpu
    });
    idx
}

/// Analyze a search result against an SLA.
pub fn analyze(evaluated: &[Evaluated], sla: &Sla) -> Analysis {
    let mut feasible: Vec<Evaluated> =
        evaluated.iter().filter(|e| e.est.meets(sla)).cloned().collect();
    feasible.sort_by(|a, b| b.est.thru_per_gpu.partial_cmp(&a.est.thru_per_gpu).unwrap());
    let pts: Vec<PerfEstimate> = feasible.iter().map(|e| e.est).collect();
    let frontier = frontier_indices(&pts);
    Analysis { feasible, frontier }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Candidate, EngineConfig, ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::models::Dtype;

    fn ev(speed: f64, thru: f64, ttft: f64) -> Evaluated {
        let eng = EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(1),
            batch: 1,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
        };
        Evaluated {
            cand: Candidate::Aggregated { engine: eng, replicas: 1 },
            est: PerfEstimate {
                ttft_ms: ttft,
                tpot_ms: 1000.0 / speed,
                speed,
                thru_per_gpu: thru,
                concurrency: 1,
            },
        }
    }

    #[test]
    fn frontier_excludes_dominated() {
        let pts = vec![
            ev(10.0, 100.0, 500.0).est,
            ev(20.0, 80.0, 500.0).est,
            ev(15.0, 90.0, 500.0).est, // dominated by neither
            ev(9.0, 90.0, 500.0).est,  // dominated by (10,100) and (15,90)
            ev(30.0, 30.0, 500.0).est,
        ];
        let f = frontier_indices(&pts);
        assert!(f.contains(&0) && f.contains(&1) && f.contains(&2) && f.contains(&4));
        assert!(!f.contains(&3));
    }

    #[test]
    fn analyze_filters_and_ranks() {
        let sla = Sla { ttft_ms: 1000.0, min_speed: 12.0 };
        let evs = vec![
            ev(10.0, 200.0, 100.0), // too slow per user
            ev(20.0, 150.0, 100.0),
            ev(25.0, 120.0, 2000.0), // TTFT violation
            ev(15.0, 170.0, 900.0),
        ];
        let a = analyze(&evs, &sla);
        assert_eq!(a.feasible.len(), 2);
        assert_eq!(a.best().unwrap().est.thru_per_gpu, 170.0);
        // Both feasible points are mutually non-dominated here.
        assert_eq!(a.frontier.len(), 2);
    }

    #[test]
    fn empty_input_safe() {
        let a = analyze(&[], &Sla { ttft_ms: 1.0, min_speed: 1.0 });
        assert!(a.best().is_none());
        assert!(a.frontier.is_empty());
    }
}

//! Discrete-event serving simulator — the ground-truth stand-in for real
//! engine benchmarks (DESIGN.md substitutions).
//!
//! Unlike the analytical models of [`crate::perfmodel`], the simulator
//! executes the *actual* iteration-by-iteration schedule: chunked-prefill
//! admission, paged KV accounting, prefill/decode interference, queueing,
//! per-iteration scheduler jitter, and (for disaggregated mode) KV-cache
//! transfer and pool imbalance. Its iteration latencies come from the
//! synthetic silicon directly — noise-free truth plus jitter — while the
//! analytical side only ever sees the noisy profiled grids. The gap
//! between the two is what the fidelity experiments (Figs 6–8) measure.

pub mod aggregated;
pub mod disagg;
pub mod kvcache;
pub mod request;

use crate::util::stats;

/// Per-request metrics of one completed request, in completion order.
/// The composition surface for fleet-level replay
/// ([`crate::fleetsim`]): ids are trace-global, so a fleet layer that
/// partitions a trace across replicas can map each engine-local result
/// back to its window/replica without the engine knowing it is part of
/// a fleet.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReqMetric {
    pub id: u64,
    /// Arrival time, ms on the trace's absolute clock.
    pub arrival_ms: f64,
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    /// Completion time, ms on the trace's absolute clock.
    pub finished_ms: f64,
}

/// Simulator knobs.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub seed: u64,
    /// Per-iteration multiplicative jitter sigma (scheduler variance the
    /// analytical model cannot see).
    pub jitter_sigma: f64,
    /// KV page granularity, tokens (PagedAttention-style allocation).
    pub kv_page_tokens: u32,
    /// Hard cap on simulated iterations (runaway guard).
    pub max_iterations: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { seed: 0xD15C, jitter_sigma: 0.05, kv_page_tokens: 32, max_iterations: 2_000_000 }
    }
}

/// Per-run results, per-request metrics included.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub ttft_ms: Vec<f64>,
    /// TTFT measured from batch-slot admission (AI-Perf concurrency
    /// semantics); equals `ttft_ms` for requests admitted on arrival.
    pub ttft_adm_ms: Vec<f64>,
    pub tpot_ms: Vec<f64>,
    pub completed: usize,
    /// Wall-clock from first arrival to last completion, ms.
    pub makespan_ms: f64,
    /// Output tokens produced.
    pub output_tokens: u64,
    pub gpus: u32,
    pub iterations: u64,
    /// Per-request detail (completion order) — see [`ReqMetric`].
    /// `ttft_ms`/`tpot_ms` above stay the aggregate-facing vectors;
    /// this adds the id/arrival/finish mapping fleet composition needs.
    pub requests: Vec<ReqMetric>,
}

impl SimResult {
    pub fn mean_ttft_ms(&self) -> f64 {
        stats::mean(&self.ttft_ms)
    }

    /// Mean admission-based TTFT (see `ttft_adm_ms`).
    pub fn mean_ttft_adm_ms(&self) -> f64 {
        stats::mean(&self.ttft_adm_ms)
    }

    pub fn mean_tpot_ms(&self) -> f64 {
        stats::mean(&self.tpot_ms)
    }

    pub fn p99_ttft_ms(&self) -> f64 {
        stats::percentile(&self.ttft_ms, 99.0)
    }

    /// Generation speed, tokens/s/user (Eq. 1 on measured TPOT).
    pub fn speed(&self) -> f64 {
        let t = self.mean_tpot_ms();
        if t > 0.0 {
            1000.0 / t
        } else {
            0.0
        }
    }

    /// System throughput, output tokens/s per GPU.
    pub fn thru_per_gpu(&self) -> f64 {
        if self.makespan_ms <= 0.0 || self.gpus == 0 {
            return 0.0;
        }
        self.output_tokens as f64 / (self.makespan_ms / 1000.0) / self.gpus as f64
    }

    /// Fraction of requests meeting the SLA (goodput numerator).
    pub fn sla_attainment(&self, sla: &crate::config::Sla) -> f64 {
        if self.ttft_ms.is_empty() {
            return 0.0;
        }
        let max_tpot = sla.max_tpot_ms();
        let ok = self
            .ttft_ms
            .iter()
            .zip(&self.tpot_ms)
            .filter(|(t, p)| **t <= sla.ttft_ms && **p <= max_tpot)
            .count();
        ok as f64 / self.ttft_ms.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Sla;

    #[test]
    fn result_metrics() {
        let r = SimResult {
            ttft_ms: vec![500.0, 1500.0],
            ttft_adm_ms: vec![400.0, 1200.0],
            tpot_ms: vec![20.0, 40.0],
            completed: 2,
            makespan_ms: 10_000.0,
            output_tokens: 1000,
            gpus: 2,
            iterations: 100,
            requests: Vec::new(),
        };
        assert_eq!(r.mean_tpot_ms(), 30.0);
        assert!((r.speed() - 1000.0 / 30.0).abs() < 1e-9);
        assert_eq!(r.thru_per_gpu(), 50.0);
        let sla = Sla { ttft_ms: 1000.0, min_speed: 30.0 }; // max tpot 33.3
        assert_eq!(r.sla_attainment(&sla), 0.5);
    }
}

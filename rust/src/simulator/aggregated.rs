//! Continuous-batching engine simulator (paper Fig 3B): iteration-level
//! discrete events with chunked-prefill scheduling, paged KV admission
//! and prefill/decode interference — the behaviours Algorithm 2 only
//! approximates with its two-phase split and F_corr.

use crate::config::EngineConfig;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::ops::{decompose, StepShape};
use crate::perfmodel::{memory, moe};
use crate::silicon::Silicon;
use crate::util::rng::Rng;
use crate::workload::Request;

use super::kvcache::KvPool;
use super::request::ReqState;
use super::{SimConfig, SimResult};

/// One aggregated engine instance working through a request trace.
pub struct AggregatedSim<'a> {
    pub silicon: &'a Silicon,
    pub model: &'a ModelArch,
    pub cluster: &'a ClusterSpec,
    pub eng: EngineConfig,
    pub cfg: SimConfig,
}

impl<'a> AggregatedSim<'a> {
    pub fn new(
        silicon: &'a Silicon,
        model: &'a ModelArch,
        cluster: &'a ClusterSpec,
        eng: EngineConfig,
        cfg: SimConfig,
    ) -> Self {
        AggregatedSim { silicon, model, cluster, eng, cfg }
    }

    /// Run a trace to completion (closed or open loop).
    pub fn run(&self, trace: &[Request]) -> SimResult {
        let mut rng = Rng::new(self.cfg.seed);
        let gamma = moe::model_imbalance(self.model, self.eng.parallel.ep, self.cfg.seed);
        let capacity =
            memory::kv_capacity_tokens(self.model, self.cluster.gpu.mem_bytes(), &self.eng);
        let mut pool = KvPool::new(capacity, self.cfg.kv_page_tokens);
        let fw = self.eng.framework.profile();

        let mut pending: std::collections::VecDeque<Request> =
            trace.iter().copied().collect();
        let mut running: Vec<ReqState> = Vec::new();
        let mut finished: Vec<ReqState> = Vec::new();

        let mut clock_ms = trace.iter().map(|r| r.arrival_ms).fold(f64::INFINITY, f64::min);
        if !clock_ms.is_finite() {
            clock_ms = 0.0;
        }
        let start_ms = clock_ms;
        let mut iterations = 0u64;
        // Prefill gating: engines alternate context-carrying iterations
        // with pure-decode ones when decoders are present (TRT-LLM-style
        // TPOT protection + scheduling pipeline delay) — the behaviour
        // Algorithm 2's F_corr constant term (≈2) reflects.
        let mut last_had_ctx = false;

        while (!pending.is_empty() || !running.is_empty())
            && iterations < self.cfg.max_iterations
        {
            // ---- Admission: FCFS while batch slots + KV pages allow. ----
            while running.len() < self.eng.batch as usize {
                let Some(next) = pending.front() else { break };
                if next.arrival_ms > clock_ms {
                    break;
                }
                // Reserve the full lifetime footprint up front
                // (conservative, preemption-free — TRT-LLM style).
                let footprint = (next.isl + next.osl) as u64;
                if !pool.can_reserve(footprint) {
                    break;
                }
                pool.reserve(footprint);
                let mut st = ReqState::new(pending.pop_front().unwrap());
                st.admitted_ms = Some(clock_ms.max(st.req.arrival_ms));
                running.push(st);
            }

            if running.is_empty() {
                // Idle until the next arrival.
                if let Some(next) = pending.front() {
                    clock_ms = clock_ms.max(next.arrival_ms);
                    continue;
                }
                break;
            }

            // ---- Schedule one iteration. -------------------------------
            let has_decoders = running.iter().any(|r| r.prefill_done() && !r.done());
            let gate_ctx = last_had_ctx && has_decoders;
            let shape = self.schedule(&mut running, gate_ctx);
            last_had_ctx = shape.ctx_reqs > 0;
            debug_assert!(shape.total_tokens() > 0);

            let ops = decompose(self.model, self.cluster, &self.eng, &shape, gamma);
            // Price the whole decomposed step as one oracle batch.
            let lat = self.silicon.latency_batch(&ops);
            let mut kernel_us: f64 =
                lat.iter().zip(&ops).map(|(l, o)| l * o.count() as f64).sum();
            // CUDA-graph replay on pure-decode iterations (same physics
            // as perfmodel::iteration — mixed steps cannot be graphed).
            if self.eng.flags.cuda_graph && shape.is_decode_only() {
                kernel_us -= crate::ops::CUDA_GRAPH_LAUNCH_SAVING
                    * crate::ops::launch_overhead_us(&ops, self.cluster.gpu.launch_us);
                kernel_us = kernel_us.max(0.0);
            }
            let host_us = fw.iter_host_overhead_us(self.eng.flags.cuda_graph, shape.is_decode_only());
            let iter_ms =
                (kernel_us + host_us) / 1000.0 * rng.noise(self.cfg.jitter_sigma);
            clock_ms += iter_ms;
            iterations += 1;

            // ---- Apply progress. ----------------------------------------
            self.apply(&mut running, &shape, clock_ms, gate_ctx);

            // ---- Retire finished requests. ------------------------------
            let mut i = 0;
            while i < running.len() {
                if running[i].done() {
                    let r = running.swap_remove(i);
                    pool.release((r.req.isl + r.req.osl) as u64);
                    finished.push(r);
                } else {
                    i += 1;
                }
            }
        }

        let makespan = finished
            .iter()
            .filter_map(|r| r.finished_ms)
            .fold(0.0f64, f64::max)
            - start_ms;
        SimResult {
            ttft_ms: finished.iter().filter_map(|r| r.ttft_ms()).collect(),
            ttft_adm_ms: finished.iter().filter_map(|r| r.ttft_from_admission_ms()).collect(),
            tpot_ms: finished.iter().filter_map(|r| r.tpot_ms()).collect(),
            completed: finished.len(),
            makespan_ms: makespan.max(0.0),
            output_tokens: finished.iter().map(|r| r.req.osl as u64).sum(),
            gpus: self.eng.parallel.gpus(),
            iterations,
            requests: finished.iter().filter_map(|r| r.metric()).collect(),
        }
    }

    /// Form this iteration's token population (chunked-prefill policy):
    /// decode slots first (each running decoder advances 1 token), then
    /// fill the remaining token budget with prompt chunks FCFS.
    fn schedule(&self, running: &mut [ReqState], gate_ctx: bool) -> StepShape {
        let budget = self.eng.flags.max_num_tokens as u64;
        let mut gen_reqs = 0u64;
        let mut gen_kv_sum = 0u64;
        for r in running.iter() {
            if r.prefill_done() && !r.done() {
                gen_reqs += 1;
                gen_kv_sum += r.kv_tokens();
            }
        }
        let mut ctx_budget =
            if gate_ctx { 0 } else { budget.saturating_sub(gen_reqs) };
        let mut ctx_reqs = 0u32;
        let mut ctx_q_sum = 0u64;
        let mut ctx_kv_sum = 0u64;
        for r in running.iter_mut() {
            if r.prefill_done() || ctx_budget == 0 {
                continue;
            }
            let chunk = if self.eng.flags.chunked_prefill {
                r.prefill_remaining().min(ctx_budget)
            } else if r.prefill_remaining() <= ctx_budget {
                r.prefill_remaining()
            } else {
                // No chunking: a prompt larger than the budget runs alone
                // in one oversized iteration (engine-enforced).
                if ctx_reqs == 0 { r.prefill_remaining() } else { 0 }
            };
            if chunk == 0 {
                continue;
            }
            ctx_budget = ctx_budget.saturating_sub(chunk);
            ctx_reqs += 1;
            ctx_q_sum += chunk;
            ctx_kv_sum += r.prefilled + chunk;
            // Stash the chunk in `generated`-adjacent scratch? No — apply()
            // recomputes the same schedule deterministically.
        }
        StepShape {
            ctx_reqs,
            ctx_q: if ctx_reqs > 0 { ctx_q_sum / ctx_reqs as u64 } else { 0 },
            ctx_kv: if ctx_reqs > 0 { ctx_kv_sum / ctx_reqs as u64 } else { 0 },
            gen_reqs,
            gen_kv: if gen_reqs > 0 { gen_kv_sum / gen_reqs } else { 0 },
        }
    }

    /// Advance request state to match the schedule just executed
    /// (same traversal order as [`Self::schedule`]).
    fn apply(&self, running: &mut [ReqState], shape: &StepShape, now_ms: f64, gate_ctx: bool) {
        // Decoders advance one token.
        for r in running.iter_mut() {
            if r.prefill_done() && !r.done() && r.first_token_ms.is_some() {
                r.generated += 1;
                if r.generated >= r.req.osl as u64 {
                    r.finished_ms = Some(now_ms);
                }
            }
        }
        // Prefill chunks land; requests completing prefill emit their
        // first token this iteration.
        if gate_ctx {
            return;
        }
        let budget = self.eng.flags.max_num_tokens as u64;
        let mut ctx_budget = budget.saturating_sub(shape.gen_reqs);
        let mut first = true;
        for r in running.iter_mut() {
            if r.prefill_done() || r.first_token_ms.is_some() || ctx_budget == 0 {
                continue;
            }
            let chunk = if self.eng.flags.chunked_prefill {
                r.prefill_remaining().min(ctx_budget)
            } else if r.prefill_remaining() <= ctx_budget || first {
                r.prefill_remaining().min(ctx_budget.max(r.prefill_remaining()))
            } else {
                0
            };
            if chunk == 0 {
                continue;
            }
            first = false;
            ctx_budget = ctx_budget.saturating_sub(chunk.min(ctx_budget));
            r.prefilled += chunk;
            if r.prefill_done() {
                r.first_token_ms = Some(now_ms);
                r.generated = 1; // prefill produces the first token
                if r.generated >= r.req.osl as u64 {
                    r.finished_ms = Some(now_ms);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::{by_name, Dtype};
    use crate::workload::closed_loop;

    fn fixture(batch: u32) -> (Silicon, ModelArch, ClusterSpec, EngineConfig) {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        (
            Silicon::new(cluster, Framework::TrtLlm.profile()),
            by_name("qwen3-32b").unwrap(),
            cluster,
            EngineConfig {
                framework: Framework::TrtLlm,
                parallel: ParallelSpec::tp(2),
                batch,
                weight_dtype: Dtype::Fp8,
                kv_dtype: Dtype::Fp8,
                flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
                placement: crate::topology::Placement::packed(),
            },
        )
    }

    use crate::models::ModelArch;

    #[test]
    fn completes_all_requests() {
        let (sil, m, c, e) = fixture(8);
        let sim = AggregatedSim::new(&sil, &m, &c, e, SimConfig::default());
        let res = sim.run(&closed_loop(16, 1024, 64));
        assert_eq!(res.completed, 16);
        assert_eq!(res.ttft_ms.len(), 16);
        assert!(res.makespan_ms > 0.0);
        assert_eq!(res.output_tokens, 16 * 64);
        assert!(res.iterations >= 64);
    }

    #[test]
    fn ttft_ordering_fcfs() {
        let (sil, m, c, e) = fixture(4);
        let sim = AggregatedSim::new(&sil, &m, &c, e, SimConfig::default());
        let res = sim.run(&closed_loop(8, 2048, 32));
        // With batch 4 and 8 closed-loop requests, the second wave's TTFT
        // must exceed the first wave's (they queue).
        let mut t = res.ttft_ms.clone();
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(t[7] > t[0] * 1.5, "{t:?}");
    }

    #[test]
    fn bigger_batch_higher_throughput() {
        let (sil, m, c, e1) = fixture(2);
        let (_, _, _, e2) = fixture(32);
        let sim1 = AggregatedSim::new(&sil, &m, &c, e1, SimConfig::default());
        let sim32 = AggregatedSim::new(&sil, &m, &c, e2, SimConfig::default());
        let r1 = sim1.run(&closed_loop(32, 1024, 128));
        let r32 = sim32.run(&closed_loop(32, 1024, 128));
        assert!(
            r32.thru_per_gpu() > r1.thru_per_gpu() * 2.0,
            "b2={} b32={}",
            r1.thru_per_gpu(),
            r32.thru_per_gpu()
        );
        // ...at worse per-user latency.
        assert!(r32.mean_tpot_ms() > r1.mean_tpot_ms());
    }

    #[test]
    fn deterministic_per_seed() {
        let (sil, m, c, e) = fixture(8);
        let sim = AggregatedSim::new(&sil, &m, &c, e, SimConfig::default());
        let a = sim.run(&closed_loop(8, 512, 32));
        let b = sim.run(&closed_loop(8, 512, 32));
        assert_eq!(a.ttft_ms, b.ttft_ms);
        assert_eq!(a.makespan_ms, b.makespan_ms);
    }

    #[test]
    fn open_loop_respects_arrivals() {
        let (sil, m, c, e) = fixture(8);
        let sim = AggregatedSim::new(&sil, &m, &c, e, SimConfig::default());
        let trace = crate::workload::poisson(2.0, 5.0, 512, 32, 0.0, 3);
        let res = sim.run(&trace);
        assert_eq!(res.completed, trace.len());
        // Low load: TTFT should be near the isolated prefill latency and
        // small relative to a saturated closed loop.
        assert!(res.mean_ttft_ms() < 2000.0, "{}", res.mean_ttft_ms());
    }
}

//! Paged KV-cache accounting (PagedAttention-style): tokens are held in
//! fixed-size pages, so capacity is consumed with page granularity —
//! one of the real-engine effects the analytical model approximates
//! away (it budgets exact tokens).

/// Page-granular KV pool for one engine instance.
#[derive(Clone, Debug)]
pub struct KvPool {
    capacity_tokens: u64,
    page_tokens: u64,
    used_pages: u64,
}

impl KvPool {
    pub fn new(capacity_tokens: u64, page_tokens: u32) -> Self {
        KvPool { capacity_tokens, page_tokens: page_tokens.max(1) as u64, used_pages: 0 }
    }

    fn total_pages(&self) -> u64 {
        self.capacity_tokens / self.page_tokens
    }

    pub fn pages_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.page_tokens)
    }

    /// Can `tokens` more be reserved right now?
    pub fn can_reserve(&self, tokens: u64) -> bool {
        self.used_pages + self.pages_for(tokens) <= self.total_pages()
    }

    /// Reserve pages for `tokens` (caller must have checked).
    pub fn reserve(&mut self, tokens: u64) {
        let p = self.pages_for(tokens);
        debug_assert!(self.used_pages + p <= self.total_pages());
        self.used_pages += p;
    }

    /// Release a request's full footprint.
    pub fn release(&mut self, tokens: u64) {
        self.used_pages = self.used_pages.saturating_sub(self.pages_for(tokens));
    }

    /// Grow an existing reservation from `old_tokens` to `new_tokens`
    /// (decode appends). Returns false if out of pages (preemption
    /// pressure — the simulator then stalls admission).
    pub fn grow(&mut self, old_tokens: u64, new_tokens: u64) -> bool {
        let delta = self.pages_for(new_tokens).saturating_sub(self.pages_for(old_tokens));
        if self.used_pages + delta > self.total_pages() {
            return false;
        }
        self.used_pages += delta;
        true
    }

    pub fn used_tokens_upper(&self) -> u64 {
        self.used_pages * self.page_tokens
    }

    pub fn utilization(&self) -> f64 {
        if self.total_pages() == 0 {
            1.0
        } else {
            self.used_pages as f64 / self.total_pages() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_granularity() {
        let mut p = KvPool::new(1000, 32); // 31 pages
        assert_eq!(p.pages_for(1), 1);
        assert_eq!(p.pages_for(32), 1);
        assert_eq!(p.pages_for(33), 2);
        assert!(p.can_reserve(31 * 32));
        assert!(!p.can_reserve(31 * 32 + 1));
        p.reserve(100); // 4 pages
        assert_eq!(p.used_tokens_upper(), 128);
        p.release(100);
        assert_eq!(p.used_tokens_upper(), 0);
    }

    #[test]
    fn grow_within_page_is_free() {
        let mut p = KvPool::new(64 * 10, 64);
        p.reserve(65); // 2 pages
        assert!(p.grow(65, 66)); // same 2 pages
        assert_eq!(p.used_tokens_upper(), 128);
        assert!(p.grow(66, 129)); // 3 pages
        assert_eq!(p.used_tokens_upper(), 192);
    }

    #[test]
    fn grow_fails_when_full() {
        let mut p = KvPool::new(64 * 2, 64);
        p.reserve(64);
        p.reserve(64);
        assert!(!p.grow(64, 65));
        assert_eq!(p.utilization(), 1.0);
    }
}

//! Per-request lifecycle state inside the simulator.

use crate::workload::Request;

/// Mutable request state while being served.
#[derive(Clone, Debug)]
pub struct ReqState {
    pub req: Request,
    /// Prompt tokens already prefilled.
    pub prefilled: u64,
    /// Output tokens generated so far.
    pub generated: u64,
    /// Time the scheduler admitted the request into a batch slot.
    pub admitted_ms: Option<f64>,
    /// Time the first token was produced (prefill complete).
    pub first_token_ms: Option<f64>,
    /// Completion time.
    pub finished_ms: Option<f64>,
    /// For disaggregated mode: when KV arrived at the decode pool.
    pub kv_ready_ms: Option<f64>,
}

impl ReqState {
    pub fn new(req: Request) -> Self {
        ReqState {
            req,
            prefilled: 0,
            generated: 0,
            admitted_ms: None,
            first_token_ms: None,
            finished_ms: None,
            kv_ready_ms: None,
        }
    }

    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.req.isl as u64
    }

    pub fn done(&self) -> bool {
        self.finished_ms.is_some()
    }

    /// Remaining prompt tokens.
    pub fn prefill_remaining(&self) -> u64 {
        (self.req.isl as u64).saturating_sub(self.prefilled)
    }

    /// Current KV footprint in tokens.
    pub fn kv_tokens(&self) -> u64 {
        self.prefilled + self.generated
    }

    /// TTFT relative to arrival (requires first_token_ms set).
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_ms.map(|t| t - self.req.arrival_ms)
    }

    /// TTFT from batch-slot admission — what AI-Perf's concurrency mode
    /// measures (the "next" request is only issued once a slot frees, so
    /// client-side queueing is excluded; in-batch context backlog is not).
    pub fn ttft_from_admission_ms(&self) -> Option<f64> {
        match (self.first_token_ms, self.admitted_ms) {
            (Some(f), Some(a)) => Some(f - a.max(self.req.arrival_ms)),
            _ => None,
        }
    }

    /// The per-request record a completed request contributes to
    /// [`super::SimResult::requests`] (None while still in flight).
    pub fn metric(&self) -> Option<super::ReqMetric> {
        Some(super::ReqMetric {
            id: self.req.id,
            arrival_ms: self.req.arrival_ms,
            ttft_ms: self.ttft_ms()?,
            tpot_ms: self.tpot_ms()?,
            finished_ms: self.finished_ms?,
        })
    }

    /// Mean TPOT over the generated tail (requires completion).
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_ms, self.finished_ms) {
            (Some(f), Some(e)) if self.req.osl > 1 => {
                Some((e - f) / (self.req.osl - 1) as f64)
            }
            (Some(_), Some(_)) => Some(0.0),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut r = ReqState::new(Request { id: 0, arrival_ms: 100.0, isl: 1000, osl: 11 });
        assert!(!r.prefill_done());
        assert_eq!(r.prefill_remaining(), 1000);
        r.prefilled = 1000;
        assert!(r.prefill_done());
        r.first_token_ms = Some(600.0);
        assert_eq!(r.ttft_ms(), Some(500.0));
        r.generated = 11;
        r.finished_ms = Some(850.0);
        assert_eq!(r.tpot_ms(), Some(25.0));
        assert_eq!(r.kv_tokens(), 1011);
    }
}

//! Disaggregated serving simulator (paper Fig 3C): x prefill workers +
//! y decode workers with KV-cache transfer between pools. Event-driven
//! over per-worker clocks; captures the queueing, transfer latency and
//! pool-imbalance effects that Algorithm 3 folds into α/β constants.

use std::collections::VecDeque;

use crate::config::EngineConfig;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::ops::{decompose, StepShape};
use crate::perfmodel::{memory, moe};
use crate::silicon::Silicon;
use crate::util::rng::Rng;
use crate::workload::Request;

use super::request::ReqState;
use super::{SimConfig, SimResult};

/// The (x)P(y)D composite under simulation.
pub struct DisaggSim<'a> {
    pub silicon: &'a Silicon,
    pub model: &'a ModelArch,
    pub cluster: &'a ClusterSpec,
    pub prefill: EngineConfig,
    pub decode: EngineConfig,
    pub x: u32,
    pub y: u32,
    pub cfg: SimConfig,
}

struct DecodeWorker {
    clock_ms: f64,
    running: Vec<ReqState>,
}

impl<'a> DisaggSim<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        silicon: &'a Silicon,
        model: &'a ModelArch,
        cluster: &'a ClusterSpec,
        prefill: EngineConfig,
        decode: EngineConfig,
        x: u32,
        y: u32,
        cfg: SimConfig,
    ) -> Self {
        DisaggSim { silicon, model, cluster, prefill, decode, x, y, cfg }
    }

    /// KV transfer time for one request's cache, ms — the physical cost
    /// behind Algorithm 3's β_TTFT correction.
    ///
    /// Routed through the fabric path: the transfer crosses the fast
    /// (NVLink) domain exactly when the (x)P(y)D composite's GPUs
    /// outgrow one domain — NOT whenever the *cluster* happens to have
    /// a second node (the seed's boolean guess, which billed IB latency
    /// to co-located pools on multi-node clusters). Deliberate second
    /// delta vs the seed: the path applies the P2P protocol-efficiency
    /// factor (0.9), aligning the simulator's transfer with how the
    /// analytic models price `Op::P2p` — the seed simulator used raw
    /// link bandwidth here and disagreed with its own estimator.
    pub fn kv_transfer_ms(&self, isl: u32) -> f64 {
        let bytes = self.model.kv_bytes_per_token(self.prefill.kv_dtype) * isl as f64;
        let gpus =
            self.x * self.prefill.parallel.gpus() + self.y * self.decode.parallel.gpus();
        let cross = gpus > self.cluster.domain_size();
        crate::topology::collective::p2p_us(&self.cluster, bytes, cross, 1) / 1000.0
    }

    pub fn run(&self, trace: &[Request]) -> SimResult {
        let mut rng = Rng::new(self.cfg.seed ^ 0xD15A66);
        let gamma_p = moe::model_imbalance(self.model, self.prefill.parallel.ep, self.cfg.seed);
        let gamma_d = moe::model_imbalance(self.model, self.decode.parallel.ep, self.cfg.seed);
        let fw_p = self.prefill.framework.profile();
        let fw_d = self.decode.framework.profile();

        // Prefill pool: each worker batches up to prefill.batch prompts.
        let mut pf_queue: VecDeque<Request> = trace.iter().copied().collect();
        let mut pf_clocks = vec![0f64; self.x as usize];
        // Decode pool: continuous batching per worker, capacity-capped.
        let dec_capacity = memory::kv_capacity_tokens(
            self.model,
            self.cluster.gpu.mem_bytes(),
            &self.decode,
        );
        let mut dec_queue: VecDeque<ReqState> = VecDeque::new();
        let mut workers: Vec<DecodeWorker> = (0..self.y)
            .map(|_| DecodeWorker { clock_ms: 0.0, running: Vec::new() })
            .collect();
        let mut finished: Vec<ReqState> = Vec::new();
        let mut iterations = 0u64;

        // ---- Phase A: prefill pool (static batches, FCFS). --------------
        while let Some(_) = pf_queue.front() {
            // Pick the earliest-free prefill worker.
            let (wi, _) = pf_clocks
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let mut batch: Vec<Request> = Vec::new();
            while batch.len() < self.prefill.batch as usize {
                match pf_queue.front() {
                    Some(r) if r.arrival_ms <= pf_clocks[wi] || batch.is_empty() => {
                        let r = *r;
                        pf_queue.pop_front();
                        if r.arrival_ms > pf_clocks[wi] {
                            pf_clocks[wi] = r.arrival_ms;
                        }
                        batch.push(r);
                    }
                    _ => break,
                }
            }
            if batch.is_empty() {
                break;
            }
            let isl = batch.iter().map(|r| r.isl as u64).sum::<u64>() / batch.len() as u64;
            let shape = StepShape::prefill(batch.len() as u32, isl, isl);
            let ops = decompose(self.model, self.cluster, &self.prefill, &shape, gamma_p);
            // One oracle batch per decomposed step (index-order sum is
            // bit-identical to the old per-op loop).
            let kernel_us: f64 = self
                .silicon
                .latency_batch(&ops)
                .iter()
                .zip(&ops)
                .map(|(l, o)| l * o.count() as f64)
                .sum();
            let us = kernel_us
                + fw_p.iter_host_overhead_us(self.prefill.flags.cuda_graph, false);
            let step_ms = us / 1000.0 * rng.noise(self.cfg.jitter_sigma);
            pf_clocks[wi] += step_ms;
            iterations += 1;
            for r in batch {
                let mut st = ReqState::new(r);
                st.admitted_ms = Some(r.arrival_ms.max(pf_clocks[wi]));
                st.prefilled = r.isl as u64;
                st.generated = 1;
                let ready = pf_clocks[wi] + self.kv_transfer_ms(r.isl);
                st.first_token_ms = Some(ready);
                st.kv_ready_ms = Some(ready);
                if st.generated >= r.osl as u64 {
                    st.finished_ms = Some(ready);
                    finished.push(st);
                } else {
                    dec_queue.push_back(st);
                }
            }
        }
        // Sort transfers by readiness (prefill workers finish out of order).
        let mut ready: Vec<ReqState> = dec_queue.into();
        ready.sort_by(|a, b| a.kv_ready_ms.partial_cmp(&b.kv_ready_ms).unwrap());
        let mut ready: VecDeque<ReqState> = ready.into();

        // ---- Phase B: decode pool (continuous batching). -----------------
        while (!ready.is_empty() || workers.iter().any(|w| !w.running.is_empty()))
            && iterations < self.cfg.max_iterations
        {
            // Earliest-clock worker steps next.
            let wi = workers
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.clock_ms.partial_cmp(&b.1.clock_ms).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            let w = &mut workers[wi];

            // Admit ready requests (KV already transferred) FCFS.
            while w.running.len() < self.decode.batch as usize {
                match ready.front() {
                    Some(r)
                        if (r.kv_ready_ms.unwrap_or(0.0) <= w.clock_ms
                            || w.running.is_empty())
                            && kv_fits(&w.running, r, dec_capacity) =>
                    {
                        let mut st = ready.pop_front().unwrap();
                        if st.kv_ready_ms.unwrap_or(0.0) > w.clock_ms {
                            w.clock_ms = st.kv_ready_ms.unwrap();
                        }
                        st.generated = st.generated.max(1);
                        w.running.push(st);
                    }
                    _ => break,
                }
            }
            if w.running.is_empty() {
                if let Some(r) = ready.front() {
                    w.clock_ms = w.clock_ms.max(r.kv_ready_ms.unwrap_or(0.0));
                } else {
                    // Nothing left for this worker: park it so the other
                    // workers keep draining their batches.
                    w.clock_ms = f64::INFINITY;
                }
                continue;
            }

            // One decode iteration.
            let gen_reqs = w.running.len() as u64;
            let gen_kv = w.running.iter().map(|r| r.kv_tokens()).sum::<u64>() / gen_reqs;
            let shape = StepShape::decode(gen_reqs, gen_kv);
            let ops = decompose(self.model, self.cluster, &self.decode, &shape, gamma_d);
            let lat = self.silicon.latency_batch(&ops);
            let mut kernel_us: f64 =
                lat.iter().zip(&ops).map(|(l, o)| l * o.count() as f64).sum();
            if self.decode.flags.cuda_graph {
                kernel_us -= crate::ops::CUDA_GRAPH_LAUNCH_SAVING
                    * crate::ops::launch_overhead_us(&ops, self.cluster.gpu.launch_us);
                kernel_us = kernel_us.max(0.0);
            }
            let us = kernel_us
                + fw_d.iter_host_overhead_us(self.decode.flags.cuda_graph, true);
            w.clock_ms += us / 1000.0 * rng.noise(self.cfg.jitter_sigma);
            iterations += 1;

            let now = w.clock_ms;
            let mut i = 0;
            while i < w.running.len() {
                w.running[i].generated += 1;
                if w.running[i].generated >= w.running[i].req.osl as u64 {
                    let mut st = w.running.swap_remove(i);
                    st.finished_ms = Some(now);
                    finished.push(st);
                } else {
                    i += 1;
                }
            }
        }

        let start = trace.iter().map(|r| r.arrival_ms).fold(f64::INFINITY, f64::min);
        let end = finished.iter().filter_map(|r| r.finished_ms).fold(0.0f64, f64::max);
        SimResult {
            ttft_ms: finished.iter().filter_map(|r| r.ttft_ms()).collect(),
            ttft_adm_ms: finished
                .iter()
                .filter_map(|r| r.ttft_from_admission_ms())
                .collect(),
            tpot_ms: finished.iter().filter_map(|r| r.tpot_ms()).collect(),
            completed: finished.len(),
            makespan_ms: (end - start.min(end)).max(0.0),
            output_tokens: finished.iter().map(|r| r.req.osl as u64).sum(),
            gpus: self.x * self.prefill.parallel.gpus() + self.y * self.decode.parallel.gpus(),
            iterations,
            requests: finished.iter().filter_map(|r| r.metric()).collect(),
        }
    }
}

fn kv_fits(running: &[ReqState], cand: &ReqState, capacity: u64) -> bool {
    let used: u64 = running
        .iter()
        .map(|r| (r.req.isl + r.req.osl) as u64)
        .sum();
    used + (cand.req.isl + cand.req.osl) as u64 <= capacity
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::{by_name, Dtype};
    use crate::workload::closed_loop;

    fn eng(tp: u32, batch: u32) -> EngineConfig {
        EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(tp),
            batch,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        }
    }

    #[test]
    fn completes_trace() {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("qwen3-32b").unwrap();
        let sim = DisaggSim::new(&sil, &model, &cluster, eng(1, 1), eng(2, 32), 4, 2,
                                 SimConfig::default());
        let res = sim.run(&closed_loop(32, 2048, 64));
        assert_eq!(res.completed, 32);
        assert_eq!(res.gpus, 4 + 4);
        assert!(res.mean_ttft_ms() > 0.0);
        assert!(res.mean_tpot_ms() > 0.0);
    }

    #[test]
    fn decode_tpot_free_of_prefill_interference() {
        // The core disaggregation claim: decode TPOT in disagg mode is
        // close to a pure decode step, while aggregated mixes chunks in.
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("qwen3-32b").unwrap();
        let trace = closed_loop(64, 4096, 128);

        let dis = DisaggSim::new(&sil, &model, &cluster, eng(1, 1), eng(2, 32), 4, 2,
                                 SimConfig::default())
            .run(&trace);

        let agg_engine = eng(2, 32);
        let agg = super::super::aggregated::AggregatedSim::new(
            &sil, &model, &cluster, agg_engine, SimConfig::default(),
        )
        .run(&trace);

        assert!(
            dis.mean_tpot_ms() < agg.mean_tpot_ms(),
            "disagg tpot {} vs agg {}",
            dis.mean_tpot_ms(),
            agg.mean_tpot_ms()
        );
    }

    #[test]
    fn transfer_overhead_visible_in_ttft() {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 2);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("qwen3-32b").unwrap();
        // 1 + 8 GPUs outgrow the 8-GPU domain: the transfer rides IB.
        let sim = DisaggSim::new(&sil, &model, &cluster, eng(1, 1), eng(8, 16), 1, 1,
                                 SimConfig::default());
        // Cross-node transfer of 8k-token KV is material.
        let t = sim.kv_transfer_ms(8192);
        assert!(t > 10.0, "transfer {t} ms");
        let res = sim.run(&closed_loop(2, 8192, 16));
        assert!(res.mean_ttft_ms() > t, "{} vs {t}", res.mean_ttft_ms());
    }

    #[test]
    fn kv_transfer_pays_ib_iff_the_composite_spans_nodes() {
        // Pinned (satellite fix): the link is chosen by whether the
        // (x+y) deployment outgrows one NVLink domain, not by whether
        // the cluster happens to have a second node. On a 2-node
        // cluster, a co-located 1P1D pair of small engines transfers
        // over NVLink; a domain-spanning deployment pays the IB rail.
        let cluster = ClusterSpec::new(h100_sxm(), 8, 2);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("qwen3-32b").unwrap();
        let colocated = DisaggSim::new(&sil, &model, &cluster, eng(1, 1), eng(2, 16), 1, 1,
                                       SimConfig::default());
        let spanning = DisaggSim::new(&sil, &model, &cluster, eng(1, 1), eng(8, 16), 1, 1,
                                      SimConfig::default());
        let bytes = model.kv_bytes_per_token(crate::models::Dtype::Fp8) * 8192.0;
        // Exact link maths: NVLink for the 3-GPU pair, IB for the 9-GPU
        // deployment (seed formula constants, P2P efficiency 0.9).
        let nv = (cluster.fabric.intra_latency_us
            + bytes / (cluster.gpu.nvlink_gbs * 1e3 * 0.9))
            / 1000.0;
        let ib = (cluster.fabric.ib_latency_us
            + bytes / (cluster.fabric.rail_gbs * 1e3 * 0.9))
            / 1000.0;
        assert_eq!(colocated.kv_transfer_ms(8192), nv);
        assert_eq!(spanning.kv_transfer_ms(8192), ib);
        assert!(ib > nv * 5.0, "nv={nv} ib={ib}");
    }

    #[test]
    fn more_decode_workers_scale_throughput() {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("llama3.1-8b").unwrap();
        let mk = |y: u32| {
            DisaggSim::new(&sil, &model, &cluster, eng(1, 2), eng(1, 16), 2, y,
                           SimConfig::default())
                .run(&closed_loop(64, 1024, 256))
        };
        let y1 = mk(1);
        let y4 = mk(4);
        // Total rate rises with workers (per-GPU may vary).
        let rate = |r: &SimResult| r.output_tokens as f64 / r.makespan_ms;
        assert!(rate(&y4) > rate(&y1) * 1.5, "y1={} y4={}", rate(&y1), rate(&y4));
    }
}

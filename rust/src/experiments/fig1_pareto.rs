//! Figure 1 — throughput-vs-speed Pareto frontiers, aggregated vs
//! disaggregated, Qwen3-235B on 64×H200 (8 nodes), ISL 4096 / OSL 1024,
//! TTFT ≤ 1000 ms.
//!
//! Paper reference: at ≥ 20 tokens/s/user the best disaggregated
//! configuration reaches 823 tokens/s/GPU vs 564 aggregated — ≈ +53%.

use crate::config::ServingMode;
use crate::frameworks::Framework;
use crate::pareto;
use crate::search::{SearchSpace, TaskRunner};

use super::common::{self, context, h200_cluster};
use super::Report;

pub fn run(quick: bool) -> Report {
    let mut rep = Report::new(
        "Figure 1: Pareto frontiers, Qwen3-235B on 64xH200, ISL 4096 / OSL 1024, TTFT<=1000ms",
    );
    let cluster = h200_cluster(8); // 64 GPUs
    let (_, model, db) = context("qwen3-235b", cluster, Framework::TrtLlm);
    let wl = common::workload("qwen3-235b", 4096, 1024, 1000.0, 0.0);

    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    if quick {
        space.batch = vec![8, 32, 128];
        space.max_x = 8;
        space.max_y = 16;
    } else {
        space.batch = vec![4, 8, 16, 32, 64, 128, 192, 256];
    }
    let report = TaskRunner::new(&model, &cluster, space, wl.clone()).run(&db);

    // Split by mode, frontier each.
    for mode in [ServingMode::Aggregated, ServingMode::Disaggregated] {
        let pts: Vec<_> = report
            .evaluated
            .iter()
            .filter(|e| e.cand.mode() == mode && e.est.ttft_ms <= wl.sla.ttft_ms)
            .cloned()
            .collect();
        let ests: Vec<_> = pts.iter().map(|e| e.est).collect();
        let frontier = pareto::frontier_indices(&ests);
        rep.line(format!("--- {} frontier ({} feasible points) ---", mode.name(), pts.len()));
        rep.line(format!(
            "{:>10} {:>14} {:>10}  config",
            "speed t/s", "thru t/s/gpu", "ttft ms"
        ));
        for &i in &frontier {
            let e = &pts[i];
            rep.line(format!(
                "{:>10.1} {:>14.1} {:>10.0}  {}",
                e.est.speed,
                e.est.thru_per_gpu,
                e.est.ttft_ms,
                e.cand.label()
            ));
        }
        // Best throughput subject to a speed floor (the paper's starred
        // configurations use >= 20 tokens/s/user).
        for floor in [20.0, 40.0] {
            let best = pts
                .iter()
                .filter(|e| e.est.speed >= floor)
                .max_by(|a, b| a.est.thru_per_gpu.partial_cmp(&b.est.thru_per_gpu).unwrap());
            if let Some(b) = best {
                rep.line(format!(
                    "* best @ speed>={floor}: {:.1} tokens/s/GPU ({})",
                    b.est.thru_per_gpu,
                    b.cand.label()
                ));
                rep.fig(&format!("best{floor}_{}", mode.name()), b.est.thru_per_gpu);
            }
        }
    }
    for floor in [20.0, 40.0] {
        if let (Some(agg), Some(dis)) = (
            rep.get(&format!("best{floor}_aggregated")),
            rep.get(&format!("best{floor}_disaggregated")),
        ) {
            let gain = (dis / agg - 1.0) * 100.0;
            rep.line(format!(
                "disaggregated advantage at >={floor} tok/s/user: {gain:+.1}%"
            ));
            rep.fig(&format!("disagg_gain_pct_{floor}"), gain);
        }
    }
    rep.line(
        "paper: +53% at >=20 tok/s/user. In our synthetic silicon the agg/disagg \
         crossover sits near ~27 tok/s/user: aggregated stays competitive at the \
         20 tok/s floor, and disaggregation dominates beyond it (see >=40 row). \
         The qualitative shape — disaggregation wins the interactive-speed region, \
         aggregation only the bulk-throughput end — is preserved."
            .to_string(),
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disagg_wins_in_interactive_speed_region() {
        let rep = run(true);
        // At the 20 t/s floor our silicon puts the two modes near parity
        // (the crossover; paper's silicon puts it below 20 → +53%).
        let g20 = rep.get("disagg_gain_pct_20").expect("both modes at >=20");
        assert!(g20 > -15.0, "agg should not dominate at 20 t/s: {g20}%");
        // Beyond the crossover disaggregation must win decisively.
        let g40 = rep.get("disagg_gain_pct_40").expect("both modes at >=40");
        assert!(g40 > 30.0, "disagg gain at 40 t/s {g40}% — expected a clear win");
        assert!(g40 < 500.0, "gain {g40}% implausibly large");
    }
}

//! Experiment harnesses: one module per table/figure of the paper's
//! evaluation (§5), each regenerating the same rows/series the paper
//! reports — prediction vs ground-truth simulator, search efficiency,
//! Pareto case studies. See DESIGN.md's per-experiment index.
//!
//! Every harness takes a `quick` flag: `true` shrinks sweeps for CI /
//! benches; `false` runs the paper-scale grid (used by
//! `examples/fidelity_report.rs` and EXPERIMENTS.md).

pub mod common;
pub mod fig1_pareto;
pub mod fig5_powerlaw;
pub mod fig6_agg_fidelity;
pub mod fig7_disagg_fidelity;
pub mod fig8_case_study;
pub mod table1_efficiency;

/// A rendered experiment report (printable, and parseable by tests).
#[derive(Clone, Debug)]
pub struct Report {
    pub title: String,
    pub lines: Vec<String>,
    /// Machine-readable key figures, e.g. ("tpot_mape_qwen3-32b", 8.2).
    pub figures: Vec<(String, f64)>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report { title: title.to_string(), lines: Vec::new(), figures: Vec::new() }
    }

    pub fn line(&mut self, s: impl Into<String>) {
        self.lines.push(s.into());
    }

    pub fn fig(&mut self, key: &str, v: f64) {
        self.figures.push((key.to_string(), v));
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.figures.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    pub fn render(&self) -> String {
        let mut out = format!("== {} ==\n", self.title);
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }
}

//! Shared fixtures for the experiment harnesses.

use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags, WorkloadSpec};
use crate::frameworks::Framework;
use crate::hardware::{h100_sxm, h200_sxm, ClusterSpec};
use crate::models::{by_name, Dtype, ModelArch};
use crate::perfdb::PerfDatabase;
use crate::silicon::Silicon;

/// Global experiment seed (all harnesses are deterministic).
pub const SEED: u64 = 0xA1C0;

pub fn h100_node() -> ClusterSpec {
    ClusterSpec::new(h100_sxm(), 8, 1)
}

pub fn h200_node() -> ClusterSpec {
    ClusterSpec::new(h200_sxm(), 8, 1)
}

pub fn h200_cluster(nodes: u32) -> ClusterSpec {
    ClusterSpec::new(h200_sxm(), 8, nodes)
}

/// (silicon, model, db) for a context — the standard triple.
pub fn context(
    model_name: &str,
    cluster: ClusterSpec,
    fw: Framework,
) -> (Silicon, ModelArch, PerfDatabase) {
    let model = by_name(model_name).expect("model");
    let silicon = Silicon::new(cluster, fw.profile());
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, SEED);
    (silicon, model, db)
}

/// A standard fp8 engine config.
pub fn engine(fw: Framework, tp: u32, ep: u32, batch: u32) -> EngineConfig {
    EngineConfig {
        framework: fw,
        parallel: ParallelSpec { tp, pp: 1, ep, dp: 1 },
        batch,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: RuntimeFlags::defaults_for(fw),
        placement: crate::topology::Placement::packed(),
    }
}

/// Standard workload constructor.
pub fn workload(model: &str, isl: u32, osl: u32, ttft_ms: f64, min_speed: f64) -> WorkloadSpec {
    WorkloadSpec::new(model, isl, osl, ttft_ms, min_speed)
}

/// Format a table row with fixed-width columns.
pub fn row(cols: &[String], widths: &[usize]) -> String {
    let mut s = String::new();
    for (c, w) in cols.iter().zip(widths) {
        s.push_str(&format!("{c:>w$}  ", w = w));
    }
    s.trim_end().to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_builds() {
        let (sil, model, db) = context("llama3.1-8b", h100_node(), Framework::TrtLlm);
        assert_eq!(model.name, "llama3.1-8b");
        assert_eq!(db.ctx.model, "llama3.1-8b");
        assert_eq!(sil.cluster.total_gpus(), 8);
    }

    #[test]
    fn row_format() {
        assert_eq!(row(&["a".into(), "bb".into()], &[3, 4]), "  a    bb");
    }
}

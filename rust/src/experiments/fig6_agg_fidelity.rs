//! Figure 6 — aggregated-serving prediction fidelity.
//!
//! Sweeps the paper's §5.1 grid (ISL 128–4096, OSL 128–512, concurrency
//! 4–128, TP/EP 1–8) on an 8×H100 node for Qwen3-32B (TRT-LLM), the
//! Qwen3-235B MoE (TRT-LLM) and Qwen3-32B (vLLM), comparing the
//! Algorithm-2 analytical predictions (over the noisy profiled database)
//! against the continuous-batching simulator ground truth, reporting
//! TPOT / TTFT MAPE and Pearson r per model-framework pair.
//!
//! Paper reference points: TPOT MAPE 8.2 / 6.8 / 11.9 %, overall 7.8 %;
//! TTFT MAPE 22.1 / 18.3 / 16.9 % (TTFT > 1000 ms filtered as outliers).

use crate::config::Candidate;
use crate::frameworks::Framework;
use crate::metrics::FidelitySet;
use crate::models::ModelArch;
use crate::perfmodel::{self, memory};
use crate::search::SearchSpace;
use crate::silicon::Silicon;
use crate::simulator::aggregated::AggregatedSim;
use crate::simulator::SimConfig;
use crate::workload::closed_loop;

use super::common::{self, context, h100_node};
use super::Report;

/// One model-framework sweep definition.
struct Sweep {
    model: &'static str,
    fw: Framework,
    isl: Vec<u32>,
    osl: Vec<u32>,
    conc: Vec<u32>,
    tp_ep: Vec<(u32, u32)>,
    label: &'static str,
}

fn sweeps(quick: bool) -> Vec<Sweep> {
    if quick {
        return vec![Sweep {
            model: "qwen3-32b",
            fw: Framework::TrtLlm,
            isl: vec![512, 2048],
            osl: vec![128],
            conc: vec![8, 32],
            tp_ep: vec![(2, 1), (4, 1)],
            label: "Qwen3-32B-TRTLLM",
        }];
    }
    vec![
        // 5 × 3 × 6 × 4 = 360 (paper: 360 for Qwen3-32B TRT-LLM).
        Sweep {
            model: "qwen3-32b",
            fw: Framework::TrtLlm,
            isl: vec![128, 512, 1024, 2048, 4096],
            osl: vec![128, 256, 512],
            conc: vec![4, 8, 16, 32, 64, 128],
            tp_ep: vec![(1, 1), (2, 1), (4, 1), (8, 1)],
            label: "Qwen3-32B-TRTLLM",
        },
        // 5 × 3 × 4 × 10 = 600 (paper: 600 for Qwen3-235B).
        Sweep {
            model: "qwen3-235b",
            fw: Framework::TrtLlm,
            isl: vec![128, 512, 1024, 2048, 4096],
            osl: vec![128, 256, 512],
            conc: vec![4, 8, 16, 32],
            tp_ep: vec![
                (1, 1), (2, 1), (2, 2), (4, 1), (4, 2), (4, 4), (8, 1), (8, 2), (8, 4), (8, 8),
            ],
            label: "Qwen3-235B-MoE-TRTLLM",
        },
        // 4 × 2 × 4 × 4 = 128 (paper: 128 for vLLM).
        Sweep {
            model: "qwen3-32b",
            fw: Framework::Vllm,
            isl: vec![512, 1024, 2048, 4096],
            osl: vec![128, 512],
            conc: vec![4, 16, 64, 128],
            tp_ep: vec![(1, 1), (2, 1), (4, 1), (8, 1)],
            label: "Qwen3-32B-VLLM",
        },
    ]
}

/// Per-pair fidelity outcome.
pub struct PairResult {
    pub label: String,
    pub configs: usize,
    pub tpot: FidelitySet,
    pub ttft: FidelitySet,
}

/// Run one sweep: analytical prediction vs simulator per grid point.
fn run_sweep(sw: &Sweep) -> PairResult {
    let cluster = h100_node();
    let (silicon, model, db) = context(sw.model, cluster, sw.fw);
    let mut tpot = FidelitySet::default();
    let mut ttft = FidelitySet::default();
    let mut configs = 0usize;

    // Parallel over grid points.
    let mut points = Vec::new();
    for &isl in &sw.isl {
        for &osl in &sw.osl {
            for &conc in &sw.conc {
                for &(tp, ep) in &sw.tp_ep {
                    points.push((isl, osl, conc, tp, ep));
                }
            }
        }
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = points.len().div_ceil(threads).max(1);
    let results: Vec<Vec<Option<(f64, f64, f64, f64)>>> = std::thread::scope(|s| {
        points
            .chunks(chunk)
            .map(|pts| {
                let model = &model;
                let db = &db;
                let silicon = &silicon;
                s.spawn(move || {
                    pts.iter()
                        .map(|&(isl, osl, conc, tp, ep)| {
                            eval_point(model, silicon, db, sw.fw, isl, osl, conc, tp, ep)
                        })
                        .collect()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for r in results.into_iter().flatten().flatten() {
        let (pt, tt, st, sf) = r;
        configs += 1;
        tpot.push(pt, st);
        ttft.push(tt, sf);
    }
    PairResult { label: sw.label.to_string(), configs, tpot, ttft }
}

/// Returns (pred_tpot, pred_ttft, sim_tpot, sim_ttft) or None if the
/// configuration is memory-infeasible (pruned, as in the paper).
#[allow(clippy::too_many_arguments)]
fn eval_point(
    model: &ModelArch,
    silicon: &Silicon,
    db: &crate::perfdb::PerfDatabase,
    fw: Framework,
    isl: u32,
    osl: u32,
    conc: u32,
    tp: u32,
    ep: u32,
) -> Option<(f64, f64, f64, f64)> {
    let eng = common::engine(fw, tp, ep, conc);
    if !SearchSpace::layout_valid(model, &silicon.cluster, &eng.parallel)
        || !memory::fits(model, silicon.cluster.gpu.mem_bytes(), &eng, isl, osl)
    {
        return None;
    }
    let wl = common::workload(model.name, isl, osl, f64::INFINITY, 0.0);

    // Analytical prediction (database oracle — the product path).
    let cand = Candidate::Aggregated { engine: eng, replicas: 1 };
    let est = perfmodel::estimate(db, model, &silicon.cluster, &cand, &wl);

    // Ground truth: closed loop at matched concurrency, 2× oversampled
    // (AI-Perf concurrency mode). TPOT from the saturated loop; TTFT
    // measured from batch-slot ADMISSION — AI-Perf only issues the next
    // request when one completes, so client-side wave queueing is not
    // part of measured TTFT, while in-batch context backlog (what
    // F_corr models) is.
    let sim = AggregatedSim::new(
        silicon,
        model,
        &silicon.cluster,
        eng,
        SimConfig { seed: common::SEED ^ (isl as u64) << 32 ^ (conc as u64) << 8 ^ tp as u64, ..SimConfig::default() },
    );
    let res = sim.run(&closed_loop(3 * conc as usize, isl, osl));
    if res.completed == 0 {
        return None;
    }
    // Warmup exclusion (paper: oversampling "to mitigate warmup effects
    // on TTFT measurements"): drop the first wave, whose requests were
    // all admitted simultaneously.
    let steady: Vec<f64> =
        res.ttft_adm_ms.iter().skip(conc as usize).copied().collect();
    let ttft_sim = if steady.is_empty() {
        res.mean_ttft_adm_ms()
    } else {
        crate::util::stats::mean(&steady)
    };
    Some((est.tpot_ms, est.ttft_ms, res.mean_tpot_ms(), ttft_sim))
}

/// Run the Figure 6 experiment.
pub fn run(quick: bool) -> Report {
    let mut rep = Report::new("Figure 6: aggregated serving fidelity (prediction vs simulator)");
    rep.line(format!(
        "{:<24} {:>8} {:>12} {:>8} {:>12} {:>8}",
        "pair", "configs", "TPOT MAPE%", "r", "TTFT MAPE%", "r"
    ));
    let mut all_tpot = FidelitySet::default();
    for sw in sweeps(quick) {
        let pr = run_sweep(&sw);
        // Paper: TTFT > 1000 ms filtered as pathological queuing.
        let ttft_f = pr.ttft.filtered(1000.0);
        rep.line(format!(
            "{:<24} {:>8} {:>12.1} {:>8.2} {:>12.1} {:>8.2}",
            pr.label,
            pr.configs,
            pr.tpot.mape(),
            pr.tpot.r(),
            ttft_f.mape(),
            ttft_f.r()
        ));
        rep.fig(&format!("tpot_mape_{}", pr.label), pr.tpot.mape());
        rep.fig(&format!("tpot_r_{}", pr.label), pr.tpot.r());
        rep.fig(&format!("ttft_mape_{}", pr.label), ttft_f.mape());
        rep.fig(&format!("configs_{}", pr.label), pr.configs as f64);
        all_tpot.pred.extend(&pr.tpot.pred);
        all_tpot.truth.extend(&pr.tpot.truth);
    }
    rep.line(format!("overall TPOT MAPE: {:.1}% (paper: 7.8%)", all_tpot.mape()));
    rep.fig("tpot_mape_overall", all_tpot.mape());
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fidelity_reasonable() {
        let rep = run(true);
        let mape = rep.get("tpot_mape_Qwen3-32B-TRTLLM").unwrap();
        // Quick grid: prediction should be in the low-error regime the
        // paper claims (single digits to low tens of percent).
        assert!(mape < 35.0, "TPOT MAPE {mape}");
        let r = rep.get("tpot_r_Qwen3-32B-TRTLLM").unwrap();
        assert!(r > 0.85, "r {r}");
    }
}

//! Table 1 — configuration-search efficiency: AIConfigurator wall-clock
//! vs end-to-end GPU benchmarking for the same configuration sets.
//!
//! Paper reference rows (H100 SXM):
//!   Llama3.1-8B   339 configs: 0.52 s vs 24.4 h  (171,000×)
//!   Qwen3-32B FP8 358 configs: 0.72 s vs 35.4 h  (177,000×)
//!   Qwen3-235B    506 configs: 0.84 s vs 99.5 h  (427,000×)
//! Median per-config: ~1.5 ms constant vs 4–11.5 min growing with size.
//!
//! The "GPU bench" column is *modeled* (we have no GPUs): per-config cost
//! = server startup (engine build + weight loading at ~1.5 GB/s/GPU) +
//! benchmark run (3 rounds of the workload at the predicted latency),
//! which reproduces the paper's 4–11.5 min/config range.

use crate::config::Candidate;
use crate::frameworks::Framework;
use crate::perfmodel::memory;
use crate::search::{SearchSpace, TaskRunner};

use super::common::{self, context, h100_node};
use super::Report;

/// Modeled end-to-end GPU benchmark time for one configuration, seconds.
pub fn gpu_bench_seconds(
    model: &crate::models::ModelArch,
    eng: &crate::config::EngineConfig,
    est: &crate::perfmodel::PerfEstimate,
    osl: u32,
) -> f64 {
    // Engine/server startup: process launch + engine build/capture.
    let startup = 120.0;
    // Weight loading: per-GPU shard at ~1.5 GB/s (disk+H2D).
    let load = memory::weight_bytes_per_gpu(model, eng) / 1.5e9;
    // Benchmark: 1 warmup + 2 measured rounds of the full workload.
    let per_round = (est.ttft_ms + osl as f64 * est.tpot_ms) / 1000.0;
    startup + load + 3.0 * per_round
}

pub fn run(quick: bool) -> Report {
    let mut rep = Report::new("Table 1: search efficiency, AIConfigurator vs GPU benchmarking");
    rep.line(format!(
        "{:<22} {:>8} {:>12} {:>12} {:>11} | {:>11} {:>12} {:>10}",
        "model", "configs", "search s", "GPU bench h", "speedup", "med ms/cfg", "med GPU min", "speedup"
    ));
    let cluster = h100_node();
    for model_name in ["llama3.1-8b", "qwen3-32b", "qwen3-235b"] {
        let (_, model, db) = context(model_name, cluster, Framework::TrtLlm);
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        // Paper-scale config counts (339 / 358 / 506): widen the batch and
        // flag axes so dense and MoE models land in that range.
        space.batch = if quick {
            vec![8, 64]
        } else {
            vec![2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 160, 192, 224, 256]
        };
        if !quick {
            space.cuda_graph = vec![true, false];
            space.max_num_tokens = if model.is_moe() {
                vec![4096, 8192]
            } else {
                vec![2048, 4096, 8192]
            };
        }
        let wl = common::workload(model_name, 2048, 256, f64::INFINITY, 0.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl.clone());
        let report = runner.run(&db);

        // Modeled GPU benchmarking campaign over the aggregated configs.
        let mut bench_s = Vec::new();
        for e in &report.evaluated {
            if let Candidate::Aggregated { engine, .. } = &e.cand {
                bench_s.push(gpu_bench_seconds(&model, engine, &e.est, wl.osl));
            }
        }
        let total_bench_h: f64 = bench_s.iter().sum::<f64>() / 3600.0;
        bench_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med_bench_min = bench_s.get(bench_s.len() / 2).copied().unwrap_or(0.0) / 60.0;
        let speedup = total_bench_h * 3600.0 / report.elapsed_s.max(1e-9);
        let med_speedup = med_bench_min * 60.0 * 1000.0 / report.median_config_ms.max(1e-9);

        rep.line(format!(
            "{:<22} {:>8} {:>12.2} {:>12.1} {:>10.0}x | {:>11.2} {:>12.1} {:>9.0}x",
            model_name,
            report.configs_priced,
            report.elapsed_s,
            total_bench_h,
            speedup,
            report.median_config_ms,
            med_bench_min,
            med_speedup,
        ));
        rep.fig(&format!("configs_{model_name}"), report.configs_priced as f64);
        rep.fig(&format!("search_s_{model_name}"), report.elapsed_s);
        rep.fig(&format!("bench_h_{model_name}"), total_bench_h);
        rep.fig(&format!("speedup_{model_name}"), speedup);
        rep.fig(&format!("median_ms_{model_name}"), report.median_config_ms);
        rep.fig(&format!("median_gpu_min_{model_name}"), med_bench_min);
    }
    rep.line("paper: 0.5-0.8 s vs 24-100 GPU-h; 1.5 ms/config vs 4-11.5 min/config".to_string());
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_is_subsecond_and_speedup_is_huge() {
        let rep = run(true);
        for m in ["llama3.1-8b", "qwen3-32b", "qwen3-235b"] {
            let s = rep.get(&format!("search_s_{m}")).unwrap();
            assert!(s < 30.0, "{m}: search {s}s");
            let sp = rep.get(&format!("speedup_{m}")).unwrap();
            assert!(sp > 1000.0, "{m}: speedup {sp}x");
            // Median GPU bench time in the paper's 2–20 min band.
            let min = rep.get(&format!("median_gpu_min_{m}")).unwrap();
            assert!(min > 1.0 && min < 30.0, "{m}: median {min} min");
        }
    }

    #[test]
    fn gpu_bench_grows_with_model_size() {
        let rep = run(true);
        let small = rep.get("median_gpu_min_llama3.1-8b").unwrap();
        let big = rep.get("median_gpu_min_qwen3-235b").unwrap();
        assert!(big > small, "8B {small} vs 235B {big}");
    }
}

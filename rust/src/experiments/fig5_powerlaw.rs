//! Figure 5 — visualizing the effect of the power-law skew α on expert
//! routing: α ≈ 0 is near-uniform, α ≈ 1.2 concentrates most tokens on
//! the top-ranked experts (the Qwen3-235B production observation).

use crate::perfmodel::moe;
use crate::util::rng::Rng;

use super::Report;

/// Sorted expert-load shares for one α (averaged over trials).
pub fn load_profile(alpha: f64, experts: usize, trials: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut acc = vec![0.0; experts];
    for _ in 0..trials {
        let mut w = moe::sample_weights(&mut rng, experts, alpha);
        w.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let total: f64 = w.iter().sum();
        for (a, x) in acc.iter_mut().zip(&w) {
            *a += x / total;
        }
    }
    acc.iter_mut().for_each(|a| *a /= trials as f64);
    acc
}

pub fn run(_quick: bool) -> Report {
    let mut rep = Report::new("Figure 5: power-law routing skew vs alpha (E=128, top-k loads)");
    let experts = 128;
    rep.line(format!(
        "{:>6} {:>12} {:>12} {:>12} {:>10}",
        "alpha", "top-1 %", "top-20% %", "gamma(EP8)", "profile"
    ));
    for &alpha in &[0.01, 0.3, 0.6, 0.9, 1.2, 1.5] {
        let prof = load_profile(alpha, experts, 64, 0x515);
        let top1 = prof[0] * 100.0;
        let top20: f64 = prof[..experts / 5].iter().sum::<f64>() * 100.0;
        let gamma = moe::ep_imbalance(experts as u64, alpha, 8, 0x515, 32);
        // Tiny ASCII sparkline over the sorted profile (8 buckets).
        let spark: String = prof
            .chunks(experts / 8)
            .map(|c| {
                let s: f64 = c.iter().sum::<f64>();
                match (s * 40.0) as u32 {
                    0 => '.',
                    1 => ':',
                    2..=3 => '+',
                    4..=6 => '*',
                    _ => '#',
                }
            })
            .collect();
        rep.line(format!(
            "{alpha:>6.2} {top1:>12.1} {top20:>12.1} {gamma:>12.2} {spark:>10}"
        ));
        rep.fig(&format!("top20_share_a{alpha}"), top20);
        rep.fig(&format!("gamma_a{alpha}"), gamma);
    }
    rep.line("paper observation: alpha~1.2 -> ~70% of compute on ~20% of experts".to_string());
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_monotone_and_matches_paper_anchor() {
        let rep = run(true);
        let t_low = rep.get("top20_share_a0.01").unwrap();
        let t_high = rep.get("top20_share_a1.2").unwrap();
        // α→0 over x∈[1,100] is uniform in x, not perfectly balanced:
        // top-20% share ≈ 36% (perfect balance would be 20%).
        assert!(t_low < 40.0, "near-uniform share {t_low}%");
        assert!(t_high > 50.0, "alpha=1.2 share {t_high}% (paper ~70%)");
        assert!(t_high > t_low + 10.0, "skew must grow: {t_low} -> {t_high}");
        assert!(rep.get("gamma_a1.5").unwrap() > rep.get("gamma_a0.3").unwrap());
    }

    #[test]
    fn profile_is_normalized_and_sorted() {
        let p = load_profile(1.2, 64, 16, 1);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(p.windows(2).all(|w| w[0] >= w[1]));
    }
}

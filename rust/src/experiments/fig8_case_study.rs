//! Figure 8 + Table 2 — production case study: Qwen3-32B-FP8 on 8×H200
//! under TTFT ≤ 1200 ms and speed ≥ 60 tokens/s/user, ISL 4000 /
//! OSL 500. AIConfigurator finds the best aggregated and disaggregated
//! deployments; both are validated against the ground-truth simulator.
//!
//! Paper reference (Table 2): aggregated 1×TP2 b8 → 321.5 t/s/GPU at
//! 95.9 t/s/user; disaggregated P:4×TP1(b1) D:2×TP2(b80) →
//! 648.3 t/s/GPU (+101.6%) at 78.4 t/s/user.

use crate::config::{Candidate, ServingMode};
use crate::frameworks::Framework;
use crate::generator;
use crate::pareto;
use crate::search::{SearchSpace, TaskRunner};
use crate::simulator::aggregated::AggregatedSim;
use crate::simulator::disagg::DisaggSim;
use crate::simulator::SimConfig;
use crate::workload::closed_loop;

use super::common::{self, context, h200_node};
use super::Report;

pub fn run(quick: bool) -> Report {
    let mut rep = Report::new(
        "Figure 8 / Table 2: Qwen3-32B-FP8 case study on 8xH200 (TTFT<=1200ms, speed>=60)",
    );
    let cluster = h200_node();
    let (silicon, model, db) = context("qwen3-32b", cluster, Framework::TrtLlm);
    let wl = common::workload("qwen3-32b", 4000, 500, 1200.0, 60.0);

    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = if quick {
        vec![4, 8, 16, 48, 80]
    } else {
        vec![2, 4, 8, 16, 24, 32, 48, 64, 80, 96, 128]
    };
    let search = TaskRunner::new(&model, &cluster, space, wl.clone()).run(&db);
    rep.line(format!(
        "searched {} configs in {:.2}s ({:.2} ms median per config)",
        search.configs_priced, search.elapsed_s, search.median_config_ms
    ));
    rep.fig("search_s", search.elapsed_s);

    rep.line(format!(
        "{:<14} {:>14} {:>12} {:>10} {:>8}  configuration",
        "mode", "thru t/s/GPU", "speed t/s/u", "TTFT ms", "batch"
    ));

    let mut best_per_mode = Vec::new();
    for mode in [ServingMode::Aggregated, ServingMode::Disaggregated] {
        let pts: Vec<_> = search
            .evaluated
            .iter()
            .filter(|e| e.cand.mode() == mode)
            .cloned()
            .collect();
        let analysis = pareto::analyze(&pts, &wl.sla);
        if let Some(best) = analysis.best() {
            rep.line(format!(
                "{:<14} {:>14.1} {:>12.1} {:>10.1} {:>8}  {}",
                mode.name(),
                best.est.thru_per_gpu,
                best.est.speed,
                best.est.ttft_ms,
                match &best.cand {
                    Candidate::Aggregated { engine, .. } => engine.batch.to_string(),
                    Candidate::Disaggregated { prefill, decode, .. } =>
                        format!("P:{},D:{}", prefill.batch, decode.batch),
                },
                best.cand.label()
            ));
            rep.fig(&format!("pred_thru_{}", mode.name()), best.est.thru_per_gpu);
            rep.fig(&format!("pred_speed_{}", mode.name()), best.est.speed);
            best_per_mode.push(best.clone());
        }
    }

    // Projection accuracy: validate both winners in the simulator.
    rep.line("--- ground-truth validation (simulator) ---".to_string());
    for best in &best_per_mode {
        let (sim_thru, sim_speed, sim_ttft) = match &best.cand {
            Candidate::Aggregated { engine, .. } => {
                let sim = AggregatedSim::new(&silicon, &model, &cluster, *engine, SimConfig::default());
                // 20× oversampling in the paper; 4× here is converged.
                let res = sim.run(&closed_loop(4 * engine.batch as usize, wl.isl, wl.osl));
                // Per-GPU: one engine replica uses engine gpus; scale-out is linear.
                (
                    res.output_tokens as f64 / (res.makespan_ms / 1000.0)
                        / engine.parallel.gpus() as f64,
                    res.speed(),
                    res.mean_ttft_adm_ms(),
                )
            }
            Candidate::Disaggregated { prefill, decode, x, y } => {
                let sim = DisaggSim::new(
                    &silicon, &model, &cluster, *prefill, *decode, *x, *y, SimConfig::default(),
                );
                let res = sim.run(&closed_loop(
                    (4 * y * decode.batch).max(32) as usize,
                    wl.isl,
                    wl.osl,
                ));
                (res.thru_per_gpu(), res.speed(), res.mean_ttft_adm_ms())
            }
        };
        let mode = best.cand.mode().name();
        let dev_thru = (best.est.thru_per_gpu / sim_thru - 1.0) * 100.0;
        let dev_speed = (best.est.speed / sim_speed - 1.0) * 100.0;
        rep.line(format!(
            "{mode:<14} measured {sim_thru:>8.1} t/s/GPU {sim_speed:>8.1} t/s/u  TTFT {sim_ttft:>7.1} ms | deviation thru {dev_thru:+.1}% speed {dev_speed:+.1}%"
        ));
        rep.fig(&format!("sim_thru_{mode}"), sim_thru);
        rep.fig(&format!("dev_thru_{mode}"), dev_thru.abs());
        rep.fig(&format!("dev_speed_{mode}"), dev_speed.abs());
    }

    if let (Some(a), Some(d)) =
        (rep.get("pred_thru_aggregated"), rep.get("pred_thru_disaggregated"))
    {
        let gain = (d / a - 1.0) * 100.0;
        rep.line(format!(
            "disaggregated throughput improvement: {gain:+.1}% (paper: +101.6%)"
        ));
        rep.fig("disagg_gain_pct", gain);
    }

    // Emit the launch bundle for the overall winner (workflow step 5).
    if let Some(best) = best_per_mode
        .iter()
        .max_by(|a, b| a.est.thru_per_gpu.partial_cmp(&b.est.thru_per_gpu).unwrap())
    {
        let bundle = generator::generate(&best.cand, "Qwen/Qwen3-32B-FP8", &wl);
        rep.line(format!(
            "generated launch bundle: {}",
            bundle.files.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
        ));
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disagg_doubles_throughput_shape() {
        let rep = run(true);
        let gain = rep.get("disagg_gain_pct").expect("both modes found");
        // Paper: +101.6%. Shape: a substantial disagg win under this SLA.
        assert!(gain > 25.0, "gain {gain}%");
        // Both winners meet the speed SLA in prediction.
        assert!(rep.get("pred_speed_aggregated").unwrap() >= 60.0);
        assert!(rep.get("pred_speed_disaggregated").unwrap() >= 60.0);
        // Projection deviation vs simulator bounded (paper: <=17.4%).
        assert!(rep.get("dev_thru_aggregated").unwrap() < 40.0);
    }
}

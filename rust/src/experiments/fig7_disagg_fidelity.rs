//! Figure 7 — disaggregated-serving fidelity for DeepSeek-V3 across two
//! 8-GPU Hopper nodes (prefill node + decode node): AIConfigurator's
//! projected Pareto frontier vs ground-truth (simulator) measurements.
//!
//! Paper reference: MAPE 25.49% (throughput) / 14.94% (speed) overall,
//! improving to 13.19% / 3.35% inside the interactive 25–50
//! tokens/s/user band.

use crate::frameworks::Framework;
use crate::metrics;
use crate::pareto;
use crate::perfmodel::{disagg, memory};
use crate::search::SearchSpace;
use crate::simulator::disagg::DisaggSim;
use crate::simulator::SimConfig;
use crate::workload::closed_loop;

use super::common::{self, context, h200_cluster};
use super::Report;

pub fn run(quick: bool) -> Report {
    let mut rep = Report::new(
        "Figure 7: disaggregated fidelity, DeepSeek-V3 on 2x8 Hopper, prefill node + decode node",
    );
    let cluster = h200_cluster(2);
    let (silicon, model, db) = context("deepseek-v3", cluster, Framework::TrtLlm);

    let profiles: &[(u32, u32)] = if quick { &[(5000, 1000)] } else { &[(5000, 1000), (6000, 1000)] };

    let mut pred_thru = Vec::new();
    let mut pred_speed = Vec::new();
    let mut true_thru = Vec::new();
    let mut true_speed = Vec::new();

    for &(isl, osl) in profiles {
        // 5-second TTFT constraint (paper §5.2).
        let wl = common::workload("deepseek-v3", isl, osl, 5000.0, 0.0);

        // Candidate pools: engines fitting one 8-GPU node each.
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = if quick { vec![16, 64] } else { vec![8, 16, 32, 64, 128] };
        space.prefill_batch = vec![1, 2];
        let mem = cluster.gpu.mem_bytes();
        let fits8 = |e: &crate::config::EngineConfig, osl_eff: u32| {
            e.parallel.gpus() <= 8 && memory::fits(&model, mem, e, isl, osl_eff)
        };
        let prefill: Vec<_> = space
            .prefill_engines(&model, &cluster, &wl)
            .into_iter()
            .filter(|e| fits8(e, 1))
            .collect();
        let decode: Vec<_> = space
            .engines(&model, &cluster, &wl, osl)
            .into_iter()
            .filter(|e| fits8(e, osl))
            .collect();

        // Price pools, compose with one full node per pool:
        // x·G_pre = 8 and y·G_dec = 8 (paper's node split).
        let p_prices: Vec<_> = prefill
            .iter()
            .map(|e| disagg::price_prefill(&db, &model, &cluster, e, &wl))
            .collect();
        let d_prices: Vec<_> = decode
            .iter()
            .map(|e| disagg::price_decode(&db, &model, &cluster, e, &wl))
            .collect();
        let mut composites = Vec::new();
        for (pi, p) in p_prices.iter().enumerate() {
            if p.latency_ms * disagg::BETA_TTFT > wl.sla.ttft_ms || 8 % p.gpus != 0 {
                continue;
            }
            for (di, d) in d_prices.iter().enumerate() {
                if 8 % d.gpus != 0 {
                    continue;
                }
                let (x, y) = (8 / p.gpus, 8 / d.gpus);
                let est = disagg::compose(p, d, x, y, &wl);
                composites.push((pi, di, x, y, est));
            }
        }

        // Projected Pareto frontier.
        let ests: Vec<_> = composites.iter().map(|c| c.4).collect();
        let frontier = pareto::frontier_indices(&ests);
        rep.line(format!(
            "profile ISL={isl} OSL={osl}: {} composites, {} frontier points",
            composites.len(),
            frontier.len()
        ));
        rep.line(format!(
            "{:>10} {:>12} {:>12} {:>12} {:>12}  config",
            "pred spd", "true spd", "pred thr", "true thr", "ttft ms"
        ));

        // Ground-truth validation of every frontier point.
        for &i in &frontier {
            let (pi, di, x, y, est) = composites[i];
            let sim = DisaggSim::new(
                &silicon,
                &model,
                &cluster,
                prefill[pi],
                decode[di],
                x,
                y,
                SimConfig { seed: common::SEED ^ (i as u64) << 17, ..SimConfig::default() },
            );
            // Two measurements, as a serving benchmark would take them:
            //  * capacity (throughput) from a saturating closed loop —
            //    queues keep both pools busy;
            //  * per-user speed from a run at ~90% of rate-matched
            //    capacity — flooding an (x)P(y)D pair would measure
            //    queue growth, not serving latency.
            let n_req = (2 * y * decode[di].batch).max(16) as usize;
            let sat = sim.run(&closed_loop(n_req, isl, osl));
            if sat.completed == 0 {
                continue;
            }
            let rate_rps =
                0.9 * est.thru_per_gpu * (x * prefill[pi].parallel.gpus()
                    + y * decode[di].parallel.gpus()) as f64
                    / osl as f64;
            let trace = crate::workload::poisson(
                rate_rps.max(0.05),
                n_req as f64 / rate_rps.max(0.05),
                isl,
                osl,
                0.0,
                common::SEED ^ (i as u64) << 9,
            );
            let res = sim.run(&trace);
            if res.completed == 0 {
                continue;
            }
            // Steady-state speed: drop the ramp-up half (warmup).
            let tail: Vec<f64> =
                res.tpot_ms.iter().skip(res.tpot_ms.len() / 2).copied().collect();
            let tpot_ss = crate::util::stats::mean(&tail);
            let speed_ss = if tpot_ss > 0.0 { 1000.0 / tpot_ss } else { 0.0 };
            pred_thru.push(est.thru_per_gpu);
            true_thru.push(sat.thru_per_gpu());
            pred_speed.push(est.speed);
            true_speed.push(speed_ss);
            rep.line(format!(
                "{:>10.1} {:>12.1} {:>12.1} {:>12.1} {:>12.0}  P:{}x{} D:{}x{}",
                est.speed,
                speed_ss,
                est.thru_per_gpu,
                sat.thru_per_gpu(),
                res.mean_ttft_ms(),
                x,
                prefill[pi].label(),
                y,
                decode[di].label(),
            ));
        }
    }

    let thru_mape = metrics::mape(&pred_thru, &true_thru);
    let speed_mape = metrics::mape(&pred_speed, &true_speed);
    let thru_band = metrics::banded_mape(&pred_thru, &true_thru, &true_speed, 25.0, 50.0);
    let speed_band = metrics::banded_mape(&pred_speed, &true_speed, &true_speed, 25.0, 50.0);
    rep.line(format!(
        "overall MAPE: throughput {thru_mape:.2}% (paper 25.49%), speed {speed_mape:.2}% (paper 14.94%)"
    ));
    rep.line(format!(
        "25-50 tok/s/user band MAPE: throughput {thru_band:.2}% (paper 13.19%), speed {speed_band:.2}% (paper 3.35%)"
    ));
    rep.fig("thru_mape", thru_mape);
    rep.fig("speed_mape", speed_mape);
    rep.fig("thru_mape_band", thru_band);
    rep.fig("speed_mape_band", speed_band);
    rep.fig("points", pred_thru.len() as f64);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_disagg_fidelity_sane() {
        let rep = run(true);
        assert!(rep.get("points").unwrap() >= 1.0);
        let speed_mape = rep.get("speed_mape").unwrap();
        let thru_mape = rep.get("thru_mape").unwrap();
        // Paper-band sanity: speed is the better-predicted metric and
        // both errors stay bounded.
        assert!(speed_mape < 40.0, "speed mape {speed_mape}");
        assert!(thru_mape < 60.0, "thru mape {thru_mape}");
    }
}

//! "Why this config won" reports (DESIGN.md §12, "Explainability").
//!
//! Builds a machine-readable explain report for a finished search or
//! capacity plan: the winner's latency decomposed by primitive class
//! (GEMM / attention / comm / memory / host) per phase, a pruning
//! audit by cause (SLA / dominance / memory infeasibility), the
//! nearest runner-up and its losing margin, resolved-flag provenance
//! and oracle tier provenance. The same report renders to JSON for
//! `--explain-out` / the v2 service (`"explain": true`) and to a
//! human-readable block for the CLI.

use crate::config::{Candidate, EngineConfig, WorkloadSpec};
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::ops::{self, StepShape};
use crate::pareto;
use crate::perfdb::LatencyOracle;
use crate::perfmodel::moe;
use crate::planner::DeploymentPlan;
use crate::search::SearchReport;
use crate::util::json::{self, Json};

/// Primitive-class buckets the decomposition reports, in print order.
pub const CLASS_GROUPS: [&str; 5] = ["gemm", "attention", "comm", "memory", "host"];

/// Fold an [`ops::Op`] class into its report bucket.
fn group_of(class: &str) -> &'static str {
    match class {
        "gemm" | "moe" => "gemm",
        "attn_prefill" | "attn_decode" => "attention",
        "allreduce" | "allgather" | "alltoall" | "p2p" => "comm",
        _ => "memory",
    }
}

/// Per-primitive-class latency of one engine step (µs): decompose the
/// step, price every op through the oracle, bucket by class, and add
/// the framework host overhead as its own bucket.
fn phase_breakdown(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    eng: &EngineConfig,
    shape: &StepShape,
) -> Json {
    let gamma = moe::model_imbalance(model, eng.parallel.ep, 0x1517);
    let ops = ops::decompose(model, cluster, eng, shape, gamma);
    let lat = oracle.latency_batch(&ops);
    let mut sums = [0.0f64; CLASS_GROUPS.len()];
    for (o, l) in ops.iter().zip(&lat) {
        let g = group_of(o.class());
        let i = CLASS_GROUPS.iter().position(|c| *c == g).unwrap_or(0);
        sums[i] += l * o.count() as f64;
    }
    let host = eng
        .framework
        .profile()
        .iter_host_overhead_us(eng.flags.cuda_graph, shape.is_decode_only());
    sums[CLASS_GROUPS.len() - 1] += host;
    let total: f64 = sums.iter().sum();
    let mut o = Json::obj();
    for (i, g) in CLASS_GROUPS.iter().enumerate() {
        let mut e = Json::obj();
        e.set("us", json::num(sums[i]))
            .set("frac", json::num(if total > 0.0 { sums[i] / total } else { 0.0 }));
        o.set(g, e);
    }
    o.set("total_us", json::num(total));
    o
}

/// Prefill + decode breakdowns for a candidate's engine(s).
fn candidate_phases(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    wl: &WorkloadSpec,
    cand: &Candidate,
) -> Json {
    let isl = wl.isl.max(1) as u64;
    let gen_kv = isl + wl.osl as u64 / 2;
    let mut phases = Json::obj();
    match cand {
        Candidate::Aggregated { engine, .. } => {
            phases.set(
                "prefill",
                phase_breakdown(oracle, model, cluster, engine, &StepShape::prefill(1, isl, isl)),
            );
            phases.set(
                "decode",
                phase_breakdown(
                    oracle,
                    model,
                    cluster,
                    engine,
                    &StepShape::decode(engine.batch.max(1) as u64, gen_kv),
                ),
            );
        }
        Candidate::Disaggregated { prefill, decode, .. } => {
            phases.set(
                "prefill",
                phase_breakdown(oracle, model, cluster, prefill, &StepShape::prefill(1, isl, isl)),
            );
            phases.set(
                "decode",
                phase_breakdown(
                    oracle,
                    model,
                    cluster,
                    decode,
                    &StepShape::decode(decode.batch.max(1) as u64, gen_kv),
                ),
            );
        }
    }
    phases
}

fn est_fields(o: &mut Json, est: &crate::perfmodel::PerfEstimate) {
    o.set("ttft_ms", json::num(est.ttft_ms))
        .set("tpot_ms", json::num(est.tpot_ms))
        .set("speed", json::num(est.speed))
        .set("thru_per_gpu", json::num(est.thru_per_gpu));
}

/// Explain report for a finished search: winner decomposition, pruning
/// audit, nearest runner-up margin, flag + tier provenance.
pub fn search_explain(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    wl: &WorkloadSpec,
    report: &SearchReport,
) -> Json {
    let mut o = Json::obj();
    o.set("kind", json::s("search-explain"));
    let mut audit = Json::obj();
    audit
        .set("configs_priced", json::num(report.configs_priced as f64))
        .set("evaluated", json::num(report.evaluated.len() as f64))
        .set("pruned_total", json::num(report.pruned as f64))
        .set("pruned_sla", json::num(report.pruned_sla as f64))
        .set("pruned_dominated", json::num(report.pruned_dominated as f64))
        .set("infeasible_memory", json::num(report.infeasible as f64));
    o.set("pruning", audit);
    let a = pareto::analyze(&report.evaluated, &wl.sla);
    o.set("feasible", json::num(a.feasible.len() as f64));
    match a.best() {
        None => {
            o.set("winner", Json::Null);
            o.set("runner_up", Json::Null);
        }
        Some(w) => {
            let mut win = Json::obj();
            win.set("config", json::s(&w.cand.label()))
                .set(
                    "mode",
                    json::s(match &w.cand {
                        Candidate::Aggregated { .. } => "agg",
                        Candidate::Disaggregated { .. } => "disagg",
                    }),
                )
                .set("gpus", json::num(w.cand.total_gpus() as f64));
            est_fields(&mut win, &w.est);
            win.set("phases", candidate_phases(oracle, model, cluster, wl, &w.cand));
            o.set("winner", win);
            match a.feasible.get(1) {
                None => {
                    o.set("runner_up", Json::Null);
                }
                Some(r) => {
                    let mut ru = Json::obj();
                    ru.set("config", json::s(&r.cand.label()));
                    est_fields(&mut ru, &r.est);
                    ru.set(
                        "margin_thru_per_gpu",
                        json::num(w.est.thru_per_gpu - r.est.thru_per_gpu),
                    )
                    .set("margin_tpot_us", json::num((r.est.tpot_ms - w.est.tpot_ms) * 1e3))
                    .set("margin_ttft_ms", json::num(r.est.ttft_ms - w.est.ttft_ms));
                    o.set("runner_up", ru);
                }
            }
        }
    }
    let flags: Vec<Json> =
        report.flag_summaries.iter().map(|f| json::s(&f.describe())).collect();
    o.set("flags", Json::Arr(flags));
    if let Some(t) = &report.tier_counts {
        let mut tiers = Json::obj();
        tiers
            .set("measured", json::num(t.measured as f64))
            .set("calibrated", json::num(t.calibrated as f64))
            .set("analytic", json::num(t.analytic as f64))
            .set("sol", json::num(t.sol as f64));
        o.set("tiers", tiers);
    }
    o
}

/// Explain report for a capacity plan: schedule economics, the option
/// audit, and the peak window's winning unit decomposed by primitive
/// class against its own leg's oracle.
pub fn plan_explain(
    model: &ModelArch,
    wl: &WorkloadSpec,
    plan: &DeploymentPlan,
    legs: &[(String, ClusterSpec, &dyn LatencyOracle)],
) -> Json {
    let mut o = Json::obj();
    o.set("kind", json::s("plan-explain"));
    let mut audit = Json::obj();
    audit
        .set("options_considered", json::num(plan.options_considered as f64))
        .set("options_pruned", json::num(plan.options_pruned as f64))
        .set("windows", json::num(plan.windows.len() as f64))
        .set(
            "active_windows",
            json::num(plan.windows.iter().filter(|w| w.replicas > 0).count() as f64),
        );
    o.set("pruning", audit);
    let mut costs = Json::obj();
    costs
        .set("total_usd", json::num(plan.total_cost_usd))
        .set("static_peak_usd", json::num(plan.static_peak_cost_usd))
        .set("elastic_savings_frac", json::num(plan.elastic_savings_frac()));
    match &plan.best_homogeneous {
        Some((gpu, cost)) => {
            costs
                .set("best_homogeneous_gpu", json::s(gpu))
                .set("best_homogeneous_usd", json::num(*cost))
                .set("margin_vs_homogeneous_usd", json::num(cost - plan.total_cost_usd));
        }
        None => {
            costs.set("best_homogeneous_gpu", Json::Null);
        }
    }
    o.set("costs", costs);
    // The peak active window carries the plan's binding constraint;
    // decompose its winning unit against the leg it runs on.
    let peak = plan
        .windows
        .iter()
        .filter(|w| w.replicas > 0)
        .max_by(|a, b| a.demand_qps.partial_cmp(&b.demand_qps).unwrap());
    match peak {
        None => {
            o.set("peak_window", Json::Null);
        }
        Some(w) => {
            let mut pw = Json::obj();
            pw.set("index", json::num(w.index as f64))
                .set("gpu", json::s(&w.gpu))
                .set("config", json::s(&w.cand.label()))
                .set("replicas", json::num(w.replicas as f64))
                .set("gpus", json::num(w.gpus as f64))
                .set("demand_qps", json::num(w.demand_qps))
                .set("capacity_qps", json::num(w.capacity_qps))
                .set("cost_usd", json::num(w.cost_usd));
            est_fields(&mut pw, &w.est);
            if let Some((_, cluster, oracle)) = legs.iter().find(|(n, _, _)| *n == w.gpu) {
                pw.set("phases", candidate_phases(*oracle, model, cluster, wl, &w.cand));
            }
            o.set("peak_window", pw);
        }
    }
    o
}

fn render_phase(out: &mut String, label: &str, p: &Json) {
    out.push_str(&format!("    {label:<8}"));
    for g in CLASS_GROUPS {
        if let Ok(e) = p.req(g) {
            out.push_str(&format!(
                "  {g} {:.1}% ({:.0} us)",
                100.0 * e.f64_or("frac", 0.0),
                e.f64_or("us", 0.0)
            ));
        }
    }
    out.push('\n');
}

/// Human-readable rendering of [`search_explain`] for the CLI.
pub fn render_search_explain(e: &Json) -> String {
    let mut out = String::from("explain: why this config won\n");
    match e.req("winner") {
        Ok(w) if w.req("config").is_ok() => {
            out.push_str(&format!(
                "  winner: {} ({}, {:.0} GPUs)  ttft {:.1} ms  tpot {:.2} ms  \
                 speed {:.1} tok/s/user  thru {:.1} tok/s/gpu\n",
                w.str_or("config", "?"),
                w.str_or("mode", "?"),
                w.f64_or("gpus", 0.0),
                w.f64_or("ttft_ms", 0.0),
                w.f64_or("tpot_ms", 0.0),
                w.f64_or("speed", 0.0),
                w.f64_or("thru_per_gpu", 0.0),
            ));
            out.push_str("  latency by primitive class (one step):\n");
            if let Ok(ph) = w.req("phases") {
                if let Ok(p) = ph.req("prefill") {
                    render_phase(&mut out, "prefill", p);
                }
                if let Ok(p) = ph.req("decode") {
                    render_phase(&mut out, "decode", p);
                }
            }
        }
        _ => out.push_str("  winner: none (no SLA-feasible candidate)\n"),
    }
    if let Ok(a) = e.req("pruning") {
        out.push_str(&format!(
            "  pruning audit: {:.0} configs priced, {:.0} evaluated, {:.0} pruned \
             ({:.0} by SLA, {:.0} dominated), {:.0} memory-infeasible\n",
            a.f64_or("configs_priced", 0.0),
            a.f64_or("evaluated", 0.0),
            a.f64_or("pruned_total", 0.0),
            a.f64_or("pruned_sla", 0.0),
            a.f64_or("pruned_dominated", 0.0),
            a.f64_or("infeasible_memory", 0.0),
        ));
    }
    match e.req("runner_up") {
        Ok(r) if r.req("config").is_ok() => out.push_str(&format!(
            "  runner-up: {} lost by {:.2} tok/s/gpu (tpot margin {:+.0} us, \
             ttft margin {:+.1} ms)\n",
            r.str_or("config", "?"),
            e.req("winner")
                .ok()
                .map(|w| w.f64_or("thru_per_gpu", 0.0) - r.f64_or("thru_per_gpu", 0.0))
                .unwrap_or(0.0),
            r.f64_or("margin_tpot_us", 0.0),
            r.f64_or("margin_ttft_ms", 0.0),
        )),
        _ => out.push_str("  runner-up: none\n"),
    }
    if let Ok(t) = e.req("tiers") {
        out.push_str(&format!(
            "  oracle tiers: measured {:.0} / calibrated {:.0} / analytic {:.0} / sol {:.0}\n",
            t.f64_or("measured", 0.0),
            t.f64_or("calibrated", 0.0),
            t.f64_or("analytic", 0.0),
            t.f64_or("sol", 0.0),
        ));
    }
    if let Ok(fs) = e.req("flags") {
        if let Some(arr) = fs.as_arr() {
            for f in arr {
                if let Some(s) = f.as_str() {
                    out.push_str(&format!("  flags: {s}\n"));
                }
            }
        }
    }
    out
}

/// Human-readable rendering of [`plan_explain`] for the CLI.
pub fn render_plan_explain(e: &Json) -> String {
    let mut out = String::from("explain: why this plan won\n");
    if let Ok(c) = e.req("costs") {
        out.push_str(&format!(
            "  cost: ${:.2} vs ${:.2} static-peak ({:.1}% elastic savings)\n",
            c.f64_or("total_usd", 0.0),
            c.f64_or("static_peak_usd", 0.0),
            100.0 * c.f64_or("elastic_savings_frac", 0.0),
        ));
        if c.req("best_homogeneous_usd").is_ok() {
            out.push_str(&format!(
                "  vs best homogeneous ({}): ${:.2} — heterogeneity margin ${:.2}\n",
                c.str_or("best_homogeneous_gpu", "?"),
                c.f64_or("best_homogeneous_usd", 0.0),
                c.f64_or("margin_vs_homogeneous_usd", 0.0),
            ));
        }
    }
    if let Ok(a) = e.req("pruning") {
        out.push_str(&format!(
            "  option audit: {:.0} considered, {:.0} frontier-pruned across \
             {:.0} windows ({:.0} active)\n",
            a.f64_or("options_considered", 0.0),
            a.f64_or("options_pruned", 0.0),
            a.f64_or("windows", 0.0),
            a.f64_or("active_windows", 0.0),
        ));
    }
    match e.req("peak_window") {
        Ok(w) if w.req("config").is_ok() => {
            out.push_str(&format!(
                "  peak window {:.0}: {} x{:.0} on {} ({:.1} qps demand, {:.1} qps \
                 capacity, ${:.2})\n",
                w.f64_or("index", 0.0),
                w.str_or("config", "?"),
                w.f64_or("replicas", 0.0),
                w.str_or("gpu", "?"),
                w.f64_or("demand_qps", 0.0),
                w.f64_or("capacity_qps", 0.0),
                w.f64_or("cost_usd", 0.0),
            ));
            if let Ok(ph) = w.req("phases") {
                out.push_str("  peak unit latency by primitive class (one step):\n");
                if let Ok(p) = ph.req("prefill") {
                    render_phase(&mut out, "prefill", p);
                }
                if let Ok(p) = ph.req("decode") {
                    render_phase(&mut out, "decode", p);
                }
            }
        }
        _ => out.push_str("  peak window: none (plan is empty)\n"),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::by_name;
    use crate::search::{SearchSpace, TaskRunner};
    use crate::silicon::Silicon;

    #[test]
    fn search_explain_names_the_required_facts() {
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let wl = WorkloadSpec::new("qwen3-32b", 1024, 128, 2000.0, 10.0);
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 32];
        let runner = TaskRunner::new(&model, &cluster, space, wl.clone());
        let report = runner.run(&sil);
        let e = search_explain(&sil, &model, &cluster, &wl, &report);
        // Acceptance bar: primitive-class breakdown, pruning-audit
        // counts and the runner-up margin must all be named.
        let w = e.req("winner").unwrap();
        let phases = w.req("phases").unwrap();
        for phase in ["prefill", "decode"] {
            let p = phases.req(phase).unwrap();
            for g in CLASS_GROUPS {
                p.req(g).unwrap_or_else(|_| panic!("{phase} missing class {g}"));
            }
            assert!(p.req_f64("total_us").unwrap() > 0.0);
            // Fractions sum to ~1.
            let s: f64 =
                CLASS_GROUPS.iter().map(|g| p.req(g).unwrap().f64_or("frac", 0.0)).sum();
            assert!((s - 1.0).abs() < 1e-6, "{phase} fracs sum to {s}");
        }
        let a = e.req("pruning").unwrap();
        assert!(a.req_f64("configs_priced").unwrap() > 0.0);
        a.req_f64("pruned_sla").unwrap();
        a.req_f64("pruned_dominated").unwrap();
        a.req_f64("infeasible_memory").unwrap();
        let r = e.req("runner_up").unwrap();
        assert!(r.req("config").is_ok(), "two feasible configs expected: {r:?}");
        r.req_f64("margin_thru_per_gpu").unwrap();
        r.req_f64("margin_tpot_us").unwrap();
        // Human rendering mentions the same facts.
        let txt = render_search_explain(&e);
        assert!(txt.contains("winner:"), "{txt}");
        assert!(txt.contains("pruning audit:"), "{txt}");
        assert!(txt.contains("runner-up:"), "{txt}");
        assert!(txt.contains("gemm"), "{txt}");
        // And the report is valid JSON end-to-end.
        assert!(json::parse(&e.to_string()).is_ok());
    }
}

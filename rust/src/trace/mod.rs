//! Pipeline-wide structured tracing (DESIGN.md §12).
//!
//! A zero-dependency span recorder threaded through search, planning,
//! fleet replay and the service: hierarchical spans with attached
//! counters, recorded into per-thread buffers and merged in worker-id
//! order (the same deterministic idiom as the sweep engine's
//! thread-local memo accumulators), exported as Chrome trace-event
//! JSON (`chrome://tracing` / Perfetto) or a human-readable span tree.
//!
//! The recorder is strictly opt-in: nothing records until a
//! [`Recorder`] is installed on the current thread, and every
//! instrumentation point ([`span`], [`count`]) is a single
//! thread-local check when none is — tracing off costs nothing
//! measurable and changes no result (pinned by `tests/trace.rs`).
//!
//! Worker threads spawned by [`crate::util::pool`] pick the recorder
//! up via [`install_worker`] inside the pool's per-worker init hook;
//! their buffers flush when the scoped thread exits (which
//! happens-before the pool join returns) and the final merge orders
//! segments by `(tid, flush sequence)`, so the exported span list is
//! identical run-to-run up to the recorded timings themselves.

pub mod explain;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Span categories with fixed indices — the service exports one
/// `aiconf_span_*` sample per category, and the Chrome export uses
/// them as event `cat` fields. Unknown categories fold into "other".
pub const CATS: [&str; 8] =
    ["search", "sweep", "plan", "validate", "replan", "price", "fleet", "other"];

/// Index of a category in [`CATS`] (unknowns map to "other").
pub fn cat_index(cat: &str) -> usize {
    CATS.iter().position(|c| *c == cat).unwrap_or(CATS.len() - 1)
}

/// One closed span: timestamps are microseconds since the recorder
/// epoch, `tid` 0 is the recording thread and `1 + w` pool worker `w`,
/// `parent` indexes the merged span list of the finished [`Trace`].
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub name: String,
    pub cat: &'static str,
    pub ts_us: f64,
    pub dur_us: f64,
    pub tid: u64,
    pub parent: Option<usize>,
    /// Accumulated counters (ops priced, memo hits, pruned-by-cause…).
    pub counters: Vec<(&'static str, f64)>,
}

/// One thread's flushed buffer, tagged for the deterministic merge.
struct Segment {
    tid: u64,
    seq: u64,
    spans: Vec<SpanRec>,
}

struct Shared {
    epoch: Instant,
    segments: Mutex<Vec<Segment>>,
    seq: AtomicU64,
}

/// Handle to one recording session. Clones share the session; the
/// handle is captured on the spawning thread and re-installed on pool
/// workers ([`install_worker`]).
#[derive(Clone)]
pub struct Recorder {
    shared: Arc<Shared>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            shared: Arc::new(Shared {
                epoch: Instant::now(),
                segments: Mutex::new(Vec::new()),
                seq: AtomicU64::new(0),
            }),
        }
    }

    /// Install on the current thread as the recording root (tid 0).
    /// No-op if any recorder is already installed here.
    pub fn install(&self) {
        install_tls(self.shared.clone(), 0);
    }

    /// Uninstall from this thread (flushing its buffer) and merge every
    /// flushed segment in `(tid, flush sequence)` order into one
    /// deterministic span list.
    pub fn finish(self) -> Trace {
        CUR.with(|c| {
            let mut b = c.borrow_mut();
            let ours = b
                .as_ref()
                .is_some_and(|t| Arc::ptr_eq(&t.shared, &self.shared));
            if ours {
                *b = None; // ThreadTrace::drop flushes the buffer
            }
        });
        let mut segments = std::mem::take(&mut *self.shared.segments.lock().unwrap());
        segments.sort_by_key(|s| (s.tid, s.seq));
        let mut spans = Vec::new();
        for seg in segments {
            let off = spans.len();
            for mut s in seg.spans {
                s.parent = s.parent.map(|p| p + off);
                spans.push(s);
            }
        }
        Trace { spans }
    }
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

struct ThreadTrace {
    shared: Arc<Shared>,
    tid: u64,
    epoch: Instant,
    spans: Vec<SpanRec>,
    stack: Vec<usize>,
}

impl ThreadTrace {
    fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }
}

impl Drop for ThreadTrace {
    fn drop(&mut self) {
        // Close anything left open (worker-lifetime spans, or guards a
        // panic unwound past) so the flushed segment is well-formed.
        let now = self.now_us();
        for i in 0..self.stack.len() {
            let idx = self.stack[i];
            if self.spans[idx].dur_us == 0.0 {
                self.spans[idx].dur_us = (now - self.spans[idx].ts_us).max(0.0);
            }
        }
        if self.spans.is_empty() {
            return;
        }
        let seq = self.shared.seq.fetch_add(1, Ordering::Relaxed);
        let spans = std::mem::take(&mut self.spans);
        if let Ok(mut g) = self.shared.segments.lock() {
            g.push(Segment { tid: self.tid, seq, spans });
        }
    }
}

thread_local! {
    static CUR: RefCell<Option<ThreadTrace>> = RefCell::new(None);
}

/// Returns true when this call installed (false = already recording).
fn install_tls(shared: Arc<Shared>, tid: u64) -> bool {
    CUR.with(|c| {
        let mut b = c.borrow_mut();
        if b.is_some() {
            return false;
        }
        let epoch = shared.epoch;
        *b = Some(ThreadTrace { shared, tid, epoch, spans: Vec::new(), stack: Vec::new() });
        true
    })
}

/// Handle to the recorder installed on the current thread, if any —
/// capture this *before* spawning pool workers, then hand it to
/// [`install_worker`] inside the pool's per-worker init hook.
pub fn current() -> Option<Recorder> {
    CUR.with(|c| c.borrow().as_ref().map(|t| Recorder { shared: t.shared.clone() }))
}

/// Install the recorder on a pool worker thread (tid `1 + wid`) and
/// open a worker-lifetime span; the buffer flushes when the scoped
/// worker thread exits. On the `threads <= 1` fast path (where the
/// pool's init hook runs on the calling, already-recording thread)
/// this is a no-op, so sequential runs don't grow phantom workers.
pub fn install_worker(rec: &Recorder, wid: usize) {
    if install_tls(rec.shared.clone(), wid as u64 + 1) {
        let g = span("price_worker", "price");
        std::mem::forget(g); // closed by ThreadTrace::drop at thread exit
    }
}

/// Is a recorder installed on this thread?
pub fn enabled() -> bool {
    CUR.with(|c| c.borrow().is_some())
}

const INERT: usize = usize::MAX;

/// Guard for one open span; the span closes when the guard drops.
#[must_use = "the span closes when the guard drops"]
pub struct SpanGuard {
    idx: usize,
}

impl SpanGuard {
    /// Add `v` to counter `key` on this span (accumulating).
    pub fn add(&self, key: &'static str, v: f64) {
        if self.idx == INERT {
            return;
        }
        let idx = self.idx;
        CUR.with(|c| {
            if let Some(t) = c.borrow_mut().as_mut() {
                if let Some(s) = t.spans.get_mut(idx) {
                    bump_counter(s, key, v);
                }
            }
        });
    }

    /// Is this guard actually recording?
    pub fn active(&self) -> bool {
        self.idx != INERT
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.idx == INERT {
            return;
        }
        let idx = self.idx;
        CUR.with(|c| {
            if let Some(t) = c.borrow_mut().as_mut() {
                let now = t.now_us();
                if let Some(s) = t.spans.get_mut(idx) {
                    s.dur_us = (now - s.ts_us).max(0.0);
                }
                while t.stack.last().is_some_and(|&top| top >= idx) {
                    t.stack.pop();
                }
            }
        });
    }
}

fn bump_counter(s: &mut SpanRec, key: &'static str, v: f64) {
    match s.counters.iter_mut().find(|(k, _)| *k == key) {
        Some(e) => e.1 += v,
        None => s.counters.push((key, v)),
    }
}

/// Open a span on the current thread. Inert — one thread-local check,
/// no allocation — when no recorder is installed.
pub fn span(name: &str, cat: &'static str) -> SpanGuard {
    CUR.with(|c| {
        let mut b = c.borrow_mut();
        match b.as_mut() {
            None => SpanGuard { idx: INERT },
            Some(t) => {
                let idx = t.spans.len();
                let parent = t.stack.last().copied();
                let ts_us = t.now_us();
                t.spans.push(SpanRec {
                    name: name.to_string(),
                    cat,
                    ts_us,
                    dur_us: 0.0,
                    tid: t.tid,
                    parent,
                    counters: Vec::new(),
                });
                t.stack.push(idx);
                SpanGuard { idx }
            }
        }
    })
}

/// Add `v` to counter `key` on the innermost open span of this thread
/// (no-op when untraced or no span is open).
pub fn count(key: &'static str, v: f64) {
    CUR.with(|c| {
        if let Some(t) = c.borrow_mut().as_mut() {
            if let Some(&idx) = t.stack.last() {
                bump_counter(&mut t.spans[idx], key, v);
            }
        }
    });
}

/// A finished, deterministically merged trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<SpanRec>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// `(category, total µs, span count)` per [`CATS`] entry — the
    /// aggregation behind the service's `aiconf_span_*` series.
    pub fn cat_totals(&self) -> Vec<(&'static str, f64, u64)> {
        let mut us = vec![0.0f64; CATS.len()];
        let mut n = vec![0u64; CATS.len()];
        for s in &self.spans {
            let i = cat_index(s.cat);
            us[i] += s.dur_us;
            n[i] += 1;
        }
        CATS.iter().enumerate().map(|(i, c)| (*c, us[i], n[i])).collect()
    }

    /// Chrome trace-event JSON (load in `chrome://tracing` or
    /// Perfetto): complete "X" events only (always balanced), `ts` /
    /// `dur` in microseconds, one process, tid 0 = recording thread,
    /// `1 + w` = pool worker `w`, counters as event `args`.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::with_capacity(self.spans.len());
        for s in &self.spans {
            let mut args = Json::obj();
            for (k, v) in &s.counters {
                args.set(k, json::num(*v));
            }
            let mut e = Json::obj();
            e.set("name", json::s(&s.name))
                .set("cat", json::s(s.cat))
                .set("ph", json::s("X"))
                .set("pid", json::num(1.0))
                .set("tid", json::num(s.tid as f64))
                .set("ts", json::num(s.ts_us))
                .set("dur", json::num(s.dur_us))
                .set("args", args);
            events.push(e);
        }
        let mut o = Json::obj();
        o.set("displayTimeUnit", json::s("ms")).set("traceEvents", Json::Arr(events));
        o
    }

    /// Human-readable span tree with total and self times (self =
    /// total minus direct children) and inline counters.
    pub fn render_tree(&self) -> String {
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); self.spans.len()];
        let mut roots: Vec<usize> = Vec::new();
        for (i, s) in self.spans.iter().enumerate() {
            match s.parent {
                Some(p) if p < self.spans.len() => children[p].push(i),
                _ => roots.push(i),
            }
        }
        fn render(
            spans: &[SpanRec],
            children: &[Vec<usize>],
            i: usize,
            depth: usize,
            out: &mut String,
        ) {
            let s = &spans[i];
            let child_us: f64 = children[i].iter().map(|&c| spans[c].dur_us).sum();
            let self_us = (s.dur_us - child_us).max(0.0);
            out.push_str(&"  ".repeat(depth + 1));
            out.push_str(&format!(
                "{:<24} total {:>10.3} ms  self {:>10.3} ms",
                s.name,
                s.dur_us / 1000.0,
                self_us / 1000.0
            ));
            if s.tid > 0 {
                out.push_str(&format!("  [w{}]", s.tid - 1));
            }
            for (k, v) in &s.counters {
                out.push_str(&format!("  {k}={v}"));
            }
            out.push('\n');
            for &c in &children[i] {
                render(spans, children, c, depth + 1, out);
            }
        }
        let threads = {
            let mut tids: Vec<u64> = self.spans.iter().map(|s| s.tid).collect();
            tids.sort_unstable();
            tids.dedup();
            tids.len()
        };
        let mut out = format!("trace: {} spans across {} threads\n", self.spans.len(), threads);
        for &r in &roots {
            render(&self.spans, &children, r, 0, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untraced_span_is_inert() {
        assert!(!enabled());
        let g = span("nothing", "other");
        assert!(!g.active());
        g.add("x", 1.0);
        count("y", 2.0);
        drop(g);
        assert!(!enabled());
    }

    #[test]
    fn spans_nest_and_counters_accumulate() {
        let rec = Recorder::new();
        rec.install();
        {
            let root = span("root", "search");
            {
                let child = span("child", "price");
                child.add("ops", 3.0);
                child.add("ops", 4.0);
                count("hits", 5.0); // innermost open span = child
            }
            root.add("total", 1.0);
        }
        let tr = rec.finish();
        assert!(!enabled(), "finish must uninstall");
        assert_eq!(tr.len(), 2);
        let root = &tr.spans[0];
        let child = &tr.spans[1];
        assert_eq!(root.name, "root");
        assert_eq!(root.parent, None);
        assert_eq!(child.parent, Some(0));
        assert_eq!(child.counters, vec![("ops", 7.0), ("hits", 5.0)]);
        assert_eq!(root.counters, vec![("total", 1.0)]);
        assert!(root.dur_us >= child.dur_us);
        assert!(child.ts_us >= root.ts_us);
    }

    #[test]
    fn worker_segments_merge_in_tid_order() {
        let rec = Recorder::new();
        rec.install();
        let _root = span("root", "search");
        // Simulate workers finishing out of order: higher wid flushes
        // first; the merge must still order by tid.
        let h = rec.clone();
        std::thread::scope(|s| {
            for wid in [2usize, 0, 1] {
                let h = h.clone();
                s.spawn(move || {
                    install_worker(&h, wid);
                    let g = span("work", "price");
                    g.add("wid", wid as f64);
                });
            }
        });
        drop(_root);
        let tr = rec.finish();
        let tids: Vec<u64> = tr.spans.iter().map(|s| s.tid).collect();
        let mut sorted = tids.clone();
        sorted.sort_unstable();
        assert_eq!(tids, sorted, "segments must merge in worker-id order");
        // Each worker contributed its lifetime span + the work span.
        assert_eq!(tr.spans.iter().filter(|s| s.name == "price_worker").count(), 3);
        assert_eq!(tr.spans.iter().filter(|s| s.name == "work").count(), 3);
        // Worker-lifetime spans were auto-closed by the flush.
        assert!(tr
            .spans
            .iter()
            .filter(|s| s.name == "price_worker")
            .all(|s| s.dur_us > 0.0));
    }

    #[test]
    fn chrome_export_shape() {
        let rec = Recorder::new();
        rec.install();
        {
            let g = span("phase", "plan");
            g.add("options", 12.0);
        }
        let j = rec.finish().to_chrome_json();
        assert_eq!(j.str_or("displayTimeUnit", ""), "ms");
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.str_or("ph", ""), "X");
        assert_eq!(e.str_or("name", ""), "phase");
        assert_eq!(e.str_or("cat", ""), "plan");
        assert!(e.req_f64("ts").is_ok() && e.req_f64("dur").is_ok());
        assert!(e.req_f64("pid").is_ok() && e.req_f64("tid").is_ok());
        assert_eq!(e.req("args").unwrap().f64_or("options", 0.0), 12.0);
        // Round-trips through the hand-rolled JSON layer.
        let txt = j.to_string();
        assert!(json::parse(&txt).is_ok());
    }

    #[test]
    fn render_tree_reports_self_time() {
        let rec = Recorder::new();
        rec.install();
        {
            let _a = span("outer", "search");
            std::thread::sleep(std::time::Duration::from_millis(2));
            let _b = span("inner", "price");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let txt = rec.finish().render_tree();
        assert!(txt.contains("outer"), "{txt}");
        assert!(txt.contains("inner"), "{txt}");
        assert!(txt.contains("self"), "{txt}");
        assert!(txt.starts_with("trace: 2 spans"), "{txt}");
    }

    #[test]
    fn cat_totals_cover_all_categories() {
        let rec = Recorder::new();
        rec.install();
        {
            let _a = span("s", "search");
            let _b = span("weird", "not-a-cat");
        }
        let totals = rec.finish().cat_totals();
        assert_eq!(totals.len(), CATS.len());
        let get = |c: &str| totals.iter().find(|(k, _, _)| *k == c).unwrap().2;
        assert_eq!(get("search"), 1);
        assert_eq!(get("other"), 1, "unknown cats fold into 'other'");
    }
}

//! Per-algorithm collective cost models over a placement's link path.
//!
//! Paper §4.4 prices "AllReduce, AllGather, AllToAll, and
//! point-to-point transfers across message sizes and GPU counts"; this
//! module adds the *where*: every cost is computed over the
//! [`LinkPath`] a placement induces (ranks per NVLink domain, domains
//! spanned, rails striped), and the exported entry points select the
//! min-cost algorithm per message size — flat ring vs tree vs
//! hierarchical two-stage for all-reduce/all-gather, pairwise vs
//! hierarchical (rail-striped) for all-to-all.
//!
//! The seed's closed-form flat formulas are kept verbatim as the
//! [`FabricModel::Legacy`](crate::topology::fabric::FabricModel) path
//! (bit-for-bit, pinned in `tests/topology.rs`);
//! [`crate::silicon::comm`] delegates here for both models.

use crate::hardware::{ClusterSpec, LinkKind};
use crate::ops::Op;

/// Protocol/algorithm efficiency of NCCL-class collectives vs raw link
/// bandwidth (shared with the legacy formulas — same constant the seed
/// used).
pub const COLL_EFF: f64 = 0.80;
/// Point-to-point protocol efficiency (KV transfer, PP boundary).
pub const P2P_EFF: f64 = 0.9;

// ---------------------------------------------------------------------------
// Legacy (seed) formulas — the flat NVLink-vs-IB switch, bit-for-bit.
// ---------------------------------------------------------------------------

fn legacy_bw_lat(c: &ClusterSpec, gpus: u32) -> (f64, f64) {
    let link = c.link_for(gpus);
    let bw = c.p2p_bw_gbs(link) * 1e3 * COLL_EFF; // GB/s -> bytes/us
    (bw, c.link_latency_us(link))
}

/// The seed's ring all-reduce (with its hierarchical cross-node
/// penalty), microseconds.
pub fn legacy_allreduce_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = legacy_bw_lat(c, gpus);
    let g = gpus as f64;
    let t = 2.0 * (g - 1.0) / g * bytes / bw + 2.0 * (g - 1.0) * lat;
    if c.link_for(gpus) == LinkKind::InfiniBand {
        let intra = legacy_allreduce_us(c, bytes, c.gpus_per_node.min(gpus));
        t + 0.5 * intra
    } else {
        t
    }
}

/// The seed's all-gather (each GPU contributes a `bytes` shard).
pub fn legacy_allgather_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = legacy_bw_lat(c, gpus);
    let g = gpus as f64;
    (g - 1.0) / g * bytes * g / bw + (g - 1.0) * lat
}

/// The seed's all-to-all (`bytes` sent per GPU).
pub fn legacy_alltoall_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = legacy_bw_lat(c, gpus);
    let g = gpus as f64;
    (g - 1.0) / g * bytes / bw + lat * (g - 1.0).sqrt() * 2.0
}

/// The seed's point-to-point transfer.
pub fn legacy_p2p_us(c: &ClusterSpec, bytes: f64, cross: bool) -> f64 {
    let link = if cross { LinkKind::InfiniBand } else { LinkKind::NvLink };
    let bw = c.p2p_bw_gbs(link) * 1e3 * P2P_EFF;
    c.link_latency_us(link) + bytes / bw
}

// ---------------------------------------------------------------------------
// Tiered path construction.
// ---------------------------------------------------------------------------

/// The link path a placed group communicates over. Bandwidths are
/// effective bytes/µs (protocol efficiency applied).
#[derive(Clone, Copy, Debug)]
pub struct LinkPath {
    /// Group width (ranks).
    pub ranks: f64,
    /// Ranks per NVLink domain.
    pub per_domain: f64,
    /// Domains spanned (clamped to the feasible range — a requested
    /// span below the natural minimum prices as naturally packed).
    pub span: f64,
    pub intra_bw: f64,
    pub intra_lat: f64,
    /// Per-GPU single-rail bandwidth across domains.
    pub inter_bw: f64,
    /// Rails a cross-domain stage stripes over (>= 1).
    pub rails: f64,
    pub inter_lat: f64,
}

impl LinkPath {
    /// Leader-aggregated bandwidth of a hierarchical inter stage.
    fn agg_bw(&self) -> f64 {
        self.inter_bw * self.rails
    }

    /// The ideal-link version of this path (latency-free, efficiency
    /// 1.0) — the Speed-of-Light bound used by
    /// [`crate::perfdb::sol`] on tiered fabrics.
    pub fn bound(&self) -> LinkPath {
        LinkPath {
            intra_bw: self.intra_bw / COLL_EFF,
            inter_bw: self.inter_bw / COLL_EFF,
            intra_lat: 0.0,
            inter_lat: 0.0,
            ..*self
        }
    }

    fn crosses(&self) -> bool {
        self.span > 1.0
    }
}

/// Build the link path of a `gpus`-wide group placed over `span`
/// domains with `rails`-way striping. Spans clamp into the feasible
/// range, so ops constructed with the packed default price as
/// naturally packed.
pub fn path_for(c: &ClusterSpec, gpus: u32, span: u32, rails: u32) -> LinkPath {
    let g = gpus.max(1);
    let natural = super::placement::natural_span(c, g);
    let ndom = super::placement::num_domains(c);
    let span = span.max(natural).min(ndom).min(g);
    let per_domain = g.div_ceil(span);
    let f = &c.fabric;
    let rails = rails.clamp(1, f.rails.max(1));
    // Second-level fabric: a group spanning more nodes than one pod
    // pays the spine on its inter stage.
    let nodes = g.div_ceil(c.gpus_per_node.max(1));
    let (rail_gbs, inter_lat) = if f.pod_nodes > 0 && nodes > f.pod_nodes {
        (f.pod_gbs, f.pod_latency_us)
    } else {
        (f.rail_gbs, f.ib_latency_us)
    };
    LinkPath {
        ranks: g as f64,
        per_domain: per_domain as f64,
        span: span as f64,
        intra_bw: c.nvlink_bw_gbs() * 1e3 * COLL_EFF,
        intra_lat: f.intra_latency_us,
        inter_bw: rail_gbs * 1e3 * COLL_EFF,
        rails: rails as f64,
        inter_lat,
    }
}

/// Ring all-reduce primitive: 2(g-1)/g of the data per link, 2(g-1)
/// latency hops.
fn ring_allreduce(bytes: f64, g: f64, bw: f64, lat: f64) -> f64 {
    if g <= 1.0 {
        return 0.0;
    }
    2.0 * (g - 1.0) / g * bytes / bw + 2.0 * (g - 1.0) * lat
}

fn ring_allgather(bytes: f64, g: f64, bw: f64, lat: f64) -> f64 {
    if g <= 1.0 {
        return 0.0;
    }
    (g - 1.0) * (bytes / bw + lat)
}

fn bottleneck(p: &LinkPath) -> (f64, f64) {
    if p.crosses() {
        (p.inter_bw, p.inter_lat)
    } else {
        (p.intra_bw, p.intra_lat)
    }
}

// ---------------------------------------------------------------------------
// Tiered algorithms (each public so the `topo` cost tables and the
// property tests can inspect the selection).
// ---------------------------------------------------------------------------

/// Flat ring all-reduce over the path's bottleneck link.
pub fn allreduce_flat_us(p: &LinkPath, bytes: f64) -> f64 {
    let (bw, lat) = bottleneck(p);
    ring_allreduce(bytes, p.ranks, bw, lat)
}

/// Binary-tree all-reduce (reduce + broadcast): latency-optimal for
/// small messages, bandwidth-poor for large ones.
pub fn allreduce_tree_us(p: &LinkPath, bytes: f64) -> f64 {
    if p.ranks <= 1.0 {
        return 0.0;
    }
    let (bw, lat) = bottleneck(p);
    let stages = 2.0 * p.ranks.log2().ceil().max(1.0);
    stages * (bytes / bw + lat)
}

/// Hierarchical two-stage all-reduce: ring reduce-scatter/all-gather
/// inside each NVLink domain, then a rail-striped ring all-reduce of
/// the per-domain shards across domains.
pub fn allreduce_hier_us(p: &LinkPath, bytes: f64) -> f64 {
    if !p.crosses() {
        return allreduce_flat_us(p, bytes);
    }
    ring_allreduce(bytes, p.per_domain, p.intra_bw, p.intra_lat)
        + ring_allreduce(bytes / p.per_domain, p.span, p.agg_bw(), p.inter_lat)
}

/// Flat ring all-gather of per-GPU `bytes` shards.
pub fn allgather_flat_us(p: &LinkPath, bytes: f64) -> f64 {
    let (bw, lat) = bottleneck(p);
    ring_allgather(bytes, p.ranks, bw, lat)
}

/// Hierarchical all-gather: intra-domain ring, then domain shards
/// exchanged across rails.
pub fn allgather_hier_us(p: &LinkPath, bytes: f64) -> f64 {
    if !p.crosses() {
        return allgather_flat_us(p, bytes);
    }
    ring_allgather(bytes, p.per_domain, p.intra_bw, p.intra_lat)
        + (p.span - 1.0) * (p.per_domain * bytes / p.agg_bw() + p.inter_lat)
}

/// Pairwise all-to-all: every rank exchanges with every other over the
/// bottleneck link (the seed's cost shape).
pub fn alltoall_flat_us(p: &LinkPath, bytes: f64) -> f64 {
    if p.ranks <= 1.0 {
        return 0.0;
    }
    let (bw, lat) = bottleneck(p);
    (p.ranks - 1.0) / p.ranks * bytes / bw + lat * (p.ranks - 1.0).sqrt() * 2.0
}

/// Hierarchical all-to-all: the local fraction moves on NVLink, the
/// remote fraction is gathered per domain and striped across rails
/// (DeepEP/PXN-style). Rail striping shares the domain's rails among
/// its senders, so it wins on wide-rail fabrics and loses when one
/// rail per GPU is already available — min-cost selection decides.
pub fn alltoall_hier_us(p: &LinkPath, bytes: f64) -> f64 {
    if !p.crosses() {
        return alltoall_flat_us(p, bytes);
    }
    let local = (p.per_domain - 1.0).max(0.0) / (p.ranks - 1.0);
    let remote = 1.0 - local;
    let remote_bw = p.agg_bw() / p.per_domain;
    bytes * local / p.intra_bw
        + 2.0 * (p.per_domain - 1.0).max(0.0).sqrt() * p.intra_lat
        + bytes * remote / remote_bw
        + 2.0 * (p.span - 1.0).sqrt() * p.inter_lat
}

// ---------------------------------------------------------------------------
// Min-cost entry points (model dispatch).
// ---------------------------------------------------------------------------

/// All-reduce of `bytes` across a placed group, microseconds.
pub fn allreduce_us(c: &ClusterSpec, bytes: f64, gpus: u32, span: u32, rails: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    if !c.fabric.placement_aware() {
        return legacy_allreduce_us(c, bytes, gpus);
    }
    let p = path_for(c, gpus, span, rails);
    allreduce_flat_us(&p, bytes)
        .min(allreduce_tree_us(&p, bytes))
        .min(allreduce_hier_us(&p, bytes))
}

/// All-gather where each GPU contributes a `bytes` shard.
pub fn allgather_us(c: &ClusterSpec, bytes: f64, gpus: u32, span: u32, rails: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    if !c.fabric.placement_aware() {
        return legacy_allgather_us(c, bytes, gpus);
    }
    let p = path_for(c, gpus, span, rails);
    allgather_flat_us(&p, bytes).min(allgather_hier_us(&p, bytes))
}

/// All-to-all of `bytes` sent per GPU (MoE dispatch/combine).
pub fn alltoall_us(c: &ClusterSpec, bytes: f64, gpus: u32, span: u32, rails: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    if !c.fabric.placement_aware() {
        return legacy_alltoall_us(c, bytes, gpus);
    }
    let p = path_for(c, gpus, span, rails);
    alltoall_flat_us(&p, bytes).min(alltoall_hier_us(&p, bytes))
}

/// Point-to-point transfer over the fabric path (PP stage boundary,
/// disaggregated KV transfer). `cross` = the endpoints live in
/// different NVLink domains.
pub fn p2p_us(c: &ClusterSpec, bytes: f64, cross: bool, rails: u32) -> f64 {
    if !c.fabric.placement_aware() {
        return legacy_p2p_us(c, bytes, cross);
    }
    if cross {
        let p = path_for(c, c.domain_size().saturating_mul(2).max(2), 2, rails);
        let bw = p.inter_bw / COLL_EFF * P2P_EFF * p.rails;
        p.inter_lat + bytes / bw
    } else {
        let bw = c.nvlink_bw_gbs() * 1e3 * P2P_EFF;
        c.fabric.intra_latency_us + bytes / bw
    }
}

/// The ratio a placement moves a collective's cost off its naturally
/// packed baseline — how [`crate::perfdb::PerfDatabase`] (profiled at
/// the packed layout) prices placed ops without re-profiling: the
/// interpolated base latency is scaled by this analytic factor. 1.0 on
/// legacy fabrics, for non-collective ops, and for packed placements.
pub fn placement_factor(c: &ClusterSpec, op: &Op) -> f64 {
    if !c.fabric.placement_aware() {
        return 1.0;
    }
    // Packed ops (the majority of grid points) would compute identical
    // placed and packed costs — skip both evaluations on the query hot
    // path. Exact: the ratio below is 1.0 bit-for-bit in this case.
    match *op {
        Op::AllReduce { span, rails, .. }
        | Op::AllGather { span, rails, .. }
        | Op::AllToAll { span, rails, .. }
            if span <= 1 && rails <= 1 =>
        {
            return 1.0;
        }
        _ => {}
    }
    let ratio = |placed: f64, packed: f64| {
        if packed > 0.0 && placed.is_finite() {
            placed / packed
        } else {
            1.0
        }
    };
    match *op {
        Op::AllReduce { bytes, gpus, span, rails, .. } => ratio(
            allreduce_us(c, bytes, gpus, span, rails),
            allreduce_us(c, bytes, gpus, 1, 1),
        ),
        Op::AllGather { bytes, gpus, span, rails, .. } => ratio(
            allgather_us(c, bytes, gpus, span, rails),
            allgather_us(c, bytes, gpus, 1, 1),
        ),
        Op::AllToAll { bytes, gpus, span, rails, .. } => ratio(
            alltoall_us(c, bytes, gpus, span, rails),
            alltoall_us(c, bytes, gpus, 1, 1),
        ),
        _ => 1.0,
    }
}

/// Precomputed placed/packed [`LinkPath`] pairs for every
/// `(gpus, span, rails)` a cluster can pose — the hot-path twin of
/// [`placement_factor`]. [`crate::perfdb::PerfDatabase`] builds one
/// table per database and answers each placed-collective query with two
/// cached path lookups plus the (cheap, closed-form) per-algorithm
/// minimum, instead of re-deriving both paths through
/// [`path_for`]'s clamping chain per op.
///
/// Deliberately a table of *paths*, not of factors bucketed by message
/// size: min-cost algorithm selection flips continuously with `bytes`,
/// so any byte bucketing would break the bit-for-bit parity this table
/// guarantees (`factor` == [`placement_factor`] exactly, pinned by a
/// property test below).
#[derive(Clone, Debug)]
pub struct PlacementTable {
    aware: bool,
    gpus_max: u32,
    span_max: u32,
    rails_max: u32,
    /// Placed paths, `[(g-1)·span_max + (s-1)]·rails_max + (r-1)`.
    placed: Vec<LinkPath>,
    /// Packed (`span=1, rails=1`) paths, indexed `g-1`.
    packed: Vec<LinkPath>,
}

impl PlacementTable {
    /// Enumerate every path the cluster can pose. Legacy fabrics skip
    /// the enumeration entirely (every factor is 1.0 there).
    pub fn build(c: &ClusterSpec) -> PlacementTable {
        if !c.fabric.placement_aware() {
            return PlacementTable {
                aware: false,
                gpus_max: 0,
                span_max: 0,
                rails_max: 0,
                placed: Vec::new(),
                packed: Vec::new(),
            };
        }
        let gpus_max = c.total_gpus().max(1);
        let span_max = super::placement::num_domains(c).max(1);
        let rails_max = c.fabric.rails.max(1);
        let mut placed =
            Vec::with_capacity((gpus_max * span_max * rails_max) as usize);
        for g in 1..=gpus_max {
            for s in 1..=span_max {
                for r in 1..=rails_max {
                    placed.push(path_for(c, g, s, r));
                }
            }
        }
        let packed = (1..=gpus_max).map(|g| path_for(c, g, 1, 1)).collect();
        PlacementTable { aware: true, gpus_max, span_max, rails_max, placed, packed }
    }

    /// The cached (placed, packed) pair for a group. Lookups clamp span
    /// and rails exactly as [`path_for`] does internally, so a table
    /// hit returns the identical `LinkPath`; groups wider than the
    /// cluster (never produced by the search, but possible through the
    /// public API) fall back to the exact on-the-fly construction.
    fn paths(&self, c: &ClusterSpec, gpus: u32, span: u32, rails: u32) -> (LinkPath, LinkPath) {
        let s = span.clamp(1, self.span_max);
        let r = rails.clamp(1, self.rails_max);
        if gpus >= 1 && gpus <= self.gpus_max {
            let i = (((gpus - 1) * self.span_max + (s - 1)) * self.rails_max + (r - 1)) as usize;
            (self.placed[i], self.packed[(gpus - 1) as usize])
        } else {
            (path_for(c, gpus, span, rails), path_for(c, gpus, 1, 1))
        }
    }

    /// Table-served twin of [`placement_factor`] — bit-identical.
    pub fn factor(&self, c: &ClusterSpec, op: &Op) -> f64 {
        if !self.aware {
            return 1.0;
        }
        match *op {
            Op::AllReduce { span, rails, .. }
            | Op::AllGather { span, rails, .. }
            | Op::AllToAll { span, rails, .. }
                if span <= 1 && rails <= 1 =>
            {
                return 1.0;
            }
            _ => {}
        }
        let ratio = |placed: f64, packed: f64| {
            if packed > 0.0 && placed.is_finite() {
                placed / packed
            } else {
                1.0
            }
        };
        match *op {
            Op::AllReduce { bytes, gpus, span, rails, .. } => {
                if gpus <= 1 {
                    return 1.0;
                }
                let (pl, pk) = self.paths(c, gpus, span, rails);
                ratio(
                    allreduce_flat_us(&pl, bytes)
                        .min(allreduce_tree_us(&pl, bytes))
                        .min(allreduce_hier_us(&pl, bytes)),
                    allreduce_flat_us(&pk, bytes)
                        .min(allreduce_tree_us(&pk, bytes))
                        .min(allreduce_hier_us(&pk, bytes)),
                )
            }
            Op::AllGather { bytes, gpus, span, rails, .. } => {
                if gpus <= 1 {
                    return 1.0;
                }
                let (pl, pk) = self.paths(c, gpus, span, rails);
                ratio(
                    allgather_flat_us(&pl, bytes).min(allgather_hier_us(&pl, bytes)),
                    allgather_flat_us(&pk, bytes).min(allgather_hier_us(&pk, bytes)),
                )
            }
            Op::AllToAll { bytes, gpus, span, rails, .. } => {
                if gpus <= 1 {
                    return 1.0;
                }
                let (pl, pk) = self.paths(c, gpus, span, rails);
                ratio(
                    alltoall_flat_us(&pl, bytes).min(alltoall_hier_us(&pl, bytes)),
                    alltoall_flat_us(&pk, bytes).min(alltoall_hier_us(&pk, bytes)),
                )
            }
            _ => 1.0,
        }
    }
}

/// Speed-of-Light bound of a placed collective on a tiered fabric
/// (latency-free, efficiency-1 links, min over algorithms). `None` on
/// legacy fabrics — [`crate::perfdb::sol`] keeps the seed's roofline
/// there.
pub fn sol_bound_us(c: &ClusterSpec, op: &Op) -> Option<f64> {
    if !c.fabric.placement_aware() {
        return None;
    }
    Some(match *op {
        Op::AllReduce { bytes, gpus, span, rails, .. } => {
            if gpus <= 1 {
                0.0
            } else {
                let p = path_for(c, gpus, span, rails).bound();
                allreduce_flat_us(&p, bytes)
                    .min(allreduce_tree_us(&p, bytes))
                    .min(allreduce_hier_us(&p, bytes))
            }
        }
        Op::AllGather { bytes, gpus, span, rails, .. }
        | Op::AllToAll { bytes, gpus, span, rails, .. } => {
            if gpus <= 1 {
                0.0
            } else {
                let p = path_for(c, gpus, span, rails).bound();
                match op {
                    Op::AllGather { .. } => {
                        allgather_flat_us(&p, bytes).min(allgather_hier_us(&p, bytes))
                    }
                    _ => alltoall_flat_us(&p, bytes).min(alltoall_hier_us(&p, bytes)),
                }
            }
        }
        Op::P2p { bytes, cross_node, .. } => {
            let link = if cross_node {
                c.fabric.rail_gbs
            } else {
                c.nvlink_bw_gbs()
            };
            bytes / (link * 1e3)
        }
        _ => return None,
    })
}

/// One row per (collective, algorithm): the cost table the `topo`
/// subcommand prints for a preset.
pub fn algo_table(
    c: &ClusterSpec,
    gpus: u32,
    span: u32,
    rails: u32,
    bytes: f64,
) -> Vec<(&'static str, f64)> {
    if !c.fabric.placement_aware() {
        return vec![
            ("allreduce/ring(legacy)", legacy_allreduce_us(c, bytes, gpus)),
            ("allgather/ring(legacy)", legacy_allgather_us(c, bytes, gpus)),
            ("alltoall/pairwise(legacy)", legacy_alltoall_us(c, bytes, gpus)),
        ];
    }
    let p = path_for(c, gpus, span, rails);
    vec![
        ("allreduce/ring", allreduce_flat_us(&p, bytes)),
        ("allreduce/tree", allreduce_tree_us(&p, bytes)),
        ("allreduce/hier", allreduce_hier_us(&p, bytes)),
        ("allgather/ring", allgather_flat_us(&p, bytes)),
        ("allgather/hier", allgather_hier_us(&p, bytes)),
        ("alltoall/pairwise", alltoall_flat_us(&p, bytes)),
        ("alltoall/hier", alltoall_hier_us(&p, bytes)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::h100_sxm;
    use crate::topology::fabric;
    use crate::util::rng::Rng;

    fn hgx(nodes: u32) -> ClusterSpec {
        ClusterSpec::with_fabric(h100_sxm(), 8, nodes, fabric::hgx_h100())
    }

    #[test]
    fn single_rank_is_free_in_both_models() {
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        let tiered = hgx(2);
        for c in [legacy, tiered] {
            assert_eq!(allreduce_us(&c, 1e8, 1, 1, 1), 0.0);
            assert_eq!(alltoall_us(&c, 1e8, 1, 1, 1), 0.0);
            assert_eq!(allgather_us(&c, 1e8, 1, 1, 1), 0.0);
        }
    }

    #[test]
    fn hier_allreduce_never_exceeds_flat_ring_cross_node() {
        // Property (satellite): on cross-node groups of every tiered
        // preset, the hierarchical two-stage all-reduce is at most the
        // flat cross-fabric ring, across message sizes, group widths
        // and rail choices (power-of-two groups — the widths the
        // profiled grid snaps to).
        let mut rng = Rng::new(0x70F0);
        for f in fabric::all() {
            let c = ClusterSpec::with_fabric(h100_sxm(), 8, 16, f);
            for _ in 0..200 {
                let bytes = 10f64.powf(2.0 + 7.0 * rng.f64()); // 100 B .. 1 GB
                let g = 2u32.pow(1 + rng.below(7) as u32); // 2 .. 128
                if g <= c.domain_size() {
                    continue; // intra-domain: hier == flat by definition
                }
                let span = super::super::placement::natural_span(&c, g)
                    * (1 + rng.below(2) as u32);
                let rails = 1 + rng.below(c.fabric.rails as u64) as u32;
                let p = path_for(&c, g, span, rails);
                let hier = allreduce_hier_us(&p, bytes);
                let flat = allreduce_flat_us(&p, bytes);
                assert!(
                    hier <= flat * (1.0 + 1e-9),
                    "{}: g={g} span={span} rails={rails} bytes={bytes:.0}: hier={hier} flat={flat}",
                    c.fabric.name
                );
            }
        }
    }

    #[test]
    fn min_cost_selection_tracks_message_size() {
        // Small messages: tree (latency-optimal) beats ring; large
        // messages: hierarchical (bandwidth-optimal) wins on a
        // cross-node path.
        let c = hgx(2);
        let p = path_for(&c, 16, 2, 4);
        assert!(allreduce_tree_us(&p, 1024.0) < allreduce_flat_us(&p, 1024.0));
        assert!(allreduce_hier_us(&p, 1e9) < allreduce_tree_us(&p, 1e9));
        // The dispatcher equals the component minimum.
        for bytes in [1024.0, 1e6, 1e9] {
            let sel = allreduce_us(&c, bytes, 16, 2, 4);
            let min = allreduce_flat_us(&p, bytes)
                .min(allreduce_tree_us(&p, bytes))
                .min(allreduce_hier_us(&p, bytes));
            assert_eq!(sel, min);
        }
    }

    #[test]
    fn rails_help_large_cross_domain_collectives() {
        let c = ClusterSpec::with_fabric(h100_sxm(), 8, 4, fabric::dgx_multirail());
        let one = allreduce_us(&c, 1e9, 32, 4, 1);
        let eight = allreduce_us(&c, 1e9, 32, 4, 8);
        assert!(eight < one, "striping must help: r1={one} r8={eight}");
        let a2a_one = alltoall_us(&c, 1e8, 32, 4, 1);
        let a2a_eight = alltoall_us(&c, 1e8, 32, 4, 8);
        assert!(a2a_eight <= a2a_one, "a2a r1={a2a_one} r8={a2a_eight}");
    }

    #[test]
    fn span_clamps_to_natural() {
        // A packed-constructed op on a group wider than a domain prices
        // as naturally packed, not as an impossible single-domain group.
        let c = hgx(2);
        let under = path_for(&c, 16, 1, 1);
        assert_eq!(under.span, 2.0);
        assert_eq!(under.per_domain, 8.0);
        let over = path_for(&c, 4, 64, 1);
        assert!(over.span <= 2.0);
    }

    #[test]
    fn wide_domain_prices_everything_on_nvlink() {
        let c = ClusterSpec::with_fabric(h100_sxm(), 4, 8, fabric::gb200_nvl72());
        // 32 GPUs inside one NVL72 domain: far cheaper than the same
        // group on an hgx fabric of the same GPU count.
        let wide = allreduce_us(&c, 1e8, 32, 1, 1);
        let narrow = allreduce_us(&hgx(4), 1e8, 32, 1, 1);
        assert!(wide < narrow * 0.8, "wide={wide} narrow={narrow}");
    }

    #[test]
    fn pod_spine_penalizes_very_wide_groups() {
        // Two-node pods: a 16-GPU group stays inside one pod, a 32-GPU
        // group (4 nodes) crosses the spine and pays its
        // bandwidth/latency on the inter stage.
        let mut f = fabric::dgx_multirail();
        f.pod_nodes = 2;
        f.rails = 1;
        let c = ClusterSpec::with_fabric(h100_sxm(), 8, 4, f);
        let in_pod = allreduce_us(&c, 1e8, 16, 2, 1);
        let cross_pod = allreduce_us(&c, 1e8, 32, 4, 1);
        assert!(cross_pod > in_pod * 1.5, "in={in_pod} cross={cross_pod}");
    }

    #[test]
    fn placement_factor_is_one_when_packed_or_legacy() {
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        let op = Op::AllReduce { bytes: 1e8, gpus: 16, span: 2, rails: 1, count: 1 };
        assert_eq!(placement_factor(&legacy, &op), 1.0);
        let tiered = hgx(2);
        let packed = Op::AllReduce { bytes: 1e8, gpus: 8, span: 1, rails: 1, count: 1 };
        assert_eq!(placement_factor(&tiered, &packed), 1.0);
        // A TP8 group forced across two domains prices worse than
        // packed — the factor exceeds 1.
        let spanned = Op::AllReduce { bytes: 1e8, gpus: 8, span: 2, rails: 1, count: 1 };
        assert!(placement_factor(&tiered, &spanned) > 1.0);
        // Rail striping on a cross-node group prices better — below 1.
        let striped = Op::AllToAll { bytes: 1e8, gpus: 16, span: 2, rails: 4, count: 1 };
        assert!(placement_factor(&tiered, &striped) <= 1.0);
    }

    #[test]
    fn placement_table_matches_placement_factor_bit_for_bit() {
        // Property (tentpole pin): the precomputed path table answers
        // every collective op the search can pose with exactly the
        // same factor as the on-the-fly computation — across presets,
        // group widths, spans (incl. out-of-range requests that
        // path_for clamps), rails, message sizes and op kinds.
        let mut rng = Rng::new(0x91ACE);
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        let mut clusters: Vec<ClusterSpec> = fabric::all()
            .into_iter()
            .map(|f| ClusterSpec::with_fabric(h100_sxm(), 8, 4, f))
            .collect();
        clusters.push(legacy);
        for c in &clusters {
            let table = PlacementTable::build(c);
            for _ in 0..300 {
                let bytes = 10f64.powf(1.0 + 8.0 * rng.f64());
                let gpus = 1 + rng.below(2 * c.total_gpus() as u64) as u32;
                let span = rng.below(20) as u32;
                let rails = rng.below(12) as u32;
                let count = 1 + rng.below(3) as u32;
                let ops = [
                    Op::AllReduce { bytes, gpus, span, rails, count },
                    Op::AllGather { bytes, gpus, span, rails, count },
                    Op::AllToAll { bytes, gpus, span, rails, count },
                ];
                for op in ops {
                    assert_eq!(
                        table.factor(c, &op).to_bits(),
                        placement_factor(c, &op).to_bits(),
                        "{}: {op:?}",
                        c.fabric.name
                    );
                }
            }
        }
    }

    #[test]
    fn sol_bound_is_below_the_model() {
        let c = hgx(2);
        for (gpus, span, rails) in [(8u32, 1u32, 1u32), (16, 2, 1), (16, 2, 4)] {
            for bytes in [1e4, 1e6, 1e8] {
                let op = Op::AllReduce { bytes, gpus, span, rails, count: 1 };
                let bound = sol_bound_us(&c, &op).unwrap();
                let model = allreduce_us(&c, bytes, gpus, span, rails);
                assert!(bound <= model * (1.0 + 1e-9), "bound={bound} model={model}");
            }
        }
        // Legacy fabrics answer None (seed roofline kept).
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        let op = Op::AllReduce { bytes: 1e6, gpus: 16, span: 2, rails: 1, count: 1 };
        assert!(sol_bound_us(&legacy, &op).is_none());
    }

    #[test]
    fn p2p_cross_domain_pays_the_rail() {
        let c = hgx(2);
        let nv = p2p_us(&c, 1e8, false, 1);
        let ib = p2p_us(&c, 1e8, true, 1);
        assert!(ib > nv * 5.0, "nv={nv} ib={ib}");
        // Legacy model keeps the seed formula bit-for-bit.
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        assert_eq!(p2p_us(&legacy, 1e8, true, 1), legacy_p2p_us(&legacy, 1e8, true));
    }
}

//! Topology-aware placement & collective-cost subsystem.
//!
//! The paper's headline claim is rapid exploration "from cluster
//! topology down to engine-specific flags"; this subsystem supplies the
//! topology half:
//!
//! * [`fabric`] — tiered [`FabricSpec`] descriptions (NVLink-domain
//!   width, intra-node tier, per-node IB rails, optional second-level
//!   pod fabric) with named presets, replacing the seed's three
//!   hard-coded `ClusterSpec` link constants (kept bit-for-bit behind
//!   [`crate::hardware::ClusterSpec::new`]);
//! * [`placement`] — maps a `(tp, pp, ep, dp)` shape onto the fabric,
//!   enumerating the distinct feasible rank layouts
//!   ([`Placement`]) the search prices as a structural axis;
//! * [`collective`] — per-algorithm cost models (flat ring, tree,
//!   hierarchical two-stage, pairwise vs rail-striped hierarchical
//!   all-to-all) with min-cost selection per message size over the
//!   placement's link path. [`crate::silicon::comm`] delegates here;
//!   [`crate::perfdb`] prices placed collectives by scaling its
//!   profiled packed baseline with [`collective::placement_factor`].

pub mod collective;
pub mod fabric;
pub mod placement;

pub use fabric::{FabricModel, FabricSpec};
pub use placement::Placement;

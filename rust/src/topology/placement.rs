//! Rank placement: how a `(tp, pp, ep, dp)` shape maps onto the fabric.
//!
//! On a tiered fabric the *same* parallel shape admits several distinct
//! rank layouts with different communication bills — a TP8 group can
//! live inside one NVLink domain (TP all-NVLink, PP boundaries over IB)
//! or span two domains with the pipeline stages interleaved per domain
//! (TP hierarchical over IB, PP boundaries on NVLink). [`enumerate`]
//! lists the feasible layouts so the search prices *placements*, not
//! just shapes; the chosen one rides on
//! [`crate::config::EngineConfig::placement`] into reports, service
//! responses and launch bundles.
//!
//! On the legacy fabric enumeration collapses to [`Placement::packed`]
//! and pricing is bit-for-bit the seed's (the spans are ignored by the
//! legacy cost model), so existing search surfaces are unchanged.

use crate::config::ParallelSpec;
use crate::hardware::ClusterSpec;

/// One concrete rank layout. All fields are *resolved* (no "auto"):
/// the collective cost model clamps spans up to the minimum feasible
/// value at pricing time, so a default-constructed packed placement is
/// always safe to price.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Placement {
    /// NVLink domains the TP group is spread across (1 = packed inside
    /// one domain when it fits).
    pub tp_span: u32,
    /// Domains the EP group spans (derived from the layout geometry).
    pub ep_span: u32,
    /// Pipeline stages interleaved across domains: every domain holds a
    /// slice of every stage, so PP boundaries become intra-domain hops
    /// (only meaningful when `tp_span > 1` and `pp > 1`).
    pub interleave_pp: bool,
    /// IB rails a cross-domain stage stripes over (1 = single rail).
    pub rails: u32,
}

impl Placement {
    /// The dense packed layout — the seed's implicit placement.
    pub const fn packed() -> Placement {
        Placement { tp_span: 1, ep_span: 1, interleave_pp: false, rails: 1 }
    }

    /// Compact label for reports / launch files ("packed" for the
    /// default layout). Every non-default field contributes a token —
    /// including `ep_span`, so an EP-spanning layout is never
    /// mislabelled as packed.
    pub fn label(&self) -> String {
        if *self == Placement::packed() {
            return "packed".to_string();
        }
        let mut parts: Vec<String> = Vec::new();
        if self.tp_span > 1 {
            parts.push(format!("tp{}dom", self.tp_span));
        }
        if self.ep_span > 1 {
            parts.push(format!("ep{}dom", self.ep_span));
        }
        if self.interleave_pp {
            parts.push("ilv".to_string());
        }
        if self.rails > 1 {
            parts.push(format!("r{}", self.rails));
        }
        if parts.is_empty() {
            // Unreachable for well-formed placements; keep the label
            // honest rather than claiming "packed".
            parts.push("custom".to_string());
        }
        parts.join("-")
    }
}

impl Default for Placement {
    fn default() -> Self {
        Placement::packed()
    }
}

/// Number of NVLink domains on the cluster.
pub fn num_domains(cluster: &ClusterSpec) -> u32 {
    cluster.total_gpus().div_ceil(cluster.domain_size()).max(1)
}

/// Minimum number of domains a `gpus`-wide group must span.
pub fn natural_span(cluster: &ClusterSpec, gpus: u32) -> u32 {
    gpus.max(1).div_ceil(cluster.domain_size()).min(num_domains(cluster)).max(1)
}

/// Domains occupied by one engine instance under a given TP span.
fn domains_used(cluster: &ClusterSpec, p: &ParallelSpec, tp_span: u32) -> u32 {
    let by_size = p.gpus().max(1).div_ceil(cluster.domain_size());
    tp_span.max(by_size).min(num_domains(cluster)).max(1)
}

/// Is `pl` a feasible layout of `p` on the cluster's fabric?
///
/// Rules (shared with [`enumerate`] and the brute-force coverage
/// property test):
/// * `tp_span` divides `tp`, is at least the natural span, at most
///   `min(tp, num_domains)`, and leaves `tp / tp_span <= domain` ranks
///   per domain;
/// * `ep_span` is exactly the derived value `min(ep, domains_used)`;
/// * `interleave_pp` requires both `tp_span > 1` and `pp > 1`, and is
///   mandatory when TP spans domains (stages co-reside per domain by
///   construction);
/// * `rails` lies in `1..=fabric.rails`.
pub fn is_feasible(cluster: &ClusterSpec, p: &ParallelSpec, pl: &Placement) -> bool {
    let d = cluster.domain_size();
    let tp = p.tp.max(1);
    if pl.tp_span == 0 || tp % pl.tp_span != 0 {
        return false;
    }
    if pl.tp_span < natural_span(cluster, tp) || pl.tp_span > tp.min(num_domains(cluster)) {
        return false;
    }
    if tp / pl.tp_span > d {
        return false;
    }
    if pl.ep_span != p.ep.max(1).min(domains_used(cluster, p, pl.tp_span)) {
        return false;
    }
    if pl.interleave_pp != (pl.tp_span > 1 && p.pp > 1) {
        return false;
    }
    if pl.rails == 0 || pl.rails > cluster.fabric.rails.max(1) {
        return false;
    }
    true
}

/// Enumerate the distinct feasible layouts of `p` on the cluster.
///
/// Legacy fabrics return exactly `[Placement::packed()]` so the search
/// grid (and therefore every pinned result) is unchanged. Tiered
/// fabrics enumerate the TP-span divisors and, when any stage crosses
/// domains on a multi-rail fabric, the `{1, rails}` striping extremes
/// (intermediate rail counts are dominated by one of the two under the
/// monotone cost model). The list is duplicate-free and deterministic
/// (spans ascending, single-rail first).
pub fn enumerate(cluster: &ClusterSpec, p: &ParallelSpec) -> Vec<Placement> {
    if !cluster.fabric.placement_aware() {
        return vec![Placement::packed()];
    }
    let tp = p.tp.max(1);
    let mut out: Vec<Placement> = Vec::new();
    for tp_span in 1..=tp {
        if tp % tp_span != 0 {
            continue;
        }
        let used = domains_used(cluster, p, tp_span);
        // Rail striping only prices differently when a rail-striping
        // collective (TP or EP group) actually crosses domains; PP
        // boundaries are single point-to-point hops. Enumerating rails
        // otherwise would emit price-identical duplicate layouts.
        let crosses = tp_span > 1 || p.ep.max(1).min(used) > 1;
        let rail_opts: &[u32] = if crosses && cluster.fabric.rails > 1 {
            &[1, 0] // 0 is a marker replaced by fabric.rails below
        } else {
            &[1]
        };
        for &r in rail_opts {
            let pl = Placement {
                tp_span,
                ep_span: p.ep.max(1).min(used),
                interleave_pp: tp_span > 1 && p.pp > 1,
                rails: if r == 0 { cluster.fabric.rails } else { r },
            };
            if is_feasible(cluster, p, &pl) && !out.contains(&pl) {
                out.push(pl);
            }
        }
    }
    if out.is_empty() {
        out.push(Placement::packed());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::h100_sxm;
    use crate::topology::fabric;

    fn hgx(nodes: u32) -> ClusterSpec {
        ClusterSpec::with_fabric(h100_sxm(), 8, nodes, fabric::hgx_h100())
    }

    #[test]
    fn legacy_fabric_collapses_to_packed() {
        let c = ClusterSpec::new(h100_sxm(), 8, 2);
        let p = ParallelSpec { tp: 8, pp: 2, ep: 1, dp: 1 };
        assert_eq!(enumerate(&c, &p), vec![Placement::packed()]);
    }

    #[test]
    fn single_domain_shape_has_one_layout_per_rail_rule() {
        let c = hgx(1);
        let p = ParallelSpec::tp(4);
        // Fits one domain, nothing crosses: exactly the packed layout.
        assert_eq!(enumerate(&c, &p), vec![Placement::packed()]);
    }

    #[test]
    fn two_node_tp8_pp2_yields_distinct_layouts() {
        let c = hgx(2);
        let p = ParallelSpec { tp: 8, pp: 2, ep: 1, dp: 1 };
        let pls = enumerate(&c, &p);
        // Packed-TP (PP over IB), and TP-spanning (PP interleaved on
        // NVLink) at 1 and 4 rails.
        assert!(pls.len() >= 3, "{pls:?}");
        assert!(pls.iter().any(|pl| pl.tp_span == 1 && !pl.interleave_pp));
        assert!(pls.iter().any(|pl| pl.tp_span == 2 && pl.interleave_pp));
        assert!(pls.iter().any(|pl| pl.rails == 4));
        // Duplicate-free.
        for (i, a) in pls.iter().enumerate() {
            assert!(!pls[i + 1..].contains(a), "duplicate {a:?}");
        }
    }

    #[test]
    fn enumeration_is_exactly_the_feasible_set_on_2x8() {
        // Brute-force the rule set over a 2-node / 8-GPU-per-node grid
        // (rails clamped to the {1, max} extremes the enumerator emits)
        // and require exact coverage: nothing missing, nothing extra,
        // nothing duplicated.
        let mut c = hgx(2);
        c.fabric.rails = 2; // {1, rails} == the full rail set
        for tp in [1u32, 2, 4, 8] {
            for pp in [1u32, 2] {
                for ep in [1u32, 2, 4] {
                    let p = ParallelSpec { tp, pp, ep, dp: 1 };
                    if p.gpus() > c.total_gpus() || ep > tp {
                        continue;
                    }
                    let got = enumerate(&c, &p);
                    let mut want = Vec::new();
                    for tp_span in 1..=c.total_gpus() {
                        for rails in 1..=c.fabric.rails {
                            for ilv in [false, true] {
                                for ep_span in 1..=c.total_gpus() {
                                    let pl = Placement {
                                        tp_span,
                                        ep_span,
                                        interleave_pp: ilv,
                                        rails,
                                    };
                                    if is_feasible(&c, &p, &pl) && !want.contains(&pl) {
                                        want.push(pl);
                                    }
                                }
                            }
                        }
                    }
                    // Crossing-free layouts don't enumerate the rail
                    // axis; drop the redundant rails>1 variants from
                    // the brute-force set for comparison (they price
                    // identically — no rail-striping collective
                    // crosses domains).
                    let crosses = |pl: &Placement| pl.tp_span > 1 || pl.ep_span > 1;
                    want.retain(|pl| pl.rails == 1 || crosses(pl));
                    for pl in &got {
                        assert!(want.contains(pl), "tp{tp}pp{pp}ep{ep}: extra {pl:?}");
                    }
                    for pl in &want {
                        assert!(got.contains(pl), "tp{tp}pp{pp}ep{ep}: missing {pl:?}");
                    }
                    for (i, a) in got.iter().enumerate() {
                        assert!(!got[i + 1..].contains(a), "duplicate {a:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn wide_domain_swallows_whole_cluster() {
        // GB200 NVL72: a 32-GPU cluster is one domain — every shape is
        // packed, nothing crosses.
        let c = ClusterSpec::with_fabric(h100_sxm(), 4, 8, fabric::gb200_nvl72());
        assert_eq!(num_domains(&c), 1);
        let p = ParallelSpec { tp: 8, pp: 4, ep: 1, dp: 1 };
        assert_eq!(enumerate(&c, &p), vec![Placement::packed()]);
    }

    #[test]
    fn natural_span_clamps() {
        let c = hgx(2);
        assert_eq!(natural_span(&c, 4), 1);
        assert_eq!(natural_span(&c, 8), 1);
        assert_eq!(natural_span(&c, 16), 2);
        assert_eq!(natural_span(&c, 64), 2, "span never exceeds the domain count");
    }

    #[test]
    fn labels_are_compact_and_never_hide_a_spanning_group() {
        assert_eq!(Placement::packed().label(), "packed");
        let pl = Placement { tp_span: 2, ep_span: 2, interleave_pp: true, rails: 4 };
        assert_eq!(pl.label(), "tp2dom-ep2dom-ilv-r4");
        let pl = Placement { tp_span: 1, ep_span: 2, interleave_pp: false, rails: 8 };
        assert_eq!(pl.label(), "ep2dom-r8");
        // An EP-only spanning layout must not read as "packed".
        let pl = Placement { tp_span: 1, ep_span: 2, interleave_pp: false, rails: 1 };
        assert_eq!(pl.label(), "ep2dom");
    }
}

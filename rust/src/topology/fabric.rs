//! Tiered fabric descriptions (paper §1 "from cluster topology down to
//! engine-specific flags").
//!
//! The seed modeled topology as a single flat NVLink-vs-IB switch
//! ([`crate::hardware::ClusterSpec::link_for`]): every group of the
//! same size priced identically regardless of where its ranks land, and
//! wide-NVLink (GB200 NVL72-class), PCIe-only and multi-rail IB fabrics
//! were unrepresentable. A [`FabricSpec`] names the tiers explicitly:
//! the NVLink-domain width, the intra-domain link, the per-GPU IB rail
//! and how many rails a cross-domain stage may stripe over, plus an
//! optional second-level (pod/spine) fabric.
//!
//! Two pricing models coexist:
//! * [`FabricModel::Legacy`] reproduces the seed's flat switch
//!   **bit-for-bit** (pinned by `tests/topology.rs`) — it is what
//!   [`crate::hardware::ClusterSpec::new`] builds, so every existing
//!   surface prices exactly as before;
//! * [`FabricModel::Tiered`] enables placement-aware pricing
//!   ([`super::placement`], [`super::collective`]), selected by the
//!   named presets / `--fabric`.

/// Which cost model prices collectives over this fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricModel {
    /// The seed's flat NVLink-vs-IB switch. Placement enumeration
    /// collapses to the packed layout and every collective uses the
    /// original closed-form ring formulas.
    Legacy,
    /// Tiered, placement-aware pricing: per-algorithm cost models with
    /// min-cost selection over the placement's link path.
    Tiered,
}

/// A tiered interconnect description. `Copy` on purpose: it rides
/// inside [`crate::hardware::ClusterSpec`] everywhere a cluster goes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FabricSpec {
    /// Preset id (stable CLI / service name).
    pub name: &'static str,
    /// GPUs wired into one NVLink/NVSwitch domain. 1 = no NVLink
    /// (PCIe-only boxes); may exceed the GPUs per node (GB200 NVL72:
    /// one 72-GPU domain spanning 18 compute trays).
    pub nvlink_domain: u32,
    /// Intra-domain bandwidth override, GB/s per GPU. 0.0 = use the
    /// GPU's own `nvlink_gbs` datasheet number (NVSwitch-class parts);
    /// a positive value models a slower tier (PCIe).
    pub intra_gbs: f64,
    /// Base latency of an intra-domain hop, microseconds.
    pub intra_latency_us: f64,
    /// Per-GPU bandwidth of one IB rail (unidirectional), GB/s.
    pub rail_gbs: f64,
    /// Independent IB rails per node that a cross-domain stage may
    /// stripe over (hierarchical leader stages aggregate up to this
    /// many; flat algorithms always pay the single per-GPU rail).
    pub rails: u32,
    /// Base latency of an IB hop, microseconds.
    pub ib_latency_us: f64,
    /// Second-level fabric: nodes per pod (0 = single-level). Groups
    /// spanning more nodes than one pod pay the spine's
    /// bandwidth/latency on their inter stage.
    pub pod_nodes: u32,
    /// Spine bandwidth per GPU, GB/s (used when `pod_nodes > 0`).
    pub pod_gbs: f64,
    /// Spine hop latency, microseconds.
    pub pod_latency_us: f64,
    pub model: FabricModel,
}

impl FabricSpec {
    /// The back-compat fabric [`crate::hardware::ClusterSpec::new`]
    /// builds: exactly the three hard-coded link constants the seed
    /// carried (NVLink = the GPU's datasheet number at 2 µs, one
    /// 50 GB/s IB rail at 8 µs), priced by the legacy flat model.
    pub const fn legacy(gpus_per_node: u32) -> FabricSpec {
        FabricSpec {
            name: "legacy",
            nvlink_domain: gpus_per_node,
            intra_gbs: 0.0,
            intra_latency_us: 2.0,
            rail_gbs: 50.0,
            rails: 1,
            ib_latency_us: 8.0,
            pod_nodes: 0,
            pod_gbs: 0.0,
            pod_latency_us: 0.0,
            model: FabricModel::Legacy,
        }
    }

    /// Placement-aware pricing on?
    pub fn placement_aware(&self) -> bool {
        self.model == FabricModel::Tiered
    }
}

/// HGX H100/H200 baseboard: 8-GPU NVSwitch domain, 4×400G compute
/// rails per node.
pub fn hgx_h100() -> FabricSpec {
    FabricSpec {
        name: "hgx-h100",
        nvlink_domain: 8,
        intra_gbs: 0.0,
        intra_latency_us: 2.0,
        rail_gbs: 50.0,
        rails: 4,
        ib_latency_us: 8.0,
        pod_nodes: 0,
        pod_gbs: 0.0,
        pod_latency_us: 0.0,
        model: FabricModel::Tiered,
    }
}

/// GB200 NVL72 rack: one 72-GPU NVLink5 domain spanning 18 compute
/// trays (4 GPUs/tray), 4 rails per tray beyond the rack.
pub fn gb200_nvl72() -> FabricSpec {
    FabricSpec {
        name: "gb200-nvl72",
        nvlink_domain: 72,
        intra_gbs: 0.0,
        intra_latency_us: 1.5,
        rail_gbs: 50.0,
        rails: 4,
        ib_latency_us: 8.0,
        pod_nodes: 0,
        pod_gbs: 0.0,
        pod_latency_us: 0.0,
        model: FabricModel::Tiered,
    }
}

/// PCIe-only A100 servers: no NVLink domain, PCIe gen4 x16 between
/// GPUs in a node, a single 200G rail out.
pub fn a100_pcie() -> FabricSpec {
    FabricSpec {
        name: "a100-pcie",
        nvlink_domain: 1,
        intra_gbs: 28.0,
        intra_latency_us: 6.0,
        rail_gbs: 25.0,
        rails: 1,
        ib_latency_us: 10.0,
        pod_nodes: 0,
        pod_gbs: 0.0,
        pod_latency_us: 0.0,
        model: FabricModel::Tiered,
    }
}

/// DGX-class multi-rail pod: 8-GPU NVSwitch domain, 8×400G rails per
/// node, 32-node pods behind a 2:1-oversubscribed spine.
pub fn dgx_multirail() -> FabricSpec {
    FabricSpec {
        name: "dgx-multirail",
        nvlink_domain: 8,
        intra_gbs: 0.0,
        intra_latency_us: 2.0,
        rail_gbs: 50.0,
        rails: 8,
        ib_latency_us: 8.0,
        pod_nodes: 32,
        pod_gbs: 25.0,
        pod_latency_us: 16.0,
        model: FabricModel::Tiered,
    }
}

/// Every named preset (the `topo` subcommand iterates this; `legacy`
/// is constructed per cluster geometry and listed separately).
pub fn all() -> Vec<FabricSpec> {
    vec![hgx_h100(), gb200_nvl72(), a100_pcie(), dgx_multirail()]
}

/// Resolve a fabric by CLI/service name. `legacy` needs the cluster's
/// `gpus_per_node` to pin the domain width.
pub fn by_name(name: &str, gpus_per_node: u32) -> Option<FabricSpec> {
    let n = name.to_ascii_lowercase();
    if n == "legacy" {
        return Some(FabricSpec::legacy(gpus_per_node));
    }
    all().into_iter().find(|f| f.name == n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        for f in all() {
            let back = by_name(f.name, 8).unwrap();
            assert_eq!(back, f, "{} does not round-trip", f.name);
            assert!(back.placement_aware(), "{} presets are tiered", f.name);
        }
        assert!(by_name("warp-fabric", 8).is_none());
    }

    #[test]
    fn legacy_matches_seed_constants() {
        let f = by_name("legacy", 4).unwrap();
        assert_eq!(f.model, FabricModel::Legacy);
        assert!(!f.placement_aware());
        assert_eq!(f.nvlink_domain, 4);
        assert_eq!(f.rail_gbs, 50.0);
        assert_eq!(f.ib_latency_us, 8.0);
        assert_eq!(f.intra_latency_us, 2.0);
        assert_eq!(f.rails, 1);
    }

    #[test]
    fn preset_shapes() {
        assert_eq!(gb200_nvl72().nvlink_domain, 72);
        assert_eq!(a100_pcie().nvlink_domain, 1);
        assert!(a100_pcie().intra_gbs > 0.0, "PCIe tier overrides the GPU NVLink number");
        assert!(dgx_multirail().rails > hgx_h100().rails);
        assert!(dgx_multirail().pod_nodes > 0 && hgx_h100().pod_nodes == 0);
    }
}

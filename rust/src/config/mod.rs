//! Core configuration types: candidate serving configurations, workload
//! descriptors and SLAs (paper §4.1 "TaskRunner ... constructs a search
//! space comprised of all the valid candidate serving configurations
//! based on the user provided workload descriptor").

use crate::frameworks::Framework;
use crate::models::Dtype;
use crate::topology::Placement;
use crate::util::json::{self, Json};

/// Serving architectures modeled by AIConfigurator (paper Fig 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ServingMode {
    /// Fixed batch processed end-to-end.
    Static,
    /// Continuous/inflight batching: prefill+decode mixed per iteration.
    Aggregated,
    /// Separate prefill and decode GPU pools with KV transfer.
    Disaggregated,
}

impl ServingMode {
    pub fn name(self) -> &'static str {
        match self {
            ServingMode::Static => "static",
            ServingMode::Aggregated => "aggregated",
            ServingMode::Disaggregated => "disaggregated",
        }
    }

    pub fn parse(s: &str) -> Option<ServingMode> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(ServingMode::Static),
            "aggregated" | "agg" | "ifb" => Some(ServingMode::Aggregated),
            "disaggregated" | "disagg" | "pd" => Some(ServingMode::Disaggregated),
            _ => None,
        }
    }

    /// Whether the TaskRunner can search this mode. `Static` parses (it
    /// names Algorithm 1's fixed-batch estimation/simulation target)
    /// but is not a deployable candidate shape, so search surfaces must
    /// reject it loudly instead of silently pricing nothing — see
    /// [`crate::search::ensure_searchable_modes`].
    pub fn searchable(self) -> bool {
        !matches!(self, ServingMode::Static)
    }
}

/// Model-parallel layout of one engine instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ParallelSpec {
    /// Tensor parallelism (shards attention heads + FFN columns).
    pub tp: u32,
    /// Pipeline parallelism (shards layers).
    pub pp: u32,
    /// Expert parallelism (shards MoE experts). 1 for dense models.
    pub ep: u32,
    /// Data parallelism of the *attention* path (DeepSeek-style DP
    /// attention; also used as replica count inside one engine).
    pub dp: u32,
}

impl ParallelSpec {
    pub fn tp(tp: u32) -> Self {
        ParallelSpec { tp, pp: 1, ep: 1, dp: 1 }
    }

    /// GPUs used by a single engine instance.
    ///
    /// EP shards the expert set across the TP×DP group rather than
    /// multiplying the GPU count (TRT-LLM/vLLM wide-EP convention), so
    /// the footprint is tp × pp × dp with ep ≤ tp × dp.
    pub fn gpus(&self) -> u32 {
        self.tp * self.pp * self.dp
    }

    pub fn label(&self) -> String {
        let mut s = format!("TP{}", self.tp);
        if self.pp > 1 {
            s.push_str(&format!("PP{}", self.pp));
        }
        if self.ep > 1 {
            s.push_str(&format!("EP{}", self.ep));
        }
        if self.dp > 1 {
            s.push_str(&format!("DP{}", self.dp));
        }
        s
    }
}

/// Framework runtime flags the paper's Generator emits (§4.1: CUDA
/// graphs, KV-cache memory fraction, token capacity, chunked context).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RuntimeFlags {
    pub cuda_graph: bool,
    /// `--kv_cache_free_gpu_mem_fraction`.
    pub kv_frac: f64,
    /// Context token capacity per iteration (C_ctx, `--max_num_tokens`).
    pub max_num_tokens: u32,
    pub chunked_prefill: bool,
}

impl RuntimeFlags {
    /// The framework's stock flags. Delegates to the backend layer's
    /// single construction point ([`crate::frameworks::Backend::default_flags`])
    /// so this and the search grid can never build different
    /// "defaults".
    pub fn defaults_for(fw: Framework) -> Self {
        fw.backend().default_flags()
    }
}

/// One candidate engine configuration for a single pool.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineConfig {
    pub framework: Framework,
    pub parallel: ParallelSpec,
    /// Max batch size (decode slots) per engine instance.
    pub batch: u32,
    /// Weight quantization.
    pub weight_dtype: Dtype,
    /// KV-cache dtype.
    pub kv_dtype: Dtype,
    pub flags: RuntimeFlags,
    /// Where the parallel groups land on the fabric
    /// ([`crate::topology::placement::enumerate`]). [`Placement::packed`]
    /// on legacy fabrics — the seed's implicit layout.
    pub placement: Placement,
}

impl EngineConfig {
    pub fn label(&self) -> String {
        let place = if self.placement == Placement::packed() {
            String::new()
        } else {
            format!("-{}", self.placement.label())
        };
        format!(
            "{}-{}-b{}-{}{}{}",
            self.framework.name(),
            self.parallel.label(),
            self.batch,
            self.weight_dtype.name(),
            if self.flags.cuda_graph { "" } else { "-nograph" },
            place,
        )
    }
}

/// A full candidate deployment: aggregated (one pool) or disaggregated
/// ((x)P(y)D composite, paper §4.2.3).
#[derive(Clone, Debug, PartialEq)]
pub enum Candidate {
    Aggregated {
        engine: EngineConfig,
        /// Number of identical replicas behind the router.
        replicas: u32,
    },
    Disaggregated {
        prefill: EngineConfig,
        decode: EngineConfig,
        /// x prefill workers.
        x: u32,
        /// y decode workers.
        y: u32,
    },
}

impl Candidate {
    pub fn total_gpus(&self) -> u32 {
        match self {
            Candidate::Aggregated { engine, replicas } => engine.parallel.gpus() * replicas,
            Candidate::Disaggregated { prefill, decode, x, y } => {
                prefill.parallel.gpus() * x + decode.parallel.gpus() * y
            }
        }
    }

    pub fn mode(&self) -> ServingMode {
        match self {
            Candidate::Aggregated { .. } => ServingMode::Aggregated,
            Candidate::Disaggregated { .. } => ServingMode::Disaggregated,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Candidate::Aggregated { engine, replicas } => {
                format!("{}x {}", replicas, engine.label())
            }
            Candidate::Disaggregated { prefill, decode, x, y } => {
                format!("P:{}x{} D:{}x{}", x, prefill.label(), y, decode.label())
            }
        }
    }
}

/// Service-level agreement targets (paper §1: TTFT + TPOT SLAs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sla {
    /// Max time-to-first-token, milliseconds.
    pub ttft_ms: f64,
    /// Min generation speed, tokens/s per user ( = 1000 / max TPOT).
    pub min_speed: f64,
}

impl Sla {
    pub fn max_tpot_ms(&self) -> f64 {
        if self.min_speed <= 0.0 {
            f64::INFINITY
        } else {
            1000.0 / self.min_speed
        }
    }
}

/// User-supplied workload descriptor (paper §4.1 step 2).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub model: String,
    /// Input sequence length (tokens).
    pub isl: u32,
    /// Output sequence length (tokens) — fixed value per the paper §4.2.
    pub osl: u32,
    /// Shared prefix length already cached (P in Algorithm 1).
    pub prefix: u32,
    pub sla: Sla,
}

impl WorkloadSpec {
    pub fn new(model: &str, isl: u32, osl: u32, ttft_ms: f64, min_speed: f64) -> Self {
        WorkloadSpec {
            model: model.to_string(),
            isl,
            osl,
            prefix: 0,
            sla: Sla { ttft_ms, min_speed },
        }
    }

    /// Parse from the JSON wire/file format:
    /// `{"model": "...", "isl": N, "osl": N, "prefix": N,
    ///   "sla": {"ttft_ms": X, "min_speed": Y}}`.
    pub fn from_json(j: &Json) -> anyhow::Result<WorkloadSpec> {
        let sla = j.get("sla").cloned().unwrap_or(Json::obj());
        Ok(WorkloadSpec {
            model: j.req_str("model")?.to_string(),
            isl: j.req_f64("isl")? as u32,
            osl: j.req_f64("osl")? as u32,
            prefix: j.f64_or("prefix", 0.0) as u32,
            sla: Sla {
                ttft_ms: sla.f64_or("ttft_ms", f64::INFINITY),
                min_speed: sla.f64_or("min_speed", 0.0),
            },
        })
    }

    pub fn to_json(&self) -> Json {
        let mut sla = Json::obj();
        sla.set("ttft_ms", json::num(self.sla.ttft_ms))
            .set("min_speed", json::num(self.sla.min_speed));
        let mut o = Json::obj();
        o.set("model", json::s(&self.model))
            .set("isl", json::num(self.isl as f64))
            .set("osl", json::num(self.osl as f64))
            .set("prefix", json::num(self.prefix as f64))
            .set("sla", sla);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_gpus_and_label() {
        let p = ParallelSpec { tp: 4, pp: 2, ep: 8, dp: 1 };
        assert_eq!(p.gpus(), 8);
        assert_eq!(p.label(), "TP4PP2EP8");
        assert_eq!(ParallelSpec::tp(2).label(), "TP2");
    }

    #[test]
    fn candidate_gpu_accounting() {
        let e = EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(2),
            batch: 8,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: Placement::packed(),
        };
        let agg = Candidate::Aggregated { engine: e, replicas: 4 };
        assert_eq!(agg.total_gpus(), 8);
        let mut p = e;
        p.parallel = ParallelSpec::tp(1);
        let dis = Candidate::Disaggregated { prefill: p, decode: e, x: 4, y: 2 };
        assert_eq!(dis.total_gpus(), 4 + 4);
        assert_eq!(dis.mode(), ServingMode::Disaggregated);
    }

    #[test]
    fn sla_tpot() {
        let sla = Sla { ttft_ms: 1000.0, min_speed: 20.0 };
        assert_eq!(sla.max_tpot_ms(), 50.0);
        let open = Sla { ttft_ms: 1000.0, min_speed: 0.0 };
        assert!(open.max_tpot_ms().is_infinite());
    }

    #[test]
    fn workload_json_roundtrip() {
        let w = WorkloadSpec::new("qwen3-32b", 4000, 500, 1200.0, 60.0);
        let j = w.to_json();
        let back = WorkloadSpec::from_json(&j).unwrap();
        assert_eq!(back.model, "qwen3-32b");
        assert_eq!(back.isl, 4000);
        assert_eq!(back.sla.min_speed, 60.0);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(ServingMode::parse("disagg"), Some(ServingMode::Disaggregated));
        assert_eq!(ServingMode::parse("IFB"), Some(ServingMode::Aggregated));
        assert_eq!(ServingMode::parse("x"), None);
    }
}

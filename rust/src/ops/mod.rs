//! Iteration → operator decomposition (paper §4.3, Fig 4).
//!
//! "Any inference iteration step can be modeled as running a fixed
//! sequence of operators for a number of times ... Introducing modern
//! parallel strategies does not alter this fundamental property except
//! for inserting a few well-defined communication operators at fixed
//! positions and scaling down the compute operators by sharding inputs."
//!
//! [`decompose`] turns (model, cluster, engine config, step shape) into a
//! flat [`Op`] list; both the synthetic silicon (ground truth) and the
//! PerfDatabase-backed analytical model consume the same list — the
//! fidelity gap then comes only from measurement noise, interpolation
//! and scheduling dynamics, exactly as in the paper.

use crate::config::EngineConfig;
use crate::hardware::ClusterSpec;
use crate::models::{AttnKind, Dtype, ModelArch};

/// Activation bytes (activations stay fp16/bf16 in all modeled engines).
pub const ACT_BYTES: f64 = 2.0;

/// A primitive operator with everything its latency depends on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Dense GEMM: `[m,k] x [k,n]`, weights in `dtype`.
    Gemm { m: u64, n: u64, k: u64, dtype: Dtype, count: u32 },
    /// Fused prefill attention for ONE request (batch handled by `count`):
    /// `q_tokens` new tokens attending to `kv_len` cached+new tokens.
    AttnPrefill {
        q_tokens: u64,
        kv_len: u64,
        heads: u64,
        head_dim: u64,
        /// 1.0 for full attention, ~0.5 for causal q==kv.
        causal_frac: f64,
        count: u32,
    },
    /// Batched decode attention: `batch` single-token queries against
    /// `kv_len`-long caches. `kv_token_bytes` = bytes of K+V (or MLA
    /// latent) per token per layer on THIS gpu.
    AttnDecode {
        batch: u64,
        kv_len: u64,
        heads: u64,
        head_dim: u64,
        kv_token_bytes: f64,
        count: u32,
    },
    /// MoE grouped GEMM on one GPU: `tokens` routed tokens spread over
    /// `experts` resident experts; FFN shapes `inter`×`hidden`;
    /// `imbalance` = hottest-GPU load / mean load (power-law tail,
    /// paper §4.4.1).
    MoeGemm {
        tokens: u64,
        experts: u64,
        inter: u64,
        hidden: u64,
        dtype: Dtype,
        imbalance: f64,
        count: u32,
    },
    /// All-reduce of `bytes` across `gpus`. `span` = NVLink domains
    /// the group's ranks are placed across (1 = packed; the cost model
    /// clamps up to the minimum feasible span), `rails` = IB rails a
    /// cross-domain stage stripes over. Both come from the engine's
    /// [`crate::topology::Placement`] and are ignored by the legacy
    /// flat fabric model.
    AllReduce { bytes: f64, gpus: u32, span: u32, rails: u32, count: u32 },
    /// All-gather of `bytes` (per-GPU shard) across `gpus` (placement
    /// fields as in [`Op::AllReduce`]).
    AllGather { bytes: f64, gpus: u32, span: u32, rails: u32, count: u32 },
    /// All-to-all (MoE dispatch/combine) of `bytes` per GPU (placement
    /// fields as in [`Op::AllReduce`]).
    AllToAll { bytes: f64, gpus: u32, span: u32, rails: u32, count: u32 },
    /// Point-to-point transfer (PP stage boundary, KV-cache transfer).
    P2p { bytes: f64, cross_node: bool, count: u32 },
    /// Bandwidth-bound elementwise/norm/embedding traffic.
    Elementwise { bytes: f64, count: u32 },
}

impl Op {
    pub fn count(&self) -> u32 {
        match self {
            Op::Gemm { count, .. }
            | Op::AttnPrefill { count, .. }
            | Op::AttnDecode { count, .. }
            | Op::MoeGemm { count, .. }
            | Op::AllReduce { count, .. }
            | Op::AllGather { count, .. }
            | Op::AllToAll { count, .. }
            | Op::P2p { count, .. }
            | Op::Elementwise { count, .. } => *count,
        }
    }

    /// Short class name (profiling/reporting).
    pub fn class(&self) -> &'static str {
        match self {
            Op::Gemm { .. } => "gemm",
            Op::AttnPrefill { .. } => "attn_prefill",
            Op::AttnDecode { .. } => "attn_decode",
            Op::MoeGemm { .. } => "moe",
            Op::AllReduce { .. } => "allreduce",
            Op::AllGather { .. } => "allgather",
            Op::AllToAll { .. } => "alltoall",
            Op::P2p { .. } => "p2p",
            Op::Elementwise { .. } => "elementwise",
        }
    }
}

/// The token population of one engine iteration.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepShape {
    /// Prefill requests scheduled this iteration.
    pub ctx_reqs: u32,
    /// New prompt tokens per prefill request (chunk size if chunked).
    pub ctx_q: u64,
    /// Total KV length each prefill request attends to (prefix + chunk).
    pub ctx_kv: u64,
    /// Decode requests scheduled this iteration.
    pub gen_reqs: u64,
    /// Mean KV length of decode requests.
    pub gen_kv: u64,
}

impl StepShape {
    pub fn prefill(reqs: u32, q: u64, kv: u64) -> Self {
        StepShape { ctx_reqs: reqs, ctx_q: q, ctx_kv: kv, ..Default::default() }
    }

    pub fn decode(reqs: u64, kv: u64) -> Self {
        StepShape { gen_reqs: reqs, gen_kv: kv, ..Default::default() }
    }

    /// Total tokens entering the GEMM path this iteration.
    pub fn total_tokens(&self) -> u64 {
        self.ctx_reqs as u64 * self.ctx_q + self.gen_reqs
    }

    pub fn is_decode_only(&self) -> bool {
        self.ctx_reqs == 0 && self.gen_reqs > 0
    }
}

/// Decompose one iteration into operators for a single PP stage times
/// `pp` stages (the per-model fixed sequence of Fig 4).
///
/// `moe_imbalance` is the per-GPU load tail factor γ ≥ 1 obtained from
/// the power-law model ([`crate::perfmodel::moe_imbalance`]); 1.0 means
/// perfectly balanced routing.
pub fn decompose(
    model: &ModelArch,
    cluster: &ClusterSpec,
    eng: &EngineConfig,
    shape: &StepShape,
    moe_imbalance: f64,
) -> Vec<Op> {
    let mut ops = Vec::with_capacity(24);
    let tp = eng.parallel.tp as u64;
    let pp = eng.parallel.pp as u64;
    let ep = eng.parallel.ep.max(1) as u64;
    let wdt = eng.weight_dtype;
    // Rank layout: where the TP/EP groups land on the fabric. The
    // legacy cost model ignores the spans, so packed placements price
    // bit-for-bit as the seed did.
    let pl = eng.placement;

    let tokens = shape.total_tokens();
    if tokens == 0 {
        return ops;
    }
    let layers = model.num_layers; // counts cover all PP stages
    let layers_u32 = layers as u32;
    let heads_tp = (model.heads / tp).max(1);
    let kv_heads_tp = (model.kv_heads / tp).max(1);

    // --- Attention projections -----------------------------------------
    match model.attn {
        AttnKind::Mha | AttnKind::Gqa => {
            // Fused QKV projection.
            let n_qkv = (heads_tp + 2 * kv_heads_tp) * model.head_dim;
            ops.push(Op::Gemm { m: tokens, n: n_qkv, k: model.hidden, dtype: wdt, count: layers_u32 });
            // Output projection.
            ops.push(Op::Gemm {
                m: tokens,
                n: model.hidden,
                k: heads_tp * model.head_dim,
                dtype: wdt,
                count: layers_u32,
            });
        }
        AttnKind::Mla { q_lora_rank, kv_lora_rank, qk_rope_dim, qk_nope_dim, v_head_dim } => {
            let q_dim = qk_nope_dim + qk_rope_dim;
            // Down-projections (replicated), up-projections (TP-sharded).
            ops.push(Op::Gemm { m: tokens, n: q_lora_rank + kv_lora_rank + qk_rope_dim, k: model.hidden, dtype: wdt, count: layers_u32 });
            ops.push(Op::Gemm { m: tokens, n: heads_tp * q_dim, k: q_lora_rank, dtype: wdt, count: layers_u32 });
            ops.push(Op::Gemm { m: tokens, n: heads_tp * (qk_nope_dim + v_head_dim), k: kv_lora_rank, dtype: wdt, count: layers_u32 });
            ops.push(Op::Gemm { m: tokens, n: model.hidden, k: heads_tp * v_head_dim, dtype: wdt, count: layers_u32 });
        }
    }

    // --- Attention cores ------------------------------------------------
    if shape.ctx_reqs > 0 {
        ops.push(Op::AttnPrefill {
            q_tokens: shape.ctx_q,
            kv_len: shape.ctx_kv.max(shape.ctx_q),
            heads: heads_tp,
            head_dim: model.head_dim,
            causal_frac: if shape.ctx_kv <= shape.ctx_q { 0.5 } else { 1.0 },
            count: layers_u32 * shape.ctx_reqs,
        });
    }
    if shape.gen_reqs > 0 {
        let kv_token_bytes = kv_bytes_per_gpu_layer(model, eng.kv_dtype, tp);
        ops.push(Op::AttnDecode {
            batch: shape.gen_reqs,
            kv_len: shape.gen_kv.max(1),
            heads: heads_tp,
            head_dim: model.head_dim,
            kv_token_bytes,
            count: layers_u32,
        });
    }

    // --- Attention-block collective (TP) --------------------------------
    if tp > 1 {
        ops.push(Op::AllReduce {
            bytes: tokens as f64 * model.hidden as f64 * ACT_BYTES,
            gpus: tp as u32,
            span: pl.tp_span,
            rails: pl.rails,
            count: layers_u32,
        });
    }

    // --- FFN / MoE --------------------------------------------------------
    match &model.moe {
        None => {
            // Gated FFN: fused gate+up, then down.
            let inter_tp = model.inter / tp;
            ops.push(Op::Gemm { m: tokens, n: 2 * inter_tp, k: model.hidden, dtype: wdt, count: layers_u32 });
            ops.push(Op::Gemm { m: tokens, n: model.hidden, k: inter_tp, dtype: wdt, count: layers_u32 });
        }
        Some(moe) => {
            let dense = moe.first_dense_layers as u32;
            let moe_layers = (layers - moe.first_dense_layers) as u32;
            if dense > 0 {
                let inter_tp = model.inter / tp;
                ops.push(Op::Gemm { m: tokens, n: 2 * inter_tp, k: model.hidden, dtype: wdt, count: dense });
                ops.push(Op::Gemm { m: tokens, n: model.hidden, k: inter_tp, dtype: wdt, count: dense });
            }
            // Dispatch: each token's hidden vector to top_k experts.
            if ep > 1 {
                let bytes =
                    crate::perfmodel::moe::dispatch_bytes_per_gpu(tokens, moe.top_k, model.hidden, ep);
                ops.push(Op::AllToAll {
                    bytes,
                    gpus: ep as u32,
                    span: pl.ep_span,
                    rails: pl.rails,
                    count: moe_layers,
                });
            }
            // Grouped GEMM over resident experts. EP shards experts across
            // the TP×DP group; without EP, TP shards each expert's FFN.
            let (experts_gpu, inter_gpu) = if ep > 1 {
                ((moe.num_experts / ep).max(1), moe.expert_inter)
            } else {
                (moe.num_experts, (moe.expert_inter / tp).max(1))
            };
            let routed = tokens * moe.top_k / ep;
            ops.push(Op::MoeGemm {
                tokens: routed.max(1),
                experts: experts_gpu,
                inter: inter_gpu,
                hidden: model.hidden,
                dtype: wdt,
                imbalance: moe_imbalance,
                count: moe_layers,
            });
            if moe.shared_inter > 0 {
                let sh = (moe.shared_inter / tp).max(1);
                ops.push(Op::Gemm { m: tokens, n: 2 * sh, k: model.hidden, dtype: wdt, count: moe_layers });
                ops.push(Op::Gemm { m: tokens, n: model.hidden, k: sh, dtype: wdt, count: moe_layers });
            }
            // Combine.
            if ep > 1 {
                let bytes =
                    crate::perfmodel::moe::dispatch_bytes_per_gpu(tokens, moe.top_k, model.hidden, ep);
                ops.push(Op::AllToAll {
                    bytes,
                    gpus: ep as u32,
                    span: pl.ep_span,
                    rails: pl.rails,
                    count: moe_layers,
                });
            }
        }
    }

    // --- FFN-block collective (TP) ---------------------------------------
    if tp > 1 {
        ops.push(Op::AllReduce {
            bytes: tokens as f64 * model.hidden as f64 * ACT_BYTES,
            gpus: tp as u32,
            span: pl.tp_span,
            rails: pl.rails,
            count: layers_u32,
        });
    }

    // --- Norms / residuals / embedding traffic ---------------------------
    // ~4 full activation sweeps per layer (2 norms + 2 residual adds).
    ops.push(Op::Elementwise {
        bytes: 4.0 * tokens as f64 * model.hidden as f64 * ACT_BYTES,
        count: layers_u32,
    });
    ops.push(Op::Elementwise {
        bytes: tokens as f64 * model.hidden as f64 * ACT_BYTES,
        count: 1, // embedding gather
    });

    // --- LM head: one sampled token per sequence -------------------------
    let sampled = shape.gen_reqs + shape.ctx_reqs as u64;
    ops.push(Op::Gemm {
        m: sampled.max(1),
        n: model.vocab / tp,
        k: model.hidden,
        dtype: wdt,
        count: 1,
    });
    if tp > 1 {
        // Gather sharded logits (top-k sampling path).
        ops.push(Op::AllGather {
            bytes: sampled as f64 * (model.vocab / tp) as f64 * ACT_BYTES,
            gpus: tp as u32,
            span: pl.tp_span,
            rails: pl.rails,
            count: 1,
        });
    }

    // --- Pipeline-parallel stage boundaries -------------------------------
    if pp > 1 {
        let bytes = tokens as f64 * model.hidden as f64 * ACT_BYTES;
        // Interleaved placements co-locate consecutive stages per
        // domain, turning the boundary into an intra-domain hop;
        // otherwise stages stack domain-by-domain and the boundary
        // crosses once the instance outgrows one NVLink domain (the
        // seed rule — `domain == node` on the legacy fabric).
        let cross = !pl.interleave_pp && eng.parallel.gpus() > cluster.domain_size();
        ops.push(Op::P2p { bytes, cross_node: cross, count: (pp - 1) as u32 });
    }

    ops
}

/// Per-kernel launch overhead contained in an op list, microseconds.
///
/// CUDA graphs capture decode-only iterations and replay them without
/// per-kernel launches; engines cannot graph mixed prefill+decode steps
/// (dynamic shapes). The iteration models subtract
/// [`CUDA_GRAPH_LAUNCH_SAVING`] × this from graphed decode steps —
/// an asymmetry that favours pure-decode pools (disaggregation) and the
/// generation-only phase of continuous batching.
pub fn launch_overhead_us(ops: &[Op], launch_us: f64) -> f64 {
    ops.iter().map(|o| o.count() as f64).sum::<f64>() * launch_us
}

/// Fraction of kernel-launch overhead removed by CUDA-graph replay.
pub const CUDA_GRAPH_LAUNCH_SAVING: f64 = 0.85;

/// KV (or MLA latent) bytes per token per layer held on one TP rank.
pub fn kv_bytes_per_gpu_layer(model: &ModelArch, kv_dtype: Dtype, tp: u64) -> f64 {
    match model.attn {
        AttnKind::Mha | AttnKind::Gqa => {
            let kv_heads_tp = (model.kv_heads / tp).max(1);
            (2 * kv_heads_tp * model.head_dim) as f64 * kv_dtype.bytes()
        }
        // MLA latent is replicated across TP ranks.
        AttnKind::Mla { kv_lora_rank, qk_rope_dim, .. } => {
            (kv_lora_rank + qk_rope_dim) as f64 * kv_dtype.bytes()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::{h100_sxm, ClusterSpec};
    use crate::models::by_name;

    fn eng(tp: u32, ep: u32) -> EngineConfig {
        EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec { tp, pp: 1, ep, dp: 1 },
            batch: 8,
            weight_dtype: Dtype::Fp16,
            kv_dtype: Dtype::Fp16,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        }
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::new(h100_sxm(), 8, 1)
    }

    #[test]
    fn dense_prefill_has_no_moe_or_comm_at_tp1() {
        let m = by_name("qwen3-32b").unwrap();
        let ops = decompose(&m, &cluster(), &eng(1, 1), &StepShape::prefill(1, 4096, 4096), 1.0);
        assert!(ops.iter().all(|o| !matches!(o, Op::MoeGemm { .. })));
        assert!(ops.iter().all(|o| !matches!(o, Op::AllReduce { .. })));
        assert!(ops.iter().any(|o| matches!(o, Op::AttnPrefill { .. })));
        assert!(ops.iter().all(|o| !matches!(o, Op::AttnDecode { .. })));
    }

    #[test]
    fn tp_inserts_two_allreduce_per_layer() {
        let m = by_name("qwen3-32b").unwrap();
        let ops = decompose(&m, &cluster(), &eng(4, 1), &StepShape::decode(16, 2048), 1.0);
        let ar: u32 = ops
            .iter()
            .filter_map(|o| match o {
                Op::AllReduce { count, .. } => Some(*count),
                _ => None,
            })
            .sum();
        assert_eq!(ar as u64, 2 * m.num_layers);
    }

    #[test]
    fn ep_inserts_dispatch_and_combine() {
        let m = by_name("qwen3-235b").unwrap();
        let ops = decompose(&m, &cluster(), &eng(1, 8), &StepShape::decode(32, 4096), 1.3);
        let a2a = ops.iter().filter(|o| matches!(o, Op::AllToAll { .. })).count();
        assert_eq!(a2a, 2, "dispatch + combine");
        let moe = ops.iter().find(|o| matches!(o, Op::MoeGemm { .. })).unwrap();
        if let Op::MoeGemm { experts, tokens, imbalance, .. } = moe {
            assert_eq!(*experts, 128 / 8);
            assert_eq!(*tokens, 32 * 8 / 8);
            assert_eq!(*imbalance, 1.3);
        }
    }

    #[test]
    fn tp_shards_gemm_n_dims() {
        let m = by_name("qwen3-32b").unwrap();
        let shape = StepShape::prefill(1, 1024, 1024);
        let t1 = decompose(&m, &cluster(), &eng(1, 1), &shape, 1.0);
        let t4 = decompose(&m, &cluster(), &eng(4, 1), &shape, 1.0);
        let flops = |ops: &[Op]| -> f64 {
            ops.iter()
                .filter_map(|o| match o {
                    Op::Gemm { m, n, k, count, .. } => {
                        Some(2.0 * *m as f64 * *n as f64 * *k as f64 * *count as f64)
                    }
                    _ => None,
                })
                .sum()
        };
        let r = flops(&t1) / flops(&t4);
        assert!(r > 3.0 && r < 4.5, "TP4 should ~quarter GEMM flops, got ratio {r}");
    }

    #[test]
    fn mla_decode_kv_is_latent_and_replicated() {
        let m = by_name("deepseek-v3").unwrap();
        assert_eq!(kv_bytes_per_gpu_layer(&m, Dtype::Fp16, 1), 1152.0);
        assert_eq!(kv_bytes_per_gpu_layer(&m, Dtype::Fp16, 8), 1152.0);
        let g = by_name("qwen3-32b").unwrap();
        assert_eq!(kv_bytes_per_gpu_layer(&g, Dtype::Fp16, 8), 4096.0 / 8.0);
    }

    #[test]
    fn placement_spans_ride_on_the_comm_ops() {
        use crate::topology::Placement;
        let m = by_name("qwen3-235b").unwrap();
        let mut e = eng(4, 8);
        e.parallel.pp = 2;
        e.placement =
            Placement { tp_span: 2, ep_span: 2, interleave_pp: true, rails: 4 };
        let ops = decompose(&m, &cluster(), &e, &StepShape::decode(16, 2048), 1.2);
        for o in &ops {
            match o {
                Op::AllReduce { span, rails, .. } | Op::AllGather { span, rails, .. } => {
                    assert_eq!((*span, *rails), (2, 4));
                }
                Op::AllToAll { span, rails, .. } => assert_eq!((*span, *rails), (2, 4)),
                // Interleaved stages keep the PP boundary intra-domain.
                Op::P2p { cross_node, .. } => assert!(!cross_node),
                _ => {}
            }
        }
        // Packed default derives the seed's PP crossing rule.
        let mut packed = eng(4, 1);
        packed.parallel.pp = 4; // 16 GPUs > 8-GPU domain
        let ops = decompose(&m, &cluster(), &packed, &StepShape::decode(16, 2048), 1.0);
        assert!(ops
            .iter()
            .any(|o| matches!(o, Op::P2p { cross_node: true, .. })));
    }

    #[test]
    fn empty_shape_no_ops() {
        let m = by_name("llama3.1-8b").unwrap();
        assert!(decompose(&m, &cluster(), &eng(1, 1), &StepShape::default(), 1.0).is_empty());
    }

    #[test]
    fn mixed_step_has_both_attention_kinds() {
        let m = by_name("llama3.1-8b").unwrap();
        let shape = StepShape { ctx_reqs: 2, ctx_q: 512, ctx_kv: 512, gen_reqs: 16, gen_kv: 1024 };
        let ops = decompose(&m, &cluster(), &eng(2, 1), &shape, 1.0);
        assert!(ops.iter().any(|o| matches!(o, Op::AttnPrefill { .. })));
        assert!(ops.iter().any(|o| matches!(o, Op::AttnDecode { .. })));
        assert_eq!(shape.total_tokens(), 2 * 512 + 16);
    }
}

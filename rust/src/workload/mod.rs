//! Workload descriptors and request traces: the load-generation side of
//! the ground-truth simulator (the role AI-Perf plays in the paper's
//! case study — concurrency-matched closed loop with oversampling).

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, ms from trace start.
    pub arrival_ms: f64,
    pub isl: u32,
    pub osl: u32,
}

/// Closed-loop trace: `n` identical requests all present at t=0
/// (concurrency-matched benchmarking; the engine's batch cap enforces
/// the actual concurrency).
pub fn closed_loop(n: usize, isl: u32, osl: u32) -> Vec<Request> {
    (0..n)
        .map(|i| Request { id: i as u64, arrival_ms: 0.0, isl, osl })
        .collect()
}

/// Poisson open-loop trace at `rate_rps`, with ±`len_jitter` uniform
/// jitter on ISL/OSL (production prompts are not all identical).
pub fn poisson(
    rate_rps: f64,
    duration_s: f64,
    isl: u32,
    osl: u32,
    len_jitter: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(rate_rps > 0.0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t_ms = 0.0;
    let mut id = 0u64;
    while t_ms < duration_s * 1000.0 {
        t_ms += rng.exponential(rate_rps) * 1000.0;
        if t_ms >= duration_s * 1000.0 {
            break;
        }
        let j = |v: u32, rng: &mut Rng| -> u32 {
            let f = 1.0 + len_jitter * (2.0 * rng.f64() - 1.0);
            ((v as f64 * f).round() as u32).max(1)
        };
        out.push(Request { id, arrival_ms: t_ms, isl: j(isl, &mut rng), osl: j(osl, &mut rng) });
        id += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_all_at_zero() {
        let t = closed_loop(10, 1024, 128);
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|r| r.arrival_ms == 0.0 && r.isl == 1024));
        assert_eq!(t[9].id, 9);
    }

    #[test]
    fn poisson_rate_approx() {
        let t = poisson(50.0, 20.0, 1000, 100, 0.0, 3);
        let rate = t.len() as f64 / 20.0;
        assert!((rate - 50.0).abs() < 5.0, "rate={rate}");
        // Arrivals strictly increasing.
        assert!(t.windows(2).all(|w| w[0].arrival_ms < w[1].arrival_ms));
    }

    #[test]
    fn jitter_spreads_lengths() {
        let t = poisson(100.0, 5.0, 1000, 100, 0.3, 7);
        assert!(t.iter().any(|r| r.isl != 1000));
        assert!(t.iter().all(|r| r.isl >= 700 - 1 && r.isl <= 1300 + 1));
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(poisson(10.0, 2.0, 100, 10, 0.2, 9), poisson(10.0, 2.0, 100, 10, 0.2, 9));
    }
}

//! Workload descriptors and request traces: the load-generation side of
//! the ground-truth simulator (the role AI-Perf plays in the paper's
//! case study — concurrency-matched closed loop with oversampling).

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, ms from trace start.
    pub arrival_ms: f64,
    pub isl: u32,
    pub osl: u32,
}

/// Closed-loop trace: `n` identical requests all present at t=0
/// (concurrency-matched benchmarking; the engine's batch cap enforces
/// the actual concurrency).
pub fn closed_loop(n: usize, isl: u32, osl: u32) -> Vec<Request> {
    (0..n)
        .map(|i| Request { id: i as u64, arrival_ms: 0.0, isl, osl })
        .collect()
}

/// Poisson open-loop trace at `rate_rps`, with ±`len_jitter` uniform
/// jitter on ISL/OSL (production prompts are not all identical).
pub fn poisson(
    rate_rps: f64,
    duration_s: f64,
    isl: u32,
    osl: u32,
    len_jitter: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(rate_rps > 0.0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t_ms = 0.0;
    let mut id = 0u64;
    while t_ms < duration_s * 1000.0 {
        t_ms += rng.exponential(rate_rps) * 1000.0;
        if t_ms >= duration_s * 1000.0 {
            break;
        }
        let j = |v: u32, rng: &mut Rng| -> u32 {
            let f = 1.0 + len_jitter * (2.0 * rng.f64() - 1.0);
            ((v as f64 * f).round() as u32).max(1)
        };
        out.push(Request { id, arrival_ms: t_ms, isl: j(isl, &mut rng), osl: j(osl, &mut rng) });
        id += 1;
    }
    out
}

/// Open-loop trace whose Poisson rate follows a per-window QPS curve
/// (the capacity planner's traffic models): window `w` spans
/// `[w·window_s, (w+1)·window_s)` seconds and arrives at `qps[w]`
/// requests/s, with the same ±`len_jitter` ISL/OSL jitter as
/// [`poisson`]. Windows with non-positive rate are silent. Deterministic
/// per seed.
///
/// This is the one piecewise trace generator in the crate — planner
/// tooling and the fleet replay both reach it through
/// [`crate::planner::TrafficModel::trace`], so the traffic a plan is
/// validated against is always drawn from the plan's own model, ids
/// dense in arrival order.
pub fn piecewise_poisson(
    qps: &[f64],
    window_s: f64,
    isl: u32,
    osl: u32,
    len_jitter: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(window_s > 0.0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut id = 0u64;
    let j = |v: u32, rng: &mut Rng| -> u32 {
        let f = 1.0 + len_jitter * (2.0 * rng.f64() - 1.0);
        ((v as f64 * f).round() as u32).max(1)
    };
    for (w, &rate) in qps.iter().enumerate() {
        if rate <= 0.0 {
            continue;
        }
        let end_ms = (w + 1) as f64 * window_s * 1000.0;
        let mut t_ms = w as f64 * window_s * 1000.0;
        loop {
            t_ms += rng.exponential(rate) * 1000.0;
            if t_ms >= end_ms {
                break;
            }
            out.push(Request {
                id,
                arrival_ms: t_ms,
                isl: j(isl, &mut rng),
                osl: j(osl, &mut rng),
            });
            id += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_all_at_zero() {
        let t = closed_loop(10, 1024, 128);
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|r| r.arrival_ms == 0.0 && r.isl == 1024));
        assert_eq!(t[9].id, 9);
    }

    #[test]
    fn poisson_rate_approx() {
        let t = poisson(50.0, 20.0, 1000, 100, 0.0, 3);
        let rate = t.len() as f64 / 20.0;
        assert!((rate - 50.0).abs() < 5.0, "rate={rate}");
        // Arrivals strictly increasing.
        assert!(t.windows(2).all(|w| w[0].arrival_ms < w[1].arrival_ms));
    }

    #[test]
    fn jitter_spreads_lengths() {
        let t = poisson(100.0, 5.0, 1000, 100, 0.3, 7);
        assert!(t.iter().any(|r| r.isl != 1000));
        assert!(t.iter().all(|r| r.isl >= 700 - 1 && r.isl <= 1300 + 1));
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(poisson(10.0, 2.0, 100, 10, 0.2, 9), poisson(10.0, 2.0, 100, 10, 0.2, 9));
    }

    #[test]
    fn piecewise_rates_follow_the_curve() {
        // 3 windows of 20 s at 50 / 0 / 10 QPS.
        let t = piecewise_poisson(&[50.0, 0.0, 10.0], 20.0, 1000, 100, 0.0, 5);
        let in_window = |w: usize| {
            t.iter()
                .filter(|r| {
                    r.arrival_ms >= w as f64 * 20_000.0 && r.arrival_ms < (w + 1) as f64 * 20_000.0
                })
                .count() as f64
        };
        assert!((in_window(0) / 20.0 - 50.0).abs() < 8.0, "w0 rate {}", in_window(0) / 20.0);
        assert_eq!(in_window(1), 0.0, "silent window must be empty");
        assert!((in_window(2) / 20.0 - 10.0).abs() < 4.0, "w2 rate {}", in_window(2) / 20.0);
        // Arrivals strictly increasing, ids dense.
        assert!(t.windows(2).all(|w| w[0].arrival_ms < w[1].arrival_ms));
        assert_eq!(t.last().unwrap().id as usize, t.len() - 1);
    }

    #[test]
    fn piecewise_deterministic_by_seed() {
        let q = [30.0, 5.0, 80.0];
        assert_eq!(
            piecewise_poisson(&q, 10.0, 512, 64, 0.2, 11),
            piecewise_poisson(&q, 10.0, 512, 64, 0.2, 11)
        );
        assert_ne!(
            piecewise_poisson(&q, 10.0, 512, 64, 0.2, 11),
            piecewise_poisson(&q, 10.0, 512, 64, 0.2, 12)
        );
    }
}

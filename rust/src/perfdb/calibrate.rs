//! Calibration pipeline: turn measurement sets ([`super::measure`])
//! into a correction on top of the analytic fill, so database answers
//! carry measurement signal instead of validating the model against
//! itself (paper pillar 2: "a calibrated kernel-level performance
//! database"; Vidur's profiled-then-interpolated tables are the prior
//! art for why this transfers across hardware).
//!
//! Per [`TableId`], measurements are binned into the compiled
//! `16×32×32×16` grid geometry and a **least-squares correction** is
//! fitted in log space: `measured ≈ analytic · exp(c₀ + c₁·x̂ + c₂·ŷ +
//! c₃·ẑ)` with normalized grid coordinates `x̂ = fx/(NX−1)` etc. —
//! a multiplicative scale plus a mild per-axis tilt. The fit is
//! weighted by repeat counts, rejects outliers by median-absolute-
//! deviation in log space, and clamps any axis tilt that would break
//! the analytic table's monotonicity (a correction must not make
//! latency *decrease* with problem size where the model says it grows).
//!
//! The result is a versioned [`CalibrationArtifact`] {scale factors,
//! residual stats, measured-cell overlay, provenance} that
//! [`CalibratedDb`] composes over a [`PerfDatabase`] with a three-tier
//! lookup chain, every query tagged with its provenance tier:
//!
//! 1. **measured** — the query lands (within [`MEASURED_SNAP`] grid
//!    units) on a cell that was directly measured: answer the binned
//!    measurement itself;
//! 2. **calibrated** — trilinear interpolation over the correction-
//!    scaled analytic grid;
//! 3. **analytic** — tables with no measurements interpolate the plain
//!    analytic fill;
//! 4. **sol** — op classes outside the tables fall back to the
//!    Speed-of-Light roofline ([`super::sol`]), as before.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::ops::Op;
use crate::util::json::{self, Json};
use crate::util::stats;

use super::measure::MeasurementSet;
use super::query::{flat, nearest_cell, trilinear};
use super::tables::{query_for, spec, TableId, GRID_LEN, NUM_TABLES, NX, NY, NZ};
use super::{sol, LatencyOracle, PerfDatabase};

/// Artifact format version; bump on any incompatible change.
pub const ARTIFACT_VERSION: u32 = 1;

/// Maximum per-axis distance (grid units) at which a query is served
/// by the measured-cell tier instead of interpolation.
pub const MEASURED_SNAP: f64 = 0.25;

/// Outlier rejection: drop points whose log-residual exceeds
/// `OUTLIER_MAD_K · 1.4826 · MAD` (floored at [`OUTLIER_FLOOR`] log
/// units ≈ 10%, so clean low-noise sets don't reject their own tails).
pub const OUTLIER_MAD_K: f64 = 3.0;
pub const OUTLIER_FLOOR: f64 = 0.10;

/// A per-axis tilt is clamped to zero when it lowers the fraction of
/// monotone adjacent cell pairs by more than this, relative to the
/// analytic grid.
pub const MONO_TOL: f64 = 0.02;

/// Below this many points a table gets a constant-only fit (no tilts).
pub const MIN_POINTS_FULL_FIT: usize = 8;

/// The fitted correction for one table.
#[derive(Clone, Debug, PartialEq)]
pub struct TableFit {
    pub table: TableId,
    /// Log-space coefficients `[c0, cx, cy, cz]` over normalized grid
    /// coordinates; the multiplicative factor at a cell is
    /// `exp(c0 + cx·x̂ + cy·ŷ + cz·ẑ)`.
    pub coeffs: [f64; 4],
    /// Points used by the final fit (after outlier rejection).
    pub n_points: usize,
    pub n_outliers: usize,
    /// Axis tilts zeroed by the monotonicity check (x, y, z).
    pub clamped_axes: [bool; 3],
    /// Mean |analytic − measured| / measured before the fit (inliers).
    pub pre_mape: f64,
    /// Same, after applying the fitted correction.
    pub post_mape: f64,
    /// Stddev of log residuals after the fit.
    pub resid_log_std: f64,
}

impl TableFit {
    /// Multiplicative correction factor at integer cell coordinates.
    pub fn factor_at(&self, ix: usize, iy: usize, iz: usize) -> f64 {
        let [c0, cx, cy, cz] = self.coeffs;
        (c0 + cx * ix as f64 / (NX - 1) as f64
            + cy * iy as f64 / (NY - 1) as f64
            + cz * iz as f64 / (NZ - 1) as f64)
            .exp()
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("table", json::s(self.table.name()))
            .set("coeffs", json::farr(&self.coeffs))
            .set("n_points", json::num(self.n_points as f64))
            .set("n_outliers", json::num(self.n_outliers as f64))
            .set(
                "clamped_axes",
                Json::Arr(self.clamped_axes.iter().map(|&b| Json::Bool(b)).collect()),
            )
            .set("pre_mape", json::num(self.pre_mape))
            .set("post_mape", json::num(self.post_mape))
            .set("resid_log_std", json::num(self.resid_log_std));
        o
    }

    fn from_json(j: &Json) -> anyhow::Result<TableFit> {
        let tname = j.req_str("table")?;
        let table = TableId::parse(tname)
            .ok_or_else(|| anyhow::anyhow!("unknown table '{tname}' in calibration fit"))?;
        let cs = j
            .req("coeffs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'coeffs' must be an array"))?;
        anyhow::ensure!(cs.len() == 4, "'coeffs' must have 4 entries, got {}", cs.len());
        let mut coeffs = [0.0; 4];
        for (i, c) in cs.iter().enumerate() {
            coeffs[i] = c
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'coeffs[{i}]' is not a number"))?;
            anyhow::ensure!(coeffs[i].is_finite(), "'coeffs[{i}]' is not finite");
        }
        let ca = j
            .req("clamped_axes")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'clamped_axes' must be an array"))?;
        anyhow::ensure!(ca.len() == 3, "'clamped_axes' must have 3 entries");
        let mut clamped_axes = [false; 3];
        for (i, c) in ca.iter().enumerate() {
            clamped_axes[i] = c
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("'clamped_axes[{i}]' is not a bool"))?;
        }
        Ok(TableFit {
            table,
            coeffs,
            n_points: j.req_f64("n_points")? as usize,
            n_outliers: j.f64_or("n_outliers", 0.0) as usize,
            clamped_axes,
            pre_mape: j.req_f64("pre_mape")?,
            post_mape: j.req_f64("post_mape")?,
            resid_log_std: j.f64_or("resid_log_std", 0.0),
        })
    }
}

/// The versioned, self-contained output of a calibration run: enough
/// to calibrate any freshly profiled database for the *same context*
/// without re-reading the measurement files.
#[derive(Clone, Debug)]
pub struct CalibrationArtifact {
    pub gpu: String,
    /// Cluster topology the fit was taken on. Collective-table
    /// corrections depend on it (NVLink vs IB latencies), so it is part
    /// of the compatibility context, not metadata.
    pub gpus_per_node: u32,
    pub num_nodes: u32,
    pub model: String,
    pub framework: String,
    pub kv_dtype: String,
    /// Free-form: measurement source, point counts, generator seeds.
    pub provenance: String,
    pub fits: Vec<TableFit>,
    /// Directly measured cells: (flat grid index, median measured µs).
    pub measured_cells: Vec<(usize, f64)>,
}

impl CalibrationArtifact {
    /// True when every fitted table's post-fit MAPE beat its pre-fit
    /// MAPE — the CI calibration-smoke gate.
    pub fn all_tables_improve(&self) -> bool {
        !self.fits.is_empty() && self.fits.iter().all(|f| f.post_mape < f.pre_mape)
    }

    /// Per-table pre/post fidelity summary (the `calibrate` CLI's
    /// report file; also uploaded by the CI smoke job).
    pub fn fidelity_json(&self) -> Json {
        let pre: Vec<f64> = self.fits.iter().map(|f| f.pre_mape).collect();
        let post: Vec<f64> = self.fits.iter().map(|f| f.post_mape).collect();
        let mut o = Json::obj();
        o.set("gpu", json::s(&self.gpu))
            .set("model", json::s(&self.model))
            .set("framework", json::s(&self.framework))
            .set("kv_dtype", json::s(&self.kv_dtype))
            .set("mean_pre_mape", json::num(stats::mean(&pre)))
            .set("mean_post_mape", json::num(stats::mean(&post)))
            .set("improves", Json::Bool(self.all_tables_improve()))
            .set("tables", Json::Arr(self.fits.iter().map(|f| f.to_json()).collect()));
        o
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", json::num(ARTIFACT_VERSION as f64))
            .set(
                "shape",
                json::farr(&[NUM_TABLES as f64, NX as f64, NY as f64, NZ as f64]),
            )
            .set("gpu", json::s(&self.gpu))
            .set("gpus_per_node", json::num(self.gpus_per_node as f64))
            .set("num_nodes", json::num(self.num_nodes as f64))
            .set("model", json::s(&self.model))
            .set("framework", json::s(&self.framework))
            .set("kv_dtype", json::s(&self.kv_dtype))
            .set("provenance", json::s(&self.provenance))
            .set("fits", Json::Arr(self.fits.iter().map(|f| f.to_json()).collect()))
            .set(
                "measured_cells",
                Json::Arr(
                    self.measured_cells
                        .iter()
                        .map(|&(i, us)| json::farr(&[i as f64, us]))
                        .collect(),
                ),
            );
        o
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CalibrationArtifact> {
        let version = j.req_f64("version")? as u32;
        anyhow::ensure!(
            version == ARTIFACT_VERSION,
            "calibration artifact version {version} != supported {ARTIFACT_VERSION}"
        );
        let shape = j.req("shape")?.as_arr().ok_or_else(|| anyhow::anyhow!("bad shape"))?;
        let dims: Vec<u64> = shape.iter().filter_map(|x| x.as_u64()).collect();
        anyhow::ensure!(
            dims == [NUM_TABLES as u64, NX as u64, NY as u64, NZ as u64],
            "calibration artifact grid shape {dims:?} does not match the compiled contract"
        );
        let fits = j
            .req("fits")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'fits' must be an array"))?
            .iter()
            .map(TableFit::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut seen = Vec::new();
        for f in &fits {
            anyhow::ensure!(!seen.contains(&f.table), "duplicate fit for table {}", f.table.name());
            seen.push(f.table);
        }
        let mut measured_cells = Vec::new();
        for (i, c) in j
            .req("measured_cells")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'measured_cells' must be an array"))?
            .iter()
            .enumerate()
        {
            let pair = c
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'measured_cells[{i}]' must be [index, us]"))?;
            anyhow::ensure!(pair.len() == 2, "'measured_cells[{i}]' must be [index, us]");
            let idx = pair[0]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad measured cell index at {i}"))?;
            let us = pair[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad measured cell value at {i}"))?;
            anyhow::ensure!(
                idx.fract() == 0.0 && idx >= 0.0 && (idx as usize) < GRID_LEN,
                "measured cell index {idx} out of range"
            );
            anyhow::ensure!(us.is_finite() && us > 0.0, "measured cell value {us} invalid");
            measured_cells.push((idx as usize, us));
        }
        Ok(CalibrationArtifact {
            gpu: j.req_str("gpu")?.to_string(),
            gpus_per_node: j.req_f64("gpus_per_node")? as u32,
            num_nodes: j.req_f64("num_nodes")? as u32,
            model: j.req_str("model")?.to_string(),
            framework: j.req_str("framework")?.to_string(),
            kv_dtype: j.req_str("kv_dtype")?.to_string(),
            provenance: j.str_or("provenance", "").to_string(),
            fits,
            measured_cells,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<CalibrationArtifact> {
        let txt = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&json::parse(&txt).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }
}

// ---------------------------------------------------------------------------
// Fitting
// ---------------------------------------------------------------------------

/// One binned measurement, ready for regression.
struct FitPoint {
    /// Design row [1, x̂, ŷ, ẑ].
    phi: [f64; 4],
    /// ln(measured / analytic).
    y: f64,
    /// Weight (repeat count).
    w: f64,
    us: f64,
    analytic: f64,
    cell: usize,
    /// Max per-axis distance to the nearest cell, grid units.
    dist: f64,
}

/// Fit a calibration artifact from measurement sets against a freshly
/// profiled analytic database. Compatibility is strict: every set must
/// record the database's own (gpu, model, framework, kv_dtype) context
/// — measurements bind to the context they were taken in (DESIGN.md).
pub fn fit(db: &PerfDatabase, sets: &[MeasurementSet]) -> anyhow::Result<CalibrationArtifact> {
    anyhow::ensure!(!sets.is_empty(), "no measurement sets to fit");
    for set in sets {
        anyhow::ensure!(
            set.gpu == db.ctx.gpu
                && set.model == db.ctx.model
                && set.framework == db.ctx.framework
                && set.kv_dtype == db.ctx.kv_dtype,
            "measurement set for table '{}' was taken in context \
             (gpu={}, model={}, framework={}, kv_dtype={}) but the database context is \
             (gpu={}, model={}, framework={}, kv_dtype={})",
            set.table.name(),
            set.gpu,
            set.model,
            set.framework,
            set.kv_dtype,
            db.ctx.gpu,
            db.ctx.model,
            db.ctx.framework,
            db.ctx.kv_dtype,
        );
    }

    // Merge sets per table (multiple files for one table are allowed
    // when measurements come from several campaigns).
    let mut by_table: Vec<(TableId, Vec<FitPoint>)> = Vec::new();
    let mut total_points = 0usize;
    for set in sets {
        let s = spec(set.table);
        let t = set.table as usize;
        let slot = match by_table.iter().position(|(id, _)| *id == set.table) {
            Some(i) => i,
            None => {
                by_table.push((set.table, Vec::new()));
                by_table.len() - 1
            }
        };
        let pts = &mut by_table[slot].1;
        for e in &set.entries {
            let (fx, fy, fz) = (s.x.frac(e.x), s.y.frac(e.y), s.z.frac(e.z));
            let analytic = trilinear(db.grids(), t, fx, fy, fz);
            if analytic <= 0.0 || e.us <= 0.0 {
                continue; // zero-latency cells (e.g. 1-GPU collectives) carry no signal
            }
            let ((cx, cy, cz), dist) = nearest_cell(fx, fy, fz);
            pts.push(FitPoint {
                phi: [
                    1.0,
                    fx / (NX - 1) as f64,
                    fy / (NY - 1) as f64,
                    fz / (NZ - 1) as f64,
                ],
                y: (e.us / analytic).ln(),
                w: e.n.max(1) as f64,
                us: e.us,
                analytic,
                cell: flat(t, cx, cy, cz),
                dist,
            });
            total_points += 1;
        }
    }
    anyhow::ensure!(total_points > 0, "measurement sets contained no usable points");

    let mut fits = Vec::new();
    let mut measured_cells: Vec<(usize, f64)> = Vec::new();
    for (table, pts) in &by_table {
        if pts.is_empty() {
            continue;
        }
        let (fit, cells) = fit_table(db, *table, pts);
        measured_cells.extend(cells);
        fits.push(fit);
    }
    fits.sort_by_key(|f| f.table as usize);
    measured_cells.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(CalibrationArtifact {
        gpu: db.ctx.gpu.clone(),
        gpus_per_node: db.ctx.gpus_per_node,
        num_nodes: db.ctx.num_nodes,
        model: db.ctx.model.clone(),
        framework: db.ctx.framework.clone(),
        kv_dtype: db.ctx.kv_dtype.clone(),
        provenance: format!(
            "fit from {} tables / {} points",
            fits.len(),
            total_points
        ),
        fits,
        measured_cells,
    })
}

/// Fit one table: weighted least squares in log space with outlier
/// rejection and per-axis monotonicity clamping. Also returns the
/// measured-cell overlay (inlier points that sit on a grid cell —
/// rejected outliers must never be served verbatim).
fn fit_table(
    db: &PerfDatabase,
    table: TableId,
    pts: &[FitPoint],
) -> (TableFit, Vec<(usize, f64)>) {
    let s = spec(table);
    // Active design columns: intercept always; an axis only when the
    // points actually vary along it (degenerate axes — the collectives'
    // z — would make the normal equations singular).
    let variance = |col: usize| -> f64 {
        let vals: Vec<f64> = pts.iter().map(|p| p.phi[col]).collect();
        stats::stddev(&vals)
    };
    let mut active = [true, false, false, false];
    if pts.len() >= MIN_POINTS_FULL_FIT {
        for a in 0..3 {
            // A physically degenerate axis never gets a tilt even if
            // numeric jitter gives its coordinates spread.
            let degenerate = match a {
                0 => s.x.hi <= s.x.lo,
                1 => s.y.hi <= s.y.lo,
                _ => s.z.hi <= s.z.lo,
            };
            active[a + 1] = !degenerate && variance(a + 1) > 1e-9;
        }
    }

    let mut used: Vec<&FitPoint> = pts.iter().collect();
    let mut coeffs = wls(&used, &active);

    // ---- Outlier rejection (one MAD pass) ------------------------------
    let resid: Vec<f64> = used.iter().map(|p| p.y - dot(&coeffs, &p.phi)).collect();
    let med = stats::median(&resid);
    let abs_dev: Vec<f64> = resid.iter().map(|r| (r - med).abs()).collect();
    let thr = (OUTLIER_MAD_K * 1.4826 * stats::median(&abs_dev)).max(OUTLIER_FLOOR);
    let inliers: Vec<&FitPoint> = used
        .iter()
        .zip(&resid)
        .filter(|(_, r)| (*r - med).abs() <= thr)
        .map(|(p, _)| *p)
        .collect();
    let n_outliers = used.len() - inliers.len();
    if n_outliers > 0 && inliers.len() >= 2 {
        used = inliers;
        coeffs = wls(&used, &active);
    }

    // ---- Per-axis monotonicity check ----------------------------------
    // A correction tilt must not break the analytic table's ordering:
    // compare the fraction of monotone (nondecreasing) adjacent cell
    // pairs along each axis, before vs after applying the correction,
    // and zero the tilt of any axis that degrades it.
    let t = table as usize;
    let base = &db.grids()[t * NX * NY * NZ..(t + 1) * NX * NY * NZ];
    let mut clamped = [false; 3];
    for _round in 0..3 {
        let fit = TableFit {
            table,
            coeffs,
            n_points: used.len(),
            n_outliers,
            clamped_axes: clamped,
            pre_mape: 0.0,
            post_mape: 0.0,
            resid_log_std: 0.0,
        };
        let cal: Vec<f32> = calibrated_slice(base, &fit);
        let mut worst: Option<usize> = None;
        let mut worst_drop = MONO_TOL;
        for a in 0..3 {
            if !active[a + 1] || clamped[a] || coeffs[a + 1] == 0.0 {
                continue;
            }
            let drop = mono_frac(base, a) - mono_frac(&cal, a);
            if drop > worst_drop {
                worst_drop = drop;
                worst = Some(a);
            }
        }
        match worst {
            Some(a) => {
                clamped[a] = true;
                active[a + 1] = false;
                coeffs = wls(&used, &active);
            }
            None => break,
        }
    }

    // ---- Residual stats ------------------------------------------------
    let pre: Vec<f64> = used.iter().map(|p| (p.analytic - p.us).abs() / p.us).collect();
    let post: Vec<f64> = used
        .iter()
        .map(|p| {
            let corrected = p.analytic * dot(&coeffs, &p.phi).exp();
            (corrected - p.us).abs() / p.us
        })
        .collect();
    let final_resid: Vec<f64> = used.iter().map(|p| p.y - dot(&coeffs, &p.phi)).collect();

    // Measured-cell overlay from the surviving points.
    let mut by_cell: HashMap<usize, Vec<f64>> = HashMap::new();
    for p in used.iter().filter(|p| p.dist <= MEASURED_SNAP) {
        by_cell.entry(p.cell).or_default().push(p.us);
    }
    let mut cells: Vec<(usize, f64)> =
        by_cell.into_iter().map(|(c, vals)| (c, stats::median(&vals))).collect();
    cells.sort_by(|a, b| a.0.cmp(&b.0));

    (
        TableFit {
            table,
            coeffs,
            n_points: used.len(),
            n_outliers,
            clamped_axes: clamped,
            pre_mape: stats::mean(&pre),
            post_mape: stats::mean(&post),
            resid_log_std: stats::stddev(&final_resid),
        },
        cells,
    )
}

fn dot(c: &[f64; 4], phi: &[f64; 4]) -> f64 {
    c[0] * phi[0] + c[1] * phi[1] + c[2] * phi[2] + c[3] * phi[3]
}

/// Weighted least squares over the active design columns (normal
/// equations + Gaussian elimination; at most 4×4). Falls back to the
/// weighted-mean intercept if the system is singular.
fn wls(pts: &[&FitPoint], active: &[bool; 4]) -> [f64; 4] {
    let cols: Vec<usize> = (0..4).filter(|&c| active[c]).collect();
    let k = cols.len();
    let mut a = vec![vec![0.0f64; k]; k];
    let mut b = vec![0.0f64; k];
    for p in pts {
        for (i, &ci) in cols.iter().enumerate() {
            for (j, &cj) in cols.iter().enumerate() {
                a[i][j] += p.w * p.phi[ci] * p.phi[cj];
            }
            b[i] += p.w * p.phi[ci] * p.y;
        }
    }
    let mut out = [0.0f64; 4];
    match gauss_solve(&mut a, &mut b) {
        Some(x) => {
            for (i, &ci) in cols.iter().enumerate() {
                out[ci] = x[i];
            }
        }
        None => {
            // Singular: constant-only calibration.
            let wsum: f64 = pts.iter().map(|p| p.w).sum();
            if wsum > 0.0 {
                out[0] = pts.iter().map(|p| p.w * p.y).sum::<f64>() / wsum;
            }
        }
    }
    out
}

/// In-place Gaussian elimination with partial pivoting; `None` when the
/// system is singular.
fn gauss_solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        let pivot = (col..n).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in (col + 1)..n {
            let f = a[row][col] / a[col][col];
            for c in col..n {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for c in (row + 1)..n {
            acc -= a[row][c] * x[c];
        }
        x[row] = acc / a[row][row];
    }
    Some(x)
}

/// One table's grid slice with the fitted correction applied.
fn calibrated_slice(base: &[f32], fit: &TableFit) -> Vec<f32> {
    let mut out = vec![0f32; NX * NY * NZ];
    for ix in 0..NX {
        for iy in 0..NY {
            for iz in 0..NZ {
                let i = (ix * NY + iy) * NZ + iz;
                out[i] = (base[i] as f64 * fit.factor_at(ix, iy, iz)) as f32;
            }
        }
    }
    out
}

/// Fraction of adjacent cell pairs along `axis` (0=x, 1=y, 2=z) that
/// are nondecreasing, over one table's `[NX, NY, NZ]` slice.
fn mono_frac(slice: &[f32], axis: usize) -> f64 {
    let idx = |ix: usize, iy: usize, iz: usize| (ix * NY + iy) * NZ + iz;
    let (mut ok, mut total) = (0usize, 0usize);
    let (lx, ly, lz) = match axis {
        0 => (NX - 1, NY, NZ),
        1 => (NX, NY - 1, NZ),
        _ => (NX, NY, NZ - 1),
    };
    for ix in 0..lx {
        for iy in 0..ly {
            for iz in 0..lz {
                let a = slice[idx(ix, iy, iz)] as f64;
                let b = match axis {
                    0 => slice[idx(ix + 1, iy, iz)],
                    1 => slice[idx(ix, iy + 1, iz)],
                    _ => slice[idx(ix, iy, iz + 1)],
                } as f64;
                if b >= a * (1.0 - 1e-9) {
                    ok += 1;
                }
                total += 1;
            }
        }
    }
    if total == 0 {
        1.0
    } else {
        ok as f64 / total as f64
    }
}

// ---------------------------------------------------------------------------
// Three-tier lookup
// ---------------------------------------------------------------------------

/// Which tier of the lookup chain answered queries so far. Obtained via
/// [`LatencyOracle::provenance_counts`]; subtract two snapshots to get
/// the counts of one search (`SearchReport::tier_counts`). Note that a
/// memoizing wrapper ([`super::MemoOracle`]) only forwards cache
/// *misses*, so counts under a memo are unique-shape counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierSnapshot {
    /// Queries answered by a directly measured cell.
    pub measured: u64,
    /// Queries interpolated on the correction-scaled analytic grid.
    pub calibrated: u64,
    /// Queries interpolated on the plain analytic grid (tables with no
    /// measurements).
    pub analytic: u64,
    /// Queries answered by the Speed-of-Light roofline fallback.
    pub sol: u64,
}

impl TierSnapshot {
    pub fn total(&self) -> u64 {
        self.measured + self.calibrated + self.analytic + self.sol
    }

    /// Counts accumulated since an `earlier` snapshot.
    pub fn since(&self, earlier: &TierSnapshot) -> TierSnapshot {
        TierSnapshot {
            measured: self.measured - earlier.measured,
            calibrated: self.calibrated - earlier.calibrated,
            analytic: self.analytic - earlier.analytic,
            sol: self.sol - earlier.sol,
        }
    }
}

#[derive(Default)]
struct TierCounters {
    measured: AtomicU64,
    calibrated: AtomicU64,
    analytic: AtomicU64,
    sol: AtomicU64,
}

/// A [`PerfDatabase`] with a calibration artifact composed on top:
/// the three-tier lookup chain with per-query provenance accounting.
pub struct CalibratedDb {
    pub base: PerfDatabase,
    /// Full packed grid with per-table corrections applied (tables
    /// without a fit keep their analytic values).
    cal_grids: Vec<f32>,
    /// Directly measured cells (flat index → median measured µs).
    measured: HashMap<usize, f64>,
    /// Which tables carry a fitted correction.
    has_fit: [bool; NUM_TABLES],
    tiers: TierCounters,
}

impl CalibratedDb {
    /// Compose an artifact over a freshly profiled database. Strictly
    /// validates the compatibility rules (DESIGN.md): format version
    /// and grid shape are checked at artifact load; the full profiling
    /// context must match here.
    pub fn compose(base: PerfDatabase, artifact: &CalibrationArtifact) -> anyhow::Result<Self> {
        // The artifact format carries no fabric field: every existing
        // artifact was fitted against legacy-fabric analytic grids
        // (flat ring collectives). Composing those corrections onto a
        // tiered database would scale min-cost tiered predictions by
        // coefficients fitted on a different cost model — reject
        // loudly until the format grows a fabric context.
        anyhow::ensure!(
            !base.cluster.fabric.placement_aware(),
            "calibration artifacts bind to the legacy fabric they were fitted on; composing \
             onto a '{}' tiered-fabric database is not supported (drop --fabric or the \
             calibration artifact)",
            base.cluster.fabric.name,
        );
        anyhow::ensure!(
            artifact.gpu == base.ctx.gpu
                && artifact.gpus_per_node == base.ctx.gpus_per_node
                && artifact.num_nodes == base.ctx.num_nodes
                && artifact.model == base.ctx.model
                && artifact.framework == base.ctx.framework
                && artifact.kv_dtype == base.ctx.kv_dtype,
            "calibration artifact context (gpu={} {}x{}, model={}, framework={}, kv_dtype={}) \
             does not match the database context (gpu={} {}x{}, model={}, framework={}, \
             kv_dtype={}) — collective corrections bind to the topology they were fitted on",
            artifact.gpu,
            artifact.num_nodes,
            artifact.gpus_per_node,
            artifact.model,
            artifact.framework,
            artifact.kv_dtype,
            base.ctx.gpu,
            base.ctx.num_nodes,
            base.ctx.gpus_per_node,
            base.ctx.model,
            base.ctx.framework,
            base.ctx.kv_dtype,
        );
        let mut cal_grids = base.grids().to_vec();
        let mut has_fit = [false; NUM_TABLES];
        for fit in &artifact.fits {
            let t = fit.table as usize;
            has_fit[t] = true;
            let start = t * NX * NY * NZ;
            let slice = calibrated_slice(&cal_grids[start..start + NX * NY * NZ], fit);
            cal_grids[start..start + NX * NY * NZ].copy_from_slice(&slice);
        }
        Ok(CalibratedDb {
            base,
            cal_grids,
            measured: artifact.measured_cells.iter().copied().collect(),
            has_fit,
            tiers: TierCounters::default(),
        })
    }

    /// Tier counts accumulated over this database's lifetime.
    pub fn tier_counts(&self) -> TierSnapshot {
        TierSnapshot {
            measured: self.tiers.measured.load(Ordering::Relaxed),
            calibrated: self.tiers.calibrated.load(Ordering::Relaxed),
            analytic: self.tiers.analytic.load(Ordering::Relaxed),
            sol: self.tiers.sol.load(Ordering::Relaxed),
        }
    }
}

/// Cloning duplicates the composed grids/overlay but starts the tier
/// counters at zero: a clone is a private accounting scope. The service
/// relies on this — it caches one composition per context and hands
/// each request a clone, so concurrent requests sharing a context
/// cannot cross-attribute each other's tier counts.
impl Clone for CalibratedDb {
    fn clone(&self) -> Self {
        CalibratedDb {
            base: self.base.clone(),
            cal_grids: self.cal_grids.clone(),
            measured: self.measured.clone(),
            has_fit: self.has_fit,
            tiers: TierCounters::default(),
        }
    }
}

impl LatencyOracle for CalibratedDb {
    fn op_latency_us(&self, op: &Op) -> f64 {
        match query_for(op) {
            Some(q) => {
                // Measured and calibrated comm entries hold the packed
                // layout; placed collectives scale by the analytic
                // placement factor exactly as the uncalibrated
                // database does (1.0 on legacy fabrics) — served from
                // the base database's precomputed path table.
                let place = self.base.place_factor(op);
                let t = q.table as usize;
                let ((cx, cy, cz), dist) = nearest_cell(q.fx, q.fy, q.fz);
                if dist <= MEASURED_SNAP {
                    if let Some(&us) = self.measured.get(&flat(t, cx, cy, cz)) {
                        self.tiers.measured.fetch_add(1, Ordering::Relaxed);
                        return us * q.scale * place;
                    }
                }
                let v = trilinear(&self.cal_grids, t, q.fx, q.fy, q.fz) * q.scale * place;
                if self.has_fit[t] {
                    self.tiers.calibrated.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.tiers.analytic.fetch_add(1, Ordering::Relaxed);
                }
                v
            }
            None => {
                self.tiers.sol.fetch_add(1, Ordering::Relaxed);
                sol::latency_us(&self.base.cluster, op)
            }
        }
    }

    /// Slab-batched three-tier lookup. Queries are bucketed by table
    /// so each bucket slices the calibrated grid once; the measured
    /// snap check, tier attribution and placement scaling per query are
    /// identical to the per-op path (total counter increments match —
    /// pinned bit-for-bit in `tests/hotpath.rs`).
    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        let mut out = vec![0.0; ops.len()];
        let mut buckets: Vec<Vec<(usize, super::tables::Query)>> = vec![Vec::new(); NUM_TABLES];
        for (i, op) in ops.iter().enumerate() {
            match query_for(op) {
                Some(q) => buckets[q.table as usize].push((i, q)),
                None => {
                    self.tiers.sol.fetch_add(1, Ordering::Relaxed);
                    out[i] = sol::latency_us(&self.base.cluster, op);
                }
            }
        }
        const SLAB: usize = NX * NY * NZ;
        for (t, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let slab = &self.cal_grids[t * SLAB..(t + 1) * SLAB];
            for &(i, q) in bucket {
                let place = self.base.place_factor(&ops[i]);
                let ((cx, cy, cz), dist) = nearest_cell(q.fx, q.fy, q.fz);
                if dist <= MEASURED_SNAP {
                    if let Some(&us) = self.measured.get(&flat(t, cx, cy, cz)) {
                        self.tiers.measured.fetch_add(1, Ordering::Relaxed);
                        out[i] = us * q.scale * place;
                        continue;
                    }
                }
                out[i] = crate::perfdb::query::trilinear_in_slab(slab, q.fx, q.fy, q.fz)
                    * q.scale
                    * place;
                if self.has_fit[t] {
                    self.tiers.calibrated.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.tiers.analytic.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        out
    }

    fn provenance_counts(&self) -> Option<TierSnapshot> {
        Some(self.tier_counts())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::{h100_sxm, ClusterSpec};
    use crate::models::{by_name, Dtype};
    use crate::perfdb::measure;
    use crate::silicon::Silicon;

    fn ctx() -> (Silicon, crate::models::ModelArch) {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        (Silicon::new(cluster, Framework::TrtLlm.profile()), by_name("qwen3-32b").unwrap())
    }

    fn db(sil: &Silicon, model: &crate::models::ModelArch) -> PerfDatabase {
        PerfDatabase::build(sil, model, Dtype::Fp8, 0xA1C0)
    }

    #[test]
    fn gauss_solves_small_systems() {
        let mut a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let mut b = vec![5.0, 10.0];
        let x = gauss_solve(&mut a, &mut b).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
        // Singular system is refused.
        let mut a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        let mut b = vec![1.0, 2.0];
        assert!(gauss_solve(&mut a, &mut b).is_none());
    }

    #[test]
    fn fit_recovers_injected_constant_factor() {
        let (sil, model) = ctx();
        let d = db(&sil, &model);
        // Pure scale, no tilt, modest noise: the fit must recover the
        // injected factor within 2% per table.
        let sets = measure::synthesize_with(&sil, &model, Dtype::Fp8, 42, 48, &|_| (1.25, 0.0), 0.02);
        let art = fit(&d, &sets).unwrap();
        assert_eq!(art.fits.len(), TableId::all_active().len());
        for f in &art.fits {
            // Evaluate the fitted correction mid-grid, where the
            // regression estimate is tightest; the injected truth is a
            // uniform 1.25 everywhere.
            let recovered = f.factor_at(NX / 2, NY / 2, NZ / 2);
            assert!(
                (recovered / 1.25 - 1.0).abs() < 0.02,
                "{}: recovered {recovered:.4}, want 1.25",
                f.table.name()
            );
            assert!(f.post_mape < f.pre_mape, "{}: {f:?}", f.table.name());
        }
        assert!(art.all_tables_improve());
    }

    #[test]
    fn fit_survives_corrupted_measurements() {
        let (sil, model) = ctx();
        let d = db(&sil, &model);
        let mut sets =
            measure::synthesize_with(&sil, &model, Dtype::Fp8, 9, 48, &|_| (1.3, 0.0), 0.02);
        // Corrupt one gemm point by 10x — a botched harness run.
        let gemm = sets.iter_mut().find(|s| s.table == TableId::GemmFp16).unwrap();
        gemm.entries[0].us *= 10.0;
        let art = fit(&d, &sets).unwrap();
        let f = art.fits.iter().find(|f| f.table == TableId::GemmFp16).unwrap();
        assert!(f.n_outliers >= 1, "the corrupted point must be rejected: {f:?}");
        assert!(f.n_points >= 44, "rejection must not gut the table: {f:?}");
        let recovered = f.factor_at(NX / 2, NY / 2, NZ / 2);
        assert!((recovered / 1.3 - 1.0).abs() < 0.02, "recovered {recovered}");
        // The rejected point must not be served verbatim by the overlay.
        let bad = &sets.iter().find(|s| s.table == TableId::GemmFp16).unwrap().entries[0];
        let s = spec(TableId::GemmFp16);
        let ((cx, cy, cz), _) = nearest_cell(s.x.frac(bad.x), s.y.frac(bad.y), s.z.frac(bad.z));
        let cell = flat(TableId::GemmFp16 as usize, cx, cy, cz);
        assert!(
            !art.measured_cells.iter().any(|&(c, _)| c == cell),
            "outlier landed in the measured-cell overlay"
        );
    }

    #[test]
    fn monotonicity_clamp_blocks_inverting_tilts() {
        let (sil, model) = ctx();
        let d = db(&sil, &model);
        // A violently negative x-tilt would make latency shrink with
        // problem size; the clamp must zero it.
        let sets =
            measure::synthesize_with(&sil, &model, Dtype::Fp8, 5, 64, &|_| (1.3, -3.0), 0.01);
        let art = fit(&d, &sets).unwrap();
        let f = art.fits.iter().find(|f| f.table == TableId::GemmFp16).unwrap();
        assert!(f.clamped_axes[0], "x tilt must be clamped: {f:?}");
        assert_eq!(f.coeffs[1], 0.0);
    }

    #[test]
    fn artifact_json_round_trip() {
        let (sil, model) = ctx();
        let d = db(&sil, &model);
        let sets = measure::synthesize(&sil, &model, Dtype::Fp8, 11, 16);
        let art = fit(&d, &sets).unwrap();
        let back = CalibrationArtifact::from_json(&art.to_json()).unwrap();
        assert_eq!(back.gpu, art.gpu);
        assert_eq!(back.fits, art.fits);
        assert_eq!(back.measured_cells, art.measured_cells);
    }

    #[test]
    fn compose_rejects_tiered_fabric_databases() {
        // Artifacts carry no fabric field: they were fitted against
        // legacy-fabric grids and must not scale tiered predictions.
        let (sil, model) = ctx();
        let sets = measure::synthesize(&sil, &model, Dtype::Fp8, 11, 8);
        let art = fit(&db(&sil, &model), &sets).unwrap();
        let tiered = ClusterSpec::with_fabric(
            h100_sxm(),
            8,
            1,
            crate::topology::fabric::hgx_h100(),
        );
        let tsil = Silicon::new(tiered, Framework::TrtLlm.profile());
        let tdb = PerfDatabase::build(&tsil, &model, Dtype::Fp8, 0xA1C0);
        let err = CalibratedDb::compose(tdb, &art).unwrap_err();
        assert!(err.to_string().contains("legacy fabric"), "{err}");
    }

    #[test]
    fn compose_rejects_context_mismatch() {
        let (sil, model) = ctx();
        let d = db(&sil, &model);
        let sets = measure::synthesize(&sil, &model, Dtype::Fp8, 11, 8);
        let mut art = fit(&d, &sets).unwrap();
        art.gpu = "b200".to_string();
        assert!(CalibratedDb::compose(db(&sil, &model), &art).is_err());
        // Topology is part of the context: collective corrections
        // fitted on 1 node must not compose onto a 2-node database.
        let mut art2 = fit(&d, &sets).unwrap();
        assert_eq!((art2.gpus_per_node, art2.num_nodes), (8, 1));
        art2.num_nodes = 2;
        assert!(CalibratedDb::compose(db(&sil, &model), &art2).is_err());
    }

    #[test]
    fn calibrated_interp_applies_factor_and_counts_tiers() {
        let (sil, model) = ctx();
        let d = db(&sil, &model);
        let sets = measure::synthesize_with(&sil, &model, Dtype::Fp8, 21, 48, &|_| (1.25, 0.0), 0.02);
        let art = fit(&d, &sets).unwrap();
        let plain = db(&sil, &model);
        let cal = CalibratedDb::compose(db(&sil, &model), &art).unwrap();
        // An off-grid query (not near any measured cell) must be scaled
        // by ~the injected factor relative to the analytic answer.
        let op = Op::Gemm { m: 3000, n: 10240, k: 5120, dtype: Dtype::Fp8, count: 1 };
        let a = plain.op_latency_us(&op);
        let c = cal.op_latency_us(&op);
        assert!((c / a / 1.25 - 1.0).abs() < 0.03, "a={a} c={c}");
        // Elementwise is SoL on both.
        let e = Op::Elementwise { bytes: 1e8, count: 1 };
        assert_eq!(cal.op_latency_us(&e), plain.op_latency_us(&e));
        let t = cal.tier_counts();
        assert_eq!(t.sol, 1);
        assert_eq!(t.calibrated + t.measured, 1);
        assert_eq!(t.total(), 2);
        // The uncalibrated database reports no provenance.
        assert!(plain.provenance_counts().is_none());
        assert!(cal.provenance_counts().is_some());
    }

    #[test]
    fn mono_frac_detects_order() {
        let mut slice = vec![0f32; NX * NY * NZ];
        for ix in 0..NX {
            for iy in 0..NY {
                for iz in 0..NZ {
                    slice[(ix * NY + iy) * NZ + iz] = ix as f32;
                }
            }
        }
        assert_eq!(mono_frac(&slice, 0), 1.0);
        assert_eq!(mono_frac(&slice, 1), 1.0); // constant along y counts as monotone
        // Strictly decreasing along x.
        for v in slice.iter_mut() {
            *v = -*v;
        }
        assert_eq!(mono_frac(&slice, 0), 0.0);
    }
}

//! Memoizing [`LatencyOracle`] wrapper for batch sweeps.
//!
//! A multi-scenario sweep ([`crate::search::TaskRunner::run_sweep`])
//! prices thousands of candidate configurations whose operator lists
//! overlap heavily — the same GEMM/attention/collective shapes recur
//! across engines and across (ISL, OSL, SLA) scenarios. Every oracle in
//! this crate is deterministic per op, so answers can be memoized: the
//! cache key is the op's full shape **excluding its `count`** (latency
//! is per instance), with float fields keyed by bit pattern.
//!
//! The map is sharded to keep lock contention negligible under the
//! worker pool, and hit/miss counters are exposed for the sweep bench.
//! For sweeps the runner goes one step further: each pool worker owns a
//! [`LocalMemo`] L1 ([`MemoOracle::local`]) that buffers every write
//! thread-locally and folds into the shared store once at join, so the
//! sharded mutexes see no write traffic at all while candidates are
//! being priced.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::ops::Op;

use super::LatencyOracle;

const SHARDS: usize = 16;

/// Op-class tag numbering of the memo key. Public because the
/// differential-replan layer ([`crate::search::delta`]) keys its
/// invalidation masks by these tags: a delta names the op classes it
/// perturbs as a bitmask (`1 << TAG_*`) and [`MemoStore::invalidate_tags`]
/// drops exactly those entries.
pub const TAG_GEMM: u8 = 0;
pub const TAG_ATTN_PREFILL: u8 = 1;
pub const TAG_ATTN_DECODE: u8 = 2;
pub const TAG_MOE_GEMM: u8 = 3;
pub const TAG_ALL_REDUCE: u8 = 4;
pub const TAG_ALL_GATHER: u8 = 5;
pub const TAG_ALL_TO_ALL: u8 = 6;
pub const TAG_P2P: u8 = 7;
pub const TAG_ELEMENTWISE: u8 = 8;
/// Number of distinct op tags (mask bits above this are meaningless).
pub const NUM_TAGS: u8 = 9;

/// Hashable identity of an op instance (count excluded).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct OpKey {
    tag: u8,
    a: u64,
    b: u64,
    c: u64,
    d: u64,
    e: u64,
}

/// The memo tag of an op (the delta layer's invalidation granularity).
pub fn op_tag(op: &Op) -> u8 {
    match op {
        Op::Gemm { .. } => TAG_GEMM,
        Op::AttnPrefill { .. } => TAG_ATTN_PREFILL,
        Op::AttnDecode { .. } => TAG_ATTN_DECODE,
        Op::MoeGemm { .. } => TAG_MOE_GEMM,
        Op::AllReduce { .. } => TAG_ALL_REDUCE,
        Op::AllGather { .. } => TAG_ALL_GATHER,
        Op::AllToAll { .. } => TAG_ALL_TO_ALL,
        Op::P2p { .. } => TAG_P2P,
        Op::Elementwise { .. } => TAG_ELEMENTWISE,
    }
}

fn key_of(op: &Op) -> OpKey {
    let tag = op_tag(op);
    match *op {
        Op::Gemm { m, n, k, dtype, .. } => {
            OpKey { tag, a: m, b: n, c: k, d: dtype as u64, e: 0 }
        }
        Op::AttnPrefill { q_tokens, kv_len, heads, head_dim, causal_frac, .. } => OpKey {
            tag,
            a: q_tokens,
            b: kv_len,
            c: heads,
            d: head_dim,
            e: causal_frac.to_bits(),
        },
        Op::AttnDecode { batch, kv_len, heads, head_dim, kv_token_bytes, .. } => OpKey {
            tag,
            a: batch,
            b: kv_len,
            c: heads,
            d: head_dim,
            e: kv_token_bytes.to_bits(),
        },
        Op::MoeGemm { tokens, experts, inter, hidden, dtype, imbalance, .. } => OpKey {
            tag,
            a: tokens,
            b: experts,
            c: inter ^ (hidden << 32),
            d: dtype as u64,
            e: imbalance.to_bits(),
        },
        // The placement (span, rails) is part of the price: two
        // layouts of the same group must never share a memo slot.
        Op::AllReduce { bytes, gpus, span, rails, .. } => {
            OpKey { tag, a: bytes.to_bits(), b: gpus as u64, c: span as u64, d: rails as u64, e: 0 }
        }
        Op::AllGather { bytes, gpus, span, rails, .. } => {
            OpKey { tag, a: bytes.to_bits(), b: gpus as u64, c: span as u64, d: rails as u64, e: 0 }
        }
        Op::AllToAll { bytes, gpus, span, rails, .. } => {
            OpKey { tag, a: bytes.to_bits(), b: gpus as u64, c: span as u64, d: rails as u64, e: 0 }
        }
        Op::P2p { bytes, cross_node, .. } => {
            OpKey { tag, a: bytes.to_bits(), b: cross_node as u64, c: 0, d: 0, e: 0 }
        }
        Op::Elementwise { bytes, .. } => {
            OpKey { tag, a: bytes.to_bits(), b: 0, c: 0, d: 0, e: 0 }
        }
    }
}

fn shard_of(k: &OpKey) -> usize {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    k.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// The sharded memo table itself, separable from any one oracle: the
/// service's warm cache keeps one `MemoStore` per deployment context
/// and wraps it around that context's oracle per request
/// ([`MemoOracle::with_store`]), so repeated requests start hot. A
/// store must only ever be shared between oracles that answer
/// identically for the same op (the keyed fields exclude which oracle
/// priced the op).
#[derive(Default)]
pub struct MemoStore {
    shards: [Mutex<HashMap<OpKey, f64>>; SHARDS],
    hits: AtomicU64,
    misses: AtomicU64,
}

impl MemoStore {
    pub fn new() -> MemoStore {
        MemoStore::default()
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Fraction of queries answered from the memo so far (0 before any
    /// query). The capacity planner's memo-warm path reports this.
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Distinct ops memoized.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read-only lookup (no counter side effects — callers account
    /// hits/misses themselves).
    fn get(&self, key: &OpKey) -> Option<f64> {
        self.shards[shard_of(key)].lock().unwrap().get(key).copied()
    }

    /// Drop every memo entry whose op tag is set in `mask`
    /// (bit `1 << op_tag(op)` — see the `TAG_*` constants). The
    /// differential-replan path calls this when a delta perturbs the
    /// backing oracle's answers for some op classes (e.g. a swapped
    /// calibration artifact): surviving entries stay bit-identical, so a
    /// replan through the invalidated store matches a cold re-search
    /// exactly while re-computing only the dropped classes. Returns the
    /// number of entries removed. Hit/miss counters are untouched.
    pub fn invalidate_tags(&self, mask: u64) -> usize {
        let mut removed = 0;
        for shard in &self.shards {
            let mut m = shard.lock().unwrap();
            let before = m.len();
            m.retain(|k, _| mask & (1u64 << k.tag) == 0);
            removed += before - m.len();
        }
        removed
    }

    /// Bulk-merge a worker-local map, taking each shard lock once.
    /// `or_insert` keeps the first value on collisions — every oracle
    /// sharing a store is deterministic per op, so colliding values are
    /// identical anyway.
    fn absorb(&self, map: HashMap<OpKey, f64>) {
        let mut buckets: [Vec<(OpKey, f64)>; SHARDS] = std::array::from_fn(|_| Vec::new());
        for (k, v) in map {
            buckets[shard_of(&k)].push((k, v));
        }
        for (i, bucket) in buckets.into_iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let mut shard = self.shards[i].lock().unwrap();
            for (k, v) in bucket {
                shard.entry(k).or_insert(v);
            }
        }
    }
}

/// Owned-or-borrowed store, so the plain `MemoOracle::new` path keeps
/// its zero-setup ergonomics while the service shares one store across
/// requests.
enum StoreRef<'a> {
    Owned(MemoStore),
    Shared(&'a MemoStore),
}

/// Thread-safe memo over any deterministic oracle.
pub struct MemoOracle<'a> {
    inner: &'a dyn LatencyOracle,
    store: StoreRef<'a>,
}

impl<'a> MemoOracle<'a> {
    /// Memoize over a fresh private store (dies with the oracle).
    pub fn new(inner: &'a dyn LatencyOracle) -> MemoOracle<'a> {
        MemoOracle { inner, store: StoreRef::Owned(MemoStore::new()) }
    }

    /// Memoize into a longer-lived shared store: hits accumulated by
    /// previous wrappers of the same store answer immediately.
    pub fn with_store(inner: &'a dyn LatencyOracle, store: &'a MemoStore) -> MemoOracle<'a> {
        MemoOracle { inner, store: StoreRef::Shared(store) }
    }

    fn store(&self) -> &MemoStore {
        match &self.store {
            StoreRef::Owned(s) => s,
            StoreRef::Shared(s) => s,
        }
    }

    /// (hits, misses) of the backing store so far.
    pub fn stats(&self) -> (u64, u64) {
        self.store().stats()
    }

    /// See [`MemoStore::hit_rate`].
    pub fn hit_rate(&self) -> f64 {
        self.store().hit_rate()
    }

    /// Distinct ops memoized in the backing store.
    pub fn len(&self) -> usize {
        self.store().len()
    }

    pub fn is_empty(&self) -> bool {
        self.store().is_empty()
    }

    /// See [`MemoStore::invalidate_tags`].
    pub fn invalidate_tags(&self, mask: u64) -> usize {
        self.store().invalidate_tags(mask)
    }

    /// A worker-private L1 over this memo: lookups hit a thread-owned
    /// map first, then fall back to one shared-store read, and misses
    /// are computed against the *inner* oracle and recorded locally
    /// only. The shared shards therefore see **zero write-lock traffic
    /// while a sweep runs**; each worker's map is folded back in one
    /// bulk [`LocalMemo::merge`] at join. Hit/miss counters still land
    /// on the shared store (atomics), so `stats()`/`hit_rate()` keep
    /// their meaning.
    pub fn local(&self) -> LocalMemo<'_> {
        LocalMemo { store: self.store(), inner: self.inner, local: Mutex::new(HashMap::new()) }
    }
}

impl LatencyOracle for MemoOracle<'_> {
    fn op_latency_us(&self, op: &Op) -> f64 {
        let st = self.store();
        let key = key_of(op);
        let shard = &st.shards[shard_of(&key)];
        if let Some(&v) = shard.lock().unwrap().get(&key) {
            st.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        // Compute outside the lock: misses on the same key may race and
        // recompute, but the oracle is deterministic so the value they
        // insert is identical.
        let v = self.inner.op_latency_us(op);
        st.misses.fetch_add(1, Ordering::Relaxed);
        shard.lock().unwrap().insert(key, v);
        v
    }

    /// Answer hits from the memo and forward only the misses to the
    /// inner oracle **in one batched call**, so backends with per-call
    /// overhead (the slab-walking database, the PJRT-executed kernel's
    /// single padded execution) keep their batching even when wrapped.
    /// For loop-based inner oracles this produces the same values in
    /// the same per-op order as the default implementation.
    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        let st = self.store();
        let mut out = vec![0.0f64; ops.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_ops: Vec<Op> = Vec::new();
        for (i, op) in ops.iter().enumerate() {
            let key = key_of(op);
            let shard = &st.shards[shard_of(&key)];
            if let Some(&v) = shard.lock().unwrap().get(&key) {
                st.hits.fetch_add(1, Ordering::Relaxed);
                out[i] = v;
            } else {
                miss_idx.push(i);
                miss_ops.push(*op);
            }
        }
        if !miss_ops.is_empty() {
            let vals = self.inner.latency_batch(&miss_ops);
            st.misses.fetch_add(miss_ops.len() as u64, Ordering::Relaxed);
            for ((&i, op), &v) in miss_idx.iter().zip(&miss_ops).zip(&vals) {
                out[i] = v;
                let key = key_of(op);
                st.shards[shard_of(&key)].lock().unwrap().insert(key, v);
            }
        }
        out
    }

    /// Forward provenance accounting to the wrapped oracle. Memo hits
    /// never reach it, so under a memo the tier counts are
    /// unique-shape counts, not raw query counts.
    fn provenance_counts(&self) -> Option<super::TierSnapshot> {
        self.inner.provenance_counts()
    }
}

/// Worker-private memo layer over a shared [`MemoStore`] — the
/// contention-free sweep path (see [`MemoOracle::local`]). One
/// `LocalMemo` is owned per pool worker; the trait's `Sync` bound
/// forces interior mutability, but the `Mutex` below is only ever taken
/// by its owning thread, so it stays uncontended (a cheap fast-path
/// lock) for the whole run.
pub struct LocalMemo<'a> {
    store: &'a MemoStore,
    inner: &'a dyn LatencyOracle,
    local: Mutex<HashMap<OpKey, f64>>,
}

impl LocalMemo<'_> {
    /// Fold this worker's map into the shared store (bulk, one lock per
    /// shard). Called at pool join, in worker-id order, so the shared
    /// store's post-run contents are deterministic.
    pub fn merge(self) {
        let map = self.local.into_inner().unwrap();
        if !map.is_empty() {
            self.store.absorb(map);
        }
    }

    fn lookup(&self, key: &OpKey) -> Option<f64> {
        if let Some(&v) = self.local.lock().unwrap().get(key) {
            self.store.hits.fetch_add(1, Ordering::Relaxed);
            return Some(v);
        }
        // One shared read (no write-lock): warm stores from earlier
        // sweeps still answer, and the value is copied down so repeats
        // stay thread-local.
        if let Some(v) = self.store.get(key) {
            self.store.hits.fetch_add(1, Ordering::Relaxed);
            self.local.lock().unwrap().insert(*key, v);
            return Some(v);
        }
        None
    }
}

impl LatencyOracle for LocalMemo<'_> {
    fn op_latency_us(&self, op: &Op) -> f64 {
        let key = key_of(op);
        if let Some(v) = self.lookup(&key) {
            return v;
        }
        let v = self.inner.op_latency_us(op);
        self.store.misses.fetch_add(1, Ordering::Relaxed);
        self.local.lock().unwrap().insert(key, v);
        v
    }

    /// Hit-scan first (local, then one shared read per op), then one
    /// inner batch for the misses — same shape as the shared wrapper's
    /// batched path, minus all shared write locks.
    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        let mut out = vec![0.0f64; ops.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_ops: Vec<Op> = Vec::new();
        for (i, op) in ops.iter().enumerate() {
            let key = key_of(op);
            match self.lookup(&key) {
                Some(v) => out[i] = v,
                None => {
                    miss_idx.push(i);
                    miss_ops.push(*op);
                }
            }
        }
        if !miss_ops.is_empty() {
            let vals = self.inner.latency_batch(&miss_ops);
            self.store.misses.fetch_add(miss_ops.len() as u64, Ordering::Relaxed);
            let mut local = self.local.lock().unwrap();
            for ((&i, op), &v) in miss_idx.iter().zip(&miss_ops).zip(&vals) {
                out[i] = v;
                local.insert(key_of(op), v);
            }
        }
        out
    }

    fn provenance_counts(&self) -> Option<super::TierSnapshot> {
        self.inner.provenance_counts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::{h100_sxm, ClusterSpec};
    use crate::models::Dtype;
    use crate::silicon::Silicon;

    fn sil() -> Silicon {
        Silicon::new(ClusterSpec::new(h100_sxm(), 8, 1), Framework::TrtLlm.profile())
    }

    #[test]
    fn memo_matches_inner_exactly() {
        let s = sil();
        let memo = MemoOracle::new(&s);
        let ops = [
            Op::Gemm { m: 128, n: 4096, k: 4096, dtype: Dtype::Fp8, count: 3 },
            Op::AttnDecode {
                batch: 16,
                kv_len: 2048,
                heads: 32,
                head_dim: 128,
                kv_token_bytes: 1024.0,
                count: 2,
            },
            Op::AllReduce { bytes: 1e7, gpus: 8, span: 1, rails: 1, count: 1 },
            Op::Elementwise { bytes: 1e6, count: 5 },
        ];
        for op in &ops {
            let truth = LatencyOracle::op_latency_us(&s, op);
            assert_eq!(memo.op_latency_us(op), truth); // miss
            assert_eq!(memo.op_latency_us(op), truth); // hit — bit-identical
        }
        let (hits, misses) = memo.stats();
        assert_eq!(misses, ops.len() as u64);
        assert_eq!(hits, ops.len() as u64);
        assert_eq!(memo.hit_rate(), 0.5);
        // step_latency_us goes through the memo too.
        let step_truth = LatencyOracle::step_latency_us(&s, &ops);
        assert_eq!(memo.step_latency_us(&ops), step_truth);
    }

    #[test]
    fn count_is_not_part_of_the_key() {
        let s = sil();
        let memo = MemoOracle::new(&s);
        let a = Op::Gemm { m: 64, n: 512, k: 512, dtype: Dtype::Fp16, count: 1 };
        let b = Op::Gemm { m: 64, n: 512, k: 512, dtype: Dtype::Fp16, count: 64 };
        memo.op_latency_us(&a);
        memo.op_latency_us(&b);
        assert_eq!(memo.stats(), (1, 1), "same shape at different counts must share an entry");
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn distinct_shapes_get_distinct_entries() {
        let s = sil();
        let memo = MemoOracle::new(&s);
        memo.op_latency_us(&Op::Gemm { m: 1, n: 512, k: 512, dtype: Dtype::Fp16, count: 1 });
        memo.op_latency_us(&Op::Gemm { m: 2, n: 512, k: 512, dtype: Dtype::Fp16, count: 1 });
        memo.op_latency_us(&Op::Gemm { m: 1, n: 512, k: 512, dtype: Dtype::Fp8, count: 1 });
        assert_eq!(memo.len(), 3);
    }

    #[test]
    fn concurrent_use_is_consistent() {
        let s = sil();
        let memo = MemoOracle::new(&s);
        let op = Op::AttnPrefill {
            q_tokens: 1024,
            kv_len: 1024,
            heads: 32,
            head_dim: 128,
            causal_frac: 0.5,
            count: 1,
        };
        let truth = LatencyOracle::op_latency_us(&s, &op);
        std::thread::scope(|sc| {
            for _ in 0..8 {
                sc.spawn(|| {
                    for _ in 0..100 {
                        assert_eq!(memo.op_latency_us(&op), truth);
                    }
                });
            }
        });
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn local_memo_matches_inner_and_merges_at_join() {
        let s = sil();
        let memo = MemoOracle::new(&s);
        let ops = [
            Op::Gemm { m: 128, n: 4096, k: 4096, dtype: Dtype::Fp8, count: 3 },
            Op::AllReduce { bytes: 1e7, gpus: 8, span: 1, rails: 1, count: 1 },
            Op::Elementwise { bytes: 1e6, count: 5 },
        ];
        {
            let local = memo.local();
            for op in &ops {
                let truth = LatencyOracle::op_latency_us(&s, op);
                assert_eq!(local.op_latency_us(op), truth); // miss → inner
                assert_eq!(local.op_latency_us(op), truth); // local hit
            }
            let batch = local.latency_batch(&ops);
            for (v, op) in batch.iter().zip(&ops) {
                assert_eq!(v.to_bits(), LatencyOracle::op_latency_us(&s, op).to_bits());
            }
            // Nothing reached the shared shards yet — all writes local.
            assert_eq!(memo.len(), 0);
            local.merge();
        }
        // After merge the shared store holds every distinct shape, and
        // a fresh worker answers from it via the shared-read fallback.
        assert_eq!(memo.len(), ops.len());
        let (h0, _) = memo.stats();
        let local2 = memo.local();
        for op in &ops {
            assert_eq!(
                local2.op_latency_us(op),
                LatencyOracle::op_latency_us(&s, op)
            );
        }
        let (h1, m1) = memo.stats();
        assert_eq!(h1 - h0, ops.len() as u64, "warm shared store must answer reads");
        assert_eq!(m1, ops.len() as u64, "no recomputation after merge");
    }

    #[test]
    fn invalidate_tags_drops_exactly_the_masked_classes() {
        let s = sil();
        let memo = MemoOracle::new(&s);
        let gemm = Op::Gemm { m: 128, n: 4096, k: 4096, dtype: Dtype::Fp8, count: 1 };
        let gemm2 = Op::Gemm { m: 256, n: 4096, k: 4096, dtype: Dtype::Fp8, count: 1 };
        let ar = Op::AllReduce { bytes: 1e7, gpus: 8, span: 1, rails: 1, count: 1 };
        let ew = Op::Elementwise { bytes: 1e6, count: 1 };
        for op in [&gemm, &gemm2, &ar, &ew] {
            memo.op_latency_us(op);
        }
        assert_eq!(memo.len(), 4);
        let removed = memo.invalidate_tags(1u64 << TAG_GEMM);
        assert_eq!(removed, 2, "both GEMM shapes dropped, nothing else");
        assert_eq!(memo.len(), 2);
        // Survivors still answer as hits; the dropped class recomputes
        // to a bit-identical value (deterministic inner oracle).
        let (_, m0) = memo.stats();
        assert_eq!(memo.op_latency_us(&ar), LatencyOracle::op_latency_us(&s, &ar));
        assert_eq!(memo.op_latency_us(&gemm), LatencyOracle::op_latency_us(&s, &gemm));
        let (_, m1) = memo.stats();
        assert_eq!(m1 - m0, 1, "only the invalidated class misses");
        // Empty and full masks are the no-op / drop-all extremes.
        assert_eq!(memo.invalidate_tags(0), 0);
        assert!(memo.invalidate_tags(!0u64) > 0);
        assert_eq!(memo.len(), 0);
    }

    #[test]
    fn op_tags_are_dense_and_distinct() {
        let ops = [
            Op::Gemm { m: 1, n: 1, k: 1, dtype: Dtype::Fp16, count: 1 },
            Op::AttnPrefill { q_tokens: 1, kv_len: 1, heads: 1, head_dim: 1, causal_frac: 0.0, count: 1 },
            Op::AttnDecode { batch: 1, kv_len: 1, heads: 1, head_dim: 1, kv_token_bytes: 1.0, count: 1 },
            Op::MoeGemm { tokens: 1, experts: 1, inter: 1, hidden: 1, dtype: Dtype::Fp16, imbalance: 1.0, count: 1 },
            Op::AllReduce { bytes: 1.0, gpus: 2, span: 1, rails: 1, count: 1 },
            Op::AllGather { bytes: 1.0, gpus: 2, span: 1, rails: 1, count: 1 },
            Op::AllToAll { bytes: 1.0, gpus: 2, span: 1, rails: 1, count: 1 },
            Op::P2p { bytes: 1.0, cross_node: false, count: 1 },
            Op::Elementwise { bytes: 1.0, count: 1 },
        ];
        let mut tags: Vec<u8> = ops.iter().map(op_tag).collect();
        tags.sort_unstable();
        let expect: Vec<u8> = (0..NUM_TAGS).collect();
        assert_eq!(tags, expect);
    }

    #[test]
    fn shared_store_survives_its_wrappers() {
        let s = sil();
        let store = MemoStore::new();
        let op = Op::Gemm { m: 256, n: 1024, k: 1024, dtype: Dtype::Fp8, count: 1 };
        let truth = LatencyOracle::op_latency_us(&s, &op);
        {
            let memo = MemoOracle::with_store(&s, &store);
            assert_eq!(memo.op_latency_us(&op), truth); // miss
        }
        {
            // A fresh wrapper of the same store answers from the memo.
            let memo = MemoOracle::with_store(&s, &store);
            assert_eq!(memo.op_latency_us(&op), truth);
            assert_eq!(memo.stats(), (1, 1));
        }
        assert_eq!(store.len(), 1);
        assert_eq!(store.hit_rate(), 0.5);
    }
}

//! Native trilinear interpolation — the Rust twin of the Pallas kernel
//! (`python/compile/kernels/interp.py`). Semantics match
//! `python/compile/kernels/ref.py` exactly (corner clamping, degenerate
//! axes); integration tests compare this path against the PJRT-executed
//! kernel on identical grids.

use super::tables::{NX, NY, NZ};

/// Trilinear interpolation on the packed `[T, NX, NY, NZ]` grid at
/// fractional coordinates (already clamped by axis mapping, re-clamped
/// here for safety).
#[inline]
pub fn trilinear(grids: &[f32], table: usize, fx: f64, fy: f64, fz: f64) -> f64 {
    let base = table * NX * NY * NZ;
    trilinear_in_slab(&grids[base..base + NX * NY * NZ], fx, fy, fz)
}

/// Trilinear interpolation inside one table's `[NX, NY, NZ]` slab. The
/// batched oracle path ([`crate::perfdb::LatencyOracle::latency_batch`])
/// groups queries by table and slices the packed grid once per slab, so
/// every lookup in the group reuses the same base pointer instead of
/// re-deriving a table offset per point. Bit-identical to [`trilinear`]
/// (which delegates here).
#[inline]
pub fn trilinear_in_slab(slab: &[f32], fx: f64, fy: f64, fz: f64) -> f64 {
    let x = fx.clamp(0.0, (NX - 1) as f64);
    let y = fy.clamp(0.0, (NY - 1) as f64);
    let z = fz.clamp(0.0, (NZ - 1) as f64);

    let x0 = x.floor() as usize;
    let y0 = y.floor() as usize;
    let z0 = z.floor() as usize;
    let x1 = (x0 + 1).min(NX - 1);
    let y1 = (y0 + 1).min(NY - 1);
    let z1 = (z0 + 1).min(NZ - 1);

    let xd = x - x0 as f64;
    let yd = y - y0 as f64;
    let zd = z - z0 as f64;

    let g = |ix: usize, iy: usize, iz: usize| -> f64 { slab[(ix * NY + iy) * NZ + iz] as f64 };

    let c00 = g(x0, y0, z0) * (1.0 - xd) + g(x1, y0, z0) * xd;
    let c01 = g(x0, y0, z1) * (1.0 - xd) + g(x1, y0, z1) * xd;
    let c10 = g(x0, y1, z0) * (1.0 - xd) + g(x1, y1, z0) * xd;
    let c11 = g(x0, y1, z1) * (1.0 - xd) + g(x1, y1, z1) * xd;

    let c0 = c00 * (1.0 - yd) + c10 * yd;
    let c1 = c01 * (1.0 - yd) + c11 * yd;
    c0 * (1.0 - zd) + c1 * zd
}

/// Flat index into the packed grid (builder-side writes).
#[inline]
pub fn flat(table: usize, ix: usize, iy: usize, iz: usize) -> usize {
    ((table * NX + ix) * NY + iy) * NZ + iz
}

/// Nearest grid cell to fractional coordinates, plus the largest
/// per-axis distance to it (in grid units). The calibration layer's
/// measured-cell tier fires only when that distance is small — a query
/// essentially *at* a measured point ([`crate::perfdb::calibrate`]).
#[inline]
pub fn nearest_cell(fx: f64, fy: f64, fz: f64) -> ((usize, usize, usize), f64) {
    let cx = fx.round().clamp(0.0, (NX - 1) as f64);
    let cy = fy.round().clamp(0.0, (NY - 1) as f64);
    let cz = fz.round().clamp(0.0, (NZ - 1) as f64);
    let dist = (fx - cx).abs().max((fy - cy).abs()).max((fz - cz).abs());
    ((cx as usize, cy as usize, cz as usize), dist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::tables::GRID_LEN;
    use crate::util::rng::Rng;

    fn linear_grid(a: f64, b: f64, c: f64, d: f64) -> Vec<f32> {
        let mut g = vec![0f32; GRID_LEN];
        for ix in 0..NX {
            for iy in 0..NY {
                for iz in 0..NZ {
                    g[flat(0, ix, iy, iz)] =
                        (a * ix as f64 + b * iy as f64 + c * iz as f64 + d) as f32;
                }
            }
        }
        g
    }

    #[test]
    fn reproduces_linear_functions_exactly() {
        let g = linear_grid(2.0, -1.0, 0.5, 10.0);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let fx = rng.f64() * (NX - 1) as f64;
            let fy = rng.f64() * (NY - 1) as f64;
            let fz = rng.f64() * (NZ - 1) as f64;
            let want = 2.0 * fx - fy + 0.5 * fz + 10.0;
            let got = trilinear(&g, 0, fx, fy, fz);
            assert!((got - want).abs() < 1e-3, "({fx},{fy},{fz}): {got} vs {want}");
        }
    }

    #[test]
    fn grid_points_exact() {
        let g = linear_grid(1.0, 3.0, 7.0, 0.0);
        assert_eq!(trilinear(&g, 0, 5.0, 6.0, 2.0), 5.0 + 18.0 + 14.0);
    }

    #[test]
    fn clamps_out_of_range() {
        let g = linear_grid(1.0, 0.0, 0.0, 0.0);
        assert_eq!(trilinear(&g, 0, -5.0, 0.0, 0.0), 0.0);
        assert_eq!(trilinear(&g, 0, 1e9, 0.0, 0.0), (NX - 1) as f64);
    }

    #[test]
    fn nearest_cell_rounds_and_reports_distance() {
        let ((x, y, z), d) = nearest_cell(5.1, 6.9, 2.0);
        assert_eq!((x, y, z), (5, 7, 2));
        assert!((d - 0.1).abs() < 1e-9, "d={d}");
        // Clamped at the edges; distance measured to the clamped cell.
        let ((x, _, _), d) = nearest_cell(-0.4, 0.0, 0.0);
        assert_eq!(x, 0);
        assert!((d - 0.4).abs() < 1e-9);
        let ((x, _, _), _) = nearest_cell(1e9, 0.0, 0.0);
        assert_eq!(x, NX - 1);
    }

    #[test]
    fn slab_view_matches_table_view_bit_for_bit() {
        let mut g = vec![0f32; GRID_LEN];
        let mut rng = Rng::new(7);
        for v in g.iter_mut() {
            *v = (rng.f64() * 100.0) as f32;
        }
        let tables = GRID_LEN / (NX * NY * NZ);
        for t in 0..tables {
            let slab = &g[t * NX * NY * NZ..(t + 1) * NX * NY * NZ];
            for _ in 0..20 {
                let fx = rng.f64() * NX as f64;
                let fy = rng.f64() * NY as f64;
                let fz = rng.f64() * NZ as f64;
                assert_eq!(
                    trilinear(&g, t, fx, fy, fz).to_bits(),
                    trilinear_in_slab(slab, fx, fy, fz).to_bits()
                );
            }
        }
    }

    #[test]
    fn table_offset_respected() {
        let mut g = vec![0f32; GRID_LEN];
        g[flat(3, 0, 0, 0)] = 99.0;
        assert_eq!(trilinear(&g, 3, 0.0, 0.0, 0.0), 99.0);
        assert_eq!(trilinear(&g, 2, 0.0, 0.0, 0.0), 0.0);
    }
}

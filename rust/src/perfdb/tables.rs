//! Table layout of the packed operator-latency database.
//!
//! The grid geometry (16 tables × 32×32×16) is the AOT shape contract
//! shared with the Pallas interpolation kernel
//! (`python/compile/model.py`); `artifacts/manifest.json` carries the
//! same numbers and the runtime asserts agreement at load.

use crate::models::Dtype;
use crate::ops::Op;

pub const NUM_TABLES: usize = 16;
pub const NX: usize = 32;
pub const NY: usize = 32;
pub const NZ: usize = 16;
pub const GRID_LEN: usize = NUM_TABLES * NX * NY * NZ;

/// Semantic table ids (slots 14–15 reserved).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum TableId {
    GemmFp16 = 0,
    GemmFp8 = 1,
    GemmInt8 = 2,
    GemmInt4 = 3,
    AttnPrefill = 4,
    AttnDecode = 5,
    MoeFp16 = 6,
    MoeFp8 = 7,
    MoeInt8 = 8,
    MoeInt4 = 9,
    AllReduce = 10,
    AllGather = 11,
    AllToAll = 12,
    P2p = 13,
}

impl TableId {
    pub fn gemm(dt: Dtype) -> TableId {
        match dt {
            Dtype::Fp16 => TableId::GemmFp16,
            Dtype::Fp8 => TableId::GemmFp8,
            Dtype::Int8 => TableId::GemmInt8,
            Dtype::Int4 => TableId::GemmInt4,
        }
    }

    pub fn moe(dt: Dtype) -> TableId {
        match dt {
            Dtype::Fp16 => TableId::MoeFp16,
            Dtype::Fp8 => TableId::MoeFp8,
            Dtype::Int8 => TableId::MoeInt8,
            Dtype::Int4 => TableId::MoeInt4,
        }
    }

    pub fn all_active() -> [TableId; 14] {
        use TableId::*;
        [
            GemmFp16, GemmFp8, GemmInt8, GemmInt4, AttnPrefill, AttnDecode,
            MoeFp16, MoeFp8, MoeInt8, MoeInt4, AllReduce, AllGather, AllToAll, P2p,
        ]
    }

    /// Stable on-disk name for this table — used by the measurement
    /// files (`artifacts/measurements/<gpu>/<table>.json`) and the
    /// calibration artifact, so renames here are format breaks.
    pub fn name(self) -> &'static str {
        use TableId::*;
        match self {
            GemmFp16 => "gemm_fp16",
            GemmFp8 => "gemm_fp8",
            GemmInt8 => "gemm_int8",
            GemmInt4 => "gemm_int4",
            AttnPrefill => "attn_prefill",
            AttnDecode => "attn_decode",
            MoeFp16 => "moe_fp16",
            MoeFp8 => "moe_fp8",
            MoeInt8 => "moe_int8",
            MoeInt4 => "moe_int4",
            AllReduce => "allreduce",
            AllGather => "allgather",
            AllToAll => "alltoall",
            P2p => "p2p",
        }
    }

    /// Inverse of [`TableId::name`].
    pub fn parse(s: &str) -> Option<TableId> {
        TableId::all_active().into_iter().find(|id| id.name() == s)
    }
}

/// One grid axis: physical range + spacing. A degenerate axis
/// (`hi <= lo`) pins every query to index 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Axis {
    pub lo: f64,
    pub hi: f64,
    pub log2: bool,
    /// Grid points along this axis (NX/NY/NZ).
    pub n: usize,
}

impl Axis {
    pub fn log(lo: f64, hi: f64, n: usize) -> Axis {
        Axis { lo, hi, log2: true, n }
    }

    pub fn lin(lo: f64, hi: f64, n: usize) -> Axis {
        Axis { lo, hi, log2: false, n }
    }

    pub fn constant(v: f64, n: usize) -> Axis {
        Axis { lo: v, hi: v, log2: false, n }
    }

    fn tf(&self, v: f64) -> f64 {
        if self.log2 {
            v.max(1e-12).log2()
        } else {
            v
        }
    }

    /// Fractional grid index for physical value `v`, clamped to
    /// [0, n-1]. Out-of-range values clamp (boundary extrapolation).
    pub fn frac(&self, v: f64) -> f64 {
        if self.hi <= self.lo {
            return 0.0;
        }
        let (l, h) = (self.tf(self.lo), self.tf(self.hi));
        let f = (self.tf(v) - l) / (h - l) * (self.n - 1) as f64;
        f.clamp(0.0, (self.n - 1) as f64)
    }

    /// Physical value of grid index `i`.
    pub fn value(&self, i: usize) -> f64 {
        if self.hi <= self.lo {
            return self.lo;
        }
        let (l, h) = (self.tf(self.lo), self.tf(self.hi));
        let t = l + (h - l) * i as f64 / (self.n - 1) as f64;
        if self.log2 {
            t.exp2()
        } else {
            t
        }
    }
}

/// Axis triple for one table.
#[derive(Clone, Copy, Debug)]
pub struct TableSpec {
    pub id: TableId,
    pub x: Axis,
    pub y: Axis,
    pub z: Axis,
}

/// Canonical axis specs (shared by the builder and the query mapper —
/// the invertibility that makes profiling and lookup agree).
pub fn spec(id: TableId) -> TableSpec {
    use TableId::*;
    match id {
        GemmFp16 | GemmFp8 | GemmInt8 | GemmInt4 => TableSpec {
            id,
            x: Axis::log(1.0, 262_144.0, NX),   // m: 1 .. 256k tokens
            y: Axis::log(64.0, 262_144.0, NY),  // n
            z: Axis::log(64.0, 32_768.0, NZ),   // k
        },
        AttnPrefill => TableSpec {
            id,
            x: Axis::log(1.0, 16_384.0, NX),    // q tokens per request
            y: Axis::log(16.0, 131_072.0, NY),  // kv length
            z: Axis::log(1.0, 128.0, NZ),       // heads per GPU
        },
        AttnDecode => TableSpec {
            id,
            x: Axis::log(1.0, 512.0, NX),       // decode batch
            y: Axis::log(16.0, 131_072.0, NY),  // kv length
            z: Axis::log(1.0, 128.0, NZ),       // heads per GPU
        },
        MoeFp16 | MoeFp8 | MoeInt8 | MoeInt4 => TableSpec {
            id,
            x: Axis::log(1.0, 131_072.0, NX),   // routed tokens per GPU
            y: Axis::log(1.0, 256.0, NY),       // resident experts per GPU
            z: Axis::lin(1.0, 8.0, NZ),         // imbalance γ
        },
        AllReduce | AllGather | AllToAll => TableSpec {
            id,
            x: Axis::log(256.0, 1.074e9, NX),   // bytes
            y: Axis::log(2.0, 64.0, NY),        // gpus
            z: Axis::constant(0.0, NZ),
        },
        P2p => TableSpec {
            id,
            x: Axis::log(256.0, 1.074e9, NX),   // bytes
            y: Axis::lin(0.0, 1.0, NY),         // cross-node flag
            z: Axis::constant(0.0, NZ),
        },
    }
}

/// Canonical MoE FFN shape the grouped-GEMM tables are profiled at.
/// Both the compute and weight-streaming paths are linear in
/// `inter * hidden`, so queries for other shapes scale the interpolated
/// latency by the volume ratio (per-expert dispatch overhead mis-scales
/// slightly — an accepted approximation recorded in DESIGN.md).
pub const MOE_CANON_INTER: u64 = 2048;
pub const MOE_CANON_HIDDEN: u64 = 4096;

/// A database lookup: table + fractional grid coordinates + a linear
/// post-scale applied to the interpolated value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Query {
    pub table: TableId,
    pub fx: f64,
    pub fy: f64,
    pub fz: f64,
    pub scale: f64,
}

/// Map an op to its database query, or `None` if the op class is not
/// profiled (answered by the Speed-of-Light fallback instead).
pub fn query_for(op: &Op) -> Option<Query> {
    let (table, x, y, z, scale) = match *op {
        Op::Gemm { m, n, k, dtype, .. } => {
            (TableId::gemm(dtype), m as f64, n as f64, k as f64, 1.0)
        }
        Op::AttnPrefill { q_tokens, kv_len, heads, .. } => {
            (TableId::AttnPrefill, q_tokens as f64, kv_len as f64, heads as f64, 1.0)
        }
        Op::AttnDecode { batch, kv_len, heads, .. } => {
            (TableId::AttnDecode, batch as f64, kv_len as f64, heads as f64, 1.0)
        }
        Op::MoeGemm { tokens, experts, inter, hidden, dtype, imbalance, .. } => {
            // Tables hold the canonical FFN shape; scale by volume ratio.
            let scale = (inter * hidden) as f64
                / (MOE_CANON_INTER * MOE_CANON_HIDDEN) as f64;
            (TableId::moe(dtype), tokens as f64, experts as f64, imbalance, scale)
        }
        Op::AllReduce { bytes, gpus, .. } => (TableId::AllReduce, bytes, gpus as f64, 0.0, 1.0),
        Op::AllGather { bytes, gpus, .. } => (TableId::AllGather, bytes, gpus as f64, 0.0, 1.0),
        Op::AllToAll { bytes, gpus, .. } => (TableId::AllToAll, bytes, gpus as f64, 0.0, 1.0),
        Op::P2p { bytes, cross_node, .. } => {
            (TableId::P2p, bytes, if cross_node { 1.0 } else { 0.0 }, 0.0, 1.0)
        }
        Op::Elementwise { .. } => return None,
    };
    let s = spec(table);
    Some(Query {
        table,
        fx: s.x.frac(x),
        fy: s.y.frac(y),
        fz: s.z.frac(z),
        scale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_roundtrip() {
        let a = Axis::log(1.0, 262_144.0, 32);
        for i in [0usize, 7, 16, 31] {
            let v = a.value(i);
            assert!((a.frac(v) - i as f64).abs() < 1e-9, "i={i} v={v}");
        }
        let l = Axis::lin(1.0, 8.0, 16);
        assert!((l.frac(l.value(5)) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn axis_clamps() {
        let a = Axis::log(16.0, 1024.0, 8);
        assert_eq!(a.frac(1.0), 0.0);
        assert_eq!(a.frac(1e9), 7.0);
    }

    #[test]
    fn constant_axis() {
        let a = Axis::constant(0.0, 16);
        assert_eq!(a.frac(123.0), 0.0);
        assert_eq!(a.value(9), 0.0);
    }

    #[test]
    fn query_mapping_dispatch() {
        use crate::models::Dtype;
        let q = query_for(&Op::Gemm { m: 64, n: 4096, k: 4096, dtype: Dtype::Fp8, count: 1 })
            .unwrap();
        assert_eq!(q.table, TableId::GemmFp8);
        assert!(q.fx > 0.0 && q.fx < 31.0);
        assert!(query_for(&Op::Elementwise { bytes: 1e6, count: 1 }).is_none());
        let p = query_for(&Op::P2p { bytes: 1e6, cross_node: true, count: 1 }).unwrap();
        assert_eq!(p.fy, 31.0);
    }

    #[test]
    fn all_active_have_specs() {
        for id in TableId::all_active() {
            let s = spec(id);
            assert_eq!(s.x.n, NX);
            assert_eq!(s.y.n, NY);
            assert_eq!(s.z.n, NZ);
        }
    }

    #[test]
    fn table_names_round_trip_and_are_unique() {
        let mut seen = Vec::new();
        for id in TableId::all_active() {
            let n = id.name();
            assert!(!seen.contains(&n), "duplicate table name {n}");
            seen.push(n);
            assert_eq!(TableId::parse(n), Some(id));
        }
        assert_eq!(TableId::parse("warp_drive"), None);
    }
}

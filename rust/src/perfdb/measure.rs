//! Kernel-latency measurement sets: the versioned on-disk format that
//! feeds the calibration pipeline ([`super::calibrate`]).
//!
//! A measurement file is one `(gpu, table)` pair's worth of observed
//! kernel latencies at explicit table coordinates:
//!
//! ```json
//! {
//!   "version": 1,
//!   "table": "gemm_fp16",
//!   "gpu": "h100-sxm",
//!   "model": "qwen3-32b",
//!   "framework": "trtllm",
//!   "kv_dtype": "fp8",
//!   "generator": "free-form provenance string",
//!   "entries": [ {"x": 1.0, "y": 64.0, "z": 64.0, "us": 12.3, "n": 3} ]
//! }
//! ```
//!
//! Coordinates are *physical* axis values in the table's own units
//! (`perfdb/tables.rs::spec` — e.g. m/n/k for GEMM tables), exactly the
//! values a profiling harness sweeps; `us` is the measured per-instance
//! latency in microseconds (median over `n` repeats). Files live at
//! `artifacts/measurements/<gpu>/<table>.json`. Real GPU traces and the
//! committed synthetic set (`python/measurements/synth.py`) share this
//! format; [`synthesize`] produces the same thing hermetically from the
//! synthetic silicon for tests and for bootstrapping new platforms.

use std::path::Path;

use crate::models::{Dtype, ModelArch};
use crate::silicon::Silicon;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::builder::op_for_point;
use super::tables::{spec, TableId, NX, NY, NZ};

/// On-disk format version; bump on any incompatible change.
pub const FORMAT_VERSION: u32 = 1;

/// One observed latency at explicit table coordinates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// Physical axis coordinates (table units, see `tables::spec`).
    pub x: f64,
    pub y: f64,
    pub z: f64,
    /// Measured per-instance latency, microseconds (median of `n`).
    pub us: f64,
    /// Repeat count behind `us` — the fit weights points by it.
    pub n: u32,
}

/// All measurements for one `(gpu, table)` pair, plus the context they
/// were taken in.
#[derive(Clone, Debug)]
pub struct MeasurementSet {
    pub table: TableId,
    pub gpu: String,
    pub model: String,
    pub framework: String,
    pub kv_dtype: String,
    /// Free-form provenance (harness name, seed, trace id, ...).
    pub generator: String,
    pub entries: Vec<Measurement>,
}

impl MeasurementSet {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", json::num(FORMAT_VERSION as f64))
            .set("table", json::s(self.table.name()))
            .set("gpu", json::s(&self.gpu))
            .set("model", json::s(&self.model))
            .set("framework", json::s(&self.framework))
            .set("kv_dtype", json::s(&self.kv_dtype))
            .set("generator", json::s(&self.generator))
            .set(
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            let mut m = Json::obj();
                            m.set("x", json::num(e.x))
                                .set("y", json::num(e.y))
                                .set("z", json::num(e.z))
                                .set("us", json::num(e.us))
                                .set("n", json::num(e.n as f64));
                            m
                        })
                        .collect(),
                ),
            );
        o
    }

    /// Parse + validate one measurement document.
    pub fn from_json(j: &Json) -> anyhow::Result<MeasurementSet> {
        let version = j.req_f64("version")? as u32;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "measurement format version {version} != supported {FORMAT_VERSION}"
        );
        let tname = j.req_str("table")?;
        let table = TableId::parse(tname)
            .ok_or_else(|| anyhow::anyhow!("unknown measurement table '{tname}'"))?;
        let entries_j = j
            .req("entries")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'entries' must be an array"))?;
        let mut entries = Vec::with_capacity(entries_j.len());
        for (i, e) in entries_j.iter().enumerate() {
            let m = Measurement {
                x: e.req_f64("x")?,
                y: e.req_f64("y")?,
                z: e.req_f64("z")?,
                us: e.req_f64("us")?,
                n: e.f64_or("n", 1.0) as u32,
            };
            anyhow::ensure!(
                m.us.is_finite() && m.us > 0.0,
                "entry {i} of table '{tname}': 'us' must be positive and finite, got {}",
                m.us
            );
            anyhow::ensure!(
                m.x.is_finite() && m.y.is_finite() && m.z.is_finite(),
                "entry {i} of table '{tname}': non-finite coordinate"
            );
            anyhow::ensure!(m.n >= 1, "entry {i} of table '{tname}': 'n' must be >= 1");
            entries.push(m);
        }
        Ok(MeasurementSet {
            table,
            gpu: j.req_str("gpu")?.to_string(),
            model: j.req_str("model")?.to_string(),
            framework: j.req_str("framework")?.to_string(),
            kv_dtype: j.req_str("kv_dtype")?.to_string(),
            generator: j.str_or("generator", "").to_string(),
            entries,
        })
    }

    pub fn parse(txt: &str) -> anyhow::Result<MeasurementSet> {
        Self::from_json(&json::parse(txt)?)
    }
}

/// Load every measurement set under `dir/<gpu>/` (one file per table).
/// Errors are loud: a malformed or mis-labelled file names itself.
pub fn load_dir(dir: &Path, gpu: &str) -> anyhow::Result<Vec<MeasurementSet>> {
    let gdir = dir.join(gpu);
    anyhow::ensure!(
        gdir.is_dir(),
        "no measurement directory for gpu '{gpu}' at {} (expected <dir>/<gpu>/<table>.json)",
        gdir.display()
    );
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(&gdir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    anyhow::ensure!(!paths.is_empty(), "no .json measurement files in {}", gdir.display());
    let mut sets = Vec::new();
    for p in paths {
        let txt = std::fs::read_to_string(&p)?;
        let set = MeasurementSet::parse(&txt)
            .map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))?;
        anyhow::ensure!(
            set.gpu == gpu,
            "{}: file is under gpu dir '{gpu}' but records gpu '{}'",
            p.display(),
            set.gpu
        );
        let expect = format!("{}.json", set.table.name());
        anyhow::ensure!(
            p.file_name().is_some_and(|f| f == expect.as_str()),
            "{}: file name does not match its table '{}'",
            p.display(),
            set.table.name()
        );
        sets.push(set);
    }
    Ok(sets)
}

/// Write sets as `dir/<gpu>/<table>.json`.
pub fn write_sets(dir: &Path, sets: &[MeasurementSet]) -> anyhow::Result<()> {
    for set in sets {
        let gdir = dir.join(&set.gpu);
        std::fs::create_dir_all(&gdir)?;
        let path = gdir.join(format!("{}.json", set.table.name()));
        std::fs::write(&path, set.to_json().to_string())?;
    }
    Ok(())
}

/// Ground-truth miscalibration injected by the default synthetic
/// measurement model: per-table `(scale factor, x-tilt)` — measured =
/// silicon × factor × exp(tilt · fx/(NX-1)) × lognormal noise. Loosely
/// shaped like real analytic-model error: GEMM efficiency misjudged by
/// a constant, attention slightly shape-dependent, collectives worst
/// (topology effects the analytic model undersells).
pub fn default_bias(id: TableId) -> (f64, f64) {
    use TableId::*;
    match id {
        GemmFp16 | GemmFp8 | GemmInt8 | GemmInt4 => (1.28, 0.10),
        AttnPrefill => (1.17, 0.08),
        AttnDecode => (1.22, 0.06),
        MoeFp16 | MoeFp8 | MoeInt8 | MoeInt4 => (1.31, 0.12),
        AllReduce | AllGather | AllToAll => (1.40, 0.05),
        P2p => (1.26, 0.0),
    }
}

/// Synthesize a measurement set per table by "measuring" the silicon at
/// random grid points through a fixed-seed multiplicative bias + noise
/// model. Deterministic per seed. `bias` maps a table to its
/// `(factor, x_tilt)` ground truth (see [`default_bias`]); tests inject
/// a known factor here and assert the fit recovers it.
pub fn synthesize_with(
    silicon: &Silicon,
    model: &ModelArch,
    kv_dtype: Dtype,
    seed: u64,
    points_per_table: usize,
    bias: &dyn Fn(TableId) -> (f64, f64),
    sigma: f64,
) -> Vec<MeasurementSet> {
    const REPEATS: usize = 3;
    let mut rng = Rng::new(seed);
    let mut sets = Vec::new();
    for id in TableId::all_active() {
        let s = spec(id);
        let (factor, tilt) = bias(id);
        let degenerate_z = s.z.hi <= s.z.lo;
        let mut cells: Vec<(usize, usize, usize)> = Vec::new();
        let mut attempts = 0usize;
        while cells.len() < points_per_table && attempts < points_per_table * 20 {
            attempts += 1;
            let c = (
                rng.below(NX as u64) as usize,
                rng.below(NY as u64) as usize,
                if degenerate_z { 0 } else { rng.below(NZ as u64) as usize },
            );
            if !cells.contains(&c) {
                cells.push(c);
            }
        }
        let mut entries = Vec::with_capacity(cells.len());
        for (ix, iy, iz) in cells {
            let (xv, yv, zv) = (s.x.value(ix), s.y.value(iy), s.z.value(iz));
            let op = op_for_point(id, model, kv_dtype, xv, yv, zv);
            let truth = silicon.op_latency_us(&op);
            let corrected = truth * factor * (tilt * ix as f64 / (NX - 1) as f64).exp();
            // Median of noisy repeats, as a real harness reports.
            let mut draws: Vec<f64> =
                (0..REPEATS).map(|_| corrected * rng.noise(sigma)).collect();
            draws.sort_by(|a, b| a.partial_cmp(b).unwrap());
            entries.push(Measurement {
                x: xv,
                y: yv,
                z: zv,
                us: draws[REPEATS / 2],
                n: REPEATS as u32,
            });
        }
        sets.push(MeasurementSet {
            table: id,
            gpu: silicon.cluster.gpu.name.to_string(),
            model: model.name.to_string(),
            framework: silicon.fw.framework.name().to_string(),
            kv_dtype: kv_dtype.name().to_string(),
            generator: format!("synthesize(seed={seed}, sigma={sigma})"),
            entries,
        });
    }
    sets
}

/// [`synthesize_with`] under the default bias model and 3% noise.
pub fn synthesize(
    silicon: &Silicon,
    model: &ModelArch,
    kv_dtype: Dtype,
    seed: u64,
    points_per_table: usize,
) -> Vec<MeasurementSet> {
    synthesize_with(silicon, model, kv_dtype, seed, points_per_table, &default_bias, 0.03)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::{h100_sxm, ClusterSpec};
    use crate::models::by_name;

    fn sil() -> Silicon {
        Silicon::new(ClusterSpec::new(h100_sxm(), 8, 1), Framework::TrtLlm.profile())
    }

    #[test]
    fn json_round_trip() {
        let set = MeasurementSet {
            table: TableId::GemmFp8,
            gpu: "h100-sxm".into(),
            model: "qwen3-32b".into(),
            framework: "trtllm".into(),
            kv_dtype: "fp8".into(),
            generator: "test".into(),
            entries: vec![
                Measurement { x: 128.0, y: 4096.0, z: 4096.0, us: 42.5, n: 3 },
                Measurement { x: 1.0, y: 64.0, z: 64.0, us: 3.1, n: 1 },
            ],
        };
        let back = MeasurementSet::parse(&set.to_json().to_string()).unwrap();
        assert_eq!(back.table, set.table);
        assert_eq!(back.entries, set.entries);
        assert_eq!(back.kv_dtype, "fp8");
    }

    #[test]
    fn validation_rejects_bad_documents() {
        // Wrong version.
        assert!(MeasurementSet::parse(
            r#"{"version": 99, "table": "gemm_fp16", "gpu": "g", "model": "m",
                "framework": "f", "kv_dtype": "fp16", "entries": []}"#
        )
        .is_err());
        // Unknown table.
        assert!(MeasurementSet::parse(
            r#"{"version": 1, "table": "nope", "gpu": "g", "model": "m",
                "framework": "f", "kv_dtype": "fp16", "entries": []}"#
        )
        .is_err());
        // Non-positive latency.
        assert!(MeasurementSet::parse(
            r#"{"version": 1, "table": "gemm_fp16", "gpu": "g", "model": "m",
                "framework": "f", "kv_dtype": "fp16",
                "entries": [{"x": 1, "y": 64, "z": 64, "us": 0, "n": 3}]}"#
        )
        .is_err());
    }

    #[test]
    fn synthesize_is_deterministic_and_biased() {
        let s = sil();
        let model = by_name("qwen3-32b").unwrap();
        let a = synthesize(&s, &model, Dtype::Fp8, 7, 12);
        let b = synthesize(&s, &model, Dtype::Fp8, 7, 12);
        assert_eq!(a.len(), TableId::all_active().len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.entries, y.entries, "same seed must reproduce bit-identically");
        }
        // The injected bias is visible: measured / silicon clusters near
        // the table factor, never near 1.0.
        let gemm = a.iter().find(|t| t.table == TableId::GemmFp16).unwrap();
        for e in &gemm.entries {
            let op = op_for_point(TableId::GemmFp16, &model, Dtype::Fp8, e.x, e.y, e.z);
            let ratio = e.us / s.op_latency_us(&op);
            assert!(ratio > 1.1 && ratio < 1.7, "ratio {ratio}");
        }
    }

    #[test]
    fn write_and_load_dir_round_trip() {
        let s = sil();
        let model = by_name("llama3.1-8b").unwrap();
        let sets = synthesize(&s, &model, Dtype::Fp8, 3, 6);
        let dir = std::env::temp_dir().join(format!("aicfg_meas_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_sets(&dir, &sets).unwrap();
        let back = load_dir(&dir, "h100-sxm").unwrap();
        assert_eq!(back.len(), sets.len());
        for b in &back {
            let orig = sets.iter().find(|s| s.table == b.table).unwrap();
            assert_eq!(b.entries, orig.entries);
        }
        // Unknown gpu dir is a loud error.
        assert!(load_dir(&dir, "b200").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Offline profiling campaign: populate the database grids by
//! "measuring" the synthetic silicon at every grid point (paper §4.4
//! "exhaustive profiling sweeps parameters ... with framework-native
//! tools, ~30 GPU-hours per platform-framework pair").
//!
//! Each grid point takes the median of [`SAMPLES`] noisy measurements —
//! the noise is what separates the database's view of the hardware from
//! the simulator's ground truth and gives the fidelity experiments a
//! realistic error floor.

use crate::models::{AttnKind, Dtype, ModelArch};
use crate::ops::Op;
use crate::silicon::Silicon;
use crate::util::rng::Rng;

use super::query::flat;
use super::tables::{spec, TableId, GRID_LEN, NX, NY, NZ};
use super::{DbContext, PerfDatabase};

/// Noisy samples per grid point (median taken).
pub const SAMPLES: usize = 3;

/// Simulated per-measurement harness overhead, seconds: kernel-benchmark
/// warmup + timing loop + reconfiguration, as a real profiling campaign
/// pays. Feeds the Table-1 "GPU benchmarking" cost accounting.
pub const HARNESS_OVERHEAD_S: f64 = 0.05;

/// Build a full database for (silicon = hardware × framework, model).
pub fn build(silicon: &Silicon, model: &ModelArch, kv_dtype: Dtype, seed: u64) -> PerfDatabase {
    let mut grids = vec![0f32; GRID_LEN];
    let mut rng = Rng::new(seed);
    let mut sim_cost_s = 0.0;

    for id in TableId::all_active() {
        let s = spec(id);
        for ix in 0..NX {
            let xv = s.x.value(ix);
            for iy in 0..NY {
                let yv = s.y.value(iy);
                // Degenerate z-axis: compute plane once, broadcast.
                let z_planes = if s.z.hi <= s.z.lo { 1 } else { NZ };
                for iz in 0..z_planes {
                    let zv = s.z.value(iz);
                    let op = op_for_point(id, model, kv_dtype, xv, yv, zv);
                    let us = silicon.measure_median_us(&op, &mut rng, SAMPLES);
                    grids[flat(id as usize, ix, iy, iz)] = us as f32;
                    sim_cost_s += SAMPLES as f64 * (us * 1e-6 * 100.0 + HARNESS_OVERHEAD_S);
                }
                if z_planes == 1 {
                    let v = grids[flat(id as usize, ix, iy, 0)];
                    for iz in 1..NZ {
                        grids[flat(id as usize, ix, iy, iz)] = v;
                    }
                }
            }
        }
    }

    let ctx = DbContext {
        model: model.name.to_string(),
        gpu: silicon.cluster.gpu.name.to_string(),
        gpus_per_node: silicon.cluster.gpus_per_node,
        num_nodes: silicon.cluster.num_nodes,
        framework: silicon.fw.framework.name().to_string(),
        kv_dtype: kv_dtype.name().to_string(),
    };
    PerfDatabase::new(ctx, grids, silicon.cluster, sim_cost_s / 3600.0)
}

/// Profile the analytic fill as [`build`], then compose a calibration
/// artifact on top — the three-tier lookup chain (measured cell →
/// calibrated-analytic → SoL) described in [`super::calibrate`].
pub fn build_calibrated(
    silicon: &Silicon,
    model: &ModelArch,
    kv_dtype: Dtype,
    seed: u64,
    artifact: &super::calibrate::CalibrationArtifact,
) -> anyhow::Result<super::calibrate::CalibratedDb> {
    super::calibrate::CalibratedDb::compose(build(silicon, model, kv_dtype, seed), artifact)
}

/// Reconstruct the representative op for a grid point — the exact
/// inverse of [`super::tables::query_for`]'s coordinate mapping. Also
/// used by [`super::measure`] to turn measurement-file coordinates back
/// into ops, so measurements and profiling agree on op semantics.
pub(crate) fn op_for_point(
    id: TableId,
    model: &ModelArch,
    kv_dtype: Dtype,
    x: f64,
    y: f64,
    z: f64,
) -> Op {
    use TableId::*;
    match id {
        GemmFp16 | GemmFp8 | GemmInt8 | GemmInt4 => {
            let dt = match id {
                GemmFp16 => Dtype::Fp16,
                GemmFp8 => Dtype::Fp8,
                GemmInt8 => Dtype::Int8,
                _ => Dtype::Int4,
            };
            Op::Gemm {
                m: x.round().max(1.0) as u64,
                n: y.round().max(1.0) as u64,
                k: z.round().max(1.0) as u64,
                dtype: dt,
                count: 1,
            }
        }
        AttnPrefill => {
            let q = x.round().max(1.0) as u64;
            let kv = y.round().max(1.0) as u64;
            Op::AttnPrefill {
                q_tokens: q,
                kv_len: kv,
                heads: z.round().max(1.0) as u64,
                head_dim: model.head_dim,
                causal_frac: if kv <= q { 0.5 } else { 1.0 },
                count: 1,
            }
        }
        AttnDecode => {
            let heads = z.round().max(1.0) as u64;
            Op::AttnDecode {
                batch: x.round().max(1.0) as u64,
                kv_len: y.round().max(1.0) as u64,
                heads,
                head_dim: model.head_dim,
                kv_token_bytes: kv_bytes_for_heads(model, kv_dtype, heads),
                count: 1,
            }
        }
        MoeFp16 | MoeFp8 | MoeInt8 | MoeInt4 => {
            let dt = match id {
                MoeFp16 => Dtype::Fp16,
                MoeFp8 => Dtype::Fp8,
                MoeInt8 => Dtype::Int8,
                _ => Dtype::Int4,
            };
            // Profiled at the canonical FFN shape; query-time scaling
            // covers TP-sharded and model-specific expert widths.
            Op::MoeGemm {
                tokens: x.round().max(1.0) as u64,
                experts: y.round().max(1.0) as u64,
                inter: super::tables::MOE_CANON_INTER,
                hidden: super::tables::MOE_CANON_HIDDEN,
                dtype: dt,
                imbalance: z.max(1.0),
                count: 1,
            }
        }
        // Collectives run over power-of-two GPU groups in practice, and
        // the latency surface is discontinuous at the node boundary
        // (NVLink -> IB). Snapping the profiled GPU count to the nearest
        // power of two turns the grid into flat plateaus, so power-of-two
        // queries interpolate exactly instead of straddling the cliff
        // (e.g. gpus=8 blending with a cross-node gpus=9 sample).
        // Span 1 = "naturally packed": the collective cost model clamps
        // the span up to the minimum feasible value for the group
        // width, so the profiled baseline is the packed layout — the
        // one [`crate::topology::collective::placement_factor`] scales
        // placed queries off of.
        AllReduce => Op::AllReduce { bytes: x, gpus: snap_pow2(y), span: 1, rails: 1, count: 1 },
        AllGather => Op::AllGather { bytes: x, gpus: snap_pow2(y), span: 1, rails: 1, count: 1 },
        AllToAll => Op::AllToAll { bytes: x, gpus: snap_pow2(y), span: 1, rails: 1, count: 1 },
        P2p => Op::P2p { bytes: x, cross_node: y >= 0.5, count: 1 },
    }
}

/// Nearest power of two in log space (≥ 2).
fn snap_pow2(v: f64) -> u32 {
    let l = v.max(2.0).log2().round();
    (2f64.powf(l) as u32).max(2)
}

/// KV bytes per token per layer on a rank holding `heads` query heads —
/// the builder-side mirror of [`crate::ops::kv_bytes_per_gpu_layer`]
/// expressed in the table's z coordinate.
fn kv_bytes_for_heads(model: &ModelArch, kv_dtype: Dtype, heads: u64) -> f64 {
    match model.attn {
        AttnKind::Mha | AttnKind::Gqa => {
            // heads-per-gpu h implies tp = heads/h; kv heads shard with tp.
            let frac = (heads as f64 / model.heads as f64).min(1.0);
            let kv_heads = (model.kv_heads as f64 * frac).max(1.0);
            2.0 * kv_heads * model.head_dim as f64 * kv_dtype.bytes()
        }
        AttnKind::Mla { kv_lora_rank, qk_rope_dim, .. } => {
            (kv_lora_rank + qk_rope_dim) as f64 * kv_dtype.bytes()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::{h100_sxm, ClusterSpec};
    use crate::models::by_name;
    use crate::perfdb::tables::{query_for, spec};
    use crate::perfdb::LatencyOracle;

    fn sil() -> Silicon {
        Silicon::new(ClusterSpec::new(h100_sxm(), 8, 1), Framework::TrtLlm.profile())
    }

    #[test]
    fn grid_point_queries_recover_measurements() {
        let s = sil();
        let model = by_name("qwen3-235b").unwrap();
        let db = build(&s, &model, Dtype::Fp8, 7);
        // A query exactly at a grid point must return (noisy) silicon
        // within the measurement-noise envelope.
        let gs = spec(TableId::GemmFp8);
        let op = Op::Gemm {
            m: gs.x.value(10).round() as u64,
            n: gs.y.value(12).round() as u64,
            k: gs.z.value(8).round() as u64,
            dtype: Dtype::Fp8,
            count: 1,
        };
        let est = db.op_latency_us(&op);
        let truth = Silicon::op_latency_us(&s, &op);
        assert!((est - truth).abs() / truth < 0.12, "est={est} truth={truth}");
        let q = query_for(&op).unwrap();
        // Rounding the log-spaced axis value to integer m/n/k shifts the
        // recovered coordinate slightly off-grid.
        assert!((q.fx - 10.0).abs() < 0.05 && (q.fy - 12.0).abs() < 0.05);
    }

    #[test]
    fn moe_table_covers_imbalance_axis() {
        let s = sil();
        let model = by_name("qwen3-235b").unwrap();
        let db = build(&s, &model, Dtype::Fp8, 7);
        let mk = |imb: f64| Op::MoeGemm {
            tokens: 4096,
            experts: 16,
            inter: 1536,
            hidden: 4096,
            dtype: Dtype::Fp8,
            imbalance: imb,
            count: 1,
        };
        let bal = db.op_latency_us(&mk(1.0));
        let hot = db.op_latency_us(&mk(4.0));
        assert!(hot > bal * 1.5, "bal={bal} hot={hot}");
    }

    #[test]
    fn p2p_cross_node_plane() {
        let s = Silicon::new(ClusterSpec::new(h100_sxm(), 8, 2), Framework::TrtLlm.profile());
        let model = by_name("llama3.1-8b").unwrap();
        let db = build(&s, &model, Dtype::Fp16, 3);
        let nv = db.op_latency_us(&Op::P2p { bytes: 1e8, cross_node: false, count: 1 });
        let ib = db.op_latency_us(&Op::P2p { bytes: 1e8, cross_node: true, count: 1 });
        assert!(ib > nv * 3.0, "nv={nv} ib={ib}");
    }

    #[test]
    fn determinism_per_seed() {
        let s = sil();
        let model = by_name("llama3.1-8b").unwrap();
        let a = build(&s, &model, Dtype::Fp16, 11);
        let b = build(&s, &model, Dtype::Fp16, 11);
        assert_eq!(a.grids(), b.grids());
    }
}

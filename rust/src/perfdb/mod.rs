//! The calibrated operator-level performance database (paper §4.4).
//!
//! Built once per (model, cluster, framework, kv-dtype) context by
//! "profiling" the synthetic silicon over log-spaced grids
//! ([`builder`]), then answering operator queries by trilinear
//! interpolation ([`query`]) with a Speed-of-Light analytical fallback
//! ([`sol`]) for unprofiled operator classes — the same three data
//! strategies the paper lists (exhaustive profiling, interpolation,
//! SoL estimation).
//!
//! On top of the analytic fill, external kernel measurements
//! ([`measure`]) can be fitted into a correction ([`calibrate`]) —
//! [`CalibratedDb`] then answers through a three-tier chain (measured
//! cell → calibrated-analytic → SoL), tagging every query with its
//! provenance tier.
//!
//! Two query backends exist: the native Rust interpolator here (used by
//! the CLI search path and as the perf baseline) and the AOT-compiled
//! Pallas kernel executed through PJRT ([`crate::runtime`]) — identical
//! semantics, verified against each other in integration tests.

pub mod builder;
pub mod cache;
pub mod calibrate;
pub mod measure;
pub mod query;
pub mod sol;
pub mod tables;

pub use cache::{LocalMemo, MemoOracle, MemoStore};
pub use calibrate::{CalibratedDb, CalibrationArtifact, TierSnapshot};

use crate::frameworks::FrameworkProfile;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::ops::Op;
use crate::silicon::Silicon;
use crate::util::json::{self, Json};
use tables::{query_for, GRID_LEN, NUM_TABLES, NX, NY, NZ};

/// Anything that can price an operator list. Implemented by the
/// database (analytical path), by [`Silicon`] (ground truth) and by the
/// PJRT-backed evaluator.
pub trait LatencyOracle: Sync {
    /// Latency of one op *instance*, microseconds.
    fn op_latency_us(&self, op: &Op) -> f64;

    /// Per-instance latency of many ops at once — the hot-path entry
    /// point: the simulators price each decomposed step as one batch.
    /// Backends with per-query setup cost override this — the database
    /// groups queries by table and walks each packed grid slab once
    /// ([`PerfDatabase`], [`CalibratedDb`]), the PJRT-executed kernel
    /// issues a single device call, the memo layer scans hits first and
    /// forwards one inner batch of misses. The default just loops.
    /// Bit-for-bit contract: `latency_batch(ops)[i]` ==
    /// `op_latency_us(&ops[i])` for every implementation (pinned in
    /// `tests/hotpath.rs`).
    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        ops.iter().map(|o| self.op_latency_us(o)).collect()
    }

    /// Total latency of an op list (each op × its count), microseconds.
    /// Routed through [`Self::latency_batch`] so every caller of the
    /// step aggregate inherits the batched fast path; the summation
    /// order (index order) is unchanged, so the result is bit-identical
    /// to the old per-op loop.
    fn step_latency_us(&self, ops: &[Op]) -> f64 {
        self.latency_batch(ops)
            .iter()
            .zip(ops)
            .map(|(lat, o)| lat * o.count() as f64)
            .sum()
    }

    /// Cumulative per-tier query counts, for oracles that track the
    /// provenance of their answers (measured / calibrated / analytic /
    /// SoL — see [`calibrate::CalibratedDb`]). `None` for oracles with
    /// a single data source; wrappers forward to their inner oracle.
    /// Callers snapshot before/after a search and subtract
    /// ([`TierSnapshot::since`]) to attribute counts to one run.
    fn provenance_counts(&self) -> Option<TierSnapshot> {
        None
    }
}

impl LatencyOracle for Silicon {
    fn op_latency_us(&self, op: &Op) -> f64 {
        Silicon::op_latency_us(self, op)
    }

    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        Silicon::latency_batch(self, ops)
    }
}

/// Identifies what a database was profiled against.
#[derive(Clone, Debug, PartialEq)]
pub struct DbContext {
    pub model: String,
    pub gpu: String,
    pub gpus_per_node: u32,
    pub num_nodes: u32,
    pub framework: String,
    pub kv_dtype: String,
}

/// The packed, calibrated database.
#[derive(Clone)]
pub struct PerfDatabase {
    pub ctx: DbContext,
    /// Row-major [T, NX, NY, NZ] latency grid, microseconds.
    grids: Vec<f32>,
    /// Cluster used for the SoL fallback (comm topology + GPU specs).
    pub cluster: ClusterSpec,
    /// Simulated wall-clock cost of the profiling campaign, hours
    /// (paper: ~30 GPU-hours per platform-framework pair) — used by the
    /// Table 1 "GPU benchmarking" comparison.
    pub profile_cost_hours: f64,
    /// Precomputed placed/packed link-path pairs — placed collectives
    /// are factored off the packed baseline with two table lookups
    /// instead of rebuilding both paths per query. `Arc` keeps the
    /// database cheap to clone (the table is immutable and shared).
    place: std::sync::Arc<crate::topology::collective::PlacementTable>,
}

impl PerfDatabase {
    pub fn new(ctx: DbContext, grids: Vec<f32>, cluster: ClusterSpec, cost_h: f64) -> Self {
        assert_eq!(grids.len(), GRID_LEN, "grid shape contract violation");
        let place =
            std::sync::Arc::new(crate::topology::collective::PlacementTable::build(&cluster));
        PerfDatabase { ctx, grids, cluster, profile_cost_hours: cost_h, place }
    }

    /// Placement factor of an op, served from the precomputed path
    /// table (bit-identical to
    /// [`crate::topology::collective::placement_factor`]).
    pub(crate) fn place_factor(&self, op: &Op) -> f64 {
        self.place.factor(&self.cluster, op)
    }

    /// Convenience: profile a fresh database for a context.
    pub fn build(silicon: &Silicon, model: &ModelArch, kv_dtype: crate::models::Dtype, seed: u64) -> Self {
        builder::build(silicon, model, kv_dtype, seed)
    }

    /// Raw packed grid (the PJRT literal payload).
    pub fn grids(&self) -> &[f32] {
        &self.grids
    }

    /// Interpolated latency at a fractional-coordinate query.
    pub fn interp(&self, q: &tables::Query) -> f64 {
        query::trilinear(&self.grids, q.table as usize, q.fx, q.fy, q.fz)
    }

    // --- Persistence ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut ctx = Json::obj();
        ctx.set("model", json::s(&self.ctx.model))
            .set("gpu", json::s(&self.ctx.gpu))
            .set("gpus_per_node", json::num(self.ctx.gpus_per_node as f64))
            .set("num_nodes", json::num(self.ctx.num_nodes as f64))
            .set("framework", json::s(&self.ctx.framework))
            .set("kv_dtype", json::s(&self.ctx.kv_dtype));
        let mut o = Json::obj();
        o.set("version", json::num(1.0))
            .set("ctx", ctx)
            .set("shape", json::farr(&[NUM_TABLES as f64, NX as f64, NY as f64, NZ as f64]))
            .set("profile_cost_hours", json::num(self.profile_cost_hours))
            .set(
                "grids",
                Json::Arr(self.grids.iter().map(|v| Json::Num(*v as f64)).collect()),
            );
        o
    }

    pub fn from_json(j: &Json, cluster: ClusterSpec) -> anyhow::Result<Self> {
        let shape = j.req("shape")?.as_arr().ok_or_else(|| anyhow::anyhow!("bad shape"))?;
        let dims: Vec<u64> = shape.iter().filter_map(|x| x.as_u64()).collect();
        anyhow::ensure!(
            dims == [NUM_TABLES as u64, NX as u64, NY as u64, NZ as u64],
            "database grid shape {dims:?} does not match the compiled contract"
        );
        let cj = j.req("ctx")?;
        let ctx = DbContext {
            model: cj.req_str("model")?.to_string(),
            gpu: cj.req_str("gpu")?.to_string(),
            gpus_per_node: cj.req_f64("gpus_per_node")? as u32,
            num_nodes: cj.req_f64("num_nodes")? as u32,
            framework: cj.req_str("framework")?.to_string(),
            kv_dtype: cj.req_str("kv_dtype")?.to_string(),
        };
        let grids: Vec<f32> = j
            .req("grids")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("grids not an array"))?
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0) as f32)
            .collect();
        anyhow::ensure!(grids.len() == GRID_LEN, "grid length {}", grids.len());
        Ok(PerfDatabase::new(ctx, grids, cluster, j.f64_or("profile_cost_hours", 0.0)))
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path, cluster: ClusterSpec) -> anyhow::Result<Self> {
        let txt = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&txt)?, cluster)
    }
}

impl LatencyOracle for PerfDatabase {
    fn op_latency_us(&self, op: &Op) -> f64 {
        match query_for(op) {
            // The profiled comm tables hold the naturally packed
            // layout; a placed collective scales that baseline by the
            // analytic placement factor (1.0 on legacy fabrics and for
            // packed/non-collective ops), so the database prices
            // placements without re-profiling per layout.
            Some(q) => self.interp(&q) * q.scale * self.place_factor(op),
            None => sol::latency_us(&self.cluster, op),
        }
    }

    /// Slab-batched interpolation: queries are bucketed by table and
    /// each bucket walks its `[NX, NY, NZ]` slab through one slice —
    /// the per-point table-offset arithmetic and bounds re-check of
    /// [`query::trilinear`] drop out of the inner loop. Unprofiled ops
    /// take the SoL fallback inline. Bit-identical to the per-op path.
    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        let mut out = vec![0.0; ops.len()];
        let mut buckets: Vec<Vec<(usize, tables::Query)>> = vec![Vec::new(); NUM_TABLES];
        for (i, op) in ops.iter().enumerate() {
            match query_for(op) {
                Some(q) => buckets[q.table as usize].push((i, q)),
                None => out[i] = sol::latency_us(&self.cluster, op),
            }
        }
        const SLAB: usize = NX * NY * NZ;
        for (t, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let slab = &self.grids[t * SLAB..(t + 1) * SLAB];
            for &(i, q) in bucket {
                out[i] = query::trilinear_in_slab(slab, q.fx, q.fy, q.fz)
                    * q.scale
                    * self.place_factor(&ops[i]);
            }
        }
        out
    }
}

/// Framework host-scheduling overhead is *not* an operator — the
/// serving-mode models add it per iteration. Re-exported here so the
/// analytical path and the simulator use the same constant source.
pub fn host_overhead_us(fw: &FrameworkProfile, cuda_graph: bool, decode_only: bool) -> f64 {
    fw.iter_host_overhead_us(cuda_graph, decode_only)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::{by_name, Dtype};

    fn db() -> PerfDatabase {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        PerfDatabase::build(&sil, &by_name("qwen3-32b").unwrap(), Dtype::Fp16, 42)
    }

    #[test]
    fn db_approximates_silicon_on_grid_and_off_grid() {
        let d = db();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        // Off-grid GEMM: interpolation should be within ~20%.
        for (m, n, k) in [(100u64, 5120u64, 5120u64), (3000, 10240, 5120), (7, 4096, 12288)] {
            let op = Op::Gemm { m, n, k, dtype: Dtype::Fp16, count: 1 };
            let truth = LatencyOracle::op_latency_us(&sil, &op);
            let est = d.op_latency_us(&op);
            let err = (est - truth).abs() / truth;
            assert!(err < 0.25, "gemm {m}x{n}x{k}: est={est:.1} truth={truth:.1} err={err:.2}");
        }
    }

    #[test]
    fn sol_fallback_for_elementwise() {
        let d = db();
        let op = Op::Elementwise { bytes: 1e8, count: 1 };
        let t = d.op_latency_us(&op);
        assert!(t > 0.0 && t < 1e5);
    }

    #[test]
    fn json_roundtrip() {
        let d = db();
        let j = d.to_json();
        let back = PerfDatabase::from_json(&j, d.cluster).unwrap();
        assert_eq!(back.ctx, d.ctx);
        let op = Op::Gemm { m: 1000, n: 8192, k: 4096, dtype: Dtype::Fp16, count: 1 };
        let a = d.op_latency_us(&op);
        let b = back.op_latency_us(&op);
        assert!((a - b).abs() / a < 1e-4);
    }

    #[test]
    fn profiling_cost_in_paper_ballpark() {
        let d = db();
        // Paper: ~30 GPU-hours per platform-framework pair.
        assert!(
            d.profile_cost_hours > 3.0 && d.profile_cost_hours < 100.0,
            "cost {} h",
            d.profile_cost_hours
        );
    }
}

//! Speed-of-Light analytical fallback (paper §4.4 "Speed-of-Light
//! estimation provides analytical bounds via roofline models for
//! unprofiled operators").
//!
//! Pure roofline — no framework efficiency, no quantization effects —
//! which is exactly why profiled tables are preferred when available.
//! In the calibrated lookup chain ([`super::calibrate::CalibratedDb`])
//! this is the last tier: measured cell → calibrated-analytic → SoL.

use crate::hardware::ClusterSpec;
use crate::models::Dtype;
use crate::ops::Op;

/// Roofline latency bound for any op, microseconds.
pub fn latency_us(cluster: &ClusterSpec, op: &Op) -> f64 {
    // Tiered fabrics bound collectives over the placement's link path
    // (latency-free ideal links, min over algorithms); legacy fabrics
    // keep the seed's flat roofline below, bit-for-bit.
    if let Some(bound) = crate::topology::collective::sol_bound_us(cluster, op) {
        return bound;
    }
    let gpu = &cluster.gpu;
    let bw = gpu.mem_bw_gbs * 1e3; // bytes/us
    match *op {
        Op::Elementwise { bytes, .. } => bytes / bw + gpu.launch_us,
        Op::Gemm { m, n, k, dtype, .. } => {
            let flops = 2.0 * m as f64 * n as f64 * k as f64;
            let t_c = flops / (gpu.tflops(dtype) * 1e12) * 1e6;
            let bytes = n as f64 * k as f64 * dtype.bytes() + (m * (n + k)) as f64 * 2.0;
            t_c.max(bytes / bw) + gpu.launch_us
        }
        Op::AttnPrefill { q_tokens, kv_len, heads, head_dim, causal_frac, .. } => {
            let flops =
                4.0 * heads as f64 * q_tokens as f64 * kv_len as f64 * head_dim as f64 * causal_frac;
            flops / (gpu.tflops(Dtype::Fp16) * 1e12) * 1e6 + gpu.launch_us
        }
        Op::AttnDecode { batch, kv_len, kv_token_bytes, .. } => {
            batch as f64 * kv_len as f64 * kv_token_bytes / bw + gpu.launch_us
        }
        Op::MoeGemm { tokens, inter, hidden, dtype, .. } => {
            let flops = 2.0 * 3.0 * tokens as f64 * inter as f64 * hidden as f64;
            flops / (gpu.tflops(dtype) * 1e12) * 1e6 + gpu.launch_us
        }
        Op::AllReduce { bytes, gpus, .. } => {
            if gpus <= 1 {
                0.0
            } else {
                let g = gpus as f64;
                2.0 * (g - 1.0) / g * bytes / (cluster.p2p_bw_gbs(cluster.link_for(gpus)) * 1e3)
            }
        }
        Op::AllGather { bytes, gpus, .. } | Op::AllToAll { bytes, gpus, .. } => {
            if gpus <= 1 {
                0.0
            } else {
                bytes / (cluster.p2p_bw_gbs(cluster.link_for(gpus)) * 1e3)
            }
        }
        Op::P2p { bytes, cross_node, .. } => {
            let link = if cross_node {
                crate::hardware::LinkKind::InfiniBand
            } else {
                crate::hardware::LinkKind::NvLink
            };
            bytes / (cluster.p2p_bw_gbs(link) * 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{h100_sxm, ClusterSpec};

    #[test]
    fn sol_is_lower_bound_of_silicon() {
        use crate::frameworks::Framework;
        use crate::silicon::Silicon;
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(c, Framework::TrtLlm.profile());
        for op in [
            Op::Gemm { m: 4096, n: 8192, k: 8192, dtype: Dtype::Fp16, count: 1 },
            Op::AttnDecode { batch: 64, kv_len: 4096, heads: 32, head_dim: 128, kv_token_bytes: 4096.0, count: 1 },
            Op::AllReduce { bytes: 1e7, gpus: 8, span: 1, rails: 1, count: 1 },
        ] {
            let sol = latency_us(&c, &op);
            let real = sil.op_latency_us(&op);
            assert!(sol <= real * 1.01, "{op:?}: sol={sol} real={real}");
        }
    }

    #[test]
    fn elementwise_bandwidth() {
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let t = latency_us(&c, &Op::Elementwise { bytes: 3.35e9, count: 1 });
        // 3.35 GB at 3350 GB/s ≈ 1 ms.
        assert!((t - 1000.0 - c.gpu.launch_us).abs() < 1.0, "t={t}");
    }
}

//! TaskRunner: evaluate every candidate configuration against the
//! workload (paper §4.1 step 3, "InferenceSession will iterate over all
//! the candidate serving configurations"), in parallel across OS threads.
//!
//! The evaluation engine prices aggregated, prefill-pool and decode-pool
//! candidates from **one unified job queue** drained by the shared
//! atomic-cursor worker pool ([`crate::util::pool`]). Disaggregated pool
//! pricing costs far more per job than an aggregated estimate, so the
//! seed's static chunking (kept as [`TaskRunner::run_baseline`] for the
//! `table1_search` bench) load-balances poorly; the shared queue keeps
//! every worker busy until the queue drains.
//!
//! Three further engine features ride on the same plumbing:
//! * **incremental pruning** ([`RunOptions::prune`]): SLA-infeasible and
//!   strictly-dominated candidates are discarded at the deterministic
//!   assembly step, against a [`crate::pareto::FrontierAccumulator`]
//!   built from the priced outcomes in queue order;
//! * **batch sweeps** ([`TaskRunner::run_sweep`]): many (ISL, OSL, SLA)
//!   scenarios priced in one pass, sharing the structural engine grid and
//!   a memoized oracle ([`crate::perfdb::MemoOracle`]);
//! * **differential replan** ([`TaskRunner::replan`]): re-price only the
//!   jobs whose op-tag mask a [`crate::search::SearchDelta`] invalidates,
//!   splice them into a retained [`RunArena`], and re-run the same
//!   assembly — bit-identical to a cold re-search by construction.
//!
//! The hot path is contention-free by construction: candidates come from
//! SoA [`CandidateGrid`]s (no per-candidate heap objects), workers grab
//! dense index slabs from the shared cursor ([`pool::scoped_map_states`]),
//! and each worker prices through a thread-local
//! [`crate::perfdb::LocalMemo`] (zero shared write-lock traffic) absorbed
//! in worker-id order at join, so results are independent of thread
//! interleaving.

use std::time::Instant;

use crate::config::{Candidate, EngineConfig, RuntimeFlags, ServingMode, WorkloadSpec};
use crate::frameworks::Framework;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::pareto::FrontierAccumulator;
use crate::perfdb::{LatencyOracle, LocalMemo, MemoOracle, TierSnapshot};
use crate::perfmodel::{self, disagg, PerfEstimate};
use crate::trace;
use crate::util::pool;

use super::space::{CandidateGrid, SearchSpace, StructuralPoint};

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Evaluated {
    pub cand: Candidate,
    pub est: PerfEstimate,
}

/// Resolved-vs-default launch-flag outcome for one framework across a
/// report's surviving candidates (the backend abstraction layer's
/// observable win: how far the analytic resolver moved the flags off
/// the one-size defaults).
#[derive(Clone, Debug)]
pub struct FlagSummary {
    pub framework: Framework,
    /// The framework's stock flags (what a resolver-less search would
    /// have pinned everywhere).
    pub defaults: RuntimeFlags,
    /// Range of resolved `kv_frac` across candidates.
    pub kv_frac_min: f64,
    pub kv_frac_max: f64,
    /// Range of resolved `max_num_tokens` across candidates.
    pub mnt_min: u32,
    pub mnt_max: u32,
    /// Engines carrying non-default flags / engines total.
    pub nondefault: usize,
    pub total: usize,
}

impl FlagSummary {
    /// One human-readable delta line for CLIs and logs.
    pub fn describe(&self) -> String {
        format!(
            "{}: kv_frac {:.2}-{:.2} (default {:.2}), max_num_tokens {}-{} (default {}); {}/{} engines off-default",
            self.framework.name(),
            self.kv_frac_min,
            self.kv_frac_max,
            self.defaults.kv_frac,
            self.mnt_min,
            self.mnt_max,
            self.defaults.max_num_tokens,
            self.nondefault,
            self.total,
        )
    }
}

/// Per-framework flag summaries over a set of evaluated candidates
/// (disaggregated composites contribute both pool engines).
pub fn flag_summaries(evaluated: &[Evaluated]) -> Vec<FlagSummary> {
    fn offer(out: &mut Vec<FlagSummary>, eng: &EngineConfig) {
        let defaults = RuntimeFlags::defaults_for(eng.framework);
        let idx = match out.iter().position(|s| s.framework == eng.framework) {
            Some(i) => i,
            None => {
                out.push(FlagSummary {
                    framework: eng.framework,
                    defaults,
                    kv_frac_min: f64::INFINITY,
                    kv_frac_max: f64::NEG_INFINITY,
                    mnt_min: u32::MAX,
                    mnt_max: 0,
                    nondefault: 0,
                    total: 0,
                });
                out.len() - 1
            }
        };
        let s = &mut out[idx];
        s.kv_frac_min = s.kv_frac_min.min(eng.flags.kv_frac);
        s.kv_frac_max = s.kv_frac_max.max(eng.flags.kv_frac);
        s.mnt_min = s.mnt_min.min(eng.flags.max_num_tokens);
        s.mnt_max = s.mnt_max.max(eng.flags.max_num_tokens);
        s.total += 1;
        if eng.flags != defaults {
            s.nondefault += 1;
        }
    }
    let mut out: Vec<FlagSummary> = Vec::new();
    for e in evaluated {
        match &e.cand {
            Candidate::Aggregated { engine, .. } => offer(&mut out, engine),
            Candidate::Disaggregated { prefill, decode, .. } => {
                offer(&mut out, prefill);
                offer(&mut out, decode);
            }
        }
    }
    out
}

/// Outcome of a full search.
#[derive(Clone, Debug)]
pub struct SearchReport {
    pub evaluated: Vec<Evaluated>,
    /// Engine-level configurations priced (the paper's "configs" count).
    pub configs_priced: usize,
    /// Candidates discarded by incremental SLA/Pareto pruning (0 when
    /// pruning is off).
    pub pruned: usize,
    /// Of `pruned`: aggregated candidates dropped for missing the SLA.
    pub pruned_sla: usize,
    /// Of `pruned`: candidates dropped as strictly dominated (includes
    /// the disaggregated composites the rate-match accumulator
    /// rejected).
    pub pruned_dominated: usize,
    /// Structural engine configurations discarded by the KV-memory
    /// feasibility filter before pricing (0 on the seed baseline path,
    /// which filters inside the enumeration).
    pub infeasible: usize,
    /// Wall-clock of the whole search, seconds.
    pub elapsed_s: f64,
    /// Median per-configuration evaluation time, milliseconds.
    pub median_config_ms: f64,
    /// Per-framework resolved-vs-default flag deltas over `evaluated`.
    pub flag_summaries: Vec<FlagSummary>,
    /// Per-tier oracle query counts for this run (measured / calibrated
    /// / analytic / SoL), when the oracle tracks provenance
    /// ([`crate::perfdb::CalibratedDb`]); `None` for single-source
    /// oracles. Under a memoized sweep these are unique-shape counts.
    pub tier_counts: Option<TierSnapshot>,
}

/// Knobs for one search run.
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Discard SLA-infeasible and strictly-dominated candidates during
    /// the sweep (instead of carrying them to the analyzer). The
    /// feasible frontier and the throughput argmax are preserved
    /// exactly; every strictly-dominated interior point is dropped, and
    /// the survivor set is scheduling-independent (exact duplicates of
    /// a frontier point all survive — strict dominance can't tell them
    /// apart, so the outcome never depends on evaluation order).
    pub prune: bool,
}

/// The candidate pools one scenario evaluates: two SoA grids plus
/// memory-fitting candidate indices into them. Aggregated and decode
/// pools share `grid` (and, mode permitting, the same filtered index
/// list); the prefill pool has its own small-batch grid.
struct EnginePools {
    grid: CandidateGrid,
    pre_grid: CandidateGrid,
    agg: Vec<u32>,
    prefill: Vec<u32>,
    decode: Vec<u32>,
    /// Grid entries the KV-memory filter rejected (pruning-audit input).
    infeasible: usize,
}

/// A unit of work in the unified queue.
#[derive(Clone, Copy)]
enum Job {
    Agg(usize),
    Pre(usize),
    Dec(usize),
}

/// Per-worker pricing context, built once per worker at spawn and
/// merged (in worker-id order) at join: a thread-local memo front
/// absorbed into the shared [`crate::perfdb::MemoStore`] when the
/// worker finishes. Pruning needs no per-worker state — the dominance
/// frontier is rebuilt deterministically from the priced outcomes in
/// queue order at assembly (see [`TaskRunner::assemble`]).
struct WorkerCtx<'m> {
    memo: Option<LocalMemo<'m>>,
}

/// Queue-cursor grab size for candidate pricing: consecutive jobs are
/// the same kind (the queue is agg… pre… dec…), so a small chunk keeps
/// load balance across heterogeneous job costs while cutting shared-
/// cursor cacheline traffic by the chunk factor.
const PRICE_CHUNK: usize = 4;

/// Result of one job (returned through the worker pool in queue order).
/// `Clone` so a [`RunArena`] can retain the priced outcomes for
/// differential replans while handing assembly a borrowed view.
#[derive(Clone)]
enum JobOut {
    Agg(Evaluated),
    Pre(disagg::PoolPrice),
    Dec(disagg::PoolPrice),
}

/// Retained state of one priced sweep, the substrate for differential
/// replanning: the scenario and options it was priced under, the
/// candidate pools, the unified job queue, each job's most recent
/// (outcome, pricing-ms), and each job's conservative op-tag mask
/// ([`super::delta::engine_tag_mask`]). Fields are private on purpose:
/// arenas are only produced by [`TaskRunner::run_cached_arena`] and
/// mutated by [`TaskRunner::replan`], which together maintain the
/// queue/outcome alignment invariant the bit-equality pin rests on.
pub struct RunArena {
    wl: WorkloadSpec,
    opts: RunOptions,
    pools: EnginePools,
    jobs: Vec<Job>,
    outs: Vec<(JobOut, f64)>,
    tags: Vec<u64>,
}

impl RunArena {
    /// Number of retained priced jobs (aggregated + prefill + decode).
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Indices of jobs whose conservative tag mask intersects `mask` —
    /// exactly the set a [`TaskRunner::replan`] with that mask
    /// re-prices.
    pub fn invalidated(&self, mask: u64) -> Vec<usize> {
        (0..self.jobs.len()).filter(|&j| self.tags[j] & mask != 0).collect()
    }
}

/// Drives the search for one workload on one cluster.
pub struct TaskRunner<'a> {
    pub model: &'a ModelArch,
    pub cluster: &'a ClusterSpec,
    pub space: SearchSpace,
    pub workload: WorkloadSpec,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
}

impl<'a> TaskRunner<'a> {
    pub fn new(
        model: &'a ModelArch,
        cluster: &'a ClusterSpec,
        space: SearchSpace,
        workload: WorkloadSpec,
    ) -> Self {
        TaskRunner { model, cluster, space, workload, threads: 0 }
    }

    fn thread_count(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }

    /// Enumerate the candidate pools for one scenario from scratch
    /// (launch flags resolved against this scenario's workload —
    /// per-scenario, not frozen at grid build).
    fn pools_for(&self, wl: &WorkloadSpec) -> EnginePools {
        let agg_mode = self.space.modes.contains(&ServingMode::Aggregated);
        let disagg_mode = self.space.modes.contains(&ServingMode::Disaggregated);
        let structural = if agg_mode || disagg_mode {
            self.space.structural_grid(self.model, self.cluster)
        } else {
            Vec::new()
        };
        let pre_space = self.space.prefill_space();
        let pre_structural = if disagg_mode {
            pre_space.structural_grid(self.model, self.cluster)
        } else {
            Vec::new()
        };
        self.pools_from(&structural, &pre_space, &pre_structural, wl)
    }

    /// Expand shared structural grids into one scenario's pools: SoA
    /// candidate grids (flags resolved against this scenario) plus
    /// memory-fitting index lists. Aggregated and decode pools are the
    /// same memory-filtered list — filter once, share the indices.
    fn pools_from(
        &self,
        structural: &[StructuralPoint],
        pre_space: &SearchSpace,
        pre_structural: &[StructuralPoint],
        wl: &WorkloadSpec,
    ) -> EnginePools {
        let sp = trace::span("grid_build", "search");
        let agg_mode = self.space.modes.contains(&ServingMode::Aggregated);
        let disagg_mode = self.space.modes.contains(&ServingMode::Disaggregated);
        let mem = self.cluster.gpu.mem_bytes();
        let grid = self.space.candidate_grid(structural, self.model, self.cluster, wl);
        let pre_grid = pre_space.candidate_grid(pre_structural, self.model, self.cluster, wl);
        let fits = |g: &CandidateGrid, i: usize, osl: u32| {
            perfmodel::memory::fits(self.model, mem, &g.get(i), wl.isl, osl)
        };
        let shared: Vec<u32> =
            (0..grid.len()).filter(|&i| fits(&grid, i, wl.osl)).map(|i| i as u32).collect();
        let prefill: Vec<u32> = if disagg_mode {
            (0..pre_grid.len()).filter(|&i| fits(&pre_grid, i, 1)).map(|i| i as u32).collect()
        } else {
            Vec::new()
        };
        let infeasible = (grid.len() - shared.len())
            + if disagg_mode { pre_grid.len() - prefill.len() } else { 0 };
        sp.add("engines", (grid.len() + pre_grid.len()) as f64);
        sp.add("infeasible", infeasible as f64);
        EnginePools {
            agg: if agg_mode { shared.clone() } else { Vec::new() },
            decode: if disagg_mode { shared } else { Vec::new() },
            prefill,
            grid,
            pre_grid,
            infeasible,
        }
    }

    /// Evaluate the full space. The oracle is typically a
    /// [`crate::perfdb::PerfDatabase`]; passing the silicon instead gives
    /// the zero-interpolation-error upper bound used in ablations.
    pub fn run(&self, oracle: &dyn LatencyOracle) -> SearchReport {
        self.run_with(oracle, &RunOptions::default())
    }

    /// [`TaskRunner::run`] with incremental SLA/Pareto pruning against
    /// the workload's SLA.
    pub fn run_pruned(&self, oracle: &dyn LatencyOracle) -> SearchReport {
        self.run_with(oracle, &RunOptions { prune: true })
    }

    /// Evaluate the full space with explicit options.
    pub fn run_with(&self, oracle: &dyn LatencyOracle, opts: &RunOptions) -> SearchReport {
        let wl = self.workload.clone();
        let pools = self.pools_for(&wl);
        self.run_inner(oracle, None, &wl, &pools, opts)
    }

    /// Single-workload run against a **caller-owned** memo (the CLI's
    /// search path): every worker prices through a thread-local
    /// [`LocalMemo`] front on the shared store, so repeated searches
    /// against the same memo skip straight to cache hits. Latencies —
    /// and hence reports — are bit-identical to [`Self::run_with`] on
    /// the memo's inner oracle (pinned in `tests/hotpath.rs`).
    pub fn run_cached(&self, memo: &MemoOracle<'_>, opts: &RunOptions) -> SearchReport {
        let wl = self.workload.clone();
        let pools = self.pools_for(&wl);
        self.run_inner(memo, Some(memo), &wl, &pools, opts)
    }

    /// [`Self::run_cached`] that additionally retains the priced sweep
    /// as a [`RunArena`] for later differential replans. The report is
    /// identical to [`Self::run_cached`] — same pricing, same assembly;
    /// the arena just keeps the outcomes instead of dropping them.
    pub fn run_cached_arena(
        &self,
        memo: &MemoOracle<'_>,
        opts: &RunOptions,
    ) -> (SearchReport, RunArena) {
        let t0 = Instant::now();
        let tiers_before = memo.provenance_counts();
        let wl = self.workload.clone();
        let pools = self.pools_for(&wl);
        let jobs = Self::jobs_for(&pools);
        let outs = self.price_all(memo, Some(memo), &wl, &pools, &jobs);
        let tags: Vec<u64> = jobs
            .iter()
            .map(|job| match *job {
                Job::Agg(i) => super::delta::engine_tag_mask(
                    self.model,
                    &pools.grid.get(pools.agg[i] as usize),
                ),
                // Prefill/decode pool prices feed disaggregated
                // composites, whose KV transfer always rides P2P.
                Job::Pre(i) => {
                    super::delta::engine_tag_mask(
                        self.model,
                        &pools.pre_grid.get(pools.prefill[i] as usize),
                    ) | super::delta::tag_bit(crate::perfdb::cache::TAG_P2P)
                }
                Job::Dec(i) => {
                    super::delta::engine_tag_mask(
                        self.model,
                        &pools.grid.get(pools.decode[i] as usize),
                    ) | super::delta::tag_bit(crate::perfdb::cache::TAG_P2P)
                }
            })
            .collect();
        let report = self.assemble(memo, &wl, &pools, opts, &outs, jobs.len(), t0, tiers_before);
        (report, RunArena { wl, opts: opts.clone(), pools, jobs, outs, tags })
    }

    /// Differential re-search: drop the memo entries for the
    /// invalidated op classes, re-price ONLY the jobs whose conservative
    /// tag mask intersects `mask`, splice the fresh outcomes into the
    /// arena, and re-run the shared deterministic assembly. The result
    /// is bit-identical (modulo the wall-clock fields `elapsed_s` and
    /// `median_config_ms`) to a from-scratch [`Self::run_cached`]
    /// against the same changed oracle — pinned in `tests/replan.rs` —
    /// while `configs_priced` counts only the re-priced jobs.
    ///
    /// Correctness leans on the tag masks being *conservative*: every
    /// job whose estimate could consult an invalidated op class is
    /// re-priced. Jobs outside the mask keep their retained outcomes,
    /// which match what a cold run would produce because pricing is
    /// deterministic and their memo entries survive
    /// [`crate::perfdb::MemoStore::invalidate_tags`] bit-identically.
    pub fn replan(&self, arena: &mut RunArena, memo: &MemoOracle<'_>, mask: u64) -> SearchReport {
        let t0 = Instant::now();
        let tiers_before = memo.provenance_counts();
        memo.invalidate_tags(mask);
        let stale = arena.invalidated(mask);
        if !stale.is_empty() {
            let jobs: Vec<Job> = stale.iter().map(|&j| arena.jobs[j]).collect();
            let fresh = self.price_all(memo, Some(memo), &arena.wl, &arena.pools, &jobs);
            for (&j, out) in stale.iter().zip(fresh) {
                arena.outs[j] = out;
            }
        }
        self.assemble(
            memo,
            &arena.wl,
            &arena.pools,
            &arena.opts,
            &arena.outs,
            stale.len(),
            t0,
            tiers_before,
        )
    }

    /// Price many workload scenarios in one pass, sharing the structural
    /// engine enumeration (grid built once, memory-filtered per
    /// scenario) and memoizing oracle queries across the whole sweep.
    /// Produces exactly the same reports as N independent [`Self::run`]
    /// calls on the same scenarios (regression-tested), only faster.
    pub fn run_sweep(
        &self,
        oracle: &dyn LatencyOracle,
        scenarios: &[WorkloadSpec],
    ) -> Vec<SearchReport> {
        self.run_sweep_with(oracle, scenarios, &RunOptions::default())
    }

    /// [`Self::run_sweep`] with explicit options (pruning applies per
    /// scenario, against each scenario's own SLA).
    pub fn run_sweep_with(
        &self,
        oracle: &dyn LatencyOracle,
        scenarios: &[WorkloadSpec],
        opts: &RunOptions,
    ) -> Vec<SearchReport> {
        let memo = MemoOracle::new(oracle);
        self.run_sweep_cached(&memo, scenarios, opts)
    }

    /// [`Self::run_sweep_with`] against a **caller-owned** memo, so
    /// several sweeps can share one warm cache: the capacity planner
    /// ([`crate::planner`]) prices every traffic window of every fleet
    /// leg through the leg's memo, and callers that hold their memos
    /// across plans (`planner::plan_cached`; the memo-warm half of
    /// `benches/planner.rs`) skip straight to cache hits. Results are
    /// identical to [`Self::run_sweep_with`] — the memo returns
    /// bit-identical latencies (regression-tested).
    pub fn run_sweep_cached(
        &self,
        memo: &MemoOracle<'_>,
        scenarios: &[WorkloadSpec],
        opts: &RunOptions,
    ) -> Vec<SearchReport> {
        let agg_mode = self.space.modes.contains(&ServingMode::Aggregated);
        let disagg_mode = self.space.modes.contains(&ServingMode::Disaggregated);
        // Workload-independent structural grids, enumerated once; the
        // backend flag resolver then expands them per scenario, so
        // flags track each scenario's ISL/SLA instead of being frozen
        // at grid build.
        let structural = if agg_mode || disagg_mode {
            self.space.structural_grid(self.model, self.cluster)
        } else {
            Vec::new()
        };
        let pre_space = self.space.prefill_space();
        let pre_structural = if disagg_mode {
            pre_space.structural_grid(self.model, self.cluster)
        } else {
            Vec::new()
        };
        scenarios
            .iter()
            .map(|wl| {
                let pools = self.pools_from(&structural, &pre_space, &pre_structural, wl);
                self.run_inner(memo, Some(memo), wl, &pools, opts)
            })
            .collect()
    }

    /// The engine core: one unified job queue over all candidate kinds,
    /// drained in dense chunks by the shared worker pool (each worker
    /// carrying a [`WorkerCtx`]), then deterministic assembly
    /// (aggregated candidates in engine order, disaggregated composites
    /// in rate-match order — the same order the seed produced).
    fn run_inner(
        &self,
        oracle: &dyn LatencyOracle,
        memo: Option<&MemoOracle<'_>>,
        wl: &WorkloadSpec,
        pools: &EnginePools,
        opts: &RunOptions,
    ) -> SearchReport {
        let t0 = Instant::now();
        let tiers_before = oracle.provenance_counts();
        let jobs = Self::jobs_for(pools);
        let outcomes = self.price_all(oracle, memo, wl, pools, &jobs);
        self.assemble(oracle, wl, pools, opts, &outcomes, jobs.len(), t0, tiers_before)
    }

    /// The unified job queue for one scenario's pools, in the pinned
    /// agg… pre… dec… order every assembly and replan relies on.
    fn jobs_for(pools: &EnginePools) -> Vec<Job> {
        let mut jobs: Vec<Job> =
            Vec::with_capacity(pools.agg.len() + pools.prefill.len() + pools.decode.len());
        jobs.extend((0..pools.agg.len()).map(Job::Agg));
        jobs.extend((0..pools.prefill.len()).map(Job::Pre));
        jobs.extend((0..pools.decode.len()).map(Job::Dec));
        jobs
    }

    /// Price one job against `o`. Shared verbatim between the pooled
    /// sweep ([`Self::price_all`]) and the differential replan path, so
    /// a re-priced outcome is bit-identical to a cold one whenever the
    /// oracle returns the same latencies.
    fn price_job(
        &self,
        o: &dyn LatencyOracle,
        wl: &WorkloadSpec,
        pools: &EnginePools,
        job: Job,
    ) -> JobOut {
        match job {
            Job::Agg(i) => {
                let eng = pools.grid.get(pools.agg[i] as usize);
                let replicas = (self.cluster.total_gpus() / eng.parallel.gpus()).max(1);
                let cand = Candidate::Aggregated { engine: eng, replicas };
                let est = perfmodel::estimate(o, self.model, self.cluster, &cand, wl);
                JobOut::Agg(Evaluated { cand, est })
            }
            Job::Pre(i) => JobOut::Pre(disagg::price_prefill(
                o,
                self.model,
                self.cluster,
                &pools.pre_grid.get(pools.prefill[i] as usize),
                wl,
            )),
            Job::Dec(i) => JobOut::Dec(disagg::price_decode(
                o,
                self.model,
                self.cluster,
                &pools.grid.get(pools.decode[i] as usize),
                wl,
            )),
        }
    }

    /// Drain `jobs` through the shared worker pool. When `memo` is set,
    /// workers price through thread-local [`LocalMemo`] fronts absorbed
    /// into the shared store in worker-id order at join. Returns each
    /// job's (outcome, pricing-ms) in queue order.
    fn price_all(
        &self,
        oracle: &dyn LatencyOracle,
        memo: Option<&MemoOracle<'_>>,
        wl: &WorkloadSpec,
        pools: &EnginePools,
        jobs: &[Job],
    ) -> Vec<(JobOut, f64)> {
        let sp = trace::span("price", "price");
        sp.add("jobs", jobs.len() as f64);
        // Capture the ambient recorder (if any) so spawned workers join
        // it; `install_worker` is a no-op on the threads<=1 fast path,
        // where `init` runs on this already-recording thread.
        let rec = trace::current();
        let (outcomes, states): (Vec<(JobOut, f64)>, Vec<WorkerCtx<'_>>) =
            pool::scoped_map_states(
                jobs,
                self.threads,
                PRICE_CHUNK,
                |wid| {
                    if let Some(r) = &rec {
                        trace::install_worker(r, wid);
                    }
                    WorkerCtx { memo: memo.map(|m| m.local()) }
                },
                |ctx, _idx, job| {
                    let o: &dyn LatencyOracle = match &ctx.memo {
                        Some(lm) => lm,
                        None => oracle,
                    };
                    let t = Instant::now();
                    let out = self.price_job(o, wl, pools, *job);
                    (out, t.elapsed().as_secs_f64() * 1e3)
                },
            );
        for st in states {
            if let Some(lm) = st.memo {
                lm.merge();
            }
        }
        outcomes
    }

    /// Deterministic assembly: rebuild the pruning frontier from the
    /// priced outcomes in queue order, filter aggregated survivors,
    /// rate-match disaggregated composites, and produce the report. A
    /// pure function of (outcomes, options) — shared verbatim by cold
    /// runs and differential replans, which is what pins a replan
    /// bit-identical to a from-scratch re-search.
    ///
    /// Rebuilding the frontier here (rather than merging per-worker
    /// accumulators at join, as earlier revisions did) is semantics-
    /// preserving: a weak-dominance offer stream converges to the
    /// maximal distinct value set regardless of offer order, and the
    /// strict-dominance `dominated()` filter below depends only on that
    /// value set — so the survivor set is identical and, as before,
    /// independent of which worker priced what.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        &self,
        oracle: &dyn LatencyOracle,
        wl: &WorkloadSpec,
        pools: &EnginePools,
        opts: &RunOptions,
        outcomes: &[(JobOut, f64)],
        configs_priced: usize,
        t0: Instant,
        tiers_before: Option<TierSnapshot>,
    ) -> SearchReport {
        let sp = trace::span("frontier_merge", "search");
        let total_gpus = self.cluster.total_gpus();
        let mut merged = FrontierAccumulator::new();
        if opts.prune {
            for (out, _) in outcomes {
                if let JobOut::Agg(ev) = out {
                    if ev.est.meets(&wl.sla) {
                        merged.offer_est(&ev.est);
                    }
                }
            }
        }

        // ---- Deterministic assembly (queue order == input order). ------
        let mut evaluated: Vec<Evaluated> = Vec::new();
        let mut per_config_ms: Vec<f64> = Vec::with_capacity(outcomes.len());
        let mut p_prices: Vec<disagg::PoolPrice> = Vec::with_capacity(pools.prefill.len());
        let mut d_prices: Vec<disagg::PoolPrice> = Vec::with_capacity(pools.decode.len());
        let mut pruned = 0usize;
        let mut pruned_sla = 0usize;
        let mut pruned_dominated = 0usize;
        for (out, ms) in outcomes {
            per_config_ms.push(*ms);
            match out {
                JobOut::Agg(ev) => {
                    // Same short-circuit order as the fused condition
                    // this replaces: SLA first, dominance only for
                    // feasible candidates — the split is attribution
                    // only, the survivor set is untouched.
                    if opts.prune && !ev.est.meets(&wl.sla) {
                        pruned += 1;
                        pruned_sla += 1;
                    } else if opts.prune
                        && merged.dominated(ev.est.speed, ev.est.thru_per_gpu)
                    {
                        pruned += 1;
                        pruned_dominated += 1;
                    } else {
                        evaluated.push(ev.clone());
                    }
                }
                JobOut::Pre(p) => p_prices.push(*p),
                JobOut::Dec(d) => d_prices.push(*d),
            }
        }

        if self.space.modes.contains(&ServingMode::Disaggregated) {
            let res = if opts.prune {
                // Seed the disagg prune with a FRESH accumulator built
                // from the aggregated survivors in input order — a
                // deterministic function of the survivor set, not of
                // worker interleaving.
                let mut acc = FrontierAccumulator::new();
                for ev in &evaluated {
                    acc.offer_est(&ev.est);
                }
                let rejected_before = acc.rejected();
                let full = disagg::rate_match_pruned(
                    self.cluster,
                    &p_prices,
                    &d_prices,
                    wl,
                    total_gpus,
                    &[],
                    self.space.max_x,
                    self.space.max_y,
                    &mut acc,
                );
                let rejected = acc.rejected() - rejected_before;
                pruned += rejected;
                pruned_dominated += rejected;
                full
            } else {
                disagg::rate_match(
                    self.cluster,
                    &p_prices,
                    &d_prices,
                    wl,
                    total_gpus,
                    &[],
                    self.space.max_x,
                    self.space.max_y,
                )
            };
            for (x, y, pi, di, est) in res.evaluated {
                evaluated.push(Evaluated {
                    cand: Candidate::Disaggregated {
                        prefill: pools.pre_grid.get(pools.prefill[pi] as usize),
                        decode: pools.grid.get(pools.decode[di] as usize),
                        x,
                        y,
                    },
                    est,
                });
            }
        }

        per_config_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_config_ms.get(per_config_ms.len() / 2).copied().unwrap_or(0.0);
        let tier_counts = match (tiers_before, oracle.provenance_counts()) {
            (Some(before), Some(after)) => Some(after.since(&before)),
            _ => None,
        };
        sp.add("evaluated", evaluated.len() as f64);
        sp.add("pruned_sla", pruned_sla as f64);
        sp.add("pruned_dominated", pruned_dominated as f64);
        sp.add("infeasible", pools.infeasible as f64);
        if let Some(t) = &tier_counts {
            sp.add("tier_measured", t.measured as f64);
            sp.add("tier_calibrated", t.calibrated as f64);
            sp.add("tier_analytic", t.analytic as f64);
            sp.add("tier_sol", t.sol as f64);
        }
        SearchReport {
            flag_summaries: flag_summaries(&evaluated),
            evaluated,
            configs_priced,
            pruned,
            pruned_sla,
            pruned_dominated,
            infeasible: pools.infeasible,
            elapsed_s: t0.elapsed().as_secs_f64(),
            median_config_ms: median,
            tier_counts,
        }
    }

    /// The seed implementation (static-chunk `thread::scope` over the
    /// aggregated candidates, sequential disaggregated pricing). Kept
    /// verbatim as the reference baseline for `benches/table1_search.rs`
    /// so the work-stealing rework's wall-clock win stays measurable;
    /// produces the same `evaluated` set as [`Self::run`].
    pub fn run_baseline(&self, oracle: &dyn LatencyOracle) -> SearchReport {
        let t0 = Instant::now();
        let tiers_before = oracle.provenance_counts();
        let wl = &self.workload;
        let mut evaluated: Vec<Evaluated> = Vec::new();
        let mut per_config_ms: Vec<f64> = Vec::new();
        let mut configs_priced = 0usize;

        // ---- Aggregated candidates --------------------------------------
        if self.space.modes.contains(&ServingMode::Aggregated) {
            let engines = self.space.engines(self.model, self.cluster, wl, wl.osl);
            configs_priced += engines.len();
            let n_threads = self.thread_count().min(engines.len().max(1));
            let chunks: Vec<&[EngineConfig]> =
                engines.chunks(engines.len().div_ceil(n_threads).max(1)).collect();
            let results: Vec<Vec<(Evaluated, f64)>> = std::thread::scope(|s| {
                let handles: Vec<_> = chunks
                    .into_iter()
                    .map(|chunk| {
                        s.spawn(move || {
                            chunk
                                .iter()
                                .map(|eng| {
                                    let t = Instant::now();
                                    let replicas =
                                        (self.cluster.total_gpus() / eng.parallel.gpus()).max(1);
                                    let cand = Candidate::Aggregated { engine: *eng, replicas };
                                    let est = perfmodel::estimate(
                                        oracle,
                                        self.model,
                                        self.cluster,
                                        &cand,
                                        wl,
                                    );
                                    (Evaluated { cand, est }, t.elapsed().as_secs_f64() * 1e3)
                                })
                                .collect()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for r in results {
                for (e, ms) in r {
                    evaluated.push(e);
                    per_config_ms.push(ms);
                }
            }
        }

        // ---- Disaggregated candidates ------------------------------------
        if self.space.modes.contains(&ServingMode::Disaggregated) {
            let prefill = self.space.prefill_engines(self.model, self.cluster, wl);
            let decode = self.space.engines(self.model, self.cluster, wl, wl.osl);
            configs_priced += prefill.len() + decode.len();

            let t_price = Instant::now();
            let p_prices: Vec<disagg::PoolPrice> = prefill
                .iter()
                .map(|e| disagg::price_prefill(oracle, self.model, self.cluster, e, wl))
                .collect();
            let d_prices: Vec<disagg::PoolPrice> = decode
                .iter()
                .map(|e| disagg::price_decode(oracle, self.model, self.cluster, e, wl))
                .collect();
            let priced = prefill.len() + decode.len();
            if priced > 0 {
                let each = t_price.elapsed().as_secs_f64() * 1e3 / priced as f64;
                per_config_ms.extend((0..priced).map(|_| each));
            }

            let res = disagg::rate_match(
                self.cluster,
                &p_prices,
                &d_prices,
                wl,
                self.cluster.total_gpus(),
                &[],
                self.space.max_x,
                self.space.max_y,
            );
            for (x, y, pi, di, est) in res.evaluated {
                evaluated.push(Evaluated {
                    cand: Candidate::Disaggregated {
                        prefill: prefill[pi],
                        decode: decode[di],
                        x,
                        y,
                    },
                    est,
                });
            }
        }

        per_config_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_config_ms.get(per_config_ms.len() / 2).copied().unwrap_or(0.0);
        let tier_counts = match (tiers_before, oracle.provenance_counts()) {
            (Some(before), Some(after)) => Some(after.since(&before)),
            _ => None,
        };
        SearchReport {
            flag_summaries: flag_summaries(&evaluated),
            evaluated,
            configs_priced,
            pruned: 0,
            pruned_sla: 0,
            pruned_dominated: 0,
            infeasible: 0,
            elapsed_s: t0.elapsed().as_secs_f64(),
            median_config_ms: median,
            tier_counts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::by_name;
    use crate::silicon::Silicon;

    #[test]
    fn search_produces_both_modes() {
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 10.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl);
        let report = runner.run(&sil);
        assert!(report.configs_priced > 10, "{}", report.configs_priced);
        assert_eq!(report.pruned, 0, "default run must not prune");
        assert!(report
            .evaluated
            .iter()
            .any(|e| matches!(e.cand, Candidate::Aggregated { .. })));
        assert!(report
            .evaluated
            .iter()
            .any(|e| matches!(e.cand, Candidate::Disaggregated { .. })));
        // Every estimate is finite and positive.
        for e in &report.evaluated {
            assert!(e.est.ttft_ms.is_finite() && e.est.ttft_ms > 0.0);
            assert!(e.est.tpot_ms.is_finite() && e.est.tpot_ms > 0.0);
            assert!(e.est.thru_per_gpu.is_finite() && e.est.thru_per_gpu > 0.0);
        }
    }

    #[test]
    fn deterministic_given_same_oracle() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::Vllm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::Vllm);
        space.batch = vec![8, 32];
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 1000.0, 20.0);
        let r1 = TaskRunner::new(&model, &cluster, space.clone(), wl.clone()).run(&sil);
        let r2 = TaskRunner::new(&model, &cluster, space, wl).run(&sil);
        assert_eq!(r1.evaluated.len(), r2.evaluated.len());
        for (a, b) in r1.evaluated.iter().zip(&r2.evaluated) {
            assert_eq!(a.est, b.est);
        }
    }

    #[test]
    fn pooled_run_matches_seed_baseline() {
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 32, 128];
        space.max_x = 8;
        space.max_y = 8;
        let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 10.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl);
        let pooled = runner.run(&sil);
        let seed = runner.run_baseline(&sil);
        assert_eq!(pooled.configs_priced, seed.configs_priced);
        assert_eq!(pooled.evaluated.len(), seed.evaluated.len());
        for (a, b) in pooled.evaluated.iter().zip(&seed.evaluated) {
            assert_eq!(a.cand, b.cand);
            assert_eq!(a.est, b.est);
        }
    }

    #[test]
    fn single_thread_run_matches_parallel() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 64];
        space.max_x = 4;
        space.max_y = 4;
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let mut r1 = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
        r1.threads = 1;
        let mut r8 = TaskRunner::new(&model, &cluster, space, wl);
        r8.threads = 8;
        let a = r1.run(&sil);
        let b = r8.run(&sil);
        assert_eq!(a.evaluated.len(), b.evaluated.len());
        for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
            assert_eq!(x.cand, y.cand);
            assert_eq!(x.est, y.est);
        }
    }

    #[test]
    fn sweep_cached_warm_memo_matches_cold() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 32];
        space.max_x = 4;
        space.max_y = 4;
        let wls = vec![
            WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0),
            WorkloadSpec::new("llama3.1-8b", 512, 64, 3000.0, 5.0),
        ];
        let runner = TaskRunner::new(&model, &cluster, space, wls[0].clone());
        let cold = runner.run_sweep(&sil, &wls);
        let memo = MemoOracle::new(&sil);
        let first = runner.run_sweep_cached(&memo, &wls, &RunOptions::default());
        let warm = runner.run_sweep_cached(&memo, &wls, &RunOptions::default());
        let (hits, _) = memo.stats();
        assert!(hits > 0, "warm pass must hit the shared memo");
        for (a, b) in cold.iter().zip(&first).chain(first.iter().zip(&warm)) {
            assert_eq!(a.evaluated.len(), b.evaluated.len());
            for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
                assert_eq!(x.cand, y.cand);
                assert_eq!(x.est, y.est);
            }
        }
    }

    /// `run_cached` (thread-local memo fronts over a shared store) is
    /// bit-identical to a plain run on the memo's inner oracle, and the
    /// warm second run hits the store.
    #[test]
    fn cached_run_matches_plain_run() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 32];
        space.max_x = 4;
        space.max_y = 4;
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl);
        let plain = runner.run(&sil);
        let memo = MemoOracle::new(&sil);
        let cold = runner.run_cached(&memo, &RunOptions::default());
        let warm = runner.run_cached(&memo, &RunOptions::default());
        let (hits, _) = memo.stats();
        assert!(hits > 0, "warm run must hit the shared memo store");
        for r in [&cold, &warm] {
            assert_eq!(plain.evaluated.len(), r.evaluated.len());
            for (x, y) in plain.evaluated.iter().zip(&r.evaluated) {
                assert_eq!(x.cand, y.cand);
                assert_eq!(x.est, y.est);
            }
        }
    }

    /// The pruned survivor set is a pure function of the candidate set
    /// — "feasible and not strictly dominated" — so it cannot depend on
    /// how jobs landed on workers.
    #[test]
    fn pruned_run_is_thread_count_independent() {
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 32, 128];
        space.max_x = 8;
        space.max_y = 8;
        let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 10.0);
        let mut r1 = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
        r1.threads = 1;
        let mut r8 = TaskRunner::new(&model, &cluster, space, wl);
        r8.threads = 8;
        let a = r1.run_pruned(&sil);
        let b = r8.run_pruned(&sil);
        assert_eq!(a.pruned, b.pruned);
        assert_eq!(a.evaluated.len(), b.evaluated.len());
        for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
            assert_eq!(x.cand, y.cand);
            assert_eq!(x.est, y.est);
        }
    }

    #[test]
    fn frontier_carries_resolved_flags_and_report_shows_deltas() {
        // The paper-level claim behind the backend layer: a
        // qwen3-32b/H100 search with flag resolution on must place at
        // least one candidate with non-default kv_frac or
        // max_num_tokens on the Pareto frontier, and the report must
        // expose the resolved-vs-default deltas.
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let wl = WorkloadSpec::new("qwen3-32b", 4000, 500, 1500.0, 20.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl.clone());
        let report = runner.run(&sil);

        assert!(!report.flag_summaries.is_empty());
        let s = &report.flag_summaries[0];
        assert_eq!(s.framework, Framework::TrtLlm);
        assert!(s.nondefault > 0, "{}", s.describe());
        assert!(s.kv_frac_min <= s.kv_frac_max && s.mnt_min <= s.mnt_max);

        let analysis = crate::pareto::analyze(&report.evaluated, &wl.sla);
        let off_default = analysis.frontier.iter().any(|&i| {
            let eng = match &analysis.feasible[i].cand {
                Candidate::Aggregated { engine, .. } => engine,
                Candidate::Disaggregated { decode, .. } => decode,
            };
            let d = crate::config::RuntimeFlags::defaults_for(eng.framework);
            eng.flags.kv_frac != d.kv_frac || eng.flags.max_num_tokens != d.max_num_tokens
        });
        assert!(off_default, "no frontier candidate left the default flag point");
    }

    #[test]
    fn pruned_run_preserves_frontier_and_best() {
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
        space.batch = vec![8, 32, 128];
        space.max_x = 8;
        space.max_y = 16;
        let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 10.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl.clone());
        let full = runner.run(&sil);
        let pruned = runner.run_pruned(&sil);
        assert!(pruned.pruned > 0, "pruning should discard something");
        assert!(pruned.evaluated.len() < full.evaluated.len());
        // The by-cause split is exhaustive over the pruned count.
        assert_eq!(pruned.pruned, pruned.pruned_sla + pruned.pruned_dominated);

        let a_full = crate::pareto::analyze(&full.evaluated, &wl.sla);
        let a_pruned = crate::pareto::analyze(&pruned.evaluated, &wl.sla);
        // Same argmax.
        assert_eq!(
            a_full.best().unwrap().est.thru_per_gpu,
            a_pruned.best().unwrap().est.thru_per_gpu
        );
        // Same frontier values.
        let vals = |a: &crate::pareto::Analysis| -> Vec<(f64, f64)> {
            a.frontier
                .iter()
                .map(|&i| (a.feasible[i].est.speed, a.feasible[i].est.thru_per_gpu))
                .collect()
        };
        assert_eq!(vals(&a_full), vals(&a_pruned));
    }

    fn assert_reports_equal(a: &SearchReport, b: &SearchReport) {
        assert_eq!(a.evaluated.len(), b.evaluated.len());
        for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
            assert_eq!(x.cand, y.cand);
            assert_eq!(x.est, y.est);
        }
        assert_eq!(a.pruned, b.pruned);
        assert_eq!(a.pruned_sla, b.pruned_sla);
        assert_eq!(a.pruned_dominated, b.pruned_dominated);
        assert_eq!(a.infeasible, b.infeasible);
    }

    fn small_replan_runner<'a>(
        model: &'a ModelArch,
        cluster: &'a ClusterSpec,
    ) -> TaskRunner<'a> {
        let mut space = SearchSpace::default_for(model, Framework::TrtLlm);
        space.batch = vec![8, 32];
        space.max_x = 4;
        space.max_y = 4;
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
        TaskRunner::new(model, cluster, space, wl)
    }

    /// Oracle wrapper that scales collective latencies — stands in for
    /// a swapped calibration artifact correcting the comm tables.
    struct ScaledCollectives<'a> {
        inner: &'a dyn LatencyOracle,
        factor: f64,
    }

    impl LatencyOracle for ScaledCollectives<'_> {
        fn op_latency_us(&self, op: &crate::ops::Op) -> f64 {
            use crate::ops::Op;
            let base = self.inner.op_latency_us(op);
            match op {
                Op::AllReduce { .. } | Op::AllGather { .. } | Op::AllToAll { .. } => {
                    base * self.factor
                }
                _ => base,
            }
        }
    }

    /// Arena-retaining runs report exactly what `run_cached` reports,
    /// and a replan with an empty invalidation mask re-prices nothing
    /// while reproducing the baseline report.
    #[test]
    fn replan_with_empty_mask_is_identity() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let runner = small_replan_runner(&model, &cluster);
        let opts = RunOptions { prune: true };

        let plain = runner.run_cached(&MemoOracle::new(&sil), &opts);
        let memo = MemoOracle::new(&sil);
        let (r1, mut arena) = runner.run_cached_arena(&memo, &opts);
        assert_reports_equal(&plain, &r1);
        assert_eq!(arena.len(), r1.configs_priced);
        assert!(arena.invalidated(0).is_empty());

        let r2 = runner.replan(&mut arena, &memo, 0);
        assert_eq!(r2.configs_priced, 0, "empty mask must re-price nothing");
        assert_reports_equal(&r1, &r2);
    }

    /// The bit-equality pin behind differential re-search: after the
    /// collective tables change, a replan that re-prices only the
    /// comm-tagged jobs through the (invalidated) shared memo store
    /// matches a from-scratch search against the changed oracle —
    /// while re-pricing strictly fewer candidates than the full sweep.
    #[test]
    fn replan_matches_from_scratch_after_collective_change() {
        use crate::perfdb::cache::{TAG_ALL_GATHER, TAG_ALL_REDUCE, TAG_ALL_TO_ALL};
        use crate::search::delta::tag_bit;

        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let runner = small_replan_runner(&model, &cluster);

        for opts in [RunOptions { prune: false }, RunOptions { prune: true }] {
            let store = crate::perfdb::MemoStore::new();
            let memo1 = MemoOracle::with_store(&sil, &store);
            let (r1, mut arena) = runner.run_cached_arena(&memo1, &opts);

            // "Recalibrate" the comm tables, keep the same memo store.
            let scaled = ScaledCollectives { inner: &sil, factor: 1.37 };
            let memo2 = MemoOracle::with_store(&scaled, &store);
            let mask = tag_bit(TAG_ALL_REDUCE) | tag_bit(TAG_ALL_GATHER) | tag_bit(TAG_ALL_TO_ALL);
            let inc = runner.replan(&mut arena, &memo2, mask);

            let fresh = runner.run_cached(&MemoOracle::new(&scaled), &opts);
            assert_reports_equal(&fresh, &inc);

            // Strictly fewer candidates re-priced: single-GPU engines
            // carry no collective tags, so they keep their outcomes.
            assert!(inc.configs_priced > 0, "multi-GPU candidates must re-price");
            assert!(
                inc.configs_priced < r1.configs_priced,
                "replan must re-price strictly fewer than the full sweep: {} vs {}",
                inc.configs_priced,
                r1.configs_priced
            );

            // A second replan with the same mask converges (the memo
            // now holds the corrected latencies).
            let again = runner.replan(&mut arena, &memo2, mask);
            assert_reports_equal(&inc, &again);
        }
    }
}

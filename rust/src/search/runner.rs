//! TaskRunner: evaluate every candidate configuration against the
//! workload (paper §4.1 step 3, "InferenceSession will iterate over all
//! the candidate serving configurations"), in parallel across OS threads.

use std::time::Instant;

use crate::config::{Candidate, ServingMode, WorkloadSpec};
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::perfdb::LatencyOracle;
use crate::perfmodel::{self, disagg, PerfEstimate};

use super::space::SearchSpace;

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Evaluated {
    pub cand: Candidate,
    pub est: PerfEstimate,
}

/// Outcome of a full search.
#[derive(Clone, Debug)]
pub struct SearchReport {
    pub evaluated: Vec<Evaluated>,
    /// Engine-level configurations priced (the paper's "configs" count).
    pub configs_priced: usize,
    /// Wall-clock of the whole search, seconds.
    pub elapsed_s: f64,
    /// Median per-configuration evaluation time, milliseconds.
    pub median_config_ms: f64,
}

/// Drives the search for one workload on one cluster.
pub struct TaskRunner<'a> {
    pub model: &'a ModelArch,
    pub cluster: &'a ClusterSpec,
    pub space: SearchSpace,
    pub workload: WorkloadSpec,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
}

impl<'a> TaskRunner<'a> {
    pub fn new(
        model: &'a ModelArch,
        cluster: &'a ClusterSpec,
        space: SearchSpace,
        workload: WorkloadSpec,
    ) -> Self {
        TaskRunner { model, cluster, space, workload, threads: 0 }
    }

    fn thread_count(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }

    /// Evaluate the full space. The oracle is typically a
    /// [`crate::perfdb::PerfDatabase`]; passing the silicon instead gives
    /// the zero-interpolation-error upper bound used in ablations.
    pub fn run(&self, oracle: &dyn LatencyOracle) -> SearchReport {
        let t0 = Instant::now();
        let wl = &self.workload;
        let mut evaluated: Vec<Evaluated> = Vec::new();
        let mut per_config_ms: Vec<f64> = Vec::new();
        let mut configs_priced = 0usize;

        // ---- Aggregated candidates --------------------------------------
        if self.space.modes.contains(&ServingMode::Aggregated) {
            let engines = self.space.engines(self.model, self.cluster, wl.isl, wl.osl);
            configs_priced += engines.len();
            let n_threads = self.thread_count().min(engines.len().max(1));
            let chunks: Vec<&[crate::config::EngineConfig]> = engines
                .chunks(engines.len().div_ceil(n_threads).max(1))
                .collect();
            let results: Vec<Vec<(Evaluated, f64)>> = std::thread::scope(|s| {
                let handles: Vec<_> = chunks
                    .into_iter()
                    .map(|chunk| {
                        s.spawn(move || {
                            chunk
                                .iter()
                                .map(|eng| {
                                    let t = Instant::now();
                                    let replicas = (self.cluster.total_gpus()
                                        / eng.parallel.gpus())
                                    .max(1);
                                    let cand =
                                        Candidate::Aggregated { engine: *eng, replicas };
                                    let est = perfmodel::estimate(
                                        oracle,
                                        self.model,
                                        self.cluster,
                                        &cand,
                                        wl,
                                    );
                                    (
                                        Evaluated { cand, est },
                                        t.elapsed().as_secs_f64() * 1e3,
                                    )
                                })
                                .collect()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for r in results {
                for (e, ms) in r {
                    evaluated.push(e);
                    per_config_ms.push(ms);
                }
            }
        }

        // ---- Disaggregated candidates ------------------------------------
        if self.space.modes.contains(&ServingMode::Disaggregated) {
            let prefill = self.space.prefill_engines(self.model, self.cluster, wl.isl);
            let decode = self.space.engines(self.model, self.cluster, wl.isl, wl.osl);
            configs_priced += prefill.len() + decode.len();

            let t_price = Instant::now();
            let p_prices: Vec<disagg::PoolPrice> = prefill
                .iter()
                .map(|e| disagg::price_prefill(oracle, self.model, self.cluster, e, wl))
                .collect();
            let d_prices: Vec<disagg::PoolPrice> = decode
                .iter()
                .map(|e| disagg::price_decode(oracle, self.model, self.cluster, e, wl))
                .collect();
            let priced = prefill.len() + decode.len();
            if priced > 0 {
                let each = t_price.elapsed().as_secs_f64() * 1e3 / priced as f64;
                per_config_ms.extend(std::iter::repeat(each).take(priced));
            }

            let res = disagg::rate_match(
                &p_prices,
                &d_prices,
                wl,
                self.cluster.total_gpus(),
                &[],
                self.space.max_x,
                self.space.max_y,
            );
            for (x, y, pi, di, est) in res.evaluated {
                evaluated.push(Evaluated {
                    cand: Candidate::Disaggregated {
                        prefill: prefill[pi],
                        decode: decode[di],
                        x,
                        y,
                    },
                    est,
                });
            }
        }

        per_config_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_config_ms
            .get(per_config_ms.len() / 2)
            .copied()
            .unwrap_or(0.0);
        SearchReport {
            evaluated,
            configs_priced,
            elapsed_s: t0.elapsed().as_secs_f64(),
            median_config_ms: median,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::by_name;
    use crate::silicon::Silicon;

    #[test]
    fn search_produces_both_modes() {
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 10.0);
        let runner = TaskRunner::new(&model, &cluster, space, wl);
        let report = runner.run(&sil);
        assert!(report.configs_priced > 10, "{}", report.configs_priced);
        assert!(report
            .evaluated
            .iter()
            .any(|e| matches!(e.cand, Candidate::Aggregated { .. })));
        assert!(report
            .evaluated
            .iter()
            .any(|e| matches!(e.cand, Candidate::Disaggregated { .. })));
        // Every estimate is finite and positive.
        for e in &report.evaluated {
            assert!(e.est.ttft_ms.is_finite() && e.est.ttft_ms > 0.0);
            assert!(e.est.tpot_ms.is_finite() && e.est.tpot_ms > 0.0);
            assert!(e.est.thru_per_gpu.is_finite() && e.est.thru_per_gpu > 0.0);
        }
    }

    #[test]
    fn deterministic_given_same_oracle() {
        let model = by_name("llama3.1-8b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::Vllm.profile());
        let mut space = SearchSpace::default_for(&model, Framework::Vllm);
        space.batch = vec![8, 32];
        let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 1000.0, 20.0);
        let r1 = TaskRunner::new(&model, &cluster, space.clone(), wl.clone()).run(&sil);
        let r2 = TaskRunner::new(&model, &cluster, space, wl).run(&sil);
        assert_eq!(r1.evaluated.len(), r2.evaluated.len());
        for (a, b) in r1.evaluated.iter().zip(&r2.evaluated) {
            assert_eq!(a.est, b.est);
        }
    }
}

//! Differential re-search input: [`SearchDelta`] describes *changes* to
//! a prior sweep/plan, and the dependency tagger maps each change onto
//! the subset of candidates and memo entries it invalidates (the
//! arrangement/delta idiom from differential dataflow, DESIGN.md §11).
//!
//! The tagger is **static and conservative**: a candidate's tag mask is
//! derived analytically from its engine shape (which op classes its
//! pricing can possibly touch), never by probing the oracle. An
//! over-approximation only costs extra re-pricing; an
//! under-approximation would break the replan bit-equality pin, so
//! every rule below errs wide:
//!
//! - every engine prices GEMMs, both attention classes and elementwise
//!   traffic ([`crate::perfmodel::iteration`] decomposes all of them
//!   unconditionally);
//! - MoE grouped GEMMs appear iff the model has an expert config;
//! - any multi-GPU layout (tp·pp·dp > 1, or ep > 1) may price any
//!   collective and the PP stage-boundary P2p;
//! - a disaggregated composite additionally ships KV over P2p.
//!
//! Delta kinds and what they invalidate:
//!
//! | delta               | candidates re-priced      | memo entries dropped |
//! |---------------------|---------------------------|----------------------|
//! | traffic window edit | none (demand-side only)   | none                 |
//! | GPU re-price        | none (cost re-derivation) | none                 |
//! | calibration swap    | the swapped leg's grid    | all tags (leg store) |
//! | added fleet leg     | the new leg's grid only   | none                 |
//! | removed fleet leg   | none (pure retraction)    | none                 |

use crate::config::{Candidate, EngineConfig};
use crate::models::ModelArch;
use crate::perfdb::cache::{
    TAG_ALL_GATHER, TAG_ALL_REDUCE, TAG_ALL_TO_ALL, TAG_ATTN_DECODE, TAG_ATTN_PREFILL,
    TAG_ELEMENTWISE, TAG_GEMM, TAG_MOE_GEMM, TAG_P2P, NUM_TAGS,
};
use crate::util::json::{self, Json};

/// Bit for one memo tag (see [`crate::perfdb::cache::op_tag`]).
pub const fn tag_bit(tag: u8) -> u64 {
    1u64 << tag
}

/// Every op class — the mask a swapped calibration artifact gets: a
/// measurement set may correct any class, so the sound choice is to
/// drop the whole leg store and re-price the leg's grid. The savings of
/// a calibration-swap replan come from the *other* legs staying priced.
pub const ALL_TAGS_MASK: u64 = (1u64 << NUM_TAGS) - 1;

/// Op classes every engine prices regardless of shape.
pub const BASE_TAGS_MASK: u64 = tag_bit(TAG_GEMM)
    | tag_bit(TAG_ATTN_PREFILL)
    | tag_bit(TAG_ATTN_DECODE)
    | tag_bit(TAG_ELEMENTWISE);

const COMM_TAGS_MASK: u64 = tag_bit(TAG_ALL_REDUCE)
    | tag_bit(TAG_ALL_GATHER)
    | tag_bit(TAG_ALL_TO_ALL)
    | tag_bit(TAG_P2P);

/// Conservative op-class mask of one engine's pricing.
pub fn engine_tag_mask(model: &ModelArch, eng: &EngineConfig) -> u64 {
    let mut mask = BASE_TAGS_MASK;
    if model.is_moe() {
        mask |= tag_bit(TAG_MOE_GEMM);
    }
    let par = &eng.parallel;
    if par.gpus() > 1 || par.ep > 1 {
        mask |= COMM_TAGS_MASK;
    }
    mask
}

/// Conservative op-class mask of a full candidate's pricing. The
/// disaggregated composite always includes P2p: its KV transfer is
/// priced over the fabric path even when both pools are single-GPU.
pub fn candidate_tag_mask(model: &ModelArch, cand: &Candidate) -> u64 {
    match cand {
        Candidate::Aggregated { engine, .. } => engine_tag_mask(model, engine),
        Candidate::Disaggregated { prefill, decode, .. } => {
            engine_tag_mask(model, prefill)
                | engine_tag_mask(model, decode)
                | tag_bit(TAG_P2P)
        }
    }
}

/// One edit set against a prior sweep/plan — the `"kind":
/// "search-delta"` artifact format (`artifacts/deltas/*.json`) and the
/// v2 `{"op": "replan"}` request's `"delta"` object.
///
/// Leg-addressed edits (`reprice`, `recalibrate`, `add_legs`,
/// `remove_legs`) name legs by the fleet grammar's GPU token; added
/// legs accept the full `GPU[@FABRIC]` form. Replanned fleets keep the
/// surviving legs in their original order and append added legs in
/// delta order — the canonical order a from-scratch equality check must
/// rebuild.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SearchDelta {
    /// (window index, new peak QPS) demand overrides.
    pub window_edits: Vec<(usize, f64)>,
    /// (GPU token, new USD per GPU-hour).
    pub reprice: Vec<(String, f64)>,
    /// Legs whose calibration artifact was swapped (full leg re-sweep
    /// through the new oracle).
    pub recalibrate: Vec<String>,
    /// Fleet legs to add, `GPU[@FABRIC]`.
    pub add_legs: Vec<String>,
    /// Fleet legs to remove.
    pub remove_legs: Vec<String>,
}

impl SearchDelta {
    /// Parse the artifact/wire format. `kind` is required so a delta
    /// file can never be confused with the other committed artifact
    /// schemas (trace specs, measurement sets):
    ///
    /// ```json
    /// {"kind": "search-delta",
    ///  "window_edits": [{"window": 3, "peak_qps": 55.0}],
    ///  "reprice": [{"gpu": "h100", "usd_per_hour": 1.49}],
    ///  "recalibrate": ["h100"],
    ///  "add_legs": ["a100@hgx-h100"],
    ///  "remove_legs": ["h200"]}
    /// ```
    pub fn from_json(j: &Json) -> anyhow::Result<SearchDelta> {
        let kind = j.req_str("kind")?;
        anyhow::ensure!(kind == "search-delta", "kind '{kind}' is not a search-delta");
        let mut d = SearchDelta::default();
        if let Some(arr) = j.get("window_edits").and_then(|v| v.as_arr()) {
            for e in arr {
                d.window_edits.push((e.req_f64("window")? as usize, e.req_f64("peak_qps")?));
            }
        }
        if let Some(arr) = j.get("reprice").and_then(|v| v.as_arr()) {
            for e in arr {
                d.reprice.push((e.req_str("gpu")?.to_string(), e.req_f64("usd_per_hour")?));
            }
        }
        for (field, out) in [
            ("recalibrate", &mut d.recalibrate),
            ("add_legs", &mut d.add_legs),
            ("remove_legs", &mut d.remove_legs),
        ] {
            if let Some(arr) = j.get(field).and_then(|v| v.as_arr()) {
                for e in arr {
                    out.push(
                        e.as_str()
                            .ok_or_else(|| anyhow::anyhow!("{field} entries must be strings"))?
                            .to_string(),
                    );
                }
            }
        }
        d.validate()?;
        Ok(d)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kind", json::s("search-delta"));
        if !self.window_edits.is_empty() {
            let arr = self
                .window_edits
                .iter()
                .map(|&(w, q)| {
                    let mut e = Json::obj();
                    e.set("window", json::num(w as f64)).set("peak_qps", json::num(q));
                    e
                })
                .collect();
            o.set("window_edits", Json::Arr(arr));
        }
        if !self.reprice.is_empty() {
            let arr = self
                .reprice
                .iter()
                .map(|(g, p)| {
                    let mut e = Json::obj();
                    e.set("gpu", json::s(g)).set("usd_per_hour", json::num(*p));
                    e
                })
                .collect();
            o.set("reprice", Json::Arr(arr));
        }
        for (field, v) in [
            ("recalibrate", &self.recalibrate),
            ("add_legs", &self.add_legs),
            ("remove_legs", &self.remove_legs),
        ] {
            if !v.is_empty() {
                o.set(field, Json::Arr(v.iter().map(|s| json::s(s)).collect()));
            }
        }
        o
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.is_empty(), "empty delta: nothing to replan");
        for &(w, q) in &self.window_edits {
            anyhow::ensure!(
                q.is_finite() && q >= 0.0,
                "window {w} edit: peak_qps {q} must be finite and non-negative"
            );
        }
        for (g, p) in &self.reprice {
            anyhow::ensure!(
                p.is_finite() && *p > 0.0,
                "reprice of '{g}': usd_per_hour {p} must be finite and positive"
            );
        }
        for name in
            self.recalibrate.iter().chain(&self.add_legs).chain(&self.remove_legs)
        {
            anyhow::ensure!(!name.is_empty(), "empty leg name in delta");
        }
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.window_edits.is_empty()
            && self.reprice.is_empty()
            && self.recalibrate.is_empty()
            && self.add_legs.is_empty()
            && self.remove_legs.is_empty()
    }

    /// Does this delta change the option *set* (as opposed to demands
    /// or prices of existing options)?
    pub fn is_structural(&self) -> bool {
        !self.recalibrate.is_empty() || !self.add_legs.is_empty() || !self.remove_legs.is_empty()
    }

    /// Pure demand-side edit: the priced option set is untouched and
    /// the planner can patch individual windows in place.
    pub fn only_window_edits(&self) -> bool {
        !self.window_edits.is_empty()
            && self.reprice.is_empty()
            && !self.is_structural()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::models::{by_name, Dtype};
    use crate::topology::Placement;

    fn eng(par: ParallelSpec) -> EngineConfig {
        EngineConfig {
            framework: Framework::TrtLlm,
            parallel: par,
            batch: 8,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: Placement::packed(),
        }
    }

    #[test]
    fn tag_masks_are_conservative_and_shape_dependent() {
        let dense = by_name("qwen3-32b").unwrap();
        let single = engine_tag_mask(&dense, &eng(ParallelSpec::tp(1)));
        assert_eq!(single, BASE_TAGS_MASK, "single-GPU dense engine prices no collectives");
        let tp4 = engine_tag_mask(&dense, &eng(ParallelSpec::tp(4)));
        assert!(tp4 & tag_bit(TAG_ALL_REDUCE) != 0);
        assert!(tp4 & tag_bit(TAG_MOE_GEMM) == 0, "dense model never prices MoE GEMMs");
        assert!(single & tp4 == single, "wider layouts only add tags");

        let moe = by_name("deepseek-v3").or_else(|| by_name("mixtral-8x7b"));
        if let Some(m) = moe {
            assert!(engine_tag_mask(&m, &eng(ParallelSpec::tp(1))) & tag_bit(TAG_MOE_GEMM) != 0);
        }
    }

    #[test]
    fn disagg_candidates_always_carry_p2p() {
        let dense = by_name("qwen3-32b").unwrap();
        let c = Candidate::Disaggregated {
            prefill: eng(ParallelSpec::tp(1)),
            decode: eng(ParallelSpec::tp(1)),
            x: 1,
            y: 1,
        };
        assert!(candidate_tag_mask(&dense, &c) & tag_bit(TAG_P2P) != 0);
        let a = Candidate::Aggregated { engine: eng(ParallelSpec::tp(1)), replicas: 2 };
        assert!(candidate_tag_mask(&dense, &a) & tag_bit(TAG_P2P) == 0);
    }

    #[test]
    fn delta_json_roundtrip_and_validation() {
        let d = SearchDelta {
            window_edits: vec![(3, 55.0), (0, 10.0)],
            reprice: vec![("h100".to_string(), 1.49)],
            recalibrate: vec!["h100".to_string()],
            add_legs: vec!["a100@hgx-h100".to_string()],
            remove_legs: vec!["h200".to_string()],
        };
        let back = SearchDelta::from_json(&d.to_json()).unwrap();
        assert_eq!(back, d);
        assert!(back.is_structural());
        assert!(!back.only_window_edits());

        let w = SearchDelta { window_edits: vec![(1, 5.0)], ..Default::default() };
        assert!(SearchDelta::from_json(&w.to_json()).unwrap().only_window_edits());

        assert!(SearchDelta::from_json(&Json::obj()).is_err(), "kind is required");
        let mut wrong = Json::obj();
        wrong.set("kind", json::s("trace-spec"));
        assert!(SearchDelta::from_json(&wrong).is_err());
        let mut empty = Json::obj();
        empty.set("kind", json::s("search-delta"));
        assert!(SearchDelta::from_json(&empty).is_err(), "empty deltas rejected");
        let bad = SearchDelta { reprice: vec![("h100".into(), -1.0)], ..Default::default() };
        assert!(bad.validate().is_err());
    }
}

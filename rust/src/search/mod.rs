//! TaskRunner (paper §4.1 step 2): construct the space of valid candidate
//! serving configurations from the workload descriptor, then evaluate
//! every candidate with the serving-mode models — thousands of
//! configurations in sub-second time on CPU (paper Table 1).
//!
//! The evaluation engine drains one unified job queue (aggregated +
//! prefill + decode candidates) through a work-stealing worker pool,
//! optionally pruning SLA-infeasible / Pareto-dominated candidates
//! incrementally, and supports multi-scenario batch sweeps that share
//! engine enumeration and memoized oracle queries.

pub mod runner;
pub mod space;

pub use runner::{RunOptions, SearchReport, TaskRunner};
pub use space::SearchSpace;

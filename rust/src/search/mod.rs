//! TaskRunner (paper §4.1 step 2): construct the space of valid candidate
//! serving configurations from the workload descriptor, then evaluate
//! every candidate with the serving-mode models — thousands of
//! configurations in sub-second time on CPU (paper Table 1).
//!
//! The evaluation engine drains one unified job queue (aggregated +
//! prefill + decode candidates) through a work-stealing worker pool,
//! optionally pruning SLA-infeasible / Pareto-dominated candidates
//! incrementally, and supports multi-scenario batch sweeps that share
//! engine enumeration and memoized oracle queries. Launch flags come
//! from the backend abstraction layer's analytic resolver
//! ([`crate::frameworks::Backend::resolve_flags`]), re-resolved per
//! workload scenario.

pub mod delta;
pub mod runner;
pub mod space;

pub use delta::SearchDelta;
pub use runner::{flag_summaries, FlagSummary, RunArena, RunOptions, SearchReport, TaskRunner};
pub use space::SearchSpace;

use crate::config::ServingMode;

/// Reject serving modes the TaskRunner cannot price. `static` parses
/// (it names Algorithm 1's fixed-batch estimation target) but is not a
/// searchable deployment shape — without this check a static-mode
/// request would price *nothing* and report an empty result without
/// warning. Shared by the CLI and the service so no surface can drift.
pub fn ensure_searchable_modes(modes: &[ServingMode]) -> anyhow::Result<()> {
    anyhow::ensure!(!modes.is_empty(), "no serving modes requested");
    for m in modes {
        anyhow::ensure!(
            m.searchable(),
            "serving mode '{}' is not searchable: static batching is an estimation/simulation \
             target (use the `simulate` subcommand or perfmodel::static_mode), not a deployable \
             candidate shape; searchable modes are 'aggregated' and 'disaggregated'",
            m.name()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_mode_is_rejected_with_clear_error() {
        let err =
            ensure_searchable_modes(&[ServingMode::Aggregated, ServingMode::Static]).unwrap_err();
        assert!(err.to_string().contains("static"), "{err}");
        assert!(err.to_string().contains("simulate"), "{err}");
        assert!(ensure_searchable_modes(&[]).is_err());
        assert!(
            ensure_searchable_modes(&[ServingMode::Aggregated, ServingMode::Disaggregated])
                .is_ok()
        );
    }
}

//! TaskRunner (paper §4.1 step 2): construct the space of valid candidate
//! serving configurations from the workload descriptor, then evaluate
//! every candidate with the serving-mode models — thousands of
//! configurations in sub-second time on CPU (paper Table 1).

pub mod runner;
pub mod space;

pub use runner::{SearchReport, TaskRunner};
pub use space::SearchSpace;

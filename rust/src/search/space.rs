//! Search-space enumeration with validity + memory pruning.
//!
//! Dimensions: framework × TP × PP × EP × DP × batch × quantization ×
//! serving mode — "from cluster topology down to engine specific
//! flags" (paper §1). Runtime flags are NOT cross-producted into the
//! grid: each structural point gets its flags from the backend
//! abstraction layer's analytic resolver
//! ([`crate::frameworks::Backend::resolve_flags`]), which covers the
//! paper's flag space without exploding the candidate count. Explicit
//! per-field overrides ([`SearchSpace::cuda_graph`] /
//! [`SearchSpace::max_num_tokens`] / [`SearchSpace::kv_frac`]) are
//! still honored, and the opt-in [`SearchSpace::flag_sweep`] mode
//! additionally enumerates {resolved, framework defaults, no-graph,
//! halved/doubled token capacity} per point for comparison runs.

use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags, ServingMode, WorkloadSpec};
use crate::frameworks::Framework;
use crate::hardware::ClusterSpec;
use crate::models::{Dtype, ModelArch};
use crate::perfmodel::memory;
use crate::topology::{placement, Placement};

/// Declarative search space. Empty vectors mean "use defaults" — and
/// for the flag fields, "resolve analytically per candidate".
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub frameworks: Vec<Framework>,
    pub tp: Vec<u32>,
    pub pp: Vec<u32>,
    pub ep: Vec<u32>,
    pub dp: Vec<u32>,
    pub batch: Vec<u32>,
    pub dtypes: Vec<Dtype>,
    /// CUDA-graph override (empty = backend-resolved per candidate).
    pub cuda_graph: Vec<bool>,
    /// Token-capacity override (empty = backend-resolved per candidate).
    pub max_num_tokens: Vec<u32>,
    /// KV-fraction override (empty = backend-resolved per candidate).
    pub kv_frac: Vec<f64>,
    /// Opt-in: besides the resolved flags, also enumerate the framework
    /// defaults, a no-graph variant and 2 extra `max_num_tokens` points
    /// per structural candidate (resolved-vs-default comparisons).
    pub flag_sweep: bool,
    pub modes: Vec<ServingMode>,
    /// Disaggregated sweep bounds (x ∈ [1, max_x], y ∈ [1, max_y] —
    /// paper Algorithm 3 uses 32 / 64).
    pub max_x: u32,
    pub max_y: u32,
    /// Prefill-pool batch sizes (kept small: prefill is compute-bound).
    pub prefill_batch: Vec<u32>,
}

/// One workload-independent grid point: everything but the flags and
/// the placement. The [`crate::topology::Placement`] axis is expanded
/// per point by [`SearchSpace::expand_flags`] ([`placement::enumerate`]) — flags
/// don't depend on where ranks land, so resolution runs once per point
/// and the layouts share it; legacy fabrics enumerate a single packed
/// layout, leaving legacy grids unchanged.
pub(crate) type StructuralPoint = (Framework, Dtype, ParallelSpec, u32);

impl SearchSpace {
    /// The paper's default sweep (§5.1): TP/EP ∈ {1,2,4,8},
    /// batch 4–128, aggregated + disaggregated, flags resolved.
    pub fn default_for(model: &ModelArch, framework: Framework) -> SearchSpace {
        SearchSpace {
            frameworks: vec![framework],
            tp: vec![1, 2, 4, 8],
            pp: vec![1],
            ep: if model.is_moe() { vec![1, 2, 4, 8] } else { vec![1] },
            dp: vec![1],
            batch: vec![4, 8, 16, 32, 64, 128],
            dtypes: vec![Dtype::Fp8],
            cuda_graph: Vec::new(),
            max_num_tokens: Vec::new(),
            kv_frac: Vec::new(),
            flag_sweep: false,
            modes: vec![ServingMode::Aggregated, ServingMode::Disaggregated],
            max_x: 32,
            max_y: 64,
            prefill_batch: vec![1, 2, 4],
        }
    }

    /// Is an engine layout structurally valid for this model/cluster?
    pub fn layout_valid(model: &ModelArch, cluster: &ClusterSpec, p: &ParallelSpec) -> bool {
        if p.tp == 0 || p.pp == 0 || p.dp == 0 {
            return false;
        }
        // TP must divide the head count.
        if model.heads % p.tp as u64 != 0 {
            return false;
        }
        // PP must divide layers.
        if model.num_layers % p.pp as u64 != 0 {
            return false;
        }
        // Engine must fit the cluster.
        if p.gpus() > cluster.total_gpus() {
            return false;
        }
        // EP only for MoE; experts shard across the TP×DP group.
        if p.ep > 1 {
            match &model.moe {
                None => return false,
                Some(m) => {
                    if p.ep as u64 > m.num_experts
                        || m.num_experts % p.ep as u64 != 0
                        || p.ep > p.tp * p.dp
                    {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Enumerate the workload-independent **structural** grid: every
    /// framework × dtype × layout × batch combination valid for the
    /// model and cluster. Batch sweeps enumerate this once and expand
    /// flags per scenario ([`Self::expand_flags`]), since flag
    /// resolution and the memory prune are the only
    /// workload-dependent steps.
    pub(crate) fn structural_grid(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
    ) -> Vec<StructuralPoint> {
        let mut out = Vec::new();
        for &fw in &self.frameworks {
            let be = fw.backend();
            // Dtypes this GPU *and* framework can run, from the
            // requested list. When none qualify (the FP8-only default
            // on Ampere), fall back to the GPU's preferred dtype so
            // every surface — search, sweep, capacity plan — enumerates
            // a non-empty grid on older parts instead of silently
            // finding nothing.
            let mut dtypes: Vec<Dtype> = self
                .dtypes
                .iter()
                .copied()
                .filter(|&dt| cluster.gpu.supports(dt) && be.supports_dtype(dt))
                .collect();
            if dtypes.is_empty() {
                let fb = cluster.gpu.preferred_kv_dtype();
                if cluster.gpu.supports(fb) && be.supports_dtype(fb) {
                    dtypes.push(fb);
                }
            }
            for &dt in &dtypes {
                for &tp in &self.tp {
                    for &pp in &self.pp {
                        for &ep in &self.ep {
                            for &dp in &self.dp {
                                let p = ParallelSpec { tp, pp, ep, dp };
                                if !Self::layout_valid(model, cluster, &p) {
                                    continue;
                                }
                                for &b in &self.batch {
                                    out.push((fw, dt, p, b));
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The flag variants of one structural point under a workload:
    /// the analytically resolved flags, widened by [`Self::flag_sweep`]
    /// and then narrowed by any explicit user overrides (which replace
    /// the corresponding resolved field, cross-producted exactly like
    /// the pre-resolver sweep lists did).
    pub(crate) fn flag_variants(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
        point: &StructuralPoint,
    ) -> Vec<RuntimeFlags> {
        let (fw, dt, p, batch) = *point;
        let be = fw.backend();
        let pol = be.flag_policy();
        // A token capacity implies a chunking decision: chunked prefill
        // engages exactly when the prompt exceeds the capacity. Every
        // variant built with a capacity other than its base's must
        // re-derive it, or the model and the emitted launch files
        // would disagree about chunking.
        let chunk_for = |mnt: u32| pol.supports_chunked_prefill && wl.isl > mnt;
        let resolved = be.resolve_flags(model, cluster, wl, &p, batch, dt);
        let mut bases = vec![resolved];
        if self.flag_sweep {
            push_unique(&mut bases, be.default_flags());
            push_unique(&mut bases, RuntimeFlags { cuda_graph: false, ..resolved });
            for mnt in [
                (resolved.max_num_tokens / 2).max(pol.min_tokens),
                resolved.max_num_tokens.saturating_mul(2).min(pol.max_tokens),
            ] {
                if mnt >= batch {
                    push_unique(
                        &mut bases,
                        RuntimeFlags {
                            max_num_tokens: mnt,
                            chunked_prefill: chunk_for(mnt),
                            ..resolved
                        },
                    );
                }
            }
        }
        let mut out = Vec::new();
        for base in bases {
            let mnts: Vec<u32> = if self.max_num_tokens.is_empty() {
                vec![base.max_num_tokens]
            } else {
                self.max_num_tokens.clone()
            };
            let cgs: Vec<bool> = if self.cuda_graph.is_empty() {
                vec![base.cuda_graph]
            } else {
                self.cuda_graph.clone()
            };
            let kvs: Vec<f64> = if self.kv_frac.is_empty() {
                vec![base.kv_frac]
            } else {
                self.kv_frac.clone()
            };
            for &mnt in &mnts {
                for &cg in &cgs {
                    for &kv in &kvs {
                        push_unique(
                            &mut out,
                            RuntimeFlags {
                                cuda_graph: cg,
                                kv_frac: kv,
                                max_num_tokens: mnt,
                                // Keep the base's chunking when its
                                // capacity is kept (preserves the exact
                                // framework-defaults point in sweeps);
                                // re-derive for substituted capacities.
                                chunked_prefill: if mnt == base.max_num_tokens {
                                    base.chunked_prefill
                                } else {
                                    chunk_for(mnt)
                                },
                            },
                        );
                    }
                }
            }
        }
        out
    }

    /// Expand a structural grid into engine configurations for one
    /// workload (flags resolved per point; no memory filtering).
    /// Delegates to the SoA [`CandidateGrid`] — the materialized vector
    /// is bit-identical (same candidates, same order) to the historical
    /// nested push loops, pinned by `grid_expansion_matches_reference`.
    pub(crate) fn expand_flags(
        &self,
        points: &[StructuralPoint],
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
    ) -> Vec<EngineConfig> {
        self.candidate_grid(points, model, cluster, wl).to_vec()
    }

    /// The SoA form of [`Self::expand_flags`]: structural axes stored
    /// once per point, flag/placement variants as arena ranges. The
    /// sweep engine iterates this directly instead of materializing a
    /// `Vec<EngineConfig>` per scenario.
    pub(crate) fn candidate_grid(
        &self,
        points: &[StructuralPoint],
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
    ) -> CandidateGrid {
        CandidateGrid::build(self, points, model, cluster, wl)
    }

    /// The full engine grid for one workload: structural enumeration +
    /// per-point flag resolution, *before* any memory check.
    pub fn engine_grid(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
    ) -> Vec<EngineConfig> {
        self.expand_flags(&self.structural_grid(model, cluster), model, cluster, wl)
    }

    /// Enumerate all valid aggregated engine configurations (memory
    /// pruned against the workload's isl + `mem_osl` footprint —
    /// `mem_osl` is `wl.osl` for aggregated/decode pools and 1 for
    /// prefill pools, which hold only in-flight prompts).
    pub fn engines(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
        mem_osl: u32,
    ) -> Vec<EngineConfig> {
        let mem = cluster.gpu.mem_bytes();
        self.engine_grid(model, cluster, wl)
            .into_iter()
            .filter(|eng| memory::fits(model, mem, eng, wl.isl, mem_osl))
            .collect()
    }

    /// The prefill-pool sub-space: small batches, CUDA graphs pinned on
    /// — unless the caller overrode the graph axis explicitly, which
    /// wins for prefill pools too.
    pub fn prefill_space(&self) -> SearchSpace {
        let mut sub = self.clone();
        sub.batch = self.prefill_batch.clone();
        if sub.cuda_graph.is_empty() {
            sub.cuda_graph = vec![true];
        }
        sub
    }

    /// Prefill-pool engine variants (small batch, chunking irrelevant).
    pub fn prefill_engines(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
    ) -> Vec<EngineConfig> {
        // Prefill pool holds only in-flight prompts (osl = 1).
        self.prefill_space().engines(model, cluster, wl, 1)
    }
}

fn push_unique(v: &mut Vec<RuntimeFlags>, f: RuntimeFlags) {
    if !v.contains(&f) {
        v.push(f);
    }
}

/// Structure-of-arrays candidate grid: the workload-expanded engine
/// grid without one `EngineConfig` per candidate. The AoS expansion
/// repeats the structural axes (framework, dtype, layout, batch) and
/// the resolved flags across every placement variant; here each
/// structural point is stored once, its flag variants and placement
/// layouts live in shared arenas, and a candidate is just an index
/// decoded on demand. Candidate order is pinned to the historical
/// nested loops: points in input order, then placement-major /
/// flag-minor within a point (`cand = pl_idx · nflags + fl_idx`).
///
/// `get` is O(log points) for the point lookup (prefix-sum
/// `partition_point`); the sweep workers walk dense index slabs so the
/// lookup amortizes to the slab, and the decoded `EngineConfig` is a
/// stack copy — no per-candidate heap traffic at all.
#[derive(Clone, Debug)]
pub(crate) struct CandidateGrid {
    /// Structural axes, one entry per grid point.
    points: Vec<StructuralPoint>,
    /// Flag-variant arena; point `p` owns `flag_ranges[p]`.
    flags: Vec<RuntimeFlags>,
    /// (arena start, variant count) per point.
    flag_ranges: Vec<(u32, u32)>,
    /// Placement arena; point `p` owns `place_ranges[p]`.
    placements: Vec<Placement>,
    /// (arena start, layout count) per point.
    place_ranges: Vec<(u32, u32)>,
    /// Prefix sums of candidates per point; the final entry is the
    /// total candidate count.
    cand_start: Vec<u32>,
}

impl CandidateGrid {
    pub(crate) fn build(
        space: &SearchSpace,
        points: &[StructuralPoint],
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
    ) -> CandidateGrid {
        let mut flags = Vec::new();
        let mut flag_ranges = Vec::with_capacity(points.len());
        let mut placements = Vec::new();
        let mut place_ranges = Vec::with_capacity(points.len());
        let mut cand_start = Vec::with_capacity(points.len() + 1);
        cand_start.push(0u32);
        for point in points {
            // Flags are placement-independent: resolve once per point,
            // then expand the structural placement axis — how the
            // shape's ranks land on the fabric
            // ([`placement::enumerate`]; exactly [packed] on legacy
            // fabrics).
            let variants = space.flag_variants(model, cluster, wl, point);
            let layouts = placement::enumerate(cluster, &point.2);
            flag_ranges.push((flags.len() as u32, variants.len() as u32));
            place_ranges.push((placements.len() as u32, layouts.len() as u32));
            let total =
                cand_start.last().unwrap() + (layouts.len() * variants.len()) as u32;
            cand_start.push(total);
            flags.extend(variants);
            placements.extend(layouts);
        }
        CandidateGrid {
            points: points.to_vec(),
            flags,
            flag_ranges,
            placements,
            place_ranges,
            cand_start,
        }
    }

    /// Total candidate count across all points.
    pub(crate) fn len(&self) -> usize {
        self.cand_start.last().copied().unwrap_or(0) as usize
    }

    /// Decode candidate `i` — placement-major, flag-minor within its
    /// structural point, the exact push order of the nested-loop
    /// expansion this grid replaced.
    pub(crate) fn get(&self, i: usize) -> EngineConfig {
        debug_assert!(i < self.len(), "candidate index {i} out of {}", self.len());
        let i = i as u32;
        // First point whose prefix sum exceeds `i`, minus one: the
        // point that owns this candidate.
        let p = self.cand_start.partition_point(|&s| s <= i) - 1;
        let within = i - self.cand_start[p];
        let (flag_start, nflags) = self.flag_ranges[p];
        let (place_start, _) = self.place_ranges[p];
        let (fw, dt, par, b) = self.points[p];
        EngineConfig {
            framework: fw,
            parallel: par,
            batch: b,
            weight_dtype: dt,
            kv_dtype: dt,
            flags: self.flags[(flag_start + within % nflags) as usize],
            placement: self.placements[(place_start + within / nflags) as usize],
        }
    }

    /// Candidates in pinned order, decoded on the fly.
    pub(crate) fn iter(&self) -> impl Iterator<Item = EngineConfig> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Materialize the AoS form (compatibility surface for callers
    /// that genuinely need a vector, e.g. launch-file emission).
    pub(crate) fn to_vec(&self) -> Vec<EngineConfig> {
        self.iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{h100_sxm, h200_sxm};
    use crate::models::by_name;

    fn wl(isl: u32, osl: u32) -> WorkloadSpec {
        WorkloadSpec::new("m", isl, osl, 1500.0, 20.0)
    }

    #[test]
    fn dense_model_never_gets_ep() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        assert_eq!(s.ep, vec![1]);
        let mut s2 = s.clone();
        s2.ep = vec![1, 4];
        let engines = s2.engines(&m, &c, &wl(1024, 128), 128);
        assert!(engines.iter().all(|e| e.parallel.ep == 1));
    }

    #[test]
    fn tp_must_divide_heads() {
        let m = by_name("qwen3-32b").unwrap(); // 64 heads
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        assert!(SearchSpace::layout_valid(&m, &c, &ParallelSpec::tp(8)));
        assert!(!SearchSpace::layout_valid(
            &m,
            &c,
            &ParallelSpec { tp: 3, pp: 1, ep: 1, dp: 1 }
        ));
    }

    #[test]
    fn memory_prunes_infeasible_batches() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let mut s = SearchSpace::default_for(&m, Framework::TrtLlm);
        s.dtypes = vec![Dtype::Fp16];
        s.batch = vec![1, 4096];
        let engines = s.engines(&m, &c, &wl(4096, 512), 512);
        assert!(!engines.is_empty());
        assert!(engines.iter().all(|e| e.batch == 1 || e.parallel.tp >= 4));
    }

    #[test]
    fn cluster_size_bounds_layouts() {
        let m = by_name("llama3.1-8b").unwrap();
        let c = ClusterSpec::new(h200_sxm(), 4, 1);
        let s = SearchSpace::default_for(&m, Framework::Vllm);
        let engines = s.engines(&m, &c, &wl(1024, 128), 128);
        assert!(engines.iter().all(|e| e.parallel.gpus() <= 4));
    }

    #[test]
    fn unsupported_dtype_list_falls_back_to_preferred() {
        use crate::hardware::a100_sxm;
        use crate::models::Dtype;
        let m = by_name("llama3.1-8b").unwrap();
        let c = ClusterSpec::new(a100_sxm(), 8, 1);
        // Default space sweeps FP8 only; Ampere has no FP8 tensor
        // cores — the grid must fall back to FP16, not come up empty.
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        assert_eq!(s.dtypes, vec![Dtype::Fp8]);
        let grid = s.engine_grid(&m, &c, &wl(1024, 128));
        assert!(!grid.is_empty());
        assert!(grid.iter().all(|e| e.weight_dtype == Dtype::Fp16));
        // A space that names a supported dtype is untouched.
        let h = ClusterSpec::new(crate::hardware::h100_sxm(), 8, 1);
        assert!(s
            .engine_grid(&m, &h, &wl(1024, 128))
            .iter()
            .all(|e| e.weight_dtype == Dtype::Fp8));
    }

    #[test]
    fn moe_gets_ep_variants() {
        let m = by_name("qwen3-235b").unwrap();
        let c = ClusterSpec::new(h200_sxm(), 8, 1);
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        let engines = s.engines(&m, &c, &wl(2048, 256), 256);
        assert!(engines.iter().any(|e| e.parallel.ep > 1));
        // ep ≤ tp·dp convention.
        assert!(engines.iter().all(|e| e.parallel.ep <= e.parallel.tp * e.parallel.dp));
    }

    #[test]
    fn tiered_fabric_widens_grid_with_placements() {
        use crate::topology::{fabric, Placement};
        let m = by_name("qwen3-32b").unwrap();
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        let tiered = ClusterSpec::with_fabric(h100_sxm(), 8, 2, fabric::hgx_h100());
        let mut s = SearchSpace::default_for(&m, Framework::TrtLlm);
        s.tp = vec![8];
        s.pp = vec![1, 2];
        let w = wl(2048, 256);
        // Legacy: every engine is packed (seed grid), one per point.
        let g_legacy = s.engine_grid(&m, &legacy, &w);
        assert!(g_legacy.iter().all(|e| e.placement == Placement::packed()));
        assert_eq!(g_legacy.len(), s.structural_grid(&m, &legacy).len());
        // Tiered: the same TP8PP2 shape expands into several layouts…
        let g_tiered = s.engine_grid(&m, &tiered, &w);
        assert!(g_tiered.len() > g_legacy.len());
        let shape = ParallelSpec { tp: 8, pp: 2, ep: 1, dp: 1 };
        let layouts: std::collections::HashSet<Placement> = g_tiered
            .iter()
            .filter(|e| e.parallel == shape)
            .map(|e| e.placement)
            .collect();
        assert!(layouts.len() >= 2, "{layouts:?}");
        // …sharing one resolved flag set per structural point.
        for e in &g_tiered {
            let packed = g_tiered.iter().find(|o| {
                o.parallel == e.parallel
                    && o.batch == e.batch
                    && o.placement == Placement::packed()
            });
            if let Some(p0) = packed {
                assert_eq!(p0.flags, e.flags, "placements must not fork the flags");
            }
        }
    }

    #[test]
    fn default_grid_carries_resolved_flags() {
        // The default space resolves flags analytically: the grid must
        // contain kv_frac / max_num_tokens values that differ from the
        // framework defaults (TP-dependent), with exactly one flag
        // variant per structural point.
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        let w = WorkloadSpec::new("qwen3-32b", 4000, 500, 1200.0, 40.0);
        let grid = s.engine_grid(&m, &c, &w);
        let structural = s.structural_grid(&m, &c);
        assert_eq!(grid.len(), structural.len());
        let d = RuntimeFlags::defaults_for(Framework::TrtLlm);
        assert!(
            grid.iter().any(|e| e.flags.kv_frac != d.kv_frac
                || e.flags.max_num_tokens != d.max_num_tokens),
            "resolved grid must leave the default flag point"
        );
        // kv_frac varies with the layout's weight footprint.
        let kv_tp1 = grid.iter().find(|e| e.parallel.tp == 1).unwrap().flags.kv_frac;
        let kv_tp8 = grid.iter().find(|e| e.parallel.tp == 8).unwrap().flags.kv_frac;
        assert!(kv_tp1 < kv_tp8);
    }

    #[test]
    fn explicit_overrides_are_honored() {
        let m = by_name("llama3.1-8b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let mut s = SearchSpace::default_for(&m, Framework::Vllm);
        s.cuda_graph = vec![true, false];
        s.max_num_tokens = vec![4096];
        s.kv_frac = vec![0.8];
        let w = wl(2048, 256);
        let grid = s.engine_grid(&m, &c, &w);
        assert!(grid.iter().all(|e| e.flags.max_num_tokens == 4096));
        assert!(grid.iter().all(|e| e.flags.kv_frac == 0.8));
        assert!(grid.iter().any(|e| e.flags.cuda_graph));
        assert!(grid.iter().any(|e| !e.flags.cuda_graph));
        // Two graph variants per structural point, nothing more.
        assert_eq!(grid.len(), 2 * s.structural_grid(&m, &c).len());
    }

    #[test]
    fn overridden_capacity_rederives_chunking() {
        // A capacity override implies a chunking decision: the model
        // and the emitted launch files must agree on it.
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let mut s = SearchSpace::default_for(&m, Framework::TrtLlm);
        let w = WorkloadSpec::new("qwen3-32b", 4000, 500, f64::INFINITY, 0.0);
        // Capacity above the prompt → no chunking anywhere.
        s.max_num_tokens = vec![8192];
        assert!(s.engine_grid(&m, &c, &w).iter().all(|e| !e.flags.chunked_prefill));
        // Capacity below the prompt → chunking on everywhere.
        s.max_num_tokens = vec![1024];
        assert!(s
            .engine_grid(&m, &c, &w)
            .iter()
            .all(|e| e.flags.chunked_prefill && e.flags.max_num_tokens == 1024));
    }

    #[test]
    fn prefill_space_honors_explicit_graph_override() {
        let m = by_name("llama3.1-8b").unwrap();
        let mut s = SearchSpace::default_for(&m, Framework::TrtLlm);
        // No override: prefill pins graphs on.
        assert_eq!(s.prefill_space().cuda_graph, vec![true]);
        // Explicit override wins for the prefill pool too.
        s.cuda_graph = vec![false];
        assert_eq!(s.prefill_space().cuda_graph, vec![false]);
    }

    /// The SoA [`CandidateGrid`] must reproduce the historical AoS
    /// expansion exactly — same candidates, same order — across dense
    /// and MoE models, legacy and tiered fabrics, flag sweeps and
    /// explicit overrides. The reference here is the literal nested
    /// push loop the grid replaced.
    #[test]
    fn grid_expansion_matches_reference() {
        use crate::topology::fabric;
        let reference = |s: &SearchSpace,
                         points: &[StructuralPoint],
                         m: &ModelArch,
                         c: &ClusterSpec,
                         w: &WorkloadSpec|
         -> Vec<EngineConfig> {
            let mut out = Vec::new();
            for point in points {
                let (fw, dt, p, b) = *point;
                let variants = s.flag_variants(m, c, w, point);
                for pl in placement::enumerate(c, &p) {
                    for &flags in &variants {
                        out.push(EngineConfig {
                            framework: fw,
                            parallel: p,
                            batch: b,
                            weight_dtype: dt,
                            kv_dtype: dt,
                            flags,
                            placement: pl,
                        });
                    }
                }
            }
            out
        };
        let dense = by_name("qwen3-32b").unwrap();
        let moe = by_name("qwen3-235b").unwrap();
        let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
        let tiered = ClusterSpec::with_fabric(h100_sxm(), 8, 2, fabric::hgx_h100());
        let w = wl(4000, 500);
        for (m, c) in [(&dense, &legacy), (&dense, &tiered), (&moe, &tiered)] {
            let mut spaces = vec![SearchSpace::default_for(m, Framework::TrtLlm)];
            let mut sweep = SearchSpace::default_for(m, Framework::Vllm);
            sweep.flag_sweep = true;
            sweep.pp = vec![1, 2];
            spaces.push(sweep);
            let mut over = SearchSpace::default_for(m, Framework::Sglang);
            over.cuda_graph = vec![true, false];
            over.max_num_tokens = vec![2048, 8192];
            spaces.push(over);
            for s in &spaces {
                let points = s.structural_grid(m, c);
                let want = reference(s, &points, m, c, &w);
                let grid = s.candidate_grid(&points, m, c, &w);
                assert_eq!(grid.len(), want.len());
                assert_eq!(grid.to_vec(), want, "SoA expansion diverged");
                // Random access decodes the same candidate as the
                // sequential walk.
                for i in [0, want.len() / 3, want.len() / 2, want.len() - 1] {
                    assert_eq!(grid.get(i), want[i], "get({i})");
                }
                // And the delegating Vec surface is the grid.
                assert_eq!(s.expand_flags(&points, m, c, &w), want);
            }
        }
    }

    #[test]
    fn flag_sweep_adds_default_and_nograph_variants() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let mut s = SearchSpace::default_for(&m, Framework::TrtLlm);
        s.flag_sweep = true;
        let w = WorkloadSpec::new("qwen3-32b", 4000, 500, 1200.0, 40.0);
        let grid = s.engine_grid(&m, &c, &w);
        let plain = {
            let mut p = s.clone();
            p.flag_sweep = false;
            p.engine_grid(&m, &c, &w)
        };
        assert!(grid.len() > plain.len(), "sweep must widen the grid");
        let d = RuntimeFlags::defaults_for(Framework::TrtLlm);
        assert!(grid.iter().any(|e| e.flags == d), "defaults variant present");
        assert!(grid.iter().any(|e| !e.flags.cuda_graph), "no-graph variant present");
        // Multiple token-capacity points around the resolved one.
        let mnts: std::collections::HashSet<u32> =
            grid.iter().map(|e| e.flags.max_num_tokens).collect();
        assert!(mnts.len() >= 2, "{mnts:?}");
    }
}

//! Search-space enumeration with validity + memory pruning.
//!
//! Dimensions: framework × TP × PP × EP × DP × batch × quantization ×
//! runtime flags (CUDA graph, max-num-tokens) × serving mode — "from
//! cluster topology down to engine specific flags" (paper §1).

use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags, ServingMode};
use crate::frameworks::Framework;
use crate::hardware::ClusterSpec;
use crate::models::{Dtype, ModelArch};
use crate::perfmodel::memory;

/// Declarative search space. Empty vectors mean "use defaults".
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub frameworks: Vec<Framework>,
    pub tp: Vec<u32>,
    pub pp: Vec<u32>,
    pub ep: Vec<u32>,
    pub dp: Vec<u32>,
    pub batch: Vec<u32>,
    pub dtypes: Vec<Dtype>,
    pub cuda_graph: Vec<bool>,
    pub max_num_tokens: Vec<u32>,
    pub modes: Vec<ServingMode>,
    /// Disaggregated sweep bounds (x ∈ [1, max_x], y ∈ [1, max_y] —
    /// paper Algorithm 3 uses 32 / 64).
    pub max_x: u32,
    pub max_y: u32,
    /// Prefill-pool batch sizes (kept small: prefill is compute-bound).
    pub prefill_batch: Vec<u32>,
}

impl SearchSpace {
    /// The paper's default sweep (§5.1): TP/EP ∈ {1,2,4,8},
    /// batch 4–128, aggregated + disaggregated.
    pub fn default_for(model: &ModelArch, framework: Framework) -> SearchSpace {
        SearchSpace {
            frameworks: vec![framework],
            tp: vec![1, 2, 4, 8],
            pp: vec![1],
            ep: if model.is_moe() { vec![1, 2, 4, 8] } else { vec![1] },
            dp: vec![1],
            batch: vec![4, 8, 16, 32, 64, 128],
            dtypes: vec![Dtype::Fp8],
            cuda_graph: vec![true],
            max_num_tokens: vec![8192],
            modes: vec![ServingMode::Aggregated, ServingMode::Disaggregated],
            max_x: 32,
            max_y: 64,
            prefill_batch: vec![1, 2, 4],
        }
    }

    /// Is an engine layout structurally valid for this model/cluster?
    pub fn layout_valid(model: &ModelArch, cluster: &ClusterSpec, p: &ParallelSpec) -> bool {
        if p.tp == 0 || p.pp == 0 || p.dp == 0 {
            return false;
        }
        // TP must divide the head count.
        if model.heads % p.tp as u64 != 0 {
            return false;
        }
        // PP must divide layers.
        if model.num_layers % p.pp as u64 != 0 {
            return false;
        }
        // Engine must fit the cluster.
        if p.gpus() > cluster.total_gpus() {
            return false;
        }
        // EP only for MoE; experts shard across the TP×DP group.
        if p.ep > 1 {
            match &model.moe {
                None => return false,
                Some(m) => {
                    if p.ep as u64 > m.num_experts
                        || m.num_experts % p.ep as u64 != 0
                        || p.ep > p.tp * p.dp
                    {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Enumerate the **structural** engine grid: every framework ×
    /// dtype × layout × flag × batch combination that is valid for the
    /// model and cluster, *before* any workload-dependent memory check.
    /// Batch sweeps ([`crate::search::TaskRunner::run_sweep`]) enumerate
    /// this once and re-filter per scenario, since only the memory prune
    /// depends on (ISL, OSL).
    pub fn engine_grid(&self, model: &ModelArch, cluster: &ClusterSpec) -> Vec<EngineConfig> {
        let mut out = Vec::new();
        for &fw in &self.frameworks {
            let fw_prof = fw.profile();
            // Dtypes this GPU *and* framework can run, from the
            // requested list. When none qualify (the FP8-only default
            // on Ampere), fall back to the GPU's preferred dtype so
            // every surface — search, sweep, capacity plan — enumerates
            // a non-empty grid on older parts instead of silently
            // finding nothing.
            let mut dtypes: Vec<Dtype> = self
                .dtypes
                .iter()
                .copied()
                .filter(|&dt| cluster.gpu.supports(dt) && fw_prof.supports_dtype(dt))
                .collect();
            if dtypes.is_empty() {
                let fb = cluster.gpu.preferred_kv_dtype();
                if cluster.gpu.supports(fb) && fw_prof.supports_dtype(fb) {
                    dtypes.push(fb);
                }
            }
            for &dt in &dtypes {
                for &tp in &self.tp {
                    for &pp in &self.pp {
                        for &ep in &self.ep {
                            for &dp in &self.dp {
                                let p = ParallelSpec { tp, pp, ep, dp };
                                if !Self::layout_valid(model, cluster, &p) {
                                    continue;
                                }
                                for &mnt in &self.max_num_tokens {
                                    for &cg in &self.cuda_graph {
                                        for &b in &self.batch {
                                            out.push(EngineConfig {
                                                framework: fw,
                                                parallel: p,
                                                batch: b,
                                                weight_dtype: dt,
                                                kv_dtype: dt,
                                                flags: RuntimeFlags {
                                                    cuda_graph: cg,
                                                    kv_frac: fw_prof.kv_frac_default,
                                                    max_num_tokens: mnt,
                                                    chunked_prefill: fw_prof
                                                        .chunked_prefill_default,
                                                },
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Enumerate all valid aggregated engine configurations (memory
    /// pruned against the workload's isl+osl footprint).
    pub fn engines(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        isl: u32,
        osl: u32,
    ) -> Vec<EngineConfig> {
        let mem = cluster.gpu.mem_bytes();
        self.engine_grid(model, cluster)
            .into_iter()
            .filter(|eng| memory::fits(model, mem, eng, isl, osl))
            .collect()
    }

    /// The prefill-pool sub-space (small batches, CUDA graphs pinned on).
    pub fn prefill_space(&self) -> SearchSpace {
        let mut sub = self.clone();
        sub.batch = self.prefill_batch.clone();
        sub.cuda_graph = vec![true];
        sub
    }

    /// Prefill-pool engine variants (small batch, chunking irrelevant).
    pub fn prefill_engines(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        isl: u32,
    ) -> Vec<EngineConfig> {
        // Prefill pool holds only in-flight prompts (osl = 1).
        self.prefill_space().engines(model, cluster, isl, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{h100_sxm, h200_sxm};
    use crate::models::by_name;

    #[test]
    fn dense_model_never_gets_ep() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        assert_eq!(s.ep, vec![1]);
        let mut s2 = s.clone();
        s2.ep = vec![1, 4];
        let engines = s2.engines(&m, &c, 1024, 128);
        assert!(engines.iter().all(|e| e.parallel.ep == 1));
    }

    #[test]
    fn tp_must_divide_heads() {
        let m = by_name("qwen3-32b").unwrap(); // 64 heads
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        assert!(SearchSpace::layout_valid(&m, &c, &ParallelSpec::tp(8)));
        assert!(!SearchSpace::layout_valid(
            &m,
            &c,
            &ParallelSpec { tp: 3, pp: 1, ep: 1, dp: 1 }
        ));
    }

    #[test]
    fn memory_prunes_infeasible_batches() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let mut s = SearchSpace::default_for(&m, Framework::TrtLlm);
        s.dtypes = vec![Dtype::Fp16];
        s.batch = vec![1, 4096];
        let engines = s.engines(&m, &c, 4096, 512);
        assert!(!engines.is_empty());
        assert!(engines.iter().all(|e| e.batch == 1 || e.parallel.tp >= 4));
    }

    #[test]
    fn cluster_size_bounds_layouts() {
        let m = by_name("llama3.1-8b").unwrap();
        let c = ClusterSpec::new(h200_sxm(), 4, 1);
        let s = SearchSpace::default_for(&m, Framework::Vllm);
        let engines = s.engines(&m, &c, 1024, 128);
        assert!(engines.iter().all(|e| e.parallel.gpus() <= 4));
    }

    #[test]
    fn unsupported_dtype_list_falls_back_to_preferred() {
        use crate::hardware::a100_sxm;
        use crate::models::Dtype;
        let m = by_name("llama3.1-8b").unwrap();
        let c = ClusterSpec::new(a100_sxm(), 8, 1);
        // Default space sweeps FP8 only; Ampere has no FP8 tensor
        // cores — the grid must fall back to FP16, not come up empty.
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        assert_eq!(s.dtypes, vec![Dtype::Fp8]);
        let grid = s.engine_grid(&m, &c);
        assert!(!grid.is_empty());
        assert!(grid.iter().all(|e| e.weight_dtype == Dtype::Fp16));
        // A space that names a supported dtype is untouched.
        let h = ClusterSpec::new(crate::hardware::h100_sxm(), 8, 1);
        assert!(s.engine_grid(&m, &h).iter().all(|e| e.weight_dtype == Dtype::Fp8));
    }

    #[test]
    fn moe_gets_ep_variants() {
        let m = by_name("qwen3-235b").unwrap();
        let c = ClusterSpec::new(h200_sxm(), 8, 1);
        let s = SearchSpace::default_for(&m, Framework::TrtLlm);
        let engines = s.engines(&m, &c, 2048, 256);
        assert!(engines.iter().any(|e| e.parallel.ep > 1));
        // ep ≤ tp·dp convention.
        assert!(engines.iter().all(|e| e.parallel.ep <= e.parallel.tp * e.parallel.dp));
    }
}

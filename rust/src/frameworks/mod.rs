//! Inference-framework backends (paper §3 "Framework Heterogeneity" +
//! §1's "abstraction layer that automatically resolves optimal launch
//! parameters for the target backend").
//!
//! Each framework exhibits distinct performance characteristics the
//! paper calls out: TensorRT-LLM (static graph optimization, custom
//! kernels), vLLM (PagedAttention, Python-based scheduling), SGLang
//! (RadixAttention, Triton kernels). All per-framework behaviour —
//! the performance profile, dtype support, scheduling overheads,
//! launch-file emission and analytic flag resolution — lives behind
//! the [`Backend`] trait ([`backend`]), with one module per framework
//! ([`trtllm`], [`vllm`], [`sglang`]). The [`Framework`] enum remains
//! the cheap `Copy` tag that configs and wire formats carry;
//! [`Framework::backend`] is the bridge to the behaviour.
//!
//! The profiles parameterize *both* sides of the fidelity experiments:
//! the synthetic silicon (ground truth) applies them exactly, while the
//! PerfDatabase observes them only through noisy grid profiling — the
//! same epistemic split as paper-vs-real-hardware (DESIGN.md).

pub mod backend;
pub mod sglang;
pub mod trtllm;
pub mod vllm;

pub use backend::{backend_for, Backend, FlagPolicy};

use crate::models::Dtype;

/// Supported inference backends (the tag; behaviour lives in
/// [`Backend`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Framework {
    TrtLlm,
    Vllm,
    Sglang,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::TrtLlm => "trtllm",
            Framework::Vllm => "vllm",
            Framework::Sglang => "sglang",
        }
    }

    pub fn parse(s: &str) -> Option<Framework> {
        match s.to_ascii_lowercase().as_str() {
            "trtllm" | "trt-llm" | "tensorrt-llm" => Some(Framework::TrtLlm),
            "vllm" => Some(Framework::Vllm),
            "sglang" => Some(Framework::Sglang),
            _ => None,
        }
    }

    pub fn all() -> [Framework; 3] {
        [Framework::TrtLlm, Framework::Vllm, Framework::Sglang]
    }

    /// The behaviour behind this tag.
    pub fn backend(self) -> &'static dyn Backend {
        backend_for(self)
    }

    pub fn profile(self) -> FrameworkProfile {
        self.backend().profile()
    }
}

/// Performance-relevant behaviour of a serving engine.
#[derive(Clone, Copy, Debug)]
pub struct FrameworkProfile {
    pub framework: Framework,
    /// GEMM kernel efficiency vs roofline (framework kernel quality).
    pub gemm_eff: f64,
    /// Prefill attention kernel efficiency (FlashAttention-class).
    pub attn_prefill_eff: f64,
    /// Decode attention kernel efficiency (XQA/PagedAttention-class).
    pub attn_decode_eff: f64,
    /// MoE grouped-GEMM efficiency.
    pub moe_eff: f64,
    /// Host scheduling overhead per iteration, microseconds
    /// (vLLM's Python scheduler is the outlier the paper highlights).
    pub sched_overhead_us: f64,
    /// Additional per-kernel launch overhead multiplier when CUDA graphs
    /// are OFF (decode iterations launch hundreds of small kernels).
    pub no_cudagraph_launch_penalty: f64,
    /// Fraction of scheduling overhead removed by CUDA graphs in decode.
    pub cudagraph_saving: f64,
    /// Default fraction of free GPU memory given to the KV cache
    /// (`--kv_cache_free_gpu_mem_fraction` and friends).
    pub kv_frac_default: f64,
    /// Whether chunked prefill is on by default.
    pub chunked_prefill_default: bool,
    /// Default max-num-tokens (context capacity C_ctx) per iteration.
    pub max_num_tokens_default: u32,
}

/// Profile lookup (kept for callers that predate the trait; the data
/// lives in each backend module).
pub fn profile(fw: Framework) -> FrameworkProfile {
    fw.backend().profile()
}

impl FrameworkProfile {
    /// Quantization formats the engine can serve.
    pub fn supports_dtype(&self, dt: Dtype) -> bool {
        self.framework.backend().supports_dtype(dt)
    }

    /// Host overhead of one iteration, given CUDA-graph state and phase.
    pub fn iter_host_overhead_us(&self, cuda_graph: bool, decode_only: bool) -> f64 {
        if decode_only && cuda_graph {
            self.sched_overhead_us * (1.0 - self.cudagraph_saving)
        } else {
            self.sched_overhead_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Framework::parse("TensorRT-LLM"), Some(Framework::TrtLlm));
        assert_eq!(Framework::parse("vllm"), Some(Framework::Vllm));
        assert_eq!(Framework::parse("sglang"), Some(Framework::Sglang));
        assert_eq!(Framework::parse("orca"), None);
    }

    #[test]
    fn vllm_python_scheduler_is_heaviest() {
        let t = profile(Framework::TrtLlm);
        let v = profile(Framework::Vllm);
        let s = profile(Framework::Sglang);
        assert!(v.sched_overhead_us > s.sched_overhead_us);
        assert!(s.sched_overhead_us > t.sched_overhead_us);
    }

    #[test]
    fn cudagraph_reduces_decode_overhead() {
        let p = profile(Framework::Vllm);
        assert!(
            p.iter_host_overhead_us(true, true) < p.iter_host_overhead_us(false, true)
        );
        // Mixed iterations don't benefit (graphs capture decode shapes).
        assert_eq!(
            p.iter_host_overhead_us(true, false),
            p.iter_host_overhead_us(false, false)
        );
    }

    #[test]
    fn dtype_support() {
        assert!(profile(Framework::TrtLlm).supports_dtype(Dtype::Int4));
        assert!(!profile(Framework::Vllm).supports_dtype(Dtype::Int4));
        assert!(profile(Framework::Sglang).supports_dtype(Dtype::Fp8));
    }

    #[test]
    fn profile_tag_round_trips_through_backend() {
        for fw in Framework::all() {
            assert_eq!(fw.profile().framework, fw);
            assert_eq!(fw.backend().framework(), fw);
        }
    }
}

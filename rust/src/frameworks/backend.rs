//! The backend abstraction layer (paper §1: "an abstraction layer that
//! automatically resolves optimal launch parameters for the target
//! backend").
//!
//! [`Backend`] is the single owner of everything that used to be keyed
//! on `match framework` across the codebase: the performance profile,
//! dtype support, the scheduling-overhead model, launch-file emission
//! (previously `generator/{trtllm,vllm,sglang}.rs`) and — the layer's
//! point — **analytic launch-flag resolution**. Instead of
//! cross-producting `kv_frac × max_num_tokens × cuda_graph ×
//! chunked_prefill` into the search grid (which would multiply the
//! candidate count by ~50), [`Backend::resolve_flags`] derives each
//! flag from the deployment's physics:
//!
//! * `kv_frac` from the memory model's actual weight footprint
//!   ([`crate::perfmodel::memory`]): whatever HBM remains after weights
//!   and the activation/runtime headroom goes to the KV cache, so
//!   low-TP layouts (heavy per-GPU weights) resolve a *smaller*
//!   fraction and high-TP layouts a larger one than the one-size
//!   default.
//! * `max_num_tokens` from the TTFT budget and chunked-prefill
//!   scheduling dynamics: small chunks minimize prefill/decode
//!   interference (TPOT) but multiply the mixed-step count Algorithm 2
//!   charges TTFT for — the resolver picks the smallest capacity whose
//!   predicted first-token latency still clears the SLA.
//! * `cuda_graph` / `chunked_prefill` from per-backend policy
//!   ([`FlagPolicy`]): graph capture pays off until its per-shape
//!   memory cost outgrows the launch savings (a batch-size bound that
//!   differs per runtime), and chunking only matters once a prompt
//!   exceeds the iteration capacity.
//!
//! Adding a fourth framework is one new module implementing this trait
//! plus a row in [`backend_for`] — no other file changes.

use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags, WorkloadSpec};
use crate::hardware::ClusterSpec;
use crate::models::{Dtype, ModelArch};
use crate::perfmodel::memory;

use super::{Framework, FrameworkProfile};

/// Per-backend policy constants steering analytic flag resolution.
/// These encode *runtime behaviour* (allocator slack, graph-capture
/// economics), not silicon performance — that stays in
/// [`FrameworkProfile`].
#[derive(Clone, Copy, Debug)]
pub struct FlagPolicy {
    /// Runtime headroom the allocator needs beyond the global
    /// activation reserve, bytes (CUDA-graph capture pools, NCCL
    /// buffers, fragmentation slack).
    pub runtime_headroom_bytes: f64,
    /// Peak activation bytes per in-flight token, per hidden dim, per
    /// weight byte (bounds the chunked-prefill working set that must
    /// stay outside the KV budget).
    pub act_bytes_per_token_hidden: f64,
    /// Clamp for the resolved KV fraction.
    pub kv_frac_floor: f64,
    pub kv_frac_ceil: f64,
    /// Share of the TTFT budget the resolver lets chunk scheduling
    /// consume when sizing `max_num_tokens`.
    pub chunk_ttft_share: f64,
    /// Token-capacity clamp and rounding quantum.
    pub min_tokens: u32,
    pub max_tokens: u32,
    /// CUDA-graph capture is enabled up to this decode batch size
    /// (capture memory and replay-table cost grow with batch).
    pub cuda_graph_max_batch: u32,
    /// Whether the runtime supports chunked prefill at all.
    pub supports_chunked_prefill: bool,
}

/// A serving framework behind the abstraction layer.
pub trait Backend: Send + Sync {
    /// The enum tag this backend implements.
    fn framework(&self) -> Framework;

    /// Kernel-efficiency / scheduling profile (synthetic-silicon
    /// parameterization; see DESIGN.md).
    fn profile(&self) -> FrameworkProfile;

    /// Quantization formats the engine can serve.
    fn supports_dtype(&self, dt: Dtype) -> bool;

    /// Launch-flag resolution policy constants.
    fn flag_policy(&self) -> FlagPolicy;

    /// Launch-file emission for one engine pool: (filename, contents)
    /// pairs, `role` ∈ {"server", "prefill", "decode"}. Absorbs the
    /// old `generator/{trtllm,vllm,sglang}.rs` free functions.
    fn emit_launch(
        &self,
        eng: &EngineConfig,
        model_hf_id: &str,
        wl: &WorkloadSpec,
        role: &str,
    ) -> Vec<(String, String)>;

    fn name(&self) -> &'static str {
        self.framework().name()
    }

    /// The framework's stock flags — the single construction point both
    /// [`RuntimeFlags::defaults_for`] and the search space route
    /// through, so the two can never drift again.
    fn default_flags(&self) -> RuntimeFlags {
        let p = self.profile();
        RuntimeFlags {
            cuda_graph: true,
            kv_frac: p.kv_frac_default,
            max_num_tokens: p.max_num_tokens_default,
            chunked_prefill: p.chunked_prefill_default,
        }
    }

    /// Analytically resolve the launch flags for one structural point
    /// (layout × batch × dtype) under a workload. Deterministic, cheap
    /// (no oracle queries) and backend-specific via [`FlagPolicy`].
    fn resolve_flags(
        &self,
        model: &ModelArch,
        cluster: &ClusterSpec,
        wl: &WorkloadSpec,
        parallel: &ParallelSpec,
        batch: u32,
        weight_dtype: Dtype,
    ) -> RuntimeFlags {
        let policy = self.flag_policy();
        let profile = self.profile();
        let max_num_tokens = resolve_max_num_tokens(
            &policy, &profile, model, cluster, wl, parallel, batch, weight_dtype,
        );
        let kv_frac = resolve_kv_frac(
            &policy, model, cluster, parallel, weight_dtype, max_num_tokens,
        );
        RuntimeFlags {
            cuda_graph: batch <= policy.cuda_graph_max_batch,
            kv_frac,
            max_num_tokens,
            // Chunking only matters once a prompt exceeds the iteration
            // capacity; below that it adds scheduler bookkeeping for
            // nothing.
            chunked_prefill: policy.supports_chunked_prefill && wl.isl > max_num_tokens,
        }
    }
}

/// Registry: the trait object for a framework tag. The only place a
/// new backend has to be wired in.
pub fn backend_for(fw: Framework) -> &'static dyn Backend {
    match fw {
        Framework::TrtLlm => &super::trtllm::TrtLlmBackend,
        Framework::Vllm => &super::vllm::VllmBackend,
        Framework::Sglang => &super::sglang::SglangBackend,
    }
}

/// First-order (roofline) prefill time per prompt token, milliseconds:
/// GEMM-bound forward pass of the *active* parameters sharded over TP.
/// PP stages pipeline across chunks, so they raise throughput but not
/// single-chunk latency; DP replicates. Good to the ~2× the resolver
/// needs — it sizes a budget share, it does not price candidates.
pub fn prefill_ms_per_token(
    profile: &FrameworkProfile,
    model: &ModelArch,
    cluster: &ClusterSpec,
    parallel: &ParallelSpec,
    weight_dtype: Dtype,
) -> f64 {
    let flops = 2.0 * model.active_params() as f64;
    let peak = cluster.gpu.tflops(weight_dtype) * 1e12 * profile.gemm_eff;
    flops / (parallel.tp.max(1) as f64 * peak) * 1e3
}

/// Predicted TTFT of chunked prefill at capacity `mnt`, following
/// Algorithm 2's shape: `ceil(ISL/C_ctx)` mixed steps, each costing the
/// chunk's roofline compute plus one host-scheduling interval, inflated
/// by the empirical F_corr (which grows as chunking stretches the
/// context backlog).
pub fn predicted_ttft_ms(
    profile: &FrameworkProfile,
    model: &ModelArch,
    cluster: &ClusterSpec,
    wl: &WorkloadSpec,
    parallel: &ParallelSpec,
    batch: u32,
    weight_dtype: Dtype,
    mnt: u32,
) -> f64 {
    let isl = wl.isl.max(1) as u64;
    let mnt = mnt.max(1) as u64;
    let per_tok = prefill_ms_per_token(profile, model, cluster, parallel, weight_dtype);
    let chunks = isl.div_ceil(mnt) as f64;
    let host_ms = profile.sched_overhead_us / 1000.0;
    let t_total_ctx = (isl * batch.max(1) as u64).div_ceil(mnt) as f64;
    let f_corr = (2.0 + (t_total_ctx - 3.0) / 20.0).clamp(1.0, 4.0);
    (per_tok * isl as f64 + chunks * host_ms) * f_corr
}

/// Smallest iteration token capacity whose predicted TTFT clears the
/// budget share. Small capacities minimize prefill/decode interference
/// (TPOT) and activation memory; the TTFT SLA is what forces them up.
#[allow(clippy::too_many_arguments)]
fn resolve_max_num_tokens(
    policy: &FlagPolicy,
    profile: &FrameworkProfile,
    model: &ModelArch,
    cluster: &ClusterSpec,
    wl: &WorkloadSpec,
    parallel: &ParallelSpec,
    batch: u32,
    weight_dtype: Dtype,
) -> u32 {
    // Decode streams share the iteration budget with the prefill chunk.
    let floor = policy.min_tokens.max(batch.next_power_of_two());
    let budget = wl.sla.ttft_ms * policy.chunk_ttft_share;
    let mut mnt = floor.min(policy.max_tokens);
    while mnt < policy.max_tokens {
        let pred = predicted_ttft_ms(
            profile, model, cluster, wl, parallel, batch, weight_dtype, mnt,
        );
        if pred <= budget {
            break;
        }
        mnt = (mnt * 2).min(policy.max_tokens);
    }
    mnt
}

/// KV fraction from the memory model: of the HBM left after weights and
/// the global activation reserve, keep back the runtime headroom plus
/// the chunk's activation working set, give the rest to KV. Low-TP
/// layouts (heavy per-GPU weights ⇒ small `free`) therefore resolve a
/// smaller fraction than high-TP layouts — exactly the dependence a
/// per-framework constant cannot express.
fn resolve_kv_frac(
    policy: &FlagPolicy,
    model: &ModelArch,
    cluster: &ClusterSpec,
    parallel: &ParallelSpec,
    weight_dtype: Dtype,
    max_num_tokens: u32,
) -> f64 {
    let mem = cluster.gpu.mem_bytes();
    let weights = memory::weight_bytes_per_gpu_parts(model, parallel, weight_dtype);
    let free = mem - weights - memory::ACT_RESERVE_BYTES;
    if free <= 0.0 {
        // Infeasible layouts keep the floor; the memory prune removes
        // them from the grid anyway.
        return policy.kv_frac_floor;
    }
    let act = max_num_tokens as f64
        * model.hidden as f64
        * policy.act_bytes_per_token_hidden
        * weight_dtype.bytes();
    let frac = (free - policy.runtime_headroom_bytes - act) / free;
    // Quantize to the 0.01 the launch files print, so emitted bundles
    // carry the resolved value bit-exactly.
    (frac.clamp(policy.kv_frac_floor, policy.kv_frac_ceil) * 100.0).floor() / 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::h100_sxm;
    use crate::models::by_name;

    fn wl(ttft_ms: f64) -> WorkloadSpec {
        WorkloadSpec::new("qwen3-32b", 4000, 500, ttft_ms, 40.0)
    }

    #[test]
    fn defaults_match_profiles_for_every_backend() {
        for fw in Framework::all() {
            let be = backend_for(fw);
            let d = be.default_flags();
            let p = be.profile();
            assert!(d.cuda_graph);
            assert_eq!(d.kv_frac, p.kv_frac_default, "{fw:?}");
            assert_eq!(d.max_num_tokens, p.max_num_tokens_default, "{fw:?}");
            assert_eq!(d.chunked_prefill, p.chunked_prefill_default, "{fw:?}");
            assert_eq!(be.framework(), fw);
            assert_eq!(be.name(), fw.name());
        }
    }

    #[test]
    fn kv_frac_shrinks_as_weights_grow() {
        // qwen3-32b on H100: TP1 holds ~33 GB of FP8 weights per GPU,
        // TP8 ~4 GB — the resolver must hand TP8 a larger KV share.
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let w = wl(1200.0);
        for fw in Framework::all() {
            let be = backend_for(fw);
            let f1 = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(1), 16, Dtype::Fp8);
            let f8 = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(8), 16, Dtype::Fp8);
            assert!(
                f1.kv_frac < f8.kv_frac,
                "{fw:?}: TP1 kv_frac {} !< TP8 kv_frac {}",
                f1.kv_frac,
                f8.kv_frac
            );
            let pol = be.flag_policy();
            for f in [f1, f8] {
                assert!(f.kv_frac >= pol.kv_frac_floor && f.kv_frac <= pol.kv_frac_ceil);
            }
        }
    }

    #[test]
    fn fp16_weights_shrink_kv_frac_vs_fp8() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let w = wl(1200.0);
        let be = backend_for(Framework::TrtLlm);
        let f8 = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(2), 16, Dtype::Fp8);
        let f16 = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(2), 16, Dtype::Fp16);
        assert!(f16.kv_frac < f8.kv_frac, "fp16 {} !< fp8 {}", f16.kv_frac, f8.kv_frac);
    }

    #[test]
    fn max_num_tokens_respects_ttft_budget() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        for fw in Framework::all() {
            let be = backend_for(fw);
            let pol = be.flag_policy();
            let prof = be.profile();
            let p = ParallelSpec::tp(1);
            // A loose budget lets the resolver keep chunks small; a
            // tight one forces capacity up (fewer, bigger chunks).
            let loose = be.resolve_flags(&m, &c, &wl(f64::INFINITY), &p, 16, Dtype::Fp8);
            let tight = be.resolve_flags(&m, &c, &wl(300.0), &p, 16, Dtype::Fp8);
            assert!(
                tight.max_num_tokens >= loose.max_num_tokens,
                "{fw:?}: tight {} < loose {}",
                tight.max_num_tokens,
                loose.max_num_tokens
            );
            // Whenever the budget is satisfiable inside the clamp, the
            // resolved capacity's predicted TTFT clears it.
            let w = wl(2000.0);
            let r = be.resolve_flags(&m, &c, &w, &p, 16, Dtype::Fp8);
            let pred = predicted_ttft_ms(
                &prof, &m, &c, &w, &p, 16, Dtype::Fp8, r.max_num_tokens,
            );
            if r.max_num_tokens < pol.max_tokens {
                assert!(
                    pred <= w.sla.ttft_ms * pol.chunk_ttft_share,
                    "{fw:?}: predicted {pred} ms over budget at mnt {}",
                    r.max_num_tokens
                );
            }
        }
    }

    #[test]
    fn capacity_never_below_batch_token_demand() {
        let m = by_name("llama3.1-8b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let be = backend_for(Framework::TrtLlm);
        let w = wl(f64::INFINITY);
        let f = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(1), 192, Dtype::Fp8);
        assert!(f.max_num_tokens >= 192);
    }

    #[test]
    fn chunked_prefill_tracks_prompt_vs_capacity() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let be = backend_for(Framework::TrtLlm);
        let p = ParallelSpec::tp(4);
        // Long prompt over a small resolved capacity → chunking on.
        let long = WorkloadSpec::new("qwen3-32b", 30_000, 500, f64::INFINITY, 0.0);
        let f = be.resolve_flags(&m, &c, &long, &p, 8, Dtype::Fp8);
        assert!(f.max_num_tokens < long.isl);
        assert!(f.chunked_prefill);
        // Short prompt that fits one iteration → chunking off.
        let short = WorkloadSpec::new("qwen3-32b", 512, 128, f64::INFINITY, 0.0);
        let f = be.resolve_flags(&m, &c, &short, &p, 8, Dtype::Fp8);
        assert!(f.max_num_tokens >= short.isl);
        assert!(!f.chunked_prefill);
    }

    #[test]
    fn cuda_graph_policy_differs_per_backend() {
        let caps: Vec<u32> =
            Framework::all().iter().map(|&fw| backend_for(fw).flag_policy().cuda_graph_max_batch).collect();
        // TRT-LLM's static-graph runtime captures far larger batches
        // than the Python-scheduled runtimes.
        assert!(caps[0] > caps[1] && caps[0] > caps[2], "{caps:?}");
    }

    #[test]
    fn resolution_is_deterministic() {
        let m = by_name("qwen3-32b").unwrap();
        let c = ClusterSpec::new(h100_sxm(), 8, 1);
        let w = wl(1200.0);
        let be = backend_for(Framework::Sglang);
        let a = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(2), 32, Dtype::Fp8);
        let b = be.resolve_flags(&m, &c, &w, &ParallelSpec::tp(2), 32, Dtype::Fp8);
        assert_eq!(a, b);
    }
}

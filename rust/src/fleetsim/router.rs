//! Fleet front door: assign each trace arrival to a live replica.
//!
//! Deterministic least-loaded routing: among the replicas of the
//! arrival's segment with an availability span covering the arrival
//! instant, pick the one with the least cumulative assigned work
//! (isl + osl tokens), ties to the lowest replica index. Requests that
//! find no live replica are dropped with a typed cause — the router is
//! where scale-lag and failure windows first become visible as lost
//! traffic.

use crate::workload::Request;

use super::lifecycle::ReplicaTimeline;
use super::report::Cause;

/// Where one request went.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Route {
    /// (timeline index, span index within that timeline).
    Assigned { timeline: usize, span: usize },
    /// No live replica at arrival; cause per the drop precedence
    /// (Failure > ScaleLag > Queueing).
    Dropped(Cause),
}

/// Route every request of a trace. `window_of` maps an arrival to its
/// plan window; `segment_of` maps a window to its segment index.
pub fn route(
    trace: &[Request],
    timelines: &[ReplicaTimeline],
    window_of: impl Fn(f64) -> usize,
    segment_of: impl Fn(usize) -> usize,
) -> Vec<Route> {
    let mut load = vec![0u64; timelines.len()];
    let mut out = Vec::with_capacity(trace.len());
    for r in trace {
        let seg = segment_of(window_of(r.arrival_ms));
        let mut best: Option<(usize, usize)> = None;
        let mut failed_down = false; // some replica is in failure downtime
        let mut lagging = false; // some replica is still launching
        for (ti, tl) in timelines.iter().enumerate() {
            if tl.segment != seg {
                continue;
            }
            let in_lag =
                tl.lag.iter().any(|&(a, b)| r.arrival_ms >= a && r.arrival_ms < b);
            if in_lag {
                lagging = true;
            }
            match tl.spans.iter().position(|s| s.contains(r.arrival_ms)) {
                Some(si) => {
                    let better = match best {
                        Some((bi, _)) => load[ti] < load[bi],
                        None => true,
                    };
                    if better {
                        best = Some((ti, si));
                    }
                }
                None => {
                    // Planned-up but spanless and not launching = the
                    // gap between a failure and its restart.
                    if !in_lag
                        && tl.spans.iter().any(|s| s.from_ms <= r.arrival_ms)
                        && tl.spans.iter().any(|s| s.to_ms > r.arrival_ms)
                    {
                        failed_down = true;
                    }
                }
            }
        }
        match best {
            Some((ti, si)) => {
                load[ti] += (r.isl + r.osl) as u64;
                out.push(Route::Assigned { timeline: ti, span: si });
            }
            None => {
                let cause = if failed_down {
                    Cause::Failure
                } else if lagging {
                    Cause::ScaleLag
                } else {
                    Cause::Queueing
                };
                out.push(Route::Dropped(cause));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleetsim::lifecycle::{Span, SpanEnd};

    fn tl(segment: usize, replica: usize, spans: Vec<Span>) -> ReplicaTimeline {
        ReplicaTimeline {
            segment,
            replica,
            spans,
            lag: Vec::new(),
            failures: Vec::new(),
            restarts: Vec::new(),
        }
    }

    fn req(id: u64, t: f64, tokens: u32) -> Request {
        Request { id, arrival_ms: t, isl: tokens, osl: 1 }
    }

    #[test]
    fn least_loaded_with_index_tiebreak() {
        let s = Span { from_ms: 0.0, to_ms: 1e9, end: SpanEnd::Horizon };
        let tls = vec![tl(0, 0, vec![s]), tl(0, 1, vec![s])];
        let trace =
            vec![req(0, 0.0, 100), req(1, 1.0, 10), req(2, 2.0, 10), req(3, 3.0, 10)];
        let routes = route(&trace, &tls, |_| 0, |_| 0);
        // Tie at start -> replica 0; then 1 (lighter); then 1 again
        // (10 < 100); then 0? loads: r0=100, r1=20 -> replica 1.
        assert_eq!(routes[0], Route::Assigned { timeline: 0, span: 0 });
        assert_eq!(routes[1], Route::Assigned { timeline: 1, span: 0 });
        assert_eq!(routes[2], Route::Assigned { timeline: 1, span: 0 });
        assert_eq!(routes[3], Route::Assigned { timeline: 1, span: 0 });
    }

    #[test]
    fn drops_are_cause_typed() {
        // Replica with a failure gap [10, 20) and a lag window [0, 5).
        let mut t = tl(
            0,
            0,
            vec![
                Span { from_ms: 5.0, to_ms: 10.0, end: SpanEnd::Failure },
                Span { from_ms: 20.0, to_ms: 30.0, end: SpanEnd::Horizon },
            ],
        );
        t.lag.push((0.0, 5.0));
        let tls = vec![t];
        let routes = route(
            &[req(0, 2.0, 8), req(1, 12.0, 8), req(2, 40.0, 8)],
            &tls,
            |_| 0,
            |_| 0,
        );
        assert_eq!(routes[0], Route::Dropped(Cause::ScaleLag));
        assert_eq!(routes[1], Route::Dropped(Cause::Failure));
        // After the last span: nothing planned-up -> queueing residual.
        assert_eq!(routes[2], Route::Dropped(Cause::Queueing));
    }
}

//! Replica lifecycle: when each replica of a deployment segment is
//! actually able to serve.
//!
//! The planner's schedule says *how many* replicas each window wants;
//! this module turns that into per-replica availability **spans** by
//! applying the physics the analytic plan ignores:
//!
//! - **Scale-up lag** — a replica whose up-interval starts after t=0
//!   (scale-out inside a segment, or a segment boundary swapping
//!   engines) spends `scale_lag_ms` launching before it serves. The
//!   horizon start is treated as pre-provisioned (no lag at t=0).
//! - **Failure injection** — with `failure_rate_per_replica_h > 0`,
//!   each replica draws exponential inter-failure times from its own
//!   deterministic stream; a failure hard-ends the span (in-flight
//!   requests are preempted), the replica restarts after `restart_ms`.
//!
//! Replica identity is per *segment* ([`DeploymentPlan::segments`]):
//! windows that deploy the same unit on the same GPU keep their
//! replicas; replica `r` is planned-up in window `w` iff
//! `r < fleet_size(w)`.

use crate::planner::DeploymentPlan;
use crate::util::rng::Rng;

use super::FleetConfig;

/// How an availability span ends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanEnd {
    /// The plan horizon ends; the replica drains in-flight work.
    Horizon,
    /// The schedule scales this replica in; it drains.
    ScaleDown,
    /// The segment ends (different unit next window); it drains.
    SegmentEnd,
    /// Injected failure: a hard stop. Requests still in flight at
    /// `to_ms` are preempted, not completed.
    Failure,
}

/// One contiguous run of serving time for one replica.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub from_ms: f64,
    pub to_ms: f64,
    pub end: SpanEnd,
}

impl Span {
    pub fn contains(&self, t_ms: f64) -> bool {
        t_ms >= self.from_ms && t_ms < self.to_ms
    }
}

/// One replica's full availability timeline inside a segment.
#[derive(Clone, Debug)]
pub struct ReplicaTimeline {
    /// Index into [`DeploymentPlan::segments`].
    pub segment: usize,
    /// Replica index within the segment's fleet.
    pub replica: usize,
    pub spans: Vec<Span>,
    /// Launch intervals `[start, start+lag)` during which this replica
    /// was planned-up but not yet serving (scale-lag attribution).
    pub lag: Vec<(f64, f64)>,
    /// Failure instants (events/report).
    pub failures: Vec<f64>,
    /// Successful restart instants (failure + downtime still inside an
    /// up-interval).
    pub restarts: Vec<f64>,
}

/// Decorrelate per-(segment, replica) failure streams while keeping
/// the degenerate stream 0 at (0, 0) irrelevant here (failures only
/// sample when the rate is positive).
fn failure_seed(base: u64, segment: usize, replica: usize) -> u64 {
    base ^ (segment as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (replica as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ 0xF1EE_7515
}

/// Effective fleet size of a window: scheduled units × engines per
/// unit (an aggregated unit may carry its own replica count; a
/// disaggregated unit is one xPyD composite).
pub fn fleet_size(plan: &DeploymentPlan, window: usize) -> usize {
    let w = &plan.windows[window];
    let per_unit = match &w.cand {
        crate::config::Candidate::Aggregated { replicas, .. } => (*replicas).max(1),
        crate::config::Candidate::Disaggregated { .. } => 1,
    };
    w.replicas as usize * per_unit as usize
}

/// Build every replica's timeline for every segment of the plan.
pub fn build_timelines(plan: &DeploymentPlan, cfg: &FleetConfig) -> Vec<ReplicaTimeline> {
    let window_ms = plan
        .windows
        .first()
        .map(|w| (w.t_end_h - w.t_start_h) * 3_600_000.0)
        .unwrap_or(0.0);
    let horizon_ms = plan.windows.len() as f64 * window_ms;
    let lag_ms = cfg.scale_lag_s * 1000.0;
    let restart_ms = cfg.restart_s * 1000.0;
    let rate_per_ms = cfg.failure_rate_per_replica_h / 3_600_000.0;

    let mut out = Vec::new();
    for (seg, (w0, w1)) in plan.segments().iter().copied().enumerate() {
        let fleet = (w0..=w1).map(|w| fleet_size(plan, w)).max().unwrap_or(0);
        for r in 0..fleet {
            // Raw planned-up intervals: maximal runs of windows wanting
            // replica r.
            let mut raw: Vec<(f64, f64, SpanEnd)> = Vec::new();
            let mut w = w0;
            while w <= w1 {
                if r < fleet_size(plan, w) {
                    let start = w as f64 * window_ms;
                    while w + 1 <= w1 && r < fleet_size(plan, w + 1) {
                        w += 1;
                    }
                    let to = (w + 1) as f64 * window_ms;
                    let end = if w + 1 >= plan.windows.len() {
                        SpanEnd::Horizon
                    } else if w == w1 {
                        SpanEnd::SegmentEnd
                    } else {
                        SpanEnd::ScaleDown
                    };
                    raw.push((start, to.min(horizon_ms), end));
                }
                w += 1;
            }

            let mut tl = ReplicaTimeline {
                segment: seg,
                replica: r,
                spans: Vec::new(),
                lag: Vec::new(),
                failures: Vec::new(),
                restarts: Vec::new(),
            };
            let mut rng = Rng::new(failure_seed(cfg.seed, seg, r));
            for (start, to, end) in raw {
                // Scale-up lag at every interval start except the
                // pre-provisioned horizon start.
                let mut from = start;
                if start > 0.0 && lag_ms > 0.0 {
                    let up = (start + lag_ms).min(to);
                    tl.lag.push((start, up));
                    from = up;
                }
                if from >= to {
                    continue;
                }
                if rate_per_ms <= 0.0 {
                    tl.spans.push(Span { from_ms: from, to_ms: to, end });
                    continue;
                }
                // Failure walk: exponential inter-failure gaps, hard
                // span end at each failure, restart after downtime.
                let mut t = from;
                loop {
                    let t_f = t + rng.exponential(rate_per_ms);
                    if t_f >= to {
                        tl.spans.push(Span { from_ms: t, to_ms: to, end });
                        break;
                    }
                    tl.spans.push(Span { from_ms: t, to_ms: t_f, end: SpanEnd::Failure });
                    tl.failures.push(t_f);
                    t = t_f + restart_ms;
                    if t >= to {
                        break;
                    }
                    tl.restarts.push(t);
                }
            }
            out.push(tl);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Candidate;
    use crate::planner::testutil::opt;
    use crate::planner::WindowPlan;
    use crate::simulator::SimConfig;

    fn plan_with_replicas(reps: &[u32]) -> DeploymentPlan {
        let o = opt("h100", 1, 2.0, 10.0, 20.0);
        let windows = reps
            .iter()
            .enumerate()
            .map(|(i, &r)| WindowPlan {
                index: i,
                t_start_h: i as f64,
                t_end_h: (i + 1) as f64,
                demand_qps: 5.0,
                gpu: "h100".into(),
                cand: o.cand.clone(),
                replicas: r,
                gpus: r as u64,
                capacity_qps: r as f64 * 10.0,
                est: o.est,
                cost_usd: r as f64 * 2.0,
            })
            .collect();
        DeploymentPlan {
            windows,
            total_cost_usd: 0.0,
            best_homogeneous: None,
            static_peak_cost_usd: 0.0,
            options_considered: 1,
            options_pruned: 0,
        }
    }

    fn cfg() -> FleetConfig {
        FleetConfig {
            seed: 7,
            scale_lag_s: 0.0,
            failure_rate_per_replica_h: 0.0,
            restart_s: 10.0,
            sim: SimConfig::default(),
        }
    }

    #[test]
    fn steady_plan_is_one_span_per_replica() {
        let plan = plan_with_replicas(&[2, 2, 2]);
        let tls = build_timelines(&plan, &cfg());
        assert_eq!(tls.len(), 2);
        for tl in &tls {
            assert_eq!(tl.spans.len(), 1);
            assert_eq!(tl.spans[0].from_ms, 0.0);
            assert_eq!(tl.spans[0].to_ms, 3.0 * 3_600_000.0);
            assert_eq!(tl.spans[0].end, SpanEnd::Horizon);
            assert!(tl.lag.is_empty());
        }
    }

    #[test]
    fn scale_out_incurs_lag_only_after_t0() {
        let plan = plan_with_replicas(&[1, 2, 2]);
        let mut c = cfg();
        c.scale_lag_s = 60.0;
        let tls = build_timelines(&plan, &c);
        // Replica 0 up from t=0 with no lag; replica 1 joins at window 1
        // and pays 60 s of launch time first.
        assert!(tls[0].lag.is_empty());
        assert_eq!(tls[1].lag.len(), 1);
        let (l0, l1) = tls[1].lag[0];
        assert_eq!(l0, 3_600_000.0);
        assert_eq!(l1, 3_600_000.0 + 60_000.0);
        assert_eq!(tls[1].spans[0].from_ms, l1);
    }

    #[test]
    fn scale_down_and_horizon_ends_are_typed() {
        let plan = plan_with_replicas(&[2, 1, 2]);
        let tls = build_timelines(&plan, &cfg());
        // Replica 1 serves windows 0 and 2 as two intervals.
        assert_eq!(tls[1].spans.len(), 2);
        assert_eq!(tls[1].spans[0].end, SpanEnd::ScaleDown);
        assert_eq!(tls[1].spans[1].end, SpanEnd::Horizon);
    }

    #[test]
    fn segment_boundary_ends_spans() {
        let o2 = opt("a100", 2, 1.0, 8.0, 15.0);
        let mut plan = plan_with_replicas(&[1, 1]);
        plan.windows[1].gpu = "a100".into();
        plan.windows[1].cand = o2.cand.clone();
        assert_eq!(plan.segments(), vec![(0, 0), (1, 1)]);
        let tls = build_timelines(&plan, &cfg());
        assert_eq!(tls.len(), 2);
        assert_eq!(tls[0].spans[0].end, SpanEnd::SegmentEnd);
        assert_eq!(tls[1].spans[0].end, SpanEnd::Horizon);
    }

    #[test]
    fn failures_split_spans_deterministically() {
        let plan = plan_with_replicas(&[1, 1, 1, 1]);
        let mut c = cfg();
        c.failure_rate_per_replica_h = 2.0; // expect ~8 failures in 4 h
        let a = build_timelines(&plan, &c);
        let b = build_timelines(&plan, &c);
        assert_eq!(a[0].failures.len(), b[0].failures.len());
        assert!(!a[0].failures.is_empty(), "2/h over 4 h should fail at least once");
        // Every failure hard-ends a span and downtime precedes the next.
        for (i, s) in a[0].spans.iter().enumerate() {
            assert!(s.from_ms < s.to_ms);
            if s.end == SpanEnd::Failure {
                if let Some(n) = a[0].spans.get(i + 1) {
                    assert!(n.from_ms >= s.to_ms + c.restart_s * 1000.0 - 1e-6);
                }
            }
        }
        // Aggregated unit with inner replicas expands the fleet.
        let mut p2 = plan_with_replicas(&[1]);
        if let Candidate::Aggregated { replicas, .. } = &mut p2.windows[0].cand {
            *replicas = 3;
        }
        assert_eq!(fleet_size(&p2, 0), 3);
    }
}

//! Fleet lifecycle events, flattened from the replica timelines into
//! one time-ordered stream (the event log a real autoscaler would
//! emit; the report's failure/restart counters come from here).

use super::lifecycle::{ReplicaTimeline, SpanEnd};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetEvent {
    /// Replica begins serving (first span of an up-interval).
    ScaleUp { t_ms: f64, segment: usize, replica: usize },
    /// Replica drains and leaves the fleet (scheduled).
    ScaleDown { t_ms: f64, segment: usize, replica: usize },
    /// Injected failure: hard stop, in-flight work preempted.
    Failure { t_ms: f64, segment: usize, replica: usize },
    /// Replica back after restart downtime.
    Restart { t_ms: f64, segment: usize, replica: usize },
}

impl FleetEvent {
    pub fn t_ms(&self) -> f64 {
        match self {
            FleetEvent::ScaleUp { t_ms, .. }
            | FleetEvent::ScaleDown { t_ms, .. }
            | FleetEvent::Failure { t_ms, .. }
            | FleetEvent::Restart { t_ms, .. } => *t_ms,
        }
    }
}

/// Time-ordered event stream for a set of timelines.
pub fn collect(timelines: &[ReplicaTimeline]) -> Vec<FleetEvent> {
    let mut out = Vec::new();
    for tl in timelines {
        for &t in &tl.failures {
            out.push(FleetEvent::Failure { t_ms: t, segment: tl.segment, replica: tl.replica });
        }
        for &t in &tl.restarts {
            out.push(FleetEvent::Restart { t_ms: t, segment: tl.segment, replica: tl.replica });
        }
        // Span starts that are not restarts are scale-ups; scheduled
        // (non-failure) span ends are scale-downs.
        for s in &tl.spans {
            if !tl.restarts.iter().any(|&r| (r - s.from_ms).abs() < 1e-9) {
                out.push(FleetEvent::ScaleUp {
                    t_ms: s.from_ms,
                    segment: tl.segment,
                    replica: tl.replica,
                });
            }
            if matches!(s.end, SpanEnd::ScaleDown | SpanEnd::SegmentEnd) {
                out.push(FleetEvent::ScaleDown {
                    t_ms: s.to_ms,
                    segment: tl.segment,
                    replica: tl.replica,
                });
            }
        }
    }
    out.sort_by(|a, b| a.t_ms().partial_cmp(&b.t_ms()).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleetsim::lifecycle::Span;

    #[test]
    fn failure_and_restart_order() {
        let tl = ReplicaTimeline {
            segment: 0,
            replica: 0,
            spans: vec![
                Span { from_ms: 0.0, to_ms: 50.0, end: SpanEnd::Failure },
                Span { from_ms: 60.0, to_ms: 100.0, end: SpanEnd::Horizon },
            ],
            lag: Vec::new(),
            failures: vec![50.0],
            restarts: vec![60.0],
        };
        let ev = collect(&[tl]);
        assert_eq!(ev.len(), 3); // ScaleUp@0, Failure@50, Restart@60
        assert!(matches!(ev[0], FleetEvent::ScaleUp { t_ms, .. } if t_ms == 0.0));
        assert!(matches!(ev[1], FleetEvent::Failure { t_ms, .. } if t_ms == 50.0));
        assert!(matches!(ev[2], FleetEvent::Restart { t_ms, .. } if t_ms == 60.0));
        let ts: Vec<f64> = ev.iter().map(|e| e.t_ms()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }
}

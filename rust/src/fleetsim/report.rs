//! Typed validation report: achieved vs promised SLA attainment per
//! window, with every miss attributed to a cause.
//!
//! The headline number is the **optimism gap** — the planner's promised
//! attainment minus what the fleet replay achieved. A positive gap
//! means the analytic plan was optimistic; the per-cause breakdown
//! ([`CauseCounts`]) says *why*: window-edge queueing the per-window
//! peak provisioning cannot see, replica scale-up lag, KV-transfer
//! contention on the shared fabric, or injected failures.

use crate::util::json::{self, Json};
use crate::util::stats;

/// Why a request missed its SLA (or never completed). Precedence when
/// several apply: `Failure` > `ScaleLag` > `Contention` > `Queueing`
/// (the most structural cause wins; queueing is the residual).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cause {
    /// Queueing delay the analytic per-window capacity check cannot
    /// see: arrivals bunching at window edges, FCFS head-of-line
    /// blocking, KV-pool admission stalls.
    Queueing,
    /// The request arrived while planned replicas were still launching
    /// (scale-up lag), or was dropped because none was up yet.
    ScaleLag,
    /// The KV-transfer contention surcharge on the shared fabric pushed
    /// an otherwise-passing TTFT over the SLA (disaggregated only).
    Contention,
    /// A replica failure: the request was preempted mid-flight, or
    /// dropped because every eligible replica was down.
    Failure,
}

impl Cause {
    pub fn name(&self) -> &'static str {
        match self {
            Cause::Queueing => "queueing",
            Cause::ScaleLag => "scale_lag",
            Cause::Contention => "contention",
            Cause::Failure => "failure",
        }
    }
}

/// Miss tally by cause.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CauseCounts {
    pub queueing: usize,
    pub scale_lag: usize,
    pub contention: usize,
    pub failure: usize,
}

impl CauseCounts {
    pub fn add(&mut self, c: Cause) {
        match c {
            Cause::Queueing => self.queueing += 1,
            Cause::ScaleLag => self.scale_lag += 1,
            Cause::Contention => self.contention += 1,
            Cause::Failure => self.failure += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.queueing + self.scale_lag + self.contention + self.failure
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("queueing", json::num(self.queueing as f64))
            .set("scale_lag", json::num(self.scale_lag as f64))
            .set("contention", json::num(self.contention as f64))
            .set("failure", json::num(self.failure as f64));
        o
    }
}

/// One request's fate under replay. Latency fields are `None` for
/// requests that never completed (dropped at the router, or preempted
/// by a failure mid-flight).
#[derive(Clone, Copy, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    /// Plan window the arrival falls in.
    pub window: usize,
    pub arrival_ms: f64,
    /// TTFT including any contention surcharge.
    pub ttft_ms: Option<f64>,
    pub tpot_ms: Option<f64>,
    pub finished_ms: Option<f64>,
    /// Completed within the SLA.
    pub met: bool,
    /// Why it missed (None iff `met`).
    pub cause: Option<Cause>,
}

impl RequestOutcome {
    pub fn completed(&self) -> bool {
        self.finished_ms.is_some()
    }
}

/// Achieved vs promised attainment for one plan window.
#[derive(Clone, Debug)]
pub struct WindowReport {
    pub index: usize,
    pub t_start_h: f64,
    pub t_end_h: f64,
    pub demand_qps: f64,
    pub capacity_qps: f64,
    /// Requests arriving in the window.
    pub offered: usize,
    pub completed: usize,
    /// What the planner promised (1.0 for provisioned windows — every
    /// scheduled option is SLA-feasible with capacity ≥ peak demand;
    /// 0.0 for scale-to-zero windows that still saw arrivals).
    pub promised_attainment: f64,
    pub achieved_attainment: f64,
    /// `promised − achieved` (positive = planner optimistic here).
    pub gap: f64,
    pub mean_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_tpot_ms: f64,
    pub misses: CauseCounts,
}

/// The full fleet-replay verdict on one deployment plan.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub windows: Vec<WindowReport>,
    pub offered: usize,
    pub completed: usize,
    /// Turned away at the router (no replica up).
    pub dropped: usize,
    /// Killed mid-flight by a replica failure.
    pub preempted: usize,
    /// Injected replica failures / successful restarts.
    pub failures: usize,
    pub restarts: usize,
    /// Request-weighted across windows.
    pub promised_attainment: f64,
    pub achieved_attainment: f64,
    /// `promised − achieved`, the headline number.
    pub optimism_gap: f64,
    /// SLA-meeting completions per second of replay.
    pub goodput_qps: f64,
    /// First arrival to last completion, ms.
    pub makespan_ms: f64,
    pub misses: CauseCounts,
    /// Per-request detail (arrival order). Not serialized — traces run
    /// to millions of requests; JSON carries the window rollup.
    pub requests: Vec<RequestOutcome>,
}

impl ValidationReport {
    /// Assemble the per-window rollup and headline numbers from
    /// per-request outcomes (met/cause already attributed) and the
    /// plan's windows.
    pub fn build(
        mut requests: Vec<RequestOutcome>,
        plan: &crate::planner::DeploymentPlan,
        failures: usize,
        restarts: usize,
    ) -> ValidationReport {
        requests.sort_by(|a, b| a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap());
        let mut windows = Vec::with_capacity(plan.windows.len());
        let mut misses = CauseCounts::default();
        let mut offered_total = 0usize;
        let mut met_total = 0usize;
        let mut promised_weighted = 0.0f64;
        for w in &plan.windows {
            let reqs: Vec<&RequestOutcome> =
                requests.iter().filter(|r| r.window == w.index).collect();
            let offered = reqs.len();
            let completed = reqs.iter().filter(|r| r.completed()).count();
            let met = reqs.iter().filter(|r| r.met).count();
            let promised = if offered == 0 {
                1.0
            } else if w.replicas == 0 {
                0.0
            } else {
                1.0
            };
            let achieved =
                if offered == 0 { 1.0 } else { met as f64 / offered as f64 };
            let ttfts: Vec<f64> = reqs.iter().filter_map(|r| r.ttft_ms).collect();
            let tpots: Vec<f64> = reqs.iter().filter_map(|r| r.tpot_ms).collect();
            let mut wm = CauseCounts::default();
            for r in &reqs {
                if let Some(c) = r.cause {
                    wm.add(c);
                    misses.add(c);
                }
            }
            offered_total += offered;
            met_total += met;
            promised_weighted += promised * offered as f64;
            windows.push(WindowReport {
                index: w.index,
                t_start_h: w.t_start_h,
                t_end_h: w.t_end_h,
                demand_qps: w.demand_qps,
                capacity_qps: w.capacity_qps,
                offered,
                completed,
                promised_attainment: promised,
                achieved_attainment: achieved,
                gap: promised - achieved,
                mean_ttft_ms: stats::mean(&ttfts),
                p99_ttft_ms: stats::percentile(&ttfts, 99.0),
                mean_tpot_ms: stats::mean(&tpots),
                misses: wm,
            });
        }
        let completed = requests.iter().filter(|r| r.completed()).count();
        let preempted = requests
            .iter()
            .filter(|r| !r.completed() && r.cause == Some(Cause::Failure))
            .count();
        let dropped = requests.len() - completed - preempted;
        let start = requests.iter().map(|r| r.arrival_ms).fold(f64::INFINITY, f64::min);
        let end = requests.iter().filter_map(|r| r.finished_ms).fold(0.0f64, f64::max);
        let makespan_ms = if start.is_finite() { (end - start.min(end)).max(0.0) } else { 0.0 };
        let promised = if offered_total > 0 {
            promised_weighted / offered_total as f64
        } else {
            1.0
        };
        let achieved = if offered_total > 0 {
            met_total as f64 / offered_total as f64
        } else {
            1.0
        };
        ValidationReport {
            windows,
            offered: offered_total,
            completed,
            dropped,
            preempted,
            failures,
            restarts,
            promised_attainment: promised,
            achieved_attainment: achieved,
            optimism_gap: promised - achieved,
            goodput_qps: if makespan_ms > 0.0 {
                met_total as f64 / (makespan_ms / 1000.0)
            } else {
                0.0
            },
            makespan_ms,
            misses,
            requests,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut windows = Vec::new();
        for w in &self.windows {
            let mut o = Json::obj();
            o.set("window", json::num(w.index as f64))
                .set("t_start_h", json::num(w.t_start_h))
                .set("t_end_h", json::num(w.t_end_h))
                .set("demand_qps", json::num(w.demand_qps))
                .set("capacity_qps", json::num(w.capacity_qps))
                .set("offered", json::num(w.offered as f64))
                .set("completed", json::num(w.completed as f64))
                .set("promised_attainment", json::num(w.promised_attainment))
                .set("achieved_attainment", json::num(w.achieved_attainment))
                .set("gap", json::num(w.gap))
                .set("mean_ttft_ms", json::num(w.mean_ttft_ms))
                .set("p99_ttft_ms", json::num(w.p99_ttft_ms))
                .set("mean_tpot_ms", json::num(w.mean_tpot_ms))
                .set("misses", w.misses.to_json());
            windows.push(o);
        }
        let mut o = Json::obj();
        o.set("windows", Json::Arr(windows))
            .set("offered", json::num(self.offered as f64))
            .set("completed", json::num(self.completed as f64))
            .set("dropped", json::num(self.dropped as f64))
            .set("preempted", json::num(self.preempted as f64))
            .set("failures", json::num(self.failures as f64))
            .set("restarts", json::num(self.restarts as f64))
            .set("promised_attainment", json::num(self.promised_attainment))
            .set("achieved_attainment", json::num(self.achieved_attainment))
            .set("optimism_gap", json::num(self.optimism_gap))
            .set("goodput_qps", json::num(self.goodput_qps))
            .set("makespan_ms", json::num(self.makespan_ms))
            .set("misses", self.misses.to_json());
        o
    }

    /// Human-readable window table + headline summary (CLI output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "window    span h   offered  done   promised  achieved      gap  \
             q/lag/con/fail\n",
        );
        for w in &self.windows {
            s.push_str(&format!(
                "{:>6}  {:>4.1}-{:<4.1}  {:>7}  {:>5}  {:>8.3}  {:>8.3}  {:>+7.3}  \
                 {}/{}/{}/{}\n",
                w.index,
                w.t_start_h,
                w.t_end_h,
                w.offered,
                w.completed,
                w.promised_attainment,
                w.achieved_attainment,
                w.gap,
                w.misses.queueing,
                w.misses.scale_lag,
                w.misses.contention,
                w.misses.failure,
            ));
        }
        s.push_str(&format!(
            "\noffered {}  completed {}  dropped {}  preempted {}  failures {} \
             (restarts {})\n",
            self.offered, self.completed, self.dropped, self.preempted, self.failures,
            self.restarts,
        ));
        s.push_str(&format!(
            "promised {:.4}  achieved {:.4}  optimism gap {:+.4}\n",
            self.promised_attainment, self.achieved_attainment, self.optimism_gap,
        ));
        s.push_str(&format!(
            "goodput {:.2} qps over {:.1} s  |  misses by cause: queueing {}  \
             scale-lag {}  contention {}  failure {}\n",
            self.goodput_qps,
            self.makespan_ms / 1000.0,
            self.misses.queueing,
            self.misses.scale_lag,
            self.misses.contention,
            self.misses.failure,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cause_counts_tally() {
        let mut c = CauseCounts::default();
        c.add(Cause::Queueing);
        c.add(Cause::Failure);
        c.add(Cause::Failure);
        assert_eq!(c.total(), 3);
        assert_eq!(c.failure, 2);
        let j = c.to_json();
        assert_eq!(j.req_f64("failure").unwrap(), 2.0);
        assert_eq!(j.req_f64("contention").unwrap(), 0.0);
    }
}

//! Fleet-level replay: execute a planner schedule against a long
//! multi-tenant trace and measure what the fleet *actually* delivers.
//!
//! The capacity planner ([`crate::planner`]) promises each window an
//! SLA-feasible deployment with capacity ≥ peak demand — an analytic
//! promise that ignores queueing at window edges, replica scale-up
//! lag, KV-transfer contention between replicas sharing a fabric, and
//! failures. This module replays the plan's own traffic (one shared
//! trace builder: [`crate::planner::TrafficModel::trace`] →
//! [`crate::workload::piecewise_poisson`]) through the schedule:
//!
//! 1. [`lifecycle`] turns the per-window replica counts into
//!    per-replica availability spans (lag + seeded failure injection);
//! 2. [`router`] assigns each arrival to the least-loaded live replica
//!    (typed drops when none is up);
//! 3. each replica's assigned sub-trace runs through the *existing*
//!    engine simulators ([`crate::simulator::aggregated::AggregatedSim`]
//!    / [`crate::simulator::disagg::DisaggSim`]) — per-replica service
//!    times are composed, never re-modelled;
//! 4. a post-pass prices KV-transfer contention between co-scheduled
//!    disaggregated replicas via the same fabric formula the engine
//!    itself uses ([`DisaggSim::kv_transfer_ms`]);
//! 5. [`report`] rolls everything into per-window achieved-vs-promised
//!    attainment with the optimism gap broken down by cause.
//!
//! Composition is exactness-preserving: a fleet of one replica with
//! zero lag, zero failures and no contention reduces to a single
//! engine run over the identical trace with the identical seed, so the
//! degenerate fleet reproduces `simulator/` metrics bit-for-bit
//! (pinned in `tests/fleetsim.rs`).

pub mod events;
pub mod lifecycle;
pub mod report;
pub mod router;

pub use report::{Cause, CauseCounts, RequestOutcome, ValidationReport, WindowReport};

use std::collections::BTreeMap;

use crate::config::Candidate;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::planner::{DeploymentPlan, PlanSpec};
use crate::silicon::Silicon;
use crate::simulator::aggregated::AggregatedSim;
use crate::simulator::disagg::DisaggSim;
use crate::simulator::{ReqMetric, SimConfig};
use crate::workload::Request;

use lifecycle::SpanEnd;
use router::Route;

/// Fleet replay knobs. The defaults are the *faithful-execution*
/// configuration: no lag, no failures — any optimism gap measured
/// there is pure queueing/contention, i.e. the planner's own analytic
/// error.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Seeds failure sampling (trace and engine seeds are separate:
    /// the trace carries its own seed, engines use `sim.seed`).
    pub seed: u64,
    /// Replica launch time, seconds (weights load + warmup). Applied
    /// to every up-interval starting after t=0.
    pub scale_lag_s: f64,
    /// Poisson failure rate per replica, failures/hour. 0 disables
    /// injection.
    pub failure_rate_per_replica_h: f64,
    /// Downtime between a failure and the replica serving again, s.
    pub restart_s: f64,
    /// Per-replica engine simulator config ([`SimConfig`]); the seed
    /// is decorrelated per (segment, replica, span) stream with the
    /// degenerate stream (0,0,0) left untouched.
    pub sim: SimConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            seed: 0xF1EE7,
            scale_lag_s: 0.0,
            failure_rate_per_replica_h: 0.0,
            restart_s: 120.0,
            sim: SimConfig::default(),
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.scale_lag_s.is_finite() && self.scale_lag_s >= 0.0,
            "scale_lag_s must be finite and non-negative"
        );
        anyhow::ensure!(
            self.failure_rate_per_replica_h.is_finite()
                && self.failure_rate_per_replica_h >= 0.0,
            "failure_rate_per_replica_h must be finite and non-negative"
        );
        anyhow::ensure!(
            self.restart_s.is_finite() && self.restart_s >= 0.0,
            "restart_s must be finite and non-negative"
        );
        Ok(())
    }
}

/// One GPU type's execution substrate, keyed by the plan's `gpu` name.
/// The silicon must be profiled for `cluster` (same invariant as the
/// planner's fleet legs).
pub struct FleetLeg<'a> {
    pub name: String,
    pub cluster: ClusterSpec,
    pub silicon: &'a Silicon,
}

/// Decorrelate per-(segment, replica, span) engine seeds. Identically
/// zero at (0, 0, 0) so the degenerate single-replica fleet runs its
/// engine with `cfg.sim.seed` itself — the equivalence pin depends on
/// this.
fn span_seed(segment: usize, replica: usize, span: usize) -> u64 {
    (segment as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (replica as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ (span as u64).wrapping_mul(0x94D0_49BB_1331_11EB)
}

/// Replay `trace` through `plan` on `legs`; the verdict is the report.
pub fn replay(
    model: &ModelArch,
    spec: &PlanSpec,
    plan: &DeploymentPlan,
    legs: &[FleetLeg<'_>],
    trace: &[Request],
    cfg: &FleetConfig,
) -> anyhow::Result<ValidationReport> {
    let rsp = crate::trace::span("replay", "validate");
    rsp.add("requests", trace.len() as f64);
    rsp.add("windows", plan.windows.len() as f64);
    cfg.validate()?;
    anyhow::ensure!(!plan.windows.is_empty(), "cannot replay an empty plan");
    let window_ms = (plan.windows[0].t_end_h - plan.windows[0].t_start_h) * 3_600_000.0;
    anyhow::ensure!(window_ms > 0.0, "plan windows must have positive length");
    let leg_of = |gpu: &str| legs.iter().find(|l| l.name == gpu);
    for w in &plan.windows {
        anyhow::ensure!(
            leg_of(&w.gpu).is_some(),
            "plan window {} deploys on '{}' but no such fleet leg was supplied",
            w.index,
            w.gpu
        );
    }

    let segments = plan.segments();
    let mut seg_of_window = vec![0usize; plan.windows.len()];
    for (si, (a, b)) in segments.iter().enumerate() {
        for w in *a..=*b {
            seg_of_window[w] = si;
        }
    }
    let last = plan.windows.len() - 1;
    let window_of = |t_ms: f64| ((t_ms / window_ms).floor() as usize).min(last);

    let timelines = lifecycle::build_timelines(plan, cfg);
    let routes = {
        let _s = crate::trace::span("route", "fleet");
        router::route(trace, &timelines, window_of, |w| seg_of_window[w])
    };

    // Group each (timeline, span)'s sub-trace, preserving arrival order.
    let mut groups: BTreeMap<(usize, usize), Vec<Request>> = BTreeMap::new();
    for (r, route) in trace.iter().zip(&routes) {
        if let Route::Assigned { timeline, span } = route {
            groups.entry((*timeline, *span)).or_default().push(*r);
        }
    }

    // Run every sub-trace through the engine simulator of its segment.
    let sp_sim = crate::trace::span("engine_sims", "fleet");
    sp_sim.add("sub_traces", groups.len() as f64);
    let mut metrics: BTreeMap<u64, ReqMetric> = BTreeMap::new();
    // (start_ms, end_ms, timeline, id, transfer_ms) per disagg transfer.
    let mut transfers_by_seg: BTreeMap<usize, Vec<(f64, f64, usize, u64, f64)>> =
        BTreeMap::new();
    for ((ti, si), sub) in &groups {
        let tl = &timelines[*ti];
        let (w0, _) = segments[tl.segment];
        let win = &plan.windows[w0];
        let leg = leg_of(&win.gpu).unwrap();
        let mut sim_cfg = cfg.sim;
        sim_cfg.seed ^= span_seed(tl.segment, tl.replica, *si);
        let result = match &win.cand {
            Candidate::Aggregated { engine, .. } => {
                AggregatedSim::new(leg.silicon, model, &leg.cluster, *engine, sim_cfg)
                    .run(sub)
            }
            Candidate::Disaggregated { prefill, decode, x, y } => {
                let dsim = DisaggSim::new(
                    leg.silicon,
                    model,
                    &leg.cluster,
                    *prefill,
                    *decode,
                    *x,
                    *y,
                    sim_cfg,
                );
                let res = dsim.run(sub);
                let by_id: BTreeMap<u64, ReqMetric> =
                    res.requests.iter().map(|m| (m.id, *m)).collect();
                for req in sub {
                    if let Some(m) = by_id.get(&req.id) {
                        let t = dsim.kv_transfer_ms(req.isl);
                        let end = m.arrival_ms + m.ttft_ms;
                        transfers_by_seg.entry(tl.segment).or_default().push((
                            end - t,
                            end,
                            *ti,
                            req.id,
                            t,
                        ));
                    }
                }
                res
            }
        };
        for m in &result.requests {
            metrics.insert(m.id, *m);
        }
    }
    drop(sp_sim);

    let sp_con = crate::trace::span("contention", "fleet");
    // Contention surcharge: transfers of *different* replicas in the
    // same segment overlap on the shared fabric and serialize. Each
    // transfer pays its own duration once more per overlapping
    // other-replica transfer (sorted-boundary counting, O(n log n)).
    let mut extra: BTreeMap<u64, f64> = BTreeMap::new();
    for tr in transfers_by_seg.values() {
        let mut starts: Vec<f64> = tr.iter().map(|t| t.0).collect();
        let mut ends: Vec<f64> = tr.iter().map(|t| t.1).collect();
        starts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ends.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut by_tl: BTreeMap<usize, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
        for (s, e, ti, _, _) in tr {
            let ent = by_tl.entry(*ti).or_default();
            ent.0.push(*s);
            ent.1.push(*e);
        }
        for ent in by_tl.values_mut() {
            ent.0.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ent.1.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        let overlap = |starts: &[f64], ends: &[f64], s: f64, e: f64| -> usize {
            let began = starts.partition_point(|&x| x < e);
            let finished = ends.partition_point(|&x| x <= s);
            began.saturating_sub(finished)
        };
        for (s, e, ti, id, t_ms) in tr {
            let all = overlap(&starts, &ends, *s, *e);
            let (os, oe) = &by_tl[ti];
            let own = overlap(os, oe, *s, *e);
            let others = all.saturating_sub(own);
            if others > 0 {
                extra.insert(*id, t_ms * others as f64);
            }
        }
    }
    sp_con.add("surcharged", extra.len() as f64);
    drop(sp_con);

    // Per-request outcomes with cause attribution.
    let sla = &spec.workload.sla;
    let max_tpot = sla.max_tpot_ms();
    let in_lag_of_segment = |seg: usize, t: f64| {
        timelines
            .iter()
            .filter(|tl| tl.segment == seg)
            .any(|tl| tl.lag.iter().any(|&(a, b)| t >= a && t < b))
    };
    let mut outcomes = Vec::with_capacity(trace.len());
    for (r, route) in trace.iter().zip(&routes) {
        let window = window_of(r.arrival_ms);
        let outcome = match route {
            Route::Dropped(cause) => RequestOutcome {
                id: r.id,
                window,
                arrival_ms: r.arrival_ms,
                ttft_ms: None,
                tpot_ms: None,
                finished_ms: None,
                met: false,
                cause: Some(*cause),
            },
            Route::Assigned { timeline, span } => {
                let tl = &timelines[*timeline];
                let sp = &tl.spans[*span];
                match metrics.get(&r.id) {
                    // Hard-ended span: completions past the failure
                    // instant never happened — the request is preempted.
                    Some(m) if sp.end == SpanEnd::Failure && m.finished_ms > sp.to_ms => {
                        RequestOutcome {
                            id: r.id,
                            window,
                            arrival_ms: r.arrival_ms,
                            ttft_ms: None,
                            tpot_ms: None,
                            finished_ms: None,
                            met: false,
                            cause: Some(Cause::Failure),
                        }
                    }
                    Some(m) => {
                        let surcharge = extra.get(&r.id).copied().unwrap_or(0.0);
                        let ttft = m.ttft_ms + surcharge;
                        let met = ttft <= sla.ttft_ms && m.tpot_ms <= max_tpot;
                        let cause = if met {
                            None
                        } else if in_lag_of_segment(tl.segment, r.arrival_ms) {
                            Some(Cause::ScaleLag)
                        } else if m.ttft_ms <= sla.ttft_ms && m.tpot_ms <= max_tpot {
                            // Only the contention surcharge broke it.
                            Some(Cause::Contention)
                        } else {
                            Some(Cause::Queueing)
                        };
                        RequestOutcome {
                            id: r.id,
                            window,
                            arrival_ms: r.arrival_ms,
                            ttft_ms: Some(ttft),
                            tpot_ms: Some(m.tpot_ms),
                            finished_ms: Some(m.finished_ms + surcharge),
                            met,
                            cause,
                        }
                    }
                    // The engine hit its iteration cap before finishing
                    // this request: count it as a queueing loss.
                    None => RequestOutcome {
                        id: r.id,
                        window,
                        arrival_ms: r.arrival_ms,
                        ttft_ms: None,
                        tpot_ms: None,
                        finished_ms: None,
                        met: false,
                        cause: Some(Cause::Queueing),
                    },
                }
            }
        };
        outcomes.push(outcome);
    }

    let failures = timelines.iter().map(|t| t.failures.len()).sum();
    let restarts = timelines.iter().map(|t| t.restarts.len()).sum();
    Ok(ValidationReport::build(outcomes, plan, failures, restarts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadSpec;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::by_name;
    use crate::planner::testutil::opt;
    use crate::planner::{TrafficModel, WindowPlan};

    fn tiny_plan(replicas: u32, windows: usize) -> DeploymentPlan {
        // A real engine config (TP2 on H100) behind a synthetic window
        // schedule — replay only reads gpu/cand/replicas per window.
        let o = opt("h100", 2, 2.0, 50.0, 25.0);
        let wins = (0..windows)
            .map(|i| WindowPlan {
                index: i,
                t_start_h: i as f64 * 0.01,
                t_end_h: (i + 1) as f64 * 0.01,
                demand_qps: 2.0,
                gpu: "h100".into(),
                cand: o.cand.clone(),
                replicas,
                gpus: (replicas * 2) as u64,
                capacity_qps: replicas as f64 * 50.0,
                est: o.est,
                cost_usd: 1.0,
            })
            .collect();
        DeploymentPlan {
            windows: wins,
            total_cost_usd: 1.0,
            best_homogeneous: None,
            static_peak_cost_usd: 2.0,
            options_considered: 1,
            options_pruned: 0,
        }
    }

    fn fixture() -> (crate::models::ModelArch, ClusterSpec, Silicon, PlanSpec) {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let wl = WorkloadSpec::new("llama3.1-8b", 256, 32, 5000.0, 5.0);
        let spec = PlanSpec::new(
            wl,
            TrafficModel::Ramp { start_qps: 2.0, end_qps: 2.0 },
            2,
            0.01,
        );
        (by_name("llama3.1-8b").unwrap(), cluster, sil, spec)
    }

    #[test]
    fn replay_reports_full_attainment_when_overprovisioned() {
        let (model, cluster, sil, spec) = fixture();
        let plan = tiny_plan(2, 2);
        let trace = spec.traffic.trace(2, 0.01, &spec.workload, 0.0, 42);
        assert!(!trace.is_empty());
        let legs =
            [FleetLeg { name: "h100".into(), cluster, silicon: &sil }];
        let rep = replay(&model, &spec, &plan, &legs, &trace, &FleetConfig::default())
            .unwrap();
        assert_eq!(rep.offered, trace.len());
        assert_eq!(rep.completed, trace.len());
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.failures, 0);
        assert!(rep.achieved_attainment > 0.9, "{}", rep.achieved_attainment);
        assert!(rep.optimism_gap.abs() <= 0.1, "{}", rep.optimism_gap);
        assert_eq!(rep.windows.len(), 2);
        let j = rep.to_json();
        assert_eq!(j.req_f64("offered").unwrap() as usize, trace.len());
        assert!(rep.render().contains("optimism gap"));
    }

    #[test]
    fn missing_leg_is_a_clean_error() {
        let (model, cluster, sil, spec) = fixture();
        let plan = tiny_plan(1, 2);
        let trace = spec.traffic.trace(2, 0.01, &spec.workload, 0.0, 42);
        let legs =
            [FleetLeg { name: "a100".into(), cluster, silicon: &sil }];
        let err = replay(&model, &spec, &plan, &legs, &trace, &FleetConfig::default())
            .unwrap_err();
        assert!(err.to_string().contains("no such fleet leg"), "{err:#}");
    }

    #[test]
    fn span_seed_degenerate_stream_is_zero() {
        assert_eq!(span_seed(0, 0, 0), 0);
        assert_ne!(span_seed(0, 1, 0), span_seed(0, 0, 0));
        assert_ne!(span_seed(1, 0, 0), span_seed(0, 1, 0));
    }
}

//! Generator (paper §4.1 step 5): convert a recommended candidate into
//! version-compatible launch files for TensorRT-LLM, vLLM or SGLang,
//! setting the optimal serving flags (`--enable_cuda_graph`,
//! `--kv_cache_free_gpu_mem_fraction`, `--enable_chunked_context`,
//! max-token capacity, parallelism), plus a Dynamo deployment spec for
//! disaggregated composites.

pub mod dynamo;
pub mod sglang;
pub mod trtllm;
pub mod vllm;

use crate::config::{Candidate, EngineConfig, WorkloadSpec};
use crate::frameworks::Framework;

/// A generated launch bundle: (filename, contents) pairs.
#[derive(Clone, Debug)]
pub struct LaunchBundle {
    pub files: Vec<(String, String)>,
}

impl LaunchBundle {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.files.iter().find(|(n, _)| n == name).map(|(_, c)| c.as_str())
    }

    pub fn write_to(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        for (name, content) in &self.files {
            std::fs::write(dir.join(name), content)?;
        }
        Ok(())
    }
}

/// Generate launch files for a candidate.
pub fn generate(cand: &Candidate, model_hf_id: &str, wl: &WorkloadSpec) -> LaunchBundle {
    match cand {
        Candidate::Aggregated { engine, replicas } => {
            let mut files = engine_files(engine, model_hf_id, wl, "server");
            files.push((
                "README.launch.md".to_string(),
                format!(
                    "# AIConfigurator recommendation\n\nMode: aggregated, {replicas} replica(s) of {}\nWorkload: ISL={} OSL={} | SLA: TTFT<={}ms speed>={} tok/s/user\n",
                    engine.label(), wl.isl, wl.osl, wl.sla.ttft_ms, wl.sla.min_speed
                ),
            ));
            LaunchBundle { files }
        }
        Candidate::Disaggregated { prefill, decode, x, y } => {
            let mut files = engine_files(prefill, model_hf_id, wl, "prefill");
            files.extend(engine_files(decode, model_hf_id, wl, "decode"));
            files.push((
                "dynamo_disagg.yaml".to_string(),
                dynamo::disagg_yaml(prefill, decode, *x, *y, model_hf_id, wl),
            ));
            LaunchBundle { files }
        }
    }
}

fn engine_files(
    eng: &EngineConfig,
    model: &str,
    wl: &WorkloadSpec,
    role: &str,
) -> Vec<(String, String)> {
    match eng.framework {
        Framework::TrtLlm => vec![
            (format!("trtllm_{role}.yaml"), trtllm::extra_llm_api_config(eng, wl)),
            (format!("launch_{role}.sh"), trtllm::serve_command(eng, model, wl)),
        ],
        Framework::Vllm => vec![(format!("launch_{role}.sh"), vllm::serve_command(eng, model, wl))],
        Framework::Sglang => {
            vec![(format!("launch_{role}.sh"), sglang::serve_command(eng, model, wl))]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags, Sla};
    use crate::models::Dtype;

    fn eng(fw: Framework) -> EngineConfig {
        EngineConfig {
            framework: fw,
            parallel: ParallelSpec { tp: 2, pp: 1, ep: 1, dp: 1 },
            batch: 8,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags {
                cuda_graph: true,
                kv_frac: 0.9,
                max_num_tokens: 8192,
                chunked_prefill: true,
            },
        }
    }

    fn wl() -> WorkloadSpec {
        WorkloadSpec {
            model: "qwen3-32b".into(),
            isl: 4000,
            osl: 500,
            prefix: 0,
            sla: Sla { ttft_ms: 1200.0, min_speed: 60.0 },
        }
    }

    #[test]
    fn aggregated_bundle_has_launch_script() {
        let c = Candidate::Aggregated { engine: eng(Framework::TrtLlm), replicas: 1 };
        let b = generate(&c, "Qwen/Qwen3-32B-FP8", &wl());
        let sh = b.get("launch_server.sh").unwrap();
        assert!(sh.contains("trtllm-serve"));
        assert!(sh.contains("--tp_size 2"));
        let yaml = b.get("trtllm_server.yaml").unwrap();
        assert!(yaml.contains("kv_cache_config"));
        assert!(yaml.contains("0.9"));
    }

    #[test]
    fn disagg_bundle_has_dynamo_spec() {
        let mut p = eng(Framework::TrtLlm);
        p.parallel = ParallelSpec::tp(1);
        p.batch = 1;
        let c = Candidate::Disaggregated { prefill: p, decode: eng(Framework::TrtLlm), x: 4, y: 2 };
        let b = generate(&c, "Qwen/Qwen3-32B-FP8", &wl());
        let y = b.get("dynamo_disagg.yaml").unwrap();
        assert!(y.contains("prefill"));
        assert!(y.contains("replicas: 4"));
        assert!(y.contains("replicas: 2"));
        assert!(b.get("launch_prefill.sh").is_some());
        assert!(b.get("launch_decode.sh").is_some());
    }

    #[test]
    fn all_frameworks_generate() {
        for fw in Framework::all() {
            let c = Candidate::Aggregated { engine: eng(fw), replicas: 1 };
            let b = generate(&c, "org/model", &wl());
            assert!(!b.files.is_empty(), "{fw:?}");
            let sh = b.get("launch_server.sh").unwrap();
            assert!(sh.contains("org/model"));
        }
    }
}

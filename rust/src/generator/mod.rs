//! Generator (paper §4.1 step 5): convert a recommended candidate into
//! version-compatible launch files, setting the optimal serving flags
//! (`--enable_cuda_graph`, `--kv_cache_free_gpu_mem_fraction`,
//! `--enable_chunked_context`, max-token capacity, parallelism), plus a
//! Dynamo deployment spec for disaggregated composites.
//!
//! Per-framework emission lives behind the backend abstraction layer
//! ([`crate::frameworks::Backend::emit_launch`]); this module only
//! assembles bundles, so adding a fourth framework never touches it.

pub mod dynamo;

use crate::config::{Candidate, EngineConfig, WorkloadSpec};

/// A generated launch bundle: (filename, contents) pairs.
#[derive(Clone, Debug)]
pub struct LaunchBundle {
    pub files: Vec<(String, String)>,
}

impl LaunchBundle {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.files.iter().find(|(n, _)| n == name).map(|(_, c)| c.as_str())
    }

    pub fn write_to(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        for (name, content) in &self.files {
            std::fs::write(dir.join(name), content)?;
        }
        Ok(())
    }
}

/// Generate launch files for a candidate.
pub fn generate(cand: &Candidate, model_hf_id: &str, wl: &WorkloadSpec) -> LaunchBundle {
    match cand {
        Candidate::Aggregated { engine, replicas } => {
            let mut files = engine_files(engine, model_hf_id, wl, "server");
            files.push((
                "README.launch.md".to_string(),
                format!(
                    "# AIConfigurator recommendation\n\nMode: aggregated, {replicas} replica(s) of {}\nPlacement: {}\nWorkload: ISL={} OSL={} | SLA: TTFT<={}ms speed>={} tok/s/user\n",
                    engine.label(), engine.placement.label(), wl.isl, wl.osl, wl.sla.ttft_ms, wl.sla.min_speed
                ),
            ));
            LaunchBundle { files }
        }
        Candidate::Disaggregated { prefill, decode, x, y } => {
            let mut files = engine_files(prefill, model_hf_id, wl, "prefill");
            files.extend(engine_files(decode, model_hf_id, wl, "decode"));
            files.push((
                "dynamo_disagg.yaml".to_string(),
                dynamo::disagg_yaml(prefill, decode, *x, *y, model_hf_id, wl),
            ));
            LaunchBundle { files }
        }
    }
}

fn engine_files(
    eng: &EngineConfig,
    model: &str,
    wl: &WorkloadSpec,
    role: &str,
) -> Vec<(String, String)> {
    eng.framework.backend().emit_launch(eng, model, wl, role)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags, Sla};
    use crate::frameworks::Framework;
    use crate::models::Dtype;

    fn eng(fw: Framework) -> EngineConfig {
        EngineConfig {
            framework: fw,
            parallel: ParallelSpec { tp: 2, pp: 1, ep: 1, dp: 1 },
            batch: 8,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags {
                cuda_graph: true,
                kv_frac: 0.9,
                max_num_tokens: 8192,
                chunked_prefill: true,
            },
            placement: crate::topology::Placement::packed(),
        }
    }

    fn wl() -> WorkloadSpec {
        WorkloadSpec {
            model: "qwen3-32b".into(),
            isl: 4000,
            osl: 500,
            prefix: 0,
            sla: Sla { ttft_ms: 1200.0, min_speed: 60.0 },
        }
    }

    #[test]
    fn aggregated_bundle_has_launch_script() {
        let c = Candidate::Aggregated { engine: eng(Framework::TrtLlm), replicas: 1 };
        let b = generate(&c, "Qwen/Qwen3-32B-FP8", &wl());
        let sh = b.get("launch_server.sh").unwrap();
        assert!(sh.contains("trtllm-serve"));
        assert!(sh.contains("--tp_size 2"));
        let yaml = b.get("trtllm_server.yaml").unwrap();
        assert!(yaml.contains("kv_cache_config"));
        assert!(yaml.contains("0.9"));
    }

    #[test]
    fn disagg_bundle_has_dynamo_spec() {
        let mut p = eng(Framework::TrtLlm);
        p.parallel = ParallelSpec::tp(1);
        p.batch = 1;
        let c = Candidate::Disaggregated { prefill: p, decode: eng(Framework::TrtLlm), x: 4, y: 2 };
        let b = generate(&c, "Qwen/Qwen3-32B-FP8", &wl());
        let y = b.get("dynamo_disagg.yaml").unwrap();
        assert!(y.contains("prefill"));
        assert!(y.contains("replicas: 4"));
        assert!(y.contains("replicas: 2"));
        assert!(b.get("launch_prefill.sh").is_some());
        assert!(b.get("launch_decode.sh").is_some());
        // Role-specific sidecars: each TRT-LLM pool script references
        // its own YAML, not the aggregated server's.
        assert!(b.get("launch_prefill.sh").unwrap().contains("./trtllm_prefill.yaml"));
        assert!(b.get("launch_decode.sh").unwrap().contains("./trtllm_decode.yaml"));
    }

    #[test]
    fn all_frameworks_generate() {
        for fw in Framework::all() {
            let c = Candidate::Aggregated { engine: eng(fw), replicas: 1 };
            let b = generate(&c, "org/model", &wl());
            assert!(!b.files.is_empty(), "{fw:?}");
            let sh = b.get("launch_server.sh").unwrap();
            assert!(sh.contains("org/model"));
        }
    }

    #[test]
    fn resolved_flags_emitted_bit_exactly() {
        // The launch bundle must carry the backend-resolved flag values
        // verbatim — the abstraction layer's whole contract.
        use crate::hardware::{h100_sxm, ClusterSpec};
        use crate::models::by_name;
        let model = by_name("qwen3-32b").unwrap();
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let w = wl();
        for fw in Framework::all() {
            let be = fw.backend();
            let mut e = eng(fw);
            e.flags = be.resolve_flags(
                &model,
                &cluster,
                &w,
                &e.parallel,
                e.batch,
                e.weight_dtype,
            );
            let b = generate(&Candidate::Aggregated { engine: e, replicas: 1 }, "org/m", &w);
            let sh = b.get("launch_server.sh").unwrap();
            assert!(
                sh.contains(&format!("{:.2}", e.flags.kv_frac)),
                "{fw:?}: resolved kv_frac {:.2} missing from\n{sh}",
                e.flags.kv_frac
            );
            assert!(
                sh.contains(&e.flags.max_num_tokens.to_string()),
                "{fw:?}: resolved max_num_tokens {} missing from\n{sh}",
                e.flags.max_num_tokens
            );
        }
    }
}

//! Power-law MoE load imbalance (paper §4.4.1, Eq. 3–4).
//!
//! Computes γ — the hottest-participant load factor that multiplies
//! grouped-GEMM compute time. The Rust implementation mirrors the Pallas
//! kernel (`python/compile/kernels/moe_powerlaw.py`); the PJRT-backed
//! service path runs the kernel, this native path serves the CLI and is
//! cross-checked against the kernel in integration tests.

use crate::models::ModelArch;
use crate::util::rng::Rng;

/// Default x bounds of the bounded power law (Eq. 3).
pub const X_MIN: f64 = 1.0;
pub const X_MAX: f64 = 100.0;
/// Guard band around the α = 1 singularity.
pub const ALPHA_GUARD: f64 = 0.02;

/// Sample one expert-load weight vector (Eq. 3, before normalization).
pub fn sample_weights(rng: &mut Rng, experts: usize, alpha: f64) -> Vec<f64> {
    let a = clamp_alpha(alpha);
    let one_m = 1.0 - a;
    let lo = X_MIN.powf(one_m);
    let hi = X_MAX.powf(one_m);
    (0..experts)
        .map(|_| ((hi - lo) * rng.f64_open() + lo).powf(1.0 / one_m))
        .collect()
}

/// Nudge α off the singular point, matching the kernel's contract.
pub fn clamp_alpha(alpha: f64) -> f64 {
    if (alpha - 1.0).abs() < ALPHA_GUARD {
        if alpha < 1.0 {
            1.0 - ALPHA_GUARD
        } else {
            1.0 + ALPHA_GUARD
        }
    } else {
        alpha
    }
}

/// Token counts per expert for a batch of `t_total` tokens routed top-k
/// (Eq. 4), with residual redistribution so the counts sum exactly.
pub fn token_counts(rng: &mut Rng, experts: usize, alpha: f64, t_total: u64, k: u64) -> Vec<u64> {
    let w = sample_weights(rng, experts, alpha);
    let sum: f64 = w.iter().sum();
    let total = t_total * k;
    let mut counts: Vec<u64> = w
        .iter()
        .map(|x| (x / sum * total as f64).round() as u64)
        .collect();
    // Fix rounding drift.
    let mut drift = counts.iter().sum::<u64>() as i64 - total as i64;
    let mut i = 0;
    while drift != 0 && experts > 0 {
        let idx = i % experts;
        if drift > 0 && counts[idx] > 0 {
            counts[idx] -= 1;
            drift -= 1;
        } else if drift < 0 {
            counts[idx] += 1;
            drift += 1;
        }
        i += 1;
    }
    counts
}

/// γ for an EP group: hottest GPU's routed-token share over the mean,
/// experts assigned to GPUs in contiguous blocks (the standard layout).
/// Averaged over `trials` samples for stability. γ = 1 when `ep <= 1`
/// (a single grouped GEMM is work-conserving across its experts).
pub fn ep_imbalance(experts: u64, alpha: f64, ep: u32, seed: u64, trials: u32) -> f64 {
    if ep <= 1 || experts == 0 {
        return 1.0;
    }
    let ep = ep.min(experts as u32);
    let per_gpu = (experts as usize).div_ceil(ep as usize);
    let mut rng = Rng::new(seed ^ MOE_SEED_SALT);
    let mut acc = 0.0;
    for _ in 0..trials.max(1) {
        let w = sample_weights(&mut rng, experts as usize, alpha);
        let total: f64 = w.iter().sum();
        let mean = total / ep as f64;
        let max_gpu = w
            .chunks(per_gpu)
            .map(|c| c.iter().sum::<f64>())
            .fold(0.0f64, f64::max);
        acc += max_gpu / mean;
    }
    acc / trials.max(1) as f64
}

const MOE_SEED_SALT: u64 = 0x5EED_0E0E_0E0E_5EED;

/// Convenience: γ for a model under `ep`-way expert parallelism.
pub fn model_imbalance(model: &ModelArch, ep: u32, seed: u64) -> f64 {
    match &model.moe {
        None => 1.0,
        Some(m) => ep_imbalance(m.num_experts, m.load_alpha, ep, seed, 16),
    }
}

/// Bytes one GPU contributes to a MoE dispatch (or combine) all-to-all:
/// every token's hidden vector travels to its `top_k` experts, sharded
/// over the EP group. One definition shared by both the dispatch and
/// combine legs of [`crate::ops::decompose`], so the two directions
/// can never drift apart; the placement layer then prices the
/// all-to-all over the EP group's span and rails.
pub fn dispatch_bytes_per_gpu(tokens: u64, top_k: u64, hidden: u64, ep: u64) -> f64 {
    tokens as f64 * top_k as f64 * hidden as f64 * crate::ops::ACT_BYTES / ep.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn counts_sum_exactly() {
        let mut rng = Rng::new(1);
        for (t, k) in [(128u64, 8u64), (4096, 2), (7, 8)] {
            let c = token_counts(&mut rng, 128, 1.2, t, k);
            assert_eq!(c.iter().sum::<u64>(), t * k);
        }
    }

    #[test]
    fn gamma_one_without_ep() {
        assert_eq!(ep_imbalance(128, 1.2, 1, 0, 8), 1.0);
        let dense = by_name("qwen3-32b").unwrap();
        assert_eq!(model_imbalance(&dense, 8, 0), 1.0);
    }

    #[test]
    fn gamma_grows_with_alpha() {
        let lo = ep_imbalance(128, 0.05, 8, 7, 32);
        let hi = ep_imbalance(128, 1.2, 8, 7, 32);
        assert!(lo < hi, "lo={lo} hi={hi}");
        assert!(lo >= 1.0 && lo < 1.4, "lo={lo}");
        assert!(hi > 1.15 && hi < 4.0, "hi={hi}");
    }

    #[test]
    fn gamma_grows_with_ep() {
        let e2 = ep_imbalance(128, 1.2, 2, 3, 32);
        let e16 = ep_imbalance(128, 1.2, 16, 3, 32);
        assert!(e16 > e2, "e2={e2} e16={e16}");
    }

    #[test]
    fn heavy_tail_top20_share() {
        // α=1.2 over 128 experts: top 20% of experts carry the majority
        // of the load (the Qwen3-235B observation).
        let mut rng = Rng::new(5);
        let mut share = 0.0;
        let trials = 64;
        for _ in 0..trials {
            let mut w = sample_weights(&mut rng, 128, 1.2);
            w.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let total: f64 = w.iter().sum();
            let top: f64 = w[..26].iter().sum();
            share += top / total;
        }
        share /= trials as f64;
        assert!(share > 0.5, "top-20% share {share}");
    }

    #[test]
    fn alpha_guard() {
        assert_eq!(clamp_alpha(1.0), 1.0 + ALPHA_GUARD);
        assert_eq!(clamp_alpha(0.999), 1.0 - ALPHA_GUARD);
        assert_eq!(clamp_alpha(0.5), 0.5);
        // No NaNs near the singularity.
        let mut rng = Rng::new(2);
        for w in sample_weights(&mut rng, 64, 1.0) {
            assert!(w.is_finite() && w > 0.0);
        }
    }
}

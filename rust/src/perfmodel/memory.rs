//! Memory feasibility: weights + KV cache + activation reserve per GPU.
//! Candidates that don't fit are pruned from the search space
//! ("Configurations exceeding memory capacity were automatically
//! pruned", paper §5.2), and the KV budget bounds batch size and the
//! context-token capacity.

use crate::config::{EngineConfig, ParallelSpec};
use crate::models::{Dtype, ModelArch};
use crate::ops::kv_bytes_per_gpu_layer;

/// Activation / workspace reserve per GPU, bytes (CUDA context, cublas
/// workspaces, activation peaks).
pub const ACT_RESERVE_BYTES: f64 = 4.0 * 1024.0 * 1024.0 * 1024.0;

/// Model weight bytes held by ONE GPU under the engine's parallelism.
pub fn weight_bytes_per_gpu(model: &ModelArch, eng: &EngineConfig) -> f64 {
    weight_bytes_per_gpu_parts(model, &eng.parallel, eng.weight_dtype)
}

/// [`weight_bytes_per_gpu`] from the layout parts alone — usable before
/// an [`EngineConfig`] exists, which is exactly the position the
/// backend flag resolver ([`crate::frameworks::Backend::resolve_flags`])
/// is in: flags depend on the weight footprint, the config needs the
/// flags.
pub fn weight_bytes_per_gpu_parts(
    model: &ModelArch,
    parallel: &ParallelSpec,
    weight_dtype: Dtype,
) -> f64 {
    let tp = parallel.tp as u64;
    let pp = parallel.pp as u64;
    let ep = parallel.ep.max(1) as u64;
    let wb = weight_dtype.bytes();

    // Embedding + LM head shard across TP.
    let embed = 2.0 * (model.vocab * model.hidden) as f64 / tp as f64 * wb;
    // Attention shards across TP.
    let attn = model.num_layers as f64 * model.attn_params_per_layer() as f64 / tp as f64 * wb;
    // FFN / MoE.
    let ffn: f64 = (0..model.num_layers)
        .map(|l| match &model.moe {
            Some(moe) if l >= moe.first_dense_layers => {
                let experts = if ep > 1 {
                    // EP shards whole experts; each kept at full width.
                    (moe.num_experts as f64 / ep as f64)
                        * 3.0
                        * (model.hidden * moe.expert_inter) as f64
                } else {
                    moe.num_experts as f64 * 3.0 * (model.hidden * moe.expert_inter) as f64
                        / tp as f64
                };
                let shared = 3.0 * (model.hidden * moe.shared_inter) as f64 / tp as f64;
                (experts + shared) * wb
            }
            _ => 3.0 * (model.hidden * model.inter) as f64 / tp as f64 * wb,
        })
        .sum();

    (embed + attn + ffn) / pp as f64
}

/// KV bytes per token held by ONE GPU (layers split over PP).
pub fn kv_bytes_per_token_gpu(model: &ModelArch, eng: &EngineConfig) -> f64 {
    let per_layer = kv_bytes_per_gpu_layer(model, eng.kv_dtype, eng.parallel.tp as u64);
    model.num_layers as f64 * per_layer / eng.parallel.pp as f64
}

/// KV-cache token capacity of one engine instance, after weights and the
/// activation reserve, scaled by the kv-fraction flag. 0 ⇒ infeasible.
pub fn kv_capacity_tokens(model: &ModelArch, gpu_mem_bytes: f64, eng: &EngineConfig) -> u64 {
    let weights = weight_bytes_per_gpu(model, eng);
    let free = gpu_mem_bytes - weights - ACT_RESERVE_BYTES;
    if free <= 0.0 {
        return 0;
    }
    let kv_budget = free * eng.flags.kv_frac;
    (kv_budget / kv_bytes_per_token_gpu(model, eng)) as u64
}

/// Can this engine hold `batch` concurrent requests of `isl+osl` tokens?
pub fn fits(model: &ModelArch, gpu_mem_bytes: f64, eng: &EngineConfig, isl: u32, osl: u32) -> bool {
    let needed = eng.batch as u64 * (isl + osl) as u64;
    kv_capacity_tokens(model, gpu_mem_bytes, eng) >= needed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::{by_name, Dtype};

    fn eng(tp: u32, ep: u32, batch: u32, dt: Dtype) -> EngineConfig {
        EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec { tp, pp: 1, ep, dp: 1 },
            batch,
            weight_dtype: dt,
            kv_dtype: dt,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        }
    }

    #[test]
    fn qwen32b_fp8_fits_tp1_on_h100_but_fp16_does_not() {
        let m = by_name("qwen3-32b").unwrap();
        let mem = h100_sxm().mem_bytes();
        // fp8: ~33 GB weights on one GPU — fits with ample KV room.
        let cap8 = kv_capacity_tokens(&m, mem, &eng(1, 1, 8, Dtype::Fp8));
        assert!(cap8 > 100_000, "cap8={cap8}");
        // fp16: ~66 GB weights + 4 GB reserve — KV squeezed hard (and
        // each token costs 2× the bytes).
        let cap16 = kv_capacity_tokens(&m, mem, &eng(1, 1, 8, Dtype::Fp16));
        assert!(cap16 < cap8 / 4, "cap16={cap16} cap8={cap8}");
    }

    #[test]
    fn tp_scales_weights_down() {
        let m = by_name("qwen3-32b").unwrap();
        let w1 = weight_bytes_per_gpu(&m, &eng(1, 1, 8, Dtype::Fp16));
        let w8 = weight_bytes_per_gpu(&m, &eng(8, 1, 8, Dtype::Fp16));
        let r = w1 / w8;
        assert!(r > 7.5 && r < 8.5, "ratio {r}");
    }

    #[test]
    fn deepseek_v3_needs_many_gpus() {
        let m = by_name("deepseek-v3").unwrap();
        let mem = h100_sxm().mem_bytes();
        // fp8 671B ≈ 671 GB: even TP8 single-node can't hold it with EP1.
        assert!(!fits(&m, mem, &eng(8, 1, 1, Dtype::Fp8), 1000, 100));
        // TP8 × EP8 over 8 GPUs (wide-EP: experts sharded 8-way) fits.
        let e = eng(8, 8, 1, Dtype::Fp8);
        let w = weight_bytes_per_gpu(&m, &e);
        assert!(w < 79.0 * 1.1e9, "w={w}");
    }

    #[test]
    fn batch_feasibility_monotone() {
        let m = by_name("llama3.1-8b").unwrap();
        let mem = h100_sxm().mem_bytes();
        assert!(fits(&m, mem, &eng(1, 1, 4, Dtype::Fp16), 4096, 512));
        assert!(!fits(&m, mem, &eng(1, 1, 4096, Dtype::Fp16), 4096, 512));
    }

    #[test]
    fn kv_frac_flag_scales_capacity() {
        let m = by_name("llama3.1-8b").unwrap();
        let mem = h100_sxm().mem_bytes();
        let mut lo = eng(1, 1, 8, Dtype::Fp16);
        lo.flags.kv_frac = 0.5;
        let hi = eng(1, 1, 8, Dtype::Fp16);
        let c_lo = kv_capacity_tokens(&m, mem, &lo);
        let c_hi = kv_capacity_tokens(&m, mem, &hi);
        assert!((c_hi as f64 / c_lo as f64 - 0.9 / 0.5).abs() < 0.05);
    }
}

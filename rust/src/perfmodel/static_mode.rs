//! Algorithm 1 — Static Mode Inference Performance Estimation.
//!
//! Strictly sequential processing of one fixed batch: TTFT = prefill
//! latency; TPOT = average decode-step latency over the output sequence,
//! estimated with the paper's stride-based optimization (default stride
//! 32): query the oracle at stride intervals and extrapolate each step's
//! cost across the next R tokens instead of querying every token.

use super::iteration::IterCtx;

/// Default stride S_stride (paper: 32).
pub const STRIDE: u64 = 32;

/// Returns (TTFT ms, TPOT ms) for a static batch.
///
/// * `isl` / `osl` — input/output lengths; `prefix` — cached prefix P.
/// * `batch` — fixed batch size B.
pub fn estimate(ctx: &IterCtx, isl: u64, osl: u64, prefix: u64, batch: u32) -> (f64, f64) {
    estimate_with_stride(ctx, isl, osl, prefix, batch, STRIDE)
}

/// Algorithm 1 with an explicit stride (ablation hook).
pub fn estimate_with_stride(
    ctx: &IterCtx,
    isl: u64,
    osl: u64,
    prefix: u64,
    batch: u32,
    stride: u64,
) -> (f64, f64) {
    let stride = stride.max(1);
    // Phase 1: context latency (TTFT).
    let isl_eff = isl.saturating_sub(prefix).max(1);
    let ttft = ctx.prefill_step_ms(batch, isl_eff, isl);

    // Phase 2: generation latency, stride-interpolated. All stride
    // points are priced in ONE oracle batch (steps_ms_batch) — a single
    // PJRT execution on the kernel-backed path.
    let mut t_gen = 0.0;
    if osl > 1 {
        let mut shapes = Vec::new();
        let mut weights = Vec::new();
        let mut k = 0u64;
        while k < osl - 1 {
            let s_seq = isl + k + 1; // current total sequence length
            shapes.push(crate::ops::StepShape::decode(batch as u64, s_seq));
            weights.push(stride.min(osl - 1 - k) as f64); // next R tokens
            k += stride;
        }
        let lat = ctx.steps_ms_batch(&shapes);
        t_gen = lat.iter().zip(&weights).map(|(l, w)| l * w).sum();
    }

    // Phase 3: TPOT.
    let tpot = if osl > 1 { t_gen / (osl - 1) as f64 } else { 0.0 };
    (ttft, tpot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::{h100_sxm, ClusterSpec};
    use crate::models::{by_name, Dtype, ModelArch};
    use crate::silicon::Silicon;

    fn fixture() -> (Silicon, ModelArch, ClusterSpec, EngineConfig) {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        (
            Silicon::new(cluster, Framework::TrtLlm.profile()),
            by_name("qwen3-32b").unwrap(),
            cluster,
            EngineConfig {
                framework: Framework::TrtLlm,
                parallel: ParallelSpec::tp(4),
                batch: 8,
                weight_dtype: Dtype::Fp8,
                kv_dtype: Dtype::Fp8,
                flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
                placement: crate::topology::Placement::packed(),
            },
        )
    }

    #[test]
    fn stride_close_to_exact() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let (_, tpot_exact) = estimate_with_stride(&ctx, 2048, 256, 0, 8, 1);
        let (_, tpot_s32) = estimate_with_stride(&ctx, 2048, 256, 0, 8, 32);
        let err = (tpot_s32 - tpot_exact).abs() / tpot_exact;
        assert!(err < 0.02, "stride error {err}");
    }

    #[test]
    fn prefix_reduces_ttft_only() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let (t0, p0) = estimate(&ctx, 4096, 128, 0, 4);
        let (t1, p1) = estimate(&ctx, 4096, 128, 3072, 4);
        assert!(t1 < t0 * 0.6, "t0={t0} t1={t1}");
        assert!((p1 - p0).abs() / p0 < 0.01);
    }

    #[test]
    fn osl_one_has_zero_tpot() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let (ttft, tpot) = estimate(&ctx, 1024, 1, 0, 2);
        assert!(ttft > 0.0);
        assert_eq!(tpot, 0.0);
    }

    #[test]
    fn tpot_grows_with_batch() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let (_, p1) = estimate(&ctx, 2048, 128, 0, 1);
        let (_, p64) = estimate(&ctx, 2048, 128, 0, 64);
        assert!(p64 > p1, "p1={p1} p64={p64}");
        // ...but far less than 64× (batching amortizes weight reads).
        assert!(p64 < p1 * 16.0);
    }
}

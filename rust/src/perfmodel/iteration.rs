//! Iteration-level latency (paper §4.3): one engine iteration's latency
//! = Σ operator latencies (from the oracle) + framework host overhead.
//! This is the GETSTEPLATENCY / GETMIXLAT / GETGENLAT primitive that
//! Algorithms 1–3 are built on.

use crate::config::EngineConfig;
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::ops::{decompose, StepShape};
use crate::perfdb::LatencyOracle;

use super::moe;

/// Context shared by every step-latency query of one estimation run.
pub struct IterCtx<'a> {
    pub oracle: &'a dyn LatencyOracle,
    pub model: &'a ModelArch,
    pub cluster: &'a ClusterSpec,
    pub eng: &'a EngineConfig,
    /// Cached MoE imbalance γ for this engine's EP degree.
    pub moe_gamma: f64,
}

impl<'a> IterCtx<'a> {
    pub fn new(
        oracle: &'a dyn LatencyOracle,
        model: &'a ModelArch,
        cluster: &'a ClusterSpec,
        eng: &'a EngineConfig,
    ) -> Self {
        let moe_gamma = moe::model_imbalance(model, eng.parallel.ep, 0x1517);
        IterCtx { oracle, model, cluster, eng, moe_gamma }
    }

    /// Latency of one iteration with the given token population, ms.
    pub fn step_ms(&self, shape: &StepShape) -> f64 {
        let ops = decompose(self.model, self.cluster, self.eng, shape, self.moe_gamma);
        let mut kernel_us = self.oracle.step_latency_us(&ops);
        // CUDA-graph replay removes per-kernel launches on decode-only
        // steps (mixed steps have dynamic shapes and cannot be graphed).
        if self.eng.flags.cuda_graph && shape.is_decode_only() {
            kernel_us -= crate::ops::CUDA_GRAPH_LAUNCH_SAVING
                * crate::ops::launch_overhead_us(&ops, self.cluster.gpu.launch_us);
            kernel_us = kernel_us.max(0.0);
        }
        let host_us = self
            .eng
            .framework
            .profile()
            .iter_host_overhead_us(self.eng.flags.cuda_graph, shape.is_decode_only());
        (kernel_us + host_us) / 1000.0
    }

    /// Latency of MANY iterations in one oracle round-trip: decompose
    /// every shape, price all ops in a single `latency_batch` call,
    /// then reassemble per-step sums (+ CUDA-graph and host adjustments).
    /// Collapses Algorithm 1's stride sweep from ~OSL/32 oracle calls to
    /// one — the §Perf L3 fix that makes the PJRT path competitive.
    pub fn steps_ms_batch(&self, shapes: &[StepShape]) -> Vec<f64> {
        let mut all_ops = Vec::with_capacity(shapes.len() * 16);
        let mut bounds = Vec::with_capacity(shapes.len());
        for shape in shapes {
            let ops = decompose(self.model, self.cluster, self.eng, shape, self.moe_gamma);
            bounds.push((all_ops.len(), ops.len()));
            all_ops.extend(ops);
        }
        let lat = self.oracle.latency_batch(&all_ops);
        let fw = self.eng.framework.profile();
        shapes
            .iter()
            .zip(&bounds)
            .map(|(shape, &(start, len))| {
                let ops = &all_ops[start..start + len];
                let mut kernel_us: f64 = ops
                    .iter()
                    .zip(&lat[start..start + len])
                    .map(|(o, l)| l * o.count() as f64)
                    .sum();
                if self.eng.flags.cuda_graph && shape.is_decode_only() {
                    kernel_us -= crate::ops::CUDA_GRAPH_LAUNCH_SAVING
                        * crate::ops::launch_overhead_us(ops, self.cluster.gpu.launch_us);
                    kernel_us = kernel_us.max(0.0);
                }
                let host_us =
                    fw.iter_host_overhead_us(self.eng.flags.cuda_graph, shape.is_decode_only());
                (kernel_us + host_us) / 1000.0
            })
            .collect()
    }

    /// GETSTEPLATENCY(batch, seq_len, 'prefill'): `batch` requests each
    /// prefilling `q` new tokens against `kv` total context.
    pub fn prefill_step_ms(&self, batch: u32, q: u64, kv: u64) -> f64 {
        self.step_ms(&StepShape::prefill(batch, q, kv))
    }

    /// GETSTEPLATENCY(batch, seq_len, 'decode').
    pub fn decode_step_ms(&self, batch: u64, kv: u64) -> f64 {
        self.step_ms(&StepShape::decode(batch, kv))
    }

    /// GETMIXLAT(N_ctx, N_gen, ISL, OSL): a mixed iteration carrying
    /// `n_ctx` prefill tokens (split into `ceil(n_ctx/isl)` requests)
    /// plus `n_gen` decode streams at mid-generation depth.
    pub fn mix_step_ms(&self, n_ctx: u64, n_gen: u64, isl: u64, osl: u64) -> f64 {
        let ctx_reqs = n_ctx.div_ceil(isl.max(1)).max(1) as u32;
        let ctx_q = (n_ctx / ctx_reqs as u64).max(1);
        let gen_kv = isl + osl / 2;
        self.step_ms(&StepShape {
            ctx_reqs,
            ctx_q,
            ctx_kv: isl.max(ctx_q),
            gen_reqs: n_gen,
            gen_kv,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::{by_name, Dtype};
    use crate::silicon::Silicon;

    fn fixture() -> (Silicon, ModelArch, ClusterSpec, EngineConfig) {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
        let model = by_name("qwen3-32b").unwrap();
        let eng = EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(2),
            batch: 8,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: crate::topology::Placement::packed(),
        };
        (sil, model, cluster, eng)
    }

    #[test]
    fn prefill_scales_superlinearly_with_isl() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let t1 = ctx.prefill_step_ms(1, 1024, 1024);
        let t4 = ctx.prefill_step_ms(1, 4096, 4096);
        assert!(t4 > t1 * 3.5, "t1={t1} t4={t4}");
    }

    #[test]
    fn decode_step_far_cheaper_than_prefill() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let p = ctx.prefill_step_ms(1, 4096, 4096);
        let d = ctx.decode_step_ms(8, 4096);
        assert!(d < p * 0.5, "prefill={p} decode={d}");
    }

    #[test]
    fn mix_step_costs_more_than_decode_only() {
        let (sil, model, cluster, eng) = fixture();
        let ctx = IterCtx::new(&sil, &model, &cluster, &eng);
        let mixed = ctx.mix_step_ms(4096, 8, 4096, 512);
        let gen = ctx.decode_step_ms(8, 4096 + 256);
        assert!(mixed > gen * 2.0, "mixed={mixed} gen={gen}");
    }
}
